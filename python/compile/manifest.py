"""The AOT artifact manifest: every (dataset preset x architecture)
executable the rust coordinator may request.

The dataset presets mirror ``rust/src/datagen/presets.rs`` — scaled-down
synthetic stand-ins for the paper's datasets (DESIGN.md §4).  Shapes here
are the *padded batch* shapes: ``b_max`` is the static batch size every
cluster-batch is padded to, chosen as ~1.3x the expected multi-cluster
batch size rounded up to the kernel tile (128).

Keep this list in sync with the experiment index in DESIGN.md §5; adding
an experiment usually means adding a line here and re-running
``make artifacts``.
"""

from __future__ import annotations

from typing import List

from compile.model import ModelConfig

# dataset presets: (task, f_in, classes, default hidden)
PPI = dict(task="multilabel", f_in=64, classes=121)
REDDIT = dict(task="multiclass", f_in=128, classes=41)
AMAZON = dict(task="multilabel", f_in=64, classes=58)
AMAZON2M = dict(task="multiclass", f_in=100, classes=47)
CORA = dict(task="multiclass", f_in=128, classes=7)
PUBMED = dict(task="multiclass", f_in=128, classes=3)


def _cfgs() -> List[ModelConfig]:
    out: List[ModelConfig] = []

    def add(name, ds, layers, f_hid, b_max, kind="train", residual=False):
        out.append(ModelConfig(
            name=name, task=ds["task"], layers=layers, f_in=ds["f_in"],
            f_hid=f_hid, classes=ds["classes"], b_max=b_max, kind=kind,
            residual=residual,
        ))

    # --- Table 2: random-vs-clustering partitions (Cora/Pubmed/PPI) -----
    add("cora_L2", CORA, 2, 128, 512)
    add("pubmed_L2", PUBMED, 2, 128, 2560)

    # --- PPI: Fig. 6, Tables 5/9/11, Fig. 5 ----------------------------
    # depth sweep 2..8, hidden 512, single-cluster batches (50 parts).
    for l in range(2, 9):
        add(f"ppi_L{l}", PPI, l, 512, 512)
    add("ppi_L2_fwd", PPI, 2, 512, 512, kind="forward")
    add("ppi_L5_fwd", PPI, 5, 512, 512, kind="forward")
    # VR-GCN baseline, depths 2..6 (Table 9).
    for l in range(2, 7):
        add(f"ppi_vrgcn_L{l}", PPI, l, 512, 512, kind="vrgcn")
    # GraphSAGE baseline: neighborhood-union batches, 4x budget.
    for l in (2, 3, 4):
        add(f"ppi_sage_L{l}", PPI, l, 512, 2048)
    # Table 10 SOTA: deep + wide.
    add("ppi_sota_L5", PPI, 5, 1024, 512)

    # --- Reddit: Figs. 2/4/6, Table 5 ----------------------------------
    for l in (2, 3, 4):
        add(f"reddit_L{l}", REDDIT, l, 128, 768)
        add(f"reddit_h512_L{l}", REDDIT, l, 512, 768)   # Table 5 (512)
        add(f"reddit_vrgcn_L{l}", REDDIT, l, 128, 768, kind="vrgcn")
        add(f"reddit_sage_L{l}", REDDIT, l, 128, 1536)
    add("reddit_small_L2", REDDIT, 2, 128, 256)          # Fig. 4 batches
    add("reddit_L2_fwd", REDDIT, 2, 128, 768, kind="forward")

    # --- Amazon: Fig. 6 ------------------------------------------------
    for l in (2, 3, 4):
        add(f"amazon_L{l}", AMAZON, l, 128, 384)
        add(f"amazon_vrgcn_L{l}", AMAZON, l, 128, 384, kind="vrgcn")

    # --- Amazon2M: Table 8 ---------------------------------------------
    for l in (2, 3, 4):
        add(f"amazon2m_L{l}", AMAZON2M, l, 400, 1792)
    for l in (2, 3):
        add(f"amazon2m_vrgcn_L{l}", AMAZON2M, l, 400, 1792, kind="vrgcn")
    add("amazon2m_L3_fwd", AMAZON2M, 3, 400, 1792, kind="forward")

    names = [c.name for c in out]
    assert len(names) == len(set(names)), "duplicate artifact names"
    return out


CONFIGS: List[ModelConfig] = _cfgs()


def by_name(name: str) -> ModelConfig:
    for c in CONFIGS:
        if c.name == name:
            return c
    raise KeyError(name)
