"""Layer-1 Pallas kernels for Cluster-GCN.

The per-batch hot spot of Cluster-GCN (eq. (1) of the paper) is one GCN
layer over the current cluster batch:

    Z = A_hat @ X @ W ;  X_next = relu(Z)           (hidden layers)
    Z = A_hat @ X @ W                               (output layer)

where ``A_hat`` is the renormalized (b, b) adjacency block of the batch
(dense — see DESIGN.md §Hardware-Adaptation: after graph clustering the
within-batch block is dense enough that on TPU the right realization is a
blocked dense matmul on the MXU, not a scatter/gather SpMM), ``X`` is the
(b, f) activation matrix and ``W`` the (f, g) weight matrix.

Kernel schedule
---------------
Grid is 1-D over row tiles of the batch: program ``i`` owns rows
``[i*bm, (i+1)*bm)``.  Per program the VMEM working set is

    A row stripe   (bm, b)      bm*b*4 bytes
    X              (b,  f)      b*f*4  bytes   (streamed once per program)
    W              (f,  g)      f*g*4  bytes
    H scratch      (bm, f)      bm*f*4 bytes   (A@X intermediate)
    O output tile  (bm, g)      bm*g*4 bytes

With the default ``bm = 128`` and the largest shipped config
(b=2048, f=512, g=512) this is ~6.5 MiB — comfortably inside a TPU core's
16 MiB VMEM, and both matmuls are (128, K) x (K, N) shapes that map onto
the 128x128 MXU systolic array at full occupancy.  For batches where
``b*f*4`` alone would overflow VMEM, ``gcn_layer_matmul`` K-tiles the
contraction (2-D grid) at the cost of re-multiplying by ``W`` per K step;
the AOT manifest picks the single-pass variant whenever it fits.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels are lowered through the Pallas interpreter into
plain HLO (while-loop over the grid + dynamic-slice).  Correctness is
pinned against the pure-jnp oracle in ``ref.py`` by ``python/tests``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-tile. 128 matches the MXU systolic array edge; see module
# docstring for the VMEM budget.
DEFAULT_BM = 128


def _gcn_layer_kernel(a_ref, x_ref, w_ref, o_ref, *, relu: bool):
    """One row-stripe of relu?(A @ X @ W).

    a_ref: (bm, b) stripe of the adjacency block.
    x_ref: (b, f) full activation matrix.
    w_ref: (f, g) weight matrix.
    o_ref: (bm, g) output stripe.
    """
    # H = A_stripe @ X: (bm, b) @ (b, f) -> (bm, f). f32 accumulation on
    # the MXU (preferred_element_type pins the accumulator dtype).
    h = jnp.dot(a_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    # Z = H @ W: (bm, f) @ (f, g) -> (bm, g).
    z = jnp.dot(h, w_ref[...], preferred_element_type=jnp.float32)
    if relu:
        z = jnp.maximum(z, 0.0)
    o_ref[...] = z


@functools.partial(jax.jit, static_argnames=("relu", "bm"))
def gcn_layer(a, x, w, *, relu: bool = True, bm: int = DEFAULT_BM):
    """Fused GCN layer ``relu?(a @ x @ w)`` as a row-tiled Pallas kernel.

    Args:
      a: (b, b) dense normalized adjacency block (rows padded with zeros
         for inert padding nodes).
      x: (b, f) activations.
      w: (f, g) weights.
      relu: apply the elementwise ReLU (hidden layers) or not (output).
      bm: row-tile size; must divide b.
    Returns:
      (b, g) output activations.
    """
    b, b2 = a.shape
    bx, f = x.shape
    f2, g = w.shape
    if b != b2 or b != bx or f != f2:
        raise ValueError(f"shape mismatch: a={a.shape} x={x.shape} w={w.shape}")
    if b % bm != 0:
        raise ValueError(f"row tile {bm} must divide batch {b}")
    grid = (b // bm,)
    return pl.pallas_call(
        functools.partial(_gcn_layer_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, b), lambda i: (i, 0)),  # A row stripe
            pl.BlockSpec((b, f), lambda i: (0, 0)),   # X resident
            pl.BlockSpec((f, g), lambda i: (0, 0)),   # W resident
        ],
        out_specs=pl.BlockSpec((bm, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g), jnp.float32),
        interpret=True,
    )(a, x, w)


def _matmul_kernel(a_ref, b_ref, o_ref, *, relu: bool):
    z = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    if relu:
        z = jnp.maximum(z, 0.0)
    o_ref[...] = z


@functools.partial(jax.jit, static_argnames=("bm", "relu"))
def matmul(a, b, *, bm: int = DEFAULT_BM, relu: bool = False):
    """Row-tiled Pallas matmul ``relu?(a @ b)`` used by the right-
    associated layer variant and the custom-VJP backward pass
    (dW = H^T dZ, dX = A^T dZ W^T are all plain matmuls)."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"shape mismatch: a={a.shape} b={b.shape}")
    tile = bm if m % bm == 0 else m
    grid = (m // tile,)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def layer_flops(b: int, f: int, g: int) -> tuple:
    """(left, right) MAC counts for Z = A@X@W: left = (A@X)@W costs
    b²f + bfg; right = A@(X@W) costs bfg + b²g.  The §Perf association
    pick: right wins iff g < f (e.g. wide-hidden → narrow-output
    layers)."""
    return (b * b * f + b * f * g, b * f * g + b * b * g)


def _gcn_layer_ktiled_kernel(a_ref, x_ref, w_ref, o_ref, *, relu: bool, nk: int):
    """K-tiled variant: 2-D grid (row tiles, K tiles) for batches whose
    full X does not fit VMEM.  Accumulates (A_blk @ X_blk) @ W into the
    output tile; W-multiply is distributed over the K sum (valid since W
    is constant across the contraction)."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = jnp.dot(a_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += jnp.dot(h, w_ref[...], preferred_element_type=jnp.float32)

    if relu:
        @pl.when(k == nk - 1)
        def _act():
            o_ref[...] = jnp.maximum(o_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bk"))
def gcn_layer_ktiled(a, x, w, *, relu: bool = True,
                     bm: int = DEFAULT_BM, bk: int = 512):
    """K-tiled fused GCN layer for large b*f (see module docstring)."""
    b, _ = a.shape
    _, f = x.shape
    _, g = w.shape
    if b % bm != 0 or b % bk != 0:
        raise ValueError(f"tiles ({bm},{bk}) must divide batch {b}")
    nk = b // bk
    grid = (b // bm, nk)
    return pl.pallas_call(
        functools.partial(_gcn_layer_ktiled_kernel, relu=relu, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, f), lambda i, k: (k, 0)),
            pl.BlockSpec((f, g), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, g), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g), jnp.float32),
        interpret=True,
    )(a, x, w)


# ---------------------------------------------------------------------------
# Differentiable fused layer: custom VJP so jax.grad works through the
# Pallas kernels (pallas_call has no automatic transpose rule).  Both
# forward and backward pick the cheaper matmul association per layer
# (§Perf: right-association halves the output-layer cost when the
# class count is far below the hidden width, as on PPI).
# ---------------------------------------------------------------------------

def _use_right(b: int, f: int, g: int) -> bool:
    left, right = layer_flops(b, f, g)
    return right < left


def gcn_layer_auto(a, x, w, *, relu: bool = True):
    """Non-differentiable fused layer with automatic association pick
    (forward/eval artifacts)."""
    b, f = x.shape
    g = w.shape[1]
    if _use_right(b, f, g):
        return matmul(a, matmul(x, w), relu=relu)
    return gcn_layer(a, x, w, relu=relu)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def gcn_layer_ad(a, x, w, relu: bool = True):
    """Differentiable relu?(a @ x @ w); gradients flow to x and w only
    (the adjacency block is data, not a parameter)."""
    b, f = x.shape
    g = w.shape[1]
    if _use_right(b, f, g):
        return matmul(a, matmul(x, w), relu=relu)
    return gcn_layer(a, x, w, relu=relu)


def _gcn_layer_fwd(a, x, w, relu):
    b, f = x.shape
    g = w.shape[1]
    if _use_right(b, f, g):
        xw = matmul(x, w)                    # (b, g), cheap
        z = matmul(a, xw)                    # (b, g)
        out = jnp.maximum(z, 0.0) if relu else z
        return out, (a, x, w, out, True)
    h = matmul(a, x)                         # cache A@X: reused by dW
    z = matmul(h, w)
    out = jnp.maximum(z, 0.0) if relu else z
    return out, (a, h, w, out, False)


def _gcn_layer_bwd(relu, res, g_out):
    a, xh, w, out, right = res
    dz = jnp.where(out > 0.0, g_out, 0.0) if relu else g_out
    if right:
        # Z = A @ (X @ W): share T = A^T dZ (b, g) between dW and dX
        x = xh
        t = matmul(a.T, dz)                  # (b, g)
        dw = matmul(x.T, t)                  # (f, g)
        dx = matmul(t, w.T)                  # (b, f)
    else:
        # Z = (A @ X) @ W with H = A @ X cached
        h = xh
        dw = matmul(h.T, dz)                 # (f, g)
        dh = matmul(dz, w.T)                 # (b, f)
        dx = matmul(a.T, dh)                 # (b, f); A^T since A not sym
    return (jnp.zeros_like(a), dx, dw)


gcn_layer_ad.defvjp(_gcn_layer_fwd, _gcn_layer_bwd)


# Differentiable matmul (pallas_call lacks an automatic transpose rule);
# backward is itself expressed with the pallas matmul.
@jax.custom_vjp
def matmul_ad(a, b):
    return matmul(a, b)


def _matmul_ad_fwd(a, b):
    return matmul(a, b), (a, b)


def _matmul_ad_bwd(res, g):
    a, b = res
    return matmul(g, b.T), matmul(a.T, g)


matmul_ad.defvjp(_matmul_ad_fwd, _matmul_ad_bwd)


def vmem_bytes(b: int, f: int, g: int, bm: int = DEFAULT_BM) -> int:
    """Per-program VMEM working set of ``gcn_layer`` in bytes (see module
    docstring); used by the AOT manifest to pick the kernel variant and by
    DESIGN/EXPERIMENTS to report the TPU feasibility estimate."""
    return 4 * (bm * b + b * f + f * g + bm * f + bm * g)


def mxu_utilization_estimate(b: int, f: int, g: int, bm: int = DEFAULT_BM) -> float:
    """Fraction of MXU-issue slots doing useful work, assuming 128x128x128
    macro-ops: both matmuls have M=bm(=128 by default) and K,N multiples
    of 128 in shipped configs, so the only waste is edge padding."""
    def eff(m, k, n):
        pad = lambda v: ((v + 127) // 128) * 128
        return (m * k * n) / (pad(m) * pad(k) * pad(n))
    flops_1 = b * b * f  # A@X per full batch
    flops_2 = b * f * g
    return (flops_1 * eff(bm, b, f) + flops_2 * eff(bm, f, g)) / (
        flops_1 + flops_2
    )
