"""Pure-jnp oracles for the Pallas kernels and the full model forward.

Every kernel in ``gcn_layer.py`` must match its oracle to float32
round-off; ``python/tests/test_kernel.py`` sweeps shapes/dtypes with
hypothesis and asserts allclose.  The oracles are also reused by the model
tests to validate the end-to-end forward and the analytic gradients.
"""

from __future__ import annotations

import jax.numpy as jnp


def gcn_layer_ref(a, x, w, *, relu: bool = True):
    """relu?(a @ x @ w) in plain jnp."""
    z = (a @ x) @ w
    return jnp.maximum(z, 0.0) if relu else z


def matmul_ref(a, b):
    return a @ b


def gcn_forward_ref(a, x, weights, *, residual: bool = False):
    """L-layer GCN forward (eq. (1), optionally eq. (8) residual): returns
    the final-layer logits. ``weights`` is a list of (f_l, f_{l+1})."""
    h = x
    n = len(weights)
    for i, w in enumerate(weights):
        last = i == n - 1
        z = gcn_layer_ref(a, h, w, relu=not last)
        if residual and not last and z.shape == h.shape:
            z = z + h
        h = z
    return h


def softmax_xent_ref(logits, y_onehot, mask):
    """Masked mean softmax cross-entropy (multi-class)."""
    logz = logits - jnp.max(logits, axis=1, keepdims=True)
    logp = logz - jnp.log(jnp.sum(jnp.exp(logz), axis=1, keepdims=True))
    ce = -jnp.sum(y_onehot * logp, axis=1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(ce * mask) / denom


def sigmoid_bce_ref(logits, y, mask):
    """Masked mean sigmoid binary cross-entropy (multi-label)."""
    # max(z, 0) - z*y + log(1 + exp(-|z|))  (stable BCE-with-logits)
    per = jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(
        jnp.exp(-jnp.abs(logits))
    )
    per_node = jnp.mean(per, axis=1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_node * mask) / denom
