"""Layer-2: the Cluster-GCN model as JAX functions built on the Pallas
kernels, AOT-exported by ``aot.py`` and executed from rust via PJRT.

Exported entry points (all shapes static, fixed by a ``ModelConfig``):

``train_step``
    One fused SGD step of Algorithm 1 (lines 5-6): forward over the batch
    adjacency block, masked loss (eq. (2)/(7)), ``jax.grad`` backward
    through the custom-VJP Pallas layers, and an Adam update — a single
    executable so the rust hot loop does one PJRT execute per step.

``forward``
    Batch logits for evaluation / the runtime parity tests.

``vrgcn_train_step``
    The VR-GCN baseline estimator (Chen et al., ICML'18): the layer input
    is the in-batch propagation ``A_in @ X_l`` *plus* a host-precomputed
    historical contribution ``Hc_l = A_out @ H_l`` (stale embeddings of
    out-of-batch neighbors); the step additionally returns each hidden
    activation so the rust coordinator can refresh its O(NLF) history
    store — reproducing both VR-GCN's convergence behaviour and its
    memory cost.

Argument order convention (mirrored by rust ``runtime::artifacts``):

    train_step : W_0..W_{L-1}, m_0.., v_0.., step, lr, A, X, Y, mask
    forward    : W_0..W_{L-1}, A, X
    vrgcn      : W_0..W_{L-1}, m_0.., v_0.., step, lr, A, Hc_0..Hc_{L-1},
                 X, Y, mask

Diagonal enhancement (eqs. (9)-(11)) needs no model variant: every
enhancement is a transform of the *adjacency block*, which rust builds
host-side and feeds through the same ``A`` input.  Only the residual
connection (eq. (8)) changes the dataflow and is a compile-time flag.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp

from compile.kernels.gcn_layer import (
    gcn_layer_ad,
    gcn_layer_auto,
    matmul,
    matmul_ad,
)

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture of one AOT artifact."""

    name: str
    task: str          # "multiclass" | "multilabel"
    layers: int        # L >= 1
    f_in: int
    f_hid: int
    classes: int
    b_max: int         # padded batch size (divisible by the kernel tile)
    residual: bool = False
    kind: str = "train"  # "train" | "forward" | "vrgcn"

    def weight_shapes(self) -> List[tuple]:
        dims = [self.f_in] + [self.f_hid] * (self.layers - 1) + [self.classes]
        return [(dims[i], dims[i + 1]) for i in range(self.layers)]

    def layer_in_dims(self) -> List[int]:
        return [self.f_in] + [self.f_hid] * (self.layers - 1)


def forward(cfg: ModelConfig, weights: Sequence[jnp.ndarray], a, x,
            *, differentiable: bool = False):
    """L-layer GCN forward (eq. (1) / eq. (8)) over one batch block."""
    layer = gcn_layer_ad if differentiable else (
        lambda a_, x_, w_, relu: gcn_layer_auto(a_, x_, w_, relu=relu)
    )
    h = x
    n = len(weights)
    for i, w in enumerate(weights):
        last = i == n - 1
        z = layer(a, h, w, not last)
        if cfg.residual and not last and z.shape == h.shape:
            z = z + h
        h = z
    return h


def masked_loss(cfg: ModelConfig, logits, y, mask):
    """Eq. (2)/(7): masked mean loss over labeled in-batch nodes."""
    if cfg.task == "multiclass":
        logz = logits - jax.lax.stop_gradient(
            jnp.max(logits, axis=1, keepdims=True)
        )
        logp = logz - jnp.log(jnp.sum(jnp.exp(logz), axis=1, keepdims=True))
        per_node = -jnp.sum(y * logp, axis=1)
    elif cfg.task == "multilabel":
        per = jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(
            jnp.exp(-jnp.abs(logits))
        )
        per_node = jnp.mean(per, axis=1)
    else:
        raise ValueError(f"unknown task {cfg.task!r}")
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_node * mask) / denom


def adam_update(w, g, m, v, step, lr):
    """One Adam step (the paper trains every method with Adam, lr=0.01)."""
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1 ** step)
    vhat = v / (1.0 - ADAM_B2 ** step)
    w = w - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return w, m, v


def make_train_step(cfg: ModelConfig):
    """Build the flat-signature train_step for AOT export.

    Returns ``fn(*args) -> tuple`` with args/outputs in the module
    docstring's order; all leaves are f32 arrays (step/lr are f32 scalars
    so the whole signature is one dtype — simpler on the rust side).
    """
    L = cfg.layers

    def train_step(*args):
        ws = list(args[0:L])
        ms = list(args[L:2 * L])
        vs = list(args[2 * L:3 * L])
        step, lr, a, x, y, mask = args[3 * L:]

        def loss_fn(ws_):
            logits = forward(cfg, ws_, a, x, differentiable=True)
            return masked_loss(cfg, logits, y, mask)

        loss, grads = jax.value_and_grad(loss_fn)(ws)
        new_w, new_m, new_v = [], [], []
        for w, g, m, v in zip(ws, grads, ms, vs):
            w2, m2, v2 = adam_update(w, g, m, v, step, lr)
            new_w.append(w2)
            new_m.append(m2)
            new_v.append(v2)
        return tuple(new_w) + tuple(new_m) + tuple(new_v) + (loss,)

    return train_step


def make_forward(cfg: ModelConfig):
    L = cfg.layers

    def fwd(*args):
        ws = list(args[0:L])
        a, x = args[L:]
        return (forward(cfg, ws, a, x, differentiable=False),)

    return fwd


def vrgcn_forward(cfg: ModelConfig, weights, a_in, hcs, x,
                  *, differentiable: bool = True):
    """VR-GCN layer: X_{l+1} = relu((A_in @ X_l + Hc_l) @ W_l).

    ``Hc_l`` is the variance-reduction term: the propagated *historical*
    activations of out-of-batch neighbors, precomputed host-side from the
    O(NLF) history store (gradients do not flow into history — exactly the
    approximation VR-GCN makes).  Returns (logits, hidden activations).
    """
    layer_mm = matmul_ad if differentiable else matmul
    h = x
    hiddens = []
    n = len(weights)
    for i, w in enumerate(weights):
        last = i == n - 1
        prop = layer_mm(a_in, h) + jax.lax.stop_gradient(hcs[i])
        z = layer_mm(prop, w)
        if not last:
            z = jnp.maximum(z, 0.0)
            hiddens.append(z)
        h = z
    return h, hiddens


def make_vrgcn_train_step(cfg: ModelConfig):
    L = cfg.layers

    def train_step(*args):
        ws = list(args[0:L])
        ms = list(args[L:2 * L])
        vs = list(args[2 * L:3 * L])
        rest = args[3 * L:]
        step, lr, a_in = rest[0], rest[1], rest[2]
        hcs = list(rest[3:3 + L])
        x, y, mask = rest[3 + L:]

        def loss_fn(ws_):
            logits, hiddens = vrgcn_forward(cfg, ws_, a_in, hcs, x)
            return masked_loss(cfg, logits, y, mask), hiddens

        (loss, hiddens), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(ws)
        new_w, new_m, new_v = [], [], []
        for w, g, m, v in zip(ws, grads, ms, vs):
            w2, m2, v2 = adam_update(w, g, m, v, step, lr)
            new_w.append(w2)
            new_m.append(m2)
            new_v.append(v2)
        return (
            tuple(new_w) + tuple(new_m) + tuple(new_v) + (loss,)
            + tuple(hiddens)
        )

    return train_step


def example_args(cfg: ModelConfig):
    """jax.ShapeDtypeStruct specs in the artifact's argument order."""
    f32 = jnp.float32
    s = lambda *dims: jax.ShapeDtypeStruct(tuple(dims), f32)
    b, c = cfg.b_max, cfg.classes
    wspecs = [s(*sh) for sh in cfg.weight_shapes()]
    if cfg.kind == "forward":
        return wspecs + [s(b, b), s(b, cfg.f_in)]
    state = wspecs + wspecs + wspecs + [s(), s()]
    if cfg.kind == "train":
        return state + [s(b, b), s(b, cfg.f_in), s(b, c), s(b)]
    if cfg.kind == "vrgcn":
        hc = [s(b, d) for d in cfg.layer_in_dims()]
        return state + [s(b, b)] + hc + [s(b, cfg.f_in), s(b, c), s(b)]
    raise ValueError(f"unknown kind {cfg.kind!r}")


def build_fn(cfg: ModelConfig):
    if cfg.kind == "train":
        return make_train_step(cfg)
    if cfg.kind == "forward":
        return make_forward(cfg)
    if cfg.kind == "vrgcn":
        return make_vrgcn_train_step(cfg)
    raise ValueError(f"unknown kind {cfg.kind!r}")


def init_weights(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """Glorot-uniform init, matching rust's ``coordinator::init`` (same
    SplitMix64 stream so runs are reproducible across layers)."""
    key = jax.random.PRNGKey(seed)
    ws = []
    for (fi, fo) in cfg.weight_shapes():
        key, sub = jax.random.split(key)
        bound = (6.0 / (fi + fo)) ** 0.5
        ws.append(jax.random.uniform(sub, (fi, fo), jnp.float32,
                                     -bound, bound))
    return ws
