"""AOT export: lower every manifest config to HLO **text** + write the
JSON manifest the rust runtime discovers artifacts through.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` rust crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--only name_prefix]

Python runs ONCE here; the rust binary is self-contained afterwards.
Incremental: a config is skipped when its .hlo.txt already exists and is
newer than the compile/ sources (make-level dependency also guards this).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from compile import manifest
from compile.model import ModelConfig, build_fn, example_args
from compile.kernels.gcn_layer import vmem_bytes, mxu_utilization_estimate


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(cfg: ModelConfig) -> str:
    fn = build_fn(cfg)
    specs = example_args(cfg)
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def manifest_entry(cfg: ModelConfig, filename: str) -> dict:
    return {
        "name": cfg.name,
        "file": filename,
        "kind": cfg.kind,
        "task": cfg.task,
        "layers": cfg.layers,
        "f_in": cfg.f_in,
        "f_hid": cfg.f_hid,
        "classes": cfg.classes,
        "b_max": cfg.b_max,
        "residual": cfg.residual,
        "weight_shapes": [list(s) for s in cfg.weight_shapes()],
        "vmem_bytes_est": vmem_bytes(cfg.b_max, max(cfg.f_in, cfg.f_hid),
                                     max(cfg.f_hid, cfg.classes)),
        "mxu_utilization_est": round(
            mxu_utilization_estimate(cfg.b_max,
                                     max(cfg.f_in, cfg.f_hid),
                                     max(cfg.f_hid, cfg.classes)), 4),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="only lower configs whose name starts with this")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    total = skipped = 0
    t_start = time.time()
    for cfg in manifest.CONFIGS:
        filename = f"{cfg.name}.hlo.txt"
        path = os.path.join(args.out_dir, filename)
        entries.append(manifest_entry(cfg, filename))
        if args.only and not cfg.name.startswith(args.only):
            continue
        total += 1
        if not args.force and os.path.exists(path):
            skipped += 1
            continue
        t0 = time.time()
        text = lower_config(cfg)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
        print(f"  {cfg.name}: {len(text)} chars in {time.time()-t0:.1f}s",
              flush=True)

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump({"artifacts": entries}, f, indent=1, sort_keys=True)
    print(f"aot: {total - skipped} lowered, {skipped} up-to-date, "
          f"manifest {len(entries)} entries, {time.time()-t_start:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
