"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (tile-aligned and ragged-in-f dims), value
ranges, and the relu flag; every kernel must match `ref.py` to f32
round-off.  This is the core correctness signal for the compute layer —
the AOT artifacts embed exactly these kernels.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gcn_layer import (
    gcn_layer,
    gcn_layer_ad,
    gcn_layer_ktiled,
    matmul,
    mxu_utilization_estimate,
    vmem_bytes,
)

import jax

RTOL = 1e-5
ATOL = 1e-5


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# fixed-shape smoke tests
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("relu", [True, False])
@pytest.mark.parametrize("b,f,g", [(128, 64, 32), (256, 128, 121), (512, 50, 16)])
def test_gcn_layer_matches_ref(b, f, g, relu):
    rng = np.random.default_rng(b + f + g)
    a, x, w = rand(rng, b, b), rand(rng, b, f), rand(rng, f, g)
    out = gcn_layer(a, x, w, relu=relu)
    expect = ref.gcn_layer_ref(a, x, w, relu=relu)
    np.testing.assert_allclose(out, expect, rtol=RTOL, atol=1e-3)


@pytest.mark.parametrize("m,k,n", [(128, 64, 32), (100, 7, 13), (256, 256, 256)])
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k)
    a, b = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(
        matmul(a, b), ref.matmul_ref(a, b), rtol=RTOL, atol=1e-3
    )


@pytest.mark.parametrize("bm,bk", [(128, 512), (256, 256), (128, 128)])
def test_gcn_layer_ktiled_matches_single_pass(bm, bk):
    rng = np.random.default_rng(bm)
    b, f, g = 512, 64, 48
    a, x, w = rand(rng, b, b), rand(rng, b, f), rand(rng, f, g)
    out = gcn_layer_ktiled(a, x, w, relu=True, bm=bm, bk=bk)
    expect = ref.gcn_layer_ref(a, x, w, relu=True)
    np.testing.assert_allclose(out, expect, rtol=RTOL, atol=1e-3)


def test_shape_validation():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        gcn_layer(rand(rng, 128, 128), rand(rng, 64, 8), rand(rng, 8, 4))
    with pytest.raises(ValueError):
        gcn_layer(rand(rng, 100, 100), rand(rng, 100, 8), rand(rng, 8, 4),
                  bm=64)  # 64 does not divide 100
    with pytest.raises(ValueError):
        matmul(rand(rng, 8, 4), rand(rng, 5, 2))


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

tile_dims = st.sampled_from([128, 256, 384])
feat_dims = st.integers(min_value=1, max_value=96)
scales = st.sampled_from([1e-3, 1.0, 1e3])


@settings(max_examples=25, deadline=None)
@given(b=tile_dims, f=feat_dims, g=feat_dims, relu=st.booleans(),
       scale=scales, seed=st.integers(0, 2**31 - 1))
def test_gcn_layer_hypothesis(b, f, g, relu, scale, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, b, b) * scale
    x, w = rand(rng, b, f), rand(rng, f, g)
    out = np.asarray(gcn_layer(a, x, w, relu=relu))
    expect = np.asarray(ref.gcn_layer_ref(a, x, w, relu=relu))
    tol = 1e-3 * max(scale, 1.0) * np.sqrt(b)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=tol)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 64), n=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(
        np.asarray(matmul(a, b)), a @ b, rtol=1e-4, atol=1e-3
    )


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([128, 256]), f=st.integers(2, 48),
       g=st.integers(2, 48), seed=st.integers(0, 2**31 - 1))
def test_padding_rows_inert(b, f, g, seed):
    """Zero rows/cols of A (batch padding) must produce zero outputs and
    not perturb real rows — the padding invariant batch assembly relies
    on."""
    rng = np.random.default_rng(seed)
    n_real = b // 2
    a = np.zeros((b, b), np.float32)
    a[:n_real, :n_real] = rand(rng, n_real, n_real)
    x = rand(rng, b, f)
    w = rand(rng, f, g)
    out = np.asarray(gcn_layer(a, x, w, relu=False))
    # padded rows: A row is zero -> output row is zero
    np.testing.assert_allclose(out[n_real:], 0.0, atol=1e-6)
    # real rows match the unpadded computation
    small = ref.gcn_layer_ref(a[:n_real, :n_real], x[:n_real], w, relu=False)
    np.testing.assert_allclose(out[:n_real], small, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# differentiable wrapper
# ---------------------------------------------------------------------------

def test_layer_flops_association_pick():
    from compile.kernels.gcn_layer import layer_flops

    # wide hidden -> narrow output: right association must be cheaper
    left, right = layer_flops(512, 512, 121)
    assert right < left
    # narrow -> wide: left cheaper
    left, right = layer_flops(512, 64, 512)
    assert left < right


def test_gcn_layer_auto_matches_ref_both_associations():
    from compile.kernels.gcn_layer import gcn_layer_auto

    rng = np.random.default_rng(11)
    for (b, f, g) in [(128, 96, 8), (128, 8, 96)]:  # right / left paths
        a, x, w = rand(rng, b, b), rand(rng, b, f), rand(rng, f, g)
        out = gcn_layer_auto(a, x, w, relu=True)
        expect = ref.gcn_layer_ref(a, x, w, relu=True)
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-3)


def test_custom_vjp_right_association_grads():
    """The right-associated VJP (wide f, narrow g) must match ref grads."""
    rng = np.random.default_rng(12)
    b, f, g = 128, 64, 4  # g << f -> right path
    a, x, w = rand(rng, b, b) * 0.1, rand(rng, b, f), rand(rng, f, g)

    def loss_kernel(x_, w_):
        return jnp.sum(gcn_layer_ad(a, x_, w_, True) ** 2)

    def loss_ref(x_, w_):
        return jnp.sum(ref.gcn_layer_ref(a, x_, w_, relu=True) ** 2)

    gx_k, gw_k = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(gw_k, gw_r, rtol=1e-4, atol=1e-3)


def test_matmul_fused_relu():
    rng = np.random.default_rng(13)
    a, b = rand(rng, 64, 32), rand(rng, 32, 16)
    np.testing.assert_allclose(
        matmul(a, b, relu=True), np.maximum(a @ b, 0.0), rtol=1e-4, atol=1e-3
    )


def test_custom_vjp_matches_jax_grad_of_ref():
    rng = np.random.default_rng(7)
    b, f, g = 128, 16, 8
    a, x, w = rand(rng, b, b) * 0.1, rand(rng, b, f), rand(rng, f, g)

    def loss_kernel(x_, w_):
        return jnp.sum(gcn_layer_ad(a, x_, w_, True) ** 2)

    def loss_ref(x_, w_):
        return jnp.sum(ref.gcn_layer_ref(a, x_, w_, relu=True) ** 2)

    gx_k, gw_k = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(gw_k, gw_r, rtol=1e-4, atol=1e-3)


def test_vjp_no_grad_to_adjacency():
    rng = np.random.default_rng(8)
    b, f, g = 128, 8, 4
    a, x, w = rand(rng, b, b), rand(rng, b, f), rand(rng, f, g)
    ga = jax.grad(lambda a_: jnp.sum(gcn_layer_ad(a_, x, w, True)))(a)
    np.testing.assert_allclose(ga, 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# feasibility estimators
# ---------------------------------------------------------------------------

def test_vmem_estimate_within_tpu_budget_for_shipped_configs():
    from compile.manifest import CONFIGS

    for cfg in CONFIGS:
        f = max(cfg.f_in, cfg.f_hid)
        g = max(cfg.f_hid, cfg.classes)
        vb = vmem_bytes(cfg.b_max, f, g)
        assert vb < 16 * 2**20, f"{cfg.name}: VMEM estimate {vb} > 16MiB"


def test_mxu_utilization_reasonable():
    # fully tile-aligned: perfect
    assert mxu_utilization_estimate(2048, 512, 512) == pytest.approx(1.0)
    # ragged small dims waste MXU slots
    assert mxu_utilization_estimate(256, 50, 121) < 1.0
