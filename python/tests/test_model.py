"""L2 correctness: model forward vs oracle, analytic vs numeric
gradients, Adam semantics, the train_step contract (argument order,
output order, loss behaviour), and the VR-GCN estimator."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def cfg_multiclass(layers=2, residual=False, kind="train"):
    return model.ModelConfig(
        name="t", task="multiclass", layers=layers, f_in=12, f_hid=24,
        classes=5, b_max=128, residual=residual, kind=kind,
    )


def cfg_multilabel(layers=3):
    return model.ModelConfig(
        name="t", task="multilabel", layers=layers, f_in=10, f_hid=16,
        classes=7, b_max=128,
    )


def make_batch(cfg, rng, n_real=100):
    b = cfg.b_max
    a = np.zeros((b, b), np.float32)
    block = rng.random((n_real, n_real)).astype(np.float32)
    block = (block < 0.05).astype(np.float32)
    # row-normalize with self loops
    np.fill_diagonal(block, 1.0)
    block /= block.sum(1, keepdims=True)
    a[:n_real, :n_real] = block
    x = rng.standard_normal((b, cfg.f_in)).astype(np.float32)
    y = np.zeros((b, cfg.classes), np.float32)
    if cfg.task == "multiclass":
        idx = rng.integers(0, cfg.classes, n_real)
        y[np.arange(n_real), idx] = 1.0
    else:
        y[:n_real] = (rng.random((n_real, cfg.classes)) < 0.3).astype(np.float32)
    mask = np.zeros((b,), np.float32)
    mask[:n_real] = 1.0
    return a, x, y, mask


def test_forward_matches_ref():
    cfg = cfg_multiclass(layers=3)
    rng = np.random.default_rng(0)
    a, x, _, _ = make_batch(cfg, rng)
    ws = model.init_weights(cfg, seed=1)
    out = model.forward(cfg, ws, a, x)
    expect = ref.gcn_forward_ref(a, x, ws)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-3)


def test_forward_residual_differs_and_matches_ref():
    cfg = cfg_multiclass(layers=3, residual=True)
    rng = np.random.default_rng(1)
    a, x, _, _ = make_batch(cfg, rng)
    ws = model.init_weights(cfg, seed=2)
    out_res = model.forward(cfg, ws, a, x)
    expect = ref.gcn_forward_ref(a, x, ws, residual=True)
    np.testing.assert_allclose(out_res, expect, rtol=1e-4, atol=1e-3)
    plain = ref.gcn_forward_ref(a, x, ws, residual=False)
    assert not np.allclose(out_res, plain)


@pytest.mark.parametrize("task", ["multiclass", "multilabel"])
def test_loss_matches_ref(task):
    cfg = cfg_multiclass() if task == "multiclass" else cfg_multilabel()
    rng = np.random.default_rng(3)
    _, _, y, mask = make_batch(cfg, rng)
    logits = rng.standard_normal((cfg.b_max, cfg.classes)).astype(np.float32)
    got = model.masked_loss(cfg, logits, y, mask)
    if task == "multiclass":
        expect = ref.softmax_xent_ref(logits, y, mask)
    else:
        expect = ref.sigmoid_bce_ref(logits, y, mask)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)


def test_masked_loss_ignores_padding():
    cfg = cfg_multiclass()
    rng = np.random.default_rng(4)
    _, _, y, mask = make_batch(cfg, rng, n_real=50)
    logits = rng.standard_normal((cfg.b_max, cfg.classes)).astype(np.float32)
    base = model.masked_loss(cfg, logits, y, mask)
    # perturb only masked-out rows: loss must not change
    logits2 = logits.copy()
    logits2[50:] += 100.0
    np.testing.assert_allclose(
        base, model.masked_loss(cfg, logits2, y, mask), rtol=1e-6
    )


def test_grads_match_finite_difference():
    cfg = cfg_multiclass(layers=2)
    rng = np.random.default_rng(5)
    a, x, y, mask = make_batch(cfg, rng, n_real=64)
    ws = model.init_weights(cfg, seed=3)

    def loss_fn(ws_):
        logits = model.forward(cfg, ws_, a, x, differentiable=True)
        return model.masked_loss(cfg, logits, y, mask)

    grads = jax.grad(loss_fn)(ws)
    # central differences on a few random entries of each weight
    eps = 1e-2
    check_rng = np.random.default_rng(6)
    for li, w in enumerate(ws):
        for _ in range(3):
            i = check_rng.integers(0, w.shape[0])
            j = check_rng.integers(0, w.shape[1])
            wp = [w_.copy() for w_ in ws]
            wm = [w_.copy() for w_ in ws]
            wp[li] = wp[li].at[i, j].add(eps)
            wm[li] = wm[li].at[i, j].add(-eps)
            fd = (loss_fn(wp) - loss_fn(wm)) / (2 * eps)
            an = grads[li][i, j]
            assert abs(fd - an) < 5e-3 + 0.05 * abs(fd), (
                f"layer {li} ({i},{j}): fd={fd} analytic={an}"
            )


def test_adam_update_semantics():
    w = jnp.ones((4,))
    g = jnp.full((4,), 0.5)
    m = jnp.zeros((4,))
    v = jnp.zeros((4,))
    w2, m2, v2 = model.adam_update(w, g, m, v, step=1.0, lr=0.1)
    # step 1 with zero state: mhat = g, vhat = g^2 -> w -= lr * sign(g)
    np.testing.assert_allclose(w2, 1.0 - 0.1 * (0.5 / (0.5 + model.ADAM_EPS)),
                               rtol=1e-6)
    np.testing.assert_allclose(m2, 0.1 * 0.5, rtol=1e-6)
    np.testing.assert_allclose(v2, 0.001 * 0.25, rtol=1e-5)


def test_train_step_contract_and_learning():
    cfg = cfg_multiclass(layers=2)
    rng = np.random.default_rng(7)
    a, x, y, mask = make_batch(cfg, rng)
    ws = model.init_weights(cfg, seed=4)
    ms = [jnp.zeros_like(w) for w in ws]
    vs = [jnp.zeros_like(w) for w in ws]
    fn = jax.jit(model.build_fn(cfg))

    losses = []
    step = 1.0
    for _ in range(40):
        out = fn(*ws, *ms, *vs, jnp.float32(step), jnp.float32(0.01),
                 a, x, y, mask)
        L = cfg.layers
        assert len(out) == 3 * L + 1
        ws, ms, vs = list(out[:L]), list(out[L:2 * L]), list(out[2 * L:3 * L])
        losses.append(float(out[-1]))
        step += 1.0
    assert losses[-1] < losses[0] * 0.9, f"no learning: {losses[0]} -> {losses[-1]}"
    for w, spec in zip(ws, cfg.weight_shapes()):
        assert w.shape == spec


def test_vrgcn_step_contract():
    cfg = model.ModelConfig(
        name="v", task="multiclass", layers=2, f_in=12, f_hid=24,
        classes=5, b_max=128, kind="vrgcn",
    )
    rng = np.random.default_rng(8)
    a, x, y, mask = make_batch(cfg, rng)
    ws = model.init_weights(cfg, seed=5)
    ms = [jnp.zeros_like(w) for w in ws]
    vs = [jnp.zeros_like(w) for w in ws]
    hcs = [np.zeros((cfg.b_max, d), np.float32) for d in cfg.layer_in_dims()]
    fn = jax.jit(model.build_fn(cfg))
    out = fn(*ws, *ms, *vs, jnp.float32(1.0), jnp.float32(0.01),
             a, *hcs, x, y, mask)
    L = cfg.layers
    assert len(out) == 3 * L + 1 + (L - 1)
    hidden = out[-1]
    assert hidden.shape == (cfg.b_max, cfg.f_hid)
    # with zero Hc, vrgcn forward == plain forward; hidden = relu(A x W0)
    expect_h = np.maximum((a @ x) @ np.asarray(ws[0]), 0.0)
    np.testing.assert_allclose(hidden, expect_h, rtol=1e-4, atol=1e-3)


def test_vrgcn_history_contribution_shifts_forward():
    cfg = model.ModelConfig(
        name="v", task="multiclass", layers=2, f_in=12, f_hid=24,
        classes=5, b_max=128, kind="vrgcn",
    )
    rng = np.random.default_rng(9)
    a, x, _, _ = make_batch(cfg, rng)
    ws = model.init_weights(cfg, seed=6)
    hcs0 = [np.zeros((cfg.b_max, d), np.float32) for d in cfg.layer_in_dims()]
    hcs1 = [np.full((cfg.b_max, d), 0.5, np.float32) for d in cfg.layer_in_dims()]
    out0, _ = model.vrgcn_forward(cfg, ws, a, hcs0, x)
    out1, _ = model.vrgcn_forward(cfg, ws, a, hcs1, x)
    assert not np.allclose(out0, out1), "history term had no effect"


def test_example_args_shapes_cover_all_kinds():
    for kind, extra in [("train", 0), ("forward", 0), ("vrgcn", 0)]:
        cfg = cfg_multiclass(kind=kind)
        specs = model.example_args(cfg)
        if kind == "train":
            assert len(specs) == 3 * cfg.layers + 2 + 4
        elif kind == "forward":
            assert len(specs) == cfg.layers + 2
        else:
            assert len(specs) == 3 * cfg.layers + 2 + 1 + cfg.layers + 3
        assert all(s.dtype == jnp.float32 for s in specs)


def test_init_weights_glorot_bounds():
    cfg = cfg_multiclass(layers=3)
    ws = model.init_weights(cfg, seed=0)
    for w, (fi, fo) in zip(ws, cfg.weight_shapes()):
        bound = (6.0 / (fi + fo)) ** 0.5
        assert np.abs(np.asarray(w)).max() <= bound + 1e-6
        assert np.asarray(w).std() > 0.1 * bound
