#!/usr/bin/env python3
"""Render bench_results/*.jsonl into the EXPERIMENTS.md result tables.

Build-time tooling only (like compile/): reads the JSONL rows the rust
benches append and prints markdown, one section per experiment, so
EXPERIMENTS.md stays mechanically derivable from recorded runs.

Usage: python python/report.py [bench_results_dir]
"""

from __future__ import annotations

import json
import os
import sys
from collections import OrderedDict


def load(path):
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def md_table(headers, rows):
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def fmt(v, nd=3):
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def latest_by(rows, keys):
    """Keep the last row per key tuple (benches append across runs)."""
    seen = OrderedDict()
    for r in rows:
        seen[tuple(r.get(k) for k in keys)] = r
    return list(seen.values())


SECTIONS = [
    ("table2", ["dataset"], ["dataset", "random_f1", "cluster_f1"]),
    ("fig2", ["clusters"], ["clusters", "mean_entropy_clustering", "mean_entropy_random"]),
    ("fig4", ["epoch"], ["epoch", "one_cluster_f1", "multi_cluster_f1"]),
    ("table5", ["dataset", "hidden", "layers"],
     ["dataset", "hidden", "layers", "vrgcn_mb", "cluster_mb", "sage_mb"]),
    ("table6", ["hidden"], ["hidden", "dense_ms", "gather_ms"]),
    ("fig6", ["dataset", "layers", "method", "epoch"],
     ["dataset", "layers", "method", "epoch", "train_s", "val_f1"]),
    ("table8", ["layers"],
     ["layers", "vrgcn_s", "cluster_s", "vrgcn_mb", "cluster_mb",
      "vrgcn_f1", "cluster_f1", "vrgcn_oom"]),
    ("table9", ["layers"], ["layers", "cluster_s", "vrgcn_s"]),
    ("table10", ["config"], ["config", "test_f1"]),
    ("table11", ["variant", "layers"], ["variant", "layers", "best_val_f1"]),
    ("fig5", ["variant", "epoch"], ["variant", "epoch", "val_f1"]),
    ("table13", ["dataset"],
     ["dataset", "partitions", "clustering_s", "preprocessing_s"]),
    ("complexity", ["layers"],
     ["layers", "cluster_per_target", "vanilla_per_target", "sage_per_target"]),
    ("ablation_partitioner", ["partitioner"],
     ["partitioner", "clustering_s", "within_fraction", "val_f1"]),
    ("ablation_q", ["q"], ["q", "s_per_epoch", "val_f1"]),
]


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "bench_results"
    if not os.path.isdir(d):
        print(f"no {d}/ — run `cargo bench` first", file=sys.stderr)
        return 1
    for name, keys, cols in SECTIONS:
        path = os.path.join(d, f"{name}.jsonl")
        if not os.path.exists(path):
            continue
        rows = latest_by(load(path), keys)
        print(f"\n### {name}\n")
        print(md_table(cols, [[fmt(r.get(c, "")) for c in cols] for r in rows]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
