//! Quickstart: the minimal Cluster-GCN pipeline on a small graph.
//!
//! ```bash
//! make artifacts          # once: AOT-lower the JAX/Pallas model
//! cargo run --release --example quickstart
//! ```
//!
//! Steps: generate a Cora-like graph → METIS-like partition into 10
//! clusters → train a 2-layer GCN with the fused PJRT train_step →
//! evaluate test micro-F1 with exact host inference.

use cluster_gcn::coordinator::{train, ClusterSampler};
use cluster_gcn::session::TrainConfig;
use cluster_gcn::datagen::{build, preset};
use cluster_gcn::graph::Split;
use cluster_gcn::partition::{parts_to_clusters, MultilevelPartitioner, Partitioner};
use cluster_gcn::runtime::Engine;
use cluster_gcn::util::Rng;

fn main() -> anyhow::Result<()> {
    // 1. data: synthetic Cora-like citation graph (2708 nodes, 7 classes)
    let ds = build(preset("cora_like").unwrap(), /*seed=*/ 42);
    println!("graph: {} nodes, {} edges", ds.n(), ds.graph.num_edges());

    // 2. cluster: multilevel partitioner (the paper's METIS step)
    let parts = 10;
    let mut rng = Rng::new(7);
    let assignment = MultilevelPartitioner::default().partition(&ds.graph, parts, &mut rng);
    let clusters = parts_to_clusters(&assignment, parts);
    println!(
        "partitioned into {parts} clusters (sizes {}..{})",
        clusters.iter().map(|c| c.len()).min().unwrap(),
        clusters.iter().map(|c| c.len()).max().unwrap()
    );

    // 3. train: one cluster per batch (Algorithm 1), fused Adam step
    let mut engine = Engine::new(std::path::Path::new("artifacts"))?;
    let sampler = ClusterSampler::new(clusters, /*q=*/ 1);
    let opts = TrainConfig {
        epochs: 30,
        eval_every: 10,
        eval_split: Split::Val,
        ..TrainConfig::default()
    };
    let result = train(&mut engine, &ds, &sampler, "cora_L2", &opts)?;
    for pt in &result.curve {
        println!(
            "epoch {:3}  loss {:.4}  val F1 {:.4}  ({:.2}s)",
            pt.epoch, pt.train_loss, pt.eval_f1, pt.train_seconds
        );
    }

    // 4. final test accuracy via exact full-graph host inference
    let test_nodes = ds.nodes_in_split(Split::Test);
    let test_f1 = cluster_gcn::coordinator::evaluate(
        &ds,
        &result.state.weights,
        opts.norm,
        false,
        &test_nodes,
    );
    println!("test micro-F1: {test_f1:.4}");
    Ok(())
}
