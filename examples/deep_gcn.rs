//! Deep GCNs with diagonal enhancement (§3.3): train 6-layer GCNs on
//! the PPI-like data under the plain eq.(1) normalization and the
//! eq.(10)+(11) diagonal enhancement, and watch the former struggle as
//! depth grows while the latter stays trainable — the effect behind
//! Table 11 / Figure 5 and the paper's SOTA PPI score.
//!
//! ```bash
//! cargo run --release --example deep_gcn [-- --layers 6 --epochs 10]
//! ```

use cluster_gcn::coordinator::{train, ClusterSampler};
use cluster_gcn::session::TrainConfig;
use cluster_gcn::datagen::{build_cached, preset};
use cluster_gcn::norm::NormConfig;
use cluster_gcn::partition::{parts_to_clusters, MultilevelPartitioner, Partitioner};
use cluster_gcn::runtime::Engine;
use cluster_gcn::util::Rng;

fn arg(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let layers = arg("--layers", 6);
    let epochs = arg("--epochs", 10);
    let seed = 42u64;

    let ds = build_cached(
        preset("ppi_like").unwrap(),
        seed,
        std::path::Path::new("data"),
    )?;
    let mut engine = Engine::new(std::path::Path::new("artifacts"))?;
    let artifact = format!("ppi_L{layers}");

    println!("=== {layers}-layer GCN on ppi_like, {epochs} epochs ===");
    for (label, norm) in [
        ("plain eq.(1) sym-norm       ", NormConfig::PAPER_DEFAULT),
        ("diag-enhanced eq.(10)+(11)  ", NormConfig::ROW_LAMBDA1),
    ] {
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let assignment =
            MultilevelPartitioner::default().partition(&ds.graph, 50, &mut rng);
        let sampler = ClusterSampler::new(parts_to_clusters(&assignment, 50), 1);
        let opts = TrainConfig {
            epochs,
            eval_every: (epochs / 5).max(1),
            seed,
            norm,
            ..TrainConfig::default()
        };
        match train(&mut engine, &ds, &sampler, &artifact, &opts) {
            Ok(r) => {
                let best = r.curve.iter().map(|c| c.eval_f1).fold(0.0, f64::max);
                let last = r.curve.last().unwrap();
                println!(
                    "{label}: best val F1 {best:.4} (final loss {:.4})",
                    last.train_loss
                );
                for pt in &r.curve {
                    println!("    epoch {:3}  loss {:8.4}  val F1 {:.4}",
                             pt.epoch, pt.train_loss, pt.eval_f1);
                }
            }
            Err(e) => println!("{label}: DIVERGED ({e})"),
        }
    }
    println!("(paper Table 11: at 7-8 layers only (10)+(11) converges)");
    Ok(())
}
