//! End-to-end driver (the EXPERIMENTS.md validation run): the full
//! Cluster-GCN system on the reddit-like workload — dataset generation,
//! multilevel clustering, stochastic multiple-partition training with
//! the paper's hyper-parameters (1500 partitions, 20 clusters/batch),
//! convergence logging, a VR-GCN comparison point, and the headline
//! report: time-to-F1 + peak training memory for both methods.
//!
//! ```bash
//! cargo run --release --example end_to_end            # default 15 epochs
//! CGCN_EPOCHS=40 cargo run --release --example end_to_end
//! ```

use cluster_gcn::baselines::{train_vrgcn, VrgcnParams};
use cluster_gcn::coordinator::{train, ClusterSampler};
use cluster_gcn::session::TrainConfig;
use cluster_gcn::datagen::{build_cached, preset};
use cluster_gcn::graph::Split;
use cluster_gcn::partition::{
    metrics::stats, parts_to_clusters, MultilevelPartitioner, Partitioner,
};
use cluster_gcn::runtime::Engine;
use cluster_gcn::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::var("CGCN_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15);
    let seed = 42u64;

    println!("=== Cluster-GCN end-to-end: reddit_like ===\n");

    // --- 1. data ---------------------------------------------------------
    let t = Timer::start();
    let ds = build_cached(
        preset("reddit_like").unwrap(),
        seed,
        std::path::Path::new("data"),
    )?;
    let (dmin, dmax, davg) = ds.graph.degree_stats();
    println!("[data] {} nodes, {} edges, {} classes, {} features ({:.2}s)",
             ds.n(), ds.graph.num_edges(), ds.num_classes, ds.f_in, t.secs());
    println!("[data] degrees min/avg/max = {dmin}/{davg:.1}/{dmax}");

    // --- 2. clustering (Algorithm 1, line 1) ------------------------------
    let parts = 1500;
    let q = 20;
    let t = Timer::start();
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let assignment = MultilevelPartitioner::default().partition(&ds.graph, parts, &mut rng);
    let pstats = stats(&ds.graph, &assignment, parts);
    println!(
        "[cluster] {parts} partitions in {:.2}s — {:.1}% edges kept within, balance {:.2}",
        t.secs(),
        100.0 * pstats.within_fraction,
        pstats.balance
    );

    // --- 3. training (Algorithm 1, lines 2-6) -----------------------------
    let mut engine = Engine::new(std::path::Path::new("artifacts"))?;
    let sampler = ClusterSampler::new(parts_to_clusters(&assignment, parts), q);
    let opts = TrainConfig {
        epochs,
        eval_every: (epochs / 5).max(1),
        seed,
        eval_split: Split::Val,
        ..TrainConfig::default()
    };
    println!("[train] {} batches/epoch (q={q}), artifact reddit_L2", sampler.batches_per_epoch());
    let result = train(&mut engine, &ds, &sampler, "reddit_L2", &opts)?;
    println!("[train] loss curve (epoch, train_s, loss, val_f1):");
    for pt in &result.curve {
        println!(
            "    {:4}  {:7.2}s  {:.4}  {:.4}",
            pt.epoch, pt.train_seconds, pt.train_loss, pt.eval_f1
        );
    }

    // --- 4. baseline comparison point (VR-GCN) ----------------------------
    let vr_epochs = (epochs / 3).max(1);
    let vr_opts = TrainConfig { epochs: vr_epochs, eval_every: 0, ..opts.clone() };
    let vr = train_vrgcn(
        &mut engine, &ds, "reddit_vrgcn_L2", &VrgcnParams::default(), &vr_opts,
    )?;

    // --- 5. headline report ------------------------------------------------
    let test_nodes = ds.nodes_in_split(Split::Test);
    let test_f1 = cluster_gcn::coordinator::evaluate(
        &ds, &result.state.weights, opts.norm, false, &test_nodes,
    );
    let vr_f1 = cluster_gcn::coordinator::evaluate(
        &ds, &vr.state.weights, opts.norm, false, &test_nodes,
    );
    println!("\n=== headline ===");
    println!(
        "cluster-gcn : {:6.2}s/epoch, peak mem {:7.1} MB, test F1 {:.4}",
        result.train_seconds / epochs as f64,
        result.peak_bytes as f64 / 1e6,
        test_f1
    );
    println!(
        "vr-gcn      : {:6.2}s/epoch, peak mem {:7.1} MB, test F1 {:.4} ({} epochs)",
        vr.train_seconds / vr_epochs as f64,
        vr.peak_bytes as f64 / 1e6,
        vr_f1,
        vr_epochs
    );
    println!(
        "memory ratio vrgcn/cluster = {:.1}x   (paper Table 8: ~3-5x)",
        vr.peak_bytes as f64 / result.peak_bytes as f64
    );
    println!(
        "embedding utilization: {:.1} within-batch edges/node",
        result.avg_within_edges_per_node
    );
    Ok(())
}
