//! Perf probe (EXPERIMENTS.md §Perf): break the training pipeline into
//! its phases so optimization targets the real bottleneck.
//!
//! Sections (each dumps JSONL rows under `bench_results/perf_probe.jsonl`
//! in the same shape as the bench harness):
//!
//! 1. **host kernels** — full-graph forward: naive scalar oracle vs the
//!    tiled fused SpMM·GEMM at 1 thread vs on the persistent pool, plus
//!    the normalize / spmm / gemm phase split.
//! 2. **backward** — the host train step on a real cluster batch: the
//!    pre-engine scalar backward vs the pooled engine end to end, plus
//!    per-kernel phase timings (gemm_at_b, scatter vs Âᵀ gather,
//!    gemm_a_bt, adam), the detected SIMD backend, and per-backend
//!    ns/op for the `util::simd` primitives (axpy / dot / gemm_tile).
//!    Also writes the cumulative snapshot
//!    `bench_results/BENCH_backward.json` so the perf trajectory is
//!    tracked from PR 3 on.
//! 3. **dispatch** — persistent-pool `run_chunks` vs spawn-per-call
//!    `scoped_chunks` dispatch overhead.
//! 4. **assembly** — per-step batch assembly: allocate-per-step vs the
//!    reused zero-allocation `assemble_into` path.
//! 5. **sharded scaling** — data-parallel throughput (batches/s) of
//!    `ShardedBackend` at shards ∈ {1, 2, 4}; writes
//!    `bench_results/BENCH_sharded.json`.
//! 6. **PJRT loop** — the original per-step phase breakdown (assembly /
//!    literal / execute / sync); skipped with a note when no compiled
//!    artifacts are available.
//!
//! ```bash
//! cargo run --release --example perf_probe [-- preset layers steps]
//! ```

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::batch::BatchAssembler;
use cluster_gcn::coordinator::inference::{
    full_forward_cached, propagate_into, spmm_layer_naive,
};
use cluster_gcn::coordinator::trainer::{step, TrainState};
use cluster_gcn::coordinator::ClusterSampler;
use cluster_gcn::datagen::{build_cached, preset};
use cluster_gcn::graph::Dataset;
use cluster_gcn::norm::{normalize_sparse, NormCache, NormConfig};
use cluster_gcn::partition::{parts_to_clusters, MultilevelPartitioner, Partitioner};
use cluster_gcn::runtime::{Engine, Tensor};
use cluster_gcn::util::pool::{self, scoped_chunks};
use cluster_gcn::util::simd;
use cluster_gcn::util::{bench, Json, Rng, Timer};

/// Deterministic pseudo-random layer weights (Glorot-ish scale).
fn probe_weights(ds: &Dataset, layers: usize, hidden: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    let mut dims = vec![ds.f_in];
    dims.extend(std::iter::repeat(hidden).take(layers - 1));
    dims.push(ds.num_classes);
    dims.windows(2)
        .map(|d| {
            let bound = (6.0 / (d[0] + d[1]) as f64).sqrt() as f32;
            let data = (0..d[0] * d[1]).map(|_| (rng.f32() * 2.0 - 1.0) * bound).collect();
            Tensor::new(vec![d[0], d[1]], data)
        })
        .collect()
}

fn host_kernel_probe(ds: &Dataset, layers: usize, iters: usize) {
    let hidden = 128;
    let weights = probe_weights(ds, layers, hidden, 11);
    let threads = pool::default_threads();

    // normalization phase (cold cost; the NormCache amortizes it away)
    let t = Timer::start();
    let (vals, sl) = normalize_sparse(&ds.graph, NormConfig::PAPER_DEFAULT);
    let normalize_ms = t.secs() * 1e3;

    // naive scalar chain (the pre-overhaul kernel at 1 thread)
    let naive = bench(1, iters, || {
        let mut h = ds.features.clone();
        let mut f = ds.f_in;
        let last = weights.len() - 1;
        for (l, w) in weights.iter().enumerate() {
            h = spmm_layer_naive(&ds.graph, &vals, &sl, &h, f, w, l != last);
            f = w.dims[1];
        }
    });

    // tiled fused kernel, single thread and pooled, through the cache
    let mut cache = NormCache::new();
    let tiled1 = {
        // thread cap 1: same kernel, no parallel dispatch
        let mut cache1 = NormCache::new();
        cache1.get_or_compute(&ds.graph, NormConfig::PAPER_DEFAULT);
        bench(1, iters, || {
            let n = ds.n();
            let adj = cache1.get_or_compute(&ds.graph, NormConfig::PAPER_DEFAULT);
            let mut h = ds.features.clone();
            let mut f = ds.f_in;
            let last = weights.len() - 1;
            for (l, w) in weights.iter().enumerate() {
                let mut z = vec![0f32; n * w.dims[1]];
                cluster_gcn::coordinator::inference::spmm_layer_into(
                    &ds.graph, &adj.vals, &adj.self_loop, &h, f, w, l != last, 1, &mut z,
                );
                h = z;
                f = w.dims[1];
            }
        })
    };
    cache.get_or_compute(&ds.graph, NormConfig::PAPER_DEFAULT); // warm
    let pooled = bench(1, iters, || {
        let _ = full_forward_cached(ds, &weights, NormConfig::PAPER_DEFAULT, false, &mut cache);
    });

    // phase attribution on the first (widest-fanout) layer
    let mut p = vec![0f32; ds.n() * ds.f_in];
    let s_prop = bench(1, iters, || {
        propagate_into(&ds.graph, &vals, &sl, &ds.features, ds.f_in, threads, &mut p);
    });
    let w0 = &weights[0];
    let mut z0 = vec![0f32; ds.n() * w0.dims[1]];
    let s_layer = bench(1, iters, || {
        cluster_gcn::coordinator::inference::spmm_layer_into(
            &ds.graph, &vals, &sl, &ds.features, ds.f_in, w0, true, threads, &mut z0,
        );
    });
    let gemm_ms = ((s_layer.mean - s_prop.mean) * 1e3).max(0.0);

    println!("== host kernels: full-graph forward ({layers} layers, hidden {hidden}) ==");
    println!("normalize (cold)   {normalize_ms:9.2} ms   (amortized to once/run by NormCache)");
    println!("naive  1t          {:9.2} ms", naive.mean * 1e3);
    println!("tiled  1t          {:9.2} ms   ({:.2}x vs naive)", tiled1.mean * 1e3, naive.mean / tiled1.mean);
    println!("tiled  pool({threads})     {:9.2} ms   ({:.2}x vs naive)", pooled.mean * 1e3, naive.mean / pooled.mean);
    println!("layer-0 phase split: spmm {:.2} ms, gemm {gemm_ms:.2} ms", s_prop.mean * 1e3);
    bs::dump_row(
        "perf_probe",
        Json::obj(vec![
            ("kind", Json::str("host_forward")),
            ("layers", Json::num(layers as f64)),
            ("hidden", Json::num(hidden as f64)),
            ("normalize_ms", Json::num(normalize_ms)),
            ("naive_ms", Json::num(naive.mean * 1e3)),
            ("tiled_ms", Json::num(tiled1.mean * 1e3)),
            ("pooled_ms", Json::num(pooled.mean * 1e3)),
            ("spmm_ms", Json::num(s_prop.mean * 1e3)),
            ("gemm_ms", Json::num(gemm_ms)),
            ("speedup_pooled_vs_naive", Json::num(naive.mean / pooled.mean)),
        ]),
    );
}

/// Backward-phase probe: the pooled backward engine vs the retained
/// pre-engine scalar backward, end to end and per kernel, over one real
/// cluster batch.  Emits JSONL rows plus the `BENCH_backward.json`
/// snapshot the ROADMAP tracks.
fn backward_probe(ds: &Dataset, sampler: &ClusterSampler, b_max: usize, iters: usize) {
    use cluster_gcn::norm::NormConfig;
    use cluster_gcn::runtime::backward::{
        adam_update, adam_update_pooled, gemm_a_bt, gemm_a_bt_pooled, gemm_at_b,
        gemm_at_b_pooled, scatter_adj_t, AdjT,
    };
    use cluster_gcn::runtime::host::host_grads_scalar;
    use cluster_gcn::runtime::{Backend, HostBackend, ModelSpec};

    let threads = pool::default_threads();
    let hidden = 128usize;
    let mut rng = Rng::new(13);
    let plan = sampler.epoch_plan(&mut rng);
    let mut nodes = Vec::new();
    sampler.batch_nodes(&plan[0], &mut nodes);
    let mut asm = BatchAssembler::new(ds.n(), b_max, NormConfig::PAPER_DEFAULT);
    let batch = asm.assemble(ds, &nodes);
    let n = batch.n_real;
    let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, hidden, ds.num_classes, b_max);
    let weights = probe_weights(ds, 2, hidden, 11);

    // ---- end-to-end train step: scalar baseline vs pooled engine ----
    let mut state_s = TrainState::init(&spec, 1);
    let step_scalar = bench(1, iters, || {
        let (_loss, grads) = host_grads_scalar(&spec, &weights, &batch, threads).unwrap();
        state_s.step += 1;
        let t = state_s.step as f32;
        for li in 0..state_s.weights.len() {
            adam_update(
                &mut state_s.weights[li].data,
                &grads[li],
                &mut state_s.m[li].data,
                &mut state_s.v[li].data,
                t,
                0.01,
            );
        }
    });
    let mut step_at = |w: usize| {
        let mut hb = HostBackend::with_threads(w);
        hb.register_model("m", spec.clone());
        let mut st = TrainState::init(&spec, 2);
        hb.train_step("m", &mut st, 0.01, &batch).unwrap(); // warm workspace
        bench(1, iters, || {
            hb.train_step("m", &mut st, 0.01, &batch).unwrap();
        })
    };
    let step_pooled1 = step_at(1);
    let step_pooled = step_at(threads);

    // ---- per-kernel phase timings over layer-0 shapes ----------------
    let (f, g) = (ds.f_in, hidden);
    let mut krng = Rng::new(7);
    let p: Vec<f32> = (0..n * f).map(|_| krng.f32() - 0.5).collect();
    let dz: Vec<f32> = (0..n * g).map(|_| krng.f32() - 0.5).collect();
    let w: Vec<f32> = (0..f * g).map(|_| krng.f32() - 0.5).collect();
    let mut gw = vec![0f32; f * g];
    let atb_scalar = bench(1, iters, || {
        gw.fill(0.0);
        gemm_at_b(&p, &dz, n, f, g, &mut gw);
    });
    let atb_pooled = bench(1, iters, || {
        gemm_at_b_pooled(&p, &dz, n, f, g, threads, &mut gw);
    });
    let mut mbuf = vec![0f32; n * f];
    let abt_scalar = bench(1, iters, || {
        gemm_a_bt(&dz, &w, n, g, f, &mut mbuf);
    });
    let abt_pooled = bench(1, iters, || {
        gemm_a_bt_pooled(&dz, &w, n, g, f, threads, &mut mbuf);
    });
    let blk = &batch.block;
    let m: Vec<f32> = (0..n * g).map(|_| krng.f32() - 0.5).collect();
    let mut dh = vec![0f32; n * g];
    let scatter = bench(1, iters, || {
        dh.fill(0.0);
        scatter_adj_t(&blk.offsets, &blk.cols, &blk.vals, &blk.self_loop, &m, g, &mut dh);
    });
    let mut adj_t = AdjT::new();
    let gather = bench(1, iters, || {
        adj_t.build(&blk.offsets, &blk.cols, &blk.vals, &blk.self_loop);
        adj_t.gather_into_pooled(&m, g, threads, &mut dh);
    });
    // adam: serial per-layer loop vs one pooled pass over the arena
    let mut spans = Vec::new();
    let mut arena = Vec::new();
    for &(a, b) in &spec.weight_shapes {
        spans.push((arena.len(), a * b));
        arena.extend((0..a * b).map(|_| krng.f32() - 0.5));
    }
    let mut st_a = TrainState::init(&spec, 3);
    let adam_scalar = bench(1, iters, || {
        for (li, &(off, len)) in spans.iter().enumerate() {
            adam_update(
                &mut st_a.weights[li].data,
                &arena[off..off + len],
                &mut st_a.m[li].data,
                &mut st_a.v[li].data,
                2.0,
                0.01,
            );
        }
    });
    let mut st_b = TrainState::init(&spec, 3);
    let adam_pooled = bench(1, iters, || {
        adam_update_pooled(
            &mut st_b.weights,
            &mut st_b.m,
            &mut st_b.v,
            &arena,
            &spans,
            2.0,
            0.01,
            threads,
        );
    });

    // sparse-aware dW: fraction of 8-wide dz column blocks the relu
    // killed batch-wide (skipped entirely by the masked kernel)
    let (atb_blocks, atb_skipped) = cluster_gcn::runtime::backward::at_b_skip_stats();
    let skip_rate = atb_skipped as f64 / (atb_blocks.max(1)) as f64;

    // ---- SIMD primitives: every detected backend vs portable ---------
    // In-process A/B through `BackendHandle`s (the global dispatch table
    // resolved once at pool startup; `CGCN_SIMD` only affects that).
    let active = simd::active_backend();
    let handles = simd::available_backends();
    println!(
        "simd backend: {active} (candidates: {})",
        handles.iter().map(|h| h.name()).collect::<Vec<_>>().join(", ")
    );
    let vn = 1024usize; // axpy/dot at a hidden-layer row width
    let xv: Vec<f32> = (0..vn).map(|_| krng.f32() - 0.5).collect();
    let mut yv: Vec<f32> = (0..vn).map(|_| krng.f32() - 0.5).collect();
    // one ROW_BLOCK × K_PANEL × COL_TILE panel — the shape the tiled
    // GEMM drivers feed the micro-kernel
    let (tr, tk, tc) = (64usize, 128usize, 64usize);
    let pt: Vec<f32> = (0..tr * tk).map(|_| krng.f32() - 0.5).collect();
    let wt: Vec<f32> = (0..tk * tc).map(|_| krng.f32() - 0.5).collect();
    let mut ot = vec![0f32; tr * tc];
    let mut simd_pairs: Vec<(String, Json)> =
        vec![("simd_backend".to_string(), Json::str(active))];
    let mut gemm_ns_portable = f64::NAN;
    const INNER: usize = 256; // amortize the per-sample timer readout
    for &h in &handles {
        let axpy_s = bench(1, iters.max(3), || {
            for _ in 0..INNER {
                h.axpy(&mut yv, &xv, 1e-5);
            }
        });
        let dot_s = bench(1, iters.max(3), || {
            for _ in 0..INNER {
                std::hint::black_box(h.dot(&yv, &xv));
            }
        });
        let gemm_s = bench(1, iters.max(3), || {
            ot.fill(0.0);
            h.gemm_tile(&mut ot, tc, &pt, tk, 1, &wt, tc, tr, tk, tc);
        });
        let axpy_ns = axpy_s.mean * 1e9 / INNER as f64;
        let dot_ns = dot_s.mean * 1e9 / INNER as f64;
        let gemm_ns = gemm_s.mean * 1e9;
        if h.name() == "portable" {
            gemm_ns_portable = gemm_ns;
        }
        let speedup = gemm_ns_portable / gemm_ns;
        println!(
            "simd {:<8} axpy({vn}) {axpy_ns:8.1} ns | dot({vn}) {dot_ns:8.1} ns | \
             gemm_tile({tr}x{tk}x{tc}) {gemm_ns:10.1} ns ({speedup:.2}x vs portable)",
            h.name()
        );
        for (prim, v) in [("axpy", axpy_ns), ("dot", dot_ns), ("gemm_tile", gemm_ns)] {
            simd_pairs.push((format!("{prim}_ns_{}", h.name()), Json::num(v)));
        }
        if h.name() != "portable" {
            simd_pairs
                .push((format!("gemm_tile_speedup_{}", h.name()), Json::num(speedup)));
        }
    }

    let ms = |s: f64| s * 1e3;
    println!("== backward engine: train step on one cluster batch ({n} nodes, hidden {hidden}) ==");
    println!(
        "gemm_at_b sparse-aware skip rate: {:.1}% of column blocks \
         ({atb_skipped}/{atb_blocks})",
        100.0 * skip_rate
    );
    println!("step scalar (pre-PR) {:9.2} ms", ms(step_scalar.mean));
    println!(
        "step pooled 1t       {:9.2} ms   ({:.2}x vs scalar)",
        ms(step_pooled1.mean),
        step_scalar.mean / step_pooled1.mean
    );
    println!(
        "step pooled pool({threads})  {:9.2} ms   ({:.2}x vs scalar)",
        ms(step_pooled.mean),
        step_scalar.mean / step_pooled.mean
    );
    println!(
        "phases: gemm_at_b {:.2} -> {:.2} ms | adj_t {:.2} -> {:.2} ms | \
         gemm_a_bt {:.2} -> {:.2} ms | adam {:.3} -> {:.3} ms",
        ms(atb_scalar.mean),
        ms(atb_pooled.mean),
        ms(scatter.mean),
        ms(gather.mean),
        ms(abt_scalar.mean),
        ms(abt_pooled.mean),
        ms(adam_scalar.mean),
        ms(adam_pooled.mean),
    );

    let mut pairs: Vec<(String, Json)> = vec![
        ("kind".to_string(), Json::str("host_backward")),
        ("batch_nodes".to_string(), Json::num(n as f64)),
        ("hidden".to_string(), Json::num(hidden as f64)),
        ("threads".to_string(), Json::num(threads as f64)),
        ("step_scalar_ms".to_string(), Json::num(ms(step_scalar.mean))),
        ("step_pooled_1t_ms".to_string(), Json::num(ms(step_pooled1.mean))),
        ("step_pooled_ms".to_string(), Json::num(ms(step_pooled.mean))),
        (
            "speedup_pooled_vs_scalar".to_string(),
            Json::num(step_scalar.mean / step_pooled.mean),
        ),
        ("gemm_at_b_scalar_ms".to_string(), Json::num(ms(atb_scalar.mean))),
        ("gemm_at_b_pooled_ms".to_string(), Json::num(ms(atb_pooled.mean))),
        ("scatter_adj_t_ms".to_string(), Json::num(ms(scatter.mean))),
        ("adj_t_gather_ms".to_string(), Json::num(ms(gather.mean))),
        ("gemm_a_bt_scalar_ms".to_string(), Json::num(ms(abt_scalar.mean))),
        ("gemm_a_bt_pooled_ms".to_string(), Json::num(ms(abt_pooled.mean))),
        ("adam_scalar_ms".to_string(), Json::num(ms(adam_scalar.mean))),
        ("adam_pooled_ms".to_string(), Json::num(ms(adam_pooled.mean))),
        ("at_b_skip_rate".to_string(), Json::num(skip_rate)),
        (
            "peak_rss_bytes".to_string(),
            Json::num(cluster_gcn::util::memstat::peak_rss_bytes() as f64),
        ),
    ];
    pairs.extend(simd_pairs);
    let row = Json::obj(pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect());
    bs::dump_row("perf_probe", row.clone());
    // one-object snapshot tracked across PRs (overwritten per run)
    let _ = std::fs::create_dir_all("bench_results");
    let _ = std::fs::write("bench_results/BENCH_backward.json", row.to_string());
}

/// Sharded-scaling probe: cluster batches pulled through
/// `Backend::step_from` on a `ShardedBackend` at shards ∈ {1, 2, 4} —
/// batches/s is the data-parallel throughput (a sharded step consumes
/// one batch per replica).  Writes the cumulative snapshot
/// `bench_results/BENCH_sharded.json`.
fn sharded_probe(ds: &Dataset, sampler: &ClusterSampler, b_max: usize, steps: usize) {
    use cluster_gcn::coordinator::source::{BatchSource, ClusterSource};
    use cluster_gcn::coordinator::trainer::TrainState;
    use cluster_gcn::runtime::{Backend, ModelSpec, ShardedBackend};

    let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, 128, ds.num_classes, b_max);
    let steps = steps.max(8);
    let mut rates: Vec<(usize, f64)> = Vec::new();
    println!("== sharded scaling ({steps} cluster batches, b_max {b_max}) ==");
    for shards in [1usize, 2, 4] {
        let mut backend = ShardedBackend::host(shards);
        backend.register_model("m", spec.clone());
        let mut src = ClusterSource::new(
            ds,
            sampler.clone(),
            &spec,
            NormConfig::PAPER_DEFAULT,
            7,
        )
        .expect("probe sampler fits b_max");
        let mut state = TrainState::init(&spec, 1);
        let mut scratch = src.new_batch();
        // warm: one step sizes every replica workspace
        src.begin_epoch(1);
        backend
            .step_from("m", &mut state, 0.01, &mut src, 0, &mut scratch)
            .expect("warm step");

        let t = Timer::start();
        let mut consumed = 0usize;
        let mut epoch = 1usize;
        'run: loop {
            epoch += 1;
            let n = src.begin_epoch(epoch);
            let mut i = 0usize;
            while i < n {
                if consumed >= steps {
                    break 'run;
                }
                let out = backend
                    .step_from("m", &mut state, 0.01, &mut src, i, &mut scratch)
                    .expect("sharded step");
                i += out.consumed;
                consumed += out.consumed;
            }
        }
        let rate = consumed as f64 / t.secs();
        println!(
            "shards {shards}   {rate:9.1} batches/s{}",
            match rates.first() {
                Some(&(_, base)) => format!("   ({:.2}x vs shards 1)", rate / base),
                None => String::new(),
            }
        );
        rates.push((shards, rate));
    }

    let base = rates[0].1;
    let mut pairs: Vec<(String, Json)> = vec![
        ("kind".into(), Json::str("sharded_scaling")),
        ("batches".into(), Json::num(steps as f64)),
        ("b_max".into(), Json::num(b_max as f64)),
    ];
    for &(shards, rate) in &rates {
        pairs.push((format!("shards_{shards}_batches_per_s"), Json::num(rate)));
        pairs.push((format!("shards_{shards}_speedup"), Json::num(rate / base)));
    }
    pairs.push((
        "peak_rss_bytes".into(),
        Json::num(cluster_gcn::util::memstat::peak_rss_bytes() as f64),
    ));
    let row = Json::obj(pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect());
    bs::dump_row("perf_probe", row.clone());
    let _ = std::fs::create_dir_all("bench_results");
    let _ = std::fs::write("bench_results/BENCH_sharded.json", row.to_string());
}

fn dispatch_probe() {
    let threads = pool::default_threads();
    let reps = 300;
    let spawn = bench(5, reps, || {
        let _ = scoped_chunks(threads, threads, |_, r| r.len());
    });
    let pooled = bench(5, reps, || {
        pool::global().run_chunks(threads, |_, _| {});
    });
    println!("== dispatch overhead ({threads} chunks) ==");
    println!("spawn-per-call     {:9.1} µs", spawn.mean * 1e6);
    println!("persistent pool    {:9.1} µs   ({:.1}x)", pooled.mean * 1e6, spawn.mean / pooled.mean);
    bs::dump_row(
        "perf_probe",
        Json::obj(vec![
            ("kind", Json::str("dispatch")),
            ("spawn_us", Json::num(spawn.mean * 1e6)),
            ("pool_us", Json::num(pooled.mean * 1e6)),
        ]),
    );
}

fn assembly_probe(ds: &Dataset, sampler: &ClusterSampler, b_max: usize, steps: usize) {
    let mut rng = Rng::new(9);
    let plan = sampler.epoch_plan(&mut rng);
    let mut asm = BatchAssembler::new(ds.n(), b_max, NormConfig::PAPER_DEFAULT);
    let mut nodes = Vec::new();

    // allocate-per-step (the pre-overhaul path)
    let t = Timer::start();
    let mut done = 0usize;
    'a: loop {
        for ids in &plan {
            if done >= steps {
                break 'a;
            }
            sampler.batch_nodes(ids, &mut nodes);
            let _batch = asm.assemble(ds, &nodes);
            done += 1;
        }
    }
    let alloc_ms = t.secs() * 1e3 / done as f64;

    // reused zero-allocation path
    let mut batch = asm.new_batch(ds);
    let t = Timer::start();
    let mut done = 0usize;
    'b: loop {
        for ids in &plan {
            if done >= steps {
                break 'b;
            }
            sampler.batch_nodes(ids, &mut nodes);
            asm.assemble_into(ds, &nodes, &mut batch);
            done += 1;
        }
    }
    let reuse_ms = t.secs() * 1e3 / done as f64;

    println!("== batch assembly ({done} steps, b_max {b_max}) ==");
    println!("alloc-per-step     {alloc_ms:9.3} ms/step");
    println!(
        "reused buffers     {reuse_ms:9.3} ms/step   ({:.1}% less)",
        100.0 * (1.0 - reuse_ms / alloc_ms)
    );
    bs::dump_row(
        "perf_probe",
        Json::obj(vec![
            ("kind", Json::str("assembly")),
            ("alloc_ms", Json::num(alloc_ms)),
            ("reuse_ms", Json::num(reuse_ms)),
            ("reduction_pct", Json::num(100.0 * (1.0 - reuse_ms / alloc_ms))),
        ]),
    );
}

fn pjrt_probe(
    ds: &Dataset,
    sampler: &ClusterSampler,
    artifact: &str,
    steps: usize,
) -> anyhow::Result<()> {
    let mut engine = Engine::new(std::path::Path::new("artifacts"))?;
    let meta = engine.meta(artifact)?;
    engine.ensure_compiled(artifact)?;
    let mut rng = Rng::new(7);
    let mut asm = BatchAssembler::new(ds.n(), meta.b_max, NormConfig::PAPER_DEFAULT);
    let mut batch = asm.new_batch(ds);
    let mut state = TrainState::init(&cluster_gcn::runtime::ModelSpec::from(&meta), 0);

    let mut assembly_s = 0.0;
    let mut step_s = 0.0;
    let mut done = 0usize;
    let mut nodes = Vec::new();
    let total = Timer::start();
    'outer: loop {
        let plan = sampler.epoch_plan(&mut rng);
        for ids in &plan {
            if done >= steps {
                break 'outer;
            }
            let t = Timer::start();
            sampler.batch_nodes(ids, &mut nodes);
            asm.assemble_into(ds, &nodes, &mut batch);
            assembly_s += t.secs();
            if batch.n_train == 0 {
                continue;
            }
            let t = Timer::start();
            step(&mut engine, artifact, &mut state, 0.01, &batch)?;
            step_s += t.secs();
            done += 1;
        }
    }
    let total_s = total.secs();

    println!("== perf probe: {artifact}, {done} steps, b_max {} ==", meta.b_max);
    let pct = |x: f64| 100.0 * x / total_s;
    println!("total          {total_s:8.3}s");
    println!("  assembly     {assembly_s:8.3}s  ({:.1}%)", pct(assembly_s));
    println!("  step         {step_s:8.3}s  ({:.1}%)", pct(step_s));
    println!("    literal    {:8.3}s  ({:.1}%)", engine.lit_seconds, pct(engine.lit_seconds));
    println!("    execute    {:8.3}s  ({:.1}%)", engine.exec_seconds, pct(engine.exec_seconds));
    println!("    sync+out   {:8.3}s  ({:.1}%)", engine.sync_seconds, pct(engine.sync_seconds));
    println!(
        "    other      {:8.3}s  (tensor clones, output conversion)",
        step_s - engine.lit_seconds - engine.exec_seconds - engine.sync_seconds
    );
    println!("per-step: {:.2} ms", 1e3 * total_s / done as f64);
    bs::dump_row(
        "perf_probe",
        Json::obj(vec![
            ("kind", Json::str("pjrt_loop")),
            ("artifact", Json::str(artifact)),
            ("assembly_s", Json::num(assembly_s)),
            ("step_s", Json::num(step_s)),
            ("per_step_ms", Json::num(1e3 * total_s / done.max(1) as f64)),
        ]),
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let preset_name = args.get(1).map(String::as_str).unwrap_or("reddit_like");
    let layers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let steps: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(100);
    let iters = bs::env_usize("CGCN_ITERS", 3);

    let p = preset(preset_name).expect("preset");
    let ds = build_cached(p, 42, std::path::Path::new("data"))?;

    host_kernel_probe(&ds, layers, iters);
    dispatch_probe();

    let mut rng = Rng::new(7);
    let part = MultilevelPartitioner::default().partition(
        &ds.graph,
        p.default_partitions,
        &mut rng,
    );
    let sampler =
        ClusterSampler::new(parts_to_clusters(&part, p.default_partitions), p.default_q);
    backward_probe(&ds, &sampler, p.b_max, iters);
    assembly_probe(&ds, &sampler, p.b_max, steps.max(20));
    sharded_probe(&ds, &sampler, p.b_max, steps.min(48));

    let short = preset_name.trim_end_matches("_like");
    let artifact = format!("{short}_L{layers}");
    if let Err(e) = pjrt_probe(&ds, &sampler, &artifact, steps) {
        println!("(PJRT loop skipped: {e})");
    }
    Ok(())
}
