//! Perf probe (EXPERIMENTS.md §Perf): break the training pipeline into
//! its phases so optimization targets the real bottleneck.
//!
//! Sections (each dumps JSONL rows under `bench_results/perf_probe.jsonl`
//! in the same shape as the bench harness):
//!
//! 1. **host kernels** — full-graph forward: naive scalar oracle vs the
//!    tiled fused SpMM·GEMM at 1 thread vs on the persistent pool, plus
//!    the normalize / spmm / gemm phase split.
//! 2. **dispatch** — persistent-pool `run_chunks` vs spawn-per-call
//!    `scoped_chunks` dispatch overhead.
//! 3. **assembly** — per-step batch assembly: allocate-per-step vs the
//!    reused zero-allocation `assemble_into` path.
//! 4. **PJRT loop** — the original per-step phase breakdown (assembly /
//!    literal / execute / sync); skipped with a note when no compiled
//!    artifacts are available.
//!
//! ```bash
//! cargo run --release --example perf_probe [-- preset layers steps]
//! ```

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::batch::BatchAssembler;
use cluster_gcn::coordinator::inference::{
    full_forward_cached, propagate_into, spmm_layer_naive,
};
use cluster_gcn::coordinator::trainer::{step, TrainState};
use cluster_gcn::coordinator::ClusterSampler;
use cluster_gcn::datagen::{build_cached, preset};
use cluster_gcn::graph::Dataset;
use cluster_gcn::norm::{normalize_sparse, NormCache, NormConfig};
use cluster_gcn::partition::{parts_to_clusters, MultilevelPartitioner, Partitioner};
use cluster_gcn::runtime::{Engine, Tensor};
use cluster_gcn::util::pool::{self, scoped_chunks};
use cluster_gcn::util::{bench, Json, Rng, Timer};

/// Deterministic pseudo-random layer weights (Glorot-ish scale).
fn probe_weights(ds: &Dataset, layers: usize, hidden: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    let mut dims = vec![ds.f_in];
    dims.extend(std::iter::repeat(hidden).take(layers - 1));
    dims.push(ds.num_classes);
    dims.windows(2)
        .map(|d| {
            let bound = (6.0 / (d[0] + d[1]) as f64).sqrt() as f32;
            let data = (0..d[0] * d[1]).map(|_| (rng.f32() * 2.0 - 1.0) * bound).collect();
            Tensor::new(vec![d[0], d[1]], data)
        })
        .collect()
}

fn host_kernel_probe(ds: &Dataset, layers: usize, iters: usize) {
    let hidden = 128;
    let weights = probe_weights(ds, layers, hidden, 11);
    let threads = pool::default_threads();

    // normalization phase (cold cost; the NormCache amortizes it away)
    let t = Timer::start();
    let (vals, sl) = normalize_sparse(&ds.graph, NormConfig::PAPER_DEFAULT);
    let normalize_ms = t.secs() * 1e3;

    // naive scalar chain (the pre-overhaul kernel at 1 thread)
    let naive = bench(1, iters, || {
        let mut h = ds.features.clone();
        let mut f = ds.f_in;
        let last = weights.len() - 1;
        for (l, w) in weights.iter().enumerate() {
            h = spmm_layer_naive(&ds.graph, &vals, &sl, &h, f, w, l != last);
            f = w.dims[1];
        }
    });

    // tiled fused kernel, single thread and pooled, through the cache
    let mut cache = NormCache::new();
    let tiled1 = {
        // thread cap 1: same kernel, no parallel dispatch
        let mut cache1 = NormCache::new();
        cache1.get_or_compute(&ds.graph, NormConfig::PAPER_DEFAULT);
        bench(1, iters, || {
            let n = ds.n();
            let adj = cache1.get_or_compute(&ds.graph, NormConfig::PAPER_DEFAULT);
            let mut h = ds.features.clone();
            let mut f = ds.f_in;
            let last = weights.len() - 1;
            for (l, w) in weights.iter().enumerate() {
                let mut z = vec![0f32; n * w.dims[1]];
                cluster_gcn::coordinator::inference::spmm_layer_into(
                    &ds.graph, &adj.vals, &adj.self_loop, &h, f, w, l != last, 1, &mut z,
                );
                h = z;
                f = w.dims[1];
            }
        })
    };
    cache.get_or_compute(&ds.graph, NormConfig::PAPER_DEFAULT); // warm
    let pooled = bench(1, iters, || {
        let _ = full_forward_cached(ds, &weights, NormConfig::PAPER_DEFAULT, false, &mut cache);
    });

    // phase attribution on the first (widest-fanout) layer
    let mut p = vec![0f32; ds.n() * ds.f_in];
    let s_prop = bench(1, iters, || {
        propagate_into(&ds.graph, &vals, &sl, &ds.features, ds.f_in, threads, &mut p);
    });
    let w0 = &weights[0];
    let mut z0 = vec![0f32; ds.n() * w0.dims[1]];
    let s_layer = bench(1, iters, || {
        cluster_gcn::coordinator::inference::spmm_layer_into(
            &ds.graph, &vals, &sl, &ds.features, ds.f_in, w0, true, threads, &mut z0,
        );
    });
    let gemm_ms = ((s_layer.mean - s_prop.mean) * 1e3).max(0.0);

    println!("== host kernels: full-graph forward ({layers} layers, hidden {hidden}) ==");
    println!("normalize (cold)   {normalize_ms:9.2} ms   (amortized to once/run by NormCache)");
    println!("naive  1t          {:9.2} ms", naive.mean * 1e3);
    println!("tiled  1t          {:9.2} ms   ({:.2}x vs naive)", tiled1.mean * 1e3, naive.mean / tiled1.mean);
    println!("tiled  pool({threads})     {:9.2} ms   ({:.2}x vs naive)", pooled.mean * 1e3, naive.mean / pooled.mean);
    println!("layer-0 phase split: spmm {:.2} ms, gemm {gemm_ms:.2} ms", s_prop.mean * 1e3);
    bs::dump_row(
        "perf_probe",
        Json::obj(vec![
            ("kind", Json::str("host_forward")),
            ("layers", Json::num(layers as f64)),
            ("hidden", Json::num(hidden as f64)),
            ("normalize_ms", Json::num(normalize_ms)),
            ("naive_ms", Json::num(naive.mean * 1e3)),
            ("tiled_ms", Json::num(tiled1.mean * 1e3)),
            ("pooled_ms", Json::num(pooled.mean * 1e3)),
            ("spmm_ms", Json::num(s_prop.mean * 1e3)),
            ("gemm_ms", Json::num(gemm_ms)),
            ("speedup_pooled_vs_naive", Json::num(naive.mean / pooled.mean)),
        ]),
    );
}

fn dispatch_probe() {
    let threads = pool::default_threads();
    let reps = 300;
    let spawn = bench(5, reps, || {
        let _ = scoped_chunks(threads, threads, |_, r| r.len());
    });
    let pooled = bench(5, reps, || {
        pool::global().run_chunks(threads, |_, _| {});
    });
    println!("== dispatch overhead ({threads} chunks) ==");
    println!("spawn-per-call     {:9.1} µs", spawn.mean * 1e6);
    println!("persistent pool    {:9.1} µs   ({:.1}x)", pooled.mean * 1e6, spawn.mean / pooled.mean);
    bs::dump_row(
        "perf_probe",
        Json::obj(vec![
            ("kind", Json::str("dispatch")),
            ("spawn_us", Json::num(spawn.mean * 1e6)),
            ("pool_us", Json::num(pooled.mean * 1e6)),
        ]),
    );
}

fn assembly_probe(ds: &Dataset, sampler: &ClusterSampler, b_max: usize, steps: usize) {
    let mut rng = Rng::new(9);
    let plan = sampler.epoch_plan(&mut rng);
    let mut asm = BatchAssembler::new(ds.n(), b_max, NormConfig::PAPER_DEFAULT);
    let mut nodes = Vec::new();

    // allocate-per-step (the pre-overhaul path)
    let t = Timer::start();
    let mut done = 0usize;
    'a: loop {
        for ids in &plan {
            if done >= steps {
                break 'a;
            }
            sampler.batch_nodes(ids, &mut nodes);
            let _batch = asm.assemble(ds, &nodes);
            done += 1;
        }
    }
    let alloc_ms = t.secs() * 1e3 / done as f64;

    // reused zero-allocation path
    let mut batch = asm.new_batch(ds);
    let t = Timer::start();
    let mut done = 0usize;
    'b: loop {
        for ids in &plan {
            if done >= steps {
                break 'b;
            }
            sampler.batch_nodes(ids, &mut nodes);
            asm.assemble_into(ds, &nodes, &mut batch);
            done += 1;
        }
    }
    let reuse_ms = t.secs() * 1e3 / done as f64;

    println!("== batch assembly ({done} steps, b_max {b_max}) ==");
    println!("alloc-per-step     {alloc_ms:9.3} ms/step");
    println!(
        "reused buffers     {reuse_ms:9.3} ms/step   ({:.1}% less)",
        100.0 * (1.0 - reuse_ms / alloc_ms)
    );
    bs::dump_row(
        "perf_probe",
        Json::obj(vec![
            ("kind", Json::str("assembly")),
            ("alloc_ms", Json::num(alloc_ms)),
            ("reuse_ms", Json::num(reuse_ms)),
            ("reduction_pct", Json::num(100.0 * (1.0 - reuse_ms / alloc_ms))),
        ]),
    );
}

fn pjrt_probe(
    ds: &Dataset,
    sampler: &ClusterSampler,
    artifact: &str,
    steps: usize,
) -> anyhow::Result<()> {
    let mut engine = Engine::new(std::path::Path::new("artifacts"))?;
    let meta = engine.meta(artifact)?;
    engine.ensure_compiled(artifact)?;
    let mut rng = Rng::new(7);
    let mut asm = BatchAssembler::new(ds.n(), meta.b_max, NormConfig::PAPER_DEFAULT);
    let mut batch = asm.new_batch(ds);
    let mut state = TrainState::init(&cluster_gcn::runtime::ModelSpec::from(&meta), 0);

    let mut assembly_s = 0.0;
    let mut step_s = 0.0;
    let mut done = 0usize;
    let mut nodes = Vec::new();
    let total = Timer::start();
    'outer: loop {
        let plan = sampler.epoch_plan(&mut rng);
        for ids in &plan {
            if done >= steps {
                break 'outer;
            }
            let t = Timer::start();
            sampler.batch_nodes(ids, &mut nodes);
            asm.assemble_into(ds, &nodes, &mut batch);
            assembly_s += t.secs();
            if batch.n_train == 0 {
                continue;
            }
            let t = Timer::start();
            step(&mut engine, artifact, &mut state, 0.01, &batch)?;
            step_s += t.secs();
            done += 1;
        }
    }
    let total_s = total.secs();

    println!("== perf probe: {artifact}, {done} steps, b_max {} ==", meta.b_max);
    let pct = |x: f64| 100.0 * x / total_s;
    println!("total          {total_s:8.3}s");
    println!("  assembly     {assembly_s:8.3}s  ({:.1}%)", pct(assembly_s));
    println!("  step         {step_s:8.3}s  ({:.1}%)", pct(step_s));
    println!("    literal    {:8.3}s  ({:.1}%)", engine.lit_seconds, pct(engine.lit_seconds));
    println!("    execute    {:8.3}s  ({:.1}%)", engine.exec_seconds, pct(engine.exec_seconds));
    println!("    sync+out   {:8.3}s  ({:.1}%)", engine.sync_seconds, pct(engine.sync_seconds));
    println!(
        "    other      {:8.3}s  (tensor clones, output conversion)",
        step_s - engine.lit_seconds - engine.exec_seconds - engine.sync_seconds
    );
    println!("per-step: {:.2} ms", 1e3 * total_s / done as f64);
    bs::dump_row(
        "perf_probe",
        Json::obj(vec![
            ("kind", Json::str("pjrt_loop")),
            ("artifact", Json::str(artifact)),
            ("assembly_s", Json::num(assembly_s)),
            ("step_s", Json::num(step_s)),
            ("per_step_ms", Json::num(1e3 * total_s / done.max(1) as f64)),
        ]),
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let preset_name = args.get(1).map(String::as_str).unwrap_or("reddit_like");
    let layers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let steps: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(100);
    let iters = bs::env_usize("CGCN_ITERS", 3);

    let p = preset(preset_name).expect("preset");
    let ds = build_cached(p, 42, std::path::Path::new("data"))?;

    host_kernel_probe(&ds, layers, iters);
    dispatch_probe();

    let mut rng = Rng::new(7);
    let part = MultilevelPartitioner::default().partition(
        &ds.graph,
        p.default_partitions,
        &mut rng,
    );
    let sampler =
        ClusterSampler::new(parts_to_clusters(&part, p.default_partitions), p.default_q);
    assembly_probe(&ds, &sampler, p.b_max, steps.max(20));

    let short = preset_name.trim_end_matches("_like");
    let artifact = format!("{short}_L{layers}");
    if let Err(e) = pjrt_probe(&ds, &sampler, &artifact, steps) {
        println!("(PJRT loop skipped: {e})");
    }
    Ok(())
}
