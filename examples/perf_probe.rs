//! Perf probe (EXPERIMENTS.md §Perf): break one training run into its
//! phases — host batch assembly, literal creation, PJRT execute, result
//! sync — so optimization targets the real bottleneck.
//!
//! ```bash
//! cargo run --release --example perf_probe [-- preset layers steps]
//! ```

use cluster_gcn::coordinator::batch::BatchAssembler;
use cluster_gcn::coordinator::trainer::{step, TrainState};
use cluster_gcn::coordinator::ClusterSampler;
use cluster_gcn::datagen::{build_cached, preset};
use cluster_gcn::norm::NormConfig;
use cluster_gcn::partition::{parts_to_clusters, MultilevelPartitioner, Partitioner};
use cluster_gcn::runtime::Engine;
use cluster_gcn::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let preset_name = args.get(1).map(String::as_str).unwrap_or("reddit_like");
    let layers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let steps: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(100);

    let p = preset(preset_name).expect("preset");
    let ds = build_cached(p, 42, std::path::Path::new("data"))?;
    let mut engine = Engine::new(std::path::Path::new("artifacts"))?;
    let short = preset_name.trim_end_matches("_like");
    let artifact = format!("{short}_L{layers}");
    let meta = engine.meta(&artifact)?;
    engine.ensure_compiled(&artifact)?;

    let mut rng = Rng::new(7);
    let part = MultilevelPartitioner::default().partition(
        &ds.graph,
        p.default_partitions,
        &mut rng,
    );
    let sampler = ClusterSampler::new(parts_to_clusters(&part, p.default_partitions), p.default_q);
    let mut asm = BatchAssembler::new(ds.n(), meta.b_max, NormConfig::PAPER_DEFAULT);
    let mut state = TrainState::init(&meta, 0);

    let mut assembly_s = 0.0;
    let mut step_s = 0.0;
    let mut done = 0usize;
    let mut nodes = Vec::new();
    let total = Timer::start();
    'outer: loop {
        let plan = sampler.epoch_plan(&mut rng);
        for ids in &plan {
            if done >= steps {
                break 'outer;
            }
            let t = Timer::start();
            sampler.batch_nodes(ids, &mut nodes);
            let batch = asm.assemble(&ds, &nodes);
            assembly_s += t.secs();
            if batch.n_train == 0 {
                continue;
            }
            let t = Timer::start();
            step(&mut engine, &artifact, &mut state, 0.01, &batch)?;
            step_s += t.secs();
            done += 1;
        }
    }
    let total_s = total.secs();

    println!("== perf probe: {artifact}, {done} steps, b_max {} ==", meta.b_max);
    let pct = |x: f64| 100.0 * x / total_s;
    println!("total          {total_s:8.3}s");
    println!("  assembly     {assembly_s:8.3}s  ({:.1}%)", pct(assembly_s));
    println!("  step         {step_s:8.3}s  ({:.1}%)", pct(step_s));
    println!("    literal    {:8.3}s  ({:.1}%)", engine.lit_seconds, pct(engine.lit_seconds));
    println!("    execute    {:8.3}s  ({:.1}%)", engine.exec_seconds, pct(engine.exec_seconds));
    println!("    sync+out   {:8.3}s  ({:.1}%)", engine.sync_seconds, pct(engine.sync_seconds));
    println!(
        "    other      {:8.3}s  (tensor clones, output conversion)",
        step_s - engine.lit_seconds - engine.exec_seconds - engine.sync_seconds
    );
    println!("per-step: {:.2} ms", 1e3 * total_s / done as f64);
    Ok(())
}
