//! Serving latency benchmark: sweep query mixes through the online
//! serving front and record p50/p99 latency, QPS, and cache behavior.
//!
//! Rows (all on the same preset + fresh deterministic weights):
//!
//! 1. **uniform / exact** — uniform node popularity against the
//!    partition-keyed activation cache (warm: everything hits).
//! 2. **hotset / exact** — power-law-ish hot-set traffic, the regime a
//!    partition-keyed cache is built for.
//! 3. **hotset cross / exact** — hot-set anchors with 50% cross-cluster
//!    batch members, fanning need-sets across partition dependencies.
//! 4. **uniform / clustered** — the block-renormalized (clusters ∪
//!    halo) approximation served per flush, no cross-flush cache.
//!
//! Writes `bench_results/BENCH_serve_mixes.json` (an object with one
//! entry per row) and re-parses it as a well-formedness check.  The
//! CLI `cluster-gcn serve` writes the single-run
//! `bench_results/BENCH_serve.json` the deep CI tier validates;
//! this sweep keeps its own file so the two never clobber each other.
//!
//! ```bash
//! cargo run --release --example serve_bench [-- preset queries]
//! ```

use cluster_gcn::bench_support as bs;
use cluster_gcn::serve::{generate, run_load, LoadConfig, Mix, ServeConfig, ServeMode};
use cluster_gcn::session::{Session, TrainConfig};
use cluster_gcn::util::{Json, Timer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(|s| s.as_str()).unwrap_or("cora_like").to_string();
    let queries = args
        .get(1)
        .map(|s| s.parse::<usize>())
        .transpose()
        .map_err(|_| anyhow::anyhow!("queries must be an integer"))?
        .unwrap_or(2000);
    let seed = bs::env_seed();
    let clients = bs::env_usize("CGCN_CLIENTS", 4);
    let ds = bs::dataset(&preset)?;

    println!("== serve_bench: {} ({} queries, {clients} clients) ==", ds.name, queries);
    let mut table = bs::Table::new(&[
        "mix", "mode", "p50 us", "p99 us", "qps", "hit rate", "flushes",
    ]);

    let rows: [(&str, Mix, f64, ServeMode); 4] = [
        ("uniform", Mix::Uniform, 0.1, ServeMode::ExactCached),
        ("hotset", Mix::Hotset { hot_frac: 0.05, hot_weight: 0.9 }, 0.1, ServeMode::ExactCached),
        ("hotset_cross", Mix::Hotset { hot_frac: 0.05, hot_weight: 0.9 }, 0.5, ServeMode::ExactCached),
        ("clustered", Mix::Uniform, 0.1, ServeMode::Clustered),
    ];

    let mut report = Vec::new();
    for (name, mix, cross, mode) in rows {
        let cfg = TrainConfig { layers: 2, seed, ..TrainConfig::default() };
        let server = Session::new(&ds)
            .config(cfg)
            .into_server(ServeConfig { mode, ..ServeConfig::default() })?;
        let load = LoadConfig {
            mix,
            queries,
            batch: 4,
            cross_frac: cross,
            seed: seed ^ 0x10AD,
        };
        let plan = generate(ds.n(), server.owner(), server.clusters(), &load);
        let t = Timer::start();
        server.warm();
        let warm_s = t.secs();
        server.reset_stats();
        let r = run_load(&server, &plan, clients)?;
        let st = server.stats();
        assert!(
            r.p99_us >= r.p50_us && r.p50_us > 0.0,
            "{name}: latency percentile invariant violated"
        );
        let hit_rate = if st.hits + st.misses > 0 {
            st.hits as f64 / (st.hits + st.misses) as f64
        } else {
            0.0
        };
        table.row(&[
            name.to_string(),
            format!("{mode:?}"),
            format!("{:.1}", r.p50_us),
            format!("{:.1}", r.p99_us),
            format!("{:.0}", r.qps),
            format!("{hit_rate:.3}"),
            format!("{}", st.flushes),
        ]);
        report.push((
            name,
            Json::obj(vec![
                ("mode", Json::str(&format!("{mode:?}"))),
                ("warm_secs", Json::num(warm_s)),
                ("p50_us", Json::num(r.p50_us)),
                ("p99_us", Json::num(r.p99_us)),
                ("mean_us", Json::num(r.mean_us)),
                ("qps", Json::num(r.qps)),
                ("hit_rate", Json::num(hit_rate)),
                ("cache_hits", Json::num(st.hits as f64)),
                ("cache_misses", Json::num(st.misses as f64)),
                ("flushes", Json::num(st.flushes as f64)),
                ("digest", Json::str(&format!("{:016x}", r.digest))),
            ]),
        ));
    }
    table.print();

    let json = Json::obj(
        std::iter::once(("preset", Json::str(&ds.name)))
            .chain(std::iter::once(("queries", Json::num(queries as f64))))
            .chain(std::iter::once(("clients", Json::num(clients as f64))))
            .chain(report.iter().map(|(k, v)| (*k, v.clone())))
            .collect(),
    );
    std::fs::create_dir_all("bench_results")?;
    let path = "bench_results/BENCH_serve_mixes.json";
    std::fs::write(path, json.to_string())?;

    // well-formedness: the file must round-trip and carry every row
    let parsed = Json::parse(&std::fs::read_to_string(path)?)
        .map_err(|e| anyhow::anyhow!("BENCH_serve_mixes.json does not parse: {e}"))?;
    for (name, ..) in rows {
        let row = parsed
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("BENCH_serve_mixes.json missing row {name}"))?;
        for key in ["p50_us", "p99_us", "qps", "hit_rate"] {
            anyhow::ensure!(
                row.get(key).is_some(),
                "BENCH_serve_mixes.json row {name} missing {key}"
            );
        }
    }
    println!("wrote {path}");
    Ok(())
}
