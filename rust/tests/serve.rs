//! Serving-layer acceptance suite:
//!
//! - **parity**: exact-mode responses are bit-identical to rows of the
//!   offline `full_forward_cached` forward — cache-cold, cache-warm,
//!   and after invalidation (the ISSUE's serving-parity property);
//! - **invalidation is load-bearing**: installing perturbed weights
//!   mid-serve evicts stale entries and answers match a fresh offline
//!   forward under the new weights;
//! - **coalescing**: N concurrent callers each receive their own
//!   correct row while the flush count stays below the query count,
//!   and single-threaded replays are byte-identical with exactly one
//!   flush per query;
//! - **clustered mode**: with a single partition the block-renormalized
//!   subgraph *is* the full graph, so clustered serving is bitwise
//!   exact; with many partitions it replays deterministically;
//! - **load generator**: plans and digests are pure functions of the
//!   seed, and warm exact-mode runs serve entirely from cache.

use cluster_gcn::coordinator::inference::{full_forward_cached, gather_rows};
use cluster_gcn::coordinator::trainer::TrainState;
use cluster_gcn::datagen::features::{gen_features, gen_labels, LabelModel};
use cluster_gcn::datagen::{generate as gen_graph, SbmSpec};
use cluster_gcn::graph::{Dataset, Split, Task};
use cluster_gcn::norm::{NormCache, NormConfig};
use cluster_gcn::runtime::ModelSpec;
use cluster_gcn::serve::{
    generate, run_load, Coalescer, LoadConfig, Mix, ServeConfig, ServeMode, Server,
};
use cluster_gcn::session::{Session, TrainConfig};
use cluster_gcn::util::Rng;

/// A tiny SBM dataset with strong community→label→feature coupling
/// (same construction as `tests/driver.rs`).
fn tiny_sbm(seed: u64) -> Dataset {
    let n = 240;
    let communities = 8;
    let classes = 4;
    let f_in = 16;
    let mut rng = Rng::new(seed);
    let sbm = gen_graph(
        &SbmSpec { n, communities, avg_deg: 8.0, intra_frac: 0.9, size_skew: 0.5 },
        &mut rng,
    );
    let labels = gen_labels(
        &LabelModel { task: Task::Multiclass, classes, noise: 0.05, active_per_community: 0 },
        &sbm.community,
        communities,
        &mut rng,
    );
    let features =
        gen_features(&labels, &sbm.community, communities, classes, f_in, 0.3, &mut rng);
    let split = (0..n)
        .map(|i| match i % 10 {
            0..=6 => Split::Train,
            7..=8 => Split::Val,
            _ => Split::Test,
        })
        .collect();
    let ds = Dataset {
        name: "tiny_sbm".into(),
        task: Task::Multiclass,
        graph: sbm.graph,
        f_in,
        num_classes: classes,
        features,
        labels,
        split,
    };
    ds.validate().unwrap();
    ds
}

const HIDDEN: usize = 32;

fn serve_cfg(seed: u64) -> TrainConfig {
    TrainConfig { layers: 2, hidden: Some(HIDDEN), seed, ..TrainConfig::default() }
}

/// The weights `Session::into_server` serves for `serve_cfg(seed)`
/// with no initial state — replicated here so tests can run the
/// offline oracle under the identical parameters.
fn served_weights(ds: &Dataset, seed: u64) -> Vec<cluster_gcn::runtime::Tensor> {
    let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, HIDDEN, ds.num_classes, 8);
    TrainState::init(&spec, seed).weights
}

fn offline_logits(ds: &Dataset, weights: &[cluster_gcn::runtime::Tensor]) -> Vec<f32> {
    let mut nc = NormCache::new();
    full_forward_cached(ds, weights, NormConfig::PAPER_DEFAULT, false, &mut nc)
}

fn make_server(ds: &Dataset, seed: u64, mode: ServeMode, parts: Option<usize>) -> Server<'_> {
    let mut session = Session::new(ds).config(serve_cfg(seed));
    if let Some(p) = parts {
        session = session.partition(p);
    }
    session
        .into_server(ServeConfig { mode, ..ServeConfig::default() })
        .unwrap()
}

#[test]
fn exact_mode_matches_full_forward_bitwise_cold_and_warm() {
    let ds = tiny_sbm(11);
    let server = make_server(&ds, 7, ServeMode::ExactCached, None);
    let full = offline_logits(&ds, &served_weights(&ds, 7));
    let classes = ds.num_classes;
    let plans: Vec<Vec<u32>> = vec![
        vec![5],
        vec![0, 17, 200],
        vec![239, 1, 1], // duplicates allowed
        (0..40).collect(),
    ];
    for q in &plans {
        assert_eq!(server.query(q).unwrap(), gather_rows(&full, classes, q), "cold {q:?}");
    }
    let st1 = server.stats();
    assert!(st1.misses > 0, "cold pass must compute entries");
    for q in &plans {
        assert_eq!(server.query(q).unwrap(), gather_rows(&full, classes, q), "warm {q:?}");
    }
    let st2 = server.stats();
    assert_eq!(st2.misses, st1.misses, "warm pass must not recompute anything");
    assert!(st2.hits > st1.hits, "warm pass must be served from cache");
    assert_eq!(st2.evictions, 0, "no invalidation happened");
}

#[test]
fn weight_install_invalidates_and_never_serves_stale_rows() {
    let ds = tiny_sbm(12);
    let seed = 3;
    let server = make_server(&ds, seed, ServeMode::ExactCached, None);
    let q: Vec<u32> = (0..ds.n() as u32).step_by(7).collect();
    let w1 = served_weights(&ds, seed);
    assert_eq!(server.query(&q).unwrap(), gather_rows(&offline_logits(&ds, &w1), 4, &q));

    // a "gradient step": perturb and install mid-serve
    let mut w2 = w1.clone();
    w2[0].data[3] += 0.25;
    w2[1].data[0] -= 0.125;
    server.install_weights(w2.clone()).unwrap();
    let full2 = offline_logits(&ds, &w2);
    assert_eq!(
        server.query(&q).unwrap(),
        gather_rows(&full2, 4, &q),
        "post-install responses must reflect the new weights"
    );
    assert!(server.stats().evictions > 0, "stale entries must have been evicted");

    // shape-mismatched installs are rejected
    let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, HIDDEN + 1, ds.num_classes, 8);
    assert!(server.install_weights(TrainState::init(&spec, 0).weights).is_err());
}

#[test]
fn coalescer_merges_concurrent_queries_into_fewer_flushes() {
    const N: usize = 16;
    let co = Coalescer::new(64);
    std::thread::scope(|s| {
        for t in 0..N as u32 {
            let co = &co;
            s.spawn(move || {
                let resp = co
                    .run(vec![t], |lists| {
                        // the first flush leader stalls until every thread
                        // has enqueued, so the remaining N-1 requests are
                        // provably coalesced into at most one more flush
                        while co.stats().queries < N as u64 {
                            std::thread::yield_now();
                        }
                        Ok(lists
                            .iter()
                            .map(|l| l.iter().map(|&v| v as f32 * 2.0).collect())
                            .collect())
                    })
                    .unwrap();
                assert_eq!(resp, vec![t as f32 * 2.0], "caller {t} got someone else's row");
            });
        }
    });
    let st = co.stats();
    assert_eq!(st.queries, N as u64);
    assert!(st.flushes <= 2, "expected ≤ 2 flushes, got {}", st.flushes);
    assert!((st.flushes as usize) < N, "coalescing must merge requests");
    // ≤ 2 flushes over N requests ⇒ the larger one carried at least N/2
    assert!(st.max_flush >= N / 2, "a flush must have merged many requests");
}

#[test]
fn single_thread_replay_is_byte_identical_with_one_flush_per_query() {
    let ds = tiny_sbm(13);
    let plan: Vec<Vec<u32>> = (0..20u32).map(|i| vec![(i * 11) % 240, (i * 7) % 240]).collect();
    let run = |seed: u64| -> Vec<Vec<f32>> {
        let server = make_server(&ds, seed, ServeMode::ExactCached, None);
        let out: Vec<Vec<f32>> = plan.iter().map(|q| server.query(q).unwrap()).collect();
        let st = server.stats();
        assert_eq!(st.queries, 20);
        assert_eq!(st.flushes, 20, "single-threaded: one flush per query");
        assert_eq!(st.max_flush, 1);
        out
    };
    let (a, b) = (run(5), run(5));
    for (qa, qb) in a.iter().zip(&b) {
        let (ba, bb): (Vec<u32>, Vec<u32>) = (
            qa.iter().map(|x| x.to_bits()).collect(),
            qb.iter().map(|x| x.to_bits()).collect(),
        );
        assert_eq!(ba, bb, "replay must be byte-identical");
    }
}

#[test]
fn concurrent_callers_each_get_their_own_rows() {
    let ds = tiny_sbm(14);
    let server = make_server(&ds, 9, ServeMode::ExactCached, None);
    let full = offline_logits(&ds, &served_weights(&ds, 9));
    std::thread::scope(|s| {
        for t in 0..8u32 {
            let (server, full) = (&server, &full);
            s.spawn(move || {
                for i in 0..30u32 {
                    let v = (t * 31 + i * 13) % 240;
                    assert_eq!(
                        server.query_one(v).unwrap(),
                        gather_rows(full, 4, &[v]),
                        "thread {t} query {v}"
                    );
                }
            });
        }
    });
    let st = server.stats();
    assert_eq!(st.queries, 8 * 30);
    assert!(st.flushes <= st.queries);
    assert!(server.query(&[240]).is_err(), "out-of-range ids are rejected");
}

#[test]
fn clustered_mode_with_one_partition_is_bitwise_exact() {
    let ds = tiny_sbm(15);
    let server = make_server(&ds, 21, ServeMode::Clustered, Some(1));
    let full = offline_logits(&ds, &served_weights(&ds, 21));
    let all: Vec<u32> = (0..240).collect();
    // one partition ⇒ the (clusters ∪ halo) block is the full graph and
    // block renormalization equals the full-graph normalization
    assert_eq!(server.query(&all).unwrap(), full);
    assert_eq!(server.query(&[3, 77, 191]).unwrap(), gather_rows(&full, 4, &[3, 77, 191]));
}

#[test]
fn clustered_mode_replays_deterministically() {
    let ds = tiny_sbm(16);
    let plan: Vec<Vec<u32>> = (0..15u32).map(|i| vec![(i * 37) % 240, (i * 3) % 240]).collect();
    let run = || -> Vec<Vec<u32>> {
        let server = make_server(&ds, 4, ServeMode::Clustered, Some(5));
        plan.iter()
            .flat_map(|q| server.query(q).unwrap())
            .map(|x| x.to_bits())
            .collect()
    };
    assert_eq!(run(), run(), "clustered replay must be byte-identical");
}

#[test]
fn loadgen_plans_and_digests_are_deterministic_and_warm_runs_all_hit() {
    let ds = tiny_sbm(17);
    let server = make_server(&ds, 6, ServeMode::ExactCached, None);
    let load = LoadConfig {
        mix: Mix::Hotset { hot_frac: 0.1, hot_weight: 0.9 },
        queries: 120,
        batch: 3,
        cross_frac: 0.25,
        seed: 99,
    };
    let plan = generate(ds.n(), server.owner(), server.clusters(), &load);
    assert_eq!(plan, generate(ds.n(), server.owner(), server.clusters(), &load));

    server.warm();
    server.reset_stats();
    let r1 = run_load(&server, &plan, 1).unwrap();
    assert!(r1.p50_us > 0.0 && r1.p99_us >= r1.p50_us, "percentile invariant");
    assert!(r1.qps > 0.0);
    let st = server.stats();
    assert_eq!(st.misses, 0, "a warm exact cache serves everything from cache");
    assert!(st.hits > 0);

    // same plan on a fresh identical server, more clients: identical
    // bits, so identical digest (the digest is order-independent)
    let server2 = make_server(&ds, 6, ServeMode::ExactCached, None);
    server2.warm();
    let r2 = run_load(&server2, &plan, 4).unwrap();
    assert_eq!(r1.digest, r2.digest, "digest must be replay- and client-count-invariant");
}
