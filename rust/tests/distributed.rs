//! End-to-end tests of the cross-process [`DistributedBackend`]: a
//! chief in this test process spawns real worker *processes* (this same
//! test binary, re-entered through the `worker_entry_hook` test below)
//! and trains over the socket protocol.
//!
//! The two contracts pinned here are the ones ci.sh gates on:
//!
//! - `--workers 1` replays the plain `HostBackend` run **bit-identically**
//!   (loss bits and final weight bits);
//! - a run with an injected socket fault (torn request frame) replays
//!   the fault-free distributed run bit-identically, because exchanges
//!   are idempotent and recovery is reconnect-and-retry.

use std::sync::{Arc, Mutex};

use cluster_gcn::datagen::{build_cached, preset};
use cluster_gcn::graph::Dataset;
use cluster_gcn::norm::NormConfig;
use cluster_gcn::runtime::distributed::{worker_main, WorkerSetup};
use cluster_gcn::runtime::{Compression, DistConfig, DistStats, DistributedBackend, Transport};
use cluster_gcn::session::{Method, Session, SessionResult, TrainConfig};
use cluster_gcn::util::failpoint;

/// Worker-process entry: when the chief spawned us (rendezvous env set)
/// run the worker loop until `Shutdown`; as an ordinary test in the
/// normal suite it is a no-op.
#[test]
fn worker_entry_hook() {
    if std::env::var("CGCN_DIST_ADDR").is_err() {
        return;
    }
    worker_main().unwrap();
}

/// Failpoints and the dataset cache are process-global; serialize the
/// tests that spawn chiefs.
static TEST_LOCK: Mutex<()> = Mutex::new(());

const PRESET: &str = "cora_like";
const DS_SEED: u64 = 42;
const PARTS: usize = 8;
const CFG_SEED: u64 = 5;

fn cache_dir() -> String {
    std::env::temp_dir()
        .join(format!("cgcn-dist-test-{}", std::process::id()))
        .display()
        .to_string()
}

fn dataset() -> Dataset {
    let p = preset(PRESET).unwrap();
    build_cached(p, DS_SEED, std::path::Path::new(&cache_dir())).unwrap()
}

fn train_cfg() -> TrainConfig {
    TrainConfig {
        layers: 2,
        hidden: Some(16),
        lr: 0.01,
        epochs: 2,
        eval_every: 1,
        seed: CFG_SEED,
        ..TrainConfig::default()
    }
}

fn worker_setup(n_workers: usize, compression: Compression) -> WorkerSetup {
    WorkerSetup {
        preset: PRESET.into(),
        ds_seed: DS_SEED,
        cache: cache_dir(),
        cfg_seed: CFG_SEED,
        layers: 2,
        hidden: Some(16),
        b_max: None,
        parts: Some(PARTS),
        q: 1,
        random_partition: false,
        norm: NormConfig::PAPER_DEFAULT,
        n_workers,
        compression,
    }
}

/// Spawned workers re-enter THIS test binary and run only
/// `worker_entry_hook` (libtest's `--exact` filter).
fn test_worker_cmd() -> (std::path::PathBuf, Vec<String>) {
    let exe = std::env::current_exe().unwrap();
    let args = vec![
        "worker_entry_hook".to_string(),
        "--exact".to_string(),
        "--nocapture".to_string(),
    ];
    (exe, args)
}

fn run_distributed(
    ds: &Dataset,
    workers: usize,
    transport: Transport,
    compression: Compression,
) -> (SessionResult, Arc<DistStats>) {
    let mut cfg = DistConfig::new(workers, transport, worker_setup(workers, compression));
    cfg.worker_cmd = Some(test_worker_cmd());
    let be = DistributedBackend::new(cfg);
    let stats = be.stats();
    let out = Session::new(ds)
        .method(Method::Cluster { q: 1 })
        .partition(PARTS)
        .config(train_cfg())
        .workers(workers)
        .backend(Box::new(be))
        .run()
        .unwrap();
    (out, stats)
}

fn run_host(ds: &Dataset) -> SessionResult {
    Session::new(ds)
        .method(Method::Cluster { q: 1 })
        .partition(PARTS)
        .config(train_cfg())
        .prefetch(false)
        .run()
        .unwrap()
}

/// Bitwise equality of two runs: loss curve bits and final weight bits.
fn assert_bitwise_equal(a: &SessionResult, b: &SessionResult, what: &str) {
    assert_eq!(a.result.curve.len(), b.result.curve.len(), "{what}: curve length");
    for (x, y) in a.result.curve.iter().zip(&b.result.curve) {
        assert_eq!(x.epoch, y.epoch, "{what}: epoch order");
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{what}: epoch {} loss bits ({} vs {})",
            x.epoch,
            x.train_loss,
            y.train_loss
        );
        assert_eq!(
            x.eval_f1.to_bits(),
            y.eval_f1.to_bits(),
            "{what}: epoch {} eval bits",
            x.epoch
        );
    }
    let (wa, wb) = (&a.result.state.weights, &b.result.state.weights);
    assert_eq!(wa.len(), wb.len(), "{what}: weight tensor count");
    for (li, (ta, tb)) in wa.iter().zip(wb).enumerate() {
        assert_eq!(ta.data.len(), tb.data.len(), "{what}: layer {li} size");
        for (i, (x, y)) in ta.data.iter().zip(&tb.data).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: layer {li} weight {i} ({x} vs {y})"
            );
        }
    }
}

/// `workers = 1` over a real spawned worker process is bit-identical to
/// the plain single-process `HostBackend` run — the parity contract.
#[test]
fn workers_one_replays_host_run_bitwise() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::clear();
    let ds = dataset();
    let host = run_host(&ds);
    let (dist, stats) = run_distributed(&ds, 1, Transport::Unix, Compression::None);
    assert_eq!(dist.backend, "distributed");
    assert_bitwise_equal(&host, &dist, "workers=1 vs host");
    assert!(stats.steps.load(std::sync::atomic::Ordering::Relaxed) > 0);
    assert_eq!(stats.retries.load(std::sync::atomic::Ordering::Relaxed), 0);
    assert_eq!(stats.respawns.load(std::sync::atomic::Ordering::Relaxed), 0);
    // raw gradients on the wire: no compression, ratio stays ~1
    assert!(stats.compression_ratio() < 1.1, "{}", stats.compression_ratio());
}

/// Two workers split the clusters and average gradients — not bitwise
/// vs one worker (the batch per Adam step doubles), but the loss curve
/// must stay equivalent: training converges to the same neighborhood.
#[test]
fn two_workers_stay_loss_curve_equivalent() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::clear();
    let ds = dataset();
    let host = run_host(&ds);
    let (dist, stats) = run_distributed(&ds, 2, Transport::Unix, Compression::None);
    let (hf, df) = (
        host.result.curve.last().unwrap(),
        dist.result.curve.last().unwrap(),
    );
    assert!(df.train_loss.is_finite() && df.eval_f1.is_finite());
    let first = dist.result.curve.first().unwrap();
    assert!(
        df.train_loss < first.train_loss,
        "2-worker loss did not decrease ({} -> {})",
        first.train_loss,
        df.train_loss
    );
    let rel = (df.train_loss - hf.train_loss).abs() / hf.train_loss.abs().max(1e-9);
    assert!(
        rel < 0.75,
        "2-worker final loss {} drifted from host {} (rel {rel:.3})",
        df.train_loss,
        hf.train_loss
    );
    assert!(stats.bytes_tx.load(std::sync::atomic::Ordering::Relaxed) > 0);
    assert!(stats.bytes_rx.load(std::sync::atomic::Ordering::Relaxed) > 0);
}

/// One injected torn request frame (the `dist.send.torn` failpoint,
/// firing exactly once in the chief) forces a worker reconnect and an
/// exchange retry — and the recovered run replays the fault-free
/// 2-worker trajectory bit for bit, because exchanges are idempotent.
#[test]
fn torn_frame_recovery_replays_clean_run_bitwise() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::clear();
    let ds = dataset();
    let (clean, _) = run_distributed(&ds, 2, Transport::Unix, Compression::None);
    failpoint::install("dist.send.torn=1:1", 0).unwrap();
    let (faulted, stats) = run_distributed(&ds, 2, Transport::Unix, Compression::None);
    failpoint::clear();
    assert!(
        stats.retries.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "the torn frame must force a retry"
    );
    assert!(
        stats.reconnects.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "the torn frame must force a reconnect"
    );
    assert_bitwise_equal(&clean, &faulted, "faulted vs clean 2-worker");
}

/// TCP transport and 8-bit quantized gradient uplink: still trains, and
/// the wire carries ~4x fewer gradient bytes than the dense f32s.
#[test]
fn tcp_transport_with_quantized_gradients_trains() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::clear();
    let ds = dataset();
    let (dist, stats) = run_distributed(&ds, 2, Transport::Tcp, Compression::Quant8);
    let first = dist.result.curve.first().unwrap();
    let last = dist.result.curve.last().unwrap();
    assert!(last.train_loss.is_finite() && last.eval_f1.is_finite());
    assert!(
        last.train_loss < first.train_loss,
        "quantized run loss did not decrease ({} -> {})",
        first.train_loss,
        last.train_loss
    );
    assert!(
        stats.compression_ratio() > 2.5,
        "q8 compression ratio only {:.2}",
        stats.compression_ratio()
    );
}
