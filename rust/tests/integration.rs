//! End-to-end integration tests over the real PJRT runtime + AOT
//! artifacts.  These require `make artifacts` to have produced at least
//! the cora/ppi artifacts; they are skipped (with a message) otherwise
//! so `cargo test` stays usable before the python step.

#![allow(unused_imports)]

use cluster_gcn::coordinator::{
    evaluate, train, BatchAssembler, ClusterSampler, TrainState,
};
use cluster_gcn::session::TrainConfig;
use cluster_gcn::datagen::{build, preset};
use cluster_gcn::norm::NormConfig;
use cluster_gcn::partition::{parts_to_clusters, MultilevelPartitioner, Partitioner};
use cluster_gcn::runtime::{Engine, ModelSpec, Tensor};
use cluster_gcn::util::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

fn engine_or_skip(needed: &[&str]) -> Option<Engine> {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ missing (run `make artifacts`)");
        return None;
    };
    for name in needed {
        let meta = Engine::new(&dir).ok()?.meta(name).ok()?;
        if !meta.file.exists() {
            eprintln!("SKIP: artifact {name} not lowered yet");
            return None;
        }
    }
    Engine::new(&dir).ok()
}

/// Host dense-block forward oracle over an assembled batch (independent
/// of both the PJRT path and `coordinator::inference`).
fn dense_block_forward(
    a: &Tensor,
    x: &Tensor,
    weights: &[Tensor],
) -> Vec<f32> {
    let b = a.dims[0];
    let mut h = x.data.clone();
    let mut f = x.dims[1];
    let last = weights.len() - 1;
    for (l, w) in weights.iter().enumerate() {
        let g = w.dims[1];
        let mut p = vec![0f32; b * f];
        for i in 0..b {
            for j in 0..b {
                let av = a.data[i * b + j];
                if av != 0.0 {
                    for t in 0..f {
                        p[i * f + t] += av * h[j * f + t];
                    }
                }
            }
        }
        let mut z = vec![0f32; b * g];
        for i in 0..b {
            for t in 0..f {
                let pv = p[i * f + t];
                if pv != 0.0 {
                    for k in 0..g {
                        z[i * g + k] += pv * w.data[t * g + k];
                    }
                }
            }
        }
        if l != last {
            z.iter_mut().for_each(|v| *v = v.max(0.0));
        }
        h = z;
        f = g;
    }
    h
}

#[test]
fn forward_artifact_matches_host_oracle() {
    let Some(mut engine) = engine_or_skip(&["ppi_L2_fwd"]) else {
        return;
    };
    let meta = engine.meta("ppi_L2_fwd").unwrap();
    let ds = build(preset("ppi_like").unwrap(), 11);
    let mut asm = BatchAssembler::new(ds.n(), meta.b_max, NormConfig::PAPER_DEFAULT);
    let nodes: Vec<u32> = (0..400u32).collect();
    let batch = asm.assemble(&ds, &nodes);

    let state = TrainState::init(&ModelSpec::from(&meta), 5);
    let mut inputs: Vec<Tensor> = state.weights.clone();
    inputs.push(batch.a.clone());
    inputs.push(batch.x.clone());
    let out = engine.run("ppi_L2_fwd", &inputs).unwrap();
    assert_eq!(out.len(), 1);
    let logits = &out[0];
    assert_eq!(logits.dims, vec![meta.b_max, meta.classes]);

    let expect = dense_block_forward(&batch.a, &batch.x, &state.weights);
    let mut max_err = 0f32;
    for (a, b) in logits.data.iter().zip(&expect) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-3, "PJRT vs host oracle max err {max_err}");
}

#[test]
fn train_step_decreases_loss_and_learns() {
    let Some(mut engine) = engine_or_skip(&["cora_L2"]) else {
        return;
    };
    let ds = build(preset("cora_like").unwrap(), 42);
    let mut rng = Rng::new(9);
    let part = MultilevelPartitioner::default().partition(&ds.graph, 10, &mut rng);
    let clusters = parts_to_clusters(&part, 10);
    let sampler = ClusterSampler::new(clusters, 1);

    let opts = TrainConfig {
        epochs: 12,
        eval_every: 6,
        seed: 1,
        ..TrainConfig::default()
    };
    let result = train(&mut engine, &ds, &sampler, "cora_L2", &opts).unwrap();

    // loss must drop substantially from the first to the last epoch
    let first = result.curve.first().unwrap().train_loss;
    let last = result.curve.last().unwrap().train_loss;
    assert!(
        last < first * 0.8,
        "loss did not decrease: {first} -> {last}"
    );
    // and val F1 must comfortably beat the 1/7 random-guess baseline
    let f1 = result.curve.last().unwrap().eval_f1;
    assert!(f1 > 0.4, "val F1 too low: {f1}");
    assert!(result.steps >= 100, "expected ~10 steps/epoch");
}

#[test]
fn vrgcn_baseline_trains() {
    let Some(mut engine) = engine_or_skip(&["ppi_vrgcn_L2"]) else {
        return;
    };
    let ds = build(preset("ppi_like").unwrap(), 6);
    let opts = TrainConfig {
        epochs: 1,
        eval_every: 1,
        seed: 3,
        max_steps_per_epoch: 100,
        ..TrainConfig::default()
    };
    let r = cluster_gcn::baselines::train_vrgcn(
        &mut engine,
        &ds,
        "ppi_vrgcn_L2",
        &cluster_gcn::baselines::VrgcnParams::default(),
        &opts,
    )
    .unwrap();
    assert!(r.steps >= 50, "expected a full-ish epoch, got {}", r.steps);
    let pt = r.curve.last().unwrap();
    assert!(pt.train_loss.is_finite());
    // all-negative predictions score 0 F1; 100 steps must clearly learn
    assert!(pt.eval_f1 > 0.3, "vrgcn f1 {}", pt.eval_f1);
    // the O(NLF) history must show up in the memory accounting
    let history_bytes = ds.n() * 512 * 4;
    assert!(r.peak_bytes > history_bytes, "history missing from peak");
}

#[test]
fn graphsage_baseline_trains() {
    let Some(mut engine) = engine_or_skip(&["ppi_sage_L2"]) else {
        return;
    };
    let ds = build(preset("ppi_like").unwrap(), 6);
    let opts = TrainConfig {
        epochs: 1,
        eval_every: 1,
        seed: 3,
        max_steps_per_epoch: 5,
        ..TrainConfig::default()
    };
    let r = cluster_gcn::baselines::train_graphsage(
        &mut engine,
        &ds,
        "ppi_sage_L2",
        &cluster_gcn::baselines::SageParams::for_depth(2, 128),
        &opts,
    )
    .unwrap();
    assert_eq!(r.steps, 5);
    assert!(r.curve.last().unwrap().train_loss.is_finite());
}

#[test]
fn engine_rejects_wrong_input_count() {
    let Some(mut engine) = engine_or_skip(&["cora_L2"]) else {
        return;
    };
    let err = engine.run("cora_L2", &[Tensor::scalar(1.0)]).unwrap_err();
    assert!(err.to_string().contains("inputs"), "unexpected: {err}");
}

#[test]
fn engine_rejects_unknown_artifact() {
    let Some(mut engine) = engine_or_skip(&["cora_L2"]) else {
        return;
    };
    assert!(engine.run("no_such_artifact", &[]).is_err());
}

#[test]
fn cluster_forward_matches_host_oracle_per_batch() {
    // batch_eval's PJRT cluster-wise inference must agree with the host
    // dense-block oracle on every batch (same weights, same blocks).
    let Some(mut engine) = engine_or_skip(&["ppi_L2_fwd"]) else {
        return;
    };
    let meta = engine.meta("ppi_L2_fwd").unwrap();
    let ds = build(preset("ppi_like").unwrap(), 21);
    let mut rng = Rng::new(5);
    let part = MultilevelPartitioner::default().partition(&ds.graph, 50, &mut rng);
    let sampler = ClusterSampler::new(parts_to_clusters(&part, 50), 1);
    let state = TrainState::init(&ModelSpec::from(&meta), 1);

    let logits = cluster_gcn::coordinator::batch_eval::cluster_forward(
        &mut engine,
        &ds,
        &sampler,
        "ppi_L2_fwd",
        &state.weights,
        NormConfig::PAPER_DEFAULT,
        7,
    )
    .unwrap();
    assert_eq!(logits.len(), ds.n() * ds.num_classes);

    // oracle check on one batch
    let mut rng2 = Rng::new(7);
    let plan = sampler.epoch_plan(&mut rng2);
    let mut nodes = Vec::new();
    sampler.batch_nodes(&plan[0], &mut nodes);
    let mut asm = BatchAssembler::new(ds.n(), meta.b_max, NormConfig::PAPER_DEFAULT);
    let batch = asm.assemble(&ds, &nodes);
    let expect = dense_block_forward(&batch.a, &batch.x, &state.weights);
    for (i, &v) in nodes.iter().enumerate() {
        for c in 0..ds.num_classes {
            let got = logits[v as usize * ds.num_classes + c];
            let want = expect[i * ds.num_classes + c];
            assert!(
                (got - want).abs() < 1e-3,
                "node {v} class {c}: {got} vs {want}"
            );
        }
    }
}

#[test]
fn expansion_trainer_runs() {
    let Some(mut engine) = engine_or_skip(&["ppi_sage_L2"]) else {
        return;
    };
    let ds = build(preset("ppi_like").unwrap(), 8);
    let opts = TrainConfig {
        epochs: 1,
        eval_every: 1,
        seed: 2,
        max_steps_per_epoch: 5,
        ..TrainConfig::default()
    };
    // vanilla SGD through the wider sage artifact (expansion needs room)
    let r = cluster_gcn::baselines::expansion::train_expansion(
        &mut engine,
        &ds,
        "ppi_sage_L2",
        32,
        &opts,
    )
    .unwrap();
    assert_eq!(r.steps, 5);
    assert!(r.curve.last().unwrap().train_loss.is_finite());
}

#[test]
fn early_stopping_halts_training() {
    let Some(mut engine) = engine_or_skip(&["cora_L2"]) else {
        return;
    };
    let ds = build(preset("cora_like").unwrap(), 9);
    let mut rng = Rng::new(1);
    let part = MultilevelPartitioner::default().partition(&ds.graph, 10, &mut rng);
    let sampler = ClusterSampler::new(parts_to_clusters(&part, 10), 1);
    let opts = TrainConfig {
        epochs: 100,
        eval_every: 1,
        seed: 1,
        patience: 2,
        ..TrainConfig::default()
    };
    let r = train(&mut engine, &ds, &sampler, "cora_L2", &opts).unwrap();
    let last_epoch = r.curve.last().unwrap().epoch;
    assert!(
        last_epoch < 100,
        "early stopping never fired (ran all {last_epoch} epochs)"
    );
}

#[test]
fn random_vs_cluster_partition_quality_table2_shape() {
    // The Table 2 effect at miniature scale: training on clustered
    // batches beats training on random batches for the same budget.
    let Some(mut engine) = engine_or_skip(&["cora_L2"]) else {
        return;
    };
    let ds = build(preset("cora_like").unwrap(), 3);
    let opts = TrainConfig {
        epochs: 10,
        eval_every: 10,
        seed: 2,
        eval_split: cluster_gcn::graph::Split::Test,
        ..TrainConfig::default()
    };

    let mut f1s = Vec::new();
    for use_cluster in [true, false] {
        let mut rng = Rng::new(4);
        let part = if use_cluster {
            MultilevelPartitioner::default().partition(&ds.graph, 10, &mut rng)
        } else {
            cluster_gcn::partition::RandomPartitioner.partition(&ds.graph, 10, &mut rng)
        };
        let sampler = ClusterSampler::new(parts_to_clusters(&part, 10), 1);
        let r = train(&mut engine, &ds, &sampler, "cora_L2", &opts).unwrap();
        f1s.push(r.curve.last().unwrap().eval_f1);
    }
    assert!(
        f1s[0] > f1s[1] - 0.02,
        "cluster ({:.3}) should not trail random ({:.3})",
        f1s[0],
        f1s[1]
    );
}
