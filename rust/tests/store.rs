//! Out-of-core storage acceptance suite (PR 9):
//!
//! - **roundtrip**: `write_store` → `DiskDataset::open` preserves every
//!   section for both tasks, and `to_dataset` is the exact inverse;
//! - **chunk-stream parity**: normalization and batch assembly over the
//!   on-disk store are bitwise-equal to the in-RAM path across chunk
//!   sizes {1, prime, full} — the core `--storage ram|disk` guarantee;
//! - **typed corruption**: a truncated file, a bit-flipped header, a
//!   wrong magic, and flipped data bytes each fail with the matching
//!   `StoreError` variant (mirroring the CGCNCKP3 checkpoint tests) —
//!   never a panic or silent acceptance;
//! - **streaming partitioner**: identical assignments on the RAM and
//!   disk storage arms;
//! - **out-of-core training**: `train_storage` over `OnDisk` replays
//!   the `InRam` run bitwise (losses, eval F1, weight bits), and
//!   `cluster_evaluate_storage` equals the resident
//!   `batch_eval::cluster_evaluate`.

use std::path::PathBuf;

use cluster_gcn::coordinator::trainer::TrainState;
use cluster_gcn::coordinator::{
    cluster_evaluate_storage, train_storage, BatchAssembler, ClusterSampler,
};
use cluster_gcn::datagen::{build, Preset};
use cluster_gcn::graph::{
    write_store, Dataset, DiskDataset, GraphStorage, Split, StoreError, Task,
};
use cluster_gcn::norm::{normalize_sparse, normalize_storage, NormConfig};
use cluster_gcn::partition::{
    parts_to_clusters, Partitioner, RandomPartitioner, StreamingPartitioner,
};
use cluster_gcn::runtime::{Backend, HostBackend, ModelSpec};
use cluster_gcn::session::TrainConfig;
use cluster_gcn::util::Rng;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cgcn_store_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Small preset with both-task coverage; big enough that chunked scans
/// cross several chunk boundaries at chunk_rows ∈ {1, 101}.
fn tiny(task: Task) -> Preset {
    Preset {
        name: "store_tiny",
        task,
        n: 700,
        communities: 10,
        avg_deg: 7.0,
        intra_frac: 0.85,
        classes: if task == Task::Multilabel { 70 } else { 6 },
        f_in: 12,
        label_noise: 0.1,
        feat_noise: 1.0,
        active_per_community: 14,
        split: (0.6, 0.2),
        default_partitions: 6,
        default_q: 2,
        b_max: 256,
        f_hid: 16,
    }
}

fn labels_equal(a: &Dataset, b: &Dataset) -> bool {
    (0..a.n()).all(|v| (0..a.num_classes).all(|c| a.labels.has_label(v, c) == b.labels.has_label(v, c)))
}

#[test]
fn roundtrip_both_tasks() {
    let dir = tmpdir("roundtrip");
    for task in [Task::Multiclass, Task::Multilabel] {
        let ds = build(&tiny(task), 11);
        let path = dir.join(format!("{task:?}.store"));
        write_store(&ds, &path).unwrap();
        let dd = DiskDataset::open(&path).unwrap();
        assert_eq!(dd.n(), ds.n());
        assert_eq!(dd.nnz(), ds.graph.nnz());
        assert_eq!(dd.task, ds.task);
        assert_eq!(dd.f_in, ds.f_in);
        assert_eq!(dd.num_classes, ds.num_classes);
        dd.verify_data().unwrap();

        let mut nb = Vec::new();
        let mut feat = vec![0f32; ds.f_in];
        for v in 0..ds.n() {
            assert_eq!(dd.degree(v), ds.graph.degree(v), "degree of {v}");
            dd.read_neighbors_into(v, &mut nb).unwrap();
            assert_eq!(nb, ds.graph.neighbors(v), "row of {v}");
            dd.read_feature_row_into(v, &mut feat).unwrap();
            assert_eq!(feat, ds.features[v * ds.f_in..(v + 1) * ds.f_in], "features of {v}");
            assert_eq!(dd.split_of(v), ds.split[v], "split of {v}");
            for c in 0..ds.num_classes {
                assert_eq!(
                    dd.has_label(v, c).unwrap(),
                    ds.labels.has_label(v, c),
                    "label ({v},{c})"
                );
            }
        }

        // exact inverse
        let back = dd.to_dataset().unwrap();
        assert_eq!(back.graph.offsets, ds.graph.offsets);
        assert_eq!(back.graph.cols, ds.graph.cols);
        assert_eq!(back.features, ds.features);
        assert_eq!(back.split, ds.split);
        assert!(labels_equal(&back, &ds));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn normalization_chunk_parity() {
    let dir = tmpdir("norm");
    let ds = build(&tiny(Task::Multiclass), 3);
    let path = dir.join("t.store");
    write_store(&ds, &path).unwrap();
    let ram = GraphStorage::InRam(ds.clone());
    let disk = GraphStorage::OnDisk(DiskDataset::open(&path).unwrap());
    for cfg in [NormConfig::PAPER_DEFAULT, NormConfig::ROW] {
        let exact = normalize_sparse(&ds.graph, cfg);
        for chunk in [1usize, 101, 0] {
            assert_eq!(normalize_storage(&ram, cfg, chunk), exact, "ram chunk {chunk}");
            assert_eq!(normalize_storage(&disk, cfg, chunk), exact, "disk chunk {chunk}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_assembly_disk_matches_ram_bitwise() {
    let dir = tmpdir("assembly");
    for task in [Task::Multiclass, Task::Multilabel] {
        let ds = build(&tiny(task), 29);
        let path = dir.join(format!("{task:?}.store"));
        write_store(&ds, &path).unwrap();
        let ram = GraphStorage::InRam(ds.clone());
        let disk = GraphStorage::OnDisk(DiskDataset::open(&path).unwrap());

        let mut rng = Rng::new(5);
        let part = RandomPartitioner.partition(&ds.graph, 6, &mut rng);
        let sampler = ClusterSampler::new(parts_to_clusters(&part, 6), 2);
        let b_max = sampler.max_batch_nodes().next_multiple_of(8);

        let mut asm_ds = BatchAssembler::new(ds.n(), b_max, NormConfig::PAPER_DEFAULT);
        let mut asm_ram = BatchAssembler::new(ds.n(), b_max, NormConfig::PAPER_DEFAULT);
        let mut asm_disk = BatchAssembler::new(ds.n(), b_max, NormConfig::PAPER_DEFAULT);
        let mut b_ds = asm_ds.new_batch(&ds);
        let mut b_ram = asm_ram.new_batch_storage(&ram);
        let mut b_disk = asm_disk.new_batch_storage(&disk);

        let plan = sampler.epoch_plan(&mut Rng::new(17));
        let mut nodes = Vec::new();
        for (i, ids) in plan.iter().enumerate() {
            sampler.batch_nodes(ids, &mut nodes);
            asm_ds.assemble_into(&ds, &nodes, &mut b_ds);
            asm_ram.assemble_storage_into(&ram, &nodes, &mut b_ram);
            asm_disk.assemble_storage_into(&disk, &nodes, &mut b_disk);
            for (tag, b) in [("ram", &b_ram), ("disk", &b_disk)] {
                assert_eq!(b.nodes, b_ds.nodes, "batch {i} {tag} nodes");
                assert_eq!(b.n_real, b_ds.n_real, "batch {i} {tag} n_real");
                assert_eq!(b.n_train, b_ds.n_train, "batch {i} {tag} n_train");
                assert_eq!(b.within_edges, b_ds.within_edges, "batch {i} {tag} edges");
                assert_eq!(b.a.data, b_ds.a.data, "batch {i} {tag} A");
                assert_eq!(b.x.data, b_ds.x.data, "batch {i} {tag} X");
                assert_eq!(b.y.data, b_ds.y.data, "batch {i} {tag} Y");
                assert_eq!(b.mask.data, b_ds.mask.data, "batch {i} {tag} mask");
                assert_eq!(b.block.offsets, b_ds.block.offsets, "batch {i} {tag} block");
                assert_eq!(b.block.cols, b_ds.block.cols, "batch {i} {tag} block cols");
                assert_eq!(b.block.vals, b_ds.block.vals, "batch {i} {tag} block vals");
                assert_eq!(b.block.self_loop, b_ds.block.self_loop, "batch {i} {tag} diag");
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_file_fails_typed() {
    let dir = tmpdir("trunc");
    let ds = build(&tiny(Task::Multiclass), 7);
    let path = dir.join("t.store");
    write_store(&ds, &path).unwrap();
    let full = std::fs::metadata(&path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(full - 5).unwrap();
    drop(f);
    match DiskDataset::open(&path) {
        Err(StoreError::Truncated { expected, actual }) => {
            assert_eq!(expected, full);
            assert_eq!(actual, full - 5);
        }
        other => panic!("expected Truncated, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_header_and_magic_fail_typed() {
    let dir = tmpdir("header");
    let ds = build(&tiny(Task::Multiclass), 7);
    let path = dir.join("t.store");
    write_store(&ds, &path).unwrap();
    let pristine = std::fs::read(&path).unwrap();

    // flip one bit inside the checksummed header field region
    let mut bytes = pristine.clone();
    bytes[100] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    match DiskDataset::open(&path) {
        Err(StoreError::Corrupt(_)) => {}
        other => panic!("expected Corrupt for header bit-flip, got {other:?}"),
    }

    // wrong magic is its own error, detected before any CRC work
    let mut bytes = pristine.clone();
    bytes[0] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    match DiskDataset::open(&path) {
        Err(StoreError::BadMagic) => {}
        other => panic!("expected BadMagic, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_data_section_fails_verify() {
    let dir = tmpdir("data");
    let ds = build(&tiny(Task::Multiclass), 7);
    let path = dir.join("t.store");
    write_store(&ds, &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // a byte inside the feature section: header (152) + index + neighbors
    let off = 152 + (ds.n() + 1) * 8 + ds.graph.nnz() * 4 + 16;
    bytes[off] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    // sections are lazily read, so open still succeeds...
    let dd = DiskDataset::open(&path).unwrap();
    // ...but the streamed checksum catches the flip
    match dd.verify_data() {
        Err(StoreError::Corrupt(_)) => {}
        other => panic!("expected Corrupt from verify_data, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn streaming_partitioner_backend_invariant() {
    let dir = tmpdir("part");
    let ds = build(&tiny(Task::Multiclass), 13);
    let path = dir.join("t.store");
    write_store(&ds, &path).unwrap();
    let ram = GraphStorage::InRam(ds.clone());
    let disk = GraphStorage::OnDisk(DiskDataset::open(&path).unwrap());
    let sp = StreamingPartitioner::default();
    let a = sp.partition_storage(&ram, 6, &mut Rng::new(2));
    let b = sp.partition_storage(&disk, 6, &mut Rng::new(2));
    assert_eq!(a, b);
    assert!(a.iter().all(|&p| p < 6));
    let _ = std::fs::remove_dir_all(&dir);
}

fn ooc_fixture(task: Task, dir: &std::path::Path) -> (GraphStorage, GraphStorage, ClusterSampler, ModelSpec) {
    let ds = build(&tiny(task), 23);
    let path = dir.join(format!("{task:?}.store"));
    write_store(&ds, &path).unwrap();
    let mut rng = Rng::new(9);
    let part = RandomPartitioner.partition(&ds.graph, 6, &mut rng);
    let sampler = ClusterSampler::new(parts_to_clusters(&part, 6), 2);
    let b_max = sampler.max_batch_nodes().next_multiple_of(8);
    let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, 16, ds.num_classes, b_max);
    let ram = GraphStorage::InRam(ds);
    let disk = GraphStorage::OnDisk(DiskDataset::open(&path).unwrap());
    (ram, disk, sampler, spec)
}

#[test]
fn ooc_training_disk_replays_ram_bitwise() {
    let dir = tmpdir("train");
    for task in [Task::Multiclass, Task::Multilabel] {
        let (ram, disk, sampler, spec) = ooc_fixture(task, &dir);
        let cfg = TrainConfig {
            layers: 2,
            hidden: Some(16),
            epochs: 3,
            eval_every: 1,
            seed: 4,
            ..TrainConfig::default()
        };
        let run = |store: &GraphStorage| {
            let mut backend = HostBackend::new();
            backend.register_model("m", spec.clone());
            train_storage(&mut backend, store, &sampler, "m", &cfg).unwrap()
        };
        let a = run(&ram);
        let b = run(&disk);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.peak_bytes, b.peak_bytes);
        assert_eq!(a.curve.len(), 3);
        for (pa, pb) in a.curve.iter().zip(&b.curve) {
            assert_eq!(pa.epoch, pb.epoch);
            assert_eq!(pa.train_loss.to_bits(), pb.train_loss.to_bits(), "{task:?} loss");
            assert_eq!(pa.eval_f1.to_bits(), pb.eval_f1.to_bits(), "{task:?} f1");
        }
        for (wa, wb) in a.state.weights.iter().zip(&b.state.weights) {
            assert_eq!(wa.data, wb.data, "{task:?} weights");
        }
        assert!(
            a.curve[2].train_loss.is_finite()
                && a.curve[2].train_loss <= a.curve[0].train_loss * 1.05,
            "{task:?} loss diverged: {} -> {}",
            a.curve[0].train_loss,
            a.curve[2].train_loss
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn storage_eval_matches_resident_cluster_evaluate() {
    let dir = tmpdir("eval");
    for task in [Task::Multiclass, Task::Multilabel] {
        let (ram, disk, sampler, spec) = ooc_fixture(task, &dir);
        let ds = ram.as_ram().expect("InRam arm").clone();
        let weights = TrainState::init(&spec, 8).weights;
        let mut backend = HostBackend::new();
        backend.register_model("m", spec.clone());
        // the storage eval re-batches the training clusters one at a
        // time; hand the resident path the identical q=1 sampler
        let eval_sampler = ClusterSampler::new(sampler.clusters.clone(), 1);
        for split in [Split::Val, Split::Test] {
            let nodes = ds.nodes_in_split(split);
            let want = cluster_gcn::coordinator::batch_eval::cluster_evaluate(
                &mut backend,
                &ds,
                &eval_sampler,
                "m",
                &weights,
                NormConfig::PAPER_DEFAULT,
                &nodes,
                77,
            )
            .unwrap();
            for store in [&ram, &disk] {
                let got = cluster_evaluate_storage(
                    &mut backend,
                    store,
                    &sampler,
                    "m",
                    &weights,
                    NormConfig::PAPER_DEFAULT,
                    split,
                    77,
                )
                .unwrap();
                assert_eq!(got.to_bits(), want.to_bits(), "{task:?} {split:?}");
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
