//! Property tests over the coordinator substrates (routing, batching,
//! state invariants) using the in-tree `testing` harness (offline
//! stand-in for proptest — failures print a reproducible seed+size).

use cluster_gcn::coordinator::inference::{full_forward, spmm_layer, spmm_layer_naive};
use cluster_gcn::coordinator::{BatchAssembler, ClusterSampler};
use cluster_gcn::graph::{
    induced_csr, within_edges, Csr, Dataset, Labels, Split, SubgraphScratch, Task,
};
use cluster_gcn::norm::{build_dense_block, normalize_sparse, NormConfig};
use cluster_gcn::runtime::Tensor;
use cluster_gcn::util::pool::{self, parallel_chunks, scoped_chunks};
use cluster_gcn::partition::{
    balance, edge_cut, parts_to_clusters, MultilevelPartitioner, Partitioner,
    RandomPartitioner,
};
use cluster_gcn::testing::{forall, gen, Config};
use cluster_gcn::util::{Json, Rng};

fn cfg(cases: usize, seed: u64, max: usize) -> Config {
    Config::with(cases, seed, max)
}

// --------------------------------------------------------------------------
// partitioning invariants
// --------------------------------------------------------------------------

#[test]
fn prop_multilevel_partition_is_total_and_bounded() {
    forall(&cfg(24, 0xA1, 400), "partition_total", |rng, size| {
        let g = gen::connected_graph(rng, size.max(8), size);
        let k = 2 + rng.usize_below(6.min(g.n() / 2)).max(1);
        let part = MultilevelPartitioner::default().partition(&g, k, rng);
        if part.len() != g.n() {
            return Err("wrong length".into());
        }
        if part.iter().any(|&p| p as usize >= k) {
            return Err("part id out of range".into());
        }
        let b = balance(&g, &part, k);
        if b > 3.0 {
            return Err(format!("balance {b} too large (k={k}, n={})", g.n()));
        }
        Ok(())
    });
}

#[test]
fn prop_multilevel_cut_not_worse_than_random() {
    // on clusterable graphs the multilevel cut must beat random's
    forall(&cfg(10, 0xA2, 1200), "cut_beats_random", |rng, size| {
        let n = (size * 8).max(400);
        let k = 8;
        let sbm = cluster_gcn::datagen::generate(
            &cluster_gcn::datagen::SbmSpec {
                n,
                communities: k * 2,
                avg_deg: 10.0,
                intra_frac: 0.9,
                size_skew: 1.0,
            },
            rng,
        );
        let ml = MultilevelPartitioner::default().partition(&sbm.graph, k, rng);
        let rd = RandomPartitioner.partition(&sbm.graph, k, rng);
        let (c_ml, c_rd) = (edge_cut(&sbm.graph, &ml), edge_cut(&sbm.graph, &rd));
        if c_ml >= c_rd {
            return Err(format!("multilevel cut {c_ml} >= random {c_rd}"));
        }
        Ok(())
    });
}

#[test]
fn prop_clusters_partition_nodes_exactly() {
    forall(&cfg(24, 0xA3, 300), "clusters_partition", |rng, size| {
        let g = gen::graph(rng, size.max(6), 4.0);
        let k = 3.min(g.n());
        let part = RandomPartitioner.partition(&g, k, rng);
        let clusters = parts_to_clusters(&part, k);
        let mut all: Vec<u32> = clusters.iter().flatten().copied().collect();
        all.sort_unstable();
        let expect: Vec<u32> = (0..g.n() as u32).collect();
        if all != expect {
            return Err("clusters don't partition the node set".into());
        }
        Ok(())
    });
}

// --------------------------------------------------------------------------
// subgraph / normalization invariants
// --------------------------------------------------------------------------

#[test]
fn prop_induced_subgraph_edge_count_matches_within_edges() {
    forall(&cfg(32, 0xB1, 200), "induced_vs_within", |rng, size| {
        let g = gen::graph(rng, size.max(4), 5.0);
        let take = 1 + rng.usize_below(g.n());
        let mut nodes: Vec<u32> = (0..g.n() as u32).collect();
        rng.shuffle(&mut nodes);
        nodes.truncate(take);
        let sub = induced_csr(&g, &nodes);
        let mut scratch = SubgraphScratch::new(g.n());
        let we = within_edges(&g, &nodes, &mut scratch);
        if sub.nnz() != we {
            return Err(format!("induced nnz {} != within {}", sub.nnz(), we));
        }
        sub.validate()
    });
}

#[test]
fn prop_rownorm_block_rows_sum_to_one() {
    forall(&cfg(32, 0xB2, 150), "rownorm_rows", |rng, size| {
        let g = gen::graph(rng, size.max(4), 6.0);
        let nodes: Vec<u32> = (0..g.n() as u32).collect();
        let mut scratch = SubgraphScratch::new(g.n());
        let mut edges = Vec::new();
        cluster_gcn::graph::induced_edges(&g, &nodes, &mut scratch, &mut edges);
        let b = g.n().next_multiple_of(8);
        let mut out = vec![0f32; b * b];
        build_dense_block(g.n(), &edges, b, NormConfig::ROW, &mut out);
        for i in 0..g.n() {
            let s: f32 = out[i * b..(i + 1) * b].iter().sum();
            if (s - 1.0).abs() > 1e-4 {
                return Err(format!("row {i} sums to {s}"));
            }
        }
        // padding rows all zero
        for i in g.n()..b {
            if out[i * b..(i + 1) * b].iter().any(|&v| v != 0.0) {
                return Err(format!("padding row {i} non-zero"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sym_block_is_symmetric() {
    forall(&cfg(24, 0xB3, 120), "sym_block", |rng, size| {
        let g = gen::graph(rng, size.max(4), 5.0);
        let nodes: Vec<u32> = (0..g.n() as u32).collect();
        let mut scratch = SubgraphScratch::new(g.n());
        let mut edges = Vec::new();
        cluster_gcn::graph::induced_edges(&g, &nodes, &mut scratch, &mut edges);
        let b = g.n();
        let mut out = vec![0f32; b * b];
        build_dense_block(b, &edges, b, NormConfig::PAPER_DEFAULT, &mut out);
        for i in 0..b {
            for j in 0..b {
                if (out[i * b + j] - out[j * b + i]).abs() > 1e-6 {
                    return Err(format!("asymmetric at ({i},{j})"));
                }
            }
        }
        Ok(())
    });
}

// --------------------------------------------------------------------------
// sampler / batch invariants
// --------------------------------------------------------------------------

fn random_dataset(rng: &mut Rng, n: usize) -> Dataset {
    let g = gen::connected_graph(rng, n, n / 2);
    let classes = 2 + rng.usize_below(5);
    let f_in = 4 + rng.usize_below(8);
    let mut labels = Labels::Multiclass(vec![0; n]);
    for v in 0..n {
        labels.set_label(v, rng.usize_below(classes));
    }
    let features: Vec<f32> = (0..n * f_in).map(|_| rng.f32() - 0.5).collect();
    let split = (0..n)
        .map(|_| match rng.usize_below(10) {
            0..=6 => Split::Train,
            7..=8 => Split::Val,
            _ => Split::Test,
        })
        .collect();
    Dataset {
        name: "prop".into(),
        task: Task::Multiclass,
        graph: g,
        f_in,
        num_classes: classes,
        features,
        labels,
        split,
    }
}

#[test]
fn prop_epoch_plan_uses_each_cluster_once() {
    forall(&cfg(32, 0xC1, 64), "epoch_plan", |rng, size| {
        let p = 2 + size;
        let q = 1 + rng.usize_below(p.min(5));
        let clusters: Vec<Vec<u32>> =
            (0..p).map(|c| vec![c as u32]).collect();
        let sampler = ClusterSampler::new(clusters, q);
        let plan = sampler.epoch_plan(rng);
        let mut seen = std::collections::HashSet::new();
        for batch in &plan {
            if batch.len() != q {
                return Err("batch with wrong q".into());
            }
            for &c in batch {
                if !seen.insert(c) {
                    return Err(format!("cluster {c} reused in one epoch"));
                }
            }
        }
        if seen.len() != (p / q) * q {
            return Err("plan size wrong".into());
        }
        Ok(())
    });
}

#[test]
fn prop_batch_assembly_invariants() {
    forall(&cfg(20, 0xC2, 120), "batch_assembly", |rng, size| {
        let ds = random_dataset(rng, size.max(10));
        let b_max = ds.n().next_multiple_of(16);
        let mut asm = BatchAssembler::new(ds.n(), b_max, NormConfig::ROW);
        let take = 1 + rng.usize_below(ds.n());
        let mut nodes: Vec<u32> = (0..ds.n() as u32).collect();
        rng.shuffle(&mut nodes);
        nodes.truncate(take);
        let batch = asm.assemble(&ds, &nodes);

        // mask only on train nodes, count matches
        let expect_train = nodes
            .iter()
            .filter(|&&v| ds.split[v as usize] == Split::Train)
            .count();
        if batch.n_train != expect_train {
            return Err("n_train mismatch".into());
        }
        for (i, &m) in batch.mask.data.iter().enumerate() {
            let should = i < nodes.len()
                && ds.split[nodes[i] as usize] == Split::Train;
            if (m == 1.0) != should {
                return Err(format!("mask wrong at {i}"));
            }
        }
        // features copied faithfully
        for (i, &v) in nodes.iter().enumerate() {
            let row = &batch.x.data[i * ds.f_in..(i + 1) * ds.f_in];
            if row != ds.feature_row(v as usize) {
                return Err("feature row mismatch".into());
            }
        }
        // y rows one-hot
        for i in 0..nodes.len() {
            let row = &batch.y.data[i * ds.num_classes..(i + 1) * ds.num_classes];
            let s: f32 = row.iter().sum();
            if (s - 1.0).abs() > 1e-6 {
                return Err("label row not one-hot".into());
            }
        }
        Ok(())
    });
}

// --------------------------------------------------------------------------
// host kernel / thread pool invariants
// --------------------------------------------------------------------------

/// Tiled fused SpMM·GEMM ≡ the scalar oracle for arbitrary graphs,
/// feature widths, output widths, norm configs, and thread counts.
#[test]
fn prop_tiled_spmm_matches_naive_oracle() {
    forall(&cfg(20, 0xE1, 220), "spmm_parity", |rng, size| {
        let g = gen::graph(rng, size.max(4), 5.0);
        let n = g.n();
        let f = 1 + rng.usize_below(140); // crosses the K_PANEL=128 boundary
        let wg = 1 + rng.usize_below(70); // crosses the COL_TILE=64 boundary
        let norm = if rng.bool_with(0.5) { NormConfig::PAPER_DEFAULT } else { NormConfig::ROW };
        let (vals, sl) = normalize_sparse(&g, norm);
        let x: Vec<f32> = (0..n * f).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let w = Tensor::new(vec![f, wg], (0..f * wg).map(|_| rng.f32() - 0.5).collect());
        let relu = rng.bool_with(0.5);
        let oracle = spmm_layer_naive(&g, &vals, &sl, &x, f, &w, relu);
        for threads in [1usize, 2, pool::default_threads().max(3)] {
            let got = spmm_layer(&g, &vals, &sl, &x, f, &w, relu, threads);
            for (i, (a, b)) in got.iter().zip(&oracle).enumerate() {
                if (a - b).abs() > 1e-4 {
                    return Err(format!(
                        "threads={threads} n={n} f={f} wg={wg} idx={i}: {a} vs {b}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The pooled dispatcher hands every item to exactly one chunk.
#[test]
fn prop_pooled_run_chunks_covers_each_item_exactly_once() {
    use std::sync::atomic::{AtomicU32, Ordering};
    forall(&cfg(24, 0xE2, 3000), "run_chunks_cover", |rng, size| {
        let n = rng.usize_below(size.max(2));
        let chunks = 1 + rng.usize_below(12);
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        pool::global().run_chunks_with(n, chunks, |_, r| {
            for j in r {
                hits[j].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (j, h) in hits.iter().enumerate() {
            let c = h.load(Ordering::Relaxed);
            if c != 1 {
                return Err(format!("item {j} visited {c} times (n={n}, chunks={chunks})"));
            }
        }
        Ok(())
    });
}

/// Pooled `parallel_chunks` produces the same ordered decomposition and
/// results as the spawn-per-call oracle, at every (n, threads).
#[test]
fn prop_pooled_chunks_deterministic_ordering() {
    forall(&cfg(24, 0xE3, 2000), "chunks_ordering", |rng, size| {
        let n = rng.usize_below(size.max(2));
        let threads = 1 + rng.usize_below(12);
        let pooled = parallel_chunks(n, threads, |i, r| (i, r.start, r.end));
        let oracle = scoped_chunks(n, threads, |i, r| (i, r.start, r.end));
        if pooled != oracle {
            return Err(format!(
                "n={n} threads={threads}: pooled {pooled:?} != oracle {oracle:?}"
            ));
        }
        // re-running yields the identical decomposition (determinism)
        let again = parallel_chunks(n, threads, |i, r| (i, r.start, r.end));
        if pooled != again {
            return Err(format!("n={n} threads={threads}: non-deterministic"));
        }
        Ok(())
    });
}

/// `HostBackend::forward` over the full-graph batch is **bit-identical**
/// to the exact evaluator `full_forward_cached` at every pool width:
/// the batch renormalization reproduces `normalize_sparse`'s values and
/// the extracted block runs through the same tiled kernel.  (Reuses the
/// PR-1 kernel-parity harness.)
#[test]
fn prop_host_backend_forward_matches_full_forward() {
    use cluster_gcn::runtime::{Backend, HostBackend, ModelSpec};

    forall(&cfg(12, 0xF1, 100), "host_forward_parity", |rng, size| {
        let ds = random_dataset(rng, size.max(8));
        let n = ds.n();
        let b_max = n.next_multiple_of(8);
        let f_hid = 1 + rng.usize_below(24);
        let layers = 2 + rng.usize_below(2);
        let spec = ModelSpec::gcn(ds.task, layers, ds.f_in, f_hid, ds.num_classes, b_max);
        let weights: Vec<Tensor> = spec
            .weight_shapes
            .iter()
            .map(|&(fi, fo)| {
                Tensor::new(vec![fi, fo], (0..fi * fo).map(|_| rng.f32() - 0.5).collect())
            })
            .collect();
        let norm = match rng.usize_below(3) {
            0 => NormConfig::PAPER_DEFAULT,
            1 => NormConfig::ROW,
            _ => NormConfig::ROW_LAMBDA1,
        };
        let mut asm = BatchAssembler::new(n, b_max, norm);
        let nodes: Vec<u32> = (0..n as u32).collect();
        let batch = asm.assemble(&ds, &nodes);
        let expect = full_forward(&ds, &weights, norm, false);
        for threads in [1usize, 2, 5, pool::default_threads().max(3)] {
            let mut hb = HostBackend::with_threads(threads);
            hb.register_model("m", spec.clone());
            let got = hb.forward("m", &weights, &batch).map_err(|e| e.to_string())?;
            if got.dims != vec![b_max, ds.num_classes] {
                return Err(format!("bad dims {:?}", got.dims));
            }
            for (i, (&a, &b)) in got.data[..n * ds.num_classes]
                .iter()
                .zip(&expect)
                .enumerate()
            {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "threads={threads} n={n} layers={layers} idx={i}: \
                         {a:?} != {b:?} (not bit-identical)"
                    ));
                }
            }
            if got.data[n * ds.num_classes..].iter().any(|&v| v != 0.0) {
                return Err("padding rows not zero".into());
            }
        }
        Ok(())
    });
}

// --------------------------------------------------------------------------
// backward-engine invariants (runtime::backward)
// --------------------------------------------------------------------------

/// The pooled/tiled backward GEMM kernels vs their retained scalar
/// oracles at pool widths 1/2/8: `gemm_pooled` and `gemm_at_b_pooled`
/// are bit-identical (per-element accumulation order preserved);
/// `gemm_a_bt_pooled` is within the 8-lane dot reassociation tolerance
/// and still exactly width-independent.
#[test]
fn prop_backward_gemms_match_scalar_oracles() {
    use cluster_gcn::runtime::backward::{
        gemm, gemm_a_bt, gemm_a_bt_pooled, gemm_at_b, gemm_at_b_pooled, gemm_pooled,
    };
    forall(&cfg(18, 0xE5, 120), "backward_gemms", |rng, size| {
        let n = 1 + rng.usize_below(size.max(2));
        let f = 1 + rng.usize_below(140); // crosses K_PANEL/K_BLOCK boundaries
        let g = 1 + rng.usize_below(70); // crosses COL_TILE
        let p: Vec<f32> = (0..n * f)
            .map(|_| if rng.bool_with(0.3) { 0.0 } else { rng.f32() - 0.5 })
            .collect();
        let dz: Vec<f32> = (0..n * g)
            .map(|_| if rng.bool_with(0.2) { 0.0 } else { rng.f32() - 0.5 })
            .collect();
        let w: Vec<f32> = (0..f * g).map(|_| rng.f32() - 0.5).collect();

        let mut z_oracle = vec![0f32; n * g];
        gemm(&p, n, f, &w, g, &mut z_oracle);
        let mut gw_oracle = vec![0f32; f * g];
        gemm_at_b(&p, &dz, n, f, g, &mut gw_oracle);
        let mut m_oracle = vec![0f32; n * f];
        gemm_a_bt(&dz, &w, n, g, f, &mut m_oracle);

        let mut m_first: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 8] {
            let mut z = vec![f32::NAN; n * g];
            gemm_pooled(&p, n, f, &w, g, threads, &mut z);
            for (i, (a, b)) in z.iter().zip(&z_oracle).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("gemm t={threads} n={n} f={f} g={g} i={i}: {a} vs {b}"));
                }
            }
            let mut gw = vec![f32::NAN; f * g];
            gemm_at_b_pooled(&p, &dz, n, f, g, threads, &mut gw);
            for (i, (a, b)) in gw.iter().zip(&gw_oracle).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "gemm_at_b t={threads} n={n} f={f} g={g} i={i}: {a} vs {b}"
                    ));
                }
            }
            let mut m = vec![f32::NAN; n * f];
            gemm_a_bt_pooled(&dz, &w, n, g, f, threads, &mut m);
            for (i, (a, b)) in m.iter().zip(&m_oracle).enumerate() {
                if (a - b).abs() > 1e-5 + 1e-4 * b.abs() {
                    return Err(format!(
                        "gemm_a_bt t={threads} n={n} f={f} g={g} i={i}: {a} vs {b}"
                    ));
                }
            }
            match m_first.take() {
                None => m_first = Some(m),
                Some(r) => {
                    if m.iter().zip(&r).any(|(a, b)| a.to_bits() != b.to_bits()) {
                        return Err(format!("gemm_a_bt width-dependent at t={threads}"));
                    }
                    m_first = Some(r);
                }
            }
        }
        Ok(())
    });
}

/// The `Âᵀ` transpose gather is bit-identical to the scalar scatter
/// oracle over real assembled batch blocks, at pool widths 1/2/8.
#[test]
fn prop_adj_t_gather_matches_scatter_oracle() {
    use cluster_gcn::runtime::backward::{scatter_adj_t, AdjT};
    forall(&cfg(16, 0xE6, 90), "adj_t_gather", |rng, size| {
        let ds = random_dataset(rng, size.max(8));
        let b_max = ds.n().next_multiple_of(8);
        let norm = if rng.bool_with(0.5) { NormConfig::PAPER_DEFAULT } else { NormConfig::ROW };
        let mut asm = BatchAssembler::new(ds.n(), b_max, norm);
        let take = 1 + rng.usize_below(ds.n());
        let mut nodes: Vec<u32> = (0..ds.n() as u32).collect();
        rng.shuffle(&mut nodes);
        nodes.truncate(take);
        let batch = asm.assemble(&ds, &nodes);
        let blk = &batch.block;
        let n = blk.n();
        let f = 1 + rng.usize_below(20);
        let m: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();

        let mut oracle = vec![0f32; n * f];
        scatter_adj_t(&blk.offsets, &blk.cols, &blk.vals, &blk.self_loop, &m, f, &mut oracle);
        let mut adj_t = AdjT::new();
        adj_t.build(&blk.offsets, &blk.cols, &blk.vals, &blk.self_loop);
        for threads in [1usize, 2, 8] {
            let mut got = vec![f32::NAN; n * f];
            adj_t.gather_into_pooled(&m, f, threads, &mut got);
            for (i, (a, b)) in got.iter().zip(&oracle).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("t={threads} n={n} f={f} i={i}: {a} vs {b}"));
                }
            }
        }
        Ok(())
    });
}

/// The sparse-native batch contract: the assembler-built CSR block is
/// structurally and bitwise identical to re-extracting the dense
/// `n_real × n_real` prefix (the old densify→re-sparsify round trip),
/// under arbitrary node subsets and norm configs.
#[test]
fn prop_sparse_block_matches_dense_extract() {
    forall(&cfg(20, 0xE7, 100), "sparse_block", |rng, size| {
        let ds = random_dataset(rng, size.max(8));
        let b_max = ds.n().next_multiple_of(8);
        let norm = match rng.usize_below(3) {
            0 => NormConfig::PAPER_DEFAULT,
            1 => NormConfig::ROW,
            _ => NormConfig::ROW_LAMBDA1,
        };
        let mut asm = BatchAssembler::new(ds.n(), b_max, norm);
        let take = 1 + rng.usize_below(ds.n());
        let mut nodes: Vec<u32> = (0..ds.n() as u32).collect();
        rng.shuffle(&mut nodes);
        nodes.truncate(take);
        let batch = asm.assemble(&ds, &nodes);
        let blk = &batch.block;
        let n = batch.n_real;
        if blk.n() != n {
            return Err(format!("block rows {} != n_real {n}", blk.n()));
        }
        for u in 0..n {
            let row = &blk.cols[blk.offsets[u]..blk.offsets[u + 1]];
            if !row.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("row {u} columns not strictly ascending"));
            }
            let mut nnz_dense = 0;
            for v in 0..n {
                let dense = batch.a.data[u * b_max + v];
                if v == u {
                    if blk.self_loop[u].to_bits() != dense.to_bits() {
                        return Err(format!("diag {u}: {} vs {dense}", blk.self_loop[u]));
                    }
                } else if dense != 0.0 {
                    nnz_dense += 1;
                    let Ok(pos) = row.binary_search(&(v as u32)) else {
                        return Err(format!("dense edge ({u},{v}) missing from CSR"));
                    };
                    let sparse = blk.vals[blk.offsets[u] + pos];
                    if sparse.to_bits() != dense.to_bits() {
                        return Err(format!("({u},{v}): {sparse} vs {dense}"));
                    }
                }
            }
            if nnz_dense != row.len() {
                return Err(format!("row {u}: {} CSR entries vs {nnz_dense} dense", row.len()));
            }
        }
        Ok(())
    });
}

/// End-to-end backward parity: the pooled engine (carried sparse block,
/// tiled kernels, flat arena) vs the retained scalar oracle
/// (dense-extracted block, scalar kernels) — loss bitwise, gradients
/// within the dot-reassociation tolerance, at pool widths 1/2/8.
#[test]
fn prop_host_backward_matches_scalar_oracle() {
    use cluster_gcn::runtime::host::host_grads_scalar;
    use cluster_gcn::runtime::{HostBackend, ModelSpec};
    forall(&cfg(10, 0xE8, 80), "host_backward_parity", |rng, size| {
        let ds = random_dataset(rng, size.max(8));
        let n = ds.n();
        let b_max = n.next_multiple_of(8);
        let f_hid = 1 + rng.usize_below(24);
        let layers = 2 + rng.usize_below(2);
        let spec = ModelSpec::gcn(ds.task, layers, ds.f_in, f_hid, ds.num_classes, b_max);
        let weights: Vec<Tensor> = spec
            .weight_shapes
            .iter()
            .map(|&(fi, fo)| {
                Tensor::new(vec![fi, fo], (0..fi * fo).map(|_| rng.f32() - 0.5).collect())
            })
            .collect();
        let norm = if rng.bool_with(0.5) { NormConfig::PAPER_DEFAULT } else { NormConfig::ROW };
        let mut asm = BatchAssembler::new(n, b_max, norm);
        let nodes: Vec<u32> = (0..n as u32).collect();
        let batch = asm.assemble(&ds, &nodes);
        let (loss_s, grads_s) =
            host_grads_scalar(&spec, &weights, &batch, 2).map_err(|e| e.to_string())?;
        for threads in [1usize, 2, 8] {
            let mut hb = HostBackend::with_threads(threads);
            hb.register_model("m", spec.clone());
            let (loss_p, grads_p) =
                hb.loss_and_grads("m", &weights, &batch).map_err(|e| e.to_string())?;
            if loss_p.to_bits() != loss_s.to_bits() {
                return Err(format!("loss t={threads}: {loss_p} vs {loss_s}"));
            }
            for (li, (gp, gs)) in grads_p.iter().zip(&grads_s).enumerate() {
                for (e, (a, b)) in gp.iter().zip(gs).enumerate() {
                    if (a - b).abs() > 1e-5 + 1e-4 * b.abs() {
                        return Err(format!(
                            "t={threads} layer {li} entry {e}: {a} vs {b}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Reused-batch assembly is indistinguishable from fresh assembly under
/// arbitrary batch sequences (the dirty-row clearing never leaks).
#[test]
fn prop_assemble_into_matches_fresh() {
    forall(&cfg(16, 0xE4, 100), "assemble_into", |rng, size| {
        let ds = random_dataset(rng, size.max(10));
        let b_max = ds.n().next_multiple_of(16);
        let mut asm = BatchAssembler::new(ds.n(), b_max, NormConfig::PAPER_DEFAULT);
        let mut reused = asm.new_batch(&ds);
        for round in 0..4 {
            let take = 1 + rng.usize_below(ds.n());
            let mut nodes: Vec<u32> = (0..ds.n() as u32).collect();
            rng.shuffle(&mut nodes);
            nodes.truncate(take);
            asm.assemble_into(&ds, &nodes, &mut reused);
            let fresh = asm.assemble(&ds, &nodes);
            if reused.a.data != fresh.a.data {
                return Err(format!("round {round}: A differs after reuse"));
            }
            if reused.x.data != fresh.x.data || reused.y.data != fresh.y.data {
                return Err(format!("round {round}: X/Y differ after reuse"));
            }
            if reused.mask.data != fresh.mask.data || reused.n_train != fresh.n_train {
                return Err(format!("round {round}: mask differs after reuse"));
            }
        }
        Ok(())
    });
}

// --------------------------------------------------------------------------
// serialization invariants
// --------------------------------------------------------------------------

/// Checkpoint save→load→save is **bytewise**-stable for both on-disk
/// versions: the v1 (`CGCNCKP1`) body and the v2 (`CGCNCKP2`) body +
/// epoch + history section reproduce themselves exactly through a load,
/// across random model shapes, steps, and history contents.
#[test]
fn prop_checkpoint_roundtrip_is_bytewise_stable() {
    use cluster_gcn::coordinator::checkpoint::{
        load_full, save, save_v2, HistorySection,
    };
    use cluster_gcn::coordinator::TrainState;
    use cluster_gcn::runtime::ModelSpec;

    forall(&cfg(12, 0xD3, 24), "ckpt_roundtrip", |rng, size| {
        let layers = 1 + rng.usize_below(3);
        let f_in = 1 + rng.usize_below(size.max(2));
        let f_hid = 1 + rng.usize_below(size.max(2));
        let classes = 1 + rng.usize_below(5);
        let spec = ModelSpec::gcn(
            cluster_gcn::graph::Task::Multiclass,
            layers,
            f_in,
            f_hid,
            classes,
            64,
        );
        let mut state = TrainState::init(&spec, rng.next_u64());
        state.step = rng.next_u64() % 10_000;
        let n = 1 + rng.usize_below(9);
        let hist = HistorySection {
            f_hid,
            n,
            layers: (0..layers.saturating_sub(1))
                .map(|_| (0..n * f_hid).map(|_| rng.f32() - 0.5).collect())
                .collect(),
        };
        let epoch = rng.usize_below(50);
        let mut path = std::env::temp_dir();
        path.push(format!(
            "cgcn_prop_ckpt_{}_{}.bin",
            std::process::id(),
            rng.next_u64()
        ));

        // v1
        save(&state, "prop_model", &path).map_err(|e| e.to_string())?;
        let b1 = std::fs::read(&path).map_err(|e| e.to_string())?;
        let ck = load_full(&path).map_err(|e| e.to_string())?;
        if ck.epoch != 0 || ck.history.is_some() {
            std::fs::remove_file(&path).ok();
            return Err("v1 load invented a trailer".into());
        }
        save(&ck.state, &ck.artifact, &path).map_err(|e| e.to_string())?;
        let b1b = std::fs::read(&path).map_err(|e| e.to_string())?;
        if b1 != b1b {
            std::fs::remove_file(&path).ok();
            return Err("v1 save→load→save not bytewise stable".into());
        }

        // v2 (with history when the model has hidden layers)
        let h_opt = if hist.layers.is_empty() { None } else { Some(&hist) };
        save_v2(&state, "prop_model", epoch, h_opt, &path).map_err(|e| e.to_string())?;
        let b2 = std::fs::read(&path).map_err(|e| e.to_string())?;
        let ck = load_full(&path).map_err(|e| e.to_string())?;
        if ck.epoch != epoch {
            std::fs::remove_file(&path).ok();
            return Err(format!("v2 epoch {} != {}", ck.epoch, epoch));
        }
        if ck.history.as_ref() != h_opt {
            std::fs::remove_file(&path).ok();
            return Err("v2 history did not roundtrip".into());
        }
        save_v2(&ck.state, &ck.artifact, ck.epoch, ck.history.as_ref(), &path)
            .map_err(|e| e.to_string())?;
        let b2b = std::fs::read(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        if b2 != b2b {
            return Err("v2 save→load→save not bytewise stable".into());
        }
        Ok(())
    });
}

/// A `CGCNCKP2` file cut anywhere inside its trailer (epoch, history
/// header, or history payload) fails with the **typed**
/// `TruncatedHistory` error — never a silent partial load.
#[test]
fn prop_truncated_history_section_is_typed() {
    use cluster_gcn::coordinator::checkpoint::{
        load_full, save_v2, CheckpointError, HistorySection,
    };
    use cluster_gcn::coordinator::TrainState;
    use cluster_gcn::runtime::ModelSpec;

    forall(&cfg(12, 0xD4, 16), "ckpt_truncation", |rng, size| {
        let f_hid = 1 + rng.usize_below(size.max(2));
        let n = 1 + rng.usize_below(size.max(2));
        let hist_layers = 1 + rng.usize_below(3);
        let spec = ModelSpec::gcn(
            cluster_gcn::graph::Task::Multiclass,
            2,
            3,
            f_hid,
            2,
            16,
        );
        let state = TrainState::init(&spec, rng.next_u64());
        let hist = HistorySection {
            f_hid,
            n,
            layers: (0..hist_layers)
                .map(|_| (0..n * f_hid).map(|_| rng.f32()).collect())
                .collect(),
        };
        let mut path = std::env::temp_dir();
        path.push(format!(
            "cgcn_prop_trunc_{}_{}.bin",
            std::process::id(),
            rng.next_u64()
        ));
        save_v2(&state, "m", 7, Some(&hist), &path).map_err(|e| e.to_string())?;
        let full = std::fs::read(&path).map_err(|e| e.to_string())?;
        let trailer = 8 * 4 + hist_layers * n * f_hid * 4;
        // cut a random number of bytes strictly inside the trailer
        let cut = 1 + rng.usize_below(trailer);
        std::fs::write(&path, &full[..full.len() - cut]).map_err(|e| e.to_string())?;
        let res = load_full(&path);
        std::fs::remove_file(&path).ok();
        match res {
            Err(CheckpointError::TruncatedHistory) => Ok(()),
            Err(other) => Err(format!("cut {cut}: wrong error kind: {other}")),
            Ok(_) => Err(format!("cut {cut}: truncated file loaded")),
        }
    });
}

#[test]
fn prop_dataset_io_roundtrip() {
    forall(&cfg(10, 0xD1, 80), "dataset_io", |rng, size| {
        let ds = random_dataset(rng, size.max(8));
        let mut path = std::env::temp_dir();
        path.push(format!(
            "cgcn_prop_io_{}_{}.bin",
            std::process::id(),
            rng.next_u64()
        ));
        cluster_gcn::graph::io::save(&ds, &path).map_err(|e| e.to_string())?;
        let ds2 = cluster_gcn::graph::io::load(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        if ds2.graph.cols != ds.graph.cols
            || ds2.features != ds.features
            || ds2.split != ds.split
        {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.usize_below(4) } else { rng.usize_below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bool_with(0.5)),
        2 => Json::Num((rng.f64() * 2e6).round() / 2.0 - 5e5),
        3 => Json::Str(
            (0..rng.usize_below(12))
                .map(|_| char::from(b'a' + (rng.usize_below(26) as u8)))
                .collect::<String>()
                + if rng.bool_with(0.3) { "\"\\\n✓" } else { "" },
        ),
        4 => Json::Arr(
            (0..rng.usize_below(4))
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => Json::Obj(
            (0..rng.usize_below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    forall(&cfg(200, 0xD2, 4), "json_roundtrip", |rng, size| {
        let v = random_json(rng, size.min(3));
        let s = v.to_string();
        let v2 = Json::parse(&s).map_err(|e| format!("{e} for {s}"))?;
        if v != v2 {
            return Err(format!("roundtrip mismatch: {s}"));
        }
        Ok(())
    });
}
