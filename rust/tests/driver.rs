//! Driver + combinator acceptance suite:
//!
//! - event ordering: StepStart/StepEnd pairs in step order, exactly one
//!   EpochEnd per epoch, Eval after EpochEnd, Done last;
//! - checkpoint save→resume through the driver bit-exactly replays the
//!   uninterrupted run (epoch streams are pure functions of
//!   `(seed, epoch)`);
//! - `ShardedBackend` with shards=1 is bit-identical to `HostBackend`
//!   per step (loss bits and weight/moment bits, property-style over
//!   seeds × partitionings), and shards=2 is loss-curve-equivalent;
//! - `PrefetchBackend` over the cluster method is bit-identical to the
//!   serial path;
//! - the 2-epoch e2e for all four methods through the driver with
//!   `EvalStrategy::Clustered`.

use cluster_gcn::baselines::VrgcnParams;
use cluster_gcn::coordinator::checkpoint;
use cluster_gcn::datagen::features::{gen_features, gen_labels, LabelModel};
use cluster_gcn::datagen::{generate, SbmSpec};
use cluster_gcn::graph::{Dataset, Split, Task};
use cluster_gcn::runtime::{Backend, HostBackend, PrefetchBackend, ShardedBackend};
use cluster_gcn::session::{Event, EvalStrategy, Method, Session, TrainConfig};
use cluster_gcn::util::Rng;

/// A tiny SBM dataset with strong community→label→feature coupling
/// (same construction as `tests/session_host.rs`).
fn tiny_sbm(seed: u64) -> Dataset {
    let n = 240;
    let communities = 8;
    let classes = 4;
    let f_in = 16;
    let mut rng = Rng::new(seed);
    let sbm = generate(
        &SbmSpec { n, communities, avg_deg: 8.0, intra_frac: 0.9, size_skew: 0.5 },
        &mut rng,
    );
    let labels = gen_labels(
        &LabelModel { task: Task::Multiclass, classes, noise: 0.05, active_per_community: 0 },
        &sbm.community,
        communities,
        &mut rng,
    );
    let features =
        gen_features(&labels, &sbm.community, communities, classes, f_in, 0.3, &mut rng);
    let split = (0..n)
        .map(|i| match i % 10 {
            0..=6 => Split::Train,
            7..=8 => Split::Val,
            _ => Split::Test,
        })
        .collect();
    let ds = Dataset {
        name: "tiny_sbm".into(),
        task: Task::Multiclass,
        graph: sbm.graph,
        f_in,
        num_classes: classes,
        features,
        labels,
        split,
    };
    ds.validate().unwrap();
    ds
}

fn cfg(epochs: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        layers: 2,
        hidden: Some(32),
        b_max: Some(256),
        lr: 0.05,
        epochs,
        eval_every: 1,
        seed,
        ..TrainConfig::default()
    }
}

fn state_bits(state: &cluster_gcn::coordinator::TrainState) -> Vec<u32> {
    state
        .weights
        .iter()
        .chain(&state.m)
        .chain(&state.v)
        .flat_map(|t| t.data.iter().map(|x| x.to_bits()))
        .collect()
}

/// The pinned event-ordering contract of the driver state machine.
#[test]
fn driver_event_stream_is_ordered() {
    let ds = tiny_sbm(42);
    let mut driver = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(6)
        .config(cfg(2, 3))
        .driver()
        .unwrap();

    let mut events = Vec::new();
    while let Some(ev) = driver.next_event().unwrap() {
        events.push(ev);
    }
    // exhausted driver stays exhausted
    assert!(driver.next_event().unwrap().is_none());

    assert!(matches!(events.last(), Some(Event::Done { .. })), "Done must be last");
    assert!(matches!(events.first(), Some(Event::StepStart { epoch: 1, step: 0 })));

    let mut cur_epoch = 0usize;
    let mut open_step: Option<(usize, usize)> = None;
    let mut next_step = 0usize;
    let mut epoch_ends = Vec::new();
    let mut epoch_closed = true;
    for (i, ev) in events.iter().enumerate() {
        match ev {
            Event::StepStart { epoch, step } => {
                assert!(open_step.is_none(), "nested StepStart at {i}");
                if epoch_closed {
                    // first step of a new epoch
                    assert_eq!(*epoch, cur_epoch + 1, "epoch must advance by one");
                    cur_epoch = *epoch;
                    epoch_closed = false;
                    next_step = 0;
                }
                assert_eq!(*epoch, cur_epoch);
                assert_eq!(*step, next_step, "steps must arrive in order");
                open_step = Some((*epoch, *step));
            }
            Event::StepEnd { epoch, step, .. } => {
                assert_eq!(open_step, Some((*epoch, *step)), "unpaired StepEnd at {i}");
                open_step = None;
                next_step = step + 1;
            }
            Event::EpochEnd { epoch, .. } => {
                assert!(open_step.is_none(), "EpochEnd inside a step at {i}");
                assert!(!epoch_closed, "double EpochEnd for epoch {epoch}");
                assert_eq!(*epoch, cur_epoch);
                epoch_closed = true;
                epoch_ends.push(*epoch);
            }
            Event::Eval { point } => {
                assert!(epoch_closed, "Eval before EpochEnd at {i}");
                assert_eq!(point.epoch, cur_epoch);
            }
            Event::EarlyStop { .. } => unreachable!("patience disabled"),
            Event::CheckpointSaved { .. } => unreachable!("driver never checkpoints"),
            Event::Done { epochs, steps } => {
                assert_eq!(i, events.len() - 1);
                assert_eq!(*epochs, 2);
                assert!(*steps > 0);
            }
        }
    }
    // exactly one EpochEnd per epoch, in order
    assert_eq!(epoch_ends, vec![1, 2]);
    // eval_every = 1 -> one Eval per epoch
    let evals = events.iter().filter(|e| matches!(e, Event::Eval { .. })).count();
    assert_eq!(evals, 2);

    let result = driver.into_result().unwrap();
    assert_eq!(result.curve.len(), 2);
    assert!(result.steps > 0);
}

/// Checkpoint at epoch k, resume with `start_epoch = k`, and the final
/// state is bit-identical to the uninterrupted run: the driver derives
/// every epoch's sampling stream from `(seed, epoch)` alone, and the
/// checkpoint round-trips f32s exactly.
#[test]
fn checkpoint_resume_replays_uninterrupted_run() {
    let ds = tiny_sbm(7);
    let run = |c: TrainConfig, init: Option<cluster_gcn::coordinator::TrainState>| {
        let mut s = Session::new(&ds)
            .method(Method::Cluster { q: 1 })
            .partition(6)
            .config(c);
        if let Some(st) = init {
            s = s.initial_state(st);
        }
        s.run().unwrap()
    };

    let full = run(cfg(4, 9), None);

    let ckpt = std::env::temp_dir().join(format!(
        "cgcn_driver_resume_{}.bin",
        std::process::id()
    ));
    let part = run(cfg(2, 9), None);
    checkpoint::save(&part.result.state, &part.model, &ckpt).unwrap();
    let (loaded, model) = checkpoint::load(&ckpt).unwrap();
    assert_eq!(model, part.model);

    let resumed = run(
        TrainConfig { start_epoch: 2, ..cfg(4, 9) },
        Some(loaded),
    );
    std::fs::remove_file(&ckpt).ok();

    assert_eq!(full.result.state.step, resumed.result.state.step);
    assert_eq!(
        state_bits(&full.result.state),
        state_bits(&resumed.result.state),
        "resumed run must replay the uninterrupted run bit for bit"
    );
    // resuming twice is equally deterministic
    let resumed2 = run(
        TrainConfig { start_epoch: 2, ..cfg(4, 9) },
        Some(part.result.state.clone()),
    );
    assert_eq!(state_bits(&resumed.result.state), state_bits(&resumed2.result.state));
}

/// `TrainConfig::checkpoint_every` writes a v2 checkpoint after every
/// k-th epoch through the `CheckpointSaved` event path, and resuming
/// from an **intermediate** periodic checkpoint (captured mid-run by an
/// observer, before later saves overwrite the path) replays the
/// uninterrupted run bitwise.  The final-state save is skipped when the
/// last periodic save already captured the final epoch, so the event
/// count is exactly `epochs / k`.
#[test]
fn periodic_checkpoints_resume_bitwise() {
    use cluster_gcn::session::Observer;

    /// Copies the checkpoint file aside on the first save, so the test
    /// can resume from the epoch-2 snapshot even though epoch 4's save
    /// overwrites the session path.
    struct CopyFirstCheckpoint {
        aside: std::path::PathBuf,
        count: usize,
    }
    impl Observer for CopyFirstCheckpoint {
        fn on_event(&mut self, event: &Event) {
            if let Event::CheckpointSaved { path } = event {
                if self.count == 0 {
                    std::fs::copy(path, &self.aside).unwrap();
                }
                self.count += 1;
            }
        }
    }

    let ds = tiny_sbm(23);
    let full = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(6)
        .config(cfg(4, 13))
        .run()
        .unwrap();

    let ckpt = std::env::temp_dir().join(format!(
        "cgcn_periodic_{}.bin",
        std::process::id()
    ));
    let aside = std::env::temp_dir().join(format!(
        "cgcn_periodic_aside_{}.bin",
        std::process::id()
    ));
    let mut obs = CopyFirstCheckpoint { aside: aside.clone(), count: 0 };
    let periodic = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(6)
        .config(TrainConfig { checkpoint_every: 2, ..cfg(4, 13) })
        .save(&ckpt)
        .observer(&mut obs)
        .run()
        .unwrap();
    // saves at epochs 2 and 4; the final-state save dedupes against the
    // epoch-4 periodic save
    assert_eq!(obs.count, 2, "one CheckpointSaved per k-th epoch, no duplicate at Done");
    // periodic checkpointing must not perturb the run itself
    assert_eq!(state_bits(&full.result.state), state_bits(&periodic.result.state));
    // the path left behind is the final (epoch 4) state
    let last = checkpoint::load_full(&ckpt).unwrap();
    assert_eq!(last.epoch, 4);
    assert_eq!(
        state_bits(&full.result.state),
        state_bits(&last.state),
        "overwritten session path must hold the final state"
    );
    std::fs::remove_file(&ckpt).ok();

    // resume from the intermediate (epoch 2) snapshot: bitwise replay
    let mid = checkpoint::load_full(&aside).unwrap();
    std::fs::remove_file(&aside).ok();
    assert_eq!(mid.epoch, 2, "first periodic save must record epoch 2");
    let resumed = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(6)
        .config(TrainConfig { start_epoch: mid.epoch, ..cfg(4, 13) })
        .initial_state(mid.state)
        .run()
        .unwrap();
    assert_eq!(full.result.state.step, resumed.result.state.step);
    assert_eq!(
        state_bits(&full.result.state),
        state_bits(&resumed.result.state),
        "resume from an intermediate periodic checkpoint must replay bitwise"
    );
}

/// The PR-5 resume gate: a VR-GCN run interrupted at an epoch boundary
/// resumes to a **bitwise**-identical final state vs the uninterrupted
/// run — and the history section in the `CGCNCKP2` checkpoint is
/// load-bearing: VR-GCN's estimator reads the activations its earlier
/// epochs stored, so resuming *without* the history diverges.
#[test]
fn vrgcn_resume_replays_uninterrupted_run_bitwise() {
    let ds = tiny_sbm(19);
    let method = || Method::VrGcn(VrgcnParams { r: 2, batch: 32 });
    let run = |c: TrainConfig,
               init: Option<cluster_gcn::coordinator::TrainState>,
               hist: Option<checkpoint::HistorySection>,
               save: Option<&std::path::Path>| {
        let mut s = Session::new(&ds).method(method()).config(c);
        if let Some(st) = init {
            s = s.initial_state(st);
        }
        if let Some(h) = hist {
            s = s.initial_history(h);
        }
        if let Some(p) = save {
            s = s.save(p);
        }
        s.run().unwrap()
    };

    let full = run(cfg(4, 11), None, None, None);

    // interrupted run: 2 epochs, checkpointed through the session (the
    // CGCNCKP2 path: epoch + history section)
    let ckpt = std::env::temp_dir().join(format!(
        "cgcn_vrgcn_resume_{}.bin",
        std::process::id()
    ));
    let part = run(cfg(2, 11), None, None, Some(ckpt.as_path()));
    let ck = checkpoint::load_full(&ckpt).unwrap();
    std::fs::remove_file(&ckpt).ok();
    assert_eq!(ck.artifact, part.model);
    assert_eq!(ck.epoch, 2, "v2 checkpoint must record the saved-at epoch");
    let history = ck.history.expect("vrgcn session checkpoint must carry history");
    assert!(!history.layers.is_empty());

    // resume with state + history + start_epoch: bitwise replay
    let resumed = run(
        TrainConfig { start_epoch: ck.epoch, ..cfg(4, 11) },
        Some(ck.state.clone()),
        Some(history.clone()),
        None,
    );
    assert_eq!(full.result.state.step, resumed.result.state.step);
    assert_eq!(
        state_bits(&full.result.state),
        state_bits(&resumed.result.state),
        "resumed vrgcn run must replay the uninterrupted run bit for bit"
    );

    // resume WITHOUT the history: the estimator falls back to a zeroed
    // store, so the replay must diverge — the section is load-bearing
    let amnesiac = run(
        TrainConfig { start_epoch: ck.epoch, ..cfg(4, 11) },
        Some(ck.state),
        None,
        None,
    );
    assert_ne!(
        state_bits(&full.result.state),
        state_bits(&amnesiac.result.state),
        "dropping the history section must change the replay"
    );
}

/// shards=1 ≡ HostBackend, bit for bit, at every step — property-style
/// over seeds × partition counts.  The two drivers run in lockstep;
/// every StepEnd must carry the same loss bits and leave the same
/// weight/moment bits.
#[test]
fn sharded_one_replica_is_bit_identical_to_host_per_step() {
    for (seed, parts) in [(1u64, 4usize), (5, 6), (11, 8)] {
        let ds = tiny_sbm(seed);
        let mk = |backend: Box<dyn Backend>| {
            Session::new(&ds)
                .method(Method::Cluster { q: 1 })
                .partition(parts)
                .config(cfg(2, seed))
                .backend(backend)
                .driver()
                .unwrap()
        };
        let mut host = mk(Box::new(HostBackend::new()));
        let mut sharded = mk(Box::new(ShardedBackend::host(1)));
        loop {
            let (eh, es) = (host.next_event().unwrap(), sharded.next_event().unwrap());
            match (&eh, &es) {
                (None, None) => break,
                (
                    Some(Event::StepEnd { loss: lh, .. }),
                    Some(Event::StepEnd { loss: ls, .. }),
                ) => {
                    assert_eq!(
                        lh.map(f32::to_bits),
                        ls.map(f32::to_bits),
                        "loss bits diverged (seed {seed}, parts {parts})"
                    );
                    assert_eq!(
                        state_bits(host.state()),
                        state_bits(sharded.state()),
                        "state bits diverged (seed {seed}, parts {parts})"
                    );
                }
                (Some(_), Some(_)) => {}
                _ => panic!("event streams diverged (seed {seed}, parts {parts})"),
            }
        }
        assert_eq!(state_bits(host.state()), state_bits(sharded.state()));
    }
}

/// shards=2 halves the optimizer steps (two batches per step) and stays
/// loss-curve-equivalent to the plain host run.
#[test]
fn sharded_two_replicas_is_curve_equivalent() {
    let ds = tiny_sbm(13);
    let run = |backend: Box<dyn Backend>| {
        Session::new(&ds)
            .method(Method::Cluster { q: 1 })
            .partition(6)
            .config(cfg(4, 2))
            .backend(backend)
            .run()
            .unwrap()
    };
    let host = run(Box::new(HostBackend::new()));
    let sharded = run(Box::new(ShardedBackend::host(2)));

    // 6 one-cluster batches per epoch: 6 host steps, 3 sharded steps
    assert_eq!(host.result.steps, 4 * 6);
    assert_eq!(sharded.result.steps, 4 * 3);
    assert_eq!(sharded.backend, "sharded");

    let (hf, sf) = (
        host.result.curve.last().unwrap(),
        sharded.result.curve.last().unwrap(),
    );
    assert!(
        sharded.result.curve.first().unwrap().train_loss > sf.train_loss,
        "sharded loss did not decrease"
    );
    assert!(
        (hf.eval_f1 - sf.eval_f1).abs() < 0.25,
        "sharded f1 {} too far from host f1 {}",
        sf.eval_f1,
        hf.eval_f1
    );
}

/// Sharded StepEnd events report how many batches the step consumed.
#[test]
fn sharded_step_events_report_batch_consumption() {
    let ds = tiny_sbm(3);
    let mut driver = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(6)
        .config(cfg(1, 4))
        .backend(Box::new(ShardedBackend::host(2)))
        .driver()
        .unwrap();
    let mut consumed = 0usize;
    while let Some(ev) = driver.next_event().unwrap() {
        if let Event::StepEnd { batches, .. } = ev {
            assert!(batches <= 2);
            consumed += batches;
        }
    }
    assert_eq!(consumed, 6, "every planned batch must be consumed");
}

/// Prefetching changes scheduling, not numerics: the cluster method's
/// assembly is a pure function of the epoch plan, so the (default)
/// prefetched run is bit-identical to the serial one — and the wrapper
/// reports the inner backend's name.
#[test]
fn prefetch_is_bit_identical_for_cluster_method() {
    let ds = tiny_sbm(21);
    let run = |prefetch: bool| {
        Session::new(&ds)
            .method(Method::Cluster { q: 2 })
            .partition(6)
            .config(cfg(3, 17))
            .backend(Box::new(HostBackend::new()))
            .prefetch(prefetch)
            .run()
            .unwrap()
    };
    let serial = run(false);
    let prefetched = run(true);
    // the prefetch wrapper is a scheduler, not a backend identity
    assert_eq!(prefetched.backend, "host");
    assert_eq!(serial.result.steps, prefetched.result.steps);
    assert_eq!(
        state_bits(&serial.result.state),
        state_bits(&prefetched.result.state),
        "prefetch must not change training numerics"
    );
    // an explicitly stacked wrapper behaves identically (double-wrap is
    // harmless: the outer one does the overlap)
    let explicit = Session::new(&ds)
        .method(Method::Cluster { q: 2 })
        .partition(6)
        .config(cfg(3, 17))
        .backend(Box::new(PrefetchBackend::new(HostBackend::new())))
        .run()
        .unwrap();
    assert_eq!(
        state_bits(&serial.result.state),
        state_bits(&explicit.result.state)
    );
    // sage assembly draws its RNG in batch order, so prefetch is
    // bit-identical there too
    let run_sage = |prefetch: bool| {
        Session::new(&ds)
            .method(Method::graphsage(2, 16))
            .config(cfg(2, 8))
            .backend(Box::new(HostBackend::new()))
            .prefetch(prefetch)
            .run()
            .unwrap()
    };
    let serial = run_sage(false);
    let prefetched = run_sage(true);
    assert_eq!(
        state_bits(&serial.result.state),
        state_bits(&prefetched.result.state),
        "prefetch must not change graphsage numerics"
    );
}

/// The acceptance e2e: 2 epochs of every method through the driver with
/// the paper's clustered approximate eval — loss decreasing, F1 finite.
#[test]
fn every_method_trains_through_driver_with_clustered_eval() {
    let ds = tiny_sbm(42);
    let methods: Vec<(&str, Method)> = vec![
        ("cluster", Method::Cluster { q: 1 }),
        ("expansion", Method::Expansion { batch: 16 }),
        ("graphsage", Method::graphsage(2, 16)),
        ("vrgcn", Method::VrGcn(VrgcnParams { r: 2, batch: 32 })),
    ];
    for (name, method) in methods {
        let out = Session::new(&ds)
            .method(method)
            .partition(6)
            .config(cfg(2, 3))
            .eval(EvalStrategy::Clustered { parts: 6 })
            .run()
            .unwrap_or_else(|e| panic!("{name} failed: {e}"));
        let first = out.result.curve.first().unwrap();
        let last = out.result.curve.last().unwrap();
        assert_eq!(last.epoch, 2, "{name} should run 2 epochs");
        assert!(
            last.train_loss < first.train_loss,
            "{name}: loss did not decrease ({} -> {})",
            first.train_loss,
            last.train_loss
        );
        assert!(
            last.eval_f1.is_finite(),
            "{name}: clustered micro-F1 not finite ({})",
            last.eval_f1
        );
        assert!(out.result.steps > 0, "{name}: no steps ran");
    }
}
