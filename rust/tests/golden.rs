//! Golden-trace regression suite: per-epoch loss/F1 trajectories for
//! all four training methods at a fixed seed on the host backend,
//! pinned **bitwise** (tolerance 0) against checked-in golden values —
//! so a kernel refactor that silently shifts numerics fails here even
//! when every parity oracle it touched moved with it.
//!
//! The pin is legitimate because every host kernel is deterministic and
//! pool-width-independent by contract (see ARCHITECTURE.md §Parity
//! contracts): the trajectory is a pure function of `(dataset seed,
//! config seed)`, so the same bits reproduce on any machine.
//!
//! Blessing: goldens live in `tests/golden/trajectories.json`.  When
//! the file is absent the suite records the current trajectories and
//! passes (first run on a fresh checkout); set `CGCN_BLESS=1` to
//! re-record after an *intentional* numeric change, and commit the
//! result.

use cluster_gcn::baselines::VrgcnParams;
use cluster_gcn::datagen::features::{gen_features, gen_labels, LabelModel};
use cluster_gcn::datagen::{generate, SbmSpec};
use cluster_gcn::graph::{Dataset, Split, Task};
use cluster_gcn::session::{Method, Session, TrainConfig};
use cluster_gcn::util::{Json, Rng};

/// Same construction as `tests/driver.rs` / `tests/session_host.rs`.
fn tiny_sbm(seed: u64) -> Dataset {
    let n = 240;
    let communities = 8;
    let classes = 4;
    let f_in = 16;
    let mut rng = Rng::new(seed);
    let sbm = generate(
        &SbmSpec { n, communities, avg_deg: 8.0, intra_frac: 0.9, size_skew: 0.5 },
        &mut rng,
    );
    let labels = gen_labels(
        &LabelModel { task: Task::Multiclass, classes, noise: 0.05, active_per_community: 0 },
        &sbm.community,
        communities,
        &mut rng,
    );
    let features =
        gen_features(&labels, &sbm.community, communities, classes, f_in, 0.3, &mut rng);
    let split = (0..n)
        .map(|i| match i % 10 {
            0..=6 => Split::Train,
            7..=8 => Split::Val,
            _ => Split::Test,
        })
        .collect();
    let ds = Dataset {
        name: "tiny_sbm".into(),
        task: Task::Multiclass,
        graph: sbm.graph,
        f_in,
        num_classes: classes,
        features,
        labels,
        split,
    };
    ds.validate().unwrap();
    ds
}

const GOLDEN_SEED: u64 = 1905;
const GOLDEN_EPOCHS: usize = 3;

fn methods() -> Vec<(&'static str, Method)> {
    vec![
        ("cluster", Method::Cluster { q: 1 }),
        ("expansion", Method::Expansion { batch: 16 }),
        ("graphsage", Method::graphsage(2, 16)),
        ("vrgcn", Method::VrGcn(VrgcnParams { r: 2, batch: 32 })),
    ]
}

/// One curve point, bit-exact: `(epoch, train_loss bits, eval_f1 bits)`.
type Point = (usize, u64, u64);

fn trajectory(ds: &Dataset, method: Method) -> Vec<Point> {
    let cfg = TrainConfig {
        layers: 2,
        hidden: Some(32),
        b_max: Some(256),
        lr: 0.05,
        epochs: GOLDEN_EPOCHS,
        eval_every: 1,
        seed: GOLDEN_SEED,
        ..TrainConfig::default()
    };
    let out = Session::new(ds)
        .method(method)
        .partition(6)
        .config(cfg)
        .run()
        .unwrap();
    out.result
        .curve
        .iter()
        .map(|pt| (pt.epoch, pt.train_loss.to_bits(), pt.eval_f1.to_bits()))
        .collect()
}

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("trajectories.json")
}

fn to_json(all: &[(&str, Vec<Point>)]) -> Json {
    Json::obj(
        all.iter()
            .map(|(name, pts)| {
                let arr = pts
                    .iter()
                    .map(|&(e, lb, fb)| {
                        Json::obj(vec![
                            ("epoch", Json::num(e as f64)),
                            // f64 bit patterns exceed 2^53: keep them as
                            // hex strings so the JSON round trip is exact
                            ("loss_bits", Json::str(&format!("{lb:016x}"))),
                            ("f1_bits", Json::str(&format!("{fb:016x}"))),
                        ])
                    })
                    .collect();
                (*name, Json::Arr(arr))
            })
            .collect(),
    )
}

fn from_json(j: &Json, name: &str) -> Option<Vec<Point>> {
    let arr = j.get(name)?.as_arr()?;
    let mut out = Vec::with_capacity(arr.len());
    for p in arr {
        let epoch = p.get("epoch")?.as_usize()?;
        let lb = u64::from_str_radix(p.get("loss_bits")?.as_str()?, 16).ok()?;
        let fb = u64::from_str_radix(p.get("f1_bits")?.as_str()?, 16).ok()?;
        out.push((epoch, lb, fb));
    }
    Some(out)
}

/// In-process determinism (no stored values needed): the same session
/// twice yields the same trajectory, bit for bit — the property that
/// makes a bitwise golden pin sound in the first place.
#[test]
fn trajectories_are_bitwise_deterministic_in_process() {
    let ds = tiny_sbm(GOLDEN_SEED);
    for (name, method) in methods() {
        let a = trajectory(&ds, method.clone());
        let b = trajectory(&ds, method);
        assert_eq!(a, b, "{name}: trajectory not deterministic");
        assert_eq!(a.len(), GOLDEN_EPOCHS, "{name}: expected one eval per epoch");
    }
}

/// The golden pin: trajectories match `tests/golden/trajectories.json`
/// with tolerance 0 (host backend).  Auto-blesses when the file is
/// absent or `CGCN_BLESS=1`.
#[test]
fn trajectories_match_checked_in_goldens() {
    let ds = tiny_sbm(GOLDEN_SEED);
    let current: Vec<(&str, Vec<Point>)> = methods()
        .into_iter()
        .map(|(name, method)| (name, trajectory(&ds, method)))
        .collect();

    let path = golden_path();
    let bless = std::env::var("CGCN_BLESS").is_ok();
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, to_json(&current).to_string()).unwrap();
        eprintln!(
            "golden: {} trajectories for {} methods at seed {GOLDEN_SEED} \
             (commit {})",
            if bless { "re-blessed" } else { "recorded" },
            current.len(),
            path.display()
        );
        return;
    }

    let stored = Json::parse(&std::fs::read_to_string(&path).unwrap())
        .unwrap_or_else(|e| panic!("unparsable golden file {}: {e}", path.display()));
    for (name, pts) in &current {
        let want = from_json(&stored, name).unwrap_or_else(|| {
            panic!(
                "golden file {} has no usable entry for '{name}' — \
                 re-bless with CGCN_BLESS=1 and commit",
                path.display()
            )
        });
        assert_eq!(
            *pts, want,
            "{name}: trajectory drifted from the checked-in golden \
             (tolerance 0 on the host backend).  If the numeric change is \
             intentional, re-run with CGCN_BLESS=1 and commit the new \
             {}",
            path.display()
        );
    }
}
