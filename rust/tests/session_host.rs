//! End-to-end tests of the unified `Session` API on the artifact-free
//! `HostBackend`: every training [`Method`] runs through the same entry
//! point, with **no** `artifacts/` directory and no PJRT involvement —
//! runnable in any CI box.

use cluster_gcn::baselines::VrgcnParams;
use cluster_gcn::datagen::features::{gen_features, gen_labels, LabelModel};
use cluster_gcn::datagen::{generate, SbmSpec};
use cluster_gcn::graph::{Dataset, Split, Task};
use cluster_gcn::session::{Method, RecordingObserver, Session, TrainConfig};
use cluster_gcn::util::Rng;

/// A tiny SBM dataset with strong community→label→feature coupling, so
/// two Adam epochs visibly reduce the loss.
fn tiny_sbm(seed: u64) -> Dataset {
    let n = 240;
    let communities = 8;
    let classes = 4;
    let f_in = 16;
    let mut rng = Rng::new(seed);
    let sbm = generate(
        &SbmSpec {
            n,
            communities,
            avg_deg: 8.0,
            intra_frac: 0.9,
            size_skew: 0.5,
        },
        &mut rng,
    );
    let labels = gen_labels(
        &LabelModel {
            task: Task::Multiclass,
            classes,
            noise: 0.05,
            active_per_community: 0,
        },
        &sbm.community,
        communities,
        &mut rng,
    );
    let features = gen_features(
        &labels,
        &sbm.community,
        communities,
        classes,
        f_in,
        0.3,
        &mut rng,
    );
    let split = (0..n)
        .map(|i| match i % 10 {
            0..=6 => Split::Train,
            7..=8 => Split::Val,
            _ => Split::Test,
        })
        .collect();
    let ds = Dataset {
        name: "tiny_sbm".into(),
        task: Task::Multiclass,
        graph: sbm.graph,
        f_in,
        num_classes: classes,
        features,
        labels,
        split,
    };
    ds.validate().unwrap();
    ds
}

fn two_epoch_cfg() -> TrainConfig {
    TrainConfig {
        layers: 2,
        hidden: Some(32),
        b_max: Some(256),
        lr: 0.05,
        epochs: 2,
        eval_every: 1,
        seed: 3,
        ..TrainConfig::default()
    }
}

/// The acceptance loop: 2 epochs of each `Method` through one `Session`
/// entry point on `HostBackend`, loss decreasing and F1 finite.
#[test]
fn every_method_trains_on_host_backend() {
    let ds = tiny_sbm(42);
    let methods: Vec<(&str, Method)> = vec![
        ("cluster", Method::Cluster { q: 1 }),
        ("expansion", Method::Expansion { batch: 16 }),
        ("graphsage", Method::graphsage(2, 16)),
        ("vrgcn", Method::VrGcn(VrgcnParams { r: 2, batch: 32 })),
    ];
    for (name, method) in methods {
        let out = Session::new(&ds)
            .method(method)
            .partition(6)
            .config(two_epoch_cfg())
            .run()
            .unwrap_or_else(|e| panic!("{name} failed: {e}"));
        assert_eq!(out.backend, "host", "{name}");
        let first = out.result.curve.first().unwrap();
        let last = out.result.curve.last().unwrap();
        assert_eq!(last.epoch, 2, "{name} should run 2 epochs");
        assert!(
            last.train_loss < first.train_loss,
            "{name}: loss did not decrease ({} -> {})",
            first.train_loss,
            last.train_loss
        );
        assert!(
            last.eval_f1.is_finite(),
            "{name}: micro-F1 not finite ({})",
            last.eval_f1
        );
        assert!(out.result.steps > 0, "{name}: no steps ran");
    }
}

/// Observer events stream from the loop: one EpochEnd per epoch, one
/// Eval per eval, and CheckpointSaved when a save path is set.
#[test]
fn session_emits_observer_events_and_checkpoints() {
    let ds = tiny_sbm(7);
    let mut obs = RecordingObserver::default();
    let ckpt = std::env::temp_dir().join(format!(
        "cgcn_session_{}_ckpt.bin",
        std::process::id()
    ));
    let out = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(6)
        .config(two_epoch_cfg())
        .observer(&mut obs)
        .save(&ckpt)
        .run()
        .unwrap();
    assert_eq!(obs.epochs.len(), 2);
    assert_eq!(obs.evals.len(), 2);
    assert_eq!(obs.checkpoints, vec![ckpt.clone()]);
    assert!(obs.early_stop.is_none());
    // the driver also streams per-step events and a final Done
    assert!(!obs.steps.is_empty());
    assert!(obs.steps.iter().all(|(e, _, _)| *e == 1 || *e == 2));
    assert_eq!(obs.done.map(|(e, _)| e), Some(2));

    // the checkpoint round-trips and records the session's model id
    let (state, model) = cluster_gcn::coordinator::checkpoint::load(&ckpt).unwrap();
    assert_eq!(model, out.model);
    assert_eq!(state.step, out.result.state.step);
    // every session save is v2: the epoch rides along (what --resume
    // continues from), with an empty history for non-VR-GCN methods
    let ck = cluster_gcn::coordinator::checkpoint::load_full(&ckpt).unwrap();
    assert_eq!(ck.epoch, 2, "session checkpoint must record its epoch");
    assert!(ck.history.is_none(), "cluster method stores no history");
    std::fs::remove_file(&ckpt).ok();
}

/// A borrowed backend survives the session, so callers can inspect the
/// registered model afterwards (and reuse the backend).
#[test]
fn borrowed_host_backend_is_reusable() {
    use cluster_gcn::runtime::{Backend, HostBackend};

    let ds = tiny_sbm(9);
    let mut hb = HostBackend::new();
    let out = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(4)
        .config(two_epoch_cfg())
        .backend_mut(&mut hb)
        .run()
        .unwrap();
    // the session registered its model on our backend
    let spec = hb.model_spec(&out.model).unwrap();
    assert_eq!(spec, out.spec);
    assert_eq!(spec.f_in, ds.f_in);
    assert_eq!(spec.f_hid, 32);
    assert_eq!(spec.classes, ds.num_classes);
    // and a second session can reuse it
    let again = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(4)
        .config(two_epoch_cfg())
        .backend_mut(&mut hb)
        .run()
        .unwrap();
    assert_eq!(again.model, out.model);
}
