//! SIMD backend parity suite: every detected backend's `axpy` / `dot` /
//! `gemm_tile` against the portable oracle, property-style over the
//! length grid {0, 1, 7, 8, 9, 63, 64, 65, 1000} × misaligned slice
//! offsets × random contents (including ±0.0 stress for the GEMM
//! zero-skip).
//!
//! Contracts checked (PERF.md "SIMD backends & dispatch"):
//!
//! - `bit_stable` backends (`portable`, `avx2`, `neon`) must match the
//!   portable oracle **bit for bit** on all three primitives;
//! - `fma` reassociates/fuses rounding, so it gets tolerance bounds;
//! - `dot` on every backend stays within tolerance of the sequential
//!   scalar sum (the contract the backward kernels rely on).
//!
//! Backends are compared through [`BackendHandle`]s — the global
//! dispatch table resolves once per process, so in-process A/B never
//! touches `CGCN_SIMD` (forced-env coverage is ci.sh's job, as separate
//! processes).  `CGCN_DEEP=1` raises the random-case count (the deep CI
//! tier).

use cluster_gcn::util::simd::{active_backend, available_backends, backend, BackendHandle};
use cluster_gcn::util::Rng;

const LENS: &[usize] = &[0, 1, 7, 8, 9, 63, 64, 65, 1000];
const OFFSETS: &[usize] = &[0, 1, 3];

/// Random cases per (backend, length, offset) cell; `CGCN_DEEP=1` is
/// the high-case-count CI tier.
fn cases() -> usize {
    if std::env::var("CGCN_DEEP").is_ok() {
        48
    } else {
        6
    }
}

/// Mixed-sign values with a controllable fraction of exact ±0.0 — the
/// GEMM zero-skip must treat both signs as "skip", and skipped signed
/// zeros are where bit-parity is easiest to lose.
fn rand_vec(rng: &mut Rng, n: usize, zero_frac: f32) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.f32() < zero_frac {
                if rng.f32() < 0.5 {
                    0.0
                } else {
                    -0.0
                }
            } else {
                (rng.f32() - 0.5) * 4.0
            }
        })
        .collect()
}

fn assert_close(got: f32, want: f32, ctx: &str) {
    assert!(
        (got - want).abs() <= 1e-4 + 1e-4 * want.abs(),
        "{ctx}: {got} vs {want}"
    );
}

#[test]
fn axpy_parity_vs_portable_oracle() {
    let portable = backend("portable").unwrap();
    for h in available_backends() {
        let mut rng = Rng::new(0x0a5_0001);
        for &n in LENS {
            for &off in OFFSETS {
                for case in 0..cases() {
                    let x = rand_vec(&mut rng, off + n, 0.2);
                    let base = rand_vec(&mut rng, off + n, 0.2);
                    let a = (rng.f32() - 0.5) * 2.0;
                    let mut want = base.clone();
                    portable.axpy(&mut want[off..], &x[off..], a);
                    let mut got = base.clone();
                    h.axpy(&mut got[off..], &x[off..], a);
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        let ctx =
                            format!("{} axpy n={n} off={off} case={case} i={i}", h.name());
                        if h.bit_stable() {
                            assert_eq!(g.to_bits(), w.to_bits(), "{ctx}");
                        } else {
                            assert_close(*g, *w, &ctx);
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn dot_parity_vs_portable_and_scalar() {
    let portable = backend("portable").unwrap();
    for h in available_backends() {
        let mut rng = Rng::new(0x0a5_0002);
        for &n in LENS {
            for &off in OFFSETS {
                for case in 0..cases() {
                    let a = rand_vec(&mut rng, off + n, 0.1);
                    let b = rand_vec(&mut rng, off + n, 0.1);
                    let want = portable.dot(&a[off..], &b[off..]);
                    let got = h.dot(&a[off..], &b[off..]);
                    let ctx = format!("{} dot n={n} off={off} case={case}", h.name());
                    if h.bit_stable() {
                        assert_eq!(got.to_bits(), want.to_bits(), "{ctx}");
                    } else {
                        assert_close(got, want, &ctx);
                    }
                    // every backend stays near the sequential scalar sum
                    let scalar: f32 =
                        a[off..].iter().zip(&b[off..]).map(|(x, y)| x * y).sum();
                    assert_close(got, scalar, &format!("{ctx} (scalar)"));
                }
            }
        }
    }
}

/// Shape grid straddling the 8×8 register blocking in every dimension,
/// with padded strides and both `pks` access patterns (`P·W` and the
/// k-strided `Pᵀ·W` read).
#[test]
fn gemm_tile_parity_vs_portable_oracle() {
    let portable = backend("portable").unwrap();
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 2, 5),
        (8, 8, 8),
        (9, 9, 9),
        (7, 16, 23),
        (16, 5, 8),
        (33, 17, 40),
        (64, 31, 24),
    ];
    for h in available_backends() {
        let mut rng = Rng::new(0x0a5_0003);
        for &(rows, kn, cols) in shapes {
            for case in 0..cases().min(12) {
                let ldo = cols + (case % 3);
                let ldw = cols + (case % 2);
                // p·w with row-major p (pks = 1) ...
                let ldp = kn + (case % 4);
                let p = rand_vec(&mut rng, rows * ldp, 0.3);
                let w = rand_vec(&mut rng, kn * ldw, 0.1);
                let base = rand_vec(&mut rng, rows * ldo, 0.3);
                let mut want = base.clone();
                portable.gemm_tile(&mut want, ldo, &p, ldp, 1, &w, ldw, rows, kn, cols);
                let mut got = base.clone();
                h.gemm_tile(&mut got, ldo, &p, ldp, 1, &w, ldw, rows, kn, cols);
                check_grid(h, &got, &want, rows, kn, cols, case, "pks=1");
                // ... and the k-strided transpose read (pks = rows'
                // stride): contraction over the leading dimension
                let pt = rand_vec(&mut rng, kn * rows, 0.3);
                let mut want_t = base.clone();
                portable.gemm_tile(&mut want_t, ldo, &pt, 1, rows, &w, ldw, rows, kn, cols);
                let mut got_t = base.clone();
                h.gemm_tile(&mut got_t, ldo, &pt, 1, rows, &w, ldw, rows, kn, cols);
                check_grid(h, &got_t, &want_t, rows, kn, cols, case, "pks=rows");
            }
        }
    }
}

fn check_grid(
    h: BackendHandle,
    got: &[f32],
    want: &[f32],
    rows: usize,
    kn: usize,
    cols: usize,
    case: usize,
    tag: &str,
) {
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let ctx = format!(
            "{} gemm_tile {tag} ({rows},{kn},{cols}) case={case} i={i}",
            h.name()
        );
        if h.bit_stable() {
            assert_eq!(g.to_bits(), w.to_bits(), "{ctx}");
        } else {
            assert_close(*g, *w, &ctx);
        }
    }
}

/// CI gate, run explicitly by `ci.sh` on x86_64 hosts with `CGCN_SIMD`
/// unset (`--ignored`): an AVX2-capable build must never *silently*
/// dispatch to portable — that would be a perf regression the test
/// suite can't otherwise see.
#[test]
#[ignore = "ci.sh dispatch gate: meaningful only with CGCN_SIMD unset"]
fn x86_dispatch_is_not_silently_portable() {
    #[cfg(target_arch = "x86_64")]
    {
        if std::env::var("CGCN_SIMD").is_err()
            && std::arch::is_x86_feature_detected!("avx2")
        {
            assert_ne!(
                active_backend(),
                "portable",
                "AVX2 host silently dispatched to portable"
            );
        }
    }
    // non-x86 or forced/portable-only hosts: nothing to gate
    let _ = active_backend();
}
