//! Chaos / robustness acceptance suite (PR 8), driven by the seeded
//! failpoint framework in `util::failpoint`:
//!
//! - **crash-durable checkpoints**: a torn or pre-write injected fault
//!   fails typed and never corrupts the previous good file; a
//!   bit-flipped `CGCNCKP3` fails with a typed checksum mismatch and
//!   the rotation falls back to the newest intact slot;
//! - **self-healing training**: an injected mid-run NaN triggers a
//!   guard rollback to the last good rotating checkpoint, and with
//!   `lr_backoff = 1.0` the post-recovery trajectory is **bitwise**
//!   identical to the fault-free run; an unrecoverable fault exhausts
//!   the retry budget with a typed error, never a panic or a hang;
//! - **overload-safe serving**: at-capacity submissions shed typed,
//!   sustained full-queue pressure engages the halo-free degraded
//!   engine (with one partition even degraded responses stay bitwise
//!   exact), deadlines expire typed under slow flushes, and injected
//!   flush faults are transient;
//! - **deep tier** (`CGCN_DEEP=1`): a seeded sweep over the whole
//!   train → checkpoint → resume → serve pipeline asserting clean
//!   recovery or typed errors — never a panic, a hang, or a silent
//!   divergence from the fault-free golden trace.
//!
//! The failpoint registry is process-global, so every test here
//! serializes on one lock and clears the plan on both sides.

use std::sync::Mutex;

use cluster_gcn::coordinator::checkpoint::{self, CheckpointError, RotatingCheckpoint};
use cluster_gcn::coordinator::inference::{full_forward_cached, gather_rows};
use cluster_gcn::coordinator::trainer::TrainState;
use cluster_gcn::datagen::features::{gen_features, gen_labels, LabelModel};
use cluster_gcn::datagen::{generate, SbmSpec};
use cluster_gcn::graph::{Dataset, Split, Task};
use cluster_gcn::norm::{NormCache, NormConfig};
use cluster_gcn::runtime::ModelSpec;
use cluster_gcn::serve::{ServeConfig, ServeError, ServeMode};
use cluster_gcn::session::guard::{run_guarded, Anomaly, GuardConfig, GuardError};
use cluster_gcn::session::{Method, NullObserver, Session, TrainConfig};
use cluster_gcn::util::{failpoint, Rng};

/// Serializes every test in this binary: the failpoint registry is
/// process-global state.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    CHAOS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cgcn_chaos_{tag}_{}", std::process::id()))
}

/// A tiny SBM dataset with strong community→label→feature coupling
/// (same construction as `tests/driver.rs`).
fn tiny_sbm(seed: u64) -> Dataset {
    let n = 240;
    let communities = 8;
    let classes = 4;
    let f_in = 16;
    let mut rng = Rng::new(seed);
    let sbm = generate(
        &SbmSpec { n, communities, avg_deg: 8.0, intra_frac: 0.9, size_skew: 0.5 },
        &mut rng,
    );
    let labels = gen_labels(
        &LabelModel { task: Task::Multiclass, classes, noise: 0.05, active_per_community: 0 },
        &sbm.community,
        communities,
        &mut rng,
    );
    let features =
        gen_features(&labels, &sbm.community, communities, classes, f_in, 0.3, &mut rng);
    let split = (0..n)
        .map(|i| match i % 10 {
            0..=6 => Split::Train,
            7..=8 => Split::Val,
            _ => Split::Test,
        })
        .collect();
    let ds = Dataset {
        name: "tiny_sbm".into(),
        task: Task::Multiclass,
        graph: sbm.graph,
        f_in,
        num_classes: classes,
        features,
        labels,
        split,
    };
    ds.validate().unwrap();
    ds
}

const HIDDEN: usize = 32;

fn cfg(epochs: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        layers: 2,
        hidden: Some(HIDDEN),
        b_max: Some(256),
        lr: 0.05,
        epochs,
        eval_every: 1,
        seed,
        ..TrainConfig::default()
    }
}

/// Serving config shape (the weights `into_server` inits for this).
fn serve_train_cfg(seed: u64) -> TrainConfig {
    TrainConfig { layers: 2, hidden: Some(HIDDEN), seed, ..TrainConfig::default() }
}

fn served_weights(ds: &Dataset, seed: u64) -> Vec<cluster_gcn::runtime::Tensor> {
    let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, HIDDEN, ds.num_classes, 8);
    TrainState::init(&spec, seed).weights
}

fn offline_logits(ds: &Dataset, weights: &[cluster_gcn::runtime::Tensor]) -> Vec<f32> {
    let mut nc = NormCache::new();
    full_forward_cached(ds, weights, NormConfig::PAPER_DEFAULT, false, &mut nc)
}

fn state_bits(state: &TrainState) -> Vec<u32> {
    state
        .weights
        .iter()
        .chain(&state.m)
        .chain(&state.v)
        .flat_map(|t| t.data.iter().map(|x| x.to_bits()))
        .collect()
}

// ---------------------------------------------------------------------
// checkpoint durability
// ---------------------------------------------------------------------

/// A save that crashes mid-write (torn tmp) or errors before the write
/// fails with the typed injected fault — and the previous good
/// checkpoint is byte-for-byte untouched (atomic tmp + rename).
#[test]
fn torn_write_fails_typed_and_leaves_previous_checkpoint_intact() {
    let _g = lock();
    failpoint::clear();
    let dir = tmp("torn");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.ckpt");
    let spec = ModelSpec::gcn(Task::Multiclass, 2, 8, 16, 4, 8);
    let st1 = TrainState::init(&spec, 1);
    checkpoint::save_v3(&st1, "m", 3, None, &path).unwrap();

    failpoint::install("ckpt.torn=1:1", 0).unwrap();
    let st2 = TrainState::init(&spec, 2);
    let err = checkpoint::save_v3(&st2, "m", 4, None, &path).unwrap_err();
    assert!(
        matches!(err, CheckpointError::Injected(f) if f.site == "ckpt.torn"),
        "torn write must surface the typed injected fault, got {err}"
    );
    failpoint::clear();

    let ck = checkpoint::load_full(&path).unwrap();
    assert_eq!(ck.epoch, 3, "the torn save must not touch the good file");
    assert_eq!(state_bits(&ck.state), state_bits(&st1));

    failpoint::install("ckpt.write=1:1", 0).unwrap();
    let err = checkpoint::save_v3(&st2, "m", 4, None, &path).unwrap_err();
    assert!(matches!(err, CheckpointError::Injected(f) if f.site == "ckpt.write"));
    failpoint::clear();
    assert_eq!(checkpoint::load_full(&path).unwrap().epoch, 3);
    std::fs::remove_dir_all(&dir).ok();
}

/// A bit-flipped `CGCNCKP3` fails with the typed checksum mismatch; the
/// rotation skips corrupt slots (flipped, then truncated) and
/// `load_full_or_fallback` lands on the newest intact survivor.
#[test]
fn corruption_is_detected_typed_and_the_rotation_falls_back() {
    let _g = lock();
    failpoint::clear();
    let dir = tmp("rot");
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("model.ckpt");
    let spec = ModelSpec::gcn(Task::Multiclass, 2, 8, 16, 4, 8);
    let store = RotatingCheckpoint::new(&base, 3);
    for epoch in 1..=4usize {
        store
            .save(&TrainState::init(&spec, epoch as u64), "m", epoch, None)
            .unwrap();
    }
    let slots = store.list().unwrap();
    assert_eq!(
        slots.iter().map(|(e, _)| *e).collect::<Vec<_>>(),
        vec![2, 3, 4],
        "rotation keeps the last 3 epochs"
    );

    // flip one bit mid-file in the newest slot
    let newest = slots.last().unwrap().1.clone();
    let mut bytes = std::fs::read(&newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&newest, &bytes).unwrap();
    assert!(
        matches!(checkpoint::load_full(&newest), Err(CheckpointError::ChecksumMismatch)),
        "a bit-flip must fail the CRC trailer, typed"
    );
    let (ck, path, rejected) = store.load_latest().unwrap();
    assert_eq!((ck.epoch, rejected), (3, 1), "fallback skips the flipped slot");
    assert_eq!(path, slots[1].1);

    // truncate the epoch-3 slot too: fallback walks on to epoch 2
    let bytes = std::fs::read(&slots[1].1).unwrap();
    std::fs::write(&slots[1].1, &bytes[..bytes.len() - 6]).unwrap();
    let (ck, _, rejected) = store.load_latest().unwrap();
    assert_eq!((ck.epoch, rejected), (2, 2));

    // the primary path never existed; the fallback still serves epoch 2
    let (ck, loaded) = checkpoint::load_full_or_fallback(&base).unwrap();
    assert_eq!(ck.epoch, 2);
    assert_eq!(loaded, slots[0].1);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// self-healing training
// ---------------------------------------------------------------------

/// The headline recovery invariant: a NaN injected mid-run (corrupting
/// only the *reported* loss, never the weights) rolls training back to
/// the last good rotating checkpoint, and with `lr_backoff = 1.0` the
/// post-recovery trajectory is **bitwise identical** to the fault-free
/// run — resume streams are pure functions of `(seed, epoch)`.
#[test]
fn guard_recovers_from_injected_nan_and_replays_fault_free_run_bitwise() {
    let _g = lock();
    failpoint::clear();
    let ds = tiny_sbm(7);
    let fault_free = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(6)
        .config(cfg(4, 9))
        .run()
        .unwrap();

    let dir = tmp("guard");
    std::fs::create_dir_all(&dir).unwrap();
    let store = RotatingCheckpoint::new(dir.join("model.ckpt.guard"), 3);
    // 6 steps per epoch (6 partitions, q = 1): skip 13 hits so the NaN
    // lands on epoch 3 step 1, after epochs 1-2 rotated clean saves
    failpoint::install("driver.loss=1:1:13", 0).unwrap();
    let gcfg = GuardConfig { lr_backoff: 1.0, max_retries: 2, ..GuardConfig::default() };
    let mut obs = NullObserver;
    let outcome = run_guarded(
        |ck, lr_scale| {
            let mut c = cfg(4, 9);
            c.lr *= lr_scale;
            let mut s = Session::new(&ds).method(Method::Cluster { q: 1 }).partition(6);
            if let Some(ck) = ck {
                c.start_epoch = ck.epoch;
                s = s.initial_state(ck.state.clone());
            }
            s.config(c).driver()
        },
        &gcfg,
        &store,
        &mut obs,
    )
    .unwrap();
    failpoint::clear();

    assert_eq!(outcome.retries, 1, "one anomaly, one recovery");
    assert_eq!(outcome.rollbacks, 1, "recovery must resume from the rotation");
    assert!(outcome.saves >= 4, "clean epochs rotate checkpoints");
    assert_eq!(outcome.lr_scale, 1.0);
    assert_eq!(
        state_bits(&fault_free.result.state),
        state_bits(&outcome.result.state),
        "post-recovery trajectory must replay the fault-free run bit for bit"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// An unrecoverable fault (every step errors) exhausts the retry budget
/// and surfaces as a typed `RetriesExhausted` — never a panic or hang.
#[test]
fn guard_gives_up_typed_after_the_retry_budget() {
    let _g = lock();
    failpoint::clear();
    let ds = tiny_sbm(3);
    let dir = tmp("exhaust");
    std::fs::create_dir_all(&dir).unwrap();
    let store = RotatingCheckpoint::new(dir.join("m.ckpt.guard"), 2);
    failpoint::install("driver.step=1", 0).unwrap();
    let gcfg = GuardConfig { max_retries: 2, ..GuardConfig::default() };
    let mut obs = NullObserver;
    let err = run_guarded(
        |ck, _| {
            let mut c = cfg(2, 5);
            let mut s = Session::new(&ds).method(Method::Cluster { q: 1 }).partition(4);
            if let Some(ck) = ck {
                c.start_epoch = ck.epoch;
                s = s.initial_state(ck.state.clone());
            }
            s.config(c).driver()
        },
        &gcfg,
        &store,
        &mut obs,
    )
    .unwrap_err();
    failpoint::clear();
    match err {
        GuardError::RetriesExhausted { retries, last } => {
            assert_eq!(retries, 2);
            assert!(
                matches!(last, Anomaly::StepError { .. }),
                "injected step faults surface as step errors, got {last}"
            );
        }
        other => panic!("expected RetriesExhausted, got {other}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// overload-safe serving
// ---------------------------------------------------------------------

/// Under sustained pressure (every flush stalled, bounded queue, 8
/// concurrent clients) the server sheds typed at admission and the
/// degradation ladder engages — and with a single partition even the
/// degraded halo-free engine answers bitwise-identical to the offline
/// full forward, so every successful response stays exact.
#[test]
fn overloaded_server_sheds_and_degrades_and_stays_exact_with_one_partition() {
    let _g = lock();
    failpoint::clear();
    let ds = tiny_sbm(11);
    let serve = ServeConfig {
        mode: ServeMode::ExactCached,
        queue_capacity: 2,
        shed_when_full: true,
        degrade_after: 1,
        ..ServeConfig::default()
    };
    let server = Session::new(&ds)
        .config(serve_train_cfg(5))
        .partition(1)
        .into_server(serve)
        .unwrap();
    let full = offline_logits(&ds, &served_weights(&ds, 5));
    failpoint::install("serve.flush.delay=1", 0).unwrap();
    let (ok, shed) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..8u32 {
            let (server, full) = (&server, &full);
            handles.push(s.spawn(move || {
                let (mut ok, mut shed) = (0u64, 0u64);
                for i in 0..40u32 {
                    let v = (t * 97 + i * 31) % 240;
                    match server.query_one(v) {
                        Ok(resp) => {
                            assert_eq!(
                                resp,
                                gather_rows(full, 4, &[v]),
                                "one partition: even degraded flushes are bitwise exact"
                            );
                            ok += 1;
                        }
                        Err(ServeError::Overloaded { queue_depth }) => {
                            assert!(queue_depth > 0);
                            shed += 1;
                        }
                        Err(e) => panic!("unexpected typed failure: {e}"),
                    }
                }
                (ok, shed)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0, 0), |a, b| (a.0 + b.0, a.1 + b.1))
    });
    failpoint::clear();
    let st = server.stats();
    assert!(ok > 0, "some queries must succeed");
    assert!(shed > 0, "admission control must shed under sustained pressure");
    assert_eq!(st.shed, shed);
    assert!(st.degraded_flushes > 0, "the degradation ladder must engage");
    assert_eq!(st.flush_panics, 0);
    // pressure gone: a lone query is non-pressured and exact again
    assert_eq!(server.query_one(17).unwrap(), gather_rows(&full, 4, &[17]));
}

/// Followers waiting behind a stalled flush expire their 1 ms deadlines
/// with the typed error (the leader never deadlines its own flush), and
/// the server counts every expiry.
#[test]
fn follower_deadlines_expire_typed_under_slow_flushes() {
    let _g = lock();
    failpoint::clear();
    let ds = tiny_sbm(13);
    let serve = ServeConfig { deadline_ms: 1, ..ServeConfig::default() };
    let server = Session::new(&ds)
        .config(serve_train_cfg(7))
        .partition(1)
        .into_server(serve)
        .unwrap();
    server.warm();
    failpoint::install("serve.flush.delay=1", 0).unwrap();
    let timeouts: u64 = std::thread::scope(|s| {
        (0..6u32)
            .map(|t| {
                let server = &server;
                s.spawn(move || {
                    let mut timeouts = 0u64;
                    for i in 0..40u32 {
                        match server.query_one((t * 37 + i) % 240) {
                            Ok(_) => {}
                            Err(ServeError::DeadlineExceeded { deadline_ms }) => {
                                assert_eq!(deadline_ms, 1);
                                timeouts += 1;
                            }
                            Err(e) => panic!("unexpected failure: {e}"),
                        }
                    }
                    timeouts
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .sum()
    });
    failpoint::clear();
    assert!(timeouts > 0, "1 ms deadlines must expire under 5 ms flushes");
    assert_eq!(server.stats().timeouts, timeouts);
}

/// An injected flush fault fails only the requests riding that flush —
/// typed, transient, and gone once the fault budget is exhausted.
#[test]
fn injected_flush_faults_are_typed_and_transient() {
    let _g = lock();
    failpoint::clear();
    let ds = tiny_sbm(12);
    let server = Session::new(&ds)
        .config(serve_train_cfg(6))
        .partition(1)
        .into_server(ServeConfig::default())
        .unwrap();
    let full = offline_logits(&ds, &served_weights(&ds, 6));
    failpoint::install("serve.flush=1:2", 0).unwrap();
    assert_eq!(server.query_one(5), Err(ServeError::Injected("serve.flush")));
    assert_eq!(server.query_one(5), Err(ServeError::Injected("serve.flush")));
    // fault budget exhausted: the same request now succeeds, bitwise
    assert_eq!(server.query_one(5).unwrap(), gather_rows(&full, 4, &[5]));
    let rep = failpoint::report();
    assert_eq!((rep[0].hits, rep[0].fires), (3, 2));
    failpoint::clear();
}

// ---------------------------------------------------------------------
// deep tier: the seeded end-to-end chaos sweep
// ---------------------------------------------------------------------

/// `CGCN_DEEP=1` sweep over train → checkpoint → resume → serve with a
/// different fault schedule per sweep seed.  Every leg must either
/// recover cleanly to the fault-free golden bits or fail with a typed
/// error — never panic, hang, or silently diverge.
#[test]
fn deep_seeded_chaos_sweep_over_train_checkpoint_resume_serve() {
    if std::env::var("CGCN_DEEP").ok().as_deref() != Some("1") {
        eprintln!("skipping deep chaos sweep (set CGCN_DEEP=1)");
        return;
    }
    let _g = lock();
    failpoint::clear();
    let ds = tiny_sbm(29);
    let fault_free = Session::new(&ds)
        .method(Method::Cluster { q: 1 })
        .partition(6)
        .config(cfg(4, 17))
        .run()
        .unwrap();
    let golden = state_bits(&fault_free.result.state);
    let gcfg = GuardConfig { lr_backoff: 1.0, max_retries: 3, ..GuardConfig::default() };

    for fail_seed in 0..4u64 {
        let dir = tmp(&format!("sweep{fail_seed}"));
        std::fs::create_dir_all(&dir).unwrap();
        let store = RotatingCheckpoint::new(dir.join("m.ckpt.guard"), 3);
        let mut obs = NullObserver;
        let mut make = |ck: Option<&checkpoint::Checkpoint>, lr_scale: f32| {
            let mut c = cfg(4, 17);
            c.lr *= lr_scale;
            let mut s = Session::new(&ds).method(Method::Cluster { q: 1 }).partition(6);
            if let Some(ck) = ck {
                c.start_epoch = ck.epoch;
                s = s.initial_state(ck.state.clone());
            }
            s.config(c).driver()
        };

        // -- train leg: mid-run NaN at a seed-dependent step ------------
        let skip = 6 + (fail_seed as usize * 5) % 17;
        failpoint::install(&format!("driver.loss=1:1:{skip}"), fail_seed).unwrap();
        let outcome = run_guarded(&mut make, &gcfg, &store, &mut obs);
        failpoint::clear();
        let outcome = match outcome {
            Ok(o) => o,
            Err(e) => panic!("seed {fail_seed}: guard must recover, got {e}"),
        };
        assert_eq!(outcome.retries, 1, "seed {fail_seed}: the fault must land once");
        assert_eq!(
            state_bits(&outcome.result.state),
            golden,
            "seed {fail_seed}: post-recovery trajectory diverged from golden"
        );

        // -- checkpoint/resume leg: a plain session resumed from the
        // oldest surviving rotation slot replays to the same bits ------
        let slots = store.list().unwrap();
        let (epoch, path) = slots.first().unwrap().clone();
        let ck = checkpoint::load_full(&path).unwrap();
        assert_eq!(ck.epoch, epoch);
        let resumed = Session::new(&ds)
            .method(Method::Cluster { q: 1 })
            .partition(6)
            .config(TrainConfig { start_epoch: ck.epoch, ..cfg(4, 17) })
            .initial_state(ck.state)
            .run()
            .unwrap();
        assert_eq!(
            state_bits(&resumed.result.state),
            golden,
            "seed {fail_seed}: resume from rotation slot e{epoch} diverged"
        );

        // -- torn-save leg: a crash during the rotating save itself is a
        // typed checkpoint error, never a panic -------------------------
        let dir2 = tmp(&format!("sweep{fail_seed}_torn"));
        std::fs::create_dir_all(&dir2).unwrap();
        let store2 = RotatingCheckpoint::new(dir2.join("m.ckpt.guard"), 3);
        failpoint::install(&format!("ckpt.torn=1:1:{fail_seed}"), fail_seed).unwrap();
        let res = run_guarded(&mut make, &gcfg, &store2, &mut obs);
        failpoint::clear();
        match res {
            Err(GuardError::Checkpoint(CheckpointError::Injected(f))) => {
                assert_eq!(f.site, "ckpt.torn", "seed {fail_seed}");
            }
            Err(e) => panic!("seed {fail_seed}: expected the typed injected fault, got {e}"),
            Ok(o) => panic!(
                "seed {fail_seed}: the torn save must surface (saves = {})",
                o.saves
            ),
        }
        // ...and every slot the torn run left behind still verifies
        for (_, p) in store2.list().unwrap() {
            checkpoint::load_full(&p).unwrap_or_else(|e| {
                panic!("seed {fail_seed}: torn run left a corrupt slot {p:?}: {e}")
            });
        }

        // -- serve leg: final weights served with random flush faults —
        // every response is bitwise exact or a typed injected error ----
        let server = Session::new(&ds)
            .config(cfg(4, 17))
            .partition(1)
            .initial_state(outcome.result.state.clone())
            .into_server(ServeConfig::default())
            .unwrap();
        let full = offline_logits(&ds, &outcome.result.state.weights);
        failpoint::install("serve.flush=0.5", fail_seed).unwrap();
        let mut injected = 0u64;
        for i in 0..40u32 {
            let v = (i * 13 + fail_seed as u32) % 240;
            match server.query_one(v) {
                Ok(resp) => assert_eq!(
                    resp,
                    gather_rows(&full, 4, &[v]),
                    "seed {fail_seed}: served bits diverged"
                ),
                Err(ServeError::Injected("serve.flush")) => injected += 1,
                Err(e) => panic!("seed {fail_seed}: unexpected serve failure: {e}"),
            }
        }
        failpoint::clear();
        assert!(injected > 0, "seed {fail_seed}: chaos faults must land in the serve leg");

        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }
}
