//! Table 6: sparse-operation realization benchmark.
//!
//! The paper benchmarks PyTorch-vs-TensorFlow sparse ops on the
//! featureless Amazon data (where `A @ W0` dominates) and attributes
//! Cluster-GCN's Amazon slowdown to the framework's sparse kernels.  In
//! our single-stack world the analogous contrast is the *adjacency
//! realization* for the batch propagation step (see DESIGN.md §4/§6):
//!
//!   dense-block — materialize the (b, b) normalized block, run the
//!                 fused MXU-friendly matmul (our L1 kernel's schedule);
//!   gather      — CSR scatter/gather SpMM over the same batch, the
//!                 GPU-framework-style realization.
//!
//! Both compute Z = Â_BB · X · W for one batch; rows report per-step
//! milliseconds for hidden 128 and 512.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::inference::spmm_layer_into;
use cluster_gcn::coordinator::BatchAssembler;
use cluster_gcn::graph::{induced_csr, SubgraphScratch};
use cluster_gcn::norm::{normalize_sparse, NormConfig};
use cluster_gcn::runtime::Tensor;
use cluster_gcn::util::pool::default_threads;
use cluster_gcn::util::{bench, Json, Rng, Timer};

/// Gather-style SpMM: z = (A_local @ x) @ w with CSR-ish edge list.
fn gather_spmm(
    n_local: usize,
    edges: &[(u32, u32)],
    vals: &[f32],
    x: &[f32],
    f: usize,
    w: &[f32],
    g: usize,
    out: &mut [f32],
    scratch: &mut [f32],
) {
    scratch[..n_local * f].iter_mut().for_each(|v| *v = 0.0);
    for (e, &(u, v)) in edges.iter().enumerate() {
        let a = vals[e];
        let src = &x[v as usize * f..(v as usize + 1) * f];
        let dst = &mut scratch[u as usize * f..(u as usize + 1) * f];
        for j in 0..f {
            dst[j] += a * src[j];
        }
    }
    out[..n_local * g].iter_mut().for_each(|v| *v = 0.0);
    for i in 0..n_local {
        for j in 0..f {
            let p = scratch[i * f + j];
            if p != 0.0 {
                let wr = &w[j * g..(j + 1) * g];
                let or = &mut out[i * g..(i + 1) * g];
                for k in 0..g {
                    or[k] += p * wr[k];
                }
            }
        }
    }
}

/// Dense-block matmul: the same computation over the materialized
/// (b, b) block (cache/MXU-friendly inner loops).
fn dense_block(
    b: usize,
    a: &[f32],
    x: &[f32],
    f: usize,
    w: &[f32],
    g: usize,
    out: &mut [f32],
    scratch: &mut [f32],
) {
    // P = A @ X
    for i in 0..b {
        let pr = &mut scratch[i * f..(i + 1) * f];
        pr.iter_mut().for_each(|v| *v = 0.0);
        let ar = &a[i * b..(i + 1) * b];
        for (j, &av) in ar.iter().enumerate() {
            if av != 0.0 {
                let xr = &x[j * f..(j + 1) * f];
                for t in 0..f {
                    pr[t] += av * xr[t];
                }
            }
        }
    }
    // Z = P @ W
    for i in 0..b {
        let or = &mut out[i * g..(i + 1) * g];
        or.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..f {
            let p = scratch[i * f + j];
            if p != 0.0 {
                let wr = &w[j * g..(j + 1) * g];
                for k in 0..g {
                    or[k] += p * wr[k];
                }
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    let iters = bs::env_usize("CGCN_ITERS", 10);
    let ds = bs::dataset("amazon_like")?;
    let seed = bs::env_seed();
    let p = bs::preset_of(&ds);

    // one real cluster batch
    let sampler = bs::cluster_sampler(&ds, p.default_partitions, p.default_q, seed);
    let mut rng = Rng::new(seed);
    let plan = sampler.epoch_plan(&mut rng);
    let mut nodes = Vec::new();
    sampler.batch_nodes(&plan[0], &mut nodes);
    let b = p.b_max;
    let mut asm = BatchAssembler::new(ds.n(), b, NormConfig::PAPER_DEFAULT);

    // phase timings: reused-buffer assembly + subgraph renormalization
    let mut batch = asm.new_batch(&ds);
    asm.assemble_into(&ds, &nodes, &mut batch); // warm the buffers
    let t = Timer::start();
    asm.assemble_into(&ds, &nodes, &mut batch);
    let assemble_ms = t.secs() * 1e3;

    // CSR view of the same batch block for the tiled fused kernel
    let sub = induced_csr(&ds.graph, &nodes);
    let t = Timer::start();
    let (svals, ssl) = normalize_sparse(&sub, NormConfig::PAPER_DEFAULT);
    let normalize_ms = t.secs() * 1e3;
    println!("phases: assemble {assemble_ms:.2} ms, normalize {normalize_ms:.2} ms");
    bs::dump_row(
        "table6",
        Json::obj(vec![
            ("assemble_ms", Json::num(assemble_ms)),
            ("normalize_ms", Json::num(normalize_ms)),
        ]),
    );

    // edge list + values for the gather path
    let mut scratch_sub = SubgraphScratch::new(ds.n());
    let mut edges = Vec::new();
    cluster_gcn::graph::induced_edges(&ds.graph, &nodes, &mut scratch_sub, &mut edges);
    // the normalized block also carries self loops — include the diagonal
    for i in 0..batch.n_real as u32 {
        edges.push((i, i));
    }
    let vals: Vec<f32> = edges
        .iter()
        .map(|&(u, v)| batch.a.data[u as usize * b + v as usize])
        .collect();

    println!("== Table 6: adjacency realization timing (amazon_like batch) ==");
    println!(
        "batch: {} real nodes, {} edges, b_max {}",
        batch.n_real,
        edges.len(),
        b
    );
    let mut table = bs::Table::new(&[
        "hidden", "dense-block ms", "gather ms", "tiled-1t ms", "tiled-pool ms",
    ]);
    let pool_threads = default_threads();
    for hidden in [128usize, 512] {
        let f = ds.f_in;
        let w: Vec<f32> = (0..f * hidden).map(|i| (i % 13) as f32 * 0.01).collect();
        let mut out = vec![0f32; b * hidden];
        let mut scr = vec![0f32; b * f.max(hidden)];

        let s_dense = bench(2, iters, || {
            dense_block(b, &batch.a.data, &batch.x.data, f, &w, hidden, &mut out, &mut scr);
        });
        let mut out2 = vec![0f32; b * hidden];
        let mut scr2 = vec![0f32; b * f.max(hidden)];
        let s_gather = bench(2, iters, || {
            gather_spmm(
                batch.n_real, &edges, &vals, &batch.x.data, f, &w, hidden,
                &mut out2, &mut scr2,
            );
        });
        // tiled fused SpMM·GEMM over the batch CSR, single-thread and
        // on the persistent pool
        let wt = Tensor::new(vec![f, hidden], w.clone());
        let x_real = &batch.x.data[..batch.n_real * f];
        let mut out3 = vec![0f32; batch.n_real * hidden];
        let s_tiled1 = bench(2, iters, || {
            spmm_layer_into(&sub, &svals, &ssl, x_real, f, &wt, false, 1, &mut out3);
        });
        let mut out4 = vec![0f32; batch.n_real * hidden];
        let s_tiledp = bench(2, iters, || {
            spmm_layer_into(&sub, &svals, &ssl, x_real, f, &wt, false, pool_threads, &mut out4);
        });

        // numeric agreement on real rows across all realizations
        let mut max_err = 0f32;
        for i in 0..batch.n_real * hidden {
            max_err = max_err.max((out[i] - out2[i]).abs());
            max_err = max_err.max((out[i] - out3[i]).abs());
            max_err = max_err.max((out[i] - out4[i]).abs());
        }
        assert!(max_err < 1e-3, "realizations disagree: {max_err}");

        table.row(&[
            hidden.to_string(),
            format!("{:.2}", s_dense.mean * 1e3),
            format!("{:.2}", s_gather.mean * 1e3),
            format!("{:.2}", s_tiled1.mean * 1e3),
            format!("{:.2}", s_tiledp.mean * 1e3),
        ]);
        bs::dump_row(
            "table6",
            Json::obj(vec![
                ("hidden", Json::num(hidden as f64)),
                ("dense_ms", Json::num(s_dense.mean * 1e3)),
                ("gather_ms", Json::num(s_gather.mean * 1e3)),
                ("tiled_ms", Json::num(s_tiled1.mean * 1e3)),
                ("tiled_pool_ms", Json::num(s_tiledp.mean * 1e3)),
            ]),
        );
    }
    table.print();
    println!("(paper's point: the sparse-op realization dominates the layer cost;");
    println!(" the gap widens with hidden size — compare the 128 vs 512 rows)");

    // ---- backward phases over the same batch: the pre-engine scalar
    // kernels vs the pooled backward engine (PR 3) --------------------
    use cluster_gcn::runtime::backward::{
        gemm_a_bt, gemm_a_bt_pooled, gemm_at_b, gemm_at_b_pooled, scatter_adj_t, AdjT,
    };
    println!();
    println!("== backward phases (same batch, f_in {} -> hidden) ==", ds.f_in);
    let mut btable = bs::Table::new(&[
        "hidden",
        "gemm_at_b ms",
        "pooled ms",
        "scatter ms",
        "adj_t gather ms",
        "gemm_a_bt ms",
        "pooled ms",
    ]);
    let blk = &batch.block;
    let n_real = batch.n_real;
    let f = ds.f_in;
    for hidden in [128usize, 512] {
        let mut rng = Rng::new(seed ^ hidden as u64);
        let p: Vec<f32> = (0..n_real * f).map(|_| rng.f32() - 0.5).collect();
        let dz: Vec<f32> = (0..n_real * hidden).map(|_| rng.f32() - 0.5).collect();
        let w: Vec<f32> = (0..f * hidden).map(|_| rng.f32() - 0.5).collect();
        let mut gw = vec![0f32; f * hidden];
        let s_atb = bench(2, iters, || {
            gw.fill(0.0);
            gemm_at_b(&p, &dz, n_real, f, hidden, &mut gw);
        });
        let s_atb_p = bench(2, iters, || {
            gemm_at_b_pooled(&p, &dz, n_real, f, hidden, pool_threads, &mut gw);
        });
        let m: Vec<f32> = (0..n_real * hidden).map(|_| rng.f32() - 0.5).collect();
        let mut dh = vec![0f32; n_real * hidden];
        let s_scatter = bench(2, iters, || {
            dh.fill(0.0);
            scatter_adj_t(&blk.offsets, &blk.cols, &blk.vals, &blk.self_loop, &m, hidden, &mut dh);
        });
        let mut adj_t = AdjT::new();
        let s_gather = bench(2, iters, || {
            adj_t.build(&blk.offsets, &blk.cols, &blk.vals, &blk.self_loop);
            adj_t.gather_into_pooled(&m, hidden, pool_threads, &mut dh);
        });
        let mut mbuf = vec![0f32; n_real * f];
        let s_abt = bench(2, iters, || {
            gemm_a_bt(&dz, &w, n_real, hidden, f, &mut mbuf);
        });
        let s_abt_p = bench(2, iters, || {
            gemm_a_bt_pooled(&dz, &w, n_real, hidden, f, pool_threads, &mut mbuf);
        });
        btable.row(&[
            hidden.to_string(),
            format!("{:.2}", s_atb.mean * 1e3),
            format!("{:.2}", s_atb_p.mean * 1e3),
            format!("{:.2}", s_scatter.mean * 1e3),
            format!("{:.2}", s_gather.mean * 1e3),
            format!("{:.2}", s_abt.mean * 1e3),
            format!("{:.2}", s_abt_p.mean * 1e3),
        ]);
        bs::dump_row(
            "table6",
            Json::obj(vec![
                ("kind", Json::str("backward")),
                ("hidden", Json::num(hidden as f64)),
                ("gemm_at_b_ms", Json::num(s_atb.mean * 1e3)),
                ("gemm_at_b_pooled_ms", Json::num(s_atb_p.mean * 1e3)),
                ("scatter_ms", Json::num(s_scatter.mean * 1e3)),
                ("adj_t_gather_ms", Json::num(s_gather.mean * 1e3)),
                ("gemm_a_bt_ms", Json::num(s_abt.mean * 1e3)),
                ("gemm_a_bt_pooled_ms", Json::num(s_abt_p.mean * 1e3)),
            ]),
        );
    }
    btable.print();
    Ok(())
}
