//! Figure 2: histograms of per-batch label-distribution entropy,
//! random vs clustering partition (reddit-like, 300 clusters).
//!
//! Paper: clustering-partitioned batches have *low* entropy (skewed
//! labels), random partitions high entropy — the imbalance motivating
//! the stochastic multiple-partitions scheme of §3.2.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::metrics::batch_label_entropy;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let clusters = bs::env_usize("CGCN_CLUSTERS", 300);
    let seed = bs::env_seed();
    let ds = bs::dataset("reddit_like")?;

    println!("== Figure 2: label entropy per batch, {clusters} clusters ==");
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for (name, random) in [("clustering", false), ("random", true)] {
        let sampler = if random {
            bs::random_sampler(&ds, clusters, 1, seed)
        } else {
            bs::cluster_sampler(&ds, clusters, 1, seed)
        };
        let entropies: Vec<f64> = sampler
            .clusters
            .iter()
            .map(|c| batch_label_entropy(&ds, c))
            .collect();
        rows.push((name.to_string(), entropies));
    }

    // text histogram, 12 bins over the combined range
    let max_h = rows
        .iter()
        .flat_map(|(_, e)| e.iter())
        .fold(0.0f64, |a, &b| a.max(b));
    let bins = 12usize;
    println!("{:>10}  {}", "entropy", "clustering | random  (batch counts)");
    let mut summary = Vec::new();
    for b in 0..bins {
        let lo = max_h * b as f64 / bins as f64;
        let hi = max_h * (b + 1) as f64 / bins as f64;
        let count = |es: &[f64]| {
            es.iter()
                .filter(|&&e| e >= lo && (e < hi || b == bins - 1))
                .count()
        };
        let c0 = count(&rows[0].1);
        let c1 = count(&rows[1].1);
        println!(
            "{lo:>5.2}-{hi:<5.2} {:<30} | {}",
            "#".repeat(c0.min(30)),
            "#".repeat(c1.min(30))
        );
        summary.push((lo, hi, c0, c1));
    }
    let mean = |es: &[f64]| es.iter().sum::<f64>() / es.len() as f64;
    let m_c = mean(&rows[0].1);
    let m_r = mean(&rows[1].1);
    println!("mean entropy: clustering {m_c:.3}  random {m_r:.3}");
    assert!(
        m_c < m_r,
        "clustering batches should have lower label entropy"
    );
    bs::dump_row(
        "fig2",
        Json::obj(vec![
            ("clusters", Json::num(clusters as f64)),
            ("mean_entropy_clustering", Json::num(m_c)),
            ("mean_entropy_random", Json::num(m_r)),
        ]),
    );
    println!("(paper: clustering partitions skew label distributions — reproduced)");
    Ok(())
}
