//! Table 11 + Figure 5: diagonal-enhancement techniques for deep GCNs
//! on PPI — best validation accuracy over a fixed epoch budget for
//! depths 2..8 under the four Â constructions:
//!
//!   (1)            symmetric normalization (paper default)
//!   (10)           row normalization Ã = (D+I)^{-1}(A+I)
//!   (10)+(9)       Ã + I
//!   (10)+(11) λ=1  Ã + λ·diag(Ã)
//!
//! Paper: all variants fine to 5 layers; at 7-8 layers only (10)+(11)
//! converges (96.2 at L8 vs ~43 for the rest).  Figure 5 is the same
//! experiment's convergence curve at 8 layers — we print both.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::train;
use cluster_gcn::session::TrainConfig;
use cluster_gcn::norm::NormConfig;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 8);
    // deep interpret-mode artifacts are RAM-hungry to XLA-compile and the
    // engine caches every executable; split the sweep across processes
    // (CGCN_MIN_LAYERS/CGCN_MAX_LAYERS) on machines under ~64 GB.
    let min_layers = bs::env_usize("CGCN_MIN_LAYERS", 2);
    let max_layers = bs::env_usize("CGCN_MAX_LAYERS", 8);
    let seed = bs::env_seed();
    let mut engine = bs::engine()?;
    let ds = bs::dataset("ppi_like")?;
    let p = bs::preset_of(&ds);

    let variants: [(&str, NormConfig); 4] = [
        ("(1) sym", NormConfig::PAPER_DEFAULT),
        ("(10) row", NormConfig::ROW),
        ("(10)+(9)", NormConfig::ROW_IDENTITY),
        ("(10)+(11) l=1", NormConfig::ROW_LAMBDA1),
    ];

    println!("== Table 11: diagonal enhancement, best val F1 in {epochs} epochs ==");
    let mut header: Vec<&str> = vec!["variant"];
    let depth_labels: Vec<String> =
        (min_layers..=max_layers).map(|l| format!("{l}-layer")).collect();
    header.extend(depth_labels.iter().map(|s| s.as_str()));
    let mut table = bs::Table::new(&header);

    let mut fig5: Vec<(String, Vec<(usize, f64)>)> = Vec::new();

    for (label, norm) in variants {
        let mut cells = vec![label.to_string()];
        for layers in min_layers..=max_layers {
            let sampler =
                bs::cluster_sampler(&ds, p.default_partitions, p.default_q, seed);
            let opts = TrainConfig {
                epochs,
                eval_every: (epochs / 5).max(1),
                seed,
                norm,
                ..TrainConfig::default()
            };
            let artifact = format!("ppi_L{layers}");
            match train(&mut engine, &ds, &sampler, &artifact, &opts) {
                Ok(r) => {
                    let best = r
                        .curve
                        .iter()
                        .map(|c| c.eval_f1)
                        .fold(0.0f64, f64::max);
                    cells.push(bs::fmt_f1(best));
                    bs::dump_row(
                        "table11",
                        Json::obj(vec![
                            ("variant", Json::str(label)),
                            ("layers", Json::num(layers as f64)),
                            ("best_val_f1", Json::num(best)),
                            ("epochs", Json::num(epochs as f64)),
                        ]),
                    );
                    if layers == max_layers {
                        fig5.push((
                            label.to_string(),
                            r.curve.iter().map(|c| (c.epoch, c.eval_f1)).collect(),
                        ));
                    }
                }
                Err(e) => {
                    // diverged (non-finite loss) — the Table 11 red cells
                    cells.push(format!("div({e:.0})").chars().take(8).collect());
                    if layers == max_layers {
                        fig5.push((label.to_string(), Vec::new()));
                    }
                }
            }
            engine.clear_cache(); // bound RSS across deep compiles
        }
        table.row(&cells);
    }
    table.print();

    println!("\n== Figure 5: {max_layers}-layer convergence (epoch, val F1) ==");
    for (label, curve) in &fig5 {
        let pts: Vec<String> = curve
            .iter()
            .map(|(e, f)| format!("({e},{f:.3})"))
            .collect();
        println!("{label:>14}: {}", if pts.is_empty() { "diverged".into() } else { pts.join(" ") });
        for (e, f) in curve {
            bs::dump_row(
                "fig5",
                Json::obj(vec![
                    ("variant", Json::str(label)),
                    ("epoch", Json::num(*e as f64)),
                    ("val_f1", Json::num(*f)),
                ]),
            );
        }
    }
    println!("\n(paper: only (10)+(11) holds up at 7-8 layers)");
    Ok(())
}
