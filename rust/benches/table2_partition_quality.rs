//! Table 2: random partition vs clustering partition, trained with
//! mini-batch SGD (one partition per batch), same epoch budget.
//!
//! Paper: Cora 78.4 vs 82.5, Pubmed 78.9 vs 79.9, PPI 68.1 vs 92.9 —
//! clustering wins everywhere, dramatically on PPI.  We reproduce the
//! *shape* (clustering >= random, largest gap on the ppi-like
//! multilabel data) on the synthetic stand-ins.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::train;
use cluster_gcn::session::TrainConfig;
use cluster_gcn::graph::Split;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 15);
    let seed = bs::env_seed();
    let mut engine = bs::engine()?;

    println!("== Table 2: random vs clustering partition (test F1) ==");
    let mut table = bs::Table::new(&["dataset", "random", "clustering"]);

    for (preset, artifact, parts) in [
        ("cora_like", "cora_L2", 10),
        ("pubmed_like", "pubmed_L2", 10),
        ("ppi_like", "ppi_L2", 50),
        // weak-feature PPI: the paper's real PPI has features that are
        // individually uninformative (motif/positional), so learning
        // *requires* neighbor aggregation — that regime is where the
        // random-partition gap blows up (paper: 68.1 vs 92.9). Our
        // default synthetic features are stronger; this row rebuilds the
        // dataset with 4x feature noise to match the paper's regime.
        ("ppi_weak", "ppi_L2", 50),
    ] {
        let ds = if preset == "ppi_weak" {
            let mut p = cluster_gcn::datagen::preset("ppi_like").unwrap().clone();
            p.feat_noise = 4.0;
            p.label_noise = 0.02;
            cluster_gcn::datagen::build(&p, seed)
        } else {
            bs::dataset(preset)?
        };
        let opts = TrainConfig {
            epochs,
            eval_every: 0, // final eval only
            seed,
            eval_split: Split::Test,
            ..TrainConfig::default()
        };
        let mut f1 = [0.0f64; 2];
        for (i, random) in [(0usize, false), (1usize, true)] {
            let sampler = if random {
                bs::random_sampler(&ds, parts, 1, seed)
            } else {
                bs::cluster_sampler(&ds, parts, 1, seed)
            };
            let r = train(&mut engine, &ds, &sampler, artifact, &opts)?;
            f1[i] = r.curve.last().unwrap().eval_f1;
        }
        table.row(&[
            preset.to_string(),
            bs::fmt_f1(f1[1]),
            bs::fmt_f1(f1[0]),
        ]);
        bs::dump_row(
            "table2",
            Json::obj(vec![
                ("dataset", Json::str(preset)),
                ("random_f1", Json::num(f1[1])),
                ("cluster_f1", Json::num(f1[0])),
                ("epochs", Json::num(epochs as f64)),
            ]),
        );
    }
    table.print();
    println!("(paper: clustering beats random; largest gap on PPI)");
    Ok(())
}
