//! Table 10: state-of-the-art accuracy via deeper Cluster-GCN.
//!
//! Paper: a 5-layer/2048-hidden Cluster-GCN with diagonal enhancement
//! reaches PPI F1 99.36 (prior best 98.71) and a 4-layer reaches Reddit
//! 96.60.  We run the scaled analogue: ppi_sota_L5 (1024 hidden,
//! (10)+(11) norm) and reddit_L4 against the 2-layer baselines, and
//! check deep > shallow on both.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::train;
use cluster_gcn::session::TrainConfig;
use cluster_gcn::graph::Split;
use cluster_gcn::norm::NormConfig;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 12);
    let seed = bs::env_seed();
    let mut engine = bs::engine()?;

    println!("== Table 10: deep Cluster-GCN vs shallow (test F1) ==");
    let mut table = bs::Table::new(&["config", "test F1"]);

    let runs: Vec<(&str, &str, &str, NormConfig)> = vec![
        ("PPI 2-layer (baseline)", "ppi_like", "ppi_L2", NormConfig::PAPER_DEFAULT),
        ("PPI 5-layer 1024h +diag", "ppi_like", "ppi_sota_L5", NormConfig::ROW_LAMBDA1),
        ("Reddit 2-layer (baseline)", "reddit_like", "reddit_L2", NormConfig::PAPER_DEFAULT),
        ("Reddit 4-layer", "reddit_like", "reddit_L4", NormConfig::PAPER_DEFAULT),
    ];
    let mut results = Vec::new();
    for (label, preset, artifact, norm) in runs {
        let ds = bs::dataset(preset)?;
        let p = bs::preset_of(&ds);
        let sampler = bs::cluster_sampler(&ds, p.default_partitions, p.default_q, seed);
        let opts = TrainConfig {
            epochs,
            eval_every: 0,
            seed,
            norm,
            eval_split: Split::Test,
            ..TrainConfig::default()
        };
        let r = train(&mut engine, &ds, &sampler, artifact, &opts)?;
        let f1 = r.curve.last().unwrap().eval_f1;
        table.row(&[label.to_string(), bs::fmt_f1(f1)]);
        bs::dump_row(
            "table10",
            Json::obj(vec![
                ("config", Json::str(label)),
                ("test_f1", Json::num(f1)),
                ("epochs", Json::num(epochs as f64)),
            ]),
        );
        results.push((label, f1));
    }
    table.print();
    println!(
        "deep-vs-shallow deltas: PPI {:+.4}, Reddit {:+.4}",
        results[1].1 - results[0].1,
        results[3].1 - results[2].1
    );
    println!("(paper: deeper GCNs set SOTA — PPI 99.36, Reddit 96.60)");
    Ok(())
}
