//! Table 8: the scalability headline — Amazon2M (scaled 1/15 here):
//! training time, memory, and test F1 for 2/3/4-layer GCNs,
//! Cluster-GCN vs VR-GCN.
//!
//! Paper: VRGCN wins time at 2 layers (337s vs 1223s), loses at 3
//! (1961s vs 1523s), OOMs at 4 layers; Cluster-GCN memory stays ~flat
//! (2.2GB) while VRGCN's grows (7.5 → 11.2GB → OOM).  We report the
//! same rows; the VRGCN 4-layer entry is the analytic memory model's
//! verdict against the configured budget.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::memory::{vrgcn_bytes, Dims};
use cluster_gcn::session::TrainConfig;
use cluster_gcn::graph::Split;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 2);
    // "GPU memory" budget for the OOM verdict, scaled with the dataset
    // (the paper's 16GB V100 vs 2.4M nodes -> we scale by our 160k).
    let budget_mb = bs::env_usize("CGCN_MEM_BUDGET_MB", 1100);
    let seed = bs::env_seed();
    let mut engine = bs::engine()?;
    let ds = bs::dataset("amazon2m_like")?;
    let p = bs::preset_of(&ds);

    println!("== Table 8: amazon2m_like time / memory / test F1 ==");
    println!(
        "(n={}, {} edges, budget for OOM verdict: {budget_mb} MB)",
        ds.n(),
        ds.graph.num_edges()
    );
    let mut table = bs::Table::new(&[
        "layers", "vrgcn time", "cluster time", "vrgcn mem", "cluster mem",
        "vrgcn F1", "cluster F1",
    ]);

    for layers in [2usize, 3, 4] {
        let opts = TrainConfig {
            epochs,
            eval_every: 0,
            seed,
            eval_split: Split::Test,
            ..TrainConfig::default()
        };
        // --- cluster ---------------------------------------------------
        let c = bs::run_method(&mut engine, &ds, "cluster", layers, &opts)?;
        let (ct, cm, cf) = (
            c.train_seconds,
            c.peak_bytes,
            c.curve.last().unwrap().eval_f1,
        );

        // --- vrgcn (4-layer: OOM verdict from the analytic model) ------
        let dims = Dims {
            n: ds.n(),
            f_in: ds.f_in,
            f_hid: p.f_hid,
            classes: ds.num_classes,
            layers,
            b: p.b_max,
            r: 2,
            d: ds.graph.nnz() as f64 / ds.n() as f64,
        };
        let vr_analytic = vrgcn_bytes(&dims);
        let oom = vr_analytic > budget_mb * 1_000_000;
        let (vt, vm, vf) = if oom {
            (None, None, None)
        } else {
            let vr_opts = TrainConfig {
                epochs: bs::env_usize("CGCN_VRGCN_EPOCHS", 1),
                ..opts.clone()
            };
            match bs::run_method(&mut engine, &ds, "vrgcn", layers, &vr_opts) {
                Ok(r) => (
                    Some(r.train_seconds),
                    Some(r.peak_bytes),
                    Some(r.curve.last().unwrap().eval_f1),
                ),
                Err(_) => (None, None, None),
            }
        };

        engine.clear_cache(); // bound RSS across deep compiles
        table.row(&[
            layers.to_string(),
            vt.map(bs::fmt_s).unwrap_or_else(|| "N/A".into()),
            bs::fmt_s(ct),
            vm.map(bs::fmt_mb)
                .unwrap_or_else(|| format!("OOM[{}]", bs::fmt_mb(vr_analytic))),
            bs::fmt_mb(cm),
            vf.map(bs::fmt_f1).unwrap_or_else(|| "N/A".into()),
            bs::fmt_f1(cf),
        ]);
        bs::dump_row(
            "table8",
            Json::obj(vec![
                ("layers", Json::num(layers as f64)),
                ("cluster_s", Json::num(ct)),
                ("cluster_mb", Json::num(cm as f64 / 1e6)),
                ("cluster_f1", Json::num(cf)),
                ("vrgcn_s", Json::num(vt.unwrap_or(-1.0))),
                (
                    "vrgcn_mb",
                    Json::num(vm.map(|b| b as f64 / 1e6).unwrap_or(-1.0)),
                ),
                ("vrgcn_f1", Json::num(vf.unwrap_or(-1.0))),
                ("vrgcn_oom", Json::Bool(oom)),
            ]),
        );
    }
    table.print();
    println!("(paper shape: cluster memory flat; vrgcn memory grows, OOM at L4;");
    println!(" vrgcn faster at L2, cluster faster at L3+)");
    Ok(())
}
