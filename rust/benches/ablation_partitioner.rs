//! Ablation (DESIGN.md §5 extension): how much of Cluster-GCN's win
//! comes from the *multilevel* clustering algorithm specifically?
//!
//! Compares three cluster constructors — random, single-level local
//! search (Graclus-flavored), multilevel (METIS-like) — on (a) edge cut
//! / embedding utilization, (b) clustering time, (c) downstream
//! validation F1 after the same training budget on ppi_like.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::{train, ClusterSampler};
use cluster_gcn::session::TrainConfig;
use cluster_gcn::partition::{
    metrics::stats, parts_to_clusters, LocalSearchPartitioner,
    MultilevelPartitioner, Partitioner, RandomPartitioner,
};
use cluster_gcn::util::{Json, Rng, Timer};

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 8);
    let seed = bs::env_seed();
    let mut engine = bs::engine()?;
    let ds = bs::dataset("ppi_like")?;
    let p = bs::preset_of(&ds);
    let k = p.default_partitions;

    println!("== Ablation: cluster constructor (ppi_like, {k} parts) ==");
    let mut table = bs::Table::new(&[
        "partitioner", "cluster s", "within %", "balance", "val F1",
    ]);

    let partitioners: Vec<(&str, Box<dyn Partitioner>)> = vec![
        ("random", Box::new(RandomPartitioner)),
        ("local-search", Box::new(LocalSearchPartitioner::default())),
        ("multilevel", Box::new(MultilevelPartitioner::default())),
    ];

    for (name, partitioner) in partitioners {
        let mut rng = Rng::new(seed ^ 0xAB1A);
        let t = Timer::start();
        let part = partitioner.partition(&ds.graph, k, &mut rng);
        let cl_s = t.secs();
        let st = stats(&ds.graph, &part, k);
        let sampler = ClusterSampler::new(parts_to_clusters(&part, k), p.default_q);
        let opts = TrainConfig {
            epochs,
            eval_every: 0,
            seed,
            ..TrainConfig::default()
        };
        let r = train(&mut engine, &ds, &sampler, "ppi_L2", &opts)?;
        let f1 = r.curve.last().unwrap().eval_f1;
        table.row(&[
            name.to_string(),
            bs::fmt_s(cl_s),
            format!("{:.1}", 100.0 * st.within_fraction),
            format!("{:.2}", st.balance),
            bs::fmt_f1(f1),
        ]);
        bs::dump_row(
            "ablation_partitioner",
            Json::obj(vec![
                ("partitioner", Json::str(name)),
                ("clustering_s", Json::num(cl_s)),
                ("within_fraction", Json::num(st.within_fraction)),
                ("val_f1", Json::num(f1)),
            ]),
        );
    }
    table.print();
    println!("(expected: within%% and F1 rise random → local-search → multilevel)");
    Ok(())
}
