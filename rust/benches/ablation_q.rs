//! Ablation: clusters-per-batch (q) sweep — the §3.2 design choice.
//! Fixes p=1500 partitions on reddit_like and sweeps q ∈ {1, 5, 10,
//! 20}, reporting convergence (val F1 at the same epoch budget) and
//! per-epoch time.  Fig. 4 compares two points of this sweep; the
//! ablation maps the whole curve.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::train;
use cluster_gcn::session::TrainConfig;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 6);
    let seed = bs::env_seed();
    let mut engine = bs::engine()?;
    let ds = bs::dataset("reddit_like")?;
    let parts = 1500;

    println!("== Ablation: clusters per batch q (reddit_like, p={parts}) ==");
    let mut table = bs::Table::new(&["q", "batch nodes", "s/epoch", "val F1"]);
    for q in [1usize, 5, 10, 20] {
        // q<=8 fits the small artifact (b_max 256); larger q needs 768
        let artifact = if q <= 8 { "reddit_small_L2" } else { "reddit_L2" };
        let sampler = bs::cluster_sampler(&ds, parts, q, seed);
        if sampler.max_batch_nodes() > engine.meta(artifact)?.b_max {
            println!("q={q}: skipped (batch exceeds {artifact} b_max)");
            continue;
        }
        let opts = TrainConfig {
            epochs,
            eval_every: 0,
            seed,
            ..TrainConfig::default()
        };
        let r = train(&mut engine, &ds, &sampler, artifact, &opts)?;
        let f1 = r.curve.last().unwrap().eval_f1;
        table.row(&[
            q.to_string(),
            format!("~{}", ds.n() / parts * q),
            bs::fmt_s(r.train_seconds / epochs as f64),
            bs::fmt_f1(f1),
        ]);
        bs::dump_row(
            "ablation_q",
            Json::obj(vec![
                ("q", Json::num(q as f64)),
                ("s_per_epoch", Json::num(r.train_seconds / epochs as f64)),
                ("val_f1", Json::num(f1)),
            ]),
        );
    }
    table.print();
    println!("(paper §3.2: larger q adds between-cluster links back and");
    println!(" lowers batch variance — F1 should improve with q)");
    Ok(())
}
