//! Table 9: running time vs depth (PPI, fixed epoch budget):
//! Cluster-GCN grows linearly with L, VR-GCN super-linearly (its
//! receptive field explodes, so deeper nets need smaller target batches
//! and more steps).
//!
//! Paper (200 epochs): cluster 52.9/82.5/109.4/137.8/157.3s for L=2..6;
//! vrgcn 103.6/229/521.2/1054/1956s.  We run a scaled epoch budget and
//! check the growth *shapes* (cluster ~linear, vrgcn ~exponential).

use cluster_gcn::bench_support as bs;
use cluster_gcn::session::TrainConfig;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 2);
    // depth cap: the 6-layer VR-GCN artifact's XLA compile needs tens of
    // GB of host RAM (deep interpret-mode loops); default to 5 on
    // smaller machines and raise via CGCN_MAX_LAYERS where it fits.
    let max_layers = bs::env_usize("CGCN_MAX_LAYERS", 5);
    let seed = bs::env_seed();
    let mut engine = bs::engine()?;
    let ds = bs::dataset("ppi_like")?;

    println!("== Table 9: runtime vs depth (ppi_like, {epochs} epochs) ==");
    let mut table = bs::Table::new(&["layers", "cluster s", "vrgcn s", "ratio"]);
    let mut cluster_times = Vec::new();
    let mut vrgcn_times = Vec::new();

    for layers in 2..=max_layers {
        let opts = TrainConfig {
            epochs,
            eval_every: 0,
            seed,
            ..TrainConfig::default()
        };
        let c = bs::run_method(&mut engine, &ds, "cluster", layers, &opts)?;
        let v = bs::run_method(&mut engine, &ds, "vrgcn", layers, &opts)?;
        cluster_times.push(c.train_seconds);
        vrgcn_times.push(v.train_seconds);
        engine.clear_cache(); // bound RSS across deep compiles
        table.row(&[
            layers.to_string(),
            bs::fmt_s(c.train_seconds),
            bs::fmt_s(v.train_seconds),
            format!("{:.2}", v.train_seconds / c.train_seconds),
        ]);
        bs::dump_row(
            "table9",
            Json::obj(vec![
                ("layers", Json::num(layers as f64)),
                ("cluster_s", Json::num(c.train_seconds)),
                ("vrgcn_s", Json::num(v.train_seconds)),
                ("epochs", Json::num(epochs as f64)),
            ]),
        );
    }
    table.print();

    // shape checks: cluster growth with depth should be mild (~linear
    // in L); vrgcn growth should clearly outpace cluster's.
    let cg = cluster_times.last().unwrap() / cluster_times.first().unwrap();
    let vg = vrgcn_times.last().unwrap() / vrgcn_times.first().unwrap();
    println!("growth L2->L{max_layers}: cluster {cg:.2}x, vrgcn {vg:.2}x");
    println!("(paper: cluster ~3x over L2..6, vrgcn ~19x)");
    Ok(())
}
