//! Table 5: training-memory comparison across methods × depths ×
//! hidden sizes (paper: VRGCN/Cluster-GCN/GraphSAGE on PPI-512,
//! Reddit-128, Reddit-512, Amazon-128).
//!
//! We report both the *measured* peak bytes of live runs (batch tensors
//! + params/optimizer + method-private state like the VR-GCN history)
//! and the analytic Table-1 models from `coordinator::memory`.
//! Expected shape: Cluster-GCN flat in depth; VRGCN grows with L and
//! dominates at hidden 512; GraphSAGE in between.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::memory::{
    cluster_gcn_bytes, graphsage_bytes, vrgcn_bytes, Dims,
};
use cluster_gcn::session::TrainConfig;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 1);
    let seed = bs::env_seed();
    let mut engine = bs::engine()?;

    println!("== Table 5: memory usage (MB), measured + [analytic] ==");
    let mut table = bs::Table::new(&[
        "dataset(hid)", "L", "vrgcn", "cluster", "sage",
    ]);

    // (preset, hidden, artifact prefix remap for the 512-hidden reddit)
    let rows: Vec<(&str, usize, Option<&str>)> = vec![
        ("ppi_like", 512, None),
        ("reddit_like", 128, None),
        ("reddit_like", 512, Some("reddit_h512")),
        ("amazon_like", 128, None),
    ];

    for (preset_name, hidden, cluster_override) in rows {
        let ds = bs::dataset(preset_name)?;
        let p = bs::preset_of(&ds);
        for layers in [2usize, 3, 4] {
            let opts = TrainConfig {
                epochs,
                eval_every: 0,
                seed,
                // a few steps reach peak state; no need for a full pass
                max_steps_per_epoch: bs::env_usize("CGCN_MEM_STEPS", 3),
                ..TrainConfig::default()
            };
            // measured runs --------------------------------------------
            let measure = |engine: &mut cluster_gcn::runtime::Engine,
                           method: &str|
             -> Option<usize> {
                let short = preset_name.trim_end_matches("_like");
                let artifact = match (method, cluster_override) {
                    ("cluster", Some(o)) => format!("{o}_L{layers}"),
                    ("cluster", None) => format!("{short}_L{layers}"),
                    ("graphsage", _) => format!("{short}_sage_L{layers}"),
                    ("vrgcn", _) => format!("{short}_vrgcn_L{layers}"),
                    _ => unreachable!(),
                };
                if engine.meta(&artifact).is_err() {
                    return None; // combination not shipped (like paper's N/A)
                }
                let r = match method {
                    "cluster" => {
                        let sampler = bs::cluster_sampler(
                            &ds,
                            p.default_partitions,
                            p.default_q,
                            seed,
                        );
                        cluster_gcn::coordinator::train(engine, &ds, &sampler, &artifact, &opts)
                    }
                    "graphsage" => cluster_gcn::baselines::train_graphsage(
                        engine,
                        &ds,
                        &artifact,
                        &cluster_gcn::baselines::SageParams::for_depth(layers, 256),
                        &opts,
                    ),
                    "vrgcn" => cluster_gcn::baselines::train_vrgcn(
                        engine,
                        &ds,
                        &artifact,
                        &cluster_gcn::baselines::VrgcnParams::default(),
                        &opts,
                    ),
                    _ => unreachable!(),
                };
                r.ok().map(|r| r.peak_bytes)
            };
            let m_vr = measure(&mut engine, "vrgcn");
            let m_cl = measure(&mut engine, "cluster");
            let m_sg = measure(&mut engine, "graphsage");
            engine.clear_cache(); // bound RSS across the grid

            // analytic models -------------------------------------------
            let dims = Dims {
                n: ds.n(),
                f_in: ds.f_in,
                f_hid: hidden,
                classes: ds.num_classes,
                layers,
                b: p.b_max,
                r: 2,
                d: ds.graph.nnz() as f64 / ds.n() as f64,
            };
            let fmt = |m: Option<usize>, analytic: usize| match m {
                Some(b) => format!("{} [{}]", bs::fmt_mb(b), bs::fmt_mb(analytic)),
                None => format!("N/A [{}]", bs::fmt_mb(analytic)),
            };
            table.row(&[
                format!("{preset_name}({hidden})"),
                layers.to_string(),
                fmt(m_vr, vrgcn_bytes(&dims)),
                fmt(m_cl, cluster_gcn_bytes(&dims)),
                fmt(m_sg, graphsage_bytes(&dims)),
            ]);
            bs::dump_row(
                "table5",
                Json::obj(vec![
                    ("dataset", Json::str(preset_name)),
                    ("hidden", Json::num(hidden as f64)),
                    ("layers", Json::num(layers as f64)),
                    ("vrgcn_mb", Json::num(m_vr.unwrap_or(0) as f64 / 1e6)),
                    ("cluster_mb", Json::num(m_cl.unwrap_or(0) as f64 / 1e6)),
                    ("sage_mb", Json::num(m_sg.unwrap_or(0) as f64 / 1e6)),
                ]),
            );
        }
    }
    table.print();
    println!("(paper: Cluster-GCN flat in depth; VRGCN grows and dominates)");
    Ok(())
}
