//! Table 13: preprocessing cost — graph clustering time vs total
//! preprocessing (dataset generation/loading + normalization), per
//! dataset at the paper's partition counts.
//!
//! Paper: clustering is a small fraction of preprocessing (e.g. Reddit
//! 33s of 286s; Amazon2M 148s of 2160s).

use std::path::Path;

use cluster_gcn::bench_support as bs;
use cluster_gcn::datagen::{build, preset};
use cluster_gcn::norm::{normalize_sparse, NormConfig};
use cluster_gcn::partition::{MultilevelPartitioner, Partitioner};
use cluster_gcn::util::{Json, Rng, Timer};

fn main() -> anyhow::Result<()> {
    let seed = bs::env_seed();
    println!("== Table 13: clustering + preprocessing time ==");
    let mut table = bs::Table::new(&[
        "dataset", "#partitions", "clustering s", "preprocessing s",
    ]);
    for name in [
        "cora_like", "pubmed_like", "ppi_like", "reddit_like",
        "amazon_like", "amazon2m_like",
    ] {
        let p = preset(name).unwrap();
        // preprocessing: generation (stands in for download/parse) +
        // feature normalization + adjacency normalization
        let t_pre = Timer::start();
        let ds = build(p, seed);
        let _ = normalize_sparse(&ds.graph, NormConfig::PAPER_DEFAULT);
        let pre_s = t_pre.secs();

        let t_cl = Timer::start();
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let part = MultilevelPartitioner::default().partition(
            &ds.graph,
            p.default_partitions,
            &mut rng,
        );
        let cl_s = t_cl.secs();
        let stats =
            cluster_gcn::partition::metrics::stats(&ds.graph, &part, p.default_partitions);

        table.row(&[
            name.to_string(),
            p.default_partitions.to_string(),
            bs::fmt_s(cl_s),
            bs::fmt_s(pre_s),
        ]);
        bs::dump_row(
            "table13",
            Json::obj(vec![
                ("dataset", Json::str(name)),
                ("partitions", Json::num(p.default_partitions as f64)),
                ("clustering_s", Json::num(cl_s)),
                ("preprocessing_s", Json::num(pre_s)),
                ("within_fraction", Json::num(stats.within_fraction)),
            ]),
        );
        // partitions are reusable across training runs — persist like a
        // real pipeline would
        let _ = std::fs::create_dir_all(Path::new("data"));
    }
    table.print();
    println!("(paper: clustering is a modest, one-off preprocessing cost)");
    Ok(())
}
