//! Figure 4: one cluster per batch (300 partitions) vs multiple
//! clusters per batch (1500 partitions, sample 5) — epoch vs val F1.
//!
//! Paper: the stochastic multiple-partitions scheme converges better
//! because between-cluster links return and batch variance drops.

use cluster_gcn::bench_support as bs;
use cluster_gcn::coordinator::train;
use cluster_gcn::session::TrainConfig;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 12);
    let seed = bs::env_seed();
    let ds = bs::dataset("reddit_like")?;
    let mut engine = bs::engine()?;

    println!("== Figure 4: one cluster vs multiple clusters (reddit_like) ==");
    let mut curves = Vec::new();
    for (label, parts, q) in [("1 cluster (300)", 300, 1), ("5 clusters (1500)", 1500, 5)] {
        let sampler = bs::cluster_sampler(&ds, parts, q, seed);
        let opts = TrainConfig {
            epochs,
            eval_every: 2,
            seed,
            ..TrainConfig::default()
        };
        let r = train(&mut engine, &ds, &sampler, "reddit_small_L2", &opts)?;
        curves.push((label, r.curve));
    }

    let mut table = bs::Table::new(&["epoch", curves[0].0, curves[1].0]);
    let n = curves[0].1.len().min(curves[1].1.len());
    for i in 0..n {
        table.row(&[
            curves[0].1[i].epoch.to_string(),
            bs::fmt_f1(curves[0].1[i].eval_f1),
            bs::fmt_f1(curves[1].1[i].eval_f1),
        ]);
        bs::dump_row(
            "fig4",
            Json::obj(vec![
                ("epoch", Json::num(curves[0].1[i].epoch as f64)),
                ("one_cluster_f1", Json::num(curves[0].1[i].eval_f1)),
                ("multi_cluster_f1", Json::num(curves[1].1[i].eval_f1)),
            ]),
        );
    }
    table.print();
    println!("(paper: multiple clusters per batch converge better)");
    Ok(())
}
