//! Figure 6: training time (x) vs validation F1 (y) for Cluster-GCN,
//! VR-GCN and GraphSAGE across PPI / Reddit / Amazon at 2/3/4 layers.
//!
//! Paper: Cluster-GCN fastest on PPI and Reddit at every depth;
//! GraphSAGE slowest (it only appears on PPI/Reddit); on Amazon (no
//! sage) VRGCN and Cluster-GCN trade places by depth.  We reproduce the
//! per-depth time-to-F1 curves; epochs default small for CPU budget
//! (CGCN_EPOCHS raises them).

use cluster_gcn::bench_support as bs;
use cluster_gcn::session::TrainConfig;
use cluster_gcn::util::Json;

fn main() -> anyhow::Result<()> {
    let epochs = bs::env_usize("CGCN_EPOCHS", 4);
    let sage_epochs = bs::env_usize("CGCN_SAGE_EPOCHS", 1);
    let seed = bs::env_seed();
    let mut engine = bs::engine()?;

    println!("== Figure 6: training time vs val F1 ==");
    for preset in ["ppi_like", "reddit_like", "amazon_like"] {
        let ds = bs::dataset(preset)?;
        for layers in [2usize, 3, 4] {
            println!("\n--- {preset}, {layers}-layer ---");
            let mut table =
                bs::Table::new(&["method", "epoch", "train_s", "val_f1"]);
            for method in ["cluster", "vrgcn", "graphsage"] {
                // paper: GraphSAGE curves only for PPI and Reddit
                if method == "graphsage" && preset == "amazon_like" {
                    continue;
                }
                let e = if method == "graphsage" { sage_epochs } else { epochs };
                let opts = TrainConfig {
                    epochs: e,
                    eval_every: (e / 3).max(1),
                    seed,
                    ..TrainConfig::default()
                };
                match bs::run_method(&mut engine, &ds, method, layers, &opts) {
                    Ok(r) => {
                        for pt in &r.curve {
                            table.row(&[
                                method.to_string(),
                                pt.epoch.to_string(),
                                bs::fmt_s(pt.train_seconds),
                                bs::fmt_f1(pt.eval_f1),
                            ]);
                            bs::dump_row(
                                "fig6",
                                Json::obj(vec![
                                    ("dataset", Json::str(preset)),
                                    ("layers", Json::num(layers as f64)),
                                    ("method", Json::str(method)),
                                    ("epoch", Json::num(pt.epoch as f64)),
                                    ("train_s", Json::num(pt.train_seconds)),
                                    ("val_f1", Json::num(pt.eval_f1)),
                                ]),
                            );
                        }
                    }
                    Err(e) => println!("  {method}: skipped ({e})"),
                }
                // XLA CPU retains big buffers per executable; evict
                // between configurations to bound RSS
                engine.clear_cache();
            }
            table.print();
        }
    }
    println!("\n(paper: Cluster-GCN reaches a given F1 fastest on PPI/Reddit)");
    Ok(())
}
