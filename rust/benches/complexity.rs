//! Table 1 (empirical): embedding-utilization and embedding-computation
//! counters per training algorithm, measured on real batches.
//!
//! Cluster-GCN computes O(b·L) embeddings per batch with high
//! within-batch edge counts (utilization); vanilla SGD's full expansion
//! and GraphSAGE's sampled expansion compute far more embeddings per
//! *target* node, growing with depth.

use cluster_gcn::baselines::expansion::{expand, target_batches};
use cluster_gcn::baselines::graphsage::{sample_field, SageParams};
use cluster_gcn::bench_support as bs;
use cluster_gcn::graph::{within_edges, SubgraphScratch};
use cluster_gcn::util::{Json, Rng};

fn main() -> anyhow::Result<()> {
    let seed = bs::env_seed();
    let ds = bs::dataset("ppi_like")?;
    let p = bs::preset_of(&ds);
    let mut rng = Rng::new(seed);
    let mut scratch = SubgraphScratch::new(ds.n());

    println!("== Table 1 (empirical): embeddings computed per target node ==");
    let mut table = bs::Table::new(&[
        "L", "cluster", "vanilla-SGD", "graphsage", "cluster util(edges/node)",
    ]);

    // cluster batches: one partition per batch (paper PPI setting)
    let sampler = bs::cluster_sampler(&ds, p.default_partitions, p.default_q, seed);
    let plan = sampler.epoch_plan(&mut rng);
    let mut nodes = Vec::new();
    sampler.batch_nodes(&plan[0], &mut nodes);
    let cluster_batch = nodes.len();
    let cluster_edges = within_edges(&ds.graph, &nodes, &mut scratch);

    let train_nodes = ds.nodes_in_split(cluster_gcn::graph::Split::Train);
    for layers in [2usize, 3, 4, 5] {
        // cluster-GCN: every batch node embedded at every layer; batch
        // IS the target set
        let cluster_per_target = layers as f64;

        // vanilla SGD: full L-hop expansion per batch of 64 targets
        let batches = target_batches(&train_nodes, 64, &mut rng);
        let e = expand(&ds.graph, &batches[0], layers, ds.n());
        let vanilla_per_target =
            (e.nodes.len() * layers) as f64 / batches[0].len() as f64;

        // graphsage: sampled expansion
        let params = SageParams::for_depth(layers, 64);
        let f = sample_field(&ds, &batches[0], &params, ds.n(), &mut rng);
        let sage_per_target =
            (f.nodes.len() * layers) as f64 / batches[0].len() as f64;

        table.row(&[
            layers.to_string(),
            format!("{cluster_per_target:.1}"),
            format!("{vanilla_per_target:.1}"),
            format!("{sage_per_target:.1}"),
            format!("{:.1}", cluster_edges as f64 / cluster_batch as f64),
        ]);
        bs::dump_row(
            "complexity",
            Json::obj(vec![
                ("layers", Json::num(layers as f64)),
                ("cluster_per_target", Json::num(cluster_per_target)),
                ("vanilla_per_target", Json::num(vanilla_per_target)),
                ("sage_per_target", Json::num(sage_per_target)),
            ]),
        );
    }
    table.print();
    println!("(Table 1: cluster O(L) per node; SGD methods grow with depth)");
    Ok(())
}
