//! VR-GCN baseline [Chen, Zhu & Song, ICML'18]: control-variate
//! neighbor sampling with historical activations.
//!
//! The estimator per layer is
//!
//!   Z_v = Â_vv·X_v + Σ_{u∈S(v)} (d_v/|S(v)|)·Â_vu·(X_u − H_u)
//!        + Σ_{u∈N(v)} Â_vu·H_u
//!
//! with S(v) the r sampled neighbors and H the *historical* activations
//! of the previous layer.  Mapping onto the backend's `vrgcn_step`
//! (PJRT `model.vrgcn_train_step` or the host implementation): the
//! first two terms form the dense in-batch block `A_in` (self loop +
//! scaled sampled edges whose other end is in the batch), everything
//! else is folded into the host-precomputed `Hc_l`; sampled neighbors
//! *outside* the batch also contribute through `Hc` (their X−H term
//! vanishes — less variance reduction, still unbiased).  Layer 0
//! history is the exact feature matrix, reproducing the AX precompute
//! of §6.2.
//!
//! The O(N·L·F) history store is real memory here — the source of the
//! paper's Table 5/8 contrast — and receptive-field targets shrink with
//! depth, reproducing Table 9's superlinear depth scaling.

use anyhow::{anyhow, Result};

use crate::coordinator::checkpoint::HistorySection;
use crate::coordinator::source::{epoch_rng, SourceStats};
use crate::coordinator::trainer::TrainResult;
use crate::graph::{Dataset, Split};
use crate::norm::{NormCache, NormConfig};
use crate::runtime::{Backend, ModelSpec, Tensor, VrgcnAdj, VrgcnBatch};
use crate::session::{NullObserver, Observer, TrainConfig};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct VrgcnParams {
    /// sampled neighbors per node (the paper uses r = 2).
    pub r: usize,
    /// target nodes per batch at depth 2; deeper nets shrink targets so
    /// the sampled receptive field still fits b_max.
    pub batch: usize,
}

impl Default for VrgcnParams {
    fn default() -> Self {
        VrgcnParams { r: 2, batch: 256 }
    }
}

/// Historical activations: layers 1..L-1 (layer 0 == features, exact).
pub struct History {
    /// [layer][node * f_hid + j]
    layers: Vec<Vec<f32>>,
    pub f_hid: usize,
    n: usize,
}

impl History {
    pub fn new(n: usize, f_hid: usize, hidden_layers: usize) -> History {
        History {
            layers: vec![vec![0f32; n * f_hid]; hidden_layers],
            f_hid,
            n,
        }
    }

    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| l.len() * 4).sum()
    }

    fn row(&self, layer: usize, v: usize) -> &[f32] {
        &self.layers[layer][v * self.f_hid..(v + 1) * self.f_hid]
    }

    fn set_row(&mut self, layer: usize, v: usize, data: &[f32]) {
        self.layers[layer][v * self.f_hid..(v + 1) * self.f_hid]
            .copy_from_slice(data);
    }

    /// Snapshot for a versioned (`CGCNCKP2`) checkpoint — the store the
    /// estimator's fidelity lives on, so an interrupted run can resume
    /// as a bitwise replay.
    pub fn section(&self) -> HistorySection {
        HistorySection {
            f_hid: self.f_hid,
            n: self.n,
            layers: self.layers.clone(),
        }
    }

    /// Restore from a checkpointed section; errors on any shape
    /// mismatch with this run's model/dataset.
    pub fn restore(&mut self, sec: &HistorySection) -> Result<()> {
        if sec.f_hid != self.f_hid || sec.n != self.n || sec.layers.len() != self.layers.len() {
            return Err(anyhow!(
                "history section is {} layers × {} nodes × {} hidden, this run \
                 needs {} × {} × {}",
                sec.layers.len(),
                sec.n,
                sec.f_hid,
                self.layers.len(),
                self.n,
                self.f_hid
            ));
        }
        for (dst, src) in self.layers.iter_mut().zip(&sec.layers) {
            if dst.len() != src.len() {
                return Err(anyhow!("history layer length mismatch"));
            }
            dst.copy_from_slice(src);
        }
        Ok(())
    }
}

/// VR-GCN's batch producer: per epoch, shuffled target batches; per
/// step, the sampled receptive union, the scaled in-batch `A_in`, and
/// the historical contributions `Hc_l` assembled into a [`VrgcnBatch`].
/// Unlike the [`crate::coordinator::source::BatchSource`] methods, this
/// source is **stateful across steps** — assembly reads the history its
/// own steps refresh — so the [`crate::session::Driver`] runs it inline
/// (no lookahead, no sharding) and calls [`VrgcnSource::refresh`] with
/// each step's returned hidden activations.
pub struct VrgcnSource<'a> {
    ds: &'a Dataset,
    params: VrgcnParams,
    layers: usize,
    b_max: usize,
    f_in: usize,
    f_hid: usize,
    classes: usize,
    norm: NormConfig,
    seed: u64,
    targets_per_batch: usize,
    layer_dims: Vec<usize>,
    history: History,
    train_nodes: Vec<u32>,
    rng: Rng,
    batches: Vec<Vec<u32>>,
    // reusable per-step buffers
    local_of: Vec<u32>,
    sampled: Vec<Vec<u32>>,
    nodes: Vec<u32>,
    /// the one reused batch: tensors and CSR buffers keep their
    /// allocations across steps (no dense `b_max²` block anywhere).
    vb: Option<VrgcnBatch>,
    /// per-row accumulator of the CSR `A_in` build (`b_max` long).
    acc: Vec<f32>,
    /// columns touched by the current row's build.
    touched: Vec<u32>,
    /// rows of the reused batch tensors the previous assembly wrote.
    dirty: usize,
    max_bytes: usize,
}

impl<'a> VrgcnSource<'a> {
    /// Source over `ds` shaped by `spec`, targets sized depth-aware so
    /// the sampled receptive field fits `b_max` (receptive field ~
    /// batch · (1+r)^(L-1), reproducing Table 9's scaling).
    pub fn new(
        ds: &'a Dataset,
        spec: &ModelSpec,
        params: VrgcnParams,
        norm: NormConfig,
        seed: u64,
    ) -> VrgcnSource<'a> {
        let l = spec.layers;
        let growth = (1 + params.r).pow(l.saturating_sub(1) as u32) as usize;
        let targets_per_batch = (spec.b_max / growth.max(1)).clamp(16, params.batch);
        VrgcnSource {
            ds,
            layers: l,
            b_max: spec.b_max,
            f_in: ds.f_in,
            f_hid: spec.f_hid,
            classes: ds.num_classes,
            norm,
            seed,
            targets_per_batch,
            layer_dims: spec.layer_in_dims(),
            history: History::new(ds.n(), spec.f_hid, l - 1),
            train_nodes: ds.nodes_in_split(Split::Train),
            rng: Rng::new(seed),
            batches: Vec::new(),
            local_of: vec![u32::MAX; ds.n()],
            sampled: Vec::new(),
            nodes: Vec::new(),
            vb: None,
            acc: Vec::new(),
            touched: Vec::new(),
            dirty: 0,
            max_bytes: 0,
            params,
        }
    }

    /// Snapshot the history store for a versioned checkpoint (see
    /// [`crate::coordinator::checkpoint`]).
    pub fn history_section(&self) -> HistorySection {
        self.history.section()
    }

    /// Restore a checkpointed history store before the first epoch, so
    /// a resumed run replays the interrupted one bit for bit.  Errors on
    /// shape mismatch with this run's model/dataset.
    pub fn restore_history(&mut self, sec: &HistorySection) -> Result<()> {
        self.history.restore(sec)
    }

    /// Start epoch `epoch` (1-based); returns the batch count.  The
    /// target-batch stream is a pure function of `(seed, epoch)`.
    pub fn begin_epoch(&mut self, epoch: usize) -> usize {
        self.rng = epoch_rng(self.seed, 0x7766_5544_3322_1100, epoch);
        self.batches = super::expansion::target_batches(
            &self.train_nodes,
            self.targets_per_batch,
            &mut self.rng,
        );
        self.batches.len()
    }

    /// Batches in the current epoch's plan.
    pub fn len(&self) -> usize {
        self.batches.len()
    }

    /// True when the current epoch has no batches.
    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }

    /// Assemble batch `i` of the current epoch: the sampled receptive
    /// union, the **CSR** `A_in` (diagonal inline — no dense `b_max²`
    /// block is ever built), the `Hc_l` contributions (through `cache`'s
    /// normalized adjacency, computed once per run), features, labels,
    /// and the target mask.  Everything is written into one reused
    /// [`VrgcnBatch`], clearing only the rows the previous step dirtied
    /// — steady-state assembly allocates nothing.  The returned batch
    /// stays valid until the next `assemble`.
    pub fn assemble(&mut self, i: usize, cache: &mut NormCache) -> &VrgcnBatch {
        // clear the previous batch's local-id map
        for &v in &self.nodes {
            self.local_of[v as usize] = u32::MAX;
        }
        self.nodes.clear();

        let ds = self.ds;
        let (l, b_max) = (self.layers, self.b_max);
        let targets = &self.batches[i];
        let adj_idx = cache.ensure(&ds.graph, self.norm);
        let adj = cache.get(adj_idx);
        let (avals, aself) = (&adj.vals, &adj.self_loop);

        // ---- receptive union: targets + r-sampled per hop -------------
        let local_of = &mut self.local_of;
        let nodes = &mut self.nodes;
        for &t in targets {
            if local_of[t as usize] == u32::MAX {
                local_of[t as usize] = nodes.len() as u32;
                nodes.push(t);
            }
        }
        let mut frontier = nodes.clone();
        'expand: for _hop in 1..l {
            let mut next = Vec::new();
            for &v in &frontier {
                let nbrs = ds.graph.neighbors(v as usize);
                if nbrs.is_empty() {
                    continue;
                }
                for _ in 0..self.params.r {
                    let u = nbrs[self.rng.usize_below(nbrs.len())];
                    if local_of[u as usize] == u32::MAX {
                        if nodes.len() >= b_max {
                            break 'expand;
                        }
                        local_of[u as usize] = nodes.len() as u32;
                        nodes.push(u);
                        next.push(u);
                    }
                }
            }
            frontier = next;
        }
        let b_real = nodes.len();

        // ---- per-node neighbor samples (shared across layers) ---------
        self.sampled.clear();
        for &v in nodes.iter() {
            let nbrs = ds.graph.neighbors(v as usize);
            let mut s: Vec<u32> = Vec::with_capacity(self.params.r);
            if nbrs.len() <= self.params.r {
                s.extend_from_slice(nbrs);
            } else {
                for idx in self.rng.sample_distinct(nbrs.len(), self.params.r) {
                    s.push(nbrs[idx]);
                }
            }
            self.sampled.push(s);
        }

        // ---- reused batch shell (allocated once, first assemble) ------
        let mut vb = match self.vb.take() {
            Some(vb) => vb,
            None => VrgcnBatch {
                a_in: VrgcnAdj::new(),
                hcs: self
                    .layer_dims
                    .iter()
                    .map(|&fd| Tensor::zeros(vec![b_max, fd]))
                    .collect(),
                x: Tensor::zeros(vec![b_max, self.f_in]),
                y: Tensor::zeros(vec![b_max, self.classes]),
                mask: Tensor::zeros(vec![b_max]),
                n_real: 0,
            },
        };
        let prev = self.dirty;
        let clear = prev.max(b_real);

        // ---- A_in: self loops + scaled sampled in-batch edges, built
        // directly in CSR form (diagonal inline, columns ascending) ----
        {
            let a_in = &mut vb.a_in;
            a_in.offsets.clear();
            a_in.offsets.push(0);
            a_in.cols.clear();
            a_in.vals.clear();
            if self.acc.len() < b_max {
                self.acc.resize(b_max, 0.0);
            }
            let acc = &mut self.acc;
            let touched = &mut self.touched;
            for (li, &v) in nodes.iter().enumerate() {
                let v = v as usize;
                touched.clear();
                acc[li] = aself[v];
                touched.push(li as u32);
                let s = &self.sampled[li];
                if !s.is_empty() {
                    let scale = ds.graph.degree(v) as f32 / s.len() as f32;
                    for &u in s {
                        let lu = local_of[u as usize];
                        if lu == u32::MAX {
                            continue;
                        }
                        // Â_vu looked up via the sorted adjacency
                        let pos = ds.graph.neighbors(v)
                            .binary_search(&u)
                            .expect("sampled neighbor");
                        let add = scale * avals[ds.graph.offsets[v] + pos];
                        if add == 0.0 {
                            continue;
                        }
                        let lu_i = lu as usize;
                        if acc[lu_i] == 0.0 {
                            touched.push(lu);
                        }
                        acc[lu_i] += add;
                    }
                }
                touched.sort_unstable();
                for &c in touched.iter() {
                    a_in.cols.push(c);
                    a_in.vals.push(acc[c as usize]);
                    acc[c as usize] = 0.0;
                }
                a_in.offsets.push(a_in.cols.len());
            }
        }

        // ---- Hc_l = Â·H_l (full) − scaled-sampled in-batch Â·H_l ------
        for (layer, hc) in vb.hcs.iter_mut().enumerate() {
            let fd = self.layer_dims[layer];
            hc.data[..clear * fd].fill(0.0);
            let history = &self.history;
            let hist_row = |u: usize| -> &[f32] {
                if layer == 0 {
                    ds.feature_row(u)
                } else {
                    history.row(layer - 1, u)
                }
            };
            for (li, &v) in nodes.iter().enumerate() {
                let v = v as usize;
                let out = &mut hc.data[li * fd..(li + 1) * fd];
                for (pos, &u) in ds.graph.neighbors(v).iter().enumerate() {
                    let a = avals[ds.graph.offsets[v] + pos];
                    let h = hist_row(u as usize);
                    for j in 0..fd {
                        out[j] += a * h[j];
                    }
                }
                // subtract the sampled in-batch part (it is covered by
                // A_in against *current* X)
                let s = &self.sampled[li];
                if s.is_empty() {
                    continue;
                }
                let scale = ds.graph.degree(v) as f32 / s.len() as f32;
                for &u in s {
                    if local_of[u as usize] != u32::MAX {
                        let pos = ds.graph.neighbors(v)
                            .binary_search(&u)
                            .unwrap();
                        let a = scale * avals[ds.graph.offsets[v] + pos];
                        let h = hist_row(u as usize);
                        for j in 0..fd {
                            out[j] -= a * h[j];
                        }
                    }
                }
            }
        }

        // ---- X, Y, mask (targets only); only stale rows cleared -------
        let (f_in, classes) = (self.f_in, self.classes);
        if prev > b_real {
            vb.x.data[b_real * f_in..prev * f_in].fill(0.0);
            vb.y.data[b_real * classes..prev * classes].fill(0.0);
        }
        for (li, &v) in nodes.iter().enumerate() {
            let v = v as usize;
            vb.x.data[li * f_in..(li + 1) * f_in].copy_from_slice(ds.feature_row(v));
            ds.labels
                .write_row(v, classes, &mut vb.y.data[li * classes..(li + 1) * classes]);
        }
        vb.mask.data[..prev].fill(0.0);
        for m in vb.mask.data.iter_mut().take(targets.len().min(b_real)) {
            *m = 1.0;
        }

        vb.n_real = b_real;
        self.dirty = b_real;
        self.max_bytes = self.max_bytes.max(vb.bytes() + self.history.bytes());
        self.vb = Some(vb);
        self.vb.as_ref().expect("batch just stored")
    }

    /// Refresh the history store with the hidden activations the step
    /// just returned (rows indexed by the current batch's union).
    pub fn refresh(&mut self, hiddens: &[Tensor]) {
        for (layer, h) in hiddens.iter().enumerate() {
            for (li, &v) in self.nodes.iter().enumerate() {
                self.history.set_row(
                    layer,
                    v as usize,
                    &h.data[li * self.f_hid..(li + 1) * self.f_hid],
                );
            }
        }
    }

    /// Accounting for the driver's result packaging (batch + history
    /// bytes; the driver adds the parameter/optimizer bytes).
    pub fn stats(&self) -> SourceStats {
        SourceStats { max_batch_bytes: self.max_bytes, utilization: 0.0 }
    }
}

/// Train VR-GCN through a vrgcn-kind model on any backend.  Thin
/// wrapper over [`train_vrgcn_observed`] with no observer attached.
pub fn train_vrgcn(
    backend: &mut dyn Backend,
    ds: &Dataset,
    model: &str,
    params: &VrgcnParams,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    train_vrgcn_observed(backend, ds, model, params, cfg, &mut NullObserver)
}

/// [`train_vrgcn`] with an observer.  Pre-driver compatibility entry:
/// builds a [`crate::session::Driver`] over a [`VrgcnSource`] and
/// drains it.  The config's model-shape fields are inert here — the
/// driver reads shapes from the backend's [`ModelSpec`].
pub fn train_vrgcn_observed(
    backend: &mut dyn Backend,
    ds: &Dataset,
    model: &str,
    params: &VrgcnParams,
    cfg: &TrainConfig,
    obs: &mut dyn Observer,
) -> Result<TrainResult> {
    use crate::session::driver::{BackendSlot, Driver, DriverSource};

    let spec = backend.model_spec(model)?;
    let cfg = cfg.clone();
    let source = VrgcnSource::new(ds, &spec, params.clone(), cfg.norm, cfg.seed);
    let mut driver = Driver::from_parts(
        BackendSlot::Borrowed(backend),
        ds,
        model.to_string(),
        cfg,
        DriverSource::Vrgcn(source),
        None,
    )?;
    driver.drive(obs)?;
    driver.into_result()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_rows() {
        let mut h = History::new(10, 4, 2);
        h.set_row(0, 3, &[1., 2., 3., 4.]);
        h.set_row(1, 3, &[5., 6., 7., 8.]);
        assert_eq!(h.row(0, 3), &[1., 2., 3., 4.]);
        assert_eq!(h.row(1, 3), &[5., 6., 7., 8.]);
        assert_eq!(h.row(0, 2), &[0.0; 4]);
        assert_eq!(h.bytes(), 2 * 10 * 4 * 4);
    }

    /// The sparse-native assembly contract: (a) the reused batch keeps
    /// its tensor allocations across steps (no dense `b_max²` block is
    /// ever built — the adjacency is CSR end to end), (b) every row
    /// carries its inline diagonal with strictly ascending columns and
    /// no stored zeros, (c) dirty-row clearing leaves the padding
    /// region exactly zero (the PJRT executable reads the full padded
    /// tensors), and (d) assembly is a pure function of the
    /// `(seed, epoch)` stream — a second source replays it exactly.
    #[test]
    fn assemble_reuses_buffers_and_matches_fresh_source() {
        use crate::norm::NormConfig;

        let ds = crate::datagen::build(crate::datagen::preset("cora_like").unwrap(), 5);
        let spec = ModelSpec::gcn(ds.task, 2, ds.f_in, 16, ds.num_classes, 256);
        let params = VrgcnParams { r: 2, batch: 48 };
        let norm = NormConfig::PAPER_DEFAULT;
        let mut src = VrgcnSource::new(&ds, &spec, params.clone(), norm, 9);
        let mut fresh = VrgcnSource::new(&ds, &spec, params, norm, 9);
        let mut cache = NormCache::new();
        let mut cache2 = NormCache::new();
        let n_b = src.begin_epoch(1);
        assert_eq!(fresh.begin_epoch(1), n_b);
        assert!(n_b >= 2, "need several batches to exercise reuse");

        let mut ptrs = None;
        for i in 0..n_b.min(4) {
            let va = src.assemble(i, &mut cache);
            assert!(va.n_real > 0);
            assert_eq!(va.a_in.n(), va.n_real);
            for u in 0..va.n_real {
                let row = &va.a_in.cols[va.a_in.offsets[u]..va.a_in.offsets[u + 1]];
                assert!(
                    row.windows(2).all(|w| w[0] < w[1]),
                    "batch {i} row {u}: columns not strictly ascending"
                );
                assert!(
                    row.binary_search(&(u as u32)).is_ok(),
                    "batch {i} row {u}: inline diagonal missing"
                );
            }
            assert!(
                va.a_in.vals.iter().all(|&v| v != 0.0),
                "batch {i}: stored zero entry"
            );
            // padding rows stay exactly zero across reuse
            let nr = va.n_real;
            assert!(
                va.x.data[nr * ds.f_in..].iter().all(|&v| v == 0.0),
                "batch {i}: stale x padding"
            );
            assert!(
                va.y.data[nr * ds.num_classes..].iter().all(|&v| v == 0.0),
                "batch {i}: stale y padding"
            );
            assert!(
                va.mask.data[nr..].iter().all(|&v| v == 0.0),
                "batch {i}: stale mask padding"
            );
            for (l, hc) in va.hcs.iter().enumerate() {
                let fd = hc.dims[1];
                assert!(
                    hc.data[nr * fd..].iter().all(|&v| v == 0.0),
                    "batch {i}: stale hc padding in layer {l}"
                );
            }
            match ptrs {
                None => {
                    ptrs = Some((
                        va.x.data.as_ptr(),
                        va.y.data.as_ptr(),
                        va.mask.data.as_ptr(),
                        va.hcs[0].data.as_ptr(),
                    ))
                }
                Some(p) => {
                    assert_eq!(p.0, va.x.data.as_ptr(), "x reallocated at batch {i}");
                    assert_eq!(p.1, va.y.data.as_ptr(), "y reallocated at batch {i}");
                    assert_eq!(p.2, va.mask.data.as_ptr(), "mask reallocated at batch {i}");
                    assert_eq!(p.3, va.hcs[0].data.as_ptr(), "hc reallocated at batch {i}");
                }
            }
            let vf = fresh.assemble(i, &mut cache2);
            assert_eq!(va.n_real, vf.n_real, "batch {i}");
            assert_eq!(va.a_in.offsets, vf.a_in.offsets, "batch {i}");
            assert_eq!(va.a_in.cols, vf.a_in.cols, "batch {i}");
            assert_eq!(va.a_in.vals, vf.a_in.vals, "batch {i}");
            assert_eq!(va.x.data, vf.x.data, "batch {i}");
            assert_eq!(va.y.data, vf.y.data, "batch {i}");
            assert_eq!(va.mask.data, vf.mask.data, "batch {i}");
            for (l, (a, b)) in va.hcs.iter().zip(&vf.hcs).enumerate() {
                assert_eq!(a.data, b.data, "batch {i} hc layer {l}");
            }
        }
    }

    #[test]
    fn target_sizing_shrinks_with_depth() {
        // the depth-aware target formula behind Table 9's scaling
        let p = VrgcnParams::default();
        let sized = |l: usize| -> usize {
            let growth = (1 + p.r).pow(l.saturating_sub(1) as u32) as usize;
            (512usize / growth.max(1)).clamp(16, p.batch)
        };
        assert!(sized(2) > sized(4));
        assert!(sized(4) >= sized(6));
        assert_eq!(sized(6), 16); // floor
    }
}
