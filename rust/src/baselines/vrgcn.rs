//! VR-GCN baseline [Chen, Zhu & Song, ICML'18]: control-variate
//! neighbor sampling with historical activations.
//!
//! The estimator per layer is
//!
//!   Z_v = Â_vv·X_v + Σ_{u∈S(v)} (d_v/|S(v)|)·Â_vu·(X_u − H_u)
//!        + Σ_{u∈N(v)} Â_vu·H_u
//!
//! with S(v) the r sampled neighbors and H the *historical* activations
//! of the previous layer.  Mapping onto the backend's `vrgcn_step`
//! (PJRT `model.vrgcn_train_step` or the host implementation): the
//! first two terms form the dense in-batch block `A_in` (self loop +
//! scaled sampled edges whose other end is in the batch), everything
//! else is folded into the host-precomputed `Hc_l`; sampled neighbors
//! *outside* the batch also contribute through `Hc` (their X−H term
//! vanishes — less variance reduction, still unbiased).  Layer 0
//! history is the exact feature matrix, reproducing the AX precompute
//! of §6.2.
//!
//! The O(N·L·F) history store is real memory here — the source of the
//! paper's Table 5/8 contrast — and receptive-field targets shrink with
//! depth, reproducing Table 9's superlinear depth scaling.

use anyhow::Result;

use crate::coordinator::trainer::{
    evaluate_cached, CurvePoint, TrainOptions, TrainResult, TrainState,
};
use crate::graph::{Dataset, Split};
use crate::norm::NormCache;
use crate::runtime::{Backend, Tensor, VrgcnBatch};
use crate::session::{Event, NullObserver, Observer};
use crate::util::{Rng, Timer};

#[derive(Clone, Debug)]
pub struct VrgcnParams {
    /// sampled neighbors per node (the paper uses r = 2).
    pub r: usize,
    /// target nodes per batch at depth 2; deeper nets shrink targets so
    /// the sampled receptive field still fits b_max.
    pub batch: usize,
}

impl Default for VrgcnParams {
    fn default() -> Self {
        VrgcnParams { r: 2, batch: 256 }
    }
}

/// Historical activations: layers 1..L-1 (layer 0 == features, exact).
pub struct History {
    /// [layer][node * f_hid + j]
    layers: Vec<Vec<f32>>,
    pub f_hid: usize,
}

impl History {
    pub fn new(n: usize, f_hid: usize, hidden_layers: usize) -> History {
        History {
            layers: vec![vec![0f32; n * f_hid]; hidden_layers],
            f_hid,
        }
    }

    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| l.len() * 4).sum()
    }

    fn row(&self, layer: usize, v: usize) -> &[f32] {
        &self.layers[layer][v * self.f_hid..(v + 1) * self.f_hid]
    }

    fn set_row(&mut self, layer: usize, v: usize, data: &[f32]) {
        self.layers[layer][v * self.f_hid..(v + 1) * self.f_hid]
            .copy_from_slice(data);
    }
}

/// Train VR-GCN through a vrgcn-kind model on any backend.  Thin
/// wrapper over [`train_vrgcn_observed`] with no observer attached.
pub fn train_vrgcn(
    backend: &mut dyn Backend,
    ds: &Dataset,
    model: &str,
    params: &VrgcnParams,
    opts: &TrainOptions,
) -> Result<TrainResult> {
    train_vrgcn_observed(backend, ds, model, params, opts, &mut NullObserver)
}

/// [`train_vrgcn`] with an observer.
pub fn train_vrgcn_observed(
    backend: &mut dyn Backend,
    ds: &Dataset,
    model: &str,
    params: &VrgcnParams,
    opts: &TrainOptions,
    obs: &mut dyn Observer,
) -> Result<TrainResult> {
    let spec = backend.model_spec(model)?;
    backend.prepare(model)?;
    let l = spec.layers;
    let b_max = spec.b_max;
    let n = ds.n();
    let f_in = ds.f_in;
    let f_hid = spec.f_hid;
    let classes = ds.num_classes;

    // depth-aware target size: receptive field ~ batch * (1+r)^(L-1)
    let growth = (1 + params.r).pow(l.saturating_sub(1) as u32) as usize;
    let targets_per_batch = (b_max / growth.max(1)).clamp(16, params.batch);

    let mut state = TrainState::init(&spec, opts.seed);
    let mut history = History::new(n, f_hid, l - 1);
    // one normalization for the whole run, shared with every eval
    let mut norm_cache = NormCache::new();
    let adj_idx = norm_cache.ensure(&ds.graph, opts.norm);
    let mut rng = Rng::new(opts.seed ^ 0x7766_5544_3322_1100);
    let train_nodes = ds.nodes_in_split(Split::Train);
    let eval_nodes = ds.nodes_in_split(opts.eval_split);

    let mut curve = Vec::new();
    let mut train_seconds = 0.0;
    let mut steps_done = 0u64;
    let mut peak_bytes = 0usize;

    // reusable buffers
    let mut local_of = vec![u32::MAX; n];
    let mut sampled: Vec<Vec<u32>> = Vec::new();

    for epoch in 1..=opts.epochs {
        let timer = Timer::start();
        let batches =
            super::expansion::target_batches(&train_nodes, targets_per_batch, &mut rng);
        let mut epoch_loss = 0.0;
        let mut nb = 0usize;
        for targets in &batches {
            if opts.max_steps_per_epoch > 0 && nb >= opts.max_steps_per_epoch {
                break;
            }
            let adj = norm_cache.get(adj_idx);
            let (avals, aself) = (&adj.vals, &adj.self_loop);
            // ---- receptive union: targets + r-sampled per hop ---------
            let mut nodes: Vec<u32> = Vec::new();
            for &t in targets {
                if local_of[t as usize] == u32::MAX {
                    local_of[t as usize] = nodes.len() as u32;
                    nodes.push(t);
                }
            }
            let mut frontier = nodes.clone();
            'expand: for _hop in 1..l {
                let mut next = Vec::new();
                for &v in &frontier {
                    let nbrs = ds.graph.neighbors(v as usize);
                    if nbrs.is_empty() {
                        continue;
                    }
                    for _ in 0..params.r {
                        let u = nbrs[rng.usize_below(nbrs.len())];
                        if local_of[u as usize] == u32::MAX {
                            if nodes.len() >= b_max {
                                break 'expand;
                            }
                            local_of[u as usize] = nodes.len() as u32;
                            nodes.push(u);
                            next.push(u);
                        }
                    }
                }
                frontier = next;
            }
            let b_real = nodes.len();

            // ---- per-node neighbor samples (shared across layers) -----
            sampled.clear();
            for &v in &nodes {
                let nbrs = ds.graph.neighbors(v as usize);
                let mut s: Vec<u32> = Vec::with_capacity(params.r);
                if nbrs.len() <= params.r {
                    s.extend_from_slice(nbrs);
                } else {
                    for idx in rng.sample_distinct(nbrs.len(), params.r) {
                        s.push(nbrs[idx]);
                    }
                }
                sampled.push(s);
            }

            // ---- A_in: self loops + scaled sampled in-batch edges ------
            let mut a_in = Tensor::zeros(vec![b_max, b_max]);
            for (li, &v) in nodes.iter().enumerate() {
                let v = v as usize;
                a_in.data[li * b_max + li] = aself[v];
                let deg = ds.graph.degree(v);
                let s = &sampled[li];
                if s.is_empty() {
                    continue;
                }
                let scale = deg as f32 / s.len() as f32;
                for &u in s {
                    let lu = local_of[u as usize];
                    if lu != u32::MAX {
                        // Â_vu looked up via the sorted adjacency
                        let pos = ds.graph.neighbors(v)
                            .binary_search(&u)
                            .expect("sampled neighbor");
                        a_in.data[li * b_max + lu as usize] +=
                            scale * avals[ds.graph.offsets[v] + pos];
                    }
                }
            }

            // ---- Hc_l = Â·H_l (full) − scaled-sampled in-batch Â·H_l ---
            let dims = spec.layer_in_dims();
            let mut hcs: Vec<Tensor> = Vec::with_capacity(l);
            for (layer, &fd) in dims.iter().enumerate() {
                let mut hc = Tensor::zeros(vec![b_max, fd]);
                let hist_row = |u: usize| -> &[f32] {
                    if layer == 0 {
                        ds.feature_row(u)
                    } else {
                        history.row(layer - 1, u)
                    }
                };
                for (li, &v) in nodes.iter().enumerate() {
                    let v = v as usize;
                    let out = &mut hc.data[li * fd..(li + 1) * fd];
                    for (pos, &u) in ds.graph.neighbors(v).iter().enumerate() {
                        let a = avals[ds.graph.offsets[v] + pos];
                        let h = hist_row(u as usize);
                        for j in 0..fd {
                            out[j] += a * h[j];
                        }
                    }
                    // subtract the sampled in-batch part (it is covered
                    // by A_in against *current* X)
                    let s = &sampled[li];
                    if s.is_empty() {
                        continue;
                    }
                    let scale = ds.graph.degree(v) as f32 / s.len() as f32;
                    for &u in s {
                        if local_of[u as usize] != u32::MAX {
                            let pos = ds.graph.neighbors(v)
                                .binary_search(&u)
                                .unwrap();
                            let a = scale * avals[ds.graph.offsets[v] + pos];
                            let h = hist_row(u as usize);
                            for j in 0..fd {
                                out[j] -= a * h[j];
                            }
                        }
                    }
                }
                hcs.push(hc);
            }

            // ---- X, Y, mask (targets only) -----------------------------
            let mut x = Tensor::zeros(vec![b_max, f_in]);
            let mut y = Tensor::zeros(vec![b_max, classes]);
            let mut mask = Tensor::zeros(vec![b_max]);
            for (li, &v) in nodes.iter().enumerate() {
                let v = v as usize;
                x.data[li * f_in..(li + 1) * f_in].copy_from_slice(ds.feature_row(v));
                ds.labels.write_row(v, classes, &mut y.data[li * classes..(li + 1) * classes]);
            }
            for i in 0..targets.len().min(b_real) {
                mask.data[i] = 1.0;
            }

            // ---- execute on the backend -------------------------------
            let vb = VrgcnBatch { a_in, hcs, x, y, mask, n_real: b_real };
            peak_bytes = peak_bytes
                .max(vb.bytes() + state.param_bytes() + history.bytes());
            let (loss, hiddens) = backend.vrgcn_step(model, &mut state, opts.lr, &vb)?;

            // ---- history refresh ---------------------------------------
            for (layer, h) in hiddens.iter().enumerate() {
                for (li, &v) in nodes.iter().enumerate() {
                    history.set_row(layer, v as usize,
                                    &h.data[li * f_hid..(li + 1) * f_hid]);
                }
            }

            // reset local map
            for &v in &nodes {
                local_of[v as usize] = u32::MAX;
            }
            epoch_loss += loss as f64;
            nb += 1;
            steps_done += 1;
        }
        train_seconds += timer.secs();
        obs.on_event(&Event::EpochEnd {
            epoch,
            train_seconds,
            mean_loss: epoch_loss / nb.max(1) as f64,
        });

        let do_eval = (opts.eval_every > 0 && epoch % opts.eval_every == 0)
            || epoch == opts.epochs;
        if do_eval {
            let f1 = evaluate_cached(
                ds, &state.weights, opts.norm, false, &eval_nodes, &mut norm_cache,
            );
            curve.push(CurvePoint {
                epoch,
                train_seconds,
                train_loss: epoch_loss / nb.max(1) as f64,
                eval_f1: f1,
            });
            obs.on_event(&Event::Eval { point: curve.last().unwrap() });
        }
    }

    Ok(TrainResult {
        state,
        curve,
        train_seconds,
        steps: steps_done,
        peak_bytes,
        avg_within_edges_per_node: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_rows() {
        let mut h = History::new(10, 4, 2);
        h.set_row(0, 3, &[1., 2., 3., 4.]);
        h.set_row(1, 3, &[5., 6., 7., 8.]);
        assert_eq!(h.row(0, 3), &[1., 2., 3., 4.]);
        assert_eq!(h.row(1, 3), &[5., 6., 7., 8.]);
        assert_eq!(h.row(0, 2), &[0.0; 4]);
        assert_eq!(h.bytes(), 2 * 10 * 4 * 4);
    }

    #[test]
    fn target_sizing_shrinks_with_depth() {
        // the depth-aware target formula behind Table 9's scaling
        let p = VrgcnParams::default();
        let sized = |l: usize| -> usize {
            let growth = (1 + p.r).pow(l.saturating_sub(1) as u32) as usize;
            (512usize / growth.max(1)).clamp(16, p.batch)
        };
        assert!(sized(2) > sized(4));
        assert!(sized(4) >= sized(6));
        assert_eq!(sized(6), 16); // floor
    }
}
