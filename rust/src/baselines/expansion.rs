//! Vanilla mini-batch SGD with exact neighborhood expansion (§3 of the
//! paper: the method whose per-epoch cost is O(d^L) per node).  A batch
//! is a random set of target training nodes plus their full L-hop
//! neighborhood; only targets contribute to the loss.
//!
//! The exploding receptive field is the point: `expand` reports the
//! per-hop frontier sizes (the embedding-computation counters behind
//! Table 1 / Table 9), and the batch only fits the model's `b_max`
//! for shallow networks or tiny targets — exactly the paper's argument.

use crate::graph::Csr;
use crate::util::Rng;

/// Result of an L-hop expansion from `targets`.
pub struct Expansion {
    /// union of targets + all hops, in discovery order (targets first).
    pub nodes: Vec<u32>,
    /// cumulative union size after each hop (index 0 = |targets|).
    pub frontier_sizes: Vec<usize>,
    /// true if the expansion was truncated by the cap.
    pub truncated: bool,
}

/// Expand `hops` levels of full neighborhoods, capping the union at
/// `cap` nodes (discovery order keeps the cap deterministic).
pub fn expand(g: &Csr, targets: &[u32], hops: usize, cap: usize) -> Expansion {
    let mut in_set = vec![false; g.n()];
    let mut nodes: Vec<u32> = Vec::with_capacity(targets.len() * 4);
    for &t in targets {
        if !in_set[t as usize] {
            in_set[t as usize] = true;
            nodes.push(t);
        }
    }
    let mut frontier_sizes = vec![nodes.len()];
    let mut truncated = false;
    let mut frontier: Vec<u32> = nodes.clone();
    for _ in 0..hops {
        let mut next = Vec::new();
        'hop: for &v in &frontier {
            for &u in g.neighbors(v as usize) {
                if !in_set[u as usize] {
                    if nodes.len() >= cap {
                        truncated = true;
                        break 'hop;
                    }
                    in_set[u as usize] = true;
                    nodes.push(u);
                    next.push(u);
                }
            }
        }
        frontier_sizes.push(nodes.len());
        if truncated {
            break;
        }
        frontier = next;
    }
    Expansion { nodes, frontier_sizes, truncated }
}

/// Random target batches over the training nodes: one epoch = shuffled
/// training nodes sliced into chunks of `batch`.
pub fn target_batches(train_nodes: &[u32], batch: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    let mut order = train_nodes.to_vec();
    rng.shuffle(&mut order);
    order.chunks(batch).map(|c| c.to_vec()).collect()
}

/// Embedding computations per batch in our dense-block realization:
/// every batch node gets an embedding at every layer.
pub fn embeddings_computed(union: usize, layers: usize) -> usize {
    union * layers
}

/// Train with vanilla neighborhood-expansion SGD through a plain
/// train-kind model on any backend.  Thin wrapper over
/// [`train_expansion_observed`] with no observer attached.
pub fn train_expansion(
    backend: &mut dyn crate::runtime::Backend,
    ds: &crate::graph::Dataset,
    model: &str,
    targets_per_batch: usize,
    opts: &crate::coordinator::trainer::TrainOptions,
) -> anyhow::Result<crate::coordinator::trainer::TrainResult> {
    train_expansion_observed(
        backend,
        ds,
        model,
        targets_per_batch,
        opts,
        &mut crate::session::NullObserver,
    )
}

/// [`train_expansion`] with an observer.  Targets per batch are sized
/// so the full L-hop expansion usually fits `b_max`; overflowing unions
/// are capped (and counted), which *underestimates* vanilla SGD's true
/// cost — i.e. the comparison is conservative in the baseline's favor.
pub fn train_expansion_observed(
    backend: &mut dyn crate::runtime::Backend,
    ds: &crate::graph::Dataset,
    model: &str,
    targets_per_batch: usize,
    opts: &crate::coordinator::trainer::TrainOptions,
    obs: &mut dyn crate::session::Observer,
) -> anyhow::Result<crate::coordinator::trainer::TrainResult> {
    use crate::coordinator::batch::BatchAssembler;
    use crate::coordinator::trainer::{evaluate_cached, CurvePoint, TrainResult, TrainState};
    use crate::graph::Split;
    use crate::norm::NormCache;
    use crate::session::Event;
    use crate::util::Timer;

    let spec = backend.model_spec(model)?;
    backend.prepare(model)?;
    let mut state = TrainState::init(&spec, opts.seed);
    let mut rng = Rng::new(opts.seed ^ 0xE0A5_1011_2233_4455);
    let mut assembler = BatchAssembler::new(ds.n(), spec.b_max, opts.norm);
    let mut batch = assembler.new_batch(ds);
    let mut norm_cache = NormCache::new();
    let train_nodes = ds.nodes_in_split(Split::Train);
    let eval_nodes = ds.nodes_in_split(opts.eval_split);

    let mut curve = Vec::new();
    let mut train_seconds = 0.0;
    let mut steps_done = 0u64;
    let mut peak_bytes = 0usize;
    let mut truncated_batches = 0u64;

    for epoch in 1..=opts.epochs {
        let timer = Timer::start();
        let batches = target_batches(&train_nodes, targets_per_batch, &mut rng);
        let mut epoch_loss = 0.0;
        let mut nb = 0usize;
        for targets in &batches {
            if opts.max_steps_per_epoch > 0 && nb >= opts.max_steps_per_epoch {
                break;
            }
            let exp = expand(&ds.graph, targets, spec.layers, spec.b_max);
            if exp.truncated {
                truncated_batches += 1;
            }
            assembler.assemble_into(ds, &exp.nodes, &mut batch);
            // loss only on the targets (first in local order)
            batch.mask.data.iter_mut().for_each(|m| *m = 0.0);
            for i in 0..targets.len().min(exp.nodes.len()) {
                batch.mask.data[i] = 1.0;
            }
            peak_bytes = peak_bytes.max(
                batch.bytes()
                    + state.param_bytes()
                    + exp.nodes.len() * spec.f_hid * 4 * spec.layers,
            );
            let loss = backend.train_step(model, &mut state, opts.lr, &batch)?;
            epoch_loss += loss as f64;
            nb += 1;
            steps_done += 1;
        }
        train_seconds += timer.secs();
        obs.on_event(&Event::EpochEnd {
            epoch,
            train_seconds,
            mean_loss: epoch_loss / nb.max(1) as f64,
        });
        let do_eval = (opts.eval_every > 0 && epoch % opts.eval_every == 0)
            || epoch == opts.epochs;
        if do_eval {
            let f1 = evaluate_cached(
                ds, &state.weights, opts.norm, spec.residual, &eval_nodes, &mut norm_cache,
            );
            curve.push(CurvePoint {
                epoch,
                train_seconds,
                train_loss: epoch_loss / nb.max(1) as f64,
                eval_f1: f1,
            });
            obs.on_event(&Event::Eval { point: curve.last().unwrap() });
        }
    }
    if truncated_batches > 0 {
        eprintln!(
            "[expansion] {truncated_batches} batches hit the b_max cap \
             (vanilla SGD cost underestimated)"
        );
    }
    Ok(TrainResult {
        state,
        curve,
        train_seconds,
        steps: steps_done,
        peak_bytes,
        avg_within_edges_per_node: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn star_of_paths() -> Csr {
        // hub 0 connected to 1..=5, each i connected to i+5
        let mut e = Vec::new();
        for i in 1..=5u32 {
            e.push((0, i));
            e.push((i, i + 5));
        }
        Csr::from_edges(11, &e)
    }

    #[test]
    fn expansion_grows_by_hops() {
        let g = star_of_paths();
        let e1 = expand(&g, &[0], 1, 1000);
        assert_eq!(e1.frontier_sizes, vec![1, 6]);
        let e2 = expand(&g, &[0], 2, 1000);
        assert_eq!(e2.frontier_sizes, vec![1, 6, 11]);
        assert!(!e2.truncated);
    }

    #[test]
    fn cap_truncates() {
        let g = star_of_paths();
        let e = expand(&g, &[0], 2, 4);
        assert!(e.truncated);
        assert!(e.nodes.len() <= 4);
    }

    #[test]
    fn targets_first_and_unique() {
        let g = star_of_paths();
        let e = expand(&g, &[3, 3, 7], 1, 100);
        assert_eq!(&e.nodes[..2], &[3, 7]);
        let set: std::collections::HashSet<_> = e.nodes.iter().collect();
        assert_eq!(set.len(), e.nodes.len());
    }

    #[test]
    fn batches_cover_all_targets() {
        let train: Vec<u32> = (0..103).collect();
        let mut rng = Rng::new(1);
        let batches = target_batches(&train, 10, &mut rng);
        assert_eq!(batches.len(), 11);
        let mut all: Vec<u32> = batches.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, train);
    }
}
