//! Vanilla mini-batch SGD with exact neighborhood expansion (§3 of the
//! paper: the method whose per-epoch cost is O(d^L) per node).  A batch
//! is a random set of target training nodes plus their full L-hop
//! neighborhood; only targets contribute to the loss.
//!
//! The exploding receptive field is the point: `expand` reports the
//! per-hop frontier sizes (the embedding-computation counters behind
//! Table 1 / Table 9), and the batch only fits the model's `b_max`
//! for shallow networks or tiny targets — exactly the paper's argument.

use crate::graph::Csr;
use crate::util::Rng;

/// Result of an L-hop expansion from `targets`.
pub struct Expansion {
    /// union of targets + all hops, in discovery order (targets first).
    pub nodes: Vec<u32>,
    /// cumulative union size after each hop (index 0 = |targets|).
    pub frontier_sizes: Vec<usize>,
    /// true if the expansion was truncated by the cap.
    pub truncated: bool,
}

/// Expand `hops` levels of full neighborhoods, capping the union at
/// `cap` nodes (discovery order keeps the cap deterministic).
pub fn expand(g: &Csr, targets: &[u32], hops: usize, cap: usize) -> Expansion {
    let mut in_set = vec![false; g.n()];
    let mut nodes: Vec<u32> = Vec::with_capacity(targets.len() * 4);
    for &t in targets {
        if !in_set[t as usize] {
            in_set[t as usize] = true;
            nodes.push(t);
        }
    }
    let mut frontier_sizes = vec![nodes.len()];
    let mut truncated = false;
    let mut frontier: Vec<u32> = nodes.clone();
    for _ in 0..hops {
        let mut next = Vec::new();
        'hop: for &v in &frontier {
            for &u in g.neighbors(v as usize) {
                if !in_set[u as usize] {
                    if nodes.len() >= cap {
                        truncated = true;
                        break 'hop;
                    }
                    in_set[u as usize] = true;
                    nodes.push(u);
                    next.push(u);
                }
            }
        }
        frontier_sizes.push(nodes.len());
        if truncated {
            break;
        }
        frontier = next;
    }
    Expansion { nodes, frontier_sizes, truncated }
}

/// Random target batches over the training nodes: one epoch = shuffled
/// training nodes sliced into chunks of `batch`.
pub fn target_batches(train_nodes: &[u32], batch: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    let mut order = train_nodes.to_vec();
    rng.shuffle(&mut order);
    order.chunks(batch).map(|c| c.to_vec()).collect()
}

/// Embedding computations per batch in our dense-block realization:
/// every batch node gets an embedding at every layer.
pub fn embeddings_computed(union: usize, layers: usize) -> usize {
    union * layers
}

/// [`crate::coordinator::source::BatchSource`] for vanilla
/// neighborhood-expansion SGD: per epoch, the shuffled training nodes
/// sliced into target batches; per batch, the full L-hop expansion
/// (capped at `b_max`, which *underestimates* vanilla SGD's true cost —
/// the comparison is conservative in the baseline's favor) assembled
/// with the loss masked to the targets.
pub struct ExpansionSource<'a> {
    ds: &'a crate::graph::Dataset,
    assembler: crate::coordinator::batch::BatchAssembler,
    layers: usize,
    f_hid: usize,
    targets_per_batch: usize,
    seed: u64,
    train_nodes: Vec<u32>,
    batches: Vec<Vec<u32>>,
    truncated: u64,
    max_batch_bytes: usize,
}

impl<'a> ExpansionSource<'a> {
    /// Source over `ds` shaped by `spec`, `targets_per_batch` targets
    /// per step.
    pub fn new(
        ds: &'a crate::graph::Dataset,
        spec: &crate::runtime::ModelSpec,
        targets_per_batch: usize,
        norm: crate::norm::NormConfig,
        seed: u64,
    ) -> ExpansionSource<'a> {
        ExpansionSource {
            ds,
            assembler: crate::coordinator::batch::BatchAssembler::new(
                ds.n(),
                spec.b_max,
                norm,
            ),
            layers: spec.layers,
            f_hid: spec.f_hid,
            targets_per_batch: targets_per_batch.max(1),
            seed,
            train_nodes: ds.nodes_in_split(crate::graph::Split::Train),
            batches: Vec::new(),
            truncated: 0,
            max_batch_bytes: 0,
        }
    }
}

impl crate::coordinator::source::BatchSource for ExpansionSource<'_> {
    fn shape(&self) -> (usize, usize, usize) {
        (self.assembler.b_max, self.ds.f_in, self.ds.num_classes)
    }

    fn begin_epoch(&mut self, epoch: usize) -> usize {
        let mut rng = crate::coordinator::source::epoch_rng(
            self.seed,
            0xE0A5_1011_2233_4455,
            epoch,
        );
        self.batches =
            target_batches(&self.train_nodes, self.targets_per_batch, &mut rng);
        self.batches.len()
    }

    fn len(&self) -> usize {
        self.batches.len()
    }

    fn assemble(&mut self, i: usize, into: &mut crate::coordinator::batch::Batch) {
        let targets = &self.batches[i];
        let exp = expand(&self.ds.graph, targets, self.layers, self.assembler.b_max);
        if exp.truncated {
            self.truncated += 1;
        }
        self.assembler.assemble_into(self.ds, &exp.nodes, into);
        // loss only on the targets (first in local order)
        let n_targets = targets.len().min(exp.nodes.len());
        into.mask.data.iter_mut().for_each(|m| *m = 0.0);
        for m in into.mask.data.iter_mut().take(n_targets) {
            *m = 1.0;
        }
        into.n_train = n_targets;
        self.max_batch_bytes = self.max_batch_bytes.max(
            into.bytes() + exp.nodes.len() * self.f_hid * 4 * self.layers,
        );
    }

    fn stats(&self) -> crate::coordinator::source::SourceStats {
        crate::coordinator::source::SourceStats {
            max_batch_bytes: self.max_batch_bytes,
            utilization: 0.0,
        }
    }
}

impl Drop for ExpansionSource<'_> {
    fn drop(&mut self) {
        if self.truncated > 0 {
            eprintln!(
                "[expansion] {} batches hit the b_max cap \
                 (vanilla SGD cost underestimated)",
                self.truncated
            );
        }
    }
}

/// Train with vanilla neighborhood-expansion SGD through a plain
/// train-kind model on any backend.  Thin wrapper over
/// [`train_expansion_observed`] with no observer attached.
pub fn train_expansion(
    backend: &mut dyn crate::runtime::Backend,
    ds: &crate::graph::Dataset,
    model: &str,
    targets_per_batch: usize,
    cfg: &crate::session::TrainConfig,
) -> anyhow::Result<crate::coordinator::trainer::TrainResult> {
    train_expansion_observed(
        backend,
        ds,
        model,
        targets_per_batch,
        cfg,
        &mut crate::session::NullObserver,
    )
}

/// [`train_expansion`] with an observer.  Pre-driver compatibility
/// entry: builds a [`crate::session::Driver`] over an
/// [`ExpansionSource`] and drains it.  The config's model-shape fields
/// are inert here — the driver reads shapes from the backend's spec.
pub fn train_expansion_observed(
    backend: &mut dyn crate::runtime::Backend,
    ds: &crate::graph::Dataset,
    model: &str,
    targets_per_batch: usize,
    cfg: &crate::session::TrainConfig,
    obs: &mut dyn crate::session::Observer,
) -> anyhow::Result<crate::coordinator::trainer::TrainResult> {
    use crate::session::driver::{BackendSlot, Driver, DriverSource};

    let spec = backend.model_spec(model)?;
    let cfg = cfg.clone();
    let source = ExpansionSource::new(ds, &spec, targets_per_batch, cfg.norm, cfg.seed);
    let mut backend = crate::runtime::PrefetchBackend::new(backend);
    let mut driver = Driver::from_parts(
        BackendSlot::Borrowed(&mut backend),
        ds,
        model.to_string(),
        cfg,
        DriverSource::Batched(Box::new(source)),
        None,
    )?;
    driver.drive(obs)?;
    driver.into_result()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn star_of_paths() -> Csr {
        // hub 0 connected to 1..=5, each i connected to i+5
        let mut e = Vec::new();
        for i in 1..=5u32 {
            e.push((0, i));
            e.push((i, i + 5));
        }
        Csr::from_edges(11, &e)
    }

    #[test]
    fn expansion_grows_by_hops() {
        let g = star_of_paths();
        let e1 = expand(&g, &[0], 1, 1000);
        assert_eq!(e1.frontier_sizes, vec![1, 6]);
        let e2 = expand(&g, &[0], 2, 1000);
        assert_eq!(e2.frontier_sizes, vec![1, 6, 11]);
        assert!(!e2.truncated);
    }

    #[test]
    fn cap_truncates() {
        let g = star_of_paths();
        let e = expand(&g, &[0], 2, 4);
        assert!(e.truncated);
        assert!(e.nodes.len() <= 4);
    }

    #[test]
    fn targets_first_and_unique() {
        let g = star_of_paths();
        let e = expand(&g, &[3, 3, 7], 1, 100);
        assert_eq!(&e.nodes[..2], &[3, 7]);
        let set: std::collections::HashSet<_> = e.nodes.iter().collect();
        assert_eq!(set.len(), e.nodes.len());
    }

    #[test]
    fn batches_cover_all_targets() {
        let train: Vec<u32> = (0..103).collect();
        let mut rng = Rng::new(1);
        let batches = target_batches(&train, 10, &mut rng);
        assert_eq!(batches.len(), 11);
        let mut all: Vec<u32> = batches.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, train);
    }
}
