//! GraphSAGE-style baseline [Hamilton et al., NIPS'17]: fixed-size
//! uniform neighbor sampling per layer (paper's comparison settings:
//! S1 = 25, S2 = 10, batch 512 — scaled down with our datasets).
//!
//! A batch is built by sampling receptive fields top-down
//! (R^L = targets, R^{l-1} = R^l ∪ sample_{S_l}(R^l)), then the union
//! runs through the same dense-block train step with the *sampled* edge
//! list (the adjacency renormalizes over sampled neighbors, which is
//! what the mean aggregator does).  Loss is masked to the targets.

use anyhow::{anyhow, Result};

use crate::coordinator::batch::BatchAssembler;
use crate::coordinator::source::BatchSource;
use crate::coordinator::trainer::TrainResult;
use crate::graph::{Dataset, Split};
use crate::runtime::Backend;
use crate::session::{NullObserver, Observer, TrainConfig};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct SageParams {
    /// neighbor samples per layer, outermost (layer-1 input) first;
    /// length must equal the model depth.
    pub samples: Vec<usize>,
    /// target nodes per batch.
    pub batch: usize,
}

impl SageParams {
    /// Paper defaults (S1=25, S2=10) scaled for depth L.
    pub fn for_depth(layers: usize, batch: usize) -> SageParams {
        let mut samples = vec![10; layers];
        if !samples.is_empty() {
            samples[0] = 25;
        }
        SageParams { samples, batch }
    }
}

/// Sampled receptive field: union node list (targets first) + sampled
/// directed local edges (u -> sampled neighbor v), both directions
/// inserted so propagation stays symmetric-ish like the mean aggregator.
pub struct SampledField {
    pub nodes: Vec<u32>,
    pub edges: Vec<(u32, u32)>,
    /// per-hop union sizes (embedding counters).
    pub frontier_sizes: Vec<usize>,
    pub truncated: bool,
}

pub fn sample_field(
    ds: &Dataset,
    targets: &[u32],
    params: &SageParams,
    cap: usize,
    rng: &mut Rng,
) -> SampledField {
    let g = &ds.graph;
    let mut local_of = vec![u32::MAX; g.n()];
    let mut nodes: Vec<u32> = Vec::new();
    let mut truncated = false;
    for &t in targets {
        if local_of[t as usize] == u32::MAX {
            local_of[t as usize] = nodes.len() as u32;
            nodes.push(t);
        }
    }
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut frontier: Vec<u32> = nodes.clone();
    let mut frontier_sizes = vec![nodes.len()];

    for &s in &params.samples {
        let mut next: Vec<u32> = Vec::new();
        'frontier: for &v in &frontier {
            let lv = local_of[v as usize];
            let nbrs = g.neighbors(v as usize);
            if nbrs.is_empty() {
                continue;
            }
            // sample s neighbors (with replacement beyond degree, like
            // GraphSAGE's uniform-with-replacement sampler)
            for _ in 0..s.min(nbrs.len().max(s)) {
                let u = nbrs[rng.usize_below(nbrs.len())];
                let lu = if local_of[u as usize] != u32::MAX {
                    local_of[u as usize]
                } else {
                    if nodes.len() >= cap {
                        truncated = true;
                        break 'frontier;
                    }
                    let lu = nodes.len() as u32;
                    local_of[u as usize] = lu;
                    nodes.push(u);
                    next.push(u);
                    lu
                };
                if lu != lv {
                    edges.push((lv, lu));
                    edges.push((lu, lv));
                }
            }
        }
        frontier_sizes.push(nodes.len());
        if truncated {
            break;
        }
        frontier = next;
    }
    edges.sort_unstable();
    edges.dedup();
    SampledField { nodes, edges, frontier_sizes, truncated }
}

/// [`BatchSource`] for GraphSAGE: per epoch, shuffled target batches;
/// per batch, a sampled receptive field assembled over the *sampled*
/// edge list with the loss masked to the targets.  Sampling draws from
/// the source's per-epoch RNG in batch order, so the stream is
/// identical whether batches are assembled inline or one step ahead by
/// a prefetching backend.
pub struct SageSource<'a> {
    ds: &'a Dataset,
    assembler: BatchAssembler,
    params: SageParams,
    layers: usize,
    f_hid: usize,
    seed: u64,
    rng: Rng,
    train_nodes: Vec<u32>,
    batches: Vec<Vec<u32>>,
    union_total: u64,
    batches_total: u64,
    max_batch_bytes: usize,
}

impl<'a> SageSource<'a> {
    /// Source over `ds` shaped by `spec`; errors when the per-layer
    /// sample counts do not match the model depth.
    pub fn new(
        ds: &'a Dataset,
        spec: &crate::runtime::ModelSpec,
        params: SageParams,
        norm: crate::norm::NormConfig,
        seed: u64,
    ) -> Result<SageSource<'a>> {
        if params.samples.len() != spec.layers {
            return Err(anyhow!(
                "sage samples {:?} must match model depth {}",
                params.samples,
                spec.layers
            ));
        }
        Ok(SageSource {
            ds,
            assembler: BatchAssembler::new(ds.n(), spec.b_max, norm),
            params,
            layers: spec.layers,
            f_hid: spec.f_hid,
            seed,
            rng: Rng::new(seed),
            train_nodes: ds.nodes_in_split(Split::Train),
            batches: Vec::new(),
            union_total: 0,
            batches_total: 0,
            max_batch_bytes: 0,
        })
    }
}

impl BatchSource for SageSource<'_> {
    fn shape(&self) -> (usize, usize, usize) {
        (self.assembler.b_max, self.ds.f_in, self.ds.num_classes)
    }

    fn begin_epoch(&mut self, epoch: usize) -> usize {
        self.rng = crate::coordinator::source::epoch_rng(
            self.seed,
            0x5A6E_0000_3333_4444,
            epoch,
        );
        self.batches =
            super::expansion::target_batches(&self.train_nodes, self.params.batch, &mut self.rng);
        self.batches.len()
    }

    fn len(&self) -> usize {
        self.batches.len()
    }

    fn assemble(&mut self, i: usize, into: &mut crate::coordinator::batch::Batch) {
        let targets = &self.batches[i];
        let field =
            sample_field(self.ds, targets, &self.params, self.assembler.b_max, &mut self.rng);
        self.assembler.assemble_with_edges_into(self.ds, &field.nodes, &field.edges, into);
        // loss only on the targets (they are first in local order)
        let n_targets = targets.len().min(field.nodes.len());
        into.mask.data.iter_mut().for_each(|m| *m = 0.0);
        for m in into.mask.data.iter_mut().take(n_targets) {
            *m = 1.0;
        }
        into.n_train = n_targets;
        self.union_total += field.nodes.len() as u64;
        self.batches_total += 1;
        self.max_batch_bytes = self.max_batch_bytes.max(
            // per-layer activations over the whole union
            into.bytes() + field.nodes.len() * self.f_hid * 4 * self.layers,
        );
    }

    fn stats(&self) -> crate::coordinator::source::SourceStats {
        crate::coordinator::source::SourceStats {
            max_batch_bytes: self.max_batch_bytes,
            // for sage this reports avg sampled-union size per batch
            utilization: self.union_total as f64 / self.batches_total.max(1) as f64,
        }
    }
}

/// Train with GraphSAGE batching through the given train-kind model
/// (typically the `*_sage_*` configs with enlarged b_max) on any
/// backend.  Thin wrapper over [`train_graphsage_observed`].
pub fn train_graphsage(
    backend: &mut dyn Backend,
    ds: &Dataset,
    model: &str,
    params: &SageParams,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    train_graphsage_observed(backend, ds, model, params, cfg, &mut NullObserver)
}

/// [`train_graphsage`] with an observer.  Pre-driver compatibility
/// entry: builds a [`crate::session::Driver`] over a [`SageSource`] and
/// drains it.  The config's model-shape fields are inert here — the
/// driver reads shapes from the backend's spec.
pub fn train_graphsage_observed(
    backend: &mut dyn Backend,
    ds: &Dataset,
    model: &str,
    params: &SageParams,
    cfg: &TrainConfig,
    obs: &mut dyn Observer,
) -> Result<TrainResult> {
    use crate::session::driver::{BackendSlot, Driver, DriverSource};

    let spec = backend.model_spec(model)?;
    let cfg = cfg.clone();
    let source = SageSource::new(ds, &spec, params.clone(), cfg.norm, cfg.seed)?;
    let mut backend = crate::runtime::PrefetchBackend::new(backend);
    let mut driver = Driver::from_parts(
        BackendSlot::Borrowed(&mut backend),
        ds,
        model.to_string(),
        cfg,
        DriverSource::Batched(Box::new(source)),
        None,
    )?;
    driver.drive(obs)?;
    driver.into_result()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{build, preset};

    #[test]
    fn field_respects_cap_and_orders_targets_first() {
        let ds = build(preset("cora_like").unwrap(), 1);
        let mut rng = Rng::new(2);
        let params = SageParams::for_depth(2, 8);
        let targets: Vec<u32> = (0..8).collect();
        let f = sample_field(&ds, &targets, &params, 128, &mut rng);
        assert_eq!(&f.nodes[..8], &targets[..]);
        assert!(f.nodes.len() <= 128);
        // all edges reference in-range locals
        for &(u, v) in &f.edges {
            assert!((u as usize) < f.nodes.len() && (v as usize) < f.nodes.len());
        }
    }

    #[test]
    fn frontier_grows_with_depth() {
        let ds = build(preset("ppi_like").unwrap(), 1);
        let mut rng = Rng::new(3);
        let p2 = SageParams::for_depth(2, 16);
        let p3 = SageParams::for_depth(3, 16);
        let targets: Vec<u32> = (0..16).collect();
        let f2 = sample_field(&ds, &targets, &p2, 100_000, &mut rng);
        let mut rng = Rng::new(3);
        let f3 = sample_field(&ds, &targets, &p3, 100_000, &mut rng);
        assert!(
            f3.nodes.len() > f2.nodes.len(),
            "3-layer field ({}) should exceed 2-layer ({})",
            f3.nodes.len(),
            f2.nodes.len()
        );
    }

    #[test]
    fn sampled_edges_are_deduped_and_symmetric() {
        let ds = build(preset("cora_like").unwrap(), 4);
        let mut rng = Rng::new(5);
        let params = SageParams { samples: vec![5, 5], batch: 4 };
        let f = sample_field(&ds, &(0..4u32).collect::<Vec<_>>(), &params, 512, &mut rng);
        let set: std::collections::HashSet<_> = f.edges.iter().collect();
        assert_eq!(set.len(), f.edges.len());
        for &(u, v) in &f.edges {
            assert!(set.contains(&(v, u)), "missing reverse of ({u},{v})");
        }
    }
}
