//! Baseline GCN training algorithms the paper compares against
//! (Table 1, Fig. 6, Tables 8/9): vanilla neighborhood-expansion SGD,
//! GraphSAGE-style fixed-size sampling, and VR-GCN with historical
//! activations.  Full-batch gradient descent is covered analytically in
//! `coordinator::memory` (the paper likewise excludes it from the
//! large-graph runs: "[9] has difficulty to scale").

pub mod expansion;
pub mod graphsage;
pub mod vrgcn;

pub use expansion::{train_expansion, train_expansion_observed, ExpansionSource};
pub use graphsage::{train_graphsage, train_graphsage_observed, SageParams, SageSource};
pub use vrgcn::{train_vrgcn, train_vrgcn_observed, VrgcnParams, VrgcnSource};
