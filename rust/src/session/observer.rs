//! Training-run observation: a callback trait the training loops feed
//! with metric/checkpoint/early-stop events as they happen, so callers
//! can stream progress, log, or implement custom stopping logic without
//! touching the loops themselves.
#![deny(missing_docs)]

use std::path::Path;

use crate::coordinator::trainer::CurvePoint;

/// One training-run event, borrowed from the loop that emitted it.
#[derive(Debug)]
pub enum Event<'a> {
    /// An epoch finished (every epoch, whether or not it evaluated).
    EpochEnd {
        /// 1-based epoch number.
        epoch: usize,
        /// cumulative training seconds so far (eval time excluded).
        train_seconds: f64,
        /// mean train loss over the epoch's batches.
        mean_loss: f64,
    },
    /// An evaluation ran; `point` is the curve entry just recorded.
    Eval {
        /// the convergence-curve point (epoch, time, loss, F1).
        point: &'a CurvePoint,
    },
    /// Early stopping fired; the run ends after this event.
    EarlyStop {
        /// epoch at which training stopped.
        epoch: usize,
        /// best eval metric seen before stopping.
        best: f64,
    },
    /// A checkpoint was written (emitted by the session, after the
    /// training loop returns).
    CheckpointSaved {
        /// destination file.
        path: &'a Path,
    },
}

/// Receiver of [`Event`]s.  Implementations must be cheap — they run
/// inline on the training thread.
pub trait Observer {
    /// Handle one event.
    fn on_event(&mut self, event: &Event<'_>);
}

/// The do-nothing observer (default when none is attached).
pub struct NullObserver;

impl Observer for NullObserver {
    fn on_event(&mut self, _event: &Event<'_>) {}
}

/// Streams eval/early-stop/checkpoint events to stderr — what the CLI
/// attaches so long runs show live progress.
#[derive(Default)]
pub struct StderrObserver;

impl Observer for StderrObserver {
    fn on_event(&mut self, event: &Event<'_>) {
        match event {
            Event::Eval { point } => eprintln!(
                "epoch {:4}  train_s {:8.2}  loss {:.4}  f1 {:.4}",
                point.epoch, point.train_seconds, point.train_loss, point.eval_f1
            ),
            Event::EarlyStop { epoch, best } => {
                eprintln!("early stop at epoch {epoch} (best f1 {best:.4})")
            }
            Event::CheckpointSaved { path } => {
                eprintln!("checkpoint saved to {}", path.display())
            }
            Event::EpochEnd { .. } => {}
        }
    }
}

/// Records every event kind — useful in tests and notebooks.
#[derive(Default)]
pub struct RecordingObserver {
    /// `(epoch, mean_loss)` per completed epoch.
    pub epochs: Vec<(usize, f64)>,
    /// cloned curve points in arrival order.
    pub evals: Vec<CurvePoint>,
    /// `(epoch, best)` if early stopping fired.
    pub early_stop: Option<(usize, f64)>,
    /// checkpoint paths written.
    pub checkpoints: Vec<std::path::PathBuf>,
}

impl Observer for RecordingObserver {
    fn on_event(&mut self, event: &Event<'_>) {
        match event {
            Event::EpochEnd { epoch, mean_loss, .. } => {
                self.epochs.push((*epoch, *mean_loss))
            }
            Event::Eval { point } => self.evals.push((*point).clone()),
            Event::EarlyStop { epoch, best } => {
                self.early_stop = Some((*epoch, *best))
            }
            Event::CheckpointSaved { path } => {
                self.checkpoints.push(path.to_path_buf())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recording_observer_collects() {
        let mut r = RecordingObserver::default();
        r.on_event(&Event::EpochEnd { epoch: 1, train_seconds: 0.5, mean_loss: 2.0 });
        let pt = CurvePoint { epoch: 1, train_seconds: 0.5, train_loss: 2.0, eval_f1: 0.3 };
        r.on_event(&Event::Eval { point: &pt });
        r.on_event(&Event::EarlyStop { epoch: 1, best: 0.3 });
        r.on_event(&Event::CheckpointSaved { path: Path::new("/tmp/x.ckpt") });
        assert_eq!(r.epochs, vec![(1, 2.0)]);
        assert_eq!(r.evals.len(), 1);
        assert_eq!(r.early_stop, Some((1, 0.3)));
        assert_eq!(r.checkpoints.len(), 1);
        // the null observer accepts anything silently
        NullObserver.on_event(&Event::Eval { point: &pt });
    }
}
