//! Training-run observation: the typed [`Event`] stream a
//! [`super::Driver`] yields (and [`super::Session::run`] forwards to an
//! attached [`Observer`]), so callers can stream progress, log, collect
//! curves, or implement custom stopping logic without owning the loop.
#![deny(missing_docs)]

use std::path::PathBuf;

use crate::coordinator::trainer::CurvePoint;

/// One training-run event, yielded in order by the driver.
///
/// Ordering contract (pinned by `tests/driver.rs`): per epoch, a
/// [`Event::StepStart`]/[`Event::StepEnd`] pair per optimization step
/// in step order, then exactly one [`Event::EpochEnd`], then
/// [`Event::Eval`] when that epoch evaluates, then [`Event::EarlyStop`]
/// if patience fired; the final event of every run is [`Event::Done`].
#[derive(Clone, Debug)]
pub enum Event {
    /// An optimization step is about to run (the driver yields this
    /// *before* assembling/executing, so a caller may inspect state or
    /// stop between steps).
    StepStart {
        /// 1-based epoch number.
        epoch: usize,
        /// 0-based optimization-step index within the epoch.
        step: usize,
    },
    /// An optimization step finished.
    StepEnd {
        /// 1-based epoch number.
        epoch: usize,
        /// 0-based optimization-step index within the epoch.
        step: usize,
        /// Mean loss over the step's contributing batches; `None` when
        /// every pulled batch had no training node (state untouched).
        loss: Option<f32>,
        /// Batches consumed from the epoch plan by this step (> 1 on a
        /// sharded backend).
        batches: usize,
    },
    /// An epoch finished (every epoch, whether or not it evaluated).
    EpochEnd {
        /// 1-based epoch number.
        epoch: usize,
        /// cumulative training seconds so far (eval time excluded).
        train_seconds: f64,
        /// mean train loss over the epoch's executed steps.
        mean_loss: f64,
    },
    /// An evaluation ran; `point` is the curve entry just recorded.
    Eval {
        /// the convergence-curve point (epoch, time, loss, F1).
        point: CurvePoint,
    },
    /// Early stopping fired; [`Event::Done`] follows immediately.
    EarlyStop {
        /// epoch at which training stopped.
        epoch: usize,
        /// best eval metric seen before stopping.
        best: f64,
    },
    /// A checkpoint was written (emitted by [`super::Session::run`]:
    /// with `TrainConfig::checkpoint_every` = k > 0, right after every
    /// k-th [`Event::EpochEnd`]; always just before [`Event::Done`] for
    /// the final state unless a periodic save already captured that
    /// epoch.  [`Event::Done`] stays the final event).
    CheckpointSaved {
        /// destination file.
        path: PathBuf,
    },
    /// The run completed; no further events follow.
    Done {
        /// last epoch that ran (0 when the run had no epochs).
        epochs: usize,
        /// total optimization steps executed.
        steps: u64,
    },
}

/// Receiver of [`Event`]s.  Implementations must be cheap — they run
/// inline on the training thread.
pub trait Observer {
    /// Handle one event.
    fn on_event(&mut self, event: &Event);
}

/// The do-nothing observer (default when none is attached).
pub struct NullObserver;

impl Observer for NullObserver {
    fn on_event(&mut self, _event: &Event) {}
}

/// Streams eval/early-stop/checkpoint events to stderr — what the CLI
/// attaches so long runs show live progress.  Per-step events are
/// ignored (too chatty for a terminal).
#[derive(Default)]
pub struct StderrObserver;

impl Observer for StderrObserver {
    fn on_event(&mut self, event: &Event) {
        match event {
            Event::Eval { point } => eprintln!(
                "epoch {:4}  train_s {:8.2}  loss {:.4}  f1 {:.4}",
                point.epoch, point.train_seconds, point.train_loss, point.eval_f1
            ),
            Event::EarlyStop { epoch, best } => {
                eprintln!("early stop at epoch {epoch} (best f1 {best:.4})")
            }
            Event::CheckpointSaved { path } => {
                eprintln!("checkpoint saved to {}", path.display())
            }
            Event::StepStart { .. }
            | Event::StepEnd { .. }
            | Event::EpochEnd { .. }
            | Event::Done { .. } => {}
        }
    }
}

/// Records every event kind — useful in tests and notebooks.
#[derive(Default)]
pub struct RecordingObserver {
    /// `(epoch, step, loss)` per completed optimization step.
    pub steps: Vec<(usize, usize, Option<f32>)>,
    /// `(epoch, mean_loss)` per completed epoch.
    pub epochs: Vec<(usize, f64)>,
    /// cloned curve points in arrival order.
    pub evals: Vec<CurvePoint>,
    /// `(epoch, best)` if early stopping fired.
    pub early_stop: Option<(usize, f64)>,
    /// checkpoint paths written.
    pub checkpoints: Vec<PathBuf>,
    /// `(last_epoch, total_steps)` once the run completed.
    pub done: Option<(usize, u64)>,
}

impl Observer for RecordingObserver {
    fn on_event(&mut self, event: &Event) {
        match event {
            Event::StepStart { .. } => {}
            Event::StepEnd { epoch, step, loss, .. } => {
                self.steps.push((*epoch, *step, *loss))
            }
            Event::EpochEnd { epoch, mean_loss, .. } => {
                self.epochs.push((*epoch, *mean_loss))
            }
            Event::Eval { point } => self.evals.push(point.clone()),
            Event::EarlyStop { epoch, best } => {
                self.early_stop = Some((*epoch, *best))
            }
            Event::CheckpointSaved { path } => {
                self.checkpoints.push(path.clone())
            }
            Event::Done { epochs, steps } => self.done = Some((*epochs, *steps)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recording_observer_collects() {
        let mut r = RecordingObserver::default();
        r.on_event(&Event::StepStart { epoch: 1, step: 0 });
        r.on_event(&Event::StepEnd { epoch: 1, step: 0, loss: Some(2.5), batches: 1 });
        r.on_event(&Event::EpochEnd { epoch: 1, train_seconds: 0.5, mean_loss: 2.0 });
        let pt = CurvePoint { epoch: 1, train_seconds: 0.5, train_loss: 2.0, eval_f1: 0.3 };
        r.on_event(&Event::Eval { point: pt.clone() });
        r.on_event(&Event::EarlyStop { epoch: 1, best: 0.3 });
        r.on_event(&Event::CheckpointSaved { path: PathBuf::from("/tmp/x.ckpt") });
        r.on_event(&Event::Done { epochs: 1, steps: 1 });
        assert_eq!(r.steps, vec![(1, 0, Some(2.5))]);
        assert_eq!(r.epochs, vec![(1, 2.0)]);
        assert_eq!(r.evals.len(), 1);
        assert_eq!(r.early_stop, Some((1, 0.3)));
        assert_eq!(r.checkpoints.len(), 1);
        assert_eq!(r.done, Some((1, 1)));
        // the null observer accepts anything silently
        NullObserver.on_event(&Event::Eval { point: pt });
    }
}
