//! The single experiment surface: a [`Session`] names a dataset, a
//! training [`Method`], a [`crate::runtime::Backend`], and a typed
//! [`TrainConfig`], then either runs to completion ([`Session::run`])
//! or hands the caller a pull-based [`Driver`] ([`Session::driver`])
//! that yields typed [`Event`]s step by step — one entry point for
//! Cluster-GCN and every baseline the paper compares against, on the
//! PJRT engine, the artifact-free host backend, or any combinator
//! stacked on top ([`crate::runtime::ShardedBackend`],
//! [`crate::runtime::PrefetchBackend`]).
//!
//! ```no_run
//! use cluster_gcn::session::{Method, Session};
//!
//! let ds = cluster_gcn::datagen::build(
//!     cluster_gcn::datagen::preset("cora_like").unwrap(), 42);
//! let out = Session::new(&ds)
//!     .partition(10)
//!     .method(Method::Cluster { q: 1 })
//!     .epochs(10)
//!     .run()
//!     .unwrap();
//! println!("{} via {}: f1 {:.4}", out.model, out.backend,
//!          out.result.curve.last().unwrap().eval_f1);
//! ```
//!
//! Pull-based driving (the same run, caller-owned loop):
//!
//! ```no_run
//! use cluster_gcn::session::{Event, Session};
//!
//! let ds = cluster_gcn::datagen::build(
//!     cluster_gcn::datagen::preset("cora_like").unwrap(), 42);
//! let mut driver = Session::new(&ds).epochs(10).driver().unwrap();
//! while let Some(ev) = driver.next_event().unwrap() {
//!     if let Event::Eval { point } = ev {
//!         println!("epoch {} f1 {:.4}", point.epoch, point.eval_f1);
//!     }
//! }
//! let result = driver.into_result().unwrap();
//! println!("trained {} steps", result.steps);
//! ```
//!
//! Layering: `Session` (what experiment) → [`Method`] (which training
//! algorithm + its sampling scheme) → [`Driver`] (the pull-based loop)
//! → [`crate::runtime::Backend`] (where `train_step`/`forward`
//! execute).  An [`Observer`] attached to the session receives every
//! [`Event`] as [`Session::run`] drains the driver.  For self-healing
//! runs, [`guard::run_guarded`] consumes the same event stream with
//! anomaly detection, rotating checkpoints, and
//! rollback-with-LR-backoff recovery.
#![deny(missing_docs)]

pub mod driver;
pub mod guard;
pub mod observer;
pub mod schedule;

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::baselines::expansion::ExpansionSource;
use crate::baselines::graphsage::SageSource;
use crate::baselines::vrgcn::VrgcnSource;
use crate::baselines::{SageParams, VrgcnParams};
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::source::ClusterSource;
use crate::coordinator::trainer::{TrainResult, TrainState};
use crate::coordinator::{checkpoint, ClusterSampler};
use crate::datagen::preset;
use crate::graph::{Dataset, Split};
use crate::norm::NormConfig;
use crate::partition::{
    parts_to_clusters, MultilevelPartitioner, Partitioner, RandomPartitioner,
};
use crate::runtime::{Backend, HostBackend, ModelSpec, PrefetchBackend};
use crate::util::Rng;

use driver::{BackendSlot, DriverSource};
pub use driver::{Driver, EvalStrategy};
pub use observer::{Event, NullObserver, Observer, RecordingObserver, StderrObserver};

/// Which training algorithm a session runs (Table 1 / Fig. 6 rows).
#[derive(Clone, Debug)]
pub enum Method {
    /// Cluster-GCN (Algorithm 1): q clusters per batch, between-cluster
    /// links restored and renormalized (§3.2/§6.2).
    Cluster {
        /// clusters per batch.
        q: usize,
    },
    /// Vanilla neighborhood-expansion SGD (§3): full L-hop receptive
    /// fields, loss on the targets.
    Expansion {
        /// target nodes per batch.
        batch: usize,
    },
    /// GraphSAGE-style fixed-size neighbor sampling.
    GraphSage(SageParams),
    /// VR-GCN control-variate sampling with historical activations.
    VrGcn(VrgcnParams),
}

impl Method {
    /// GraphSAGE with the paper's default fan-outs sized for `layers`.
    pub fn graphsage(layers: usize, batch: usize) -> Method {
        Method::GraphSage(SageParams::for_depth(layers, batch))
    }
}

/// The one typed training configuration, flowing Session → [`Driver`] →
/// [`crate::runtime::Backend`].  Everything the run needs lives here —
/// model shape, optimization, scheduling, adjacency normalization, and
/// the [`EvalStrategy`].  This is also what the pre-driver free
/// functions (`coordinator::train`, the baseline `train_*` entries)
/// take directly — the legacy `TrainOptions` shim was removed after its
/// one-release deprecation window.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// GCN depth L.
    pub layers: usize,
    /// hidden width override (None = the preset's `f_hid`, or 128 for
    /// datasets without a preset).
    pub hidden: Option<usize>,
    /// padded batch size override (None = preset `b_max`, grown to fit
    /// the sampler when needed on the host backend).
    pub b_max: Option<usize>,
    /// Adam learning rate (the paper uses 0.01 for every method).
    pub lr: f32,
    /// training epochs.
    pub epochs: usize,
    /// evaluate every k epochs (0 = only at the end).
    pub eval_every: usize,
    /// experiment seed (weights, sampling, partitioning).
    pub seed: u64,
    /// split evaluated for the convergence curve.
    pub eval_split: Split,
    /// cap steps per epoch (0 = no cap).
    pub max_steps_per_epoch: usize,
    /// learning-rate schedule over epochs.
    pub schedule: LrSchedule,
    /// early-stop patience in evals (0 = disabled).
    pub patience: usize,
    /// adjacency normalization (§6.2 / Table 11 variants).
    pub norm: NormConfig,
    /// how the curve's F1 is computed (exact full-graph vs the paper's
    /// clustered approximate eval).
    pub eval: EvalStrategy,
    /// first epoch already completed (0 = fresh run); the driver runs
    /// epochs `start_epoch + 1 ..= epochs`.  Pair with
    /// [`Session::initial_state`] to resume from a checkpoint: epoch
    /// streams are pure functions of `(seed, epoch)`, so a resumed run
    /// replays exactly what the uninterrupted run would have done.
    pub start_epoch: usize,
    /// save a versioned (`CGCNCKP2`) checkpoint every k epochs when the
    /// session has a save path (0 = final-only).  Each periodic save
    /// overwrites the same path and emits [`Event::CheckpointSaved`];
    /// resuming from an intermediate checkpoint replays the
    /// uninterrupted run bitwise (see `start_epoch`).
    pub checkpoint_every: usize,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            layers: 2,
            hidden: None,
            b_max: None,
            lr: 0.01,
            epochs: 40,
            eval_every: 5,
            seed: 0,
            eval_split: Split::Val,
            max_steps_per_epoch: 0,
            schedule: LrSchedule::Constant,
            patience: 0,
            norm: NormConfig::PAPER_DEFAULT,
            eval: EvalStrategy::ExactFullGraph,
            start_epoch: 0,
            checkpoint_every: 0,
        }
    }
}

/// What [`Session::run`] returns: the training result plus the resolved
/// model identity.
pub struct SessionResult {
    /// model id the backend trained (artifact name on PJRT).
    pub model: String,
    /// backend that executed the run (`"pjrt"` | `"host"` |
    /// `"sharded"`; a prefetch wrapper reports its inner backend).
    pub backend: String,
    /// the spec the run was shaped by (authoritative, from the backend).
    pub spec: ModelSpec,
    /// curve, final state, timing, and memory accounting.
    pub result: TrainResult,
}

/// Builder for one training run; see the module docs for the layering.
///
/// Defaults: Cluster-GCN with the dataset preset's partition count and
/// q, symmetric normalization, exact full-graph eval, the artifact-free
/// [`HostBackend`], and the default [`TrainConfig`].
pub struct Session<'a> {
    ds: &'a Dataset,
    method: Method,
    cfg: TrainConfig,
    parts: Option<usize>,
    random_partition: bool,
    backend: BackendSlot<'a>,
    observer: Option<&'a mut dyn Observer>,
    save: Option<PathBuf>,
    initial: Option<TrainState>,
    initial_history: Option<checkpoint::HistorySection>,
    prefetch: bool,
    workers: usize,
}

/// Resolve the partition count for `ds`: explicit override, else the
/// preset default, else 10 — clamped to the node count.
fn resolve_parts(ds: &Dataset, parts: Option<usize>) -> usize {
    parts
        .or(preset(&ds.name).map(|p| p.default_partitions))
        .unwrap_or(10)
        .clamp(1, ds.n().max(1))
}

/// The session partition, shared by every process of a run (the chief's
/// driver, distributed workers, the serving path): identical clusters
/// are derived from `(seed, parts, random)` via the same
/// `seed ^ 0xBEEF` RNG stream.
fn session_clusters(
    ds: &Dataset,
    seed: u64,
    parts_n: usize,
    random: bool,
) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let part = if random {
        RandomPartitioner.partition(&ds.graph, parts_n, &mut rng)
    } else {
        MultilevelPartitioner::default().partition(&ds.graph, parts_n, &mut rng)
    };
    parts_to_clusters(&part, parts_n)
}

impl<'a> Session<'a> {
    /// Start building a run over `ds`.
    pub fn new(ds: &'a Dataset) -> Session<'a> {
        let q = preset(&ds.name).map(|p| p.default_q).unwrap_or(1);
        Session {
            ds,
            method: Method::Cluster { q },
            cfg: TrainConfig::default(),
            parts: None,
            random_partition: false,
            backend: BackendSlot::Owned(Box::new(HostBackend::new())),
            observer: None,
            save: None,
            initial: None,
            initial_history: None,
            prefetch: true,
            workers: 1,
        }
    }

    /// Plan the cluster source for `n` distributed workers (cluster `c`
    /// is owned by worker `c % n`; per-epoch plans interleave the
    /// workers' shuffles round-robin).  `1` (the default) is the
    /// ordinary single-process plan.  Pair with a
    /// [`crate::runtime::DistributedBackend`] of the same width on the
    /// chief; worker processes derive their matching view via
    /// [`Session::into_worker`].
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Overlap batch assembly with execution by wrapping the (owned)
    /// backend in a [`crate::runtime::PrefetchBackend`] — **on by
    /// default**, preserving the pre-driver trainer's pipelining for
    /// every method.  Pass `false` for a strictly serial
    /// assemble-then-execute loop (borrowed backends are never wrapped;
    /// wrap them yourself to opt in).
    pub fn prefetch(mut self, enabled: bool) -> Self {
        self.prefetch = enabled;
        self
    }

    /// Number of graph partitions (Cluster-GCN only; default = the
    /// preset's `default_partitions`, or 10).
    pub fn partition(mut self, parts: usize) -> Self {
        self.parts = Some(parts);
        self
    }

    /// Use random partitioning instead of the multilevel partitioner
    /// (the Table 2 ablation).
    pub fn partition_random(mut self) -> Self {
        self.random_partition = true;
        self
    }

    /// Adjacency normalization (§6.2 / Table 11 variants).
    pub fn norm(mut self, norm: NormConfig) -> Self {
        self.cfg.norm = norm;
        self
    }

    /// Evaluation strategy for the convergence curve (default: exact
    /// full-graph inference).
    pub fn eval(mut self, eval: EvalStrategy) -> Self {
        self.cfg.eval = eval;
        self
    }

    /// Training algorithm (default: Cluster-GCN with the preset's q).
    pub fn method(mut self, method: Method) -> Self {
        self.method = method;
        self
    }

    /// Replace the whole training configuration.
    pub fn config(mut self, cfg: TrainConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// GCN depth.
    pub fn layers(mut self, layers: usize) -> Self {
        self.cfg.layers = layers;
        self
    }

    /// Training epochs.
    pub fn epochs(mut self, epochs: usize) -> Self {
        self.cfg.epochs = epochs;
        self
    }

    /// Adam learning rate.
    pub fn lr(mut self, lr: f32) -> Self {
        self.cfg.lr = lr;
        self
    }

    /// Experiment seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Execute on an owned backend (e.g. a freshly opened PJRT engine,
    /// or a combinator stack like
    /// `Box::new(ShardedBackend::host(4))`).
    pub fn backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.backend = BackendSlot::Owned(backend);
        self
    }

    /// Execute on a caller-owned backend (kept alive for inspection or
    /// reuse across sessions).
    pub fn backend_mut(mut self, backend: &'a mut dyn Backend) -> Self {
        self.backend = BackendSlot::Borrowed(backend);
        self
    }

    /// Attach an observer receiving [`Event`]s during [`Session::run`]
    /// (ignored when the caller drives a [`Driver`] directly — the
    /// events are already in the caller's hands).
    pub fn observer(mut self, obs: &'a mut dyn Observer) -> Self {
        self.observer = Some(obs);
        self
    }

    /// Save a checkpoint of the final state to `path` after training
    /// ([`Session::run`] only; manual drivers checkpoint via
    /// [`crate::coordinator::checkpoint`] whenever they choose).
    pub fn save(mut self, path: impl Into<PathBuf>) -> Self {
        self.save = Some(path.into());
        self
    }

    /// Start from an existing [`TrainState`] (e.g. a loaded checkpoint)
    /// instead of a fresh Glorot init.  Set
    /// [`TrainConfig::start_epoch`] to the epoch the state was saved at
    /// for a resume that bit-exactly replays the uninterrupted run.
    pub fn initial_state(mut self, state: TrainState) -> Self {
        self.initial = Some(state);
        self
    }

    /// Restore a VR-GCN historical-activation store from a versioned
    /// (`CGCNCKP2`) checkpoint before the first epoch.  VR-GCN's
    /// estimator reads the history its own steps refresh, so a resume
    /// is only a **bitwise** replay of the uninterrupted run when the
    /// history comes back with the weights — pair this with
    /// [`Session::initial_state`] and [`TrainConfig::start_epoch`].
    /// Errors at driver construction if the section's shape does not
    /// match the run, or if the method is not [`Method::VrGcn`].
    pub fn initial_history(mut self, history: checkpoint::HistorySection) -> Self {
        self.initial_history = Some(history);
        self
    }

    /// Resolve the model id this session will ask the backend for.
    /// Artifact names stay the historical scheme
    /// (`{short}[_sage|_vrgcn][_h{H}]_L{layers}`), so PJRT sessions keep
    /// finding their AOT artifacts; the host backend registers a fresh
    /// spec under the same id.
    pub fn model_name(&self) -> String {
        let short = self.ds.name.trim_end_matches("_like");
        let layers = self.cfg.layers;
        let kind = match &self.method {
            Method::Cluster { .. } => "",
            Method::Expansion { .. } | Method::GraphSage(_) => "_sage",
            Method::VrGcn(_) => "_vrgcn",
        };
        let hid = match self.cfg.hidden {
            Some(h) if preset(&self.ds.name).map(|p| p.f_hid) != Some(h) => {
                format!("_h{h}")
            }
            _ => String::new(),
        };
        format!("{short}{kind}{hid}_L{layers}")
    }

    /// Build the pull-based [`Driver`] for this session: partition (if
    /// clustering), register/resolve the model on the backend, wire the
    /// method's batch source — then hand the loop to the caller.
    pub fn driver(self) -> Result<Driver<'a>> {
        self.into_driver_parts().map(|(d, _, _)| d)
    }

    /// Build an online-serving [`crate::serve::Server`] from this
    /// session: partition the graph exactly as training would (same
    /// partitioner, same `seed ^ 0xBEEF` stream, so serving cache keys
    /// are the training clusters), resolve the model shape from the
    /// config/preset, and serve either the session's
    /// [`Session::initial_state`] weights (e.g. a loaded checkpoint) or
    /// a fresh deterministic init.  The server's exact mode answers
    /// queries bit-identical to the offline
    /// [`crate::coordinator::inference::full_forward_cached`] forward.
    pub fn into_server(self, serve: crate::serve::ServeConfig) -> Result<crate::serve::Server<'a>> {
        let Session { ds, cfg, parts, random_partition, initial, .. } = self;
        if cfg.layers == 0 {
            return Err(anyhow!("a model needs at least one layer"));
        }
        let p = preset(&ds.name);
        let parts_n = resolve_parts(ds, parts);
        let clusters = session_clusters(ds, cfg.seed, parts_n, random_partition);
        let f_hid = cfg.hidden.or(p.map(|p| p.f_hid)).unwrap_or(128);
        // b_max only shapes batch assembly, which serving sizes itself;
        // the weight shapes it implies are what matter here
        let spec = ModelSpec::gcn(ds.task, cfg.layers, ds.f_in, f_hid, ds.num_classes, 8);
        let weights = match initial {
            Some(st) => {
                let want = &spec.weight_shapes;
                let got: Vec<(usize, usize)> =
                    st.weights.iter().map(|w| (w.dims[0], w.dims[1])).collect();
                if got != *want {
                    return Err(anyhow!(
                        "initial state weight shapes {got:?} do not match the \
                         session's model {want:?} (layers/hidden/preset mismatch?)"
                    ));
                }
                st.weights
            }
            None => TrainState::init(&spec, cfg.seed).weights,
        };
        crate::serve::Server::new(ds, clusters, weights, cfg.norm, spec.residual, serve)
    }

    fn into_driver_parts(
        self,
    ) -> Result<(Driver<'a>, Option<&'a mut dyn Observer>, Option<PathBuf>)> {
        let model = self.model_name();
        let Session {
            ds,
            method,
            cfg,
            parts,
            random_partition,
            mut backend,
            observer,
            save,
            initial,
            initial_history,
            prefetch,
            workers,
        } = self;
        if cfg.layers == 0 {
            return Err(anyhow!("a model needs at least one layer"));
        }
        // default-on assembly/execute overlap: every owned backend runs
        // behind a PrefetchBackend (a pure scheduling wrapper — name
        // and numerics are the inner backend's; pass-through when the
        // inner consumes >1 batch per step).  Backends that must pull
        // batches themselves (the distributed backend, whose workers
        // assemble their own clusters' batches) opt out via
        // `Backend::prefetchable`.
        if prefetch {
            backend = match backend {
                BackendSlot::Owned(b) if b.prefetchable() => {
                    BackendSlot::Owned(Box::new(PrefetchBackend::new(b)))
                }
                other => other,
            };
        }
        let p = preset(&ds.name);

        // ---- partition + sampler (Cluster-GCN only) -------------------
        let sampler = if let Method::Cluster { q } = &method {
            let parts = resolve_parts(ds, parts);
            let q = (*q).clamp(1, parts);
            Some(ClusterSampler::new(
                session_clusters(ds, cfg.seed, parts, random_partition),
                q,
            ))
        } else {
            None
        };
        if workers > 1 && !matches!(method, Method::Cluster { .. }) {
            return Err(anyhow!(
                "distributed training supports the cluster method only \
                 (partitions are the unit of worker ownership)"
            ));
        }

        // ---- spec registration (host backends synthesize models) ------
        let f_hid = cfg.hidden.or(p.map(|p| p.f_hid)).unwrap_or(128);
        let base_bmax = cfg.b_max.or(p.map(|p| p.b_max)).unwrap_or(512);
        let need = sampler.as_ref().map(|s| s.max_batch_nodes()).unwrap_or(0);
        let b_max = base_bmax.max(need).next_multiple_of(8);
        let spec = ModelSpec::gcn(ds.task, cfg.layers, ds.f_in, f_hid, ds.num_classes, b_max);
        let spec = {
            let be: &mut dyn Backend = match &mut backend {
                BackendSlot::Owned(b) => b.as_mut(),
                BackendSlot::Borrowed(b) => &mut **b,
            };
            be.register_model(&model, spec);
            // authoritative: PJRT ignores registration (its manifest is
            // the source of truth), so sources must be shaped by what
            // the backend actually resolves
            be.model_spec(&model)?
        };

        // ---- per-method batch source ----------------------------------
        let source = match method {
            Method::Cluster { .. } => {
                let sampler = sampler.expect("cluster method always builds a sampler");
                DriverSource::Batched(Box::new(ClusterSource::new_distributed(
                    ds, sampler, &spec, cfg.norm, cfg.seed, workers,
                )?))
            }
            Method::Expansion { batch } => DriverSource::Batched(Box::new(
                ExpansionSource::new(ds, &spec, batch.max(1), cfg.norm, cfg.seed),
            )),
            Method::GraphSage(params) => DriverSource::Batched(Box::new(
                SageSource::new(ds, &spec, params, cfg.norm, cfg.seed)?,
            )),
            Method::VrGcn(params) => {
                let mut source = VrgcnSource::new(ds, &spec, params, cfg.norm, cfg.seed);
                if let Some(h) = &initial_history {
                    source.restore_history(h)?;
                }
                DriverSource::Vrgcn(source)
            }
        };
        if initial_history.is_some() && !matches!(source, DriverSource::Vrgcn(_)) {
            return Err(anyhow!(
                "initial_history is a VR-GCN resume input, but this session's \
                 method ({model}) keeps no history store"
            ));
        }

        let driver = Driver::from_parts(backend, ds, model, cfg, source, initial)?;
        Ok((driver, observer, save))
    }

    /// Build the pieces a **distributed worker process** needs to serve
    /// gradient requests for its share of a run's clusters: the model
    /// id, the resolved spec, and the ownership-aware batch source.
    /// The derivation runs through the same partition / q-clamp / spec
    /// sizing code as the chief's [`Session::driver`], so every process
    /// of a distributed run agrees on clusters, epoch plans, and
    /// shapes.  Requires [`Method::Cluster`]; set [`Session::workers`]
    /// to the run's width first.
    pub fn into_worker(self) -> Result<(String, ModelSpec, ClusterSource<'a>)> {
        let model = self.model_name();
        let Session { ds, method, cfg, parts, random_partition, workers, .. } = self;
        let Method::Cluster { q } = method else {
            return Err(anyhow!(
                "distributed training supports the cluster method only \
                 (partitions are the unit of worker ownership)"
            ));
        };
        if cfg.layers == 0 {
            return Err(anyhow!("a model needs at least one layer"));
        }
        let p = preset(&ds.name);
        let parts_n = resolve_parts(ds, parts);
        let q = q.clamp(1, parts_n);
        let sampler = ClusterSampler::new(
            session_clusters(ds, cfg.seed, parts_n, random_partition),
            q,
        );
        let f_hid = cfg.hidden.or(p.map(|p| p.f_hid)).unwrap_or(128);
        let base_bmax = cfg.b_max.or(p.map(|p| p.b_max)).unwrap_or(512);
        let b_max = base_bmax.max(sampler.max_batch_nodes()).next_multiple_of(8);
        let spec =
            ModelSpec::gcn(ds.task, cfg.layers, ds.f_in, f_hid, ds.num_classes, b_max);
        let source =
            ClusterSource::new_distributed(ds, sampler, &spec, cfg.norm, cfg.seed, workers)?;
        Ok((model, spec, source))
    }

    /// Run the session to completion: build the [`Driver`], drain every
    /// event into the attached observer, optionally checkpoint.  With a
    /// save path, the final checkpoint is written — and
    /// [`Event::CheckpointSaved`] emitted — just before [`Event::Done`],
    /// which stays the final event; with
    /// [`TrainConfig::checkpoint_every`] = k > 0, the same path is
    /// additionally overwritten right after every k-th
    /// [`Event::EpochEnd`] (the final save is skipped when a periodic
    /// save already captured the last epoch).  Every session checkpoint
    /// is the versioned `CGCNCKP2` format, so it records the epoch it
    /// was saved at (what `--resume` continues from); VR-GCN runs
    /// additionally carry their historical-activation store, making
    /// their resume a bitwise replay too.  Equivalent to driving the
    /// loop by hand — this is now a convenience, not the loop's owner.
    pub fn run(self) -> Result<SessionResult> {
        let (mut driver, observer, save) = self.into_driver_parts()?;
        let mut null = NullObserver;
        let obs: &mut dyn Observer = match observer {
            Some(o) => o,
            None => &mut null,
        };
        let every = driver.config().checkpoint_every;
        let mut saved_at: Option<usize> = None;
        while let Some(ev) = driver.next_event()? {
            if matches!(ev, Event::Done { .. }) {
                if let Some(path) = &save {
                    // skip when a periodic save already captured this
                    // exact epoch (no state change since EpochEnd)
                    if saved_at != Some(driver.epoch()) {
                        let history = driver.history_section();
                        checkpoint::save_v2(
                            driver.state(),
                            driver.model(),
                            driver.epoch(),
                            history.as_ref(),
                            path,
                        )?;
                        obs.on_event(&Event::CheckpointSaved { path: path.clone() });
                    }
                }
            }
            let epoch_end = match &ev {
                Event::EpochEnd { epoch, .. } => Some(*epoch),
                _ => None,
            };
            obs.on_event(&ev);
            if let (Some(epoch), Some(path)) = (epoch_end, &save) {
                if every > 0 && epoch % every == 0 {
                    let history = driver.history_section();
                    checkpoint::save_v2(
                        driver.state(),
                        driver.model(),
                        epoch,
                        history.as_ref(),
                        path,
                    )?;
                    saved_at = Some(epoch);
                    obs.on_event(&Event::CheckpointSaved { path: path.clone() });
                }
            }
        }
        let model = driver.model().to_string();
        let backend = driver.backend_name().to_string();
        let spec = driver.spec().clone();
        let result = driver.into_result()?;
        Ok(SessionResult { model, backend, spec, result })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Csr, Labels, Task};

    fn mini_ds(name: &str) -> Dataset {
        Dataset {
            name: name.into(),
            task: Task::Multiclass,
            graph: Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3)]),
            f_in: 2,
            num_classes: 2,
            features: vec![0.0; 8],
            labels: Labels::Multiclass(vec![0, 1, 0, 1]),
            split: vec![Split::Train; 4],
        }
    }

    #[test]
    fn model_names_follow_artifact_scheme() {
        let ds = mini_ds("cora_like");
        let s = Session::new(&ds).method(Method::Cluster { q: 1 });
        assert_eq!(s.model_name(), "cora_L2");
        let s = Session::new(&ds).method(Method::graphsage(3, 64)).layers(3);
        assert_eq!(s.model_name(), "cora_sage_L3");
        let s = Session::new(&ds).method(Method::VrGcn(VrgcnParams::default()));
        assert_eq!(s.model_name(), "cora_vrgcn_L2");
        let s = Session::new(&ds).method(Method::Expansion { batch: 8 });
        assert_eq!(s.model_name(), "cora_sage_L2");
    }

    #[test]
    fn hidden_override_lands_in_the_name() {
        let ds = mini_ds("reddit_like");
        let cfg = TrainConfig { hidden: Some(512), ..TrainConfig::default() };
        let s = Session::new(&ds).config(cfg);
        assert_eq!(s.model_name(), "reddit_h512_L2");
    }

    #[test]
    fn unknown_dataset_defaults_are_sane() {
        let ds = mini_ds("custom_graph");
        let s = Session::new(&ds);
        assert_eq!(s.model_name(), "custom_graph_L2");
        // default method is cluster with q = 1 for presetless datasets
        assert!(matches!(s.method, Method::Cluster { q: 1 }));
        // default eval strategy is the exact evaluator
        assert_eq!(s.cfg.eval, EvalStrategy::ExactFullGraph);
    }
}
