//! [`Driver`]: the pull-based training loop.  Where the old
//! `Session::run()` *owned* a closed epoch loop, the driver is a
//! resumable state machine the **caller** advances: each
//! [`Driver::next_event`] (or iterator step) moves the run forward by
//! exactly one visible transition and yields the typed [`Event`] for it
//! — so CLIs, examples, benches, and tests can interleave their own
//! logic (inspection, custom stopping, UI) between steps without
//! forking the trainer.
//!
//! ```text
//!             ┌────────────────────────────────────────────────┐
//!             ▼                                                │
//!   NextEpoch ──► Step ──► StepRun ──► … ──► EpochEnd ──► MaybeEval
//!       │          │StepStart   │StepEnd        │EpochEnd   │Eval? EarlyStop?
//!       │          └────◄───────┘                            │
//!       └(epochs done / early stop)──► Finish ──► Exhausted
//!                                        │Done
//! ```
//!
//! One `next_event` call performs at most one unit of work: `StepRun`
//! assembles + executes one optimization step (through
//! [`Backend::step_from`], where the sharded/prefetch combinators hook
//! in), `MaybeEval` runs at most one evaluation.  Time is accumulated
//! around the work units only, so caller time between pulls never
//! pollutes `train_seconds`.
//!
//! `Session::run()` survives as a thin convenience — build the driver,
//! drain it into the attached observer, package the result.
#![deny(missing_docs)]

use std::collections::VecDeque;

use anyhow::{anyhow, Result};

use crate::baselines::vrgcn::VrgcnSource;
use crate::coordinator::batch::Batch;
use crate::coordinator::batch_eval::cluster_evaluate;
use crate::coordinator::sampler::ClusterSampler;
use crate::coordinator::schedule::EarlyStopper;
use crate::coordinator::source::{BatchSource, SourceStats};
use crate::coordinator::trainer::{evaluate_cached, CurvePoint, TrainResult, TrainState};
use crate::graph::Dataset;
use crate::norm::NormCache;
use crate::partition::{parts_to_clusters, MultilevelPartitioner, Partitioner};
use crate::runtime::{Backend, ModelSpec, StepOutcome};
use crate::session::{Event, Observer, TrainConfig};
use crate::util::{Rng, Timer};

/// How the convergence curve's F1 is computed at each evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalStrategy {
    /// Exact full-graph host inference (the default; what every curve
    /// so far used).
    ExactFullGraph,
    /// The paper's cheap approximate eval: cluster-wise batched
    /// inference over `parts` partitions (between-batch links dropped —
    /// the Δ approximation of eq. (4) at eval time), routed through
    /// `batch_eval::cluster_evaluate` on the session's backend.
    Clustered {
        /// Partitions of the eval-time clustering (one cluster per
        /// batch); must be large enough for every cluster to fit the
        /// model's `b_max`.
        parts: usize,
    },
}

/// Owned-or-borrowed execution backend of one run.
pub(crate) enum BackendSlot<'a> {
    /// The driver owns the backend (built by the session or CLI).
    Owned(Box<dyn Backend>),
    /// Caller-owned backend, kept alive for inspection or reuse.
    Borrowed(&'a mut dyn Backend),
}

impl BackendSlot<'_> {
    fn get(&mut self) -> &mut dyn Backend {
        match self {
            BackendSlot::Owned(b) => b.as_mut(),
            BackendSlot::Borrowed(b) => &mut **b,
        }
    }
}

/// The per-method batch production half of a run.
pub(crate) enum DriverSource<'a> {
    /// [`BatchSource`]-backed methods (Cluster, Expansion, GraphSage):
    /// steps pull through [`Backend::step_from`].
    Batched(Box<dyn BatchSource + 'a>),
    /// VR-GCN: assembly reads the history its own steps refresh, so the
    /// driver runs its step inline (no lookahead, no sharding).
    Vrgcn(VrgcnSource<'a>),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    NextEpoch,
    Step,
    StepRun,
    EpochEnd,
    MaybeEval,
    Finish,
    Exhausted,
}

/// The resumable training state machine; see the module docs for the
/// transition diagram and `tests/driver.rs` for the pinned event
/// ordering.  Build one with [`crate::session::Session::driver`], pull
/// events with [`Driver::next_event`] or by iterating
/// (`Item = Result<Event>`), and package the run with
/// [`Driver::into_result`].
pub struct Driver<'a> {
    ds: &'a Dataset,
    model: String,
    spec: ModelSpec,
    cfg: TrainConfig,
    backend: BackendSlot<'a>,
    source: DriverSource<'a>,
    scratch: Option<Batch>,
    eval_nodes: Vec<u32>,

    // ---- state-machine position ----
    phase: Phase,
    epoch: usize,
    lr: f32,
    plan_len: usize,
    cursor: usize,
    step_ix: usize,
    exec_steps: usize,
    epoch_loss: f64,
    last_mean: f64,
    stopped: bool,
    queued: VecDeque<Event>,

    // ---- run accumulators ----
    state: TrainState,
    curve: Vec<CurvePoint>,
    train_seconds: f64,
    steps: u64,
    stopper: EarlyStopper,
    norm_cache: NormCache,
    eval_sampler: Option<ClusterSampler>,
}

impl<'a> Driver<'a> {
    pub(crate) fn from_parts(
        mut backend: BackendSlot<'a>,
        ds: &'a Dataset,
        model: String,
        cfg: TrainConfig,
        source: DriverSource<'a>,
        initial: Option<TrainState>,
    ) -> Result<Driver<'a>> {
        let spec = backend.get().model_spec(&model)?;
        backend.get().prepare(&model)?;
        let state = match initial {
            Some(st) => {
                for (li, (w, &shape)) in
                    st.weights.iter().zip(&spec.weight_shapes).enumerate()
                {
                    if w.dims != [shape.0, shape.1] {
                        return Err(anyhow!(
                            "resume state layer {li} has shape {:?}, model {model} \
                             expects {:?}",
                            w.dims,
                            shape
                        ));
                    }
                }
                st
            }
            None => TrainState::init(&spec, cfg.seed),
        };
        let scratch = match &source {
            DriverSource::Batched(src) => Some(src.new_batch()),
            DriverSource::Vrgcn(_) => None,
        };
        let eval_nodes = ds.nodes_in_split(cfg.eval_split);
        // Clustered eval is validated here, not at the first eval —
        // a part count whose clusters overflow b_max must fail before
        // epochs of training are spent, not after.
        let eval_sampler = match cfg.eval {
            EvalStrategy::Clustered { parts } => {
                let parts = parts.clamp(1, ds.n().max(1));
                let mut rng = Rng::new(cfg.seed ^ 0xE7A1_C105_7E2E_D001);
                let part =
                    MultilevelPartitioner::default().partition(&ds.graph, parts, &mut rng);
                let sampler = ClusterSampler::new(parts_to_clusters(&part, parts), 1);
                if sampler.max_batch_nodes() > spec.b_max {
                    return Err(anyhow!(
                        "clustered eval with {parts} parts produces batches of up \
                         to {} nodes but model {model} has b_max={}; raise the \
                         eval part count",
                        sampler.max_batch_nodes(),
                        spec.b_max
                    ));
                }
                Some(sampler)
            }
            EvalStrategy::ExactFullGraph => None,
        };
        let stopper = EarlyStopper::new(cfg.patience);
        let epoch = cfg.start_epoch;
        Ok(Driver {
            ds,
            model,
            spec,
            cfg,
            backend,
            source,
            scratch,
            eval_nodes,
            phase: Phase::NextEpoch,
            epoch,
            lr: 0.0,
            plan_len: 0,
            cursor: 0,
            step_ix: 0,
            exec_steps: 0,
            epoch_loss: 0.0,
            last_mean: 0.0,
            stopped: false,
            queued: VecDeque::new(),
            state,
            curve: Vec::new(),
            train_seconds: 0.0,
            steps: 0,
            stopper,
            norm_cache: NormCache::new(),
            eval_sampler,
        })
    }

    /// The model id this run trains.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// The resolved architecture (authoritative, from the backend).
    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// The configuration this run was built from (what
    /// [`super::Session::run`] consults for checkpoint cadence).
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Name of the executing backend (`"host"`, `"pjrt"`, `"sharded"`;
    /// a prefetch wrapper forwards its inner backend's name).
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            BackendSlot::Owned(b) => b.name(),
            BackendSlot::Borrowed(b) => b.name(),
        }
    }

    /// The live training state (weights + Adam moments + step counter)
    /// — inspectable between any two events.
    pub fn state(&self) -> &TrainState {
        &self.state
    }

    /// The 1-based epoch the run has reached so far (equals
    /// `cfg.start_epoch` before the first epoch begins) — what a
    /// checkpoint taken between events records as its resume point.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Snapshot of the VR-GCN historical-activation store, for a
    /// versioned (`CGCNCKP2`) checkpoint — `None` for every
    /// [`BatchSource`]-backed method, whose resume needs no history.
    pub fn history_section(&self) -> Option<crate::coordinator::checkpoint::HistorySection> {
        match &self.source {
            DriverSource::Vrgcn(src) => Some(src.history_section()),
            DriverSource::Batched(_) => None,
        }
    }

    /// Convergence curve recorded so far.
    pub fn curve(&self) -> &[CurvePoint] {
        &self.curve
    }

    /// Override the base learning rate for epochs that have not started
    /// yet ([`TrainConfig::schedule`] still shapes the per-epoch rate on
    /// top of this base).  The application hook for
    /// [`super::schedule::Directive::SetLr`] — pair it with
    /// [`crate::coordinator::LrSchedule::Constant`] so the external
    /// schedule is the only rate policy in play.
    pub fn set_base_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    /// Request a graceful stop: no further epoch starts, exactly as if
    /// [`TrainConfig::patience`] had fired ([`Event::Done`] still
    /// arrives).  The application hook for
    /// [`super::schedule::Directive::Stop`].
    pub fn request_stop(&mut self) {
        self.stopped = true;
    }

    /// Advance the state machine to its next visible transition and
    /// yield the event for it; `Ok(None)` once [`Event::Done`] has been
    /// delivered.  Errors from the backend or evaluator abort the run.
    pub fn next_event(&mut self) -> Result<Option<Event>> {
        if let Some(ev) = self.queued.pop_front() {
            return Ok(Some(ev));
        }
        loop {
            match self.phase {
                Phase::NextEpoch => {
                    if self.epoch >= self.cfg.epochs || self.stopped {
                        self.phase = Phase::Finish;
                        continue;
                    }
                    self.epoch += 1;
                    self.lr =
                        self.cfg.schedule.lr_at(self.cfg.lr, self.epoch, self.cfg.epochs);
                    let t = Timer::start();
                    self.backend.get().epoch_begin();
                    self.plan_len = match &mut self.source {
                        DriverSource::Batched(src) => src.begin_epoch(self.epoch),
                        DriverSource::Vrgcn(src) => src.begin_epoch(self.epoch),
                    };
                    self.train_seconds += t.secs();
                    self.cursor = 0;
                    self.step_ix = 0;
                    self.exec_steps = 0;
                    self.epoch_loss = 0.0;
                    self.phase = Phase::Step;
                }
                Phase::Step => {
                    let capped = self.cfg.max_steps_per_epoch > 0
                        && self.exec_steps >= self.cfg.max_steps_per_epoch;
                    if self.cursor >= self.plan_len || capped {
                        self.phase = Phase::EpochEnd;
                        continue;
                    }
                    self.phase = Phase::StepRun;
                    return Ok(Some(Event::StepStart {
                        epoch: self.epoch,
                        step: self.step_ix,
                    }));
                }
                Phase::StepRun => {
                    let t = Timer::start();
                    let outcome = self.run_step()?;
                    self.train_seconds += t.secs();
                    self.cursor += outcome.consumed;
                    let ev = Event::StepEnd {
                        epoch: self.epoch,
                        step: self.step_ix,
                        loss: outcome.loss,
                        batches: outcome.consumed,
                    };
                    self.step_ix += 1;
                    if let Some(l) = outcome.loss {
                        self.exec_steps += 1;
                        self.steps += 1;
                        self.epoch_loss += l as f64;
                    }
                    self.phase = Phase::Step;
                    return Ok(Some(ev));
                }
                Phase::EpochEnd => {
                    self.last_mean = self.epoch_loss / self.exec_steps.max(1) as f64;
                    self.phase = Phase::MaybeEval;
                    return Ok(Some(Event::EpochEnd {
                        epoch: self.epoch,
                        train_seconds: self.train_seconds,
                        mean_loss: self.last_mean,
                    }));
                }
                Phase::MaybeEval => {
                    let last = self.epoch == self.cfg.epochs;
                    let due = self.cfg.eval_every > 0
                        && self.epoch % self.cfg.eval_every == 0;
                    self.phase = Phase::NextEpoch;
                    if due || last {
                        let f1 = self.run_eval()?;
                        let point = CurvePoint {
                            epoch: self.epoch,
                            train_seconds: self.train_seconds,
                            train_loss: self.last_mean,
                            eval_f1: f1,
                        };
                        self.curve.push(point.clone());
                        if self.stopper.update(f1) {
                            self.stopped = true;
                            self.queued.push_back(Event::EarlyStop {
                                epoch: self.epoch,
                                best: self.stopper.best(),
                            });
                        }
                        return Ok(Some(Event::Eval { point }));
                    }
                }
                Phase::Finish => {
                    self.phase = Phase::Exhausted;
                    return Ok(Some(Event::Done {
                        epochs: self.epoch,
                        steps: self.steps,
                    }));
                }
                Phase::Exhausted => return Ok(None),
            }
        }
    }

    /// Execute one optimization step (the `StepRun` transition body).
    /// Failpoints (chaos tests only; inert branches otherwise):
    /// `driver.step` fails the step with a typed error before any work;
    /// `driver.loss` corrupts the *reported* loss to NaN while leaving
    /// the weights untouched — the anomaly the self-healing
    /// [`super::guard`] detects and rolls back from, chosen so the
    /// post-recovery trajectory can be compared bitwise against the
    /// fault-free run.
    fn run_step(&mut self) -> Result<StepOutcome> {
        crate::util::failpoint::check("driver.step")?;
        let mut outcome = self.run_step_inner()?;
        if outcome.loss.is_some() && crate::util::failpoint::should_fail("driver.loss") {
            outcome.loss = Some(f32::NAN);
        }
        Ok(outcome)
    }

    fn run_step_inner(&mut self) -> Result<StepOutcome> {
        let backend = match &mut self.backend {
            BackendSlot::Owned(b) => b.as_mut(),
            BackendSlot::Borrowed(b) => &mut **b,
        };
        match &mut self.source {
            DriverSource::Batched(src) => {
                let scratch =
                    self.scratch.as_mut().expect("batched driver owns a scratch batch");
                backend.step_from(
                    &self.model,
                    &mut self.state,
                    self.lr,
                    src.as_mut(),
                    self.cursor,
                    scratch,
                )
            }
            DriverSource::Vrgcn(src) => {
                let vb = src.assemble(self.cursor, &mut self.norm_cache);
                let (loss, hiddens) =
                    backend.vrgcn_step(&self.model, &mut self.state, self.lr, vb)?;
                src.refresh(&hiddens);
                Ok(StepOutcome { loss: Some(loss), consumed: 1 })
            }
        }
    }

    /// Run one evaluation per the configured [`EvalStrategy`].
    fn run_eval(&mut self) -> Result<f64> {
        if self.eval_nodes.is_empty() {
            return Ok(0.0);
        }
        // VR-GCN's training step has no residual path, so its exact
        // eval must not apply one either, whatever the spec flag says
        // (the pre-driver loop pinned this to false).
        let residual = match &self.source {
            DriverSource::Vrgcn(_) => false,
            DriverSource::Batched(_) => self.spec.residual,
        };
        match self.cfg.eval {
            EvalStrategy::ExactFullGraph => Ok(evaluate_cached(
                self.ds,
                &self.state.weights,
                self.cfg.norm,
                residual,
                &self.eval_nodes,
                &mut self.norm_cache,
            )),
            EvalStrategy::Clustered { .. } => {
                let sampler = self
                    .eval_sampler
                    .as_ref()
                    .expect("clustered eval sampler built at construction");
                let backend = match &mut self.backend {
                    BackendSlot::Owned(b) => b.as_mut(),
                    BackendSlot::Borrowed(b) => &mut **b,
                };
                cluster_evaluate(
                    backend,
                    self.ds,
                    sampler,
                    &self.model,
                    &self.state.weights,
                    self.cfg.norm,
                    &self.eval_nodes,
                    self.cfg.seed,
                )
            }
        }
    }

    /// Drain every remaining event into `obs` (the push-style
    /// convenience `Session::run` uses).
    pub fn drive(&mut self, obs: &mut dyn Observer) -> Result<()> {
        while let Some(ev) = self.next_event()? {
            obs.on_event(&ev);
        }
        Ok(())
    }

    /// Package the run (drains any remaining events first, so calling
    /// this on a half-driven driver completes the run silently).
    pub fn into_result(mut self) -> Result<TrainResult> {
        while self.next_event()?.is_some() {}
        let stats: SourceStats = match &self.source {
            DriverSource::Batched(src) => src.stats(),
            DriverSource::Vrgcn(src) => src.stats(),
        };
        let peak_bytes = stats.max_batch_bytes + self.state.param_bytes();
        Ok(TrainResult {
            state: self.state,
            curve: self.curve,
            train_seconds: self.train_seconds,
            steps: self.steps,
            peak_bytes,
            avg_within_edges_per_node: stats.utilization,
        })
    }
}

impl Iterator for Driver<'_> {
    type Item = Result<Event>;

    fn next(&mut self) -> Option<Result<Event>> {
        match self.next_event() {
            Ok(Some(ev)) => Some(Ok(ev)),
            Ok(None) => None,
            Err(e) => {
                self.phase = Phase::Exhausted;
                self.queued.clear();
                Some(Err(e))
            }
        }
    }
}
