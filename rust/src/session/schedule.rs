//! Caller-side training schedules: patience-based early stopping and
//! step/**cosine** learning-rate decay as reusable [`Event`] consumers.
//!
//! The [`super::driver::Driver`] already applies the *internal*
//! [`crate::coordinator::LrSchedule`] and [`TrainConfig::patience`]
//! policies; this module is the composable alternative for callers
//! driving the steppable event loop themselves — feed every event to a
//! [`Schedule`] and apply the [`Directive`]s it emits via
//! [`super::driver::Driver::set_base_lr`] /
//! [`super::driver::Driver::request_stop`].  Configure the driver with
//! [`crate::coordinator::LrSchedule::Constant`] and `patience: 0` so
//! the external schedule is the only policy in play.
//!
//! A schedule is a **pure function of (config, event stream)**: it
//! reads nothing but the events it is fed and keeps no clock, so
//! identical streams produce identical directive sequences (pinned by
//! the unit tests below).

use crate::coordinator::CurvePoint;

use super::observer::Event;

#[allow(unused_imports)] // doc links
use super::TrainConfig;

/// Learning-rate decay family (applied to [`ScheduleConfig::base_lr`]
/// as a function of the 1-based epoch).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decay {
    /// No decay.
    Constant,
    /// Multiply by `factor` every `every` epochs — mirrors
    /// [`crate::coordinator::LrSchedule::StepDecay`] exactly, so the
    /// two implementations are interchangeable.
    Step {
        /// Epochs per step (0 disables decay).
        every: usize,
        /// Multiplier per step.
        factor: f32,
    },
    /// Cosine annealing from `base_lr` at epoch 1 down to
    /// `base_lr * min_frac` at [`ScheduleConfig::total_epochs`].
    Cosine {
        /// Final learning rate as a fraction of the base.
        min_frac: f32,
    },
}

/// Schedule configuration; the schedule is a pure function of this plus
/// the event stream.
#[derive(Clone, Copy, Debug)]
pub struct ScheduleConfig {
    /// Epoch-1 learning rate.
    pub base_lr: f32,
    /// Planned run length (the cosine horizon; unused by other decays).
    pub total_epochs: usize,
    /// Decay family.
    pub decay: Decay,
    /// Early-stop patience: stop after this many consecutive
    /// [`Event::Eval`]s without a new best `eval_f1` (0 = never stop).
    pub patience: usize,
}

/// What the caller should do to the driver in response to an event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Directive {
    /// Apply via [`super::driver::Driver::set_base_lr`] — the rate for
    /// the upcoming epoch.
    SetLr(f32),
    /// Apply via [`super::driver::Driver::request_stop`].
    Stop,
}

/// The learning rate `cfg` prescribes for a 1-based `epoch` — exposed
/// as a free function so tests can pin the whole curve without
/// replaying events.
pub fn lr_for(cfg: &ScheduleConfig, epoch: usize) -> f32 {
    let e = epoch.max(1);
    match cfg.decay {
        Decay::Constant => cfg.base_lr,
        Decay::Step { every, factor } => {
            if every == 0 {
                cfg.base_lr
            } else {
                cfg.base_lr * factor.powi(((e - 1) / every) as i32)
            }
        }
        Decay::Cosine { min_frac } => {
            let t = cfg.total_epochs;
            if t <= 1 {
                cfg.base_lr
            } else {
                let phase =
                    std::f32::consts::PI * (e.min(t) - 1) as f32 / (t - 1) as f32;
                cfg.base_lr * (min_frac + (1.0 - min_frac) * 0.5 * (1.0 + phase.cos()))
            }
        }
    }
}

/// Stateful consumer over a [`super::driver::Driver`]'s event stream;
/// see the module docs for wiring.
pub struct Schedule {
    cfg: ScheduleConfig,
    lr: f32,
    best: f64,
    since_best: usize,
    stopped: bool,
}

impl Schedule {
    /// A schedule starting at `lr_for(cfg, 1)`.
    pub fn new(cfg: ScheduleConfig) -> Schedule {
        Schedule {
            lr: lr_for(&cfg, 1),
            cfg,
            best: f64::NEG_INFINITY,
            since_best: 0,
            stopped: false,
        }
    }

    /// Feed one event; returns at most one directive to apply.
    ///
    /// - [`Event::EpochEnd`] for epoch `e` → [`Directive::SetLr`] with
    ///   the epoch-`e+1` rate, when it differs from the current one.
    /// - [`Event::Eval`] → patience bookkeeping on
    ///   [`CurvePoint::eval_f1`]; emits [`Directive::Stop`] once when
    ///   patience runs out.
    /// - Every other event is bookkeeping-free and returns `None`.
    pub fn observe(&mut self, ev: &Event) -> Option<Directive> {
        if self.stopped {
            return None;
        }
        match ev {
            Event::EpochEnd { epoch, .. } => {
                let next = lr_for(&self.cfg, epoch + 1);
                if next != self.lr {
                    self.lr = next;
                    Some(Directive::SetLr(next))
                } else {
                    None
                }
            }
            Event::Eval { point } => self.observe_eval(point),
            _ => None,
        }
    }

    fn observe_eval(&mut self, point: &CurvePoint) -> Option<Directive> {
        if self.cfg.patience == 0 {
            return None;
        }
        if point.eval_f1 > self.best {
            self.best = point.eval_f1;
            self.since_best = 0;
            None
        } else {
            self.since_best += 1;
            if self.since_best >= self.cfg.patience {
                self.stopped = true;
                Some(Directive::Stop)
            } else {
                None
            }
        }
    }

    /// The rate currently in effect.
    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Whether a [`Directive::Stop`] has been emitted.
    pub fn stopped(&self) -> bool {
        self.stopped
    }

    /// Best `eval_f1` seen so far (`-inf` before the first eval).
    pub fn best(&self) -> f64 {
        self.best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval(epoch: usize, f1: f64) -> Event {
        Event::Eval {
            point: CurvePoint {
                epoch,
                train_seconds: 0.0,
                train_loss: 1.0,
                eval_f1: f1,
            },
        }
    }

    fn epoch_end(epoch: usize) -> Event {
        Event::EpochEnd { epoch, train_seconds: 0.0, mean_loss: 1.0 }
    }

    fn replay(cfg: ScheduleConfig, stream: &[Event]) -> Vec<Option<Directive>> {
        let mut s = Schedule::new(cfg);
        stream.iter().map(|e| s.observe(e)).collect()
    }

    #[test]
    fn schedule_is_a_pure_function_of_config_and_event_stream() {
        let cfg = ScheduleConfig {
            base_lr: 0.1,
            total_epochs: 6,
            decay: Decay::Cosine { min_frac: 0.1 },
            patience: 2,
        };
        let stream: Vec<Event> = (1..=6)
            .flat_map(|e| {
                vec![
                    Event::StepStart { epoch: e, step: 0 },
                    Event::StepEnd { epoch: e, step: 0, loss: Some(0.5), batches: 1 },
                    epoch_end(e),
                    eval(e, 0.8 - 0.05 * e as f64),
                ]
            })
            .collect();
        let a = replay(cfg, &stream);
        let b = replay(cfg, &stream);
        assert_eq!(a, b, "identical streams must produce identical directives");
        // step events never produce directives
        for (ev, d) in stream.iter().zip(&a) {
            if matches!(ev, Event::StepStart { .. } | Event::StepEnd { .. }) {
                assert_eq!(*d, None);
            }
        }
        // declining f1 with patience 2 stops at the second non-best eval
        assert_eq!(a[4 * 2 + 3], Some(Directive::Stop));
        assert!(a[4 * 2 + 3 + 1..].iter().all(|d| d.is_none()), "stop is terminal");
    }

    #[test]
    fn cosine_hits_its_endpoints() {
        let cfg = ScheduleConfig {
            base_lr: 0.2,
            total_epochs: 10,
            decay: Decay::Cosine { min_frac: 0.05 },
            patience: 0,
        };
        assert_eq!(lr_for(&cfg, 1), 0.2);
        let end = lr_for(&cfg, 10);
        assert!((end - 0.2 * 0.05).abs() < 1e-6, "end lr {end}");
        // monotone non-increasing across the horizon
        for e in 1..10 {
            assert!(lr_for(&cfg, e + 1) <= lr_for(&cfg, e) + 1e-9);
        }
        // past the horizon it clamps at the floor
        assert_eq!(lr_for(&cfg, 25), end);
    }

    #[test]
    fn step_decay_matches_the_internal_lr_schedule() {
        let cfg = ScheduleConfig {
            base_lr: 0.08,
            total_epochs: 12,
            decay: Decay::Step { every: 3, factor: 0.5 },
            patience: 0,
        };
        let internal = crate::coordinator::LrSchedule::StepDecay { every: 3, factor: 0.5 };
        for e in 1..=12 {
            assert_eq!(lr_for(&cfg, e), internal.lr_at(0.08, e, 12), "epoch {e}");
        }
        // directives fire exactly at step boundaries
        let mut s = Schedule::new(cfg);
        let mut sets = Vec::new();
        for e in 1..=12 {
            if let Some(Directive::SetLr(lr)) = s.observe(&epoch_end(e)) {
                sets.push((e, lr));
            }
        }
        assert_eq!(sets, vec![(3, 0.04), (6, 0.02), (9, 0.01)]);
    }

    #[test]
    fn patience_zero_never_stops() {
        let cfg = ScheduleConfig {
            base_lr: 0.1,
            total_epochs: 4,
            decay: Decay::Constant,
            patience: 0,
        };
        let mut s = Schedule::new(cfg);
        for e in 1..=50 {
            assert_eq!(s.observe(&eval(e, -1.0)), None);
        }
        assert!(!s.stopped());
    }
}
