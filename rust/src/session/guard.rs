//! Self-healing training: a guard loop that consumes [`Event`]s from a
//! [`Driver`], detects anomalies (NaN/Inf losses, loss spikes, step
//! errors — including injected faults from
//! [`crate::util::failpoint`]), and recovers by rolling back to the
//! last good checkpoint with learning-rate backoff, bounded by a retry
//! budget.
//!
//! ## State machine
//!
//! ```text
//!            build driver (fresh, or resumed from last-good ckpt)
//!                 │
//!                 ▼
//!   ┌───────► RUNNING ── clean EpochEnd ──► rotate CGCNCKP3 save ──┐
//!   │             │                                                │
//!   │   anomaly / step error                                       │
//!   │             ▼                                                │
//!   │         RECOVER: retries += 1 (give up past max_retries),    │
//!   │         lr ← lr · backoff, reload newest intact checkpoint   │
//!   │             │                                                │
//!   └─────────────┘                        Done ──► GuardOutcome ◄─┘
//! ```
//!
//! Recovery leans on two existing contracts: epoch streams are pure
//! functions of `(seed, epoch)` (PR 5's bitwise resume), so a rebuilt
//! driver resumed at the checkpoint's epoch replays exactly what the
//! uninterrupted run would have done; and
//! [`RotatingCheckpoint::load_latest`] skips torn/corrupt files, so a
//! crash during the save itself still leaves a rollback target.  With
//! `lr_backoff = 1.0` the post-recovery trajectory is therefore
//! **bitwise identical** to the fault-free run — the invariant the
//! chaos suite pins.
//!
//! The guard is a pure event consumer over the public driver surface
//! (the same seam as [`super::schedule::Schedule`]): it owns no
//! training internals, so any method/backend combination the session
//! can build is guardable.

use std::path::PathBuf;

use crate::coordinator::checkpoint::{Checkpoint, CheckpointError, RotatingCheckpoint};
use crate::coordinator::trainer::TrainResult;
use crate::session::{Driver, Event, Observer};

/// Tuning for the anomaly detector and the recovery policy.
#[derive(Clone, Debug)]
pub struct GuardConfig {
    /// An epoch whose mean loss exceeds `spike_factor ×` the EMA of
    /// previous epoch means is an anomaly (≤ 0 disables spike
    /// detection; NaN/Inf detection is always on).
    pub spike_factor: f64,
    /// EMA smoothing for the epoch-mean loss baseline (weight of the
    /// newest epoch).
    pub ema_alpha: f64,
    /// Recovery attempts before giving up with
    /// [`GuardError::RetriesExhausted`].
    pub max_retries: usize,
    /// Base-LR multiplier applied on every recovery (1.0 = pure
    /// rollback, which keeps the post-recovery trajectory bitwise equal
    /// to the fault-free run; < 1.0 trades that for stability).
    pub lr_backoff: f32,
    /// Save a rotating checkpoint every k clean epochs (0 ⇒ 1; the
    /// guard cannot roll back further than its save cadence).
    pub checkpoint_every: usize,
}

impl Default for GuardConfig {
    fn default() -> GuardConfig {
        GuardConfig {
            spike_factor: 4.0,
            ema_alpha: 0.3,
            max_retries: 3,
            lr_backoff: 0.5,
            checkpoint_every: 1,
        }
    }
}

/// What the detector flagged (also the terminal diagnosis when retries
/// run out).
#[derive(Clone, Debug)]
pub enum Anomaly {
    /// A step reported a NaN/Inf loss, or an epoch's mean was
    /// non-finite.
    NonFinite {
        /// epoch of the offending event.
        epoch: usize,
        /// step index within the epoch (0 when flagged at epoch end).
        step: usize,
    },
    /// An epoch's mean loss jumped past `spike_factor ×` the EMA
    /// baseline.
    LossSpike {
        /// epoch whose mean spiked.
        epoch: usize,
        /// the spiked mean loss.
        mean: f64,
        /// the EMA baseline it was compared against.
        ema: f64,
    },
    /// The driver itself returned an error (backend failure, injected
    /// `driver.step`/`shard.exchange` fault, checkpoint IO, …).
    StepError {
        /// rendered error chain.
        message: String,
    },
}

impl std::fmt::Display for Anomaly {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Anomaly::NonFinite { epoch, step } => {
                write!(f, "non-finite loss at epoch {epoch} step {step}")
            }
            Anomaly::LossSpike { epoch, mean, ema } => write!(
                f,
                "loss spike at epoch {epoch}: mean {mean:.4} vs ema {ema:.4}"
            ),
            Anomaly::StepError { message } => write!(f, "driver error: {message}"),
        }
    }
}

/// Why a guarded run gave up.
#[derive(Debug)]
pub enum GuardError {
    /// The driver factory failed (bad config, backend construction).
    Build(anyhow::Error),
    /// A rotating checkpoint save failed with a real (non-injected
    /// handled) error.
    Checkpoint(CheckpointError),
    /// Every retry was spent; carries the last anomaly seen.
    RetriesExhausted {
        /// the configured retry budget that was exhausted.
        retries: usize,
        /// the anomaly that consumed the final retry.
        last: Anomaly,
    },
}

impl std::fmt::Display for GuardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GuardError::Build(e) => write!(f, "guard could not build a driver: {e}"),
            GuardError::Checkpoint(e) => write!(f, "guard checkpoint failure: {e}"),
            GuardError::RetriesExhausted { retries, last } => {
                write!(f, "guard gave up after {retries} retries; last anomaly: {last}")
            }
        }
    }
}

impl std::error::Error for GuardError {}

/// A completed guarded run: the training result plus the recovery
/// ledger.
pub struct GuardOutcome {
    /// The final training result (curve, state, timing).
    pub result: TrainResult,
    /// Recovery attempts that were spent (0 = fault-free run).
    pub retries: usize,
    /// Recoveries that resumed from a checkpoint (the rest restarted
    /// from scratch because no intact checkpoint existed yet).
    pub rollbacks: usize,
    /// Rotating checkpoints written.
    pub saves: usize,
    /// The base-LR scale in effect when the run completed
    /// (`lr_backoff ^ retries`).
    pub lr_scale: f32,
}

/// Streaming anomaly detector over driver [`Event`]s: flags NaN/Inf
/// step losses immediately, and epoch means that are non-finite or
/// spike past an EMA baseline.  Pure and allocation-free; feed it every
/// event in order.
pub struct AnomalyDetector {
    spike_factor: f64,
    ema_alpha: f64,
    ema: Option<f64>,
}

impl AnomalyDetector {
    /// Detector with the config's thresholds and an empty baseline.
    pub fn new(cfg: &GuardConfig) -> AnomalyDetector {
        AnomalyDetector {
            spike_factor: cfg.spike_factor,
            ema_alpha: cfg.ema_alpha.clamp(0.0, 1.0),
            ema: None,
        }
    }

    /// Inspect one event; `Some` means training must not continue past
    /// it.  Clean epoch means update the EMA baseline.
    pub fn observe(&mut self, ev: &Event) -> Option<Anomaly> {
        match ev {
            Event::StepEnd { epoch, step, loss: Some(l), .. } if !l.is_finite() => {
                Some(Anomaly::NonFinite { epoch: *epoch, step: *step })
            }
            Event::EpochEnd { epoch, mean_loss, .. } => {
                if !mean_loss.is_finite() {
                    return Some(Anomaly::NonFinite { epoch: *epoch, step: 0 });
                }
                if self.spike_factor > 0.0 {
                    if let Some(ema) = self.ema {
                        if *mean_loss > self.spike_factor * ema {
                            return Some(Anomaly::LossSpike {
                                epoch: *epoch,
                                mean: *mean_loss,
                                ema,
                            });
                        }
                    }
                }
                self.ema = Some(match self.ema {
                    Some(e) => (1.0 - self.ema_alpha) * e + self.ema_alpha * *mean_loss,
                    None => *mean_loss,
                });
                None
            }
            _ => None,
        }
    }
}

/// Run training under the guard.  `make_driver` is called for the
/// initial attempt (`None`) and after every recovery (`Some(last good
/// checkpoint)`, plus the backed-off base-LR scale); it rebuilds the
/// driver however the caller likes — typically a fresh
/// [`super::Session`] with [`super::Session::initial_state`] /
/// [`super::TrainConfig::start_epoch`] (+
/// [`super::Session::initial_history`] for VR-GCN) taken from the
/// checkpoint.  Clean epochs are checkpointed into `store`
/// ([`Event::CheckpointSaved`] is forwarded to `obs` like every other
/// event; across retries the observer sees each attempt's stream in
/// order).
pub fn run_guarded<'d, F>(
    mut make_driver: F,
    cfg: &GuardConfig,
    store: &RotatingCheckpoint,
    obs: &mut dyn Observer,
) -> Result<GuardOutcome, GuardError>
where
    F: FnMut(Option<&Checkpoint>, f32) -> anyhow::Result<Driver<'d>>,
{
    let every = cfg.checkpoint_every.max(1);
    let mut lr_scale = 1.0f32;
    let mut retries = 0usize;
    let mut rollbacks = 0usize;
    let mut saves = 0usize;
    let mut last_good: Option<Checkpoint> = None;

    loop {
        let mut driver =
            make_driver(last_good.as_ref(), lr_scale).map_err(GuardError::Build)?;
        let mut detector = AnomalyDetector::new(cfg);
        let anomaly: Anomaly = loop {
            match driver.next_event() {
                Ok(Some(ev)) => {
                    obs.on_event(&ev);
                    if let Some(a) = detector.observe(&ev) {
                        break a;
                    }
                    if let Event::EpochEnd { epoch, .. } = ev {
                        // the epoch was clean (observe() passed it):
                        // make it the newest rollback target
                        if epoch % every == 0 {
                            let history = driver.history_section();
                            let path = store
                                .save(
                                    driver.state(),
                                    driver.model(),
                                    epoch,
                                    history.as_ref(),
                                )
                                .map_err(GuardError::Checkpoint)?;
                            saves += 1;
                            obs.on_event(&Event::CheckpointSaved { path });
                        }
                    }
                }
                Ok(None) => {
                    let result = driver.into_result().map_err(GuardError::Build)?;
                    return Ok(GuardOutcome { result, retries, rollbacks, saves, lr_scale });
                }
                Err(e) => break Anomaly::StepError { message: format!("{e:#}") },
            }
        };

        retries += 1;
        if retries > cfg.max_retries {
            return Err(GuardError::RetriesExhausted {
                retries: cfg.max_retries,
                last: anomaly,
            });
        }
        lr_scale *= cfg.lr_backoff;
        last_good = match store.load_latest() {
            Ok((ck, _path, _skipped)) => {
                rollbacks += 1;
                Some(ck)
            }
            // nothing intact (or nothing saved yet): restart from scratch
            Err(CheckpointError::NoIntactCheckpoint { .. }) => None,
            Err(e) => return Err(GuardError::Checkpoint(e)),
        };
    }
}

/// Convenience: the rotation base path the CLI derives from a `--save`
/// target (`<save>.guard`), so guard slots never collide with the
/// session's own final checkpoint.
pub fn rotation_base(save: &std::path::Path) -> PathBuf {
    let mut name = save.file_name().map(|s| s.to_os_string()).unwrap_or_default();
    name.push(".guard");
    save.with_file_name(name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::CurvePoint;

    fn cfg() -> GuardConfig {
        GuardConfig::default()
    }

    #[test]
    fn detector_flags_nonfinite_step_loss() {
        let mut d = AnomalyDetector::new(&cfg());
        let ok = Event::StepEnd { epoch: 1, step: 0, loss: Some(0.7), batches: 1 };
        assert!(d.observe(&ok).is_none());
        let skip = Event::StepEnd { epoch: 1, step: 1, loss: None, batches: 1 };
        assert!(d.observe(&skip).is_none(), "no-loss steps are not anomalies");
        let bad = Event::StepEnd { epoch: 1, step: 2, loss: Some(f32::NAN), batches: 1 };
        assert!(matches!(
            d.observe(&bad),
            Some(Anomaly::NonFinite { epoch: 1, step: 2 })
        ));
        let inf = Event::StepEnd {
            epoch: 2,
            step: 0,
            loss: Some(f32::INFINITY),
            batches: 1,
        };
        assert!(matches!(d.observe(&inf), Some(Anomaly::NonFinite { .. })));
    }

    #[test]
    fn detector_flags_spikes_against_the_ema() {
        let mut d = AnomalyDetector::new(&GuardConfig {
            spike_factor: 2.0,
            ema_alpha: 0.5,
            ..cfg()
        });
        let epoch_end = |epoch: usize, mean: f64| Event::EpochEnd {
            epoch,
            train_seconds: 0.0,
            mean_loss: mean,
        };
        // first epoch seeds the baseline, never spikes
        assert!(d.observe(&epoch_end(1, 1.0)).is_none());
        // gentle drift is fine
        assert!(d.observe(&epoch_end(2, 1.5)).is_none());
        // ema = 1.25 now; 3.0 > 2 × 1.25 spikes
        match d.observe(&epoch_end(3, 3.0)) {
            Some(Anomaly::LossSpike { epoch: 3, mean, ema }) => {
                assert_eq!(mean, 3.0);
                assert!((ema - 1.25).abs() < 1e-12);
            }
            other => panic!("expected LossSpike, got {other:?}"),
        }
        // a spiked epoch must not pollute the baseline
        assert!(d.observe(&epoch_end(4, 1.5)).is_none());
        // NaN epoch mean is always an anomaly
        assert!(matches!(
            d.observe(&epoch_end(5, f64::NAN)),
            Some(Anomaly::NonFinite { epoch: 5, step: 0 })
        ));
    }

    #[test]
    fn detector_ignores_spikes_when_disabled() {
        let mut d = AnomalyDetector::new(&GuardConfig { spike_factor: 0.0, ..cfg() });
        for (e, m) in [(1usize, 1.0f64), (2, 50.0), (3, 0.1)] {
            assert!(d
                .observe(&Event::EpochEnd { epoch: e, train_seconds: 0.0, mean_loss: m })
                .is_none());
        }
        // eval/early-stop/done events are never anomalies
        let pt = CurvePoint { epoch: 3, train_seconds: 0.0, train_loss: 0.1, eval_f1: 0.9 };
        assert!(d.observe(&Event::Eval { point: pt }).is_none());
        assert!(d.observe(&Event::Done { epochs: 3, steps: 9 }).is_none());
    }

    #[test]
    fn rotation_base_appends_guard_suffix() {
        assert_eq!(
            rotation_base(std::path::Path::new("/tmp/model.ckpt")),
            PathBuf::from("/tmp/model.ckpt.guard")
        );
    }
}
