//! # cluster-gcn
//!
//! A production-quality reproduction of **Cluster-GCN: An Efficient
//! Algorithm for Training Deep and Large Graph Convolutional Networks**
//! (Chiang et al., KDD 2019) as a three-layer rust + JAX + Pallas stack:
//!
//! - **rust (this crate)** — the training coordinator: graph store,
//!   multilevel (METIS-like) partitioner, stochastic multiple-partition
//!   batch sampler, batch assembly/renormalization, PJRT runtime, the
//!   epoch loop, metrics, memory accounting, and the baseline training
//!   algorithms the paper compares against.
//! - **JAX (python/compile, build-time only)** — the L-layer GCN model
//!   with fused Adam `train_step`, AOT-lowered to HLO text artifacts.
//! - **Pallas (python/compile/kernels)** — the fused blocked `Â·X·W`
//!   GCN-layer kernel the model is built from.
//!
//! Training runs through one experiment surface — [`session::Session`]
//! — over pluggable [`runtime::Backend`]s: the PJRT engine (AOT
//! artifacts) or the artifact-free [`runtime::HostBackend`].  See
//! ARCHITECTURE.md for the Session → Method → Backend layering,
//! DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for paper-vs-measured results.

pub mod baselines;
pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod datagen;
pub mod graph;
pub mod norm;
pub mod partition;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod testing;
pub mod util;
