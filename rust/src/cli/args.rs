//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `cluster-gcn <subcommand> [--key value | --flag]...`.
//! Unknown keys are rejected against a per-command whitelist so typos
//! fail loudly.  Boolean switches are declared explicitly per command:
//! a switch never consumes the next token (`train --guard 5` is an
//! error, not `guard="5"`), a value flag must be given a value, and a
//! flag seen twice is rejected instead of last-one-wins.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
}

impl Args {
    /// Parse from raw argv (without the program name).
    ///
    /// `allowed` is the subcommand's full flag whitelist; `bools` is
    /// the subset that are boolean switches and therefore never take a
    /// value.  Every key may appear at most once.
    pub fn parse(argv: &[String], allowed: &[&str], bools: &[&str]) -> Result<Args> {
        debug_assert!(
            bools.iter().all(|b| allowed.contains(b)),
            "every boolean switch must also be in the whitelist"
        );
        let command = argv
            .first()
            .ok_or_else(|| anyhow!("missing subcommand"))?
            .clone();
        let mut opts = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {a:?}"))?;
            if !allowed.contains(&key) {
                bail!(
                    "unknown option --{key} for {command} (allowed: {})",
                    allowed.join(", ")
                );
            }
            if opts.contains_key(key) {
                bail!("duplicate option --{key} for {command}");
            }
            if bools.contains(&key) {
                opts.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        opts.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => bail!("--{key} expects a value"),
                }
            }
        }
        Ok(Args { command, opts })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_key_values_and_flags() {
        let a = Args::parse(
            &argv(&["train", "--preset", "cora_like", "--epochs", "10", "--verbose"]),
            &["preset", "epochs", "verbose"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("preset"), Some("cora_like"));
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 10);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn rejects_unknown_option() {
        let e = Args::parse(&argv(&["train", "--nope", "1"]), &["preset"], &[]);
        assert!(e.is_err());
    }

    #[test]
    fn rejects_missing_command() {
        assert!(Args::parse(&[], &[], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(&["x"]), &[], &[]).unwrap();
        assert_eq!(a.usize_or("k", 7).unwrap(), 7);
        assert_eq!(a.str_or("s", "d"), "d");
        assert_eq!(a.f64_or("lr", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv(&["x", "--k", "abc"]), &["k"], &[]).unwrap();
        assert!(a.usize_or("k", 1).is_err());
    }

    /// Regression: a boolean switch must not swallow the next token as
    /// its value.  `train --guard 5` used to silently set `guard="5"`
    /// (so `flag("guard")` was false and the guard never engaged); the
    /// stray token must now be rejected.
    #[test]
    fn boolean_switch_never_takes_a_value() {
        let e = Args::parse(&argv(&["train", "--guard", "5"]), &["guard"], &["guard"]);
        let msg = format!("{:#}", e.unwrap_err());
        assert!(msg.contains("expected --flag"), "got: {msg}");
        // a switch followed by another flag parses as a plain switch
        let a = Args::parse(
            &argv(&["train", "--guard", "--keep", "2"]),
            &["guard", "keep"],
            &["guard"],
        )
        .unwrap();
        assert!(a.flag("guard"));
        assert_eq!(a.usize_or("keep", 0).unwrap(), 2);
    }

    /// Regression: duplicate flags used to silently overwrite each
    /// other (`--epochs 5 --epochs 50` ran 50); they must error.
    #[test]
    fn rejects_duplicate_flags() {
        let e = Args::parse(
            &argv(&["train", "--epochs", "5", "--epochs", "50"]),
            &["epochs"],
            &[],
        );
        let msg = format!("{:#}", e.unwrap_err());
        assert!(msg.contains("duplicate option --epochs"), "got: {msg}");
        let e = Args::parse(&argv(&["train", "--guard", "--guard"]), &["guard"], &["guard"]);
        assert!(e.is_err());
    }

    /// A value flag with no value (end of argv or another flag next)
    /// must error instead of becoming `"true"`.
    #[test]
    fn value_flag_requires_a_value() {
        for argvec in [
            argv(&["train", "--epochs"]),
            argv(&["train", "--epochs", "--seed", "1"]),
        ] {
            let e = Args::parse(&argvec, &["epochs", "seed"], &[]);
            let msg = format!("{:#}", e.unwrap_err());
            assert!(msg.contains("--epochs expects a value"), "got: {msg}");
        }
    }
}
