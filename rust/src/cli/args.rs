//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `cluster-gcn <subcommand> [--key value | --flag]...`.
//! Unknown keys are rejected against a per-command whitelist so typos
//! fail loudly.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
}

impl Args {
    /// Parse from raw argv (without the program name).
    pub fn parse(argv: &[String], allowed: &[&str]) -> Result<Args> {
        let command = argv
            .first()
            .ok_or_else(|| anyhow!("missing subcommand"))?
            .clone();
        let mut opts = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {a:?}"))?;
            if !allowed.contains(&key) {
                bail!(
                    "unknown option --{key} for {command} (allowed: {})",
                    allowed.join(", ")
                );
            }
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                opts.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                opts.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { command, opts })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_key_values_and_flags() {
        let a = Args::parse(
            &argv(&["train", "--preset", "cora_like", "--epochs", "10", "--verbose"]),
            &["preset", "epochs", "verbose"],
        )
        .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("preset"), Some("cora_like"));
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 10);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn rejects_unknown_option() {
        let e = Args::parse(&argv(&["train", "--nope", "1"]), &["preset"]);
        assert!(e.is_err());
    }

    #[test]
    fn rejects_missing_command() {
        assert!(Args::parse(&[], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(&["x"]), &[]).unwrap();
        assert_eq!(a.usize_or("k", 7).unwrap(), 7);
        assert_eq!(a.str_or("s", "d"), "d");
        assert_eq!(a.f64_or("lr", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv(&["x", "--k", "abc"]), &["k"]).unwrap();
        assert!(a.usize_or("k", 1).is_err());
    }
}
