//! `cluster-gcn` command-line interface: dataset generation, graph
//! partitioning, training (cluster-gcn + baselines), and inspection.
//!
//! ```text
//! cluster-gcn datagen   --preset ppi_like [--seed 42] [--cache data/]
//! cluster-gcn partition --preset ppi_like [--parts 50] [--algo multilevel|random]
//! cluster-gcn train     --preset ppi_like [--layers 2] [--epochs 40]
//!                       [--method cluster|graphsage|vrgcn] [--q 1] [--parts 50]
//!                       [--norm sym|row|row+id|row+l1] [--lr 0.01] [--seed 0]
//!                       [--artifacts artifacts/]
//! cluster-gcn inspect   [--artifacts artifacts/]
//! ```

pub mod args;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{train, ClusterSampler, TrainOptions};
use crate::datagen::{build_cached, preset, PRESETS};
use crate::norm::NormConfig;
use crate::partition::{
    parts_to_clusters, MultilevelPartitioner, Partitioner, RandomPartitioner,
};
use crate::runtime::Engine;
use crate::util::{Rng, Timer};
use args::Args;

pub fn parse_norm(s: &str) -> Result<NormConfig> {
    Ok(match s {
        "sym" => NormConfig::PAPER_DEFAULT,
        "row" => NormConfig::ROW,
        "row+id" => NormConfig::ROW_IDENTITY,
        "row+l1" => NormConfig::ROW_LAMBDA1,
        other => bail!("unknown norm {other} (sym|row|row+id|row+l1)"),
    })
}

pub fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{}", USAGE);
        return Ok(());
    }
    match argv[0].as_str() {
        "datagen" => cmd_datagen(&argv),
        "partition" => cmd_partition(&argv),
        "train" => cmd_train(&argv),
        "eval" => cmd_eval(&argv),
        "inspect" => cmd_inspect(&argv),
        other => Err(anyhow!("unknown command {other}\n{USAGE}")),
    }
}

const USAGE: &str = "\
cluster-gcn — Cluster-GCN (KDD'19) three-layer reproduction

USAGE:
  cluster-gcn datagen   --preset NAME [--seed N] [--cache DIR]
  cluster-gcn partition --preset NAME [--parts K] [--algo multilevel|random] [--seed N]
  cluster-gcn train     --preset NAME [--layers L] [--epochs N] [--method cluster|graphsage|vrgcn]
                        [--q Q] [--parts P] [--norm sym|row|row+id|row+l1]
                        [--lr F] [--seed N] [--artifacts DIR] [--cache DIR] [--eval-every K]
  cluster-gcn eval      --preset NAME --checkpoint FILE [--norm ...] [--split val|test]
  cluster-gcn inspect   [--artifacts DIR]

Presets: cora_like pubmed_like ppi_like reddit_like amazon_like amazon2m_like
";

fn load_ds(a: &Args) -> Result<crate::graph::Dataset> {
    let name = a
        .get("preset")
        .ok_or_else(|| anyhow!("--preset required"))?;
    let p = preset(name).ok_or_else(|| {
        anyhow!(
            "unknown preset {name}; have: {}",
            PRESETS.iter().map(|p| p.name).collect::<Vec<_>>().join(" ")
        )
    })?;
    let seed = a.u64_or("seed", 42)?;
    let cache = a.str_or("cache", "data");
    let t = Timer::start();
    let ds = build_cached(p, seed, std::path::Path::new(&cache))?;
    eprintln!(
        "dataset {} ready in {:.2}s (cache {})",
        p.name,
        t.secs(),
        cache
    );
    Ok(ds)
}

fn cmd_datagen(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &["preset", "seed", "cache"])?;
    let ds = load_ds(&a)?;
    let (dmin, dmax, davg) = ds.graph.degree_stats();
    let (tr, va, te) = ds.split_counts();
    // Table 3 / Table 12 style report
    println!("name       : {}", ds.name);
    println!("task       : {:?}", ds.task);
    println!("#nodes     : {}", ds.n());
    println!("#edges     : {}", ds.graph.num_edges());
    println!("#labels    : {}", ds.num_classes);
    println!("#features  : {}", ds.f_in);
    println!("degree     : min {dmin} max {dmax} avg {davg:.1}");
    println!("splits     : {tr}/{va}/{te} (train/val/test)");
    Ok(())
}

fn cmd_partition(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &["preset", "seed", "cache", "parts", "algo"])?;
    let ds = load_ds(&a)?;
    let k = a.usize_or(
        "parts",
        preset(&ds.name).map(|p| p.default_partitions).unwrap_or(10),
    )?;
    let algo = a.str_or("algo", "multilevel");
    let mut rng = Rng::new(a.u64_or("seed", 42)? ^ 0xBEEF);
    let t = Timer::start();
    let part = match algo.as_str() {
        "multilevel" => MultilevelPartitioner::default().partition(&ds.graph, k, &mut rng),
        "random" => RandomPartitioner.partition(&ds.graph, k, &mut rng),
        other => bail!("unknown algo {other}"),
    };
    let secs = t.secs();
    let stats = crate::partition::metrics::stats(&ds.graph, &part, k);
    // Table 13 style report
    println!("algo             : {algo}");
    println!("#partitions      : {k}");
    println!("clustering time  : {secs:.2}s");
    println!(
        "edge cut         : {} ({:.1}% of entries)",
        stats.edge_cut,
        100.0 * (1.0 - stats.within_fraction)
    );
    println!("within fraction  : {:.3}", stats.within_fraction);
    println!("balance          : {:.3}", stats.balance);
    println!("part sizes       : min {} max {}", stats.min_part, stats.max_part);
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let a = Args::parse(
        argv,
        &[
            "preset", "seed", "cache", "layers", "epochs", "method", "q",
            "parts", "norm", "lr", "artifacts", "eval-every", "hidden",
            "lr-decay", "lr-decay-every", "patience", "save",
        ],
    )?;
    let ds = load_ds(&a)?;
    let p = preset(&ds.name).unwrap();
    let layers = a.usize_or("layers", 2)?;
    let method = a.str_or("method", "cluster");
    let artifacts = a.str_or("artifacts", "artifacts");
    let mut engine = Engine::new(std::path::Path::new(&artifacts))?;

    let short = ds.name.trim_end_matches("_like");
    let artifact = match method.as_str() {
        "cluster" => match a.get("hidden") {
            Some("512") if short == "reddit" => format!("reddit_h512_L{layers}"),
            _ => format!("{short}_L{layers}"),
        },
        "graphsage" => format!("{short}_sage_L{layers}"),
        "vrgcn" => format!("{short}_vrgcn_L{layers}"),
        other => bail!("unknown method {other}"),
    };

    let opts = TrainOptions {
        lr: a.f64_or("lr", 0.01)? as f32,
        epochs: a.usize_or("epochs", 40)?,
        eval_every: a.usize_or("eval-every", 5)?,
        seed: a.u64_or("seed", 0)?,
        norm: parse_norm(&a.str_or("norm", "sym"))?,
        eval_split: crate::graph::Split::Val,
        max_steps_per_epoch: 0,
        schedule: match a.get("lr-decay") {
            Some(f) => crate::coordinator::LrSchedule::StepDecay {
                every: a.usize_or("lr-decay-every", 20)?,
                factor: f.parse().map_err(|_| anyhow!("bad --lr-decay"))?,
            },
            None => crate::coordinator::LrSchedule::Constant,
        },
        patience: a.usize_or("patience", 0)?,
    };

    let t = Timer::start();
    let result = match method.as_str() {
        "cluster" => {
            let parts = a.usize_or("parts", p.default_partitions)?;
            let q = a.usize_or("q", p.default_q)?;
            let mut rng = Rng::new(opts.seed ^ 0xBEEF);
            let pt = Timer::start();
            let part =
                MultilevelPartitioner::default().partition(&ds.graph, parts, &mut rng);
            eprintln!("partitioned into {parts} parts in {:.2}s", pt.secs());
            let sampler = ClusterSampler::new(parts_to_clusters(&part, parts), q);
            train(&mut engine, &ds, &sampler, &artifact, &opts)?
        }
        "graphsage" => {
            let params = crate::baselines::SageParams::for_depth(layers, 128);
            crate::baselines::train_graphsage(&mut engine, &ds, &artifact, &params, &opts)?
        }
        "vrgcn" => {
            let params = crate::baselines::VrgcnParams::default();
            crate::baselines::train_vrgcn(&mut engine, &ds, &artifact, &params, &opts)?
        }
        _ => unreachable!(),
    };

    if let Some(path) = a.get("save") {
        crate::coordinator::checkpoint::save(
            &result.state,
            &artifact,
            std::path::Path::new(path),
        )?;
        eprintln!("checkpoint saved to {path}");
    }
    println!("method        : {method} ({artifact})");
    println!("epochs        : {}", opts.epochs);
    println!("steps         : {}", result.steps);
    println!(
        "train time    : {:.2}s (wall {:.2}s)",
        result.train_seconds,
        t.secs()
    );
    println!("peak memory   : {:.1} MB", result.peak_bytes as f64 / 1e6);
    println!("curve (epoch, train_s, loss, val_f1):");
    for pt in &result.curve {
        println!(
            "  {:4}  {:8.2}  {:.4}  {:.4}",
            pt.epoch, pt.train_seconds, pt.train_loss, pt.eval_f1
        );
    }
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let a = Args::parse(
        argv,
        &["preset", "seed", "cache", "checkpoint", "norm", "split"],
    )?;
    let ds = load_ds(&a)?;
    let ckpt = a
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let (state, artifact) =
        crate::coordinator::checkpoint::load(std::path::Path::new(ckpt))?;
    let norm = parse_norm(&a.str_or("norm", "sym"))?;
    let split = match a.str_or("split", "test").as_str() {
        "val" => crate::graph::Split::Val,
        "test" => crate::graph::Split::Test,
        other => bail!("unknown split {other}"),
    };
    let nodes = ds.nodes_in_split(split);
    let t = Timer::start();
    let f1 = crate::coordinator::evaluate(&ds, &state.weights, norm, false, &nodes);
    println!("checkpoint    : {ckpt} (trained via {artifact}, step {})", state.step);
    println!("split         : {split:?} ({} nodes)", nodes.len());
    println!("micro-F1      : {f1:.4}  ({:.2}s exact host inference)", t.secs());
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &["artifacts"])?;
    let dir = a.str_or("artifacts", "artifacts");
    let reg = crate::runtime::Registry::load(std::path::Path::new(&dir))?;
    println!(
        "{:<22} {:>5} {:>7} {:>6} {:>6} {:>7} {:>9} {:>6}",
        "artifact", "kind", "layers", "f_in", "f_hid", "b_max", "vmem_est", "mxu"
    );
    for name in reg.names() {
        let m = reg.get(name)?;
        println!(
            "{:<22} {:>5} {:>7} {:>6} {:>6} {:>7} {:>8.1}M {:>6.2}",
            m.name,
            match m.kind {
                crate::runtime::Kind::Train => "train",
                crate::runtime::Kind::Forward => "fwd",
                crate::runtime::Kind::Vrgcn => "vrgcn",
            },
            m.layers,
            m.f_in,
            m.f_hid,
            m.b_max,
            m.vmem_bytes_est as f64 / 1e6,
            m.mxu_utilization_est,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_parsing() {
        assert_eq!(parse_norm("sym").unwrap(), NormConfig::PAPER_DEFAULT);
        assert_eq!(parse_norm("row+l1").unwrap(), NormConfig::ROW_LAMBDA1);
        assert!(parse_norm("bogus").is_err());
    }
}
