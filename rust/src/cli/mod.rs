//! `cluster-gcn` command-line interface — a thin shell over
//! [`crate::session::Session`]: dataset generation, graph partitioning,
//! training (Cluster-GCN + all baselines, on either backend),
//! checkpoint evaluation, and artifact inspection.
//!
//! The usage block below is included verbatim from `usage.txt` — the
//! same file [`USAGE`] is built from and `main` prints for `--help`, so
//! the docs and the runtime help cannot drift:
//!
#![doc = concat!("```text\n", include_str!("usage.txt"), "```")]

pub mod args;

use anyhow::{anyhow, bail, Result};

use crate::baselines::VrgcnParams;
use crate::coordinator::checkpoint::{self, RotatingCheckpoint};
use crate::datagen::{build_cached, preset, PRESETS};
use crate::norm::NormConfig;
use crate::runtime::distributed::WorkerSetup;
use crate::runtime::{
    Backend, Compression, DistConfig, DistributedBackend, Engine, HostBackend,
    ManifestMissing, ShardedBackend, Transport,
};
use crate::serve::{generate, run_load, LoadConfig, Mix, ServeConfig, ServeMode};
use crate::session::guard::{rotation_base, run_guarded, GuardConfig};
use crate::session::{EvalStrategy, Method, Session, StderrObserver, TrainConfig};
use crate::util::{failpoint, Json, Timer};
use args::Args;

/// The `--help` text; single source of truth shared with the module
/// docs via `include_str!("usage.txt")`.
pub const USAGE: &str = include_str!("usage.txt");

/// One subcommand's full flag surface — the single source of truth
/// shared by every `Args::parse` call site and the usage-drift test
/// (`usage_flags_match_command_whitelists`), so the synopsis in
/// `usage.txt` and the parser whitelists cannot diverge.
pub struct CommandSpec {
    /// Subcommand name as dispatched by `main`.
    pub name: &'static str,
    /// Every accepted `--key` (value flags and boolean switches).
    pub keys: &'static [&'static str],
    /// The subset of `keys` that are boolean switches (never take a
    /// value).
    pub bools: &'static [&'static str],
}

/// Flag surface of every public subcommand.  The hidden `__worker`
/// dispatch (the spawned distributed-training worker entry) takes no
/// flags and is deliberately absent.
pub const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "datagen",
        keys: &["preset", "seed", "cache", "storage", "chunk-rows"],
        bools: &[],
    },
    CommandSpec {
        name: "partition",
        keys: &["preset", "seed", "cache", "parts", "algo"],
        bools: &[],
    },
    CommandSpec {
        name: "train",
        keys: &[
            "preset", "seed", "cache", "layers", "epochs", "method", "q",
            "parts", "norm", "lr", "artifacts", "eval-every", "hidden",
            "lr-decay", "lr-decay-every", "patience", "save", "backend",
            "batch", "algo", "shards", "prefetch", "no-prefetch", "eval",
            "eval-parts", "resume", "checkpoint-every", "guard",
            "guard-retries", "lr-backoff", "keep", "failpoints", "fail-seed",
            "storage", "chunk-rows", "workers", "transport", "compress",
        ],
        bools: &["prefetch", "no-prefetch", "guard"],
    },
    CommandSpec {
        name: "eval",
        keys: &[
            "preset", "seed", "cache", "checkpoint", "norm", "split",
            "storage", "chunk-rows",
        ],
        bools: &[],
    },
    CommandSpec {
        name: "serve",
        keys: &[
            "preset", "seed", "cache", "layers", "hidden", "parts", "algo",
            "norm", "checkpoint", "queries", "batch", "mix", "hot-frac",
            "hot-weight", "cross", "clients", "mode", "out", "no-warm",
            "queue", "shed", "deadline-ms", "degrade-after", "failpoints",
            "fail-seed", "storage", "chunk-rows",
        ],
        bools: &["no-warm", "shed"],
    },
    CommandSpec {
        name: "table8",
        keys: &[
            "preset", "seed", "cache", "storage", "chunk-rows", "parts", "q",
            "group-cap", "layers", "hidden", "epochs", "eval-every", "lr",
            "norm", "out",
        ],
        bools: &[],
    },
    CommandSpec { name: "inspect", keys: &["artifacts"], bools: &[] },
];

/// Parse `argv` against the named subcommand's [`CommandSpec`].
fn parse_cmd(name: &str, argv: &[String]) -> Result<Args> {
    let c = COMMANDS
        .iter()
        .find(|c| c.name == name)
        .expect("every dispatched command has a CommandSpec");
    Args::parse(argv, c.keys, c.bools)
}

pub fn parse_norm(s: &str) -> Result<NormConfig> {
    Ok(match s {
        "sym" => NormConfig::PAPER_DEFAULT,
        "row" => NormConfig::ROW,
        "row+id" => NormConfig::ROW_IDENTITY,
        "row+l1" => NormConfig::ROW_LAMBDA1,
        other => bail!("unknown norm {other} (sym|row|row+id|row+l1)"),
    })
}

pub fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{}", USAGE);
        return Ok(());
    }
    // chaos-testing hook: CGCN_FAILPOINTS/CGCN_FAIL_SEED activate the
    // deterministic fault-injection registry for any subcommand; an
    // explicit --failpoints flag (train/serve) overrides the env spec
    match failpoint::install_from_env() {
        Ok(true) => eprintln!("failpoints active (CGCN_FAILPOINTS)"),
        Ok(false) => {}
        Err(e) => return Err(anyhow!("bad CGCN_FAILPOINTS: {e}")),
    }
    match argv[0].as_str() {
        "datagen" => cmd_datagen(&argv),
        "partition" => cmd_partition(&argv),
        "train" => cmd_train(&argv),
        "eval" => cmd_eval(&argv),
        "serve" => cmd_serve(&argv),
        "table8" => cmd_table8(&argv),
        "inspect" => cmd_inspect(&argv),
        // hidden: the distributed-training worker entry point; spawned
        // by the chief with its rendezvous in CGCN_DIST_* env vars,
        // never invoked by hand (hence absent from COMMANDS and usage)
        "__worker" => crate::runtime::distributed::worker_main(),
        other => Err(anyhow!("unknown command {other}\n{USAGE}")),
    }
}

fn load_ds(a: &Args) -> Result<crate::graph::Dataset> {
    let name = a
        .get("preset")
        .ok_or_else(|| anyhow!("--preset required"))?;
    let p = preset(name).ok_or_else(|| {
        anyhow!(
            "unknown preset {name}; have: {}",
            PRESETS.iter().map(|p| p.name).collect::<Vec<_>>().join(" ")
        )
    })?;
    let seed = a.u64_or("seed", 42)?;
    let cache = a.str_or("cache", "data");
    let t = Timer::start();
    let ds = build_cached(p, seed, std::path::Path::new(&cache))?;
    eprintln!(
        "dataset {} ready in {:.2}s (cache {})",
        p.name,
        t.secs(),
        cache
    );
    Ok(ds)
}

/// Resolve the preset named by `--preset` (or `fallback` when given).
fn resolve_preset(a: &Args, fallback: Option<&str>) -> Result<&'static crate::datagen::Preset> {
    let name = match (a.get("preset"), fallback) {
        (Some(n), _) => n.to_string(),
        (None, Some(f)) => f.to_string(),
        (None, None) => return Err(anyhow!("--preset required")),
    };
    preset(&name).ok_or_else(|| {
        anyhow!(
            "unknown preset {name}; have: {}",
            PRESETS.iter().map(|p| p.name).collect::<Vec<_>>().join(" ")
        )
    })
}

/// Build (or open from cache) the on-disk `CGCNGS01` store for the
/// `--preset`/`--seed` of `a` — the out-of-core twin of [`load_ds`].
fn load_store(a: &Args) -> Result<crate::graph::DiskDataset> {
    let p = resolve_preset(a, None)?;
    let seed = a.u64_or("seed", 42)?;
    let cache = a.str_or("cache", "data");
    let chunk_rows = a.usize_or("chunk-rows", 0)?;
    let t = Timer::start();
    let dd = crate::datagen::build_cached_store(
        p,
        seed,
        std::path::Path::new(&cache),
        chunk_rows,
    )?;
    eprintln!(
        "store {} ready in {:.2}s ({})",
        p.name,
        t.secs(),
        dd.path().display()
    );
    Ok(dd)
}

/// `--storage ram` (default) loads/builds the resident dataset;
/// `--storage disk` builds the chunk-streamed store and materializes a
/// dataset from it (byte-identical to the RAM build — pinned by the
/// `stream` tests).  Commands whose math requires residency (exact
/// eval, serving) go through this; the out-of-core paths
/// (`train --storage disk`, `table8`) never materialize.
fn load_ds_storage(a: &Args) -> Result<crate::graph::Dataset> {
    match a.str_or("storage", "ram").as_str() {
        "ram" => load_ds(a),
        "disk" => Ok(load_store(a)?.to_dataset()?),
        other => bail!("unknown storage {other} (ram|disk)"),
    }
}

fn cmd_datagen(argv: &[String]) -> Result<()> {
    let a = parse_cmd("datagen", argv)?;
    if a.str_or("storage", "ram") == "disk" {
        // report straight off the store header + offset index — the
        // 2M-node preset never fits as a resident Dataset
        let dd = load_store(&a)?;
        let n = dd.n();
        let (mut dmin, mut dmax, mut dsum) = (usize::MAX, 0usize, 0u64);
        let (mut tr, mut va, mut te) = (0usize, 0usize, 0usize);
        for v in 0..n {
            let d = dd.degree(v);
            dmin = dmin.min(d);
            dmax = dmax.max(d);
            dsum += d as u64;
            match dd.split_of(v) {
                crate::graph::Split::Train => tr += 1,
                crate::graph::Split::Val => va += 1,
                crate::graph::Split::Test => te += 1,
            }
        }
        println!("name       : {}", dd.name);
        println!("task       : {:?}", dd.task);
        println!("#nodes     : {n}");
        println!("#edges     : {}", dd.nnz() / 2);
        println!("#labels    : {}", dd.num_classes);
        println!("#features  : {}", dd.f_in);
        println!(
            "degree     : min {} max {dmax} avg {:.1}",
            if n == 0 { 0 } else { dmin },
            dsum as f64 / n.max(1) as f64
        );
        println!("splits     : {tr}/{va}/{te} (train/val/test)");
        println!("store      : {}", dd.path().display());
        return Ok(());
    }
    let ds = load_ds_storage(&a)?;
    let (dmin, dmax, davg) = ds.graph.degree_stats();
    let (tr, va, te) = ds.split_counts();
    // Table 3 / Table 12 style report
    println!("name       : {}", ds.name);
    println!("task       : {:?}", ds.task);
    println!("#nodes     : {}", ds.n());
    println!("#edges     : {}", ds.graph.num_edges());
    println!("#labels    : {}", ds.num_classes);
    println!("#features  : {}", ds.f_in);
    println!("degree     : min {dmin} max {dmax} avg {davg:.1}");
    println!("splits     : {tr}/{va}/{te} (train/val/test)");
    Ok(())
}

fn cmd_partition(argv: &[String]) -> Result<()> {
    use crate::partition::{MultilevelPartitioner, Partitioner, RandomPartitioner};
    use crate::util::Rng;

    let a = parse_cmd("partition", argv)?;
    let ds = load_ds(&a)?;
    let k = a.usize_or(
        "parts",
        preset(&ds.name).map(|p| p.default_partitions).unwrap_or(10),
    )?;
    let algo = a.str_or("algo", "multilevel");
    let mut rng = Rng::new(a.u64_or("seed", 42)? ^ 0xBEEF);
    let t = Timer::start();
    let part = match algo.as_str() {
        "multilevel" => MultilevelPartitioner::default().partition(&ds.graph, k, &mut rng),
        "random" => RandomPartitioner.partition(&ds.graph, k, &mut rng),
        other => bail!("unknown algo {other}"),
    };
    let secs = t.secs();
    let stats = crate::partition::metrics::stats(&ds.graph, &part, k);
    // Table 13 style report
    println!("algo             : {algo}");
    println!("#partitions      : {k}");
    println!("clustering time  : {secs:.2}s");
    println!(
        "edge cut         : {} ({:.1}% of entries)",
        stats.edge_cut,
        100.0 * (1.0 - stats.within_fraction)
    );
    println!("within fraction  : {:.3}", stats.within_fraction);
    println!("balance          : {:.3}", stats.balance);
    println!("part sizes       : min {} max {}", stats.min_part, stats.max_part);
    Ok(())
}

/// Build the execution backend the `--backend` flag names.  A PJRT
/// request with no artifacts present gets a pointed suggestion instead
/// of a raw path error.
fn make_backend(a: &Args) -> Result<Box<dyn Backend>> {
    let kind = a.str_or("backend", "pjrt");
    match kind.as_str() {
        "host" => Ok(Box::new(HostBackend::new())),
        "pjrt" => {
            let dir = a.str_or("artifacts", "artifacts");
            match Engine::new(std::path::Path::new(&dir)) {
                Ok(engine) => Ok(Box::new(engine)),
                Err(e) if e.downcast_ref::<ManifestMissing>().is_some() => Err(anyhow!(
                    "{e}\nhint: build the AOT artifacts with `make artifacts`, \
                     or train artifact-free with `--backend host`"
                )),
                Err(e) => Err(e),
            }
        }
        other => bail!("unknown backend {other} (pjrt|host)"),
    }
}

/// Install the per-command `--failpoints SPEC` (seeded by
/// `--fail-seed`), replacing whatever `CGCN_FAILPOINTS` set up.
fn install_failpoints(a: &Args) -> Result<()> {
    if let Some(spec) = a.get("failpoints") {
        let seed = a.u64_or("fail-seed", 0)?;
        failpoint::install(spec, seed).map_err(|e| anyhow!("bad --failpoints: {e}"))?;
        eprintln!("failpoints installed: {spec} (seed {seed})");
    }
    Ok(())
}

/// Per-site hit/fire counters, printed after a chaos run so the sweep
/// can assert its faults actually landed.
fn print_failpoint_report() {
    if !failpoint::active() {
        return;
    }
    for r in failpoint::report() {
        eprintln!("failpoint {:<16} {} hits, {} fires", r.site, r.hits, r.fires);
    }
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let a = parse_cmd("train", argv)?;
    install_failpoints(&a)?;
    match a.str_or("storage", "ram").as_str() {
        "ram" => {}
        "disk" => return cmd_train_disk(&a),
        other => bail!("unknown storage {other} (ram|disk)"),
    }
    let ds = load_ds(&a)?;
    let p = preset(&ds.name).unwrap();
    let layers = a.usize_or("layers", 2)?;

    let method_name = a.str_or("method", "cluster");
    let method = match method_name.as_str() {
        "cluster" => Method::Cluster { q: a.usize_or("q", p.default_q)? },
        "expansion" => Method::Expansion { batch: a.usize_or("batch", 32)? },
        "graphsage" => Method::graphsage(layers, a.usize_or("batch", 128)?),
        "vrgcn" => Method::VrGcn(VrgcnParams {
            batch: a.usize_or("batch", VrgcnParams::default().batch)?,
            ..VrgcnParams::default()
        }),
        other => bail!("unknown method {other} (cluster|expansion|graphsage|vrgcn)"),
    };

    // ---- backend (base or combinator stack) ---------------------------
    // built through a factory so the guard can rebuild a fresh backend
    // for every recovery attempt
    let backend_kind = a.str_or("backend", "pjrt");
    let shards = a.usize_or("shards", 1)?;
    if shards > 1 {
        if backend_kind != "host" {
            bail!(
                "--shards {shards} needs --backend host: the PJRT step is \
                 fused and cannot expose the per-batch gradients a \
                 data-parallel all-reduce averages"
            );
        }
        if a.flag("prefetch") {
            eprintln!(
                "note: --prefetch is a pass-through on a sharded backend \
                 (it pulls its replicas' batches itself)"
            );
        }
    }
    // ---- cross-process distributed backend (--workers N) --------------
    // chief + N spawned worker processes exchanging gradients over
    // UNIX/TCP sockets; partition-aligned placement means each worker
    // assembles only its own clusters' batches
    let workers = a.usize_or("workers", 1)?;
    let distributed = a.get("workers").is_some();
    if distributed {
        if workers == 0 {
            bail!("--workers must be >= 1");
        }
        if shards > 1 {
            bail!(
                "--workers and --shards are exclusive: pick in-process \
                 replicas (--shards) or worker processes (--workers)"
            );
        }
        if a.flag("guard") {
            bail!(
                "--guard is not supported with --workers: the guard rebuilds \
                 its backend per recovery attempt, which would respawn the \
                 worker fleet mid-run (distributed runs recover from socket \
                 faults internally; see --failpoints dist.*)"
            );
        }
        if backend_kind != "host" {
            bail!(
                "--workers {workers} needs --backend host: workers compute \
                 gradients on the host kernels and the chief applies the \
                 averaged update with the same math"
            );
        }
        if method_name != "cluster" {
            bail!(
                "--workers supports --method cluster only: graph partitions \
                 are the unit of worker ownership (got {method_name})"
            );
        }
    } else if a.get("transport").is_some() || a.get("compress").is_some() {
        bail!("--transport/--compress only apply with --workers N");
    }
    let transport = Transport::parse(&a.str_or("transport", "unix"))?;
    let compression = Compression::parse(&a.str_or("compress", "none"))?;

    let build_backend = || -> Result<Box<dyn Backend>> {
        if shards > 1 {
            Ok(Box::new(ShardedBackend::host(shards)))
        } else {
            make_backend(&a)
        }
    };
    // assembly/execute overlap is on by default (the session wraps the
    // backend in a PrefetchBackend); --no-prefetch forces serial,
    // --prefetch is the explicit default for scripts
    let prefetch = !a.flag("no-prefetch") || a.flag("prefetch");

    let eval = match a.str_or("eval", "exact").as_str() {
        "exact" => EvalStrategy::ExactFullGraph,
        "clustered" => {
            if backend_kind == "pjrt" && shards <= 1 {
                bail!(
                    "--eval clustered needs --backend host: clustered eval \
                     runs batched forward passes through the training model \
                     id, and PJRT train artifacts expose no forward entry"
                );
            }
            EvalStrategy::Clustered {
                parts: a.usize_or(
                    "eval-parts",
                    a.usize_or("parts", p.default_partitions)?,
                )?,
            }
        }
        other => bail!("unknown eval strategy {other} (exact|clustered)"),
    };

    // ---- resume from a checkpoint (weights + recorded epoch; v2/v3
    // files additionally restore the VR-GCN history so the resumed run
    // is a bitwise replay of the uninterrupted one).  A torn/corrupt
    // file falls back to the newest intact rotation sibling
    // (`<path>.e<epoch>`) instead of refusing to start. ----------------
    let resumed = match a.get("resume") {
        Some(path) => {
            let (ck, loaded) =
                checkpoint::load_full_or_fallback(std::path::Path::new(path))?;
            if loaded != std::path::Path::new(path) {
                eprintln!(
                    "warning: {path} is torn or corrupt; falling back to {}",
                    loaded.display()
                );
            }
            eprintln!(
                "resuming from {} (model {}, step {}, epoch {}{})",
                loaded.display(),
                ck.artifact,
                ck.state.step,
                ck.epoch,
                if ck.history.is_some() { ", with VR-GCN history" } else { "" }
            );
            Some(ck)
        }
        None => None,
    };

    let hidden = a.usize_or("hidden", 0)?;
    let cfg = TrainConfig {
        layers,
        hidden: if hidden == 0 { None } else { Some(hidden) },
        b_max: None,
        lr: a.f64_or("lr", 0.01)? as f32,
        epochs: a.usize_or("epochs", 40)?,
        eval_every: a.usize_or("eval-every", 5)?,
        seed: a.u64_or("seed", 0)?,
        eval_split: crate::graph::Split::Val,
        max_steps_per_epoch: 0,
        schedule: match a.get("lr-decay") {
            Some(f) => crate::coordinator::LrSchedule::StepDecay {
                every: a.usize_or("lr-decay-every", 20)?,
                factor: f.parse().map_err(|_| anyhow!("bad --lr-decay"))?,
            },
            None => crate::coordinator::LrSchedule::Constant,
        },
        patience: a.usize_or("patience", 0)?,
        norm: parse_norm(&a.str_or("norm", "sym"))?,
        eval,
        start_epoch: resumed.as_ref().map(|ck| ck.epoch).unwrap_or(0),
        checkpoint_every: a.usize_or("checkpoint-every", 0)?,
    };
    if resumed.is_some() && cfg.start_epoch >= cfg.epochs {
        bail!(
            "checkpoint was saved at epoch {} but --epochs is {}; raise \
             --epochs to continue training",
            cfg.start_epoch,
            cfg.epochs
        );
    }

    let parts_n: Option<usize> = match a.get("parts") {
        Some(p) => Some(
            p.parse()
                .map_err(|_| anyhow!("--parts expects an integer, got {p:?}"))?,
        ),
        None => None,
    };
    let random_algo = match a.str_or("algo", "multilevel").as_str() {
        "multilevel" => false,
        "random" => true,
        other => bail!("unknown algo {other} (multilevel|random)"),
    };

    // ---- self-healing path: run under the session guard ---------------
    if a.flag("guard") {
        let save = a.get("save").map(std::path::PathBuf::from);
        let base = match &save {
            Some(p) => rotation_base(p),
            None => bail!(
                "--guard needs --save FILE: its rolling last-good \
                 checkpoints live at <FILE>.guard.e<epoch>"
            ),
        };
        let store = RotatingCheckpoint::new(base, a.usize_or("keep", 3)?);
        let gcfg = GuardConfig {
            max_retries: a.usize_or("guard-retries", 3)?,
            lr_backoff: a.f64_or("lr-backoff", 0.5)? as f32,
            checkpoint_every: a.usize_or("checkpoint-every", 1)?,
            ..GuardConfig::default()
        };
        let model = Session::new(&ds)
            .method(method.clone())
            .config(cfg.clone())
            .model_name();
        let mut obs = StderrObserver;
        let t = Timer::start();
        let outcome = run_guarded(
            |ck, lr_scale| {
                let mut cfg = cfg.clone();
                cfg.lr *= lr_scale;
                // resume priority: last-good rollback target, else the
                // --resume checkpoint, else a fresh init
                let init = match ck {
                    Some(c) => Some((c.state.clone(), c.history.clone(), c.epoch)),
                    None => resumed
                        .as_ref()
                        .map(|c| (c.state.clone(), c.history.clone(), c.epoch)),
                };
                let mut session = Session::new(&ds)
                    .method(method.clone())
                    .prefetch(prefetch);
                if let Some(p) = parts_n {
                    session = session.partition(p);
                }
                if random_algo {
                    session = session.partition_random();
                }
                if let Some((state, history, epoch)) = init {
                    cfg.start_epoch = epoch;
                    session = session.initial_state(state);
                    if let Some(h) = history {
                        session = session.initial_history(h);
                    }
                }
                session.config(cfg).backend(build_backend()?).driver()
            },
            &gcfg,
            &store,
            &mut obs,
        )
        .map_err(|e| anyhow!("{e}"))?;
        // materialize the newest intact rotation slot at --save (it
        // carries the epoch stamp and any VR-GCN history); fall back to
        // the bare final state when nothing was rotated
        if let Some(path) = &save {
            match store.load_latest() {
                Ok((ck, _, _)) => checkpoint::save_v3(
                    &ck.state,
                    &ck.artifact,
                    ck.epoch,
                    ck.history.as_ref(),
                    path,
                )?,
                Err(_) => {
                    checkpoint::save_v3(&outcome.result.state, &model, cfg.epochs, None, path)?
                }
            }
        }
        print_failpoint_report();
        println!("method        : {method_name} ({model}, guarded)");
        println!(
            "guard         : {} retries, {} rollbacks, {} ckpt saves, lr scale {}",
            outcome.retries, outcome.rollbacks, outcome.saves, outcome.lr_scale
        );
        println!(
            "epochs        : {}",
            outcome.result.curve.last().map(|c| c.epoch).unwrap_or(0)
        );
        println!("steps         : {}", outcome.result.steps);
        println!(
            "train time    : {:.2}s (wall {:.2}s)",
            outcome.result.train_seconds,
            t.secs()
        );
        println!("curve (epoch, train_s, loss, val_f1):");
        for pt in &outcome.result.curve {
            println!(
                "  {:4}  {:8.2}  {:.4}  {:.4}",
                pt.epoch, pt.train_seconds, pt.train_loss, pt.eval_f1
            );
        }
        return Ok(());
    }

    // distributed: the chief ships a WorkerSetup (configuration only,
    // never graph data) from which each spawned worker re-derives the
    // identical dataset, partition, and epoch plans
    let mut dist_stats = None;
    let backend_box: Box<dyn Backend> = if distributed {
        let setup = WorkerSetup {
            preset: ds.name.clone(),
            // same flag, different defaults: the dataset cache defaults
            // to seed 42, the experiment seed to 0 (matches load_ds and
            // TrainConfig above)
            ds_seed: a.u64_or("seed", 42)?,
            cache: a.str_or("cache", "data"),
            cfg_seed: cfg.seed,
            layers,
            hidden: cfg.hidden,
            b_max: None,
            parts: parts_n,
            q: match &method {
                Method::Cluster { q } => *q,
                _ => unreachable!("validated above"),
            },
            random_partition: random_algo,
            norm: cfg.norm,
            n_workers: workers,
            compression,
        };
        let be = DistributedBackend::new(DistConfig::new(workers, transport, setup));
        dist_stats = Some(be.stats());
        Box::new(be)
    } else {
        build_backend()?
    };

    let mut obs = StderrObserver;
    let mut session = Session::new(&ds)
        .method(method)
        .config(cfg)
        .backend(backend_box)
        .workers(workers)
        .prefetch(prefetch)
        .observer(&mut obs);
    if let Some(ck) = resumed {
        session = session.initial_state(ck.state);
        if let Some(h) = ck.history {
            session = session.initial_history(h);
        }
    }
    if let Some(p) = parts_n {
        session = session.partition(p);
    }
    if random_algo {
        session = session.partition_random();
    }
    if let Some(path) = a.get("save") {
        session = session.save(path);
    }

    let t = Timer::start();
    let out = session.run()?;
    print_failpoint_report();
    println!("method        : {method_name} ({})", out.model);
    println!("backend       : {}{}", out.backend, if shards > 1 {
        format!(" ({shards} shards)")
    } else {
        String::new()
    });
    println!("epochs        : {}", out.result.curve.last().map(|c| c.epoch).unwrap_or(0));
    println!("steps         : {}", out.result.steps);
    println!(
        "train time    : {:.2}s (wall {:.2}s)",
        out.result.train_seconds,
        t.secs()
    );
    println!("peak memory   : {:.1} MB", out.result.peak_bytes as f64 / 1e6);
    if let Some(stats) = &dist_stats {
        use std::sync::atomic::Ordering::Relaxed;
        let epochs_run = out.result.curve.last().map(|c| c.epoch).unwrap_or(0);
        let peak_rss = crate::util::memstat::peak_rss_bytes();
        println!(
            "distributed   : {workers} workers over {} ({} dist steps, {} retries, {} reconnects, {} respawns)",
            transport.label(),
            stats.steps.load(Relaxed),
            stats.retries.load(Relaxed),
            stats.reconnects.load(Relaxed),
            stats.respawns.load(Relaxed),
        );
        println!(
            "wire          : {:.1} MB tx / {:.1} MB rx (grads {}: {:.2}x compression)",
            stats.bytes_tx.load(Relaxed) as f64 / 1e6,
            stats.bytes_rx.load(Relaxed) as f64 / 1e6,
            compression.label(),
            stats.compression_ratio(),
        );
        println!("peak RSS      : {:.1} MB (chief only)", peak_rss as f64 / 1e6);
        let json = Json::obj(vec![
            ("kind", Json::str("distributed")),
            ("preset", Json::str(&ds.name)),
            ("workers", Json::num(workers as f64)),
            ("transport", Json::str(transport.label())),
            ("compress", Json::str(&compression.label())),
            ("epochs", Json::num(epochs_run as f64)),
            ("steps", Json::num(out.result.steps as f64)),
            ("dist_steps", Json::num(stats.steps.load(Relaxed) as f64)),
            ("train_secs", Json::num(out.result.train_seconds)),
            (
                "epoch_secs",
                Json::num(out.result.train_seconds / epochs_run.max(1) as f64),
            ),
            ("bytes_tx", Json::num(stats.bytes_tx.load(Relaxed) as f64)),
            ("bytes_rx", Json::num(stats.bytes_rx.load(Relaxed) as f64)),
            (
                "grad_raw_bytes",
                Json::num(stats.raw_grad_bytes.load(Relaxed) as f64),
            ),
            (
                "grad_wire_bytes",
                Json::num(stats.wire_grad_bytes.load(Relaxed) as f64),
            ),
            ("compression_ratio", Json::num(stats.compression_ratio())),
            ("retries", Json::num(stats.retries.load(Relaxed) as f64)),
            ("reconnects", Json::num(stats.reconnects.load(Relaxed) as f64)),
            ("respawns", Json::num(stats.respawns.load(Relaxed) as f64)),
            (
                "final_loss",
                Json::num(
                    out.result.curve.last().map(|c| c.train_loss).unwrap_or(f64::NAN),
                ),
            ),
            ("peak_rss_bytes", Json::num(peak_rss as f64)),
        ]);
        let out_path = "bench_results/BENCH_distributed.json";
        if let Some(dir) = std::path::Path::new(out_path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(out_path, json.to_string())?;
        println!("report        : {out_path}");
    }
    println!("curve (epoch, train_s, loss, val_f1):");
    for pt in &out.result.curve {
        println!(
            "  {:4}  {:8.2}  {:.4}  {:.4}",
            pt.epoch, pt.train_seconds, pt.train_loss, pt.eval_f1
        );
    }
    Ok(())
}

/// Shared setup for the out-of-core paths (`train --storage disk`,
/// `table8`): open/build the store, partition it with the streaming
/// partitioner (coarse graph only in RAM), and size the model to the
/// sampler like the session does.
struct OocRun {
    store: crate::graph::GraphStorage,
    sampler: crate::coordinator::ClusterSampler,
    spec: crate::runtime::ModelSpec,
    model: String,
    parts: usize,
    q: usize,
    gen_secs: f64,
    partition_secs: f64,
}

fn ooc_setup(a: &Args, p: &crate::datagen::Preset, layers: usize) -> Result<OocRun> {
    use crate::partition::{StreamingParams, StreamingPartitioner};
    use crate::util::Rng;

    let ds_seed = a.u64_or("seed", 42)?;
    let t = Timer::start();
    let dd = load_store(a)?;
    let gen_secs = t.secs();
    let store = crate::graph::GraphStorage::OnDisk(dd);

    let parts = a.usize_or("parts", p.default_partitions)?.max(1);
    let q = a.usize_or("q", p.default_q)?.max(1).min(parts);
    let chunk_rows = a.usize_or("chunk-rows", 0)?;
    let sp = StreamingPartitioner {
        params: StreamingParams {
            group_cap: a.usize_or("group-cap", 8)?,
            chunk_rows: if chunk_rows == 0 {
                crate::graph::store::DEFAULT_CHUNK_ROWS
            } else {
                chunk_rows
            },
            ..StreamingParams::default()
        },
    };
    // same partition-seed convention as `cluster-gcn partition`
    let mut rng = Rng::new(ds_seed ^ 0xBEEF);
    let t = Timer::start();
    let part = sp.partition_storage(&store, parts, &mut rng);
    let partition_secs = t.secs();
    let sampler = crate::coordinator::ClusterSampler::new(
        crate::partition::parts_to_clusters(&part, parts),
        q,
    );

    let hidden = a.usize_or("hidden", 0)?;
    let f_hid = if hidden == 0 { p.f_hid } else { hidden };
    // grow the padded batch to fit the sampler, as the session does
    let b_max = p.b_max.max(sampler.max_batch_nodes()).next_multiple_of(8);
    let spec = crate::runtime::ModelSpec::gcn(
        store.task(),
        layers,
        store.f_in(),
        f_hid,
        store.num_classes(),
        b_max,
    );
    let model = format!("gcn_l{layers}_h{f_hid}_b{b_max}_ooc");
    Ok(OocRun { store, sampler, spec, model, parts, q, gen_secs, partition_secs })
}

/// `train --storage disk`: Cluster-GCN on the host backend with the
/// graph never resident — batches assemble row-by-row from the store,
/// the partitioner streams edge chunks, and the convergence curve uses
/// the clustered eval over the training partitions (a full-graph exact
/// eval would require residency).
fn cmd_train_disk(a: &Args) -> Result<()> {
    for unsupported in
        ["guard", "shards", "resume", "eval", "eval-parts", "failpoints", "workers", "transport", "compress"]
    {
        if a.get(unsupported).is_some() {
            bail!("--{unsupported} is not supported with --storage disk");
        }
    }
    let method_name = a.str_or("method", "cluster");
    if method_name != "cluster" {
        bail!("--storage disk supports --method cluster only (got {method_name})");
    }
    if a.str_or("backend", "host") != "host" {
        bail!(
            "--storage disk trains on --backend host only: the PJRT step is \
             driven through the same assembler, but artifact shape resolution \
             assumes a resident dataset"
        );
    }
    let p = resolve_preset(a, None)?;
    let layers = a.usize_or("layers", 2)?;
    let run = ooc_setup(a, p, layers)?;

    let hidden = a.usize_or("hidden", 0)?;
    let cfg = TrainConfig {
        layers,
        hidden: if hidden == 0 { None } else { Some(hidden) },
        b_max: None,
        lr: a.f64_or("lr", 0.01)? as f32,
        epochs: a.usize_or("epochs", 40)?,
        eval_every: a.usize_or("eval-every", 5)?,
        seed: a.u64_or("seed", 0)?,
        schedule: match a.get("lr-decay") {
            Some(f) => crate::coordinator::LrSchedule::StepDecay {
                every: a.usize_or("lr-decay-every", 20)?,
                factor: f.parse().map_err(|_| anyhow!("bad --lr-decay"))?,
            },
            None => crate::coordinator::LrSchedule::Constant,
        },
        patience: a.usize_or("patience", 0)?,
        norm: parse_norm(&a.str_or("norm", "sym"))?,
        ..TrainConfig::default()
    };

    let mut backend = HostBackend::new();
    backend.register_model(&run.model, run.spec.clone());
    let t = Timer::start();
    let out = crate::coordinator::train_storage(
        &mut backend,
        &run.store,
        &run.sampler,
        &run.model,
        &cfg,
    )?;
    let wall = t.secs();
    if let Some(path) = a.get("save") {
        checkpoint::save_v3(
            &out.state,
            &run.model,
            cfg.epochs,
            None,
            std::path::Path::new(path),
        )?;
        eprintln!("saved checkpoint to {path}");
    }
    println!("method        : cluster ({}, out-of-core)", run.model);
    println!("backend       : host (--storage disk)");
    println!("partitions    : {} (q={}, streaming multilevel)", run.parts, run.q);
    println!("epochs        : {}", out.curve.last().map(|c| c.epoch).unwrap_or(0));
    println!("steps         : {}", out.steps);
    println!(
        "train time    : {:.2}s (wall {:.2}s, partition {:.2}s)",
        out.train_seconds, wall, run.partition_secs
    );
    println!("peak memory   : {:.1} MB", out.peak_bytes as f64 / 1e6);
    println!(
        "peak RSS      : {:.1} MB",
        crate::util::memstat::peak_rss_bytes() as f64 / 1e6
    );
    println!("curve (epoch, train_s, loss, clustered_val_f1):");
    for pt in &out.curve {
        println!(
            "  {:4}  {:8.2}  {:.4}  {:.4}",
            pt.epoch, pt.train_seconds, pt.train_loss, pt.eval_f1
        );
    }
    Ok(())
}

/// `cluster-gcn table8`: the paper's Table 8 experiment — Cluster-GCN
/// on Amazon2M-scale data, recording memory alongside time.  Generates
/// the preset shard-by-shard into the `CGCNGS01` store (O(chunk)
/// resident), partitions it with the streaming coarsener, trains
/// out-of-core on the host backend, and writes peak RSS + phase
/// timings to a benchmark JSON.
fn cmd_table8(argv: &[String]) -> Result<()> {
    let a = parse_cmd("table8", argv)?;
    match a.str_or("storage", "disk").as_str() {
        "disk" => {}
        "ram" => bail!("table8 is the out-of-core benchmark; use `train` for RAM runs"),
        other => bail!("unknown storage {other} (disk)"),
    }
    let p = resolve_preset(&a, Some("amazon2m_full"))?;
    let layers = a.usize_or("layers", 2)?;
    let run = ooc_setup(&a, p, layers)?;

    let hidden = a.usize_or("hidden", 0)?;
    let cfg = TrainConfig {
        layers,
        hidden: if hidden == 0 { None } else { Some(hidden) },
        lr: a.f64_or("lr", 0.01)? as f32,
        epochs: a.usize_or("epochs", 5)?,
        // Table 8 reports time/memory, not a convergence curve: default
        // to a single final clustered eval
        eval_every: a.usize_or("eval-every", 0)?,
        seed: a.u64_or("seed", 0)?,
        norm: parse_norm(&a.str_or("norm", "sym"))?,
        ..TrainConfig::default()
    };

    let mut backend = HostBackend::new();
    backend.register_model(&run.model, run.spec.clone());
    let t = Timer::start();
    let out = crate::coordinator::train_storage(
        &mut backend,
        &run.store,
        &run.sampler,
        &run.model,
        &cfg,
    )?;
    let wall = t.secs();
    let epochs_run = out.curve.last().map(|c| c.epoch).unwrap_or(cfg.epochs);
    let final_pt = out.curve.last();
    let peak_rss = crate::util::memstat::peak_rss_bytes();

    let out_path = a.str_or("out", "bench_results/BENCH_table8.json");
    let json = Json::obj(vec![
        ("kind", Json::str("table8")),
        ("preset", Json::str(p.name)),
        ("storage", Json::str("disk")),
        ("n", Json::num(run.store.n() as f64)),
        ("nnz", Json::num(run.store.nnz() as f64)),
        ("parts", Json::num(run.parts as f64)),
        ("q", Json::num(run.q as f64)),
        ("layers", Json::num(layers as f64)),
        ("b_max", Json::num(run.spec.b_max as f64)),
        ("epochs", Json::num(epochs_run as f64)),
        ("steps", Json::num(out.steps as f64)),
        ("gen_secs", Json::num(run.gen_secs)),
        ("partition_secs", Json::num(run.partition_secs)),
        ("train_secs", Json::num(out.train_seconds)),
        ("wall_secs", Json::num(wall)),
        (
            "epoch_secs",
            Json::num(out.train_seconds / epochs_run.max(1) as f64),
        ),
        (
            "final_loss",
            Json::num(final_pt.map(|c| c.train_loss).unwrap_or(f64::NAN)),
        ),
        (
            "final_f1",
            Json::num(final_pt.map(|c| c.eval_f1).unwrap_or(f64::NAN)),
        ),
        ("peak_batch_bytes", Json::num(out.peak_bytes as f64)),
        ("peak_rss_bytes", Json::num(peak_rss as f64)),
        (
            "within_edges_per_node",
            Json::num(out.avg_within_edges_per_node),
        ),
    ]);
    if let Some(dir) = std::path::Path::new(&out_path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out_path, json.to_string())?;

    println!("preset        : {} ({} nodes, {} edges)", p.name, run.store.n(), run.store.nnz() / 2);
    println!("partitions    : {} (q={})", run.parts, run.q);
    println!("phases        : gen {:.2}s  partition {:.2}s  train {:.2}s (wall {:.2}s)", run.gen_secs, run.partition_secs, out.train_seconds, wall);
    println!("per epoch     : {:.2}s over {epochs_run} epochs ({} steps)", out.train_seconds / epochs_run.max(1) as f64, out.steps);
    if let Some(pt) = final_pt {
        println!("final         : loss {:.4}  clustered val F1 {:.4}", pt.train_loss, pt.eval_f1);
    }
    println!("peak batch    : {:.1} MB", out.peak_bytes as f64 / 1e6);
    println!("peak RSS      : {:.1} MB", peak_rss as f64 / 1e6);
    println!("report        : {out_path}");
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let a = parse_cmd("eval", argv)?;
    let ds = load_ds_storage(&a)?;
    let ckpt = a
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let (state, model) =
        crate::coordinator::checkpoint::load(std::path::Path::new(ckpt))?;
    let norm = parse_norm(&a.str_or("norm", "sym"))?;
    let split = match a.str_or("split", "test").as_str() {
        "val" => crate::graph::Split::Val,
        "test" => crate::graph::Split::Test,
        other => bail!("unknown split {other}"),
    };
    let nodes = ds.nodes_in_split(split);
    let t = Timer::start();
    let f1 = crate::coordinator::evaluate(&ds, &state.weights, norm, false, &nodes);
    println!("checkpoint    : {ckpt} (trained via {model}, step {})", state.step);
    println!("split         : {split:?} ({} nodes)", nodes.len());
    println!("micro-F1      : {f1:.4}  ({:.2}s exact host inference)", t.secs());
    Ok(())
}

/// `cluster-gcn serve`: build an online-serving front over a preset
/// graph (optionally loading trained weights from a `CGCNCKP2`
/// checkpoint), warm the partition-keyed activation cache, replay a
/// deterministic query mix through the request coalescer from
/// concurrent clients, and write p50/p99 latency, QPS, and cache
/// hit-rate to a benchmark JSON.
fn cmd_serve(argv: &[String]) -> Result<()> {
    let a = parse_cmd("serve", argv)?;
    install_failpoints(&a)?;
    let ds = load_ds_storage(&a)?;
    let seed = a.u64_or("seed", 0)?;
    let hidden = a.usize_or("hidden", 0)?;
    let cfg = TrainConfig {
        layers: a.usize_or("layers", 2)?,
        hidden: if hidden == 0 { None } else { Some(hidden) },
        seed,
        norm: parse_norm(&a.str_or("norm", "sym"))?,
        ..TrainConfig::default()
    };
    let mode = match a.str_or("mode", "exact").as_str() {
        "exact" => ServeMode::ExactCached,
        "clustered" => ServeMode::Clustered,
        other => bail!("unknown serve mode {other} (exact|clustered)"),
    };

    let mut session = Session::new(&ds).config(cfg);
    if let Some(parts) = a.get("parts") {
        session = session.partition(
            parts
                .parse()
                .map_err(|_| anyhow!("--parts expects an integer, got {parts:?}"))?,
        );
    }
    match a.str_or("algo", "multilevel").as_str() {
        "multilevel" => {}
        "random" => session = session.partition_random(),
        other => bail!("unknown algo {other} (multilevel|random)"),
    }
    match a.get("checkpoint") {
        Some(path) => {
            let (ck, loaded) =
                checkpoint::load_full_or_fallback(std::path::Path::new(path))?;
            if loaded != std::path::Path::new(path) {
                eprintln!(
                    "warning: {path} is torn or corrupt; serving fallback {}",
                    loaded.display()
                );
            }
            eprintln!(
                "serving checkpoint {} (model {}, step {}, epoch {})",
                loaded.display(),
                ck.artifact,
                ck.state.step,
                ck.epoch
            );
            session = session.initial_state(ck.state);
        }
        None => eprintln!(
            "note: no --checkpoint given; serving fresh seed-{seed} init weights \
             (latency/cache behavior is representative, predictions are not)"
        ),
    }
    let serve_cfg = ServeConfig {
        mode,
        queue_capacity: a.usize_or("queue", ServeConfig::default().queue_capacity)?,
        shed_when_full: a.flag("shed"),
        deadline_ms: a.u64_or("deadline-ms", 0)?,
        degrade_after: a.usize_or("degrade-after", 0)?,
        ..ServeConfig::default()
    };
    let server = session.into_server(serve_cfg)?;

    let mix_name = a.str_or("mix", "uniform");
    let mix = match mix_name.as_str() {
        "uniform" => Mix::Uniform,
        "hotset" => Mix::Hotset {
            hot_frac: a.f64_or("hot-frac", 0.05)?,
            hot_weight: a.f64_or("hot-weight", 0.9)?,
        },
        other => bail!("unknown mix {other} (uniform|hotset)"),
    };
    let queries = a.usize_or("queries", 1000)?;
    if queries == 0 {
        bail!("--queries must be > 0");
    }
    let load = LoadConfig {
        mix,
        queries,
        batch: a.usize_or("batch", 1)?,
        cross_frac: a.f64_or("cross", 0.1)?,
        seed: seed ^ 0x10AD,
    };
    let plan = generate(ds.n(), server.owner(), server.clusters(), &load);

    if !a.flag("no-warm") {
        let t = Timer::start();
        server.warm();
        eprintln!("cache warmed in {:.2}s", t.secs());
    }
    server.reset_stats();

    let clients = a.usize_or("clients", 4)?;
    let report = run_load(&server, &plan, clients)?;
    let st = server.stats();
    // the invariants the deep-tier CI gate relies on hold by
    // construction (nearest-rank percentiles over floored latencies);
    // fail loudly here rather than shipping a nonsense benchmark file.
    // A fully-shed run has no latencies to bound, so the invariant is
    // conditional on at least one success.
    assert!(
        report.ok == 0 || (report.p99_us >= report.p50_us && report.p50_us > 0.0),
        "latency percentiles violated their invariant: p50 {} p99 {}",
        report.p50_us,
        report.p99_us
    );
    let hit_rate = if st.hits + st.misses > 0 {
        st.hits as f64 / (st.hits + st.misses) as f64
    } else {
        0.0
    };

    let out = a.str_or("out", "bench_results/BENCH_serve.json");
    let json = Json::obj(vec![
        ("kind", Json::str("serve")),
        ("preset", Json::str(&ds.name)),
        ("mode", Json::str(&a.str_or("mode", "exact"))),
        ("mix", Json::str(&mix_name)),
        ("queries", Json::num(queries as f64)),
        ("batch", Json::num(load.batch as f64)),
        ("clients", Json::num(clients as f64)),
        ("p50_us", Json::num(report.p50_us)),
        ("p99_us", Json::num(report.p99_us)),
        ("mean_us", Json::num(report.mean_us)),
        ("qps", Json::num(report.qps)),
        ("wall_secs", Json::num(report.wall_secs)),
        ("cache_hits", Json::num(st.hits as f64)),
        ("cache_misses", Json::num(st.misses as f64)),
        ("cache_evictions", Json::num(st.evictions as f64)),
        ("hit_rate", Json::num(hit_rate)),
        ("flushes", Json::num(st.flushes as f64)),
        ("max_flush", Json::num(st.max_flush as f64)),
        // overload-safety counters (PR 8): the deep-tier CI smoke
        // asserts these keys exist and that a pressured run sheds
        ("ok", Json::num(report.ok as f64)),
        ("shed", Json::num(report.shed as f64)),
        ("timeouts", Json::num(report.timeouts as f64)),
        ("errors", Json::num(report.errors as f64)),
        ("flush_panics", Json::num(st.flush_panics as f64)),
        ("degraded_flushes", Json::num(st.degraded_flushes as f64)),
        (
            "peak_rss_bytes",
            Json::num(crate::util::memstat::peak_rss_bytes() as f64),
        ),
        // u64 digest as hex text: f64 would silently drop low bits
        ("digest", Json::str(&format!("{:016x}", report.digest))),
    ]);
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, json.to_string())?;

    println!("mode          : {:?}", server.mode());
    println!("queries       : {queries} x batch {} ({clients} clients)", load.batch);
    println!("mix           : {mix_name}");
    println!("latency       : p50 {:.1}us  p99 {:.1}us  mean {:.1}us", report.p50_us, report.p99_us, report.mean_us);
    println!("throughput    : {:.0} qps over {:.2}s", report.qps, report.wall_secs);
    println!("coalescing    : {} flushes for {} queries (max flush {})", st.flushes, st.queries, st.max_flush);
    println!("cache         : {} hits / {} misses / {} evictions (hit rate {:.3})", st.hits, st.misses, st.evictions, hit_rate);
    println!(
        "overload      : {} ok / {} shed / {} timeouts / {} errors ({} degraded flushes, {} flush panics)",
        report.ok, report.shed, report.timeouts, report.errors,
        st.degraded_flushes, st.flush_panics
    );
    print_failpoint_report();
    println!("report        : {out}");
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let a = parse_cmd("inspect", argv)?;
    let dir = a.str_or("artifacts", "artifacts");
    let reg = crate::runtime::Registry::load(std::path::Path::new(&dir))?;
    println!(
        "{:<22} {:>5} {:>7} {:>6} {:>6} {:>7} {:>9} {:>6}",
        "artifact", "kind", "layers", "f_in", "f_hid", "b_max", "vmem_est", "mxu"
    );
    for name in reg.names() {
        let m = reg.get(name)?;
        println!(
            "{:<22} {:>5} {:>7} {:>6} {:>6} {:>7} {:>8.1}M {:>6.2}",
            m.name,
            match m.kind {
                crate::runtime::Kind::Train => "train",
                crate::runtime::Kind::Forward => "fwd",
                crate::runtime::Kind::Vrgcn => "vrgcn",
            },
            m.layers,
            m.f_in,
            m.f_hid,
            m.b_max,
            m.vmem_bytes_est as f64 / 1e6,
            m.mxu_utilization_est,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_parsing() {
        assert_eq!(parse_norm("sym").unwrap(), NormConfig::PAPER_DEFAULT);
        assert_eq!(parse_norm("row+l1").unwrap(), NormConfig::ROW_LAMBDA1);
        assert!(parse_norm("bogus").is_err());
    }

    /// `USAGE` (and therefore the module doc, which includes the same
    /// file) must mention every subcommand `main` dispatches and the
    /// backend selector.
    #[test]
    fn usage_covers_every_subcommand() {
        for sub in ["datagen", "partition", "train", "eval", "serve", "table8", "inspect"] {
            assert!(
                USAGE.contains(&format!("cluster-gcn {sub}")),
                "usage.txt missing subcommand {sub}"
            );
        }
        assert!(USAGE.contains("--backend pjrt|host"));
        for flag in [
            "--shards", "--prefetch", "--eval exact|clustered", "--eval-parts",
            "--guard", "--guard-retries", "--lr-backoff", "--keep",
            "--failpoints", "--fail-seed", "--queue", "--shed",
            "--deadline-ms", "--degrade-after", "--storage ram|disk",
            "--chunk-rows", "--group-cap", "--workers",
            "--transport unix|tcp", "--compress none|topk:F|q8",
        ] {
            assert!(USAGE.contains(flag), "usage.txt missing flag {flag}");
        }
        for m in ["cluster", "expansion", "graphsage", "vrgcn"] {
            assert!(USAGE.contains(m), "usage.txt missing method {m}");
        }
        for p in crate::datagen::PRESETS {
            assert!(USAGE.contains(p.name), "usage.txt missing preset {}", p.name);
        }
    }

    /// Every `--flag` in the USAGE synopsis of each subcommand must be
    /// accepted by that subcommand's parser whitelist, and every
    /// whitelisted key must appear in its synopsis — both directions,
    /// so `usage.txt` and [`COMMANDS`] cannot drift apart.
    #[test]
    fn usage_flags_match_command_whitelists() {
        // Parse only the synopsis block: from "USAGE:" to the first
        // blank line that ends it.  A line starting a new command
        // switches the accumulator; continuation lines attach to the
        // current command.
        let body = USAGE
            .split_once("USAGE:")
            .expect("usage.txt has a USAGE: section")
            .1;
        let mut per_cmd: std::collections::HashMap<&str, std::collections::BTreeSet<String>> =
            std::collections::HashMap::new();
        let mut current: Option<&str> = None;
        for line in body.lines() {
            if line.trim().is_empty() && current.is_some() {
                break; // end of the synopsis block
            }
            if let Some(rest) = line.trim_start().strip_prefix("cluster-gcn ") {
                let name = rest.split_whitespace().next().unwrap_or("");
                let known = COMMANDS.iter().find(|c| c.name == name);
                current = known.map(|c| c.name);
                assert!(
                    current.is_some(),
                    "usage.txt synopsis names unknown subcommand {name:?}"
                );
            }
            let Some(cmd) = current else { continue };
            let flags = per_cmd.entry(cmd).or_default();
            let mut rest = line;
            while let Some(at) = rest.find("--") {
                rest = &rest[at + 2..];
                let end = rest
                    .find(|c: char| !(c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'))
                    .unwrap_or(rest.len());
                if end > 0 {
                    flags.insert(rest[..end].to_string());
                }
                rest = &rest[end..];
            }
        }
        for c in COMMANDS {
            let in_usage = per_cmd
                .get(c.name)
                .unwrap_or_else(|| panic!("subcommand {} missing from USAGE synopsis", c.name));
            for key in c.keys {
                assert!(
                    in_usage.contains(*key),
                    "`{} --{key}` is accepted by the parser but absent from usage.txt",
                    c.name
                );
            }
            for flag in in_usage {
                assert!(
                    c.keys.contains(&flag.as_str()),
                    "usage.txt advertises `{} --{flag}` but the parser rejects it",
                    c.name
                );
            }
            for b in c.bools {
                assert!(c.keys.contains(b), "{}: bool {b} not in keys", c.name);
            }
        }
    }
}
