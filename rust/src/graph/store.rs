//! Out-of-core graph storage: the `CGCNGS01` on-disk dataset format and
//! the [`GraphStorage`] seam that lets normalization, batch assembly,
//! and evaluation read rows lazily instead of requiring the whole
//! adjacency + feature matrix resident (ROADMAP item 1 — the paper's
//! Table 8 trains Amazon2M, 2M nodes / 61M edges, in 2.2 GB).
//!
//! ## File layout (all integers little-endian)
//!
//! ```text
//! +---------------------------------------------------------------+
//! | magic "CGCNGS01" (8)  | name (32, zero-padded utf-8)          |
//! | task | n | nnz | f_in | num_classes | words_per_node          |
//! | index_off | neigh_off | feat_off | label_off | split_off      |
//! | file_len | data_crc | header_crc            (u64 each)        |
//! +---------------------------------------------------------------+
//! | index:  (n+1) x u64   row offsets into the neighbor section,  |
//! |         in entries (RAM-resident after open: 8(n+1) bytes)    |
//! | neigh:  nnz x u32     column ids, CSR order                   |
//! | feat:   n*f_in x f32  row-major features                      |
//! | label:  multiclass:  n x u32 class ids                        |
//! |         multilabel:  n*words_per_node x u64 bitset words      |
//! | split:  n x u8        0=train 1=val 2=test (RAM-resident)     |
//! +---------------------------------------------------------------+
//! ```
//!
//! `header_crc` (CRC32, IEEE) covers every header byte before itself, so
//! metadata corruption fails typed at [`DiskDataset::open`]; `data_crc`
//! covers everything after the header and is checked on demand by
//! [`DiskDataset::verify_data`] (a full sequential scan — opening stays
//! O(n) index + split, never O(nnz)).  This mirrors the `CGCNCKP3`
//! checkpoint pattern: corruption is a typed [`StoreError`], never a
//! panic or silent garbage.
//!
//! ## Residency contract
//!
//! After `open`, only the fixed-width row-offset index ((n+1) × u64) and
//! the split bytes (n × u8, needed by every batch's train mask) are
//! resident.  Neighbor, feature, and label rows are fetched with
//! positioned reads (`pread`) on demand; chunked scans
//! ([`GraphStorage::scan_rows`]) buffer one row-chunk at a time.  The
//! full adjacency is never materialized by any consumer on the disk
//! path.
//!
//! ## Error contract
//!
//! Validation at `open`/`verify_data` is typed.  I/O failures *after* a
//! successful open (mid-train reads on a validated file) are treated
//! like allocation failure — the [`GraphStorage`] convenience accessors
//! panic with context, keeping the hot batch-assembly path infallible
//! like its in-RAM twin.

use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Seek, SeekFrom, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

use super::csr::Csr;
use super::dataset::{Dataset, Labels, Split, Task};

/// Format magic, version 1.
pub const STORE_MAGIC: &[u8; 8] = b"CGCNGS01";
const NAME_BYTES: usize = 32;
/// 8 magic + 32 name + 14 u64 fields.
const HEADER_LEN: u64 = 8 + NAME_BYTES as u64 + 14 * 8;
/// Default row-chunk granularity for streaming scans (rows per chunk).
pub const DEFAULT_CHUNK_ROWS: usize = 65_536;

// ---------------------------------------------------------------------
// typed errors (CGCNCKP3 pattern: corruption fails typed, never panics)
// ---------------------------------------------------------------------

/// Typed failure modes of the on-disk store.
#[derive(Debug)]
pub enum StoreError {
    /// The file does not start with the `CGCNGS01` magic.
    BadMagic,
    /// The file is shorter than the header claims.
    Truncated {
        /// Bytes the header (or format minimum) requires.
        expected: u64,
        /// Bytes actually present.
        actual: u64,
    },
    /// Structural validation failed (checksum mismatch, inconsistent
    /// section table, out-of-range values).
    Corrupt(String),
    /// An underlying I/O error.
    Io(io::Error),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::BadMagic => write!(f, "not a CGCNGS01 graph store (bad magic)"),
            StoreError::Truncated { expected, actual } => write!(
                f,
                "graph store truncated: need {expected} bytes, have {actual}"
            ),
            StoreError::Corrupt(m) => write!(f, "graph store corrupt: {m}"),
            StoreError::Io(e) => write!(f, "graph store I/O error: {e}"),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for StoreError {
    fn from(e: io::Error) -> StoreError {
        StoreError::Io(e)
    }
}

fn corrupt(msg: impl Into<String>) -> StoreError {
    StoreError::Corrupt(msg.into())
}

// ---------------------------------------------------------------------
// CRC32 (IEEE), table-driven — same polynomial as the CGCNCKP3 trailer
// ---------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// Fold `bytes` into a running (finalized-form) CRC32; start from 0.
fn crc32_update(crc: u32, bytes: &[u8]) -> u32 {
    let mut c = !crc;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------
// little-endian positioned-read helpers (no unsafe, io.rs idiom)
// ---------------------------------------------------------------------

/// Small reads (a feature row, one adjacency row) borrow a stack
/// buffer; chunk scans fall back to a heap allocation.
const STACK_BUF: usize = 4096;

fn with_bytes<R>(len: usize, f: impl FnOnce(&mut [u8]) -> io::Result<R>) -> io::Result<R> {
    if len <= STACK_BUF {
        let mut buf = [0u8; STACK_BUF];
        f(&mut buf[..len])
    } else {
        let mut buf = vec![0u8; len];
        f(&mut buf)
    }
}

fn read_u32s_at(file: &File, off: u64, count: usize, out: &mut Vec<u32>) -> io::Result<()> {
    out.clear();
    out.reserve(count);
    with_bytes(count * 4, |b| {
        file.read_exact_at(b, off)?;
        out.extend(
            b.chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        Ok(())
    })
}

fn read_u64s_at(file: &File, off: u64, count: usize) -> io::Result<Vec<u64>> {
    let mut buf = vec![0u8; count * 8];
    file.read_exact_at(&mut buf, off)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

fn read_f32s_at(file: &File, off: u64, out: &mut [f32]) -> io::Result<()> {
    with_bytes(out.len() * 4, |b| {
        file.read_exact_at(b, off)?;
        for (o, c) in out.iter_mut().zip(b.chunks_exact(4)) {
            *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(())
    })
}

fn u32s_to_bytes(vals: &[u32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Row-chunk ranges `[0, n)` in `chunk_rows` steps (`0` = one full
/// chunk).  The shared chunking policy for every streaming scan.
pub fn chunk_ranges(n: usize, chunk_rows: usize) -> impl Iterator<Item = std::ops::Range<usize>> {
    let step = if chunk_rows == 0 { n.max(1) } else { chunk_rows };
    (0..n.div_ceil(step.max(1))).map(move |i| {
        let start = i * step;
        start..(start + step).min(n)
    })
}

// ---------------------------------------------------------------------
// metadata + streaming writer
// ---------------------------------------------------------------------

/// Dataset-level metadata fixed before any row is written.
#[derive(Clone, Debug)]
pub struct StoreMeta {
    /// Dataset name (≤ 31 utf-8 bytes; stored zero-padded).
    pub name: String,
    /// Multiclass or multilabel.
    pub task: Task,
    /// Node count.
    pub n: usize,
    /// Feature width.
    pub f_in: usize,
    /// Class count.
    pub num_classes: usize,
}

impl StoreMeta {
    fn words_per_node(&self) -> usize {
        match self.task {
            Task::Multiclass => 0,
            Task::Multilabel => self.num_classes.div_ceil(64),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Stage {
    Neigh,
    Feat,
    Label,
    Split,
    Done,
}

/// Sequential section writer: adjacency rows, then feature rows, then
/// label rows, then splits — exactly the file order, so a generator can
/// stream a graph to disk with O(chunk) residency.  `nnz` and the row
/// index are unknown up front; [`StoreWriter::finish`] back-fills the
/// index and header with positioned writes.
pub struct StoreWriter {
    file: BufWriter<File>,
    meta: StoreMeta,
    /// Row offsets in entries; grows to n+1 as rows are pushed.
    offsets: Vec<u64>,
    stage: Stage,
    feat_vals: usize,
    label_rows: usize,
    split_rows: usize,
    /// Absolute byte position of the next sequential write.
    pos: u64,
}

impl StoreWriter {
    /// Create `path` (truncating) and reserve the header + index region.
    pub fn create(path: &Path, meta: StoreMeta) -> Result<StoreWriter, StoreError> {
        assert!(meta.n > 0, "empty dataset");
        assert!(
            meta.name.len() < NAME_BYTES,
            "store name too long: {}",
            meta.name
        );
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        let neigh_off = HEADER_LEN + (meta.n as u64 + 1) * 8;
        // reserve the header + index region (back-filled in finish)
        file.set_len(neigh_off)?;
        let mut file = BufWriter::new(file);
        file.seek(SeekFrom::Start(neigh_off))?;
        Ok(StoreWriter {
            file,
            offsets: vec![0u64],
            meta,
            stage: Stage::Neigh,
            feat_vals: 0,
            label_rows: 0,
            split_rows: 0,
            pos: neigh_off,
        })
    }

    fn write_bytes(&mut self, b: &[u8]) -> Result<(), StoreError> {
        self.file.write_all(b)?;
        self.pos += b.len() as u64;
        Ok(())
    }

    /// Append the sorted adjacency row of the next node (rows must
    /// arrive in node order, `0..n`).
    pub fn push_neighbor_row(&mut self, cols: &[u32]) -> Result<(), StoreError> {
        assert_eq!(self.stage, Stage::Neigh, "neighbor rows already complete");
        let mut bytes = Vec::new();
        u32s_to_bytes(cols, &mut bytes);
        self.write_bytes(&bytes)?;
        let last = *self.offsets.last().unwrap();
        self.offsets.push(last + cols.len() as u64);
        if self.offsets.len() == self.meta.n + 1 {
            self.stage = Stage::Feat;
        }
        Ok(())
    }

    /// Append feature values (row-major, any multiple of `f_in`).
    pub fn push_feature_rows(&mut self, vals: &[f32]) -> Result<(), StoreError> {
        assert_eq!(self.stage, Stage::Feat, "not in the feature stage");
        assert_eq!(vals.len() % self.meta.f_in, 0, "partial feature row");
        let mut bytes = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.write_bytes(&bytes)?;
        self.feat_vals += vals.len();
        assert!(self.feat_vals <= self.meta.n * self.meta.f_in, "too many feature rows");
        if self.feat_vals == self.meta.n * self.meta.f_in {
            self.stage = Stage::Label;
        }
        Ok(())
    }

    /// Re-scan written feature rows in place, chunk by chunk (valid
    /// between the last feature row and the first label row).  This is
    /// how the streaming generator standardizes columns without holding
    /// the feature matrix: pass 1 accumulates moments, pass 2 rewrites.
    pub fn for_each_feature_chunk_mut(
        &mut self,
        chunk_rows: usize,
        mut f: impl FnMut(usize, &mut [f32]),
    ) -> Result<(), StoreError> {
        assert_eq!(self.stage, Stage::Label, "feature rows incomplete");
        assert_eq!(self.label_rows, 0, "label rows already started");
        self.file.flush()?;
        let fi = self.meta.f_in;
        let feat_off = self.pos - (self.meta.n * fi * 4) as u64;
        let file = self.file.get_ref();
        let mut rows = Vec::new();
        let mut bytes = Vec::new();
        for r in chunk_ranges(self.meta.n, chunk_rows) {
            let vals = (r.end - r.start) * fi;
            rows.resize(vals, 0.0);
            let off = feat_off + (r.start * fi * 4) as u64;
            read_f32s_at(file, off, &mut rows)?;
            f(r.start, &mut rows);
            bytes.clear();
            bytes.reserve(vals * 4);
            for v in &rows {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            file.write_all_at(&bytes, off)?;
        }
        Ok(())
    }

    /// Append the next node's class id (multiclass stores only).
    pub fn push_class(&mut self, class: u32) -> Result<(), StoreError> {
        assert_eq!(self.stage, Stage::Label, "not in the label stage");
        assert_eq!(self.meta.words_per_node(), 0, "multilabel store wants words");
        let b = class.to_le_bytes();
        self.write_bytes(&b)?;
        self.advance_label()
    }

    /// Append the next node's label bitset words (multilabel stores).
    pub fn push_label_words(&mut self, words: &[u64]) -> Result<(), StoreError> {
        assert_eq!(self.stage, Stage::Label, "not in the label stage");
        assert_eq!(words.len(), self.meta.words_per_node(), "label word count");
        let mut bytes = Vec::with_capacity(words.len() * 8);
        for w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        self.write_bytes(&bytes)?;
        self.advance_label()
    }

    fn advance_label(&mut self) -> Result<(), StoreError> {
        self.label_rows += 1;
        assert!(self.label_rows <= self.meta.n, "too many label rows");
        if self.label_rows == self.meta.n {
            self.stage = Stage::Split;
        }
        Ok(())
    }

    /// Append the next node's split tag.
    pub fn push_split(&mut self, s: Split) -> Result<(), StoreError> {
        assert_eq!(self.stage, Stage::Split, "not in the split stage");
        let b = [split_to_u8(s)];
        self.write_bytes(&b)?;
        self.split_rows += 1;
        if self.split_rows == self.meta.n {
            self.stage = Stage::Done;
        }
        Ok(())
    }

    /// Append split tags in bulk.
    pub fn push_splits(&mut self, splits: &[Split]) -> Result<(), StoreError> {
        for &s in splits {
            self.push_split(s)?;
        }
        Ok(())
    }

    /// Back-fill the row index + header (with checksums) and fsync.
    pub fn finish(mut self) -> Result<(), StoreError> {
        assert_eq!(self.stage, Stage::Done, "store sections incomplete");
        self.file.flush()?;
        let file = self.file.into_inner().map_err(|e| e.into_error())?;
        let n = self.meta.n as u64;
        let nnz = *self.offsets.last().unwrap();
        let index_off = HEADER_LEN;
        let neigh_off = index_off + (n + 1) * 8;
        let feat_off = neigh_off + nnz * 4;
        let label_off = feat_off + n * self.meta.f_in as u64 * 4;
        let wpn = self.meta.words_per_node() as u64;
        let label_bytes = if wpn == 0 { n * 4 } else { n * wpn * 8 };
        let split_off = label_off + label_bytes;
        let file_len = split_off + n;
        debug_assert_eq!(self.pos, file_len, "writer position drifted");

        // back-fill the row index
        let mut index = Vec::with_capacity(self.offsets.len() * 8);
        for o in &self.offsets {
            index.extend_from_slice(&o.to_le_bytes());
        }
        file.write_all_at(&index, index_off)?;

        // data CRC over everything after the header (one streaming pass)
        let data_crc = crc_range(&file, HEADER_LEN, file_len)?;

        // header
        let mut h = Vec::with_capacity(HEADER_LEN as usize);
        h.extend_from_slice(STORE_MAGIC);
        let mut name = [0u8; NAME_BYTES];
        name[..self.meta.name.len()].copy_from_slice(self.meta.name.as_bytes());
        h.extend_from_slice(&name);
        let task = match self.meta.task {
            Task::Multiclass => 0u64,
            Task::Multilabel => 1u64,
        };
        for v in [
            task,
            n,
            nnz,
            self.meta.f_in as u64,
            self.meta.num_classes as u64,
            wpn,
            index_off,
            neigh_off,
            feat_off,
            label_off,
            split_off,
            file_len,
            data_crc as u64,
        ] {
            h.extend_from_slice(&v.to_le_bytes());
        }
        let header_crc = crc32_update(0, &h);
        h.extend_from_slice(&(header_crc as u64).to_le_bytes());
        debug_assert_eq!(h.len() as u64, HEADER_LEN);
        file.write_all_at(&h, 0)?;
        file.sync_all()?;
        Ok(())
    }
}

fn split_to_u8(s: Split) -> u8 {
    match s {
        Split::Train => 0,
        Split::Val => 1,
        Split::Test => 2,
    }
}

fn split_from_u8(b: u8) -> Option<Split> {
    match b {
        0 => Some(Split::Train),
        1 => Some(Split::Val),
        2 => Some(Split::Test),
        _ => None,
    }
}

/// CRC32 over the byte range `[from, to)` of `file`, streamed.
fn crc_range(file: &File, from: u64, to: u64) -> io::Result<u32> {
    let mut crc = 0u32;
    let mut buf = vec![0u8; 1 << 20];
    let mut off = from;
    while off < to {
        let take = ((to - off) as usize).min(buf.len());
        file.read_exact_at(&mut buf[..take], off)?;
        crc = crc32_update(crc, &buf[..take]);
        off += take as u64;
    }
    Ok(crc)
}

/// Serialize an in-RAM [`Dataset`] to the on-disk format.  Byte-for-byte
/// identical to what the streaming generator produces for the same
/// logical content (pinned by tests), so `--storage disk` on a preset
/// that fits in RAM is a pure representation change.
pub fn write_store(ds: &Dataset, path: &Path) -> Result<(), StoreError> {
    let meta = StoreMeta {
        name: ds.name.clone(),
        task: ds.task,
        n: ds.n(),
        f_in: ds.f_in,
        num_classes: ds.num_classes,
    };
    let mut w = StoreWriter::create(path, meta)?;
    for v in 0..ds.n() {
        w.push_neighbor_row(ds.graph.neighbors(v))?;
    }
    w.push_feature_rows(&ds.features)?;
    match &ds.labels {
        Labels::Multiclass(y) => {
            for &c in y {
                w.push_class(c)?;
            }
        }
        Labels::Multilabel { bits, words_per_node } => {
            for v in 0..ds.n() {
                w.push_label_words(&bits[v * words_per_node..(v + 1) * words_per_node])?;
            }
        }
    }
    w.push_splits(&ds.split)?;
    w.finish()
}

// ---------------------------------------------------------------------
// lazy reader
// ---------------------------------------------------------------------

/// An opened `CGCNGS01` store: resident row-offset index + split bytes,
/// positioned (`pread`) access to everything else.
pub struct DiskDataset {
    file: File,
    path: PathBuf,
    /// Dataset name from the header.
    pub name: String,
    /// Multiclass or multilabel.
    pub task: Task,
    n: usize,
    nnz: usize,
    /// Feature width.
    pub f_in: usize,
    /// Class count.
    pub num_classes: usize,
    words_per_node: usize,
    /// Row offsets in entries, length n+1 (the only O(n) adjacency
    /// state held in RAM — degrees come from here for free).
    offsets: Vec<u64>,
    split: Vec<Split>,
    neigh_off: u64,
    feat_off: u64,
    label_off: u64,
    file_len: u64,
    data_crc: u32,
}

impl std::fmt::Debug for DiskDataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DiskDataset")
            .field("name", &self.name)
            .field("path", &self.path)
            .field("n", &self.n)
            .field("nnz", &self.nnz)
            .finish()
    }
}

impl DiskDataset {
    /// Open and validate a store: magic, header checksum, section-table
    /// consistency, file length, index monotonicity, split tags.  Every
    /// failure mode is a typed [`StoreError`].
    pub fn open(path: &Path) -> Result<DiskDataset, StoreError> {
        let file = File::open(path)?;
        let actual = file.metadata()?.len();
        if actual < HEADER_LEN {
            return Err(StoreError::Truncated { expected: HEADER_LEN, actual });
        }
        let mut h = vec![0u8; HEADER_LEN as usize];
        file.read_exact_at(&mut h, 0)?;
        if &h[..8] != STORE_MAGIC {
            return Err(StoreError::BadMagic);
        }
        let stored_crc =
            u64::from_le_bytes(h[HEADER_LEN as usize - 8..].try_into().unwrap()) as u32;
        if crc32_update(0, &h[..HEADER_LEN as usize - 8]) != stored_crc {
            return Err(corrupt("header checksum mismatch"));
        }
        let name_raw = &h[8..8 + NAME_BYTES];
        let name_len = name_raw.iter().position(|&b| b == 0).unwrap_or(NAME_BYTES);
        let name = std::str::from_utf8(&name_raw[..name_len])
            .map_err(|_| corrupt("name is not utf-8"))?
            .to_string();
        let field = |i: usize| -> u64 {
            let at = 8 + NAME_BYTES + i * 8;
            u64::from_le_bytes(h[at..at + 8].try_into().unwrap())
        };
        let task = match field(0) {
            0 => Task::Multiclass,
            1 => Task::Multilabel,
            t => return Err(corrupt(format!("unknown task tag {t}"))),
        };
        let n = field(1) as usize;
        let nnz = field(2) as usize;
        let f_in = field(3) as usize;
        let num_classes = field(4) as usize;
        let wpn = field(5) as usize;
        if n == 0 || num_classes == 0 {
            return Err(corrupt("empty dataset"));
        }
        let want_wpn = match task {
            Task::Multiclass => 0,
            Task::Multilabel => num_classes.div_ceil(64),
        };
        if wpn != want_wpn {
            return Err(corrupt("words_per_node inconsistent with task"));
        }
        // recompute the section table and demand an exact match
        let index_off = HEADER_LEN;
        let neigh_off = index_off + (n as u64 + 1) * 8;
        let feat_off = neigh_off + nnz as u64 * 4;
        let label_off = feat_off + (n * f_in) as u64 * 4;
        let label_bytes = if wpn == 0 { n as u64 * 4 } else { (n * wpn) as u64 * 8 };
        let split_off = label_off + label_bytes;
        let file_len = split_off + n as u64;
        let stored = [
            field(6), field(7), field(8), field(9), field(10), field(11),
        ];
        if stored != [index_off, neigh_off, feat_off, label_off, split_off, file_len] {
            return Err(corrupt("section table inconsistent"));
        }
        if actual < file_len {
            return Err(StoreError::Truncated { expected: file_len, actual });
        }
        if actual > file_len {
            return Err(corrupt("trailing bytes after split section"));
        }
        let data_crc = field(12) as u32;

        let offsets = read_u64s_at(&file, index_off, n + 1)?;
        if offsets[0] != 0
            || offsets.windows(2).any(|w| w[0] > w[1])
            || offsets[n] != nnz as u64
        {
            return Err(corrupt("row-offset index is not a monotone 0..nnz ramp"));
        }
        let mut split_bytes = vec![0u8; n];
        file.read_exact_at(&mut split_bytes, split_off)?;
        let split = split_bytes
            .iter()
            .map(|&b| split_from_u8(b).ok_or_else(|| corrupt(format!("bad split tag {b}"))))
            .collect::<Result<Vec<Split>, StoreError>>()?;

        Ok(DiskDataset {
            file,
            path: path.to_path_buf(),
            name,
            task,
            n,
            nnz,
            f_in,
            num_classes,
            words_per_node: wpn,
            offsets,
            split,
            neigh_off,
            feat_off,
            label_off,
            file_len,
            data_crc,
        })
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored (directed) adjacency entries.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Backing file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Degree of `v`, from the resident index (no I/O).
    pub fn degree(&self, v: usize) -> usize {
        (self.offsets[v + 1] - self.offsets[v]) as usize
    }

    /// Adjacency-row entry offset of `v` (index units, not bytes).
    pub fn row_entry_offset(&self, v: usize) -> u64 {
        self.offsets[v]
    }

    /// Split tag of `v` (resident; no I/O).
    pub fn split_of(&self, v: usize) -> Split {
        self.split[v]
    }

    /// Read the adjacency row of `v` into `out` (cleared first).
    pub fn read_neighbors_into(&self, v: usize, out: &mut Vec<u32>) -> Result<(), StoreError> {
        let off = self.neigh_off + self.offsets[v] * 4;
        read_u32s_at(&self.file, off, self.degree(v), out)?;
        Ok(())
    }

    /// Read the concatenated adjacency rows `[start, end)` into `out`
    /// (cleared first) — one positioned read per chunk scan.
    pub fn read_neighbor_rows_into(
        &self,
        start: usize,
        end: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), StoreError> {
        let off = self.neigh_off + self.offsets[start] * 4;
        let count = (self.offsets[end] - self.offsets[start]) as usize;
        read_u32s_at(&self.file, off, count, out)?;
        Ok(())
    }

    /// Read the feature row of `v` into `out` (length `f_in`).
    pub fn read_feature_row_into(&self, v: usize, out: &mut [f32]) -> Result<(), StoreError> {
        debug_assert_eq!(out.len(), self.f_in);
        let off = self.feat_off + (v * self.f_in * 4) as u64;
        read_f32s_at(&self.file, off, out)?;
        Ok(())
    }

    /// Mirror of [`Labels::write_row`] reading the label row from disk:
    /// zero `row`, then set the one-hot / multi-hot entries.
    pub fn read_label_row(
        &self,
        v: usize,
        classes: usize,
        row: &mut [f32],
    ) -> Result<(), StoreError> {
        debug_assert_eq!(row.len(), classes);
        row.iter_mut().for_each(|x| *x = 0.0);
        if self.words_per_node == 0 {
            let mut b = [0u8; 4];
            self.file.read_exact_at(&mut b, self.label_off + v as u64 * 4)?;
            row[u32::from_le_bytes(b) as usize] = 1.0;
        } else {
            let words = self.read_label_words(v)?;
            for (c, x) in row.iter_mut().enumerate() {
                if words[c / 64] >> (c % 64) & 1 == 1 {
                    *x = 1.0;
                }
            }
        }
        Ok(())
    }

    /// Mirror of [`Labels::has_label`] with a positioned read.
    pub fn has_label(&self, v: usize, class: usize) -> Result<bool, StoreError> {
        if self.words_per_node == 0 {
            let mut b = [0u8; 4];
            self.file.read_exact_at(&mut b, self.label_off + v as u64 * 4)?;
            Ok(u32::from_le_bytes(b) == class as u32)
        } else {
            let words = self.read_label_words(v)?;
            Ok(words[class / 64] >> (class % 64) & 1 == 1)
        }
    }

    fn read_label_words(&self, v: usize) -> Result<Vec<u64>, StoreError> {
        let off = self.label_off + (v * self.words_per_node * 8) as u64;
        Ok(read_u64s_at(&self.file, off, self.words_per_node)?)
    }

    /// Stream the post-header bytes against the stored data checksum.
    /// O(file) sequential read — on demand, not part of `open`.
    pub fn verify_data(&self) -> Result<(), StoreError> {
        let crc = crc_range(&self.file, HEADER_LEN, self.file_len)?;
        if crc != self.data_crc {
            return Err(corrupt("data checksum mismatch"));
        }
        Ok(())
    }

    /// Fully materialize the store as an in-RAM [`Dataset`] (serving's
    /// exact engine needs full-graph residency; miniature presets in
    /// tests).  The inverse of [`write_store`].
    pub fn to_dataset(&self) -> Result<Dataset, StoreError> {
        let mut cols = Vec::new();
        read_u32s_at(&self.file, self.neigh_off, self.nnz, &mut cols)?;
        let offsets: Vec<usize> = self.offsets.iter().map(|&o| o as usize).collect();
        let graph = Csr {
            offsets,
            cols,
            weights: vec![1; self.nnz],
            node_weights: vec![1; self.n],
        };
        let mut features = vec![0.0f32; self.n * self.f_in];
        read_f32s_at(&self.file, self.feat_off, &mut features)?;
        let labels = if self.words_per_node == 0 {
            let mut y = Vec::new();
            read_u32s_at(&self.file, self.label_off, self.n, &mut y)?;
            Labels::Multiclass(y)
        } else {
            let bits = read_u64s_at(&self.file, self.label_off, self.n * self.words_per_node)?;
            Labels::Multilabel { bits, words_per_node: self.words_per_node }
        };
        let ds = Dataset {
            name: self.name.clone(),
            task: self.task,
            graph,
            f_in: self.f_in,
            num_classes: self.num_classes,
            features,
            labels,
            split: self.split.clone(),
        };
        ds.validate().map_err(corrupt)?;
        Ok(ds)
    }
}

// ---------------------------------------------------------------------
// the storage seam
// ---------------------------------------------------------------------

fn read_fail(what: &str, e: StoreError) -> ! {
    panic!("graph store {what} read failed on a validated file: {e}")
}

/// Where a dataset's rows live.  `InRam` wraps the classic [`Dataset`];
/// `OnDisk` reads rows lazily from a `CGCNGS01` file.  Consumers
/// (normalization, batch assembly, the streaming partitioner, clustered
/// eval) are written against this enum so the two modes produce
/// bit-identical results — pinned by the `store` test suite.
#[derive(Debug)]
pub enum GraphStorage {
    /// Everything resident (the classic path).
    InRam(Dataset),
    /// Lazy row reads from the on-disk format.
    OnDisk(DiskDataset),
}

impl GraphStorage {
    /// Node count.
    pub fn n(&self) -> usize {
        match self {
            GraphStorage::InRam(ds) => ds.n(),
            GraphStorage::OnDisk(dd) => dd.n(),
        }
    }

    /// Stored (directed) adjacency entries.
    pub fn nnz(&self) -> usize {
        match self {
            GraphStorage::InRam(ds) => ds.graph.nnz(),
            GraphStorage::OnDisk(dd) => dd.nnz(),
        }
    }

    /// Feature width.
    pub fn f_in(&self) -> usize {
        match self {
            GraphStorage::InRam(ds) => ds.f_in,
            GraphStorage::OnDisk(dd) => dd.f_in,
        }
    }

    /// Class count.
    pub fn num_classes(&self) -> usize {
        match self {
            GraphStorage::InRam(ds) => ds.num_classes,
            GraphStorage::OnDisk(dd) => dd.num_classes,
        }
    }

    /// Multiclass or multilabel.
    pub fn task(&self) -> Task {
        match self {
            GraphStorage::InRam(ds) => ds.task,
            GraphStorage::OnDisk(dd) => dd.task,
        }
    }

    /// Dataset name.
    pub fn name(&self) -> &str {
        match self {
            GraphStorage::InRam(ds) => &ds.name,
            GraphStorage::OnDisk(dd) => &dd.name,
        }
    }

    /// Degree of `v` (no I/O on either arm).
    pub fn degree(&self, v: usize) -> usize {
        match self {
            GraphStorage::InRam(ds) => ds.graph.degree(v),
            GraphStorage::OnDisk(dd) => dd.degree(v),
        }
    }

    /// Split tag of `v` (no I/O on either arm).
    pub fn split_of(&self, v: usize) -> Split {
        match self {
            GraphStorage::InRam(ds) => ds.split[v],
            GraphStorage::OnDisk(dd) => dd.split_of(v),
        }
    }

    /// Nodes in `want`, ascending — mirror of [`Dataset::nodes_in_split`].
    pub fn nodes_in_split(&self, want: Split) -> Vec<u32> {
        (0..self.n())
            .filter(|&v| self.split_of(v) == want)
            .map(|v| v as u32)
            .collect()
    }

    /// Copy the adjacency row of `v` into `out` (cleared first).
    pub fn neighbors_into(&self, v: usize, out: &mut Vec<u32>) {
        match self {
            GraphStorage::InRam(ds) => {
                out.clear();
                out.extend_from_slice(ds.graph.neighbors(v));
            }
            GraphStorage::OnDisk(dd) => {
                if let Err(e) = dd.read_neighbors_into(v, out) {
                    read_fail("neighbor", e)
                }
            }
        }
    }

    /// Copy the feature row of `v` into `out` (length `f_in`).
    pub fn feature_row_into(&self, v: usize, out: &mut [f32]) {
        match self {
            GraphStorage::InRam(ds) => out.copy_from_slice(ds.feature_row(v)),
            GraphStorage::OnDisk(dd) => {
                if let Err(e) = dd.read_feature_row_into(v, out) {
                    read_fail("feature", e)
                }
            }
        }
    }

    /// Mirror of [`Labels::write_row`] over either arm.
    pub fn write_label_row(&self, v: usize, classes: usize, row: &mut [f32]) {
        match self {
            GraphStorage::InRam(ds) => ds.labels.write_row(v, classes, row),
            GraphStorage::OnDisk(dd) => {
                if let Err(e) = dd.read_label_row(v, classes, row) {
                    read_fail("label", e)
                }
            }
        }
    }

    /// Mirror of [`Labels::has_label`] over either arm.
    pub fn has_label(&self, v: usize, class: usize) -> bool {
        match self {
            GraphStorage::InRam(ds) => ds.labels.has_label(v, class),
            GraphStorage::OnDisk(dd) => match dd.has_label(v, class) {
                Ok(b) => b,
                Err(e) => read_fail("label", e),
            },
        }
    }

    /// Stream every adjacency row in ascending node order, buffering at
    /// most one `chunk_rows` chunk of the neighbor section (`0` = one
    /// full chunk).  The scan primitive behind storage normalization
    /// and the streaming partitioner's coarsening passes.
    pub fn scan_rows(&self, chunk_rows: usize, mut f: impl FnMut(usize, &[u32])) {
        match self {
            GraphStorage::InRam(ds) => {
                for v in 0..ds.n() {
                    f(v, ds.graph.neighbors(v));
                }
            }
            GraphStorage::OnDisk(dd) => {
                let mut cols = Vec::new();
                for r in chunk_ranges(dd.n(), chunk_rows) {
                    if let Err(e) = dd.read_neighbor_rows_into(r.start, r.end, &mut cols) {
                        read_fail("neighbor chunk", e)
                    }
                    let base = dd.row_entry_offset(r.start);
                    for v in r {
                        let s = (dd.row_entry_offset(v) - base) as usize;
                        let e = (dd.row_entry_offset(v + 1) - base) as usize;
                        f(v, &cols[s..e]);
                    }
                }
            }
        }
    }

    /// The in-RAM dataset, when this storage is resident.
    pub fn as_ram(&self) -> Option<&Dataset> {
        match self {
            GraphStorage::InRam(ds) => Some(ds),
            GraphStorage::OnDisk(_) => None,
        }
    }

    /// Materialize as an in-RAM [`Dataset`] (cloning on the RAM arm).
    pub fn to_dataset(&self) -> Result<Dataset, StoreError> {
        match self {
            GraphStorage::InRam(ds) => Ok(ds.clone()),
            GraphStorage::OnDisk(dd) => dd.to_dataset(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (n, c) in [(10, 3), (10, 1), (10, 0), (10, 10), (7, 64), (1, 1)] {
            let ranges: Vec<_> = chunk_ranges(n, c).collect();
            assert_eq!(ranges.first().map(|r| r.start), Some(0));
            assert_eq!(ranges.last().map(|r| r.end), Some(n));
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
        assert_eq!(chunk_ranges(0, 4).count(), 0);
    }

    #[test]
    fn crc_is_stable() {
        // pin the polynomial so a refactor can't silently change the
        // format (the CGCNCKP3 trailer uses the same IEEE table)
        assert_eq!(crc32_update(0, b"123456789"), 0xCBF4_3926);
        let ab = crc32_update(crc32_update(0, b"12345"), b"6789");
        assert_eq!(ab, 0xCBF4_3926);
    }

    #[test]
    fn split_tags_roundtrip() {
        for s in [Split::Train, Split::Val, Split::Test] {
            assert_eq!(split_from_u8(split_to_u8(s)), Some(s));
        }
        assert_eq!(split_from_u8(3), None);
    }
}
