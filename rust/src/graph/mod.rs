//! Graph substrate: CSR store, dataset container, induced-subgraph
//! extraction, binary IO, and the out-of-core `CGCNGS01` storage layer.

pub mod csr;
pub mod dataset;
pub mod io;
pub mod store;
pub mod subgraph;
pub mod text_io;

pub use csr::Csr;
pub use dataset::{Dataset, Labels, Split, Task};
pub use store::{write_store, DiskDataset, GraphStorage, StoreError, StoreMeta, StoreWriter};
pub use subgraph::{
    induced_csr, induced_edges, induced_edges_by, within_edges, SubgraphScratch,
};
