//! Graph substrate: CSR store, dataset container, induced-subgraph
//! extraction, and binary IO.

pub mod csr;
pub mod dataset;
pub mod io;
pub mod subgraph;
pub mod text_io;

pub use csr::Csr;
pub use dataset::{Dataset, Labels, Split, Task};
pub use subgraph::{induced_csr, induced_edges, within_edges, SubgraphScratch};
