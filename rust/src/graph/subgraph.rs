//! Induced-subgraph extraction — the core of batch assembly.
//!
//! Given a node set (the union of the sampled clusters, Algorithm 1
//! line 4), extract the induced adjacency block `A_{V̄,V̄}` *including
//! between-cluster links* (§3.2).  The extraction is allocation-light:
//! callers reuse a scratch `SubgraphScratch` across batches (the batch
//! assembly loop is the L3 hot path — see DESIGN.md §8).

use super::csr::Csr;

/// Reusable scratch for repeated extractions over the same parent graph.
pub struct SubgraphScratch {
    /// global node id -> local index + 1, 0 = absent. Reset lazily via
    /// an epoch counter so clearing is O(|batch|), not O(N).
    local_of: Vec<u32>,
    epoch_of: Vec<u32>,
    epoch: u32,
}

impl SubgraphScratch {
    pub fn new(n: usize) -> Self {
        SubgraphScratch {
            local_of: vec![0; n],
            epoch_of: vec![0; n],
            epoch: 0,
        }
    }

    fn begin(&mut self, nodes: &[u32]) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // wrapped: hard reset
            self.epoch_of.iter_mut().for_each(|e| *e = 0);
            self.epoch = 1;
        }
        for (i, &g) in nodes.iter().enumerate() {
            self.local_of[g as usize] = i as u32;
            self.epoch_of[g as usize] = self.epoch;
        }
    }

    #[inline]
    fn local(&self, g: u32) -> Option<u32> {
        if self.epoch_of[g as usize] == self.epoch {
            Some(self.local_of[g as usize])
        } else {
            None
        }
    }
}

/// Induced subgraph in local indices; `edges` are (local_u, local_v)
/// directed entries (both directions present, mirroring Csr storage).
pub struct Induced {
    pub n: usize,
    /// (src, dst) directed pairs over local ids.
    pub edges: Vec<(u32, u32)>,
}

/// Extract the induced subgraph over `nodes` (global ids, defining the
/// local ordering).  Returns directed local edge pairs.
pub fn induced_edges(
    g: &Csr,
    nodes: &[u32],
    scratch: &mut SubgraphScratch,
    out: &mut Vec<(u32, u32)>,
) {
    scratch.begin(nodes);
    out.clear();
    for (li, &gi) in nodes.iter().enumerate() {
        for &gj in g.neighbors(gi as usize) {
            if let Some(lj) = scratch.local(gj) {
                out.push((li as u32, lj));
            }
        }
    }
}

/// Storage-generic twin of [`induced_edges`]: the parent adjacency is
/// supplied as a row lookup (`neighbors_into` fills `nb_buf` with the
/// sorted adjacency row of a global id) instead of a resident [`Csr`],
/// so the out-of-core batch assembler can gather induced blocks with
/// lazy row reads.  Produces the exact same `(local_u, local_v)` pairs
/// in the exact same order when the lookup yields the same rows —
/// the invariant behind ram/disk bitwise batch parity.
pub fn induced_edges_by(
    nodes: &[u32],
    scratch: &mut SubgraphScratch,
    nb_buf: &mut Vec<u32>,
    out: &mut Vec<(u32, u32)>,
    mut neighbors_into: impl FnMut(u32, &mut Vec<u32>),
) {
    scratch.begin(nodes);
    out.clear();
    for (li, &gi) in nodes.iter().enumerate() {
        neighbors_into(gi, nb_buf);
        for &gj in nb_buf.iter() {
            if let Some(lj) = scratch.local(gj) {
                out.push((li as u32, lj));
            }
        }
    }
}

/// Induced subgraph as a standalone Csr (used by tests, the partitioner
/// per-part reporting, and exact inference over parts).
pub fn induced_csr(g: &Csr, nodes: &[u32]) -> Csr {
    let mut scratch = SubgraphScratch::new(g.n());
    let mut edges = Vec::new();
    induced_edges(g, nodes, &mut scratch, &mut edges);
    // keep one direction; from_edges re-symmetrizes
    let undirected: Vec<(u32, u32)> =
        edges.into_iter().filter(|&(u, v)| u < v).collect();
    Csr::from_edges(nodes.len(), &undirected)
}

/// Count edges inside the node set (embedding utilization ||A_BB||_0 of
/// §3.1, in directed entries).
pub fn within_edges(g: &Csr, nodes: &[u32], scratch: &mut SubgraphScratch) -> usize {
    scratch.begin(nodes);
    let mut count = 0;
    for &gi in nodes {
        for &gj in g.neighbors(gi as usize) {
            if scratch.local(gj).is_some() {
                count += 1;
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path5() -> Csr {
        // 0-1-2-3-4
        Csr::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)])
    }

    #[test]
    fn induced_block() {
        let g = path5();
        let sub = induced_csr(&g, &[1, 2, 3]);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.num_edges(), 2); // 1-2, 2-3 survive
        sub.validate().unwrap();
    }

    #[test]
    fn induced_no_edges() {
        let g = path5();
        let sub = induced_csr(&g, &[0, 2, 4]);
        assert_eq!(sub.num_edges(), 0);
    }

    #[test]
    fn within_edges_counts_directed() {
        let g = path5();
        let mut scratch = SubgraphScratch::new(g.n());
        assert_eq!(within_edges(&g, &[1, 2, 3], &mut scratch), 4);
        assert_eq!(within_edges(&g, &[0, 4], &mut scratch), 0);
        // reuse across calls (epoch reset works)
        assert_eq!(within_edges(&g, &[0, 1], &mut scratch), 2);
    }

    #[test]
    fn induced_edges_by_matches_csr_path() {
        let g = path5();
        let mut s1 = SubgraphScratch::new(g.n());
        let mut s2 = SubgraphScratch::new(g.n());
        let mut nb = Vec::new();
        for nodes in [vec![1, 2, 3], vec![3, 2], vec![0, 4], vec![4]] {
            let mut a = Vec::new();
            let mut b = Vec::new();
            induced_edges(&g, &nodes, &mut s1, &mut a);
            induced_edges_by(&nodes, &mut s2, &mut nb, &mut b, |v, buf| {
                buf.clear();
                buf.extend_from_slice(g.neighbors(v as usize));
            });
            assert_eq!(a, b, "nodes {nodes:?}");
        }
    }

    #[test]
    fn local_ordering_follows_input() {
        let g = path5();
        let mut scratch = SubgraphScratch::new(g.n());
        let mut edges = Vec::new();
        induced_edges(&g, &[3, 2], &mut scratch, &mut edges);
        edges.sort_unstable();
        // local 0 = global 3, local 1 = global 2; edge both directions
        assert_eq!(edges, vec![(0, 1), (1, 0)]);
    }
}
