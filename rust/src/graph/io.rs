//! Binary dataset IO.
//!
//! Generated datasets are cached on disk so benches don't regenerate
//! (Table 13's "preprocessing" timing separates generation, clustering
//! and training).  Format: little-endian sections with a magic header;
//! version-checked on load.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::csr::Csr;
use super::dataset::{Dataset, Labels, Split, Task};

const MAGIC: &[u8; 8] = b"CGCNDS01";

fn w_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn w_u32s(w: &mut impl Write, v: &[u32]) -> std::io::Result<()> {
    w_u64(w, v.len() as u64)?;
    // SAFETY-free path: serialize via chunks to avoid unsafe casts.
    let mut buf = Vec::with_capacity(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)
}

fn r_u32s(r: &mut impl Read) -> std::io::Result<Vec<u32>> {
    let len = r_u64(r)? as usize;
    let mut buf = vec![0u8; len * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn w_f32s(w: &mut impl Write, v: &[f32]) -> std::io::Result<()> {
    w_u64(w, v.len() as u64)?;
    let mut buf = Vec::with_capacity(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)
}

fn r_f32s(r: &mut impl Read) -> std::io::Result<Vec<f32>> {
    let len = r_u64(r)? as usize;
    let mut buf = vec![0u8; len * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn w_u64s(w: &mut impl Write, v: &[u64]) -> std::io::Result<()> {
    w_u64(w, v.len() as u64)?;
    let mut buf = Vec::with_capacity(v.len() * 8);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)
}

fn r_u64s(r: &mut impl Read) -> std::io::Result<Vec<u64>> {
    let len = r_u64(r)? as usize;
    let mut buf = vec![0u8; len * 8];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub fn save(ds: &Dataset, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    let name = ds.name.as_bytes();
    w_u64(&mut w, name.len() as u64)?;
    w.write_all(name)?;
    w_u64(&mut w, match ds.task {
        Task::Multiclass => 0,
        Task::Multilabel => 1,
    })?;
    w_u64(&mut w, ds.f_in as u64)?;
    w_u64(&mut w, ds.num_classes as u64)?;
    // graph
    w_u64(&mut w, ds.graph.n() as u64)?;
    let offs: Vec<u32> = ds.graph.offsets.iter().map(|&o| o as u32).collect();
    w_u32s(&mut w, &offs)?;
    w_u32s(&mut w, &ds.graph.cols)?;
    w_u32s(&mut w, &ds.graph.weights)?;
    w_u32s(&mut w, &ds.graph.node_weights)?;
    // features / labels / split
    w_f32s(&mut w, &ds.features)?;
    match &ds.labels {
        Labels::Multiclass(v) => {
            w_u64(&mut w, 0)?;
            w_u32s(&mut w, v)?;
        }
        Labels::Multilabel { bits, words_per_node } => {
            w_u64(&mut w, 1)?;
            w_u64(&mut w, *words_per_node as u64)?;
            w_u64s(&mut w, bits)?;
        }
    }
    let split: Vec<u32> = ds
        .split
        .iter()
        .map(|s| match s {
            Split::Train => 0u32,
            Split::Val => 1,
            Split::Test => 2,
        })
        .collect();
    w_u32s(&mut w, &split)?;
    w.flush()
}

pub fn load(path: &Path) -> std::io::Result<Dataset> {
    let bad = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m);
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad magic / version"));
    }
    let name_len = r_u64(&mut r)? as usize;
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let name = String::from_utf8(name).map_err(|_| bad("bad name"))?;
    let task = match r_u64(&mut r)? {
        0 => Task::Multiclass,
        1 => Task::Multilabel,
        _ => return Err(bad("bad task")),
    };
    let f_in = r_u64(&mut r)? as usize;
    let num_classes = r_u64(&mut r)? as usize;
    let _n = r_u64(&mut r)? as usize;
    let offsets: Vec<usize> = r_u32s(&mut r)?.into_iter().map(|o| o as usize).collect();
    let cols = r_u32s(&mut r)?;
    let weights = r_u32s(&mut r)?;
    let node_weights = r_u32s(&mut r)?;
    let graph = Csr { offsets, cols, weights, node_weights };
    let features = r_f32s(&mut r)?;
    let labels = match r_u64(&mut r)? {
        0 => Labels::Multiclass(r_u32s(&mut r)?),
        1 => {
            let wpn = r_u64(&mut r)? as usize;
            Labels::Multilabel { bits: r_u64s(&mut r)?, words_per_node: wpn }
        }
        _ => return Err(bad("bad labels tag")),
    };
    let split = r_u32s(&mut r)?
        .into_iter()
        .map(|s| match s {
            0 => Ok(Split::Train),
            1 => Ok(Split::Val),
            2 => Ok(Split::Test),
            _ => Err(bad("bad split tag")),
        })
        .collect::<Result<Vec<_>, _>>()?;
    let ds = Dataset {
        name,
        task,
        graph,
        f_in,
        num_classes,
        features,
        labels,
        split,
    };
    ds.validate().map_err(|e| bad(&e))?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cgcn_io_test_{}_{}", std::process::id(), name));
        p
    }

    fn sample(task: Task) -> Dataset {
        let graph = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let labels = match task {
            Task::Multiclass => Labels::Multiclass(vec![0, 1, 2, 0]),
            Task::Multilabel => {
                let mut l = Labels::multilabel_new(4, 3);
                l.set_label(0, 0);
                l.set_label(2, 2);
                l
            }
        };
        Dataset {
            name: "io_sample".into(),
            task,
            graph,
            f_in: 3,
            num_classes: 3,
            features: (0..12).map(|i| i as f32 * 0.5).collect(),
            labels,
            split: vec![Split::Train, Split::Val, Split::Test, Split::Train],
        }
    }

    #[test]
    fn roundtrip_multiclass() {
        let p = tmpfile("mc");
        let ds = sample(Task::Multiclass);
        save(&ds, &p).unwrap();
        let ds2 = load(&p).unwrap();
        assert_eq!(ds2.name, ds.name);
        assert_eq!(ds2.task, ds.task);
        assert_eq!(ds2.graph.cols, ds.graph.cols);
        assert_eq!(ds2.features, ds.features);
        assert_eq!(ds2.split, ds.split);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_multilabel() {
        let p = tmpfile("ml");
        let ds = sample(Task::Multilabel);
        save(&ds, &p).unwrap();
        let ds2 = load(&p).unwrap();
        assert!(ds2.labels.has_label(2, 2));
        assert!(!ds2.labels.has_label(1, 0));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmpfile("bad");
        std::fs::write(&p, b"not a dataset").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
