//! Compressed-sparse-row graph store.
//!
//! The whole pipeline — generators, the multilevel partitioner, batch
//! assembly, exact host inference — operates on this one structure.
//! Graphs are undirected and stored symmetrically (every edge appears in
//! both adjacency lists), matching the paper's setting where `A` is a
//! symmetric 0/1 adjacency matrix.

/// CSR adjacency with optional edge weights (the coarsened graphs of the
/// multilevel partitioner carry accumulated edge weights; level-0 input
/// graphs have unit weights).
#[derive(Clone, Debug)]
pub struct Csr {
    /// Row offsets, length n+1.
    pub offsets: Vec<usize>,
    /// Column indices, length = 2 * #edges (symmetric storage).
    pub cols: Vec<u32>,
    /// Edge weights aligned with `cols` (unit for level-0 graphs).
    pub weights: Vec<u32>,
    /// Node weights (coarsening accumulates contracted node counts).
    pub node_weights: Vec<u32>,
}

impl Csr {
    /// Number of nodes.
    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges (symmetric entries / 2).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.cols.len() / 2
    }

    /// Number of stored (directed) entries == nnz of A.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.cols[self.offsets[v]..self.offsets[v + 1]]
    }

    #[inline]
    pub fn neighbor_weights(&self, v: usize) -> &[u32] {
        &self.weights[self.offsets[v]..self.offsets[v + 1]]
    }

    pub fn total_node_weight(&self) -> u64 {
        self.node_weights.iter().map(|&w| w as u64).sum()
    }

    /// Build from an undirected edge list (deduplicates, drops self
    /// loops, symmetrizes). Nodes are `0..n`.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Csr {
        let mut deg = vec![0usize; n];
        let mut clean = Vec::with_capacity(edges.len());
        for &(u, v) in edges {
            if u == v {
                continue;
            }
            debug_assert!((u as usize) < n && (v as usize) < n);
            let (a, b) = if u < v { (u, v) } else { (v, u) };
            clean.push((a, b));
        }
        clean.sort_unstable();
        clean.dedup();
        for &(u, v) in &clean {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut cols = vec![0u32; offsets[n]];
        let mut cursor = offsets.clone();
        for &(u, v) in &clean {
            cols[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
            cols[cursor[v as usize]] = u;
            cursor[v as usize] += 1;
        }
        // sort each adjacency list for binary-searchable lookups
        for v in 0..n {
            cols[offsets[v]..offsets[v + 1]].sort_unstable();
        }
        let nnz = cols.len();
        Csr {
            offsets,
            cols,
            weights: vec![1; nnz],
            node_weights: vec![1; n],
        }
    }

    /// Is (u, v) an edge? Adjacency lists are sorted by construction.
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }

    /// Structural validation; used by tests and after IO.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n();
        if self.weights.len() != self.cols.len() {
            return Err("weights/cols length mismatch".into());
        }
        if self.node_weights.len() != n {
            return Err("node_weights length mismatch".into());
        }
        if *self.offsets.last().unwrap() != self.cols.len() {
            return Err("offsets end != cols len".into());
        }
        for v in 0..n {
            if self.offsets[v] > self.offsets[v + 1] {
                return Err(format!("offsets not monotone at {v}"));
            }
            let nb = self.neighbors(v);
            for w in nb.windows(2) {
                if w[0] > w[1] {
                    return Err(format!("unsorted adjacency at {v}"));
                }
            }
            for &u in nb {
                if u as usize >= n {
                    return Err(format!("col out of range at {v}"));
                }
                if u as usize == v {
                    return Err(format!("self loop at {v}"));
                }
                if !self.has_edge(u as usize, v) {
                    return Err(format!("asymmetric edge {v}->{u}"));
                }
            }
        }
        Ok(())
    }

    /// Degree statistics (Table 3-style reporting).
    pub fn degree_stats(&self) -> (usize, usize, f64) {
        let n = self.n();
        if n == 0 {
            return (0, 0, 0.0);
        }
        let mut min = usize::MAX;
        let mut max = 0;
        for v in 0..n {
            let d = self.degree(v);
            min = min.min(d);
            max = max.max(d);
        }
        (min, max, self.nnz() as f64 / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Csr {
        Csr::from_edges(3, &[(0, 1), (1, 2), (2, 0)])
    }

    #[test]
    fn build_triangle() {
        let g = triangle();
        assert_eq!(g.n(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.nnz(), 6);
        for v in 0..3 {
            assert_eq!(g.degree(v), 2);
        }
        g.validate().unwrap();
    }

    #[test]
    fn dedup_and_self_loops() {
        let g = Csr::from_edges(3, &[(0, 1), (1, 0), (0, 1), (2, 2)]);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(2), 0);
        g.validate().unwrap();
    }

    #[test]
    fn has_edge() {
        let g = triangle();
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0));
        let g2 = Csr::from_edges(4, &[(0, 1), (2, 3)]);
        assert!(!g2.has_edge(0, 2));
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edges(5, &[]);
        assert_eq!(g.n(), 5);
        assert_eq!(g.num_edges(), 0);
        g.validate().unwrap();
    }

    #[test]
    fn degree_stats() {
        let g = Csr::from_edges(4, &[(0, 1), (0, 2), (0, 3)]);
        let (min, max, avg) = g.degree_stats();
        assert_eq!((min, max), (1, 3));
        assert!((avg - 1.5).abs() < 1e-12);
    }
}
