//! Text-format graph ingestion (SNAP-style edge lists) so downstream
//! users can run the pipeline on real datasets: the paper's
//! PPI/Reddit/Amazon graphs all ship as edge lists + per-node label and
//! feature tables.
//!
//! Formats:
//! - edge list: one `u v` pair per line, `#` comments, whitespace
//!   separated, node ids arbitrary u32 (compacted to 0..n).
//! - labels: `node label` (multiclass) or `node l1,l2,...` (multilabel).
//! - features: `node f1 f2 ... fF`.

use std::collections::HashMap;
use std::io::BufRead;
use std::path::Path;

use crate::graph::csr::Csr;

/// Parse an edge list; returns (graph, original-id -> compact-id map).
pub fn load_edge_list(path: &Path) -> std::io::Result<(Csr, HashMap<u64, u32>)> {
    let f = std::fs::File::open(path)?;
    let r = std::io::BufReader::new(f);
    parse_edge_list(r.lines().map_while(Result::ok))
}

/// Parse from an iterator of lines (testable without files).
pub fn parse_edge_list<I: Iterator<Item = String>>(
    lines: I,
) -> std::io::Result<(Csr, HashMap<u64, u32>)> {
    let bad = |m: String| std::io::Error::new(std::io::ErrorKind::InvalidData, m);
    let mut id_of: HashMap<u64, u32> = HashMap::new();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut intern = |raw: u64, id_of: &mut HashMap<u64, u32>| -> u32 {
        let next = id_of.len() as u32;
        *id_of.entry(raw).or_insert(next)
    };
    for (lineno, line) in lines.enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(a), Some(b)) = (it.next(), it.next()) else {
            return Err(bad(format!("line {}: expected 'u v'", lineno + 1)));
        };
        let u: u64 = a
            .parse()
            .map_err(|_| bad(format!("line {}: bad node id {a:?}", lineno + 1)))?;
        let v: u64 = b
            .parse()
            .map_err(|_| bad(format!("line {}: bad node id {b:?}", lineno + 1)))?;
        let lu = intern(u, &mut id_of);
        let lv = intern(v, &mut id_of);
        edges.push((lu, lv));
    }
    let n = id_of.len();
    Ok((Csr::from_edges(n, &edges), id_of))
}

/// Write a graph back out as an edge list (one direction per edge).
pub fn save_edge_list(g: &Csr, path: &Path) -> std::io::Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "# cluster-gcn edge list: {} nodes {} edges", g.n(), g.num_edges())?;
    for v in 0..g.n() {
        for &u in g.neighbors(v) {
            if (v as u32) < u {
                writeln!(w, "{v} {u}")?;
            }
        }
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines(s: &str) -> impl Iterator<Item = String> + '_ {
        s.lines().map(|l| l.to_string())
    }

    #[test]
    fn parses_simple_list() {
        let (g, ids) = parse_edge_list(lines(
            "# comment\n10 20\n20 30\n\n% also comment\n10 30\n",
        ))
        .unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(ids.len(), 3);
        // compact ids assigned in first-seen order
        assert_eq!(ids[&10], 0);
        assert_eq!(ids[&20], 1);
        g.validate().unwrap();
    }

    #[test]
    fn dedups_and_ignores_direction() {
        let (g, _) = parse_edge_list(lines("1 2\n2 1\n1 2\n")).unwrap();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_edge_list(lines("1 x\n")).is_err());
        assert!(parse_edge_list(lines("lonely\n")).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]);
        let mut p = std::env::temp_dir();
        p.push(format!("cgcn_txt_{}.edges", std::process::id()));
        save_edge_list(&g, &p).unwrap();
        let (g2, _) = load_edge_list(&p).unwrap();
        assert_eq!(g2.n(), 5);
        assert_eq!(g2.num_edges(), 5);
        std::fs::remove_file(&p).ok();
    }
}
