//! Dataset container: graph + node features + labels + train/val/test
//! split (Table 3 / Table 12 of the paper).

use super::csr::Csr;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Softmax cross-entropy, single label per node (Reddit, Amazon2M).
    Multiclass,
    /// Sigmoid BCE, label bitset per node (PPI, Amazon).
    Multilabel,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

#[derive(Clone, Debug)]
pub enum Labels {
    /// class id per node.
    Multiclass(Vec<u32>),
    /// row-major dense 0/1 matrix [n, classes] packed into u64 words;
    /// `words_per_node = ceil(classes / 64)`.
    Multilabel { bits: Vec<u64>, words_per_node: usize },
}

#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub task: Task,
    pub graph: Csr,
    pub f_in: usize,
    pub num_classes: usize,
    /// row-major [n, f_in]
    pub features: Vec<f32>,
    pub labels: Labels,
    pub split: Vec<Split>,
}

impl Labels {
    pub fn multilabel_new(n: usize, classes: usize) -> Labels {
        let wpn = classes.div_ceil(64);
        Labels::Multilabel { bits: vec![0; n * wpn], words_per_node: wpn }
    }

    pub fn set_label(&mut self, node: usize, class: usize) {
        match self {
            Labels::Multiclass(v) => v[node] = class as u32,
            Labels::Multilabel { bits, words_per_node } => {
                bits[node * *words_per_node + class / 64] |= 1u64 << (class % 64);
            }
        }
    }

    pub fn has_label(&self, node: usize, class: usize) -> bool {
        match self {
            Labels::Multiclass(v) => v[node] == class as u32,
            Labels::Multilabel { bits, words_per_node } => {
                bits[node * *words_per_node + class / 64] >> (class % 64) & 1 == 1
            }
        }
    }

    pub fn class_of(&self, node: usize) -> Option<u32> {
        match self {
            Labels::Multiclass(v) => Some(v[node]),
            Labels::Multilabel { .. } => None,
        }
    }

    /// Write the one-hot / multi-hot row for `node` into `row` (length
    /// = num_classes). Used by batch assembly.
    pub fn write_row(&self, node: usize, classes: usize, row: &mut [f32]) {
        debug_assert_eq!(row.len(), classes);
        row.iter_mut().for_each(|x| *x = 0.0);
        match self {
            Labels::Multiclass(v) => {
                row[v[node] as usize] = 1.0;
            }
            Labels::Multilabel { bits, words_per_node } => {
                for c in 0..classes {
                    if bits[node * *words_per_node + c / 64] >> (c % 64) & 1 == 1 {
                        row[c] = 1.0;
                    }
                }
            }
        }
    }
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    pub fn feature_row(&self, node: usize) -> &[f32] {
        &self.features[node * self.f_in..(node + 1) * self.f_in]
    }

    pub fn split_counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for s in &self.split {
            match s {
                Split::Train => c.0 += 1,
                Split::Val => c.1 += 1,
                Split::Test => c.2 += 1,
            }
        }
        c
    }

    pub fn nodes_in_split(&self, want: Split) -> Vec<u32> {
        (0..self.n())
            .filter(|&v| self.split[v] == want)
            .map(|v| v as u32)
            .collect()
    }

    /// Class histogram over a node set (Fig. 2 label entropy; for
    /// multilabel, each set bit counts).
    pub fn label_histogram(&self, nodes: &[u32]) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &v in nodes {
            match &self.labels {
                Labels::Multiclass(l) => h[l[v as usize] as usize] += 1,
                Labels::Multilabel { .. } => {
                    for c in 0..self.num_classes {
                        if self.labels.has_label(v as usize, c) {
                            h[c] += 1;
                        }
                    }
                }
            }
        }
        h
    }

    /// Structural + shape validation.
    pub fn validate(&self) -> Result<(), String> {
        self.graph.validate()?;
        let n = self.n();
        if self.features.len() != n * self.f_in {
            return Err("features shape mismatch".into());
        }
        if self.split.len() != n {
            return Err("split length mismatch".into());
        }
        match &self.labels {
            Labels::Multiclass(v) => {
                if v.len() != n {
                    return Err("labels length mismatch".into());
                }
                if v.iter().any(|&c| c as usize >= self.num_classes) {
                    return Err("label out of range".into());
                }
            }
            Labels::Multilabel { bits, words_per_node } => {
                if *words_per_node != self.num_classes.div_ceil(64)
                    || bits.len() != n * *words_per_node
                {
                    return Err("multilabel bits shape mismatch".into());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let graph = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let mut labels = Labels::Multiclass(vec![0; 4]);
        labels.set_label(1, 2);
        Dataset {
            name: "tiny".into(),
            task: Task::Multiclass,
            graph,
            f_in: 2,
            num_classes: 3,
            features: vec![0.0; 8],
            labels,
            split: vec![Split::Train, Split::Train, Split::Val, Split::Test],
        }
    }

    #[test]
    fn validate_ok() {
        tiny().validate().unwrap();
    }

    #[test]
    fn split_counts() {
        assert_eq!(tiny().split_counts(), (2, 1, 1));
    }

    #[test]
    fn multiclass_row() {
        let d = tiny();
        let mut row = vec![9.0; 3];
        d.labels.write_row(1, 3, &mut row);
        assert_eq!(row, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn multilabel_bits() {
        let mut l = Labels::multilabel_new(2, 70);
        l.set_label(0, 0);
        l.set_label(0, 69);
        l.set_label(1, 64);
        assert!(l.has_label(0, 0) && l.has_label(0, 69) && l.has_label(1, 64));
        assert!(!l.has_label(0, 64) && !l.has_label(1, 0));
        let mut row = vec![0.0; 70];
        l.write_row(0, 70, &mut row);
        assert_eq!(row.iter().sum::<f32>(), 2.0);
    }

    #[test]
    fn histogram() {
        let d = tiny();
        let h = d.label_histogram(&[0, 1, 2, 3]);
        assert_eq!(h, vec![3, 0, 1]);
    }

    #[test]
    fn validate_catches_bad_label() {
        let mut d = tiny();
        if let Labels::Multiclass(v) = &mut d.labels {
            v[0] = 99;
        }
        assert!(d.validate().is_err());
    }
}
