//! [`DistributedBackend`]: cross-process data-parallel training.  The
//! chief process spawns `N` worker processes, places each worker over
//! its own share of the graph clusters (cluster `c` belongs to worker
//! `c % N` — partition-aligned data placement, so a worker only ever
//! assembles batches from clusters it owns), and runs a chief
//! all-reduce per optimization step over a byte protocol on UNIX or
//! TCP sockets ([`wire`]):
//!
//! ```text
//!   step_from(first):                (one request per plan entry)
//!     chief ── Step{epoch, i, weights} ──► worker owner(i) ─ Grads ─┐
//!     chief ── Step{epoch, i+1, ...}  ──► worker owner(i+1) ─ Grads ┼─ avg ─► chief Adam
//!     chief ── Step{epoch, i+k-1,...} ──► worker owner(...) ─ Grads ┘
//! ```
//!
//! Workers are stateless request servers: every `Step` carries the
//! full weights, every reply the batch loss + per-layer gradients
//! (optionally top-k sparsified or 8-bit quantized,
//! [`wire::Compression`]).  That statelessness is what makes the fault
//! story simple — an exchange is idempotent (`(epoch, index, weights)`
//! deterministically produces the same gradient bits), so any socket
//! fault (dropped frame, torn frame, stalled read; injectable via the
//! `dist.*` failpoints) is handled by dropping the connection,
//! re-accepting the worker's reconnect (respawning the process if it
//! died), and re-running the exchange with bounded backoff — the same
//! retry discipline as the PR-8 self-healing layer, at the transport
//! level.  A recovered run replays the exact trajectory of an
//! unfaulted one.
//!
//! Parity contract (mirrors [`super::ShardedBackend`], pinned by
//! `tests/distributed.rs` and gated in ci.sh): `workers = 1` is
//! **bit-identical** to [`HostBackend`] — same loss bits, same weight
//! bits — because the single worker derives the identical epoch plan
//! (`ClusterSource::new_distributed` with one worker *is* the plain
//! source), assembles the identical batches, computes gradients with
//! the same kernels, ships them raw, and the chief applies the same
//! single-replica Adam step.  `workers = N` grows the per-step batch
//! N-fold and is loss-curve equivalent, not bitwise.
//!
//! Every process derives partition, plan, and shapes from the same
//! `(preset, seed, parts, q)` via [`crate::session::Session`] — the
//! `Setup` frame carries configuration, never graph data.
#![deny(missing_docs)]

pub mod wire;

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::batch::Batch;
use crate::coordinator::source::BatchSource;
use crate::coordinator::trainer::TrainState;
use crate::norm::{DiagEnhance, NormConfig, NormKind};
use crate::runtime::backend::{Backend, ModelSpec, StepOutcome, VrgcnBatch};
use crate::runtime::exec::Tensor;
use crate::runtime::host::HostBackend;
use crate::util::failpoint;
use crate::util::simd::axpy;
use wire::{Frame, Kind, PayloadReader, PayloadWriter, FLAG_EMPTY, PROTO_VERSION};

pub use wire::Compression;

/// Socket family the chief listens on and workers dial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    /// `AF_UNIX` stream socket in the temp dir (default; lowest latency).
    Unix,
    /// TCP on `127.0.0.1` (an ephemeral port); the cross-host shape.
    Tcp,
}

impl Transport {
    /// Parse the CLI surface (`unix` | `tcp`).
    pub fn parse(s: &str) -> Result<Transport> {
        match s {
            "unix" => Ok(Transport::Unix),
            "tcp" => Ok(Transport::Tcp),
            other => bail!("unknown transport {other:?} (expected unix | tcp)"),
        }
    }

    /// Short label for logs and env plumbing.
    pub fn label(&self) -> &'static str {
        match self {
            Transport::Unix => "unix",
            Transport::Tcp => "tcp",
        }
    }
}

// ---------------------------------------------------------------------
// Transport plumbing
// ---------------------------------------------------------------------

/// A connected chief↔worker byte stream.
enum Stream {
    /// UNIX domain stream.
    Unix(UnixStream),
    /// Localhost TCP stream (`TCP_NODELAY` set).
    Tcp(TcpStream),
}

impl Stream {
    fn connect(transport: Transport, addr: &str) -> Result<Stream> {
        Ok(match transport {
            Transport::Unix => Stream::Unix(UnixStream::connect(addr)?),
            Transport::Tcp => {
                let s = TcpStream::connect(addr)?;
                s.set_nodelay(true)?;
                Stream::Tcp(s)
            }
        })
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        match self {
            Stream::Unix(s) => s.set_read_timeout(d)?,
            Stream::Tcp(s) => s.set_read_timeout(d)?,
        }
        Ok(())
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

/// The chief's accept socket; UNIX sockets clean their path up on drop.
enum Listener {
    /// UNIX listener plus the socket path to unlink.
    Unix(UnixListener, PathBuf),
    /// Localhost TCP listener on an ephemeral port.
    Tcp(TcpListener),
}

impl Listener {
    fn bind(transport: Transport) -> Result<Listener> {
        match transport {
            Transport::Unix => {
                static SEQ: AtomicU64 = AtomicU64::new(0);
                let path = std::env::temp_dir().join(format!(
                    "cgcn-dist-{}-{}.sock",
                    std::process::id(),
                    SEQ.fetch_add(1, Ordering::Relaxed)
                ));
                let _ = std::fs::remove_file(&path);
                let l = UnixListener::bind(&path)
                    .with_context(|| format!("bind {}", path.display()))?;
                l.set_nonblocking(true)?;
                Ok(Listener::Unix(l, path))
            }
            Transport::Tcp => {
                let l = TcpListener::bind("127.0.0.1:0")?;
                l.set_nonblocking(true)?;
                Ok(Listener::Tcp(l))
            }
        }
    }

    /// The address workers dial (socket path, or `127.0.0.1:port`).
    fn addr(&self) -> Result<String> {
        Ok(match self {
            Listener::Unix(_, path) => path.display().to_string(),
            Listener::Tcp(l) => l.local_addr()?.to_string(),
        })
    }

    /// Accept one connection, polling until `deadline`; `Ok(None)` on
    /// timeout (the listener is non-blocking so a dead worker cannot
    /// hang the chief forever).
    fn accept_by(&self, deadline: Instant) -> Result<Option<Stream>> {
        loop {
            let r = match self {
                Listener::Unix(l, _) => l.accept().map(|(s, _)| Stream::Unix(s)),
                Listener::Tcp(l) => l.accept().map(|(s, _)| {
                    let _ = s.set_nodelay(true);
                    Stream::Tcp(s)
                }),
            };
            match r {
                Ok(s) => return Ok(Some(s)),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Ok(None);
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

// ---------------------------------------------------------------------
// Run setup shipped to workers
// ---------------------------------------------------------------------

/// Everything a worker process needs to rebuild the chief's exact view
/// of the run — configuration only, never graph data: the worker
/// re-derives dataset, partition, plan, and spec through the same
/// [`crate::session::Session`] code path the chief used.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerSetup {
    /// Dataset preset name (`cora_like`, ...).
    pub preset: String,
    /// Dataset generation seed.
    pub ds_seed: u64,
    /// Dataset cache directory (workers reuse the chief's cache).
    pub cache: String,
    /// Experiment seed ([`crate::session::TrainConfig::seed`]).
    pub cfg_seed: u64,
    /// GCN depth.
    pub layers: usize,
    /// Hidden width override.
    pub hidden: Option<usize>,
    /// Padded batch size override.
    pub b_max: Option<usize>,
    /// Partition count override.
    pub parts: Option<usize>,
    /// Clusters per batch.
    pub q: usize,
    /// Random instead of multilevel partitioning.
    pub random_partition: bool,
    /// Adjacency normalization.
    pub norm: NormConfig,
    /// Total distributed workers (the ownership modulus).
    pub n_workers: usize,
    /// Gradient uplink compression.
    pub compression: Compression,
}

impl WorkerSetup {
    /// Serialize for the `Setup` frame.
    pub fn to_payload(&self) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.put_u32(PROTO_VERSION);
        w.put_str(&self.preset);
        w.put_u64(self.ds_seed);
        w.put_str(&self.cache);
        w.put_u64(self.cfg_seed);
        w.put_u32(self.layers as u32);
        put_opt(&mut w, self.hidden);
        put_opt(&mut w, self.b_max);
        put_opt(&mut w, self.parts);
        w.put_u32(self.q as u32);
        w.put_u8(self.random_partition as u8);
        w.put_u8(match self.norm.kind {
            NormKind::Sym => 0,
            NormKind::RowNorm => 1,
        });
        match self.norm.enhance {
            DiagEnhance::None => {
                w.put_u8(0);
                w.put_f32(0.0);
            }
            DiagEnhance::AddIdentity => {
                w.put_u8(1);
                w.put_f32(0.0);
            }
            DiagEnhance::AddLambdaDiag(l) => {
                w.put_u8(2);
                w.put_f32(l);
            }
        }
        w.put_u32(self.n_workers as u32);
        self.compression.put(&mut w);
        w.buf
    }

    /// Parse a `Setup` frame payload (rejects protocol mismatches).
    pub fn from_payload(bytes: &[u8]) -> Result<WorkerSetup> {
        let mut r = PayloadReader::new(bytes);
        let ver = r.get_u32()?;
        if ver != PROTO_VERSION {
            bail!("protocol version mismatch: chief {ver}, worker {PROTO_VERSION}");
        }
        let preset = r.get_str()?;
        let ds_seed = r.get_u64()?;
        let cache = r.get_str()?;
        let cfg_seed = r.get_u64()?;
        let layers = r.get_u32()? as usize;
        let hidden = get_opt(&mut r)?;
        let b_max = get_opt(&mut r)?;
        let parts = get_opt(&mut r)?;
        let q = r.get_u32()? as usize;
        let random_partition = r.get_u8()? != 0;
        let kind = match r.get_u8()? {
            0 => NormKind::Sym,
            1 => NormKind::RowNorm,
            k => bail!("unknown norm kind tag {k}"),
        };
        let etag = r.get_u8()?;
        let lambda = r.get_f32()?;
        let enhance = match etag {
            0 => DiagEnhance::None,
            1 => DiagEnhance::AddIdentity,
            2 => DiagEnhance::AddLambdaDiag(lambda),
            k => bail!("unknown diag-enhance tag {k}"),
        };
        let n_workers = r.get_u32()? as usize;
        let compression = Compression::get(&mut r)?;
        if !r.done() {
            bail!("trailing bytes in setup payload");
        }
        Ok(WorkerSetup {
            preset,
            ds_seed,
            cache,
            cfg_seed,
            layers,
            hidden,
            b_max,
            parts,
            q,
            random_partition,
            norm: NormConfig { kind, enhance },
            n_workers,
            compression,
        })
    }

    /// Rebuild the session this setup describes over a worker-local
    /// dataset (same derivation code as the chief's driver).
    fn session<'a>(&self, ds: &'a crate::graph::Dataset) -> crate::session::Session<'a> {
        let cfg = crate::session::TrainConfig {
            layers: self.layers,
            hidden: self.hidden,
            b_max: self.b_max,
            seed: self.cfg_seed,
            norm: self.norm,
            ..crate::session::TrainConfig::default()
        };
        let mut s = crate::session::Session::new(ds)
            .method(crate::session::Method::Cluster { q: self.q })
            .config(cfg)
            .workers(self.n_workers);
        if let Some(p) = self.parts {
            s = s.partition(p);
        }
        if self.random_partition {
            s = s.partition_random();
        }
        s
    }
}

fn put_opt(w: &mut PayloadWriter, v: Option<usize>) {
    match v {
        Some(x) => {
            w.put_u8(1);
            w.put_u64(x as u64);
        }
        None => {
            w.put_u8(0);
            w.put_u64(0);
        }
    }
}

fn get_opt(r: &mut PayloadReader) -> Result<Option<usize>> {
    let present = r.get_u8()? != 0;
    let v = r.get_u64()? as usize;
    Ok(present.then_some(v))
}

// ---------------------------------------------------------------------
// Chief-side configuration + stats
// ---------------------------------------------------------------------

/// Configuration of a [`DistributedBackend`].
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Worker process count (the plan's ownership modulus).
    pub workers: usize,
    /// Socket family.
    pub transport: Transport,
    /// What workers rebuild the run from.
    pub setup: WorkerSetup,
    /// Override the worker command (defaults to
    /// `current_exe __worker`); integration tests point this at their
    /// own test binary's worker hook.
    pub worker_cmd: Option<(PathBuf, Vec<String>)>,
    /// Exchange retries per step before the step errors.
    pub max_retries: usize,
    /// Base backoff between retries (doubled per attempt).
    pub backoff: Duration,
    /// Chief-side read timeout per response (a hung worker becomes a
    /// retriable fault instead of a hang).
    pub read_timeout: Duration,
    /// How long to wait for a worker (re)connect.
    pub accept_timeout: Duration,
}

impl DistConfig {
    /// Config with the retry/backoff defaults (4 retries, 25 ms base
    /// backoff, 120 s read timeout, 60 s accept timeout).
    pub fn new(workers: usize, transport: Transport, setup: WorkerSetup) -> DistConfig {
        assert!(workers >= 1, "a distributed backend needs at least one worker");
        DistConfig {
            workers,
            transport,
            setup,
            worker_cmd: None,
            max_retries: 4,
            backoff: Duration::from_millis(25),
            read_timeout: Duration::from_secs(120),
            accept_timeout: Duration::from_secs(60),
        }
    }
}

/// Shared transport counters, readable after the run through the
/// `Arc` handed out by [`DistributedBackend::stats`] (the backend
/// itself disappears behind `Box<dyn Backend>` in the session).
#[derive(Debug, Default)]
pub struct DistStats {
    /// Bytes written to workers (requests).
    pub bytes_tx: AtomicU64,
    /// Bytes read from workers (responses).
    pub bytes_rx: AtomicU64,
    /// Dense `f32` bytes the received gradients represent.
    pub raw_grad_bytes: AtomicU64,
    /// Gradient payload bytes actually on the wire.
    pub wire_grad_bytes: AtomicU64,
    /// Exchanges re-run after a fault.
    pub retries: AtomicU64,
    /// Connections re-established.
    pub reconnects: AtomicU64,
    /// Worker processes respawned after dying.
    pub respawns: AtomicU64,
    /// Optimization steps completed.
    pub steps: AtomicU64,
}

impl DistStats {
    /// Uplink compression ratio: dense gradient bytes over wire
    /// gradient bytes (1.0 when nothing was exchanged yet).
    pub fn compression_ratio(&self) -> f64 {
        let raw = self.raw_grad_bytes.load(Ordering::Relaxed);
        let wire = self.wire_grad_bytes.load(Ordering::Relaxed);
        if wire == 0 {
            1.0
        } else {
            raw as f64 / wire as f64
        }
    }

    fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------
// The chief backend
// ---------------------------------------------------------------------

struct WorkerSlot {
    child: Option<Child>,
    conn: Option<Stream>,
}

/// Cross-process data-parallel [`Backend`]; see the module docs for the
/// step anatomy, fault handling, and the parity contract.
pub struct DistributedBackend {
    chief: HostBackend,
    cfg: DistConfig,
    stats: Arc<DistStats>,
    listener: Option<Listener>,
    slots: Vec<WorkerSlot>,
    avg: Vec<Vec<f32>>,
}

impl DistributedBackend {
    /// Chief over `cfg.workers` spawned worker processes (spawned
    /// lazily on the first step, so constructing the backend is cheap
    /// and registration/eval paths never fork).
    pub fn new(cfg: DistConfig) -> DistributedBackend {
        let slots = (0..cfg.workers).map(|_| WorkerSlot { child: None, conn: None }).collect();
        DistributedBackend {
            chief: HostBackend::new(),
            cfg,
            stats: Arc::new(DistStats::default()),
            listener: None,
            slots,
            avg: Vec::new(),
        }
    }

    /// Shared transport counters (keep a clone before boxing the
    /// backend into a session).
    pub fn stats(&self) -> Arc<DistStats> {
        Arc::clone(&self.stats)
    }

    fn worker_cmd(&self) -> Result<(PathBuf, Vec<String>)> {
        if let Some(c) = &self.cfg.worker_cmd {
            return Ok(c.clone());
        }
        Ok((std::env::current_exe()?, vec!["__worker".to_string()]))
    }

    fn spawn_worker(&mut self, id: usize) -> Result<()> {
        let addr = self
            .listener
            .as_ref()
            .expect("listener bound before spawning")
            .addr()?;
        let (exe, args) = self.worker_cmd()?;
        let child = Command::new(&exe)
            .args(&args)
            .env("CGCN_DIST_ADDR", &addr)
            .env("CGCN_DIST_TRANSPORT", self.cfg.transport.label())
            .env("CGCN_DIST_ID", id.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawn worker {id} ({})", exe.display()))?;
        self.slots[id].child = Some(child);
        Ok(())
    }

    /// Accept one connection and route it to its slot by the worker id
    /// in its `Hello`.  Returns the id.  Polls in short slices so a
    /// worker process that died without connecting fails the handshake
    /// with its exit status instead of a bare timeout.
    fn accept_one(&mut self, deadline: Instant) -> Result<usize> {
        let mut conn = loop {
            self.reap_dead_children()?;
            if Instant::now() >= deadline {
                bail!("timed out waiting for a worker to connect");
            }
            let slice = (Instant::now() + Duration::from_millis(200)).min(deadline);
            let listener = self.listener.as_ref().expect("listener bound");
            if let Some(conn) = listener.accept_by(slice)? {
                break conn;
            }
        };
        conn.set_read_timeout(Some(self.cfg.read_timeout))?;
        let (hello, n) = wire::read_frame(&mut conn)?;
        DistStats::add(&self.stats.bytes_rx, n as u64);
        if hello.kind != Kind::Hello {
            bail!("expected Hello, got {:?}", hello.kind);
        }
        let mut r = PayloadReader::new(&hello.payload);
        let id = r.get_u32()? as usize;
        let ver = r.get_u32()?;
        if ver != PROTO_VERSION {
            bail!("worker {id} speaks protocol {ver}, chief speaks {PROTO_VERSION}");
        }
        if id >= self.slots.len() {
            bail!("worker id {id} out of range ({} workers)", self.slots.len());
        }
        let tx = wire::write_frame(
            &mut conn,
            Kind::Setup,
            0,
            &self.cfg.setup.to_payload(),
        )?;
        DistStats::add(&self.stats.bytes_tx, tx as u64);
        self.slots[id].conn = Some(conn);
        Ok(id)
    }

    /// Bind, spawn every worker, and complete the Hello/Setup
    /// handshake.  Idempotent.
    fn ensure_started(&mut self) -> Result<()> {
        if self.listener.is_some() {
            return Ok(());
        }
        self.listener = Some(Listener::bind(self.cfg.transport)?);
        for id in 0..self.cfg.workers {
            self.spawn_worker(id)?;
        }
        let deadline = Instant::now() + self.cfg.accept_timeout;
        while self.slots.iter().any(|s| s.conn.is_none()) {
            self.accept_one(deadline)?;
        }
        Ok(())
    }

    /// Error out early when a worker process died without a connection
    /// up (misconfigured command, crashed on startup).
    fn reap_dead_children(&mut self) -> Result<()> {
        for (id, slot) in self.slots.iter_mut().enumerate() {
            if slot.conn.is_none() {
                if let Some(child) = &mut slot.child {
                    if let Some(status) = child.try_wait()? {
                        bail!("worker {id} exited without connecting ({status})");
                    }
                }
            }
        }
        Ok(())
    }

    /// Tear down and re-create worker `id`'s connection: close the old
    /// stream (the worker's read then fails and it dials back in),
    /// respawn the process if it died, and re-accept — re-routing any
    /// *other* worker that happened to reconnect in the meantime.
    fn reestablish(&mut self, id: usize) -> Result<()> {
        self.slots[id].conn = None;
        DistStats::add(&self.stats.reconnects, 1);
        let deadline = Instant::now() + self.cfg.accept_timeout;
        loop {
            let dead = match &mut self.slots[id].child {
                Some(child) => child.try_wait()?.is_some(),
                None => true,
            };
            if dead {
                self.slots[id].child = None;
                DistStats::add(&self.stats.respawns, 1);
                self.spawn_worker(id)?;
            }
            match self.accept_one(deadline) {
                Ok(got) if got == id => return Ok(()),
                // some other worker reconnected first; it has been
                // routed to its slot — keep waiting for ours
                Ok(_) => {}
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    // the worker died inside the accept window — the
                    // next pass respawns it
                    eprintln!("distributed: worker {id} reconnect failed ({e:#}), retrying");
                }
            }
        }
    }

    /// Serialize the full weight set for a `Step` request prefix.
    fn weights_payload(state: &TrainState, epoch: u64, index: u64) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.put_u64(epoch);
        w.put_u64(index);
        w.put_u32(state.weights.len() as u32);
        for t in &state.weights {
            w.put_u32(t.data.len() as u32);
            w.put_f32s(&t.data);
        }
        w.buf
    }
}

/// One worker's reply to a `Step`: the batch loss and decoded per-layer
/// gradients (`None` when the batch held no training node).
type GradReply = Option<(f32, Vec<Vec<f32>>)>;

/// Run one request/response exchange over an established connection.
/// Any error (including injected faults) leaves the connection dirty;
/// the caller must [`DistributedBackend::reestablish`] before retrying.
fn exchange_one(
    conn: &mut Stream,
    payload: &[u8],
    stats: &DistStats,
) -> Result<GradReply> {
    // injected fault: the request frame never makes it onto the wire
    failpoint::check("dist.send.drop")?;
    // injected fault: the request frame is cut mid-write; the worker's
    // frame decode fails (EOF or CRC) and it reconnects
    if let Err(fault) = failpoint::check("dist.send.torn") {
        let n = wire::write_torn_frame(conn, Kind::Step, 0, payload)?;
        DistStats::add(&stats.bytes_tx, n as u64);
        return Err(fault.into());
    }
    let tx = wire::write_frame(conn, Kind::Step, 0, payload)?;
    DistStats::add(&stats.bytes_tx, tx as u64);
    // injected fault: a stalled response (latency, not loss)
    failpoint::maybe_delay("dist.recv.delay", 10);
    let (frame, rx) = wire::read_frame(conn)?;
    DistStats::add(&stats.bytes_rx, rx as u64);
    if frame.kind != Kind::Grads {
        bail!("expected Grads, got {:?}", frame.kind);
    }
    let mut r = PayloadReader::new(&frame.payload);
    let loss = r.get_f32()?;
    if frame.flags & FLAG_EMPTY != 0 {
        return Ok(None);
    }
    let layers = r.get_u32()? as usize;
    let mut grads = Vec::with_capacity(layers);
    for _ in 0..layers {
        let mut g = Vec::new();
        wire::decode_grad(&mut r, &mut g)?;
        DistStats::add(&stats.raw_grad_bytes, g.len() as u64 * 4);
        grads.push(g);
    }
    DistStats::add(&stats.wire_grad_bytes, frame.payload.len() as u64);
    Ok(Some((loss, grads)))
}

impl Backend for DistributedBackend {
    fn name(&self) -> &'static str {
        "distributed"
    }

    fn model_spec(&mut self, model: &str) -> Result<ModelSpec> {
        self.chief.model_spec(model)
    }

    fn register_model(&mut self, model: &str, spec: ModelSpec) -> bool {
        self.chief.register_model(model, spec)
    }

    fn train_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &Batch,
    ) -> Result<f32> {
        // non-pull entry points (guard replays, ad-hoc steps) run on
        // the chief's own kernels — bit-identical to a worker's by the
        // parity contract
        self.chief.train_step(model, state, lr, batch)
    }

    fn forward(&mut self, model: &str, weights: &[Tensor], batch: &Batch) -> Result<Tensor> {
        self.chief.forward(model, weights, batch)
    }

    fn vrgcn_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        self.chief.vrgcn_step(model, state, lr, batch)
    }

    fn batches_per_step(&self) -> usize {
        self.cfg.workers
    }

    fn epoch_begin(&mut self) {
        self.chief.epoch_begin();
    }

    fn prefetchable(&self) -> bool {
        // batches are assembled by worker processes from their own
        // clusters; a lookahead wrapper feeding chief-assembled batches
        // into train_step would silently bypass distribution
        false
    }

    fn step_from(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        source: &mut dyn BatchSource,
        first: usize,
        _scratch: &mut Batch,
    ) -> Result<StepOutcome> {
        let k = self.cfg.workers.min(source.len().saturating_sub(first));
        if k == 0 {
            return Err(anyhow!("step_from past the end of the epoch plan"));
        }
        self.ensure_started()?;
        let epoch = source.epoch() as u64;

        // one deterministic reply slot per plan entry; retries only
        // re-run the entries whose exchange faulted
        let mut replies: Vec<Option<GradReply>> = (0..k).map(|_| None).collect();
        let mut attempt = 0;
        loop {
            let pending: Vec<(usize, usize)> = (0..k)
                .filter(|&j| replies[j].is_none())
                .map(|j| (j, source.owner_of(first + j)))
                .collect();
            if pending.is_empty() {
                break;
            }
            if attempt > self.cfg.max_retries {
                bail!(
                    "distributed step at epoch {epoch} gave up after {} retries \
                     ({} of {k} exchanges still failing)",
                    self.cfg.max_retries,
                    pending.len()
                );
            }
            if attempt > 0 {
                DistStats::add(&self.stats.retries, pending.len() as u64);
                std::thread::sleep(self.cfg.backoff * (1 << (attempt - 1).min(6)));
                let mut owners: Vec<usize> = pending.iter().map(|&(_, o)| o).collect();
                owners.sort_unstable();
                owners.dedup();
                for o in owners {
                    self.reestablish(o)?;
                }
            }
            attempt += 1;

            // group pending entries by owning worker, then fan out one
            // thread per worker connection
            let mut jobs: Vec<Vec<usize>> = vec![Vec::new(); self.cfg.workers];
            for &(j, o) in &pending {
                jobs[o].push(j);
            }
            let payloads: Vec<Vec<u8>> = (0..k)
                .map(|j| Self::weights_payload(state, epoch, (first + j) as u64))
                .collect();
            let stats: &DistStats = &self.stats;
            let slots = &mut self.slots;
            let outcomes: Vec<(usize, Result<GradReply>)> = std::thread::scope(|s| {
                let handles: Vec<_> = slots
                    .iter_mut()
                    .zip(jobs.iter())
                    .filter(|(_, js)| !js.is_empty())
                    .map(|(slot, js)| {
                        let payloads = &payloads;
                        s.spawn(move || {
                            let conn = slot
                                .conn
                                .as_mut()
                                .expect("established before exchange");
                            let mut out = Vec::with_capacity(js.len());
                            for &j in js {
                                let r = exchange_one(conn, &payloads[j], stats);
                                let failed = r.is_err();
                                out.push((j, r));
                                if failed {
                                    // connection is dirty; the retry
                                    // pass reestablishes it
                                    break;
                                }
                            }
                            out
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| match h.join() {
                        Ok(v) => v,
                        Err(p) => std::panic::resume_unwind(p),
                    })
                    .collect()
            });
            for (j, r) in outcomes {
                match r {
                    Ok(reply) => replies[j] = Some(reply),
                    Err(e) => eprintln!(
                        "distributed: exchange for batch {} faulted (attempt {attempt}): {e:#}",
                        first + j
                    ),
                }
            }
        }

        // ---- all-reduce: sum in plan order, scale once ---------------
        let active: Vec<(f32, Vec<Vec<f32>>)> = replies
            .into_iter()
            .flat_map(|r| r.expect("filled by the retry loop"))
            .collect();
        DistStats::add(&self.stats.steps, 1);
        if active.is_empty() {
            return Ok(StepOutcome { loss: None, consumed: k });
        }
        let layers = active[0].1.len();
        self.avg.resize(layers, Vec::new());
        for li in 0..layers {
            let dst = &mut self.avg[li];
            dst.clear();
            dst.extend_from_slice(&active[0].1[li]);
            for (_, g) in &active[1..] {
                axpy(dst, &g[li], 1.0);
            }
            if active.len() > 1 {
                // skipped for one contributor: dst == that worker's
                // gradient, bit for bit (the workers=1 parity contract)
                let scale = 1.0 / active.len() as f32;
                for v in dst.iter_mut() {
                    *v *= scale;
                }
            }
        }
        self.chief.apply_grads(model, state, lr, &self.avg)?;

        let loss_sum: f32 = active.iter().map(|(l, _)| *l).sum();
        let loss = if active.len() > 1 {
            loss_sum / active.len() as f32
        } else {
            loss_sum
        };
        if !loss.is_finite() {
            return Err(anyhow!("non-finite distributed loss at step {}", state.step));
        }
        Ok(StepOutcome { loss: Some(loss), consumed: k })
    }

    fn grad_step(
        &mut self,
        model: &str,
        weights: &[Tensor],
        batch: &Batch,
        grads: &mut Vec<Vec<f32>>,
    ) -> Result<f32> {
        self.chief.grad_step(model, weights, batch, grads)
    }

    fn apply_grads(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        grads: &[Vec<f32>],
    ) -> Result<()> {
        self.chief.apply_grads(model, state, lr, grads)
    }
}

impl Drop for DistributedBackend {
    fn drop(&mut self) {
        // polite shutdown, then a bounded wait, then the axe
        for slot in &mut self.slots {
            if let Some(conn) = &mut slot.conn {
                let _ = wire::write_frame(conn, Kind::Shutdown, 0, &[]);
            }
            slot.conn = None;
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        for slot in &mut self.slots {
            if let Some(child) = &mut slot.child {
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_millis(20));
                        }
                        _ => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// The worker process
// ---------------------------------------------------------------------

/// Entry point of a spawned worker process (the hidden `__worker` CLI
/// dispatch).  Reads its rendezvous from `CGCN_DIST_ADDR` /
/// `CGCN_DIST_TRANSPORT` / `CGCN_DIST_ID`, dials the chief, rebuilds
/// the run from the `Setup` frame, and serves gradient requests until
/// `Shutdown` (reconnecting with bounded retries when the chief tears
/// the connection down to recover from a fault).
pub fn worker_main() -> Result<()> {
    let addr = std::env::var("CGCN_DIST_ADDR").context("CGCN_DIST_ADDR not set")?;
    let transport = Transport::parse(
        &std::env::var("CGCN_DIST_TRANSPORT").context("CGCN_DIST_TRANSPORT not set")?,
    )?;
    let id: usize = std::env::var("CGCN_DIST_ID")
        .context("CGCN_DIST_ID not set")?
        .parse()
        .context("CGCN_DIST_ID must be an integer")?;

    let mut conn = worker_connect(transport, &addr, id)?;
    let setup_bytes = match wire::read_frame(&mut conn)? {
        (Frame { kind: Kind::Setup, payload, .. }, _) => payload,
        (f, _) => bail!("worker {id}: expected Setup, got {:?}", f.kind),
    };
    let setup = WorkerSetup::from_payload(&setup_bytes)?;

    // rebuild the chief's exact view: dataset from the shared cache,
    // partition/plan/spec through the same session code path
    let p = crate::datagen::preset(&setup.preset)
        .ok_or_else(|| anyhow!("worker {id}: unknown preset {}", setup.preset))?;
    let ds = crate::datagen::build_cached(p, setup.ds_seed, std::path::Path::new(&setup.cache))?;
    let (model, spec, mut source) = setup.session(&ds).into_worker()?;
    let mut backend = HostBackend::new();
    backend.register_model(&model, spec.clone());
    let mut weights: Vec<Tensor> = spec
        .weight_shapes
        .iter()
        .map(|&(a, b)| Tensor::zeros(vec![a, b]))
        .collect();
    let mut batch = source.new_batch();
    let mut grads: Vec<Vec<f32>> = Vec::new();
    // None until the first Step so epoch 0 still triggers begin_epoch
    let mut epoch: Option<usize> = None;

    loop {
        let frame = match wire::read_frame(&mut conn) {
            Ok((f, _)) => f,
            Err(e) => {
                // chief dropped us (fault recovery) — dial back in; a
                // fresh Setup follows on the new connection
                eprintln!("worker {id}: connection lost ({e:#}), reconnecting");
                conn = worker_connect(transport, &addr, id)?;
                continue;
            }
        };
        match frame.kind {
            Kind::Shutdown => return Ok(()),
            Kind::Setup => {
                if frame.payload != setup_bytes {
                    bail!("worker {id}: run setup changed mid-run");
                }
            }
            Kind::Step => {
                let mut r = PayloadReader::new(&frame.payload);
                let e = r.get_u64()? as usize;
                let index = r.get_u64()? as usize;
                let nl = r.get_u32()? as usize;
                if nl != weights.len() {
                    bail!("worker {id}: {nl} weight tensors, model has {}", weights.len());
                }
                for t in &mut weights {
                    let n = r.get_u32()? as usize;
                    if n != t.data.len() {
                        bail!("worker {id}: weight size {n}, expected {}", t.data.len());
                    }
                    let mut data = std::mem::take(&mut t.data);
                    r.get_f32s(n, &mut data)?;
                    t.data = data;
                }
                if epoch != Some(e) {
                    source.begin_epoch(e);
                    epoch = Some(e);
                }
                if index >= source.len() {
                    bail!(
                        "worker {id}: batch {index} outside epoch {e}'s plan ({})",
                        source.len()
                    );
                }
                source.assemble(index, &mut batch);
                let mut w = PayloadWriter::new();
                let flags = if batch.n_train == 0 {
                    w.put_f32(0.0);
                    FLAG_EMPTY
                } else {
                    let loss = backend.grad_step(&model, &weights, &batch, &mut grads)?;
                    w.put_f32(loss);
                    w.put_u32(grads.len() as u32);
                    for g in &grads {
                        wire::encode_grad(setup.compression, g, &mut w);
                    }
                    0
                };
                if let Err(e) = wire::write_frame(&mut conn, Kind::Grads, flags, &w.buf) {
                    // reply lost; the chief retries the whole exchange
                    eprintln!("worker {id}: reply failed ({e:#}), reconnecting");
                    conn = worker_connect(transport, &addr, id)?;
                }
            }
            other => bail!("worker {id}: unexpected frame {other:?}"),
        }
    }
}

/// Dial the chief and introduce ourselves, with bounded retries (the
/// chief may be between accept windows during fault recovery).
fn worker_connect(transport: Transport, addr: &str, id: usize) -> Result<Stream> {
    let mut last = None;
    for _ in 0..100 {
        match Stream::connect(transport, addr) {
            Ok(mut conn) => {
                // block until the next Step; if the chief is gone the
                // timeout turns an orphaned worker into a clean exit
                conn.set_read_timeout(Some(Duration::from_secs(600)))?;
                let mut w = PayloadWriter::new();
                w.put_u32(id as u32);
                w.put_u32(PROTO_VERSION);
                wire::write_frame(&mut conn, Kind::Hello, 0, &w.buf)?;
                return Ok(conn);
            }
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
    Err(anyhow!("worker {id}: cannot reach chief at {addr}: {:#?}", last))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norm::NormConfig;

    fn setup() -> WorkerSetup {
        WorkerSetup {
            preset: "cora_like".into(),
            ds_seed: 42,
            cache: "data".into(),
            cfg_seed: 7,
            layers: 2,
            hidden: Some(16),
            b_max: None,
            parts: Some(8),
            q: 2,
            random_partition: true,
            norm: NormConfig::ROW_LAMBDA1,
            n_workers: 2,
            compression: Compression::TopK { frac: 0.5 },
        }
    }

    #[test]
    fn worker_setup_roundtrips() {
        let s = setup();
        let bytes = s.to_payload();
        assert_eq!(WorkerSetup::from_payload(&bytes).unwrap(), s);
        // every norm/compression variant survives
        for (norm, comp) in [
            (NormConfig::PAPER_DEFAULT, Compression::None),
            (NormConfig::ROW, Compression::Quant8),
            (NormConfig::ROW_IDENTITY, Compression::TopK { frac: 0.01 }),
        ] {
            let s = WorkerSetup { norm, compression: comp, hidden: None, ..setup() };
            assert_eq!(WorkerSetup::from_payload(&s.to_payload()).unwrap(), s);
        }
    }

    #[test]
    fn setup_rejects_protocol_mismatch() {
        let mut bytes = setup().to_payload();
        bytes[0] = 99;
        let e = WorkerSetup::from_payload(&bytes).unwrap_err();
        assert!(format!("{e:#}").contains("protocol version"), "{e:#}");
    }

    #[test]
    fn transport_parses() {
        assert_eq!(Transport::parse("unix").unwrap(), Transport::Unix);
        assert_eq!(Transport::parse("tcp").unwrap(), Transport::Tcp);
        assert!(Transport::parse("carrier-pigeon").is_err());
        assert_eq!(Transport::Unix.label(), "unix");
    }

    #[test]
    fn stats_compression_ratio() {
        let s = DistStats::default();
        assert_eq!(s.compression_ratio(), 1.0);
        s.raw_grad_bytes.store(4000, Ordering::Relaxed);
        s.wire_grad_bytes.store(1000, Ordering::Relaxed);
        assert_eq!(s.compression_ratio(), 4.0);
    }

    #[test]
    fn backend_surface_delegates_to_chief() {
        let mut be = DistributedBackend::new(DistConfig::new(3, Transport::Unix, setup()));
        assert_eq!(be.name(), "distributed");
        assert_eq!(be.batches_per_step(), 3);
        assert!(!be.prefetchable());
        let spec = ModelSpec::gcn(crate::graph::Task::Multiclass, 2, 4, 8, 2, 16);
        assert!(be.register_model("m", spec.clone()));
        assert_eq!(be.model_spec("m").unwrap(), spec);
        // dropping a never-started backend must not hang or spawn
        drop(be);
    }
}
