//! Byte protocol of the distributed training backend: length-prefixed,
//! CRC-guarded frames over a UNIX or TCP stream, plus the gradient
//! payload codecs (raw / top-k sparsified / 8-bit quantized).
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//!   magic   "CGDF"          4 bytes
//!   kind    u8              Hello | Setup | Step | Grads | Shutdown
//!   flags   u8              per-kind bits (Grads: bit 0 = empty batch)
//!   pad     u16             zero
//!   len     u32             payload byte count
//!   payload len bytes
//!   crc32   u32             IEEE CRC over kind..payload
//! ```
//!
//! The CRC turns a torn or corrupted frame into a typed decode error
//! instead of silently training on garbage gradients; the transport
//! layer reacts by dropping the connection and re-running the
//! request/response exchange (every exchange is idempotent: the same
//! `(epoch, batch index, weights)` request deterministically produces
//! the same gradient bits, so a retry cannot fork the trajectory).
#![deny(missing_docs)]

use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};

/// Frame magic: "CGDF" (Cluster-GCN Distributed Frame).
pub const MAGIC: [u8; 4] = *b"CGDF";

/// Protocol version carried in `Hello`; chief and worker must agree.
pub const PROTO_VERSION: u32 = 1;

/// Frame kinds (the `kind` byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// worker → chief: `worker id u32, proto version u32`.
    Hello,
    /// chief → worker: serialized run setup (see `WorkerSetup`).
    Setup,
    /// chief → worker: `epoch u64, batch index u64, weights`.
    Step,
    /// worker → chief: `loss f32, per-layer gradient payloads`.
    Grads,
    /// chief → worker: clean exit request (empty payload).
    Shutdown,
}

impl Kind {
    fn to_u8(self) -> u8 {
        match self {
            Kind::Hello => 1,
            Kind::Setup => 2,
            Kind::Step => 3,
            Kind::Grads => 4,
            Kind::Shutdown => 5,
        }
    }

    fn from_u8(b: u8) -> Result<Kind> {
        Ok(match b {
            1 => Kind::Hello,
            2 => Kind::Setup,
            3 => Kind::Step,
            4 => Kind::Grads,
            5 => Kind::Shutdown,
            _ => bail!("unknown frame kind {b}"),
        })
    }
}

/// `Grads` flag bit 0: the worker's batch held no training node, so the
/// frame carries no gradients and must not contribute to the average.
pub const FLAG_EMPTY: u8 = 1;

/// One decoded frame.
#[derive(Debug)]
pub struct Frame {
    /// What the payload means.
    pub kind: Kind,
    /// Per-kind flag bits.
    pub flags: u8,
    /// Kind-specific payload bytes.
    pub payload: Vec<u8>,
}

// ---------------------------------------------------------------------
// CRC32 (IEEE), table-driven — same scheme as the checkpoint and
// out-of-core store formats
// ---------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

fn crc32_update(crc: u32, bytes: &[u8]) -> u32 {
    let mut c = !crc;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Serialize one frame into its on-wire bytes.
pub fn frame_bytes(kind: Kind, flags: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(kind.to_u8());
    out.push(flags);
    out.extend_from_slice(&[0, 0]);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let crc = crc32_update(0, &out[4..]);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Write one frame.  Returns the bytes put on the wire.
pub fn write_frame(
    w: &mut impl Write,
    kind: Kind,
    flags: u8,
    payload: &[u8],
) -> Result<usize> {
    let bytes = frame_bytes(kind, flags, payload);
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(bytes.len())
}

/// Write a deliberately truncated frame (the `dist.send.torn`
/// failpoint): header plus half the payload, no CRC.  The peer's
/// `read_frame` fails on EOF or CRC and the connection is torn down.
pub fn write_torn_frame(
    w: &mut impl Write,
    kind: Kind,
    flags: u8,
    payload: &[u8],
) -> Result<usize> {
    let bytes = frame_bytes(kind, flags, payload);
    let cut = 12 + payload.len() / 2;
    w.write_all(&bytes[..cut])?;
    w.flush()?;
    Ok(cut)
}

/// Read one frame, verifying magic and CRC.  A short read (torn frame,
/// closed peer) or checksum mismatch is an error — the caller drops the
/// connection and re-runs the exchange.  Returns the frame and the
/// bytes consumed from the wire.
pub fn read_frame(r: &mut impl Read) -> Result<(Frame, usize)> {
    let mut head = [0u8; 12];
    r.read_exact(&mut head)?;
    if head[..4] != MAGIC {
        bail!("bad frame magic {:02x?}", &head[..4]);
    }
    let kind = Kind::from_u8(head[4])?;
    let flags = head[5];
    let len = u32::from_le_bytes([head[8], head[9], head[10], head[11]]) as usize;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let mut crc_b = [0u8; 4];
    r.read_exact(&mut crc_b)?;
    let got = u32::from_le_bytes(crc_b);
    let want = crc32_update(crc32_update(0, &head[4..]), &payload);
    if got != want {
        bail!("frame CRC mismatch (kind {kind:?}, {len} payload bytes)");
    }
    Ok((Frame { kind, flags, payload }, 16 + len))
}

// ---------------------------------------------------------------------
// Payload primitives
// ---------------------------------------------------------------------

/// Append-only payload builder (little-endian primitives).
#[derive(Default)]
pub struct PayloadWriter {
    /// Accumulated payload bytes.
    pub buf: Vec<u8>,
}

impl PayloadWriter {
    /// Fresh empty payload.
    pub fn new() -> PayloadWriter {
        PayloadWriter::default()
    }

    /// Append a `u8`.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a little-endian `u32`.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian `u64`.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32` (bit pattern, little-endian).
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a slice of `f32` as raw little-endian bytes.
    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.buf.reserve(vs.len() * 4);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Cursor-based payload reader mirroring [`PayloadWriter`].
pub struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    /// Read from the start of `buf`.
    pub fn new(buf: &'a [u8]) -> PayloadReader<'a> {
        PayloadReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow!("truncated payload (at {}, want {n})", self.pos))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    /// Next `u8`.
    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Next little-endian `u32`.
    pub fn get_u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Next little-endian `u64`.
    pub fn get_u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    /// Next `f32` (bit pattern).
    pub fn get_f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Next length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    /// Next `n` `f32`s into `out` (cleared first).
    pub fn get_f32s(&mut self, n: usize, out: &mut Vec<f32>) -> Result<()> {
        let b = self.take(n * 4)?;
        out.clear();
        out.reserve(n);
        for c in b.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(())
    }

    /// True when every byte has been consumed.
    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

// ---------------------------------------------------------------------
// Gradient compression
// ---------------------------------------------------------------------

/// Gradient uplink compression, selected per run (`--compress`).
/// Weight downlinks are always raw — the parity contracts require
/// bit-exact weights on every worker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Compression {
    /// Raw little-endian `f32` gradients — bit-exact, required for the
    /// `workers=1` ≡ `HostBackend` parity contract.
    None,
    /// Magnitude top-k sparsification: keep `ceil(frac · n)` entries
    /// per layer (ties broken toward the lower index), zero the rest.
    TopK {
        /// Kept fraction in `(0, 1]`.
        frac: f32,
    },
    /// Per-layer linear 8-bit quantization (min/scale + one byte per
    /// entry; ~4x uplink reduction).
    Quant8,
}

impl Compression {
    /// Parse the CLI surface: `none`, `topk:<frac>`, `q8`.
    pub fn parse(s: &str) -> Result<Compression> {
        if s == "none" {
            return Ok(Compression::None);
        }
        if s == "q8" {
            return Ok(Compression::Quant8);
        }
        if let Some(f) = s.strip_prefix("topk:") {
            let frac: f32 = f
                .parse()
                .map_err(|_| anyhow!("bad top-k fraction {f:?} (want e.g. topk:0.1)"))?;
            if !(frac > 0.0 && frac <= 1.0) {
                bail!("top-k fraction must be in (0, 1], got {frac}");
            }
            return Ok(Compression::TopK { frac });
        }
        bail!("unknown compression {s:?} (expected none | topk:<frac> | q8)")
    }

    /// Short label for logs and the bench report.
    pub fn label(&self) -> String {
        match self {
            Compression::None => "none".into(),
            Compression::TopK { frac } => format!("topk:{frac}"),
            Compression::Quant8 => "q8".into(),
        }
    }

    /// Serialize into a setup payload.
    pub fn put(&self, w: &mut PayloadWriter) {
        match self {
            Compression::None => {
                w.put_u8(0);
                w.put_f32(0.0);
            }
            Compression::TopK { frac } => {
                w.put_u8(1);
                w.put_f32(*frac);
            }
            Compression::Quant8 => {
                w.put_u8(2);
                w.put_f32(0.0);
            }
        }
    }

    /// Deserialize from a setup payload.
    pub fn get(r: &mut PayloadReader) -> Result<Compression> {
        let tag = r.get_u8()?;
        let param = r.get_f32()?;
        Ok(match tag {
            0 => Compression::None,
            1 => Compression::TopK { frac: param },
            2 => Compression::Quant8,
            _ => bail!("unknown compression tag {tag}"),
        })
    }
}

/// Encode one layer's gradient under `mode`, appending `mode tag, n,
/// data` to `w`.  The decode side dispatches on the tag alone, so a
/// worker and chief configured differently still interoperate (the
/// worker's setup decides).
pub fn encode_grad(mode: Compression, g: &[f32], w: &mut PayloadWriter) {
    let n = g.len();
    match mode {
        Compression::None => {
            w.put_u8(0);
            w.put_u32(n as u32);
            w.put_f32s(g);
        }
        Compression::TopK { frac } => {
            let k = (((frac as f64) * n as f64).ceil() as usize).clamp(1, n.max(1));
            let mut idx: Vec<u32> = (0..n as u32).collect();
            idx.sort_by(|&a, &b| {
                let (va, vb) = (g[a as usize].abs(), g[b as usize].abs());
                vb.total_cmp(&va).then(a.cmp(&b))
            });
            idx.truncate(k);
            idx.sort_unstable();
            w.put_u8(1);
            w.put_u32(n as u32);
            w.put_u32(k as u32);
            for &i in &idx {
                w.put_u32(i);
                w.put_f32(g[i as usize]);
            }
        }
        Compression::Quant8 => {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &v in g {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if g.is_empty() {
                lo = 0.0;
                hi = 0.0;
            }
            let scale = (hi - lo) / 255.0;
            w.put_u8(2);
            w.put_u32(n as u32);
            w.put_f32(lo);
            w.put_f32(scale);
            for &v in g {
                let code = if scale > 0.0 {
                    (((v - lo) / scale).round()).clamp(0.0, 255.0) as u8
                } else {
                    0
                };
                w.put_u8(code);
            }
        }
    }
}

/// Decode one layer's gradient (inverse of [`encode_grad`]).
pub fn decode_grad(r: &mut PayloadReader, out: &mut Vec<f32>) -> Result<()> {
    let tag = r.get_u8()?;
    let n = r.get_u32()? as usize;
    match tag {
        0 => r.get_f32s(n, out)?,
        1 => {
            let k = r.get_u32()? as usize;
            out.clear();
            out.resize(n, 0.0);
            for _ in 0..k {
                let i = r.get_u32()? as usize;
                let v = r.get_f32()?;
                *out.get_mut(i)
                    .ok_or_else(|| anyhow!("top-k index {i} out of bounds ({n})"))? = v;
            }
        }
        2 => {
            let lo = r.get_f32()?;
            let scale = r.get_f32()?;
            out.clear();
            out.reserve(n);
            for _ in 0..n {
                let code = r.get_u8()?;
                out.push(lo + code as f32 * scale);
            }
        }
        _ => bail!("unknown gradient encoding tag {tag}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let payload = b"hello gradients".to_vec();
        let mut wire = Vec::new();
        let tx = write_frame(&mut wire, Kind::Grads, FLAG_EMPTY, &payload).unwrap();
        assert_eq!(tx, wire.len());
        let (f, rx) = read_frame(&mut wire.as_slice()).unwrap();
        assert_eq!(rx, wire.len());
        assert_eq!(f.kind, Kind::Grads);
        assert_eq!(f.flags, FLAG_EMPTY);
        assert_eq!(f.payload, payload);
    }

    #[test]
    fn corrupted_frame_fails_crc() {
        let mut wire = frame_bytes(Kind::Step, 0, b"0123456789");
        let mid = wire.len() / 2;
        wire[mid] ^= 0xFF;
        let e = read_frame(&mut wire.as_slice()).unwrap_err();
        assert!(format!("{e:#}").contains("CRC"), "{e:#}");
    }

    #[test]
    fn torn_frame_fails_to_read() {
        let mut wire = Vec::new();
        write_torn_frame(&mut wire, Kind::Step, 0, &[7u8; 64]).unwrap();
        assert!(read_frame(&mut wire.as_slice()).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut wire = frame_bytes(Kind::Hello, 0, &[]);
        wire[0] = b'X';
        let e = read_frame(&mut wire.as_slice()).unwrap_err();
        assert!(format!("{e:#}").contains("magic"), "{e:#}");
    }

    #[test]
    fn payload_primitives_roundtrip() {
        let mut w = PayloadWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_f32(-0.5);
        w.put_str("reddit_like");
        w.put_f32s(&[1.0, 2.5]);
        let mut r = PayloadReader::new(&w.buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_f32().unwrap(), -0.5);
        assert_eq!(r.get_str().unwrap(), "reddit_like");
        let mut fs = Vec::new();
        r.get_f32s(2, &mut fs).unwrap();
        assert_eq!(fs, vec![1.0, 2.5]);
        assert!(r.done());
        assert!(r.get_u8().is_err());
    }

    #[test]
    fn compression_parse_and_labels() {
        assert_eq!(Compression::parse("none").unwrap(), Compression::None);
        assert_eq!(Compression::parse("q8").unwrap(), Compression::Quant8);
        assert_eq!(
            Compression::parse("topk:0.25").unwrap(),
            Compression::TopK { frac: 0.25 }
        );
        assert!(Compression::parse("topk:0").is_err());
        assert!(Compression::parse("topk:1.5").is_err());
        assert!(Compression::parse("zip").is_err());
        assert_eq!(Compression::parse("topk:0.25").unwrap().label(), "topk:0.25");
    }

    #[test]
    fn raw_grads_roundtrip_bitwise() {
        let g = vec![0.125f32, -3.5, 0.0, f32::MIN_POSITIVE, 1e30];
        let mut w = PayloadWriter::new();
        encode_grad(Compression::None, &g, &mut w);
        let mut out = Vec::new();
        decode_grad(&mut PayloadReader::new(&w.buf), &mut out).unwrap();
        assert_eq!(
            g.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            out.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn topk_keeps_largest_magnitudes() {
        let g = vec![0.1f32, -5.0, 0.2, 4.0, -0.3];
        let mut w = PayloadWriter::new();
        encode_grad(Compression::TopK { frac: 0.4 }, &g, &mut w);
        let mut out = Vec::new();
        decode_grad(&mut PayloadReader::new(&w.buf), &mut out).unwrap();
        assert_eq!(out, vec![0.0, -5.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn quant8_bounds_error_by_step() {
        let g: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.01).collect();
        let mut w = PayloadWriter::new();
        encode_grad(Compression::Quant8, &g, &mut w);
        // ~4x smaller than raw (tag + n + min + scale + n bytes)
        assert!(w.buf.len() < g.len() * 4 / 3);
        let mut out = Vec::new();
        decode_grad(&mut PayloadReader::new(&w.buf), &mut out).unwrap();
        let step = (g.last().unwrap() - g[0]) / 255.0;
        for (a, b) in g.iter().zip(&out) {
            assert!((a - b).abs() <= step * 0.5 + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn quant8_constant_layer() {
        let g = vec![0.25f32; 9];
        let mut w = PayloadWriter::new();
        encode_grad(Compression::Quant8, &g, &mut w);
        let mut out = Vec::new();
        decode_grad(&mut PayloadReader::new(&w.buf), &mut out).unwrap();
        assert_eq!(out, g);
    }
}
