//! The host backward-pass engine: pooled, cache-tiled gradient kernels
//! — a first-class peer of the forward SpMM·GEMM engine in
//! `coordinator::inference`.
//!
//! One GCN train step needs four gradient-side contractions (see
//! `runtime::host` for the chain rule):
//!
//! ```text
//!   Z  = P · W            forward GEMM          -> gemm_pooled
//!   dW = P^T · dZ         Aᵀ·B accumulation     -> gemm_at_b_pooled
//!   M  = dZ · W^T         B·Aᵀ projection       -> gemm_a_bt_pooled
//!   dH = Â^T · M          transpose SpMM        -> AdjT::gather_into_pooled
//! ```
//!
//! plus the Adam update, batched across layers into one pooled pass
//! over a flat gradient arena ([`adam_update_pooled`]).
//!
//! Engineering rules (the same ones as the forward kernel, PERF.md):
//!
//! - Everything dispatches over the persistent `util::pool`; the chunk
//!   layout is a pure function of the problem size and the requested
//!   chunk count, never of worker scheduling, so results are
//!   deterministic and identical at every pool width.
//! - Inner loops run through the runtime-dispatched `util::simd`
//!   kernels (explicit AVX2/NEON with a portable chunked-lane
//!   fallback); the GEMM tiles call the register-blocked
//!   `simd::gemm_tile` micro-kernel.
//! - The scalar single-thread originals are **kept** ([`gemm`],
//!   [`gemm_at_b`], [`gemm_a_bt`], [`scatter_adj_t`], [`adam_update`])
//!   as property-test oracles and as the pre-engine baseline for the
//!   backward benches.
//!
//! Parity contracts (pinned by unit + property tests):
//!
//! - [`gemm_pooled`], [`gemm_at_b_pooled`], [`AdjT::gather_into_pooled`]
//!   and [`adam_update_pooled`] accumulate each output element in the
//!   exact order of their scalar oracle, so they are **bit-identical**
//!   to it at every chunk count.
//! - [`gemm_a_bt_pooled`] reduces dot products through `simd::dot`'s
//!   8-lane accumulators — deterministic, but reassociated, so its
//!   parity bound is a small tolerance rather than bit equality.
//!
//! The transpose structure the dH step needs is materialized once per
//! batch ([`AdjT::build`]) into reused buffers: `Â` is stored row-major
//! (a *scatter* along Âᵀ), and a parallel scatter would race on output
//! rows; the counting-sort transpose turns it into a race-free row
//! gather whose per-row accumulation order matches the scalar scatter
//! oracle exactly.
#![deny(missing_docs)]

use crate::coordinator::inference::{COL_TILE, K_PANEL, ROW_BLOCK};
use crate::runtime::exec::Tensor;
use crate::util::pool;
use crate::util::simd::{self, axpy, dot};

/// Adam β1 (first-moment decay), matching `python/compile/model.py`.
pub const ADAM_B1: f32 = 0.9;
/// Adam β2 (second-moment decay).
pub const ADAM_B2: f32 = 0.999;
/// Adam ε.
pub const ADAM_EPS: f32 = 1e-8;

/// Rows of the `gw` accumulator processed per cache block in
/// [`gemm_at_b_pooled`] (reuses the forward tile geometry: the active
/// `K_BLOCK × g` gradient panel stays cache-resident while every batch
/// row streams through it).
pub const K_BLOCK: usize = 64;

/// Column-block width of the sparse-aware `dW` kernel
/// ([`gemm_at_b_masked_pooled`]) — one `[f32; 8]` simd lane, so a
/// retained block is exactly one `axpy` chunk.
pub const AT_B_COL_BLOCK: usize = 8;

// ---------------------------------------------------------------------------
// scalar oracles (the pre-engine kernels, kept verbatim)
// ---------------------------------------------------------------------------

/// `z[n,g] = p[n,f] · w[f,g]` (dense, zero-skipping on `p`).  Scalar
/// oracle for [`gemm_pooled`].
pub fn gemm(p: &[f32], n: usize, f: usize, w: &[f32], g: usize, z: &mut [f32]) {
    debug_assert_eq!(p.len(), n * f);
    debug_assert_eq!(w.len(), f * g);
    debug_assert_eq!(z.len(), n * g);
    z.fill(0.0);
    for i in 0..n {
        let pr = &p[i * f..(i + 1) * f];
        let zr = &mut z[i * g..(i + 1) * g];
        for (k, &pv) in pr.iter().enumerate() {
            if pv == 0.0 {
                continue;
            }
            let wr = &w[k * g..(k + 1) * g];
            for (zv, &wv) in zr.iter_mut().zip(wr) {
                *zv += pv * wv;
            }
        }
    }
}

/// `gw[f,g] += p[n,f]^T · dz[n,g]` (caller zeroes `gw`).  Scalar oracle
/// for [`gemm_at_b_pooled`].
pub fn gemm_at_b(p: &[f32], dz: &[f32], n: usize, f: usize, g: usize, gw: &mut [f32]) {
    debug_assert_eq!(gw.len(), f * g);
    for i in 0..n {
        let pr = &p[i * f..(i + 1) * f];
        let dr = &dz[i * g..(i + 1) * g];
        for (k, &pv) in pr.iter().enumerate() {
            if pv == 0.0 {
                continue;
            }
            let gr = &mut gw[k * g..(k + 1) * g];
            for (gv, &dv) in gr.iter_mut().zip(dr) {
                *gv += pv * dv;
            }
        }
    }
}

/// `m[n,f] = dz[n,g] · w[f,g]^T`.  Scalar oracle for
/// [`gemm_a_bt_pooled`].
pub fn gemm_a_bt(dz: &[f32], w: &[f32], n: usize, g: usize, f: usize, m: &mut [f32]) {
    debug_assert_eq!(m.len(), n * f);
    for i in 0..n {
        let dr = &dz[i * g..(i + 1) * g];
        let mr = &mut m[i * f..(i + 1) * f];
        for (k, mv) in mr.iter_mut().enumerate() {
            let wr = &w[k * g..(k + 1) * g];
            let mut acc = 0f32;
            for (&dv, &wv) in dr.iter().zip(wr) {
                acc += dv * wv;
            }
            *mv = acc;
        }
    }
}

/// `out[n,f] += Â^T · m[n,f]` over a sparse block in the
/// `SparseBlock`/`normalize_sparse` layout (off-diagonal CSR + separate
/// per-node self-loop); caller zeroes `out`.  Scatter each stored entry
/// `Â[u,v]` into row `v`, with the self-loop interleaved at `u == v`.
/// Scalar oracle for the [`AdjT`] transpose gather.
pub fn scatter_adj_t(
    offsets: &[usize],
    cols: &[u32],
    vals: &[f32],
    self_loop: &[f32],
    m: &[f32],
    f: usize,
    out: &mut [f32],
) {
    let n = offsets.len() - 1;
    debug_assert_eq!(self_loop.len(), n);
    debug_assert_eq!(m.len(), n * f);
    debug_assert_eq!(out.len(), n * f);
    for u in 0..n {
        let sl = self_loop[u];
        for j in 0..f {
            out[u * f + j] += sl * m[u * f + j];
        }
        let off = offsets[u];
        for (idx, &v) in cols[off..offsets[u + 1]].iter().enumerate() {
            let a = vals[off + idx];
            let v = v as usize;
            for j in 0..f {
                out[v * f + j] += a * m[u * f + j];
            }
        }
    }
}

/// One bias-corrected Adam update over a flat parameter group.  Scalar
/// oracle for [`adam_update_pooled`] (which is bit-identical — the
/// update is element-wise).
pub fn adam_update(w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], t: f32, lr: f32) {
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    adam_slice(w, g, m, v, bc1, bc2, lr);
}

/// The element-wise Adam core shared by the scalar and pooled paths —
/// one definition, so the two can never drift numerically.
#[inline]
fn adam_slice(w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], bc1: f32, bc2: f32, lr: f32) {
    for i in 0..w.len() {
        let gi = g[i];
        m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * gi;
        v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * gi * gi;
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        w[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

// ---------------------------------------------------------------------------
// pooled, tiled kernels
// ---------------------------------------------------------------------------

/// Pooled, cache-tiled `z[n,g] = p[n,f] · w[f,g]` (fully overwrites
/// `z`).  Rows fan out over the pool; within a chunk the GEMM runs in
/// the forward kernel's `ROW_BLOCK × K_PANEL × COL_TILE` tiling.  The
/// k-accumulation is ascending for every output element, so the result
/// is **bit-identical** to [`gemm`] at every chunk count.
pub fn gemm_pooled(
    p: &[f32],
    n: usize,
    f: usize,
    w: &[f32],
    g: usize,
    threads: usize,
    z: &mut [f32],
) {
    debug_assert_eq!(p.len(), n * f);
    debug_assert_eq!(w.len(), f * g);
    assert_eq!(z.len(), n * g, "gemm output mismatch");
    pool::global().run_rows_with(n, threads.max(1), g, z, |_ci, rows, out_rows| {
        let mut rb = rows.start;
        while rb < rows.end {
            let nb = ROW_BLOCK.min(rows.end - rb);
            let ob = (rb - rows.start) * g;
            let out_block = &mut out_rows[ob..ob + nb * g];
            out_block.fill(0.0);
            let mut kp = 0;
            while kp < f {
                let kn = K_PANEL.min(f - kp);
                let mut ct = 0;
                while ct < g {
                    let cn = COL_TILE.min(g - ct);
                    simd::gemm_tile(
                        &mut out_block[ct..],
                        g,
                        &p[rb * f + kp..],
                        f,
                        1,
                        &w[kp * g + ct..],
                        g,
                        nb,
                        kn,
                        cn,
                    );
                    ct += cn;
                }
                kp += kn;
            }
            rb += nb;
        }
    });
}

/// Pooled, tiled `gw[f,g] = p[n,f]^T · dz[n,g]` (fully overwrites
/// `gw`).  The *output* rows (the `f` dimension) fan out over the pool
/// — every chunk owns a disjoint slice of the gradient, so there is no
/// reduction step and no per-worker partial buffer; inside a chunk the
/// accumulator is walked in `K_BLOCK`-row panels that stay
/// cache-resident while all `n` batch rows stream through.  Per
/// element the accumulation runs over `i` ascending with the same
/// zero-skip as the oracle, so the result is **bit-identical** to
/// [`gemm_at_b`] at every chunk count.
pub fn gemm_at_b_pooled(
    p: &[f32],
    dz: &[f32],
    n: usize,
    f: usize,
    g: usize,
    threads: usize,
    gw: &mut [f32],
) {
    debug_assert_eq!(p.len(), n * f);
    debug_assert_eq!(dz.len(), n * g);
    assert_eq!(gw.len(), f * g, "gradient buffer mismatch");
    if n == 0 {
        gw.fill(0.0);
        return;
    }
    pool::global().run_rows_with(f, threads.max(1), g, gw, |_ci, krange, gw_rows| {
        gw_rows.fill(0.0);
        let mut kb = krange.start;
        while kb < krange.end {
            let kn = K_BLOCK.min(krange.end - kb);
            // rows = the kn gradient rows of this panel, contraction
            // over the n batch rows: p is read k-strided (`pks = f`) as
            // p[i*f + kb + k], so no transpose is materialized and the
            // per-element accumulation stays ascending-i with the
            // oracle's zero-skip.
            simd::gemm_tile(
                &mut gw_rows[(kb - krange.start) * g..],
                g,
                &p[kb..],
                1,
                f,
                dz,
                g,
                kn,
                n,
                g,
            );
            kb += kn;
        }
    });
}

/// Pooled `m[n,f] = dz[n,g] · w[f,g]^T` (fully overwrites `m`).  Rows
/// fan out over the pool; each output element is a [`dot`] over
/// contiguous `dz`/`w` rows.  Deterministic at every chunk count, but
/// the 8-lane reduction reassociates the sum — parity vs [`gemm_a_bt`]
/// is tolerance-based, not bitwise.
pub fn gemm_a_bt_pooled(
    dz: &[f32],
    w: &[f32],
    n: usize,
    g: usize,
    f: usize,
    threads: usize,
    m: &mut [f32],
) {
    debug_assert_eq!(dz.len(), n * g);
    debug_assert_eq!(w.len(), f * g);
    assert_eq!(m.len(), n * f, "projection buffer mismatch");
    pool::global().run_rows_with(n, threads.max(1), f, m, |_ci, rows, out_rows| {
        for (ri, i) in rows.clone().enumerate() {
            let dr = &dz[i * g..(i + 1) * g];
            let mr = &mut out_rows[ri * f..(ri + 1) * f];
            for (k, mv) in mr.iter_mut().enumerate() {
                *mv = dot(dr, &w[k * g..(k + 1) * g]);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// sparse-aware dW: skip relu-killed column blocks
// ---------------------------------------------------------------------------

/// Process-wide counters behind [`at_b_skip_stats`].
static AT_B_BLOCKS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
static AT_B_SKIPPED: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// `(column blocks scanned, blocks found all-zero)` across every
/// [`dz_col_block_mask`] call so far in this process — the skip rate of
/// the sparse-aware `dW` kernel (see PERF.md §Backward engine).
pub fn at_b_skip_stats() -> (u64, u64) {
    (
        AT_B_BLOCKS.load(std::sync::atomic::Ordering::Relaxed),
        AT_B_SKIPPED.load(std::sync::atomic::Ordering::Relaxed),
    )
}

/// Scan `dz` (`n × g`, row-major) for [`AT_B_COL_BLOCK`]-wide column
/// blocks that are zero in **every** row — the units relu kills across
/// the whole batch, whose `dW` columns are therefore exactly zero.
/// `mask[b] = true` marks a *live* block.  Returns
/// `(blocks, skipped)`; the scan early-exits once every block is live.
pub fn dz_col_block_mask(dz: &[f32], n: usize, g: usize, mask: &mut Vec<bool>) -> (usize, usize) {
    debug_assert_eq!(dz.len(), n * g);
    let blocks = g.div_ceil(AT_B_COL_BLOCK).max(1);
    mask.clear();
    mask.resize(blocks, false);
    let mut live = 0usize;
    'rows: for i in 0..n {
        let row = &dz[i * g..(i + 1) * g];
        for (b, m) in mask.iter_mut().enumerate() {
            if *m {
                continue;
            }
            let lo = b * AT_B_COL_BLOCK;
            let hi = (lo + AT_B_COL_BLOCK).min(g);
            if row[lo..hi].iter().any(|&v| v != 0.0) {
                *m = true;
                live += 1;
                if live == blocks {
                    break 'rows;
                }
            }
        }
    }
    AT_B_BLOCKS.fetch_add(blocks as u64, std::sync::atomic::Ordering::Relaxed);
    AT_B_SKIPPED.fetch_add((blocks - live) as u64, std::sync::atomic::Ordering::Relaxed);
    (blocks, blocks - live)
}

/// Sparse-aware `gw[f,g] = p[n,f]^T · dz[n,g]`: identical tiling and
/// per-element accumulation order as [`gemm_at_b_pooled`], but
/// [`AT_B_COL_BLOCK`]-wide column blocks whose `col_live` flag is false
/// (all-zero `dz` columns, from [`dz_col_block_mask`]) are skipped
/// entirely.  **Bit-identical** to the unmasked kernel (and therefore
/// to the scalar [`gemm_at_b`] oracle) at every chunk count: a skipped
/// block only ever contributed `pv · 0.0` terms, and adding `±0.0` to a
/// `+0.0` accumulator leaves `+0.0` under IEEE round-to-nearest —
/// exactly what the zero-filled output already holds.
#[allow(clippy::too_many_arguments)]
pub fn gemm_at_b_masked_pooled(
    p: &[f32],
    dz: &[f32],
    n: usize,
    f: usize,
    g: usize,
    col_live: &[bool],
    threads: usize,
    gw: &mut [f32],
) {
    debug_assert_eq!(p.len(), n * f);
    debug_assert_eq!(dz.len(), n * g);
    debug_assert_eq!(col_live.len(), g.div_ceil(AT_B_COL_BLOCK).max(1));
    assert_eq!(gw.len(), f * g, "gradient buffer mismatch");
    if n == 0 {
        gw.fill(0.0);
        return;
    }
    pool::global().run_rows_with(f, threads.max(1), g, gw, |_ci, krange, gw_rows| {
        gw_rows.fill(0.0);
        let mut kb = krange.start;
        while kb < krange.end {
            let kn = K_BLOCK.min(krange.end - kb);
            // One k-strided micro-kernel call per live column block
            // (AT_B_COL_BLOCK = 8 matches the kernel's column
            // blocking); per output element the accumulation order is
            // unchanged (ascending i, zero-skip), so hoisting the block
            // loop outside the i loop keeps bit-identity.
            for (b, &alive) in col_live.iter().enumerate() {
                if !alive {
                    continue;
                }
                let lo = b * AT_B_COL_BLOCK;
                let hi = (lo + AT_B_COL_BLOCK).min(g);
                simd::gemm_tile(
                    &mut gw_rows[(kb - krange.start) * g + lo..],
                    g,
                    &p[kb..],
                    1,
                    f,
                    &dz[lo..],
                    g,
                    kn,
                    n,
                    hi - lo,
                );
            }
            kb += kn;
        }
    });
}

// ---------------------------------------------------------------------------
// Âᵀ as a reusable gather structure
// ---------------------------------------------------------------------------

/// `Âᵀ` of one batch block in CSR form, rebuilt per batch into reused
/// buffers (zero steady-state allocation).  Row `v` lists the source
/// rows `u` (ascending) whose entry `Â[u,v]` contributes to `dH[v]`,
/// turning the backward transpose-SpMM into a race-free pooled row
/// gather.  The ascending-`u` order (with the diagonal interleaved at
/// `u == v`) reproduces the scalar [`scatter_adj_t`] accumulation order
/// exactly, so the gather is **bit-identical** to it.
#[derive(Default)]
pub struct AdjT {
    offsets: Vec<usize>,
    src: Vec<u32>,
    vals: Vec<f32>,
    cursor: Vec<usize>,
}

impl AdjT {
    /// Empty structure; sized by the first [`AdjT::build`].
    pub fn new() -> AdjT {
        AdjT::default()
    }

    /// Rows of the built transpose.
    pub fn n(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Build from a block in the `SparseBlock` layout (off-diagonal CSR
    /// + separate per-node self-loop); the diagonal is injected as a
    /// regular entry at its sorted position.
    pub fn build(
        &mut self,
        offsets: &[usize],
        cols: &[u32],
        vals: &[f32],
        self_loop: &[f32],
    ) {
        self.build_core(offsets, cols, vals, Some(self_loop));
    }

    /// Build from a CSR whose entries already carry the diagonal inline
    /// (the VR-GCN `A_in` view).
    pub fn build_inline(&mut self, offsets: &[usize], cols: &[u32], vals: &[f32]) {
        self.build_core(offsets, cols, vals, None);
    }

    fn build_core(
        &mut self,
        offsets: &[usize],
        cols: &[u32],
        vals: &[f32],
        self_loop: Option<&[f32]>,
    ) {
        let n = offsets.len() - 1;
        let diag = usize::from(self_loop.is_some());
        self.offsets.clear();
        self.offsets.resize(n + 1, 0);
        for i in 0..n {
            self.offsets[i + 1] = diag;
        }
        for &v in &cols[..offsets[n]] {
            self.offsets[v as usize + 1] += 1;
        }
        for i in 0..n {
            self.offsets[i + 1] += self.offsets[i];
        }
        let nnz = self.offsets[n];
        self.src.clear();
        self.src.resize(nnz, 0);
        self.vals.clear();
        self.vals.resize(nnz, 0.0);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.offsets[..n]);
        for u in 0..n {
            if let Some(sl) = self_loop {
                let c = self.cursor[u];
                self.src[c] = u as u32;
                self.vals[c] = sl[u];
                self.cursor[u] += 1;
            }
            let off = offsets[u];
            for (idx, &v) in cols[off..offsets[u + 1]].iter().enumerate() {
                let c = &mut self.cursor[v as usize];
                self.src[*c] = u as u32;
                self.vals[*c] = vals[off + idx];
                *c += 1;
            }
        }
    }

    /// Pooled row gather `out[v,:] = Σ_u Âᵀ[v,u] · m[u,:]` (fully
    /// overwrites `out`).  Bit-identical to the scalar scatter oracle
    /// at every chunk count (see the type docs).
    pub fn gather_into_pooled(&self, m: &[f32], f: usize, threads: usize, out: &mut [f32]) {
        let n = self.n();
        debug_assert_eq!(m.len(), n * f);
        assert_eq!(out.len(), n * f, "gather output mismatch");
        pool::global().run_rows_with(n, threads.max(1), f, out, |_ci, rows, out_rows| {
            for (ri, v) in rows.clone().enumerate() {
                let or = &mut out_rows[ri * f..(ri + 1) * f];
                or.fill(0.0);
                let off = self.offsets[v];
                for (idx, &u) in self.src[off..self.offsets[v + 1]].iter().enumerate() {
                    let a = self.vals[off + idx];
                    let u = u as usize;
                    axpy(or, &m[u * f..(u + 1) * f], a);
                }
            }
        });
    }
}

// ---------------------------------------------------------------------------
// batched Adam over a flat gradient arena
// ---------------------------------------------------------------------------

/// Per-layer raw parameter pointers smuggled into the pooled Adam
/// closure.  Safety: chunks of the flat index space are disjoint, so no
/// element is touched by two workers; the pointee tensors outlive the
/// (blocking) dispatch.
struct ParamPtrs(Vec<(usize, usize, *mut f32, *mut f32, *mut f32)>);
unsafe impl Send for ParamPtrs {}
unsafe impl Sync for ParamPtrs {}

/// One bias-corrected Adam step over **all** layers at once: the flat
/// gradient arena `grads` (layer `li` occupying `spans[li] = (offset,
/// len)`) drives a single pooled pass over the concatenated parameter
/// space, instead of one serial loop per layer.  Element-wise
/// **bit-identical** to per-layer [`adam_update`] at every chunk count
/// (both run the same private `adam_slice` core).
#[allow(clippy::too_many_arguments)]
pub fn adam_update_pooled(
    weights: &mut [Tensor],
    m: &mut [Tensor],
    v: &mut [Tensor],
    grads: &[f32],
    spans: &[(usize, usize)],
    t: f32,
    lr: f32,
    threads: usize,
) {
    assert_eq!(weights.len(), spans.len(), "span/layer mismatch");
    assert_eq!(m.len(), spans.len());
    assert_eq!(v.len(), spans.len());
    let mut ptrs = Vec::with_capacity(spans.len());
    let mut total = 0usize;
    // Real (release-mode) asserts: these are the memory-safety
    // invariants of the unchecked pointer writes below, and the checks
    // are O(layers) per step — free next to the update itself.
    for li in 0..spans.len() {
        let (start, len) = spans[li];
        assert_eq!(weights[li].data.len(), len, "layer {li} span mismatch");
        assert_eq!(m[li].data.len(), len, "layer {li} moment-m span mismatch");
        assert_eq!(v[li].data.len(), len, "layer {li} moment-v span mismatch");
        assert_eq!(start, total, "spans must be contiguous and ascending");
        ptrs.push((
            start,
            len,
            weights[li].data.as_mut_ptr(),
            m[li].data.as_mut_ptr(),
            v[li].data.as_mut_ptr(),
        ));
        total += len;
    }
    assert!(grads.len() >= total, "gradient arena shorter than the parameter space");
    let ptrs = ParamPtrs(ptrs);
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    pool::global().run_chunks_with(total, threads.max(1), |_ci, r| {
        for &(start, len, wp, mp, vp) in &ptrs.0 {
            let lo = r.start.max(start);
            let hi = r.end.min(start + len);
            if lo >= hi {
                continue;
            }
            let off = lo - start;
            let cnt = hi - lo;
            // Safety: see `ParamPtrs` — disjoint chunk ranges over the
            // flat index space map to disjoint tensor elements.
            let (w, mm, vv) = unsafe {
                (
                    std::slice::from_raw_parts_mut(wp.add(off), cnt),
                    std::slice::from_raw_parts_mut(mp.add(off), cnt),
                    std::slice::from_raw_parts_mut(vp.add(off), cnt),
                )
            };
            adam_slice(w, &grads[lo..hi], mm, vv, bc1, bc2, lr);
        }
    });
}

// ---------------------------------------------------------------------------
// reusable per-backend workspace
// ---------------------------------------------------------------------------

/// Every per-step buffer of the host train path, hoisted out of the hot
/// loop: forward stores (`P_l`, `Z_l`, hidden ping-pong), backward
/// scratch (`dz`, `mbuf`, `dh`/`dh_new`), the flat gradient arena with
/// its per-layer spans, the [`AdjT`] transpose, and the column-block
/// mask of the sparse-aware `dW` kernel.  Buffers only ever grow
/// ([`BackwardWorkspace::prepare`]), so steady-state training performs
/// **no** heap allocation in the backward path.
#[derive(Default)]
pub struct BackwardWorkspace {
    /// Per-layer propagations `P_l = Â·H_l` (`n × f_l`).
    pub(crate) ps: Vec<Vec<f32>>,
    /// Per-layer pre-activations `Z_l = P_l·W_l` (`n × f_{l+1}`).
    pub(crate) zs: Vec<Vec<f32>>,
    /// Forward hidden ping buffer (`n × max_width`).
    pub(crate) cur: Vec<f32>,
    /// Forward hidden pong buffer.
    pub(crate) nxt: Vec<f32>,
    /// Upstream gradient dL/dH (ping).
    pub(crate) dh: Vec<f32>,
    /// Downstream gradient buffer (pong).
    pub(crate) dh_new: Vec<f32>,
    /// Pre-activation gradient dL/dZ.
    pub(crate) dz: Vec<f32>,
    /// `dZ · Wᵀ` projection scratch.
    pub(crate) mbuf: Vec<f32>,
    /// Flat per-layer gradient arena (layer `li` at `spans[li]`).
    pub(crate) grads: Vec<f32>,
    /// Per-layer `(offset, len)` into `grads`, contiguous ascending.
    pub(crate) spans: Vec<(usize, usize)>,
    /// Transpose of the current batch block.
    pub(crate) adj_t: AdjT,
    /// Live-column-block mask for [`gemm_at_b_masked_pooled`].
    pub(crate) col_mask: Vec<bool>,
}

fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

impl BackwardWorkspace {
    /// Empty workspace; sized on first use.
    pub fn new() -> BackwardWorkspace {
        BackwardWorkspace::default()
    }

    /// Size every buffer for the given layer weights over an `n`-row
    /// batch, and (re)build the gradient spans.  Buffers never shrink,
    /// so after the first step at the run's peak shape this allocates
    /// nothing.
    pub fn prepare(&mut self, weights: &[Tensor], n: usize) {
        let l = weights.len();
        if self.ps.len() < l {
            self.ps.resize_with(l, Vec::new);
            self.zs.resize_with(l, Vec::new);
        }
        let mut max_w = weights.first().map(|w| w.dims[0]).unwrap_or(0);
        let mut off = 0usize;
        self.spans.clear();
        for (li, w) in weights.iter().enumerate() {
            let (fi, fo) = (w.dims[0], w.dims[1]);
            max_w = max_w.max(fo);
            grow(&mut self.ps[li], n * fi);
            grow(&mut self.zs[li], n * fo);
            self.spans.push((off, fi * fo));
            off += fi * fo;
        }
        grow(&mut self.grads, off);
        let nb = n * max_w;
        grow(&mut self.cur, nb);
        grow(&mut self.nxt, nb);
        grow(&mut self.dh, nb);
        grow(&mut self.dh_new, nb);
        grow(&mut self.dz, nb);
        grow(&mut self.mbuf, nb);
    }

    /// Per-layer gradient slices (diagnostics/tests; training consumes
    /// the arena directly through [`adam_update_pooled`]).
    pub fn grad_layers(&self) -> Vec<&[f32]> {
        self.spans.iter().map(|&(off, len)| &self.grads[off..off + len]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize, zero_frac: f64) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if rng.f64() < zero_frac {
                    0.0
                } else {
                    rng.f32() * 2.0 - 1.0
                }
            })
            .collect()
    }

    #[test]
    fn gemm_pooled_matches_scalar_bitwise() {
        let mut rng = Rng::new(31);
        for &(n, f, g) in &[(1usize, 1usize, 1usize), (7, 5, 3), (70, 140, 66), (129, 32, 65)] {
            let p = rand_vec(&mut rng, n * f, 0.3);
            let w = rand_vec(&mut rng, f * g, 0.0);
            let mut oracle = vec![0f32; n * g];
            gemm(&p, n, f, &w, g, &mut oracle);
            for threads in [1usize, 2, 8] {
                let mut got = vec![f32::NAN; n * g];
                gemm_pooled(&p, n, f, &w, g, threads, &mut got);
                for (i, (a, b)) in got.iter().zip(&oracle).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} f={f} g={g} t={threads} i={i}");
                }
            }
        }
    }

    #[test]
    fn gemm_at_b_pooled_matches_scalar_bitwise() {
        let mut rng = Rng::new(32);
        for &(n, f, g) in &[(1usize, 1usize, 1usize), (9, 7, 4), (80, 130, 33), (64, 64, 65)] {
            let p = rand_vec(&mut rng, n * f, 0.4);
            let dz = rand_vec(&mut rng, n * g, 0.2);
            let mut oracle = vec![0f32; f * g];
            gemm_at_b(&p, &dz, n, f, g, &mut oracle);
            for threads in [1usize, 2, 8] {
                let mut got = vec![f32::NAN; f * g];
                gemm_at_b_pooled(&p, &dz, n, f, g, threads, &mut got);
                for (i, (a, b)) in got.iter().zip(&oracle).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} f={f} g={g} t={threads} i={i}");
                }
            }
        }
    }

    /// The sparse-aware dW kernel with a mask from `dz_col_block_mask`
    /// is bit-identical to the scalar oracle on dz matrices whose relu
    /// killed whole column blocks — at pool widths 1/2/8, across block
    /// boundaries.
    #[test]
    fn gemm_at_b_masked_matches_scalar_bitwise() {
        let mut rng = Rng::new(41);
        for &(n, f, g) in &[(1usize, 1usize, 1usize), (9, 7, 8), (40, 70, 33), (64, 33, 65)] {
            let p = rand_vec(&mut rng, n * f, 0.4);
            let mut dz = rand_vec(&mut rng, n * g, 0.2);
            // kill whole column blocks (the all-rows-relu-dead case)
            let blocks = g.div_ceil(AT_B_COL_BLOCK).max(1);
            for b in 0..blocks {
                if rng.bool_with(0.5) {
                    let lo = b * AT_B_COL_BLOCK;
                    let hi = (lo + AT_B_COL_BLOCK).min(g);
                    for i in 0..n {
                        dz[i * g + lo..i * g + hi].fill(0.0);
                    }
                }
            }
            let mut mask = Vec::new();
            let (total, skipped) = dz_col_block_mask(&dz, n, g, &mut mask);
            assert_eq!(total, blocks);
            assert_eq!(skipped, mask.iter().filter(|&&m| !m).count());
            // a live flag must mean a non-zero column exists in the block
            for (b, &alive) in mask.iter().enumerate() {
                let lo = b * AT_B_COL_BLOCK;
                let hi = (lo + AT_B_COL_BLOCK).min(g);
                let any = (0..n).any(|i| dz[i * g + lo..i * g + hi].iter().any(|&v| v != 0.0));
                assert_eq!(alive, any, "block {b} mask wrong");
            }
            let mut oracle = vec![0f32; f * g];
            gemm_at_b(&p, &dz, n, f, g, &mut oracle);
            for threads in [1usize, 2, 8] {
                let mut got = vec![f32::NAN; f * g];
                gemm_at_b_masked_pooled(&p, &dz, n, f, g, &mask, threads, &mut got);
                for (i, (a, b)) in got.iter().zip(&oracle).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} f={f} g={g} t={threads} i={i}");
                }
            }
        }
    }

    #[test]
    fn gemm_a_bt_pooled_close_to_scalar() {
        let mut rng = Rng::new(33);
        for &(n, f, g) in &[(1usize, 3usize, 2usize), (20, 17, 40), (50, 64, 130)] {
            let dz = rand_vec(&mut rng, n * g, 0.2);
            let w = rand_vec(&mut rng, f * g, 0.0);
            let mut oracle = vec![0f32; n * f];
            gemm_a_bt(&dz, &w, n, g, f, &mut oracle);
            let mut ref1 = None;
            for threads in [1usize, 2, 8] {
                let mut got = vec![f32::NAN; n * f];
                gemm_a_bt_pooled(&dz, &w, n, g, f, threads, &mut got);
                for (i, (a, b)) in got.iter().zip(&oracle).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-5 * b.abs().max(1.0),
                        "n={n} f={f} g={g} t={threads} i={i}: {a} vs {b}"
                    );
                }
                // chunk-count independence is still exact
                match ref1.take() {
                    None => ref1 = Some(got),
                    Some(r) => {
                        assert!(
                            got.iter().zip(r.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                            "width-dependent result"
                        );
                        ref1 = Some(r);
                    }
                }
            }
        }
    }

    #[test]
    fn adj_t_gather_matches_scatter_oracle_bitwise() {
        let mut rng = Rng::new(34);
        // random sparse block in the SparseBlock layout
        let n = 37;
        let f = 9;
        let mut offsets = vec![0usize; n + 1];
        let mut cols: Vec<u32> = Vec::new();
        let mut vals: Vec<f32> = Vec::new();
        for u in 0..n {
            for v in 0..n {
                if u != v && rng.f64() < 0.15 {
                    cols.push(v as u32);
                    vals.push(rng.f32() + 0.1);
                }
            }
            offsets[u + 1] = cols.len();
        }
        let self_loop: Vec<f32> = (0..n).map(|_| rng.f32() + 0.1).collect();
        let m = rand_vec(&mut rng, n * f, 0.1);

        let mut oracle = vec![0f32; n * f];
        scatter_adj_t(&offsets, &cols, &vals, &self_loop, &m, f, &mut oracle);

        let mut adj_t = AdjT::new();
        adj_t.build(&offsets, &cols, &vals, &self_loop);
        assert_eq!(adj_t.n(), n);
        for threads in [1usize, 2, 8] {
            let mut got = vec![f32::NAN; n * f];
            adj_t.gather_into_pooled(&m, f, threads, &mut got);
            for (i, (a, b)) in got.iter().zip(&oracle).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "t={threads} i={i}");
            }
        }
    }

    #[test]
    fn adj_t_inline_matches_dense_transpose() {
        let mut rng = Rng::new(35);
        let n = 21;
        let f = 5;
        let b = 24; // padded dense row stride
        let mut dense = vec![0f32; b * b];
        for u in 0..n {
            dense[u * b + u] = rng.f32() + 0.2;
            for v in 0..n {
                if u != v && rng.f64() < 0.2 {
                    dense[u * b + v] = rng.f32() + 0.1;
                }
            }
        }
        // sparse rows (diag inline, ascending cols)
        let mut offsets = vec![0usize; n + 1];
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for u in 0..n {
            for v in 0..n {
                let av = dense[u * b + v];
                if av != 0.0 {
                    cols.push(v as u32);
                    vals.push(av);
                }
            }
            offsets[u + 1] = cols.len();
        }
        let m = rand_vec(&mut rng, n * f, 0.0);
        // dense scatter reference: out[v] += a[u][v] * m[u]
        let mut expect = vec![0f32; n * f];
        for u in 0..n {
            for v in 0..n {
                let a = dense[u * b + v];
                if a != 0.0 {
                    for j in 0..f {
                        expect[v * f + j] += a * m[u * f + j];
                    }
                }
            }
        }
        let mut adj_t = AdjT::new();
        adj_t.build_inline(&offsets, &cols, &vals);
        let mut got = vec![f32::NAN; n * f];
        adj_t.gather_into_pooled(&m, f, 4, &mut got);
        for (i, (a, b)) in got.iter().zip(&expect).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "i={i}");
        }
    }

    #[test]
    fn adam_single_step_known_values() {
        let mut w = vec![1.0f32];
        let g = vec![0.5f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        adam_update(&mut w, &g, &mut m, &mut v, 1.0, 0.1);
        // m = 0.05, v = 0.00025; bias-corrected mhat = 0.5, vhat = 0.25
        assert!((m[0] - 0.05).abs() < 1e-7);
        assert!((v[0] - 0.00025).abs() < 1e-9);
        // w -= 0.1 * 0.5 / (0.5 + eps) ≈ 1 - 0.1
        assert!((w[0] - 0.9).abs() < 1e-5, "w = {}", w[0]);
    }

    #[test]
    fn pooled_adam_matches_per_layer_scalar_bitwise() {
        let shapes = [(7usize, 13usize), (13, 13), (13, 3)];
        let mut rng = Rng::new(36);
        let mk = |rng: &mut Rng| -> Vec<Tensor> {
            shapes
                .iter()
                .map(|&(a, b)| Tensor::new(vec![a, b], rand_vec(rng, a * b, 0.0)))
                .collect()
        };
        let w0 = mk(&mut rng);
        let m0 = mk(&mut rng);
        let v0: Vec<Tensor> = mk(&mut rng)
            .into_iter()
            .map(|t| Tensor::new(t.dims.clone(), t.data.iter().map(|x| x.abs()).collect()))
            .collect();
        let mut spans = Vec::new();
        let mut grads = Vec::new();
        for &(a, b) in &shapes {
            spans.push((grads.len(), a * b));
            grads.extend(rand_vec(&mut rng, a * b, 0.0));
        }
        for t in [1.0f32, 7.0] {
            // scalar per-layer reference
            let (mut we, mut me, mut ve) = (w0.clone(), m0.clone(), v0.clone());
            for (li, &(off, len)) in spans.iter().enumerate() {
                adam_update(
                    &mut we[li].data,
                    &grads[off..off + len],
                    &mut me[li].data,
                    &mut ve[li].data,
                    t,
                    0.03,
                );
            }
            for threads in [1usize, 2, 8] {
                let (mut wg, mut mg, mut vg) = (w0.clone(), m0.clone(), v0.clone());
                adam_update_pooled(&mut wg, &mut mg, &mut vg, &grads, &spans, t, 0.03, threads);
                for li in 0..shapes.len() {
                    for i in 0..wg[li].data.len() {
                        assert_eq!(
                            wg[li].data[i].to_bits(),
                            we[li].data[i].to_bits(),
                            "w layer {li} i={i} t={t} threads={threads}"
                        );
                        assert_eq!(mg[li].data[i].to_bits(), me[li].data[i].to_bits());
                        assert_eq!(vg[li].data[i].to_bits(), ve[li].data[i].to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn workspace_prepare_is_idempotent_and_never_shrinks() {
        let w = vec![
            Tensor::zeros(vec![6, 16]),
            Tensor::zeros(vec![16, 4]),
        ];
        let mut ws = BackwardWorkspace::new();
        ws.prepare(&w, 50);
        assert_eq!(ws.spans, vec![(0, 96), (96, 64)]);
        assert_eq!(ws.ps[0].len(), 50 * 6);
        assert_eq!(ws.zs[1].len(), 50 * 4);
        assert!(ws.cur.len() >= 50 * 16);
        let caps = (ws.grads.capacity(), ws.cur.capacity(), ws.ps[0].capacity());
        ws.prepare(&w, 30); // smaller batch: no shrink, no realloc
        assert_eq!(caps.0, ws.grads.capacity());
        assert_eq!(caps.1, ws.cur.capacity());
        assert_eq!(caps.2, ws.ps[0].capacity());
        assert!(ws.ps[0].len() >= 30 * 6);
        assert_eq!(ws.grad_layers().len(), 2);
        assert_eq!(ws.grad_layers()[1].len(), 64);
    }
}
