//! [`ShardedBackend`]: the data-parallel [`Backend`] combinator.
//! Cluster partitions are a natural unit of data parallelism (each
//! batch is an almost-self-contained subgraph), so a sharded step pulls
//! one cluster batch per replica, computes gradients on every replica
//! concurrently, all-reduce-averages them, and applies **one** shared
//! bias-corrected Adam step on the chief backend:
//!
//! ```text
//!   step_from(first):  source ── batch first+0 ──► replica 0 ─ grads ─┐
//!                      source ── batch first+1 ──► replica 1 ─ grads ─┼─ avg ─► chief Adam
//!                      source ── batch first+k ──► replica k ─ grads ─┘
//! ```
//!
//! Replicas run on scoped OS threads with their kernel width pinned to
//! 1 (the pooled kernels are bit-identical at every width, so this
//! changes nothing numerically and keeps replica gradient work off the
//! shared pool, which runs one job at a time).  Determinism: gradients
//! are summed in replica order and scaled once, so a sharded run is a
//! pure function of `(seed, shards)`.
//!
//! Parity contract (pinned by `tests/driver.rs`): with **one** replica
//! the sum has a single term and the scale is skipped, so every step is
//! **bit-identical** to `HostBackend::train_step` — same loss bits,
//! same weight/moment bits.  With N replicas the per-step batch size
//! grows N-fold and the loss curve is statistically equivalent, not
//! bitwise.
#![deny(missing_docs)]

use anyhow::{anyhow, Result};

use crate::coordinator::batch::Batch;
use crate::coordinator::source::BatchSource;
use crate::coordinator::trainer::TrainState;
use crate::runtime::backend::{Backend, ModelSpec, StepOutcome, VrgcnBatch};
use crate::runtime::exec::Tensor;
use crate::runtime::host::HostBackend;
use crate::util::simd::axpy;

/// Data-parallel combinator over `N` replica backends plus a chief
/// (spec registry, optimizer, forward/eval path).  See the module docs
/// for the step anatomy and the parity contract.
pub struct ShardedBackend<B> {
    chief: B,
    replicas: Vec<B>,
    bufs: Vec<Batch>,
    grads: Vec<Vec<Vec<f32>>>,
    avg: Vec<Vec<f32>>,
}

impl ShardedBackend<HostBackend> {
    /// `shards` host replicas (kernel width 1 each) behind a
    /// default-width host chief — the configuration `--shards N`
    /// builds.
    pub fn host(shards: usize) -> ShardedBackend<HostBackend> {
        assert!(shards >= 1, "a sharded backend needs at least one replica");
        ShardedBackend::new(
            HostBackend::new(),
            (0..shards).map(|_| HostBackend::with_threads(1)).collect(),
        )
    }
}

impl<B: Backend + Send> ShardedBackend<B> {
    /// Combinator over explicit chief + replica backends (every one
    /// must support [`Backend::grad_step`]; the chief must support
    /// [`Backend::apply_grads`]).
    pub fn new(chief: B, replicas: Vec<B>) -> ShardedBackend<B> {
        assert!(!replicas.is_empty(), "a sharded backend needs at least one replica");
        let shards = replicas.len();
        ShardedBackend {
            chief,
            replicas,
            bufs: Vec::new(),
            grads: vec![Vec::new(); shards],
            avg: Vec::new(),
        }
    }

    /// Replica count (batches consumed per optimization step).
    pub fn shards(&self) -> usize {
        self.replicas.len()
    }

    fn ensure_bufs(&mut self, source: &dyn BatchSource) {
        let (b, f, c) = source.shape();
        let fits = |bt: &Batch| {
            bt.a.dims == [b, b] && bt.x.dims == [b, f] && bt.y.dims == [b, c]
        };
        if self.bufs.len() != self.replicas.len() || !self.bufs.iter().all(fits) {
            self.bufs = (0..self.replicas.len()).map(|_| source.new_batch()).collect();
        }
    }
}

impl<B: Backend + Send> Backend for ShardedBackend<B> {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn model_spec(&mut self, model: &str) -> Result<ModelSpec> {
        self.chief.model_spec(model)
    }

    fn prepare(&mut self, model: &str) -> Result<()> {
        self.chief.prepare(model)?;
        for r in &mut self.replicas {
            r.prepare(model)?;
        }
        Ok(())
    }

    fn register_model(&mut self, model: &str, spec: ModelSpec) -> bool {
        let ok = self.chief.register_model(model, spec.clone());
        for r in &mut self.replicas {
            r.register_model(model, spec.clone());
        }
        ok
    }

    fn train_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &Batch,
    ) -> Result<f32> {
        // One-batch data-parallel step through the same replica
        // grad_step + chief apply_grads chain as step_from, so every
        // entry point (including a prefetch wrapper around a one-shard
        // backend) exercises the replica path — bit-identical to the
        // chief's fused step by the parity contract.
        let rep = &mut self.replicas[0];
        let gb = &mut self.grads[0];
        let loss = rep.grad_step(model, &state.weights, batch, gb)?;
        self.chief.apply_grads(model, state, lr, &self.grads[0])?;
        Ok(loss)
    }

    fn forward(&mut self, model: &str, weights: &[Tensor], batch: &Batch) -> Result<Tensor> {
        self.chief.forward(model, weights, batch)
    }

    fn vrgcn_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        self.chief.vrgcn_step(model, state, lr, batch)
    }

    fn batches_per_step(&self) -> usize {
        self.replicas.len()
    }

    fn epoch_begin(&mut self) {
        self.chief.epoch_begin();
        for r in &mut self.replicas {
            r.epoch_begin();
        }
    }

    fn step_from(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        source: &mut dyn BatchSource,
        first: usize,
        _scratch: &mut Batch,
    ) -> Result<StepOutcome> {
        let k = self.replicas.len().min(source.len().saturating_sub(first));
        if k == 0 {
            return Err(anyhow!("step_from past the end of the epoch plan"));
        }
        // chaos-only: a replica dying mid-exchange surfaces as a typed
        // error before any replica's gradients are applied
        crate::util::failpoint::check("shard.exchange")?;
        self.ensure_bufs(source);
        for (j, buf) in self.bufs.iter_mut().enumerate().take(k) {
            source.assemble(first + j, buf);
        }

        // ---- fan out: one grad computation per replica thread -------
        let weights: &[Tensor] = &state.weights;
        let losses: Vec<Option<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .replicas
                .iter_mut()
                .zip(self.bufs.iter())
                .zip(self.grads.iter_mut())
                .take(k)
                .map(|((rep, buf), gb)| {
                    s.spawn(move || -> Result<Option<f32>> {
                        if buf.n_train == 0 {
                            return Ok(None);
                        }
                        rep.grad_step(model, weights, buf, gb).map(Some)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    Err(p) => std::panic::resume_unwind(p),
                })
                .collect::<Result<Vec<_>>>()
        })?;

        let active: Vec<usize> =
            (0..k).filter(|&j| losses[j].is_some()).collect();
        if active.is_empty() {
            return Ok(StepOutcome { loss: None, consumed: k });
        }

        // ---- all-reduce: sum in replica order, scale once ------------
        let layers = self.grads[active[0]].len();
        self.avg.resize(layers, Vec::new());
        for li in 0..layers {
            let len = self.grads[active[0]][li].len();
            let dst = &mut self.avg[li];
            dst.clear();
            dst.extend_from_slice(&self.grads[active[0]][li]);
            debug_assert_eq!(dst.len(), len);
            for &j in &active[1..] {
                axpy(dst, &self.grads[j][li], 1.0);
            }
            if active.len() > 1 {
                // skipped for one shard: dst == the single replica's
                // gradient, bit for bit (the shards=1 parity contract)
                let scale = 1.0 / active.len() as f32;
                for v in dst.iter_mut() {
                    *v *= scale;
                }
            }
        }
        self.chief.apply_grads(model, state, lr, &self.avg)?;

        let loss_sum: f32 = active.iter().map(|&j| losses[j].unwrap()).sum();
        let loss = if active.len() > 1 {
            loss_sum / active.len() as f32
        } else {
            loss_sum
        };
        if !loss.is_finite() {
            return Err(anyhow!("non-finite sharded loss at step {}", state.step));
        }
        Ok(StepOutcome { loss: Some(loss), consumed: k })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Task;

    #[test]
    fn registration_reaches_every_replica() {
        let mut sb = ShardedBackend::host(3);
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 4, 8, 2, 16);
        assert!(sb.register_model("m", spec.clone()));
        assert_eq!(sb.shards(), 3);
        assert_eq!(sb.batches_per_step(), 3);
        assert!(sb.prepare("m").is_ok());
        assert_eq!(sb.model_spec("m").unwrap(), spec);
        for r in &mut sb.replicas {
            assert_eq!(r.model_spec("m").unwrap(), spec);
        }
    }

    #[test]
    #[should_panic(expected = "at least one replica")]
    fn zero_shards_rejected() {
        let _ = ShardedBackend::host(0);
    }
}
