//! PJRT execution engine: wraps the `xla` crate (PJRT C API) to load
//! `artifacts/*.hlo.txt`, compile once per artifact, and run train/eval
//! steps from the L3 hot loop.
//!
//! Pattern (see /opt/xla-example): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` →
//! `execute(&[Literal])` → the 1-tuple result is decomposed into output
//! literals.  Python is never involved at this point.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifacts::{ArtifactMeta, Registry};

/// f32 host tensor — the interchange type between the coordinator and
/// PJRT.  Row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let len = dims.iter().product();
        Tensor { dims, data: vec![0.0; len] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { dims: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * 4,
            )
        };
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.dims,
            bytes,
        )
        .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
        Ok(Tensor::new(dims, data))
    }
}

/// Compiled-executable cache over a PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    registry: Registry,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative phase timings (profiling; EXPERIMENTS.md §Perf).
    pub lit_seconds: f64,
    pub exec_seconds: f64,
    pub sync_seconds: f64,
    pub exec_count: u64,
}

impl Engine {
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let registry = Registry::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            registry,
            cache: HashMap::new(),
            lit_seconds: 0.0,
            exec_seconds: 0.0,
            sync_seconds: 0.0,
            exec_count: 0,
        })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn meta(&self, name: &str) -> Result<ArtifactMeta> {
        Ok(self.registry.get(name)?.clone())
    }

    /// Drop all cached executables.  XLA CPU retains sizeable buffers
    /// per compiled executable; long bench sweeps over many artifacts
    /// must evict between configurations or exhaust host RAM
    /// (EXPERIMENTS.md §Perf).
    pub fn clear_cache(&mut self) {
        self.cache.clear();
    }

    /// Number of live compiled executables.
    pub fn cached_executables(&self) -> usize {
        self.cache.len()
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self.registry.get(name)?;
        let path = meta
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {path}: {e:?}"))
            .with_context(|| format!("artifact {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("XLA compile of {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute the named artifact on the given inputs; returns the
    /// decomposed output tuple as host tensors.
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(name, &refs)
    }

    /// Reference-taking variant of [`Engine::run`] — the training hot
    /// loop passes params/batch tensors without cloning them
    /// (EXPERIMENTS.md §Perf: ~10 MB/step of memcpy saved on wide
    /// models).
    pub fn run_refs(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;
        let meta = self.registry.get(name)?;
        if inputs.len() != meta.input_count() {
            return Err(anyhow!(
                "artifact {name} expects {} inputs, got {}",
                meta.input_count(),
                inputs.len()
            ));
        }
        let expected_outputs = meta.output_count();
        let exe = self.cache.get(name).unwrap();

        let t0 = std::time::Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let t1 = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("PJRT execute of {name}: {e:?}"))?;
        let t2 = std::time::Instant::now();
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("result sync: {e:?}"))?;
        let t3 = std::time::Instant::now();
        self.lit_seconds += (t1 - t0).as_secs_f64();
        self.exec_seconds += (t2 - t1).as_secs_f64();
        self.sync_seconds += (t3 - t2).as_secs_f64();
        self.exec_count += 1;

        // aot.py lowers with return_tuple=True: the root is a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow!("result decompose: {e:?}"))?;
        if parts.len() != expected_outputs {
            return Err(anyhow!(
                "artifact {name}: expected {expected_outputs} outputs, got {}",
                parts.len()
            ));
        }
        parts.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip_through_literal() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar(7.5);
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t2.data, vec![7.5]);
        assert!(t2.dims.is_empty());
    }

    #[test]
    fn zeros_shape() {
        let t = Tensor::zeros(vec![4, 5]);
        assert_eq!(t.len(), 20);
        assert_eq!(t.size_bytes(), 80);
    }
}
