//! [`PrefetchBackend`]: the assembly-overlap [`Backend`] combinator.
//! The PR-1 trainer pipelined batch assembly against PJRT execution
//! with an ad-hoc double buffer private to the cluster loop; this
//! combinator moves that overlap behind the trait, where every
//! [`BatchSource`]-backed method (Cluster, Expansion, GraphSage) gets
//! it for free:
//!
//! ```text
//!   step_from(i):   helper thread ── source.assemble(i + 1) ──► back buffer
//!                   this thread   ── inner.train_step(front = batch i)
//!                   join, swap front/back
//! ```
//!
//! Each call overlaps the *next* batch's assembly with the *current*
//! batch's execution; across calls the freshly assembled batch is
//! carried in the front buffer, so steady state assembles each batch
//! exactly once and executes with zero assembly on the critical path.
//! Numerically nothing changes: batches are assembled in the same
//! order, by the same source, with the same RNG stream — a prefetched
//! cluster run is bit-identical to the serial one (pinned by
//! `tests/driver.rs`).
//!
//! Lookahead is disabled (pass-through to the inner backend) when the
//! source declares itself non-prefetchable
//! ([`BatchSource::prefetchable`], the opt-out for future sources whose
//! assembly depends on step results — VR-GCN itself bypasses
//! `BatchSource` entirely and runs inline in the driver), when the
//! inner backend consumes more than one batch per step (a sharded
//! inner pulls its own replicas' batches), or when the inner backend
//! declares itself non-prefetchable ([`Backend::prefetchable`] — the
//! distributed backend's batches are assembled by worker processes,
//! never locally).  The cross-epoch carry is invalidated by
//! [`Backend::epoch_begin`].
//!
//! The wrapper is a *scheduler*, not an execution identity:
//! [`Backend::name`] forwards the inner backend's name, and the
//! session wraps every owned backend in one by default
//! (`Session::prefetch(false)` opts out) — the PR-1 trainer's overlap
//! is the default again, now for every method.
#![deny(missing_docs)]

use anyhow::Result;

use crate::coordinator::batch::Batch;
use crate::coordinator::source::BatchSource;
use crate::coordinator::trainer::TrainState;
use crate::runtime::backend::{Backend, ModelSpec, StepOutcome, VrgcnBatch};
use crate::runtime::exec::Tensor;

/// Double-buffered assembly-overlap combinator; see the module docs.
pub struct PrefetchBackend<B> {
    inner: B,
    front: Option<Batch>,
    back: Option<Batch>,
    /// Batch index currently assembled in `front`, if any.
    have: Option<usize>,
}

impl<B: Backend> PrefetchBackend<B> {
    /// Wrap `inner`; buffers are lazily shaped from the first source.
    pub fn new(inner: B) -> PrefetchBackend<B> {
        PrefetchBackend { inner, front: None, back: None, have: None }
    }

    /// The wrapped backend (for inspection after a run).
    pub fn inner(&self) -> &B {
        &self.inner
    }

    fn ensure_bufs(&mut self, source: &dyn BatchSource) {
        let (b, f, c) = source.shape();
        let fits = |bt: &Batch| {
            bt.a.dims == [b, b] && bt.x.dims == [b, f] && bt.y.dims == [b, c]
        };
        if !self.front.as_ref().is_some_and(fits) {
            self.front = Some(source.new_batch());
            self.have = None;
        }
        if !self.back.as_ref().is_some_and(fits) {
            self.back = Some(source.new_batch());
        }
    }
}

impl<B: Backend> Backend for PrefetchBackend<B> {
    fn name(&self) -> &'static str {
        // a scheduling wrapper, not an execution identity — reports
        // where the math actually runs
        self.inner.name()
    }

    fn model_spec(&mut self, model: &str) -> Result<ModelSpec> {
        self.inner.model_spec(model)
    }

    fn prepare(&mut self, model: &str) -> Result<()> {
        self.inner.prepare(model)
    }

    fn register_model(&mut self, model: &str, spec: ModelSpec) -> bool {
        self.inner.register_model(model, spec)
    }

    fn train_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &Batch,
    ) -> Result<f32> {
        self.inner.train_step(model, state, lr, batch)
    }

    fn forward(&mut self, model: &str, weights: &[Tensor], batch: &Batch) -> Result<Tensor> {
        self.inner.forward(model, weights, batch)
    }

    fn vrgcn_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        self.inner.vrgcn_step(model, state, lr, batch)
    }

    fn batches_per_step(&self) -> usize {
        self.inner.batches_per_step()
    }

    fn epoch_begin(&mut self) {
        // a batch carried over from the previous epoch's plan is stale
        self.have = None;
        self.inner.epoch_begin();
    }

    fn prefetchable(&self) -> bool {
        self.inner.prefetchable()
    }

    fn step_from(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        source: &mut dyn BatchSource,
        first: usize,
        scratch: &mut Batch,
    ) -> Result<StepOutcome> {
        if self.inner.batches_per_step() != 1
            || !self.inner.prefetchable()
            || !source.prefetchable()
        {
            self.have = None;
            return self.inner.step_from(model, state, lr, source, first, scratch);
        }
        self.ensure_bufs(source);
        let inner = &mut self.inner;
        let front = self.front.as_mut().expect("front buffer just ensured");
        let back = self.back.as_mut().expect("back buffer just ensured");
        if self.have != Some(first) {
            // cold start (first step of an epoch, or lookahead was
            // invalidated): assemble inline
            source.assemble(first, front);
        }
        let next = first + 1;
        let lookahead = next < source.len();
        let loss = std::thread::scope(|s| {
            let handle = lookahead.then(|| s.spawn(|| source.assemble(next, back)));
            let r = if front.n_train == 0 {
                Ok(None)
            } else {
                inner.train_step(model, state, lr, front).map(Some)
            };
            if let Some(h) = handle {
                if let Err(p) = h.join() {
                    std::panic::resume_unwind(p);
                }
            }
            r
        })?;
        if lookahead {
            std::mem::swap(&mut self.front, &mut self.back);
            self.have = Some(next);
        } else {
            self.have = None;
        }
        Ok(StepOutcome { loss, consumed: 1 })
    }

    fn grad_step(
        &mut self,
        model: &str,
        weights: &[Tensor],
        batch: &Batch,
        grads: &mut Vec<Vec<f32>>,
    ) -> Result<f32> {
        self.inner.grad_step(model, weights, batch, grads)
    }

    fn apply_grads(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        grads: &[Vec<f32>],
    ) -> Result<()> {
        self.inner.apply_grads(model, state, lr, grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Task;
    use crate::runtime::HostBackend;

    #[test]
    fn forwards_registry_to_inner() {
        let mut pb = PrefetchBackend::new(HostBackend::new());
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 4, 8, 2, 16);
        assert!(pb.register_model("m", spec.clone()));
        assert_eq!(pb.model_spec("m").unwrap(), spec);
        assert_eq!(pb.batches_per_step(), 1);
        assert_eq!(pb.inner().models().count(), 1);
    }
}
