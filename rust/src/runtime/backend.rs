//! The execution-backend abstraction: one trait every training loop and
//! the [`crate::session::Session`] API talk to, with two implementations
//! — the PJRT [`Engine`] (AOT artifacts, this module) and the pure-host
//! [`super::HostBackend`] (no artifacts at all, `runtime::host`).
//!
//! The trait carries exactly the operations the four training methods
//! need: resolve a [`ModelSpec`] for a model id, prepare (compile/cache)
//! it, run one fused `train_step` over an assembled [`Batch`], run a
//! batch `forward`, and run one VR-GCN control-variate step over a
//! [`VrgcnBatch`].  Everything else — sampling, assembly, normalization,
//! evaluation, scheduling — is backend-independent host code.
#![deny(missing_docs)]

use anyhow::{anyhow, Result};

use crate::coordinator::batch::Batch;
use crate::coordinator::source::BatchSource;
use crate::coordinator::trainer::TrainState;
use crate::graph::Task;
use crate::runtime::artifacts::{ArtifactMeta, Kind};
use crate::runtime::exec::{Engine, Tensor};

/// What one [`Backend::step_from`] call did: how many of the epoch's
/// batches it pulled from the source, and the resulting optimization
/// loss (`None` when every pulled batch had nothing to learn from —
/// no train-split node — and the optimizer state was left untouched).
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// Mean loss over the batches that contributed gradients, or
    /// `None` when the step was skipped entirely.
    pub loss: Option<f32>,
    /// Batches consumed from the source (`>= 1`).
    pub consumed: usize,
}

/// Typed architecture of one trainable model — the backend-neutral
/// replacement for reading shapes out of an [`ArtifactMeta`].  A spec is
/// all [`TrainState::init`] and the training loops need, so a model can
/// exist without any artifact directory behind it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    /// Loss/metric family (softmax multiclass vs sigmoid multilabel).
    pub task: Task,
    /// Number of GCN layers `L`.
    pub layers: usize,
    /// Input feature width.
    pub f_in: usize,
    /// Hidden width of layers `1..L-1`.
    pub f_hid: usize,
    /// Output classes.
    pub classes: usize,
    /// Padded batch size every batch tensor is shaped to.
    pub b_max: usize,
    /// Residual connections between equal-width hidden layers (eq. (8)).
    pub residual: bool,
    /// `(f_in, f_out)` of each layer's weight matrix.
    pub weight_shapes: Vec<(usize, usize)>,
}

impl ModelSpec {
    /// Standard L-layer GCN spec: `f_in -> f_hid^(L-1) -> classes`, no
    /// residual (the paper's default architecture).
    pub fn gcn(
        task: Task,
        layers: usize,
        f_in: usize,
        f_hid: usize,
        classes: usize,
        b_max: usize,
    ) -> ModelSpec {
        assert!(layers >= 1, "a model needs at least one layer");
        let mut dims = Vec::with_capacity(layers + 1);
        dims.push(f_in);
        for _ in 1..layers {
            dims.push(f_hid);
        }
        dims.push(classes);
        let weight_shapes = (0..layers).map(|i| (dims[i], dims[i + 1])).collect();
        ModelSpec { task, layers, f_in, f_hid, classes, b_max, residual: false, weight_shapes }
    }

    /// Same spec with residual connections enabled.
    pub fn with_residual(mut self) -> ModelSpec {
        self.residual = true;
        self
    }

    /// Per-layer activation input dims (the VR-GCN `Hc` shapes).
    pub fn layer_in_dims(&self) -> Vec<usize> {
        self.weight_shapes.iter().map(|&(fi, _)| fi).collect()
    }

    /// Total parameter element count (one weight set; Adam state is 2x).
    pub fn param_elements(&self) -> usize {
        self.weight_shapes.iter().map(|&(a, b)| a * b).sum()
    }
}

impl From<&ArtifactMeta> for ModelSpec {
    fn from(m: &ArtifactMeta) -> ModelSpec {
        ModelSpec {
            task: m.task,
            layers: m.layers,
            f_in: m.f_in,
            f_hid: m.f_hid,
            classes: m.classes,
            b_max: m.b_max,
            residual: m.residual,
            weight_shapes: m.weight_shapes.clone(),
        }
    }
}

/// CSR view of one VR-GCN step's scaled in-batch sampled adjacency
/// `A_in`, with the diagonal (self-loop) stored **inline** at its
/// sorted column position — the layout the host backward's
/// `AdjT::build_inline` transpose consumes directly.  Columns are local
/// batch ids, strictly ascending within each row; every stored value is
/// non-zero.  This is the *native* representation: the VR-GCN assembly
/// writes it without ever materializing the `b_max²` dense block the
/// pre-PR-5 path allocated per step (the dense tensor survives only as
/// an on-demand realization for the PJRT executable and the parity
/// oracle, [`VrgcnAdj::to_dense`]).
#[derive(Clone, Debug, Default)]
pub struct VrgcnAdj {
    /// Row offsets into `cols`/`vals`, length `n_real + 1`.
    pub offsets: Vec<usize>,
    /// Local column ids, strictly ascending within each row (diagonal
    /// inline).
    pub cols: Vec<u32>,
    /// Entry values aligned with `cols`.
    pub vals: Vec<f32>,
}

impl VrgcnAdj {
    /// Empty adjacency (filled by the first assembly).
    pub fn new() -> VrgcnAdj {
        VrgcnAdj::default()
    }

    /// Number of real rows.
    pub fn n(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Stored entries (diagonal included).
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Host bytes of the CSR buffers (Table 5/8 memory accounting).
    pub fn bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<usize>()
            + self.cols.len() * 4
            + self.vals.len() * 4
    }

    /// Materialize the padded `(b, b)` dense block — what the PJRT
    /// executable consumes and what the retained dense parity oracle
    /// re-extracts.  Values are written verbatim, so the realization is
    /// bit-identical to the CSR entries.
    pub fn to_dense(&self, b: usize) -> Tensor {
        let n = self.n();
        debug_assert!(n <= b, "adjacency rows exceed the padded batch");
        let mut out = Tensor::zeros(vec![b, b]);
        for u in 0..n {
            let off = self.offsets[u];
            for (idx, &v) in self.cols[off..self.offsets[u + 1]].iter().enumerate() {
                out.data[u * b + v as usize] = self.vals[off + idx];
            }
        }
        out
    }
}

/// Inputs of one VR-GCN control-variate step (Chen et al., ICML'18), as
/// assembled by `baselines::vrgcn`: the scaled in-batch sampled
/// adjacency — carried **sparsely** as a [`VrgcnAdj`], end to end —
/// plus the host-precomputed historical contributions.
pub struct VrgcnBatch {
    /// In-batch block (self loops + scaled sampled edges whose other
    /// end is in the batch), CSR with the diagonal inline.  The PJRT
    /// path densifies on demand via [`VrgcnAdj::to_dense`]; the host
    /// path consumes the CSR natively.
    pub a_in: VrgcnAdj,
    /// Per-layer historical contribution `Hc_l = Â·H_l` minus the
    /// sampled in-batch part, `(b_max, f_l)` each, `L` entries.
    pub hcs: Vec<Tensor>,
    /// `(b_max, f_in)` features.
    pub x: Tensor,
    /// `(b_max, classes)` labels.
    pub y: Tensor,
    /// `(b_max,)` loss mask over the target nodes.
    pub mask: Tensor,
    /// Number of real (non-padding) nodes.
    pub n_real: usize,
}

impl VrgcnBatch {
    /// Host bytes of the batch tensors + the CSR adjacency (Table 5
    /// memory accounting).
    pub fn bytes(&self) -> usize {
        self.a_in.bytes()
            + self.hcs.iter().map(|t| t.size_bytes()).sum::<usize>()
            + self.x.size_bytes()
            + self.y.size_bytes()
            + self.mask.size_bytes()
    }
}

/// An execution backend: where `train_step`/`forward` actually run.
///
/// Implementations:
///
/// - [`Engine`] — the PJRT path; model ids are AOT artifact names and
///   specs come from `artifacts/manifest.json`.
/// - [`super::HostBackend`] — pure host; model ids are whatever the
///   caller registered via [`Backend::register_model`], and the math
///   runs on the tiled SpMM·GEMM kernels of `coordinator::inference`
///   plus a host Adam step.  No artifacts directory is needed.
///
/// Contract shared by all implementations: `train_step` and
/// `vrgcn_step` increment `state.step`, update weights + Adam moments
/// in place, and return the batch loss (erroring on a non-finite loss);
/// `forward` returns `(b_max, classes)` logits with zeroed padding
/// rows.
pub trait Backend {
    /// Short backend identifier (`"pjrt"` | `"host"`), used in logs and
    /// the CLI summary.
    fn name(&self) -> &'static str;

    /// Resolve the spec for a model id.  Errors if the backend does not
    /// know the model (unknown artifact / never registered).
    fn model_spec(&mut self, model: &str) -> Result<ModelSpec>;

    /// Prepare the model for execution (compile the artifact, warm
    /// caches).  Idempotent; the default does nothing.
    fn prepare(&mut self, model: &str) -> Result<()> {
        let _ = model;
        Ok(())
    }

    /// Register a spec under a model id for backends that synthesize
    /// models instead of loading artifacts.  Returns `true` if the
    /// backend accepted the registration (the PJRT engine ignores it —
    /// its manifest is the source of truth — and returns `false`).
    fn register_model(&mut self, model: &str, spec: ModelSpec) -> bool {
        let _ = (model, spec);
        false
    }

    /// One fused train step (forward + masked loss + backward + Adam)
    /// over an assembled batch; updates `state` in place and returns
    /// the batch loss.
    fn train_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &Batch,
    ) -> Result<f32>;

    /// Batch forward: `(b_max, classes)` logits over the batch block.
    fn forward(&mut self, model: &str, weights: &[Tensor], batch: &Batch) -> Result<Tensor>;

    /// One VR-GCN control-variate step; returns the batch loss and the
    /// `L-1` hidden activations `(b_max, f_hid)` used to refresh the
    /// history store.
    fn vrgcn_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>)>;

    // ---- pull-side surface (driver + combinators) -------------------

    /// How many of an epoch's batches one [`Backend::step_from`] call
    /// consumes — the data-parallel width (1 for plain backends,
    /// replica count for [`super::ShardedBackend`]).
    fn batches_per_step(&self) -> usize {
        1
    }

    /// Epoch boundary notification from the driver.  Combinators use it
    /// to invalidate cross-step lookahead state (a prefetched batch
    /// from the previous epoch's plan); plain backends ignore it.
    fn epoch_begin(&mut self) {}

    /// Whether a lookahead wrapper ([`super::PrefetchBackend`]) may
    /// drive this backend through [`Backend::train_step`] with batches
    /// it assembled itself.  `false` for backends that must pull
    /// batches through their own [`Backend::step_from`] — the
    /// distributed backend's workers assemble their own clusters'
    /// batches from worker-local data, so a wrapper handing it
    /// chief-assembled batches would silently bypass distribution.
    fn prefetchable(&self) -> bool {
        true
    }

    /// Execute one optimization step by pulling batches starting at
    /// index `first` from `source` (see the [`BatchSource`] call
    /// contract).  `scratch` is a driver-owned reusable buffer shaped
    /// by the source; combinators that keep their own buffers ignore
    /// it.  The default pulls exactly one batch and delegates to
    /// [`Backend::train_step`], skipping batches with no training
    /// nodes.
    fn step_from(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        source: &mut dyn BatchSource,
        first: usize,
        scratch: &mut Batch,
    ) -> Result<StepOutcome> {
        source.assemble(first, scratch);
        if scratch.n_train == 0 {
            return Ok(StepOutcome { loss: None, consumed: 1 });
        }
        let loss = self.train_step(model, state, lr, scratch)?;
        Ok(StepOutcome { loss: Some(loss), consumed: 1 })
    }

    /// Loss + per-layer weight gradients over one batch **without**
    /// touching optimizer state — the data-parallel primitive
    /// [`super::ShardedBackend`] fans out to its replicas.  `grads` is
    /// a caller-owned reusable buffer (resized to one `Vec` per layer).
    /// Backends whose step is fused and cannot expose gradients (the
    /// PJRT engine) return an error.
    fn grad_step(
        &mut self,
        model: &str,
        weights: &[Tensor],
        batch: &Batch,
        grads: &mut Vec<Vec<f32>>,
    ) -> Result<f32> {
        let _ = (model, weights, batch, grads);
        Err(anyhow!(
            "backend '{}' cannot expose per-batch gradients (fused step); \
             sharded training needs the host backend",
            self.name()
        ))
    }

    /// Apply externally accumulated per-layer gradients with one
    /// bias-corrected Adam step (increments `state.step`) — the reduce
    /// side of a data-parallel step.  Backends without a host optimizer
    /// return an error.
    fn apply_grads(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        grads: &[Vec<f32>],
    ) -> Result<()> {
        let _ = (model, state, lr, grads);
        Err(anyhow!(
            "backend '{}' cannot apply external gradients (fused step); \
             sharded training needs the host backend",
            self.name()
        ))
    }
}

/// Mutable references forward every method (including the pull-side
/// surface, so combinator overrides survive the indirection) — this is
/// what lets the compat training entries wrap a caller's
/// `&mut dyn Backend` in a `PrefetchBackend` without taking ownership.
impl<B: Backend + ?Sized> Backend for &mut B {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn model_spec(&mut self, model: &str) -> Result<ModelSpec> {
        (**self).model_spec(model)
    }
    fn prepare(&mut self, model: &str) -> Result<()> {
        (**self).prepare(model)
    }
    fn register_model(&mut self, model: &str, spec: ModelSpec) -> bool {
        (**self).register_model(model, spec)
    }
    fn train_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &Batch,
    ) -> Result<f32> {
        (**self).train_step(model, state, lr, batch)
    }
    fn forward(&mut self, model: &str, weights: &[Tensor], batch: &Batch) -> Result<Tensor> {
        (**self).forward(model, weights, batch)
    }
    fn vrgcn_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        (**self).vrgcn_step(model, state, lr, batch)
    }
    fn batches_per_step(&self) -> usize {
        (**self).batches_per_step()
    }
    fn epoch_begin(&mut self) {
        (**self).epoch_begin()
    }
    fn prefetchable(&self) -> bool {
        (**self).prefetchable()
    }
    fn step_from(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        source: &mut dyn BatchSource,
        first: usize,
        scratch: &mut Batch,
    ) -> Result<StepOutcome> {
        (**self).step_from(model, state, lr, source, first, scratch)
    }
    fn grad_step(
        &mut self,
        model: &str,
        weights: &[Tensor],
        batch: &Batch,
        grads: &mut Vec<Vec<f32>>,
    ) -> Result<f32> {
        (**self).grad_step(model, weights, batch, grads)
    }
    fn apply_grads(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        grads: &[Vec<f32>],
    ) -> Result<()> {
        (**self).apply_grads(model, state, lr, grads)
    }
}

/// Boxed backends forward every method (including the pull-side
/// surface, so combinator overrides survive the indirection) — this is
/// what lets the session stack `PrefetchBackend<Box<dyn Backend>>` over
/// whatever backend the caller supplied.
impl<B: Backend + ?Sized> Backend for Box<B> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn model_spec(&mut self, model: &str) -> Result<ModelSpec> {
        (**self).model_spec(model)
    }
    fn prepare(&mut self, model: &str) -> Result<()> {
        (**self).prepare(model)
    }
    fn register_model(&mut self, model: &str, spec: ModelSpec) -> bool {
        (**self).register_model(model, spec)
    }
    fn train_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &Batch,
    ) -> Result<f32> {
        (**self).train_step(model, state, lr, batch)
    }
    fn forward(&mut self, model: &str, weights: &[Tensor], batch: &Batch) -> Result<Tensor> {
        (**self).forward(model, weights, batch)
    }
    fn vrgcn_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        (**self).vrgcn_step(model, state, lr, batch)
    }
    fn batches_per_step(&self) -> usize {
        (**self).batches_per_step()
    }
    fn epoch_begin(&mut self) {
        (**self).epoch_begin()
    }
    fn prefetchable(&self) -> bool {
        (**self).prefetchable()
    }
    fn step_from(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        source: &mut dyn BatchSource,
        first: usize,
        scratch: &mut Batch,
    ) -> Result<StepOutcome> {
        (**self).step_from(model, state, lr, source, first, scratch)
    }
    fn grad_step(
        &mut self,
        model: &str,
        weights: &[Tensor],
        batch: &Batch,
        grads: &mut Vec<Vec<f32>>,
    ) -> Result<f32> {
        (**self).grad_step(model, weights, batch, grads)
    }
    fn apply_grads(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        grads: &[Vec<f32>],
    ) -> Result<()> {
        (**self).apply_grads(model, state, lr, grads)
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn model_spec(&mut self, model: &str) -> Result<ModelSpec> {
        Ok(ModelSpec::from(&self.meta(model)?))
    }

    fn prepare(&mut self, model: &str) -> Result<()> {
        self.ensure_compiled(model)
    }

    fn train_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &Batch,
    ) -> Result<f32> {
        state.step += 1;
        let l = state.weights.len();
        let step_t = Tensor::scalar(state.step as f32);
        let lr_t = Tensor::scalar(lr);
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(3 * l + 6);
        inputs.extend(state.weights.iter());
        inputs.extend(state.m.iter());
        inputs.extend(state.v.iter());
        inputs.push(&step_t);
        inputs.push(&lr_t);
        inputs.push(&batch.a);
        inputs.push(&batch.x);
        inputs.push(&batch.y);
        inputs.push(&batch.mask);

        let mut out = self.run_refs(model, &inputs)?;
        let loss = out
            .pop()
            .ok_or_else(|| anyhow!("empty output"))?
            .data
            .first()
            .copied()
            .ok_or_else(|| anyhow!("empty loss"))?;
        if !loss.is_finite() {
            return Err(anyhow!("non-finite loss at step {}", state.step));
        }
        let vs: Vec<Tensor> = out.split_off(2 * l);
        let ms: Vec<Tensor> = out.split_off(l);
        state.weights = out;
        state.m = ms;
        state.v = vs;
        Ok(loss)
    }

    fn forward(&mut self, model: &str, weights: &[Tensor], batch: &Batch) -> Result<Tensor> {
        let meta = self.meta(model)?;
        if meta.kind != Kind::Forward {
            return Err(anyhow!("artifact {model} is not forward-kind"));
        }
        let mut inputs: Vec<&Tensor> = weights.iter().collect();
        inputs.push(&batch.a);
        inputs.push(&batch.x);
        let mut out = self.run_refs(model, &inputs)?;
        out.pop().ok_or_else(|| anyhow!("forward artifact returned no output"))
    }

    fn vrgcn_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        let meta = self.meta(model)?;
        if meta.kind != Kind::Vrgcn {
            return Err(anyhow!("artifact {model} is not vrgcn-kind"));
        }
        let l = meta.layers;
        state.step += 1;
        let step_t = Tensor::scalar(state.step as f32);
        let lr_t = Tensor::scalar(lr);
        // the AOT executable takes a dense (b_max, b_max) block; realize
        // the carried CSR on demand (bit-identical entries)
        let a_dense = batch.a_in.to_dense(batch.x.dims[0]);
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(3 * l + 2 + 1 + l + 3);
        inputs.extend(state.weights.iter());
        inputs.extend(state.m.iter());
        inputs.extend(state.v.iter());
        inputs.push(&step_t);
        inputs.push(&lr_t);
        inputs.push(&a_dense);
        inputs.extend(batch.hcs.iter());
        inputs.push(&batch.x);
        inputs.push(&batch.y);
        inputs.push(&batch.mask);

        let mut out = self.run_refs(model, &inputs)?;
        // outputs: W, m, v (3L), loss, hiddens (L-1)
        let hiddens: Vec<Tensor> = out.split_off(3 * l + 1);
        let loss = out
            .pop()
            .ok_or_else(|| anyhow!("empty output"))?
            .data
            .first()
            .copied()
            .ok_or_else(|| anyhow!("empty loss"))?;
        if !loss.is_finite() {
            return Err(anyhow!("vrgcn non-finite loss at step {}", state.step));
        }
        let vs = out.split_off(2 * l);
        let ms = out.split_off(l);
        state.weights = out;
        state.m = ms;
        state.v = vs;
        Ok((loss, hiddens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcn_spec_shapes() {
        let s = ModelSpec::gcn(Task::Multiclass, 3, 8, 16, 4, 128);
        assert_eq!(s.weight_shapes, vec![(8, 16), (16, 16), (16, 4)]);
        assert_eq!(s.layer_in_dims(), vec![8, 16, 16]);
        assert_eq!(s.param_elements(), 8 * 16 + 16 * 16 + 16 * 4);
        assert!(!s.residual);
        assert!(s.with_residual().residual);
    }

    #[test]
    fn single_layer_spec() {
        let s = ModelSpec::gcn(Task::Multilabel, 1, 6, 99, 3, 32);
        assert_eq!(s.weight_shapes, vec![(6, 3)]);
    }

    #[test]
    fn spec_from_meta_roundtrips_shapes() {
        let meta = ArtifactMeta {
            name: "x".into(),
            file: "/dev/null".into(),
            kind: Kind::Train,
            task: Task::Multiclass,
            layers: 2,
            f_in: 8,
            f_hid: 16,
            classes: 4,
            b_max: 128,
            residual: true,
            weight_shapes: vec![(8, 16), (16, 4)],
            vmem_bytes_est: 0,
            mxu_utilization_est: 0.0,
        };
        let spec = ModelSpec::from(&meta);
        assert_eq!(spec, ModelSpec::gcn(Task::Multiclass, 2, 8, 16, 4, 128).with_residual());
    }
}
