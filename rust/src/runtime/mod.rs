//! Execution runtime: the [`Backend`] abstraction plus its two
//! implementations — the PJRT [`Engine`] (loads the HLO text artifacts
//! produced once by `python/compile/aot.py` and runs them on the PJRT
//! CPU client; python is never on the training path) and the
//! artifact-free [`HostBackend`] (forward on the tiled SpMM·GEMM
//! kernels, gradients + Adam on the pooled [`backward`] engine).

pub mod artifacts;
pub mod backend;
pub mod backward;
pub mod exec;
pub mod host;

pub use artifacts::{ArtifactMeta, Kind, ManifestMissing, Registry};
pub use backend::{Backend, ModelSpec, VrgcnBatch};
pub use backward::BackwardWorkspace;
pub use exec::{Engine, Tensor};
pub use host::HostBackend;
