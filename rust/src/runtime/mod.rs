//! PJRT runtime: artifact registry + execution engine.  Loads the HLO
//! text artifacts produced once by `python/compile/aot.py` and runs them
//! on the PJRT CPU client — python is never on the training path.

pub mod artifacts;
pub mod exec;

pub use artifacts::{ArtifactMeta, Kind, Registry};
pub use exec::{Engine, Tensor};
