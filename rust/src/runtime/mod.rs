//! Execution runtime: the [`Backend`] abstraction, its two base
//! implementations — the PJRT [`Engine`] (loads the HLO text artifacts
//! produced once by `python/compile/aot.py` and runs them on the PJRT
//! CPU client; python is never on the training path) and the
//! artifact-free [`HostBackend`] (forward on the tiled SpMM·GEMM
//! kernels, gradients + Adam on the pooled [`backward`] engine) — and
//! the composable combinators layered on top: [`ShardedBackend`]
//! (data-parallel gradient averaging across replicas),
//! [`PrefetchBackend`] (batch assembly double-buffered against
//! execution), and [`DistributedBackend`] (cross-process gradient
//! exchange with spawned workers over UNIX/TCP sockets).

pub mod artifacts;
pub mod backend;
pub mod backward;
pub mod distributed;
pub mod exec;
pub mod host;
pub mod prefetch;
pub mod sharded;

pub use artifacts::{ArtifactMeta, Kind, ManifestMissing, Registry};
pub use backend::{Backend, ModelSpec, StepOutcome, VrgcnAdj, VrgcnBatch};
pub use backward::BackwardWorkspace;
pub use distributed::{Compression, DistConfig, DistStats, DistributedBackend, Transport};
pub use exec::{Engine, Tensor};
pub use host::HostBackend;
pub use prefetch::PrefetchBackend;
pub use sharded::ShardedBackend;
