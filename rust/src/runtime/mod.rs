//! Execution runtime: the [`Backend`] abstraction plus its two
//! implementations — the PJRT [`Engine`] (loads the HLO text artifacts
//! produced once by `python/compile/aot.py` and runs them on the PJRT
//! CPU client; python is never on the training path) and the
//! artifact-free [`HostBackend`] (the full pipeline on the host
//! kernels).

pub mod artifacts;
pub mod backend;
pub mod exec;
pub mod host;

pub use artifacts::{ArtifactMeta, Kind, ManifestMissing, Registry};
pub use backend::{Backend, ModelSpec, VrgcnBatch};
pub use exec::{Engine, Tensor};
pub use host::HostBackend;
