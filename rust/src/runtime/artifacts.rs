//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and exposes typed metadata for every AOT
//! executable.  The argument-order convention is documented in
//! `python/compile/model.py` and mirrored by `runtime::exec`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::Task;
use crate::util::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Train,
    Forward,
    Vrgcn,
}

/// Typed error for "the artifact directory has no manifest at all" —
/// as opposed to a malformed manifest or a missing entry.  Callers
/// (the CLI in particular) downcast to this to suggest the
/// artifact-free `--backend host` path instead of dumping a raw IO
/// error.
#[derive(Clone, Debug)]
pub struct ManifestMissing {
    /// Directory that was searched for `manifest.json`.
    pub dir: PathBuf,
}

impl std::fmt::Display for ManifestMissing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "no artifact manifest at {} (expected manifest.json; run `make artifacts`)",
            self.dir.display()
        )
    }
}

impl std::error::Error for ManifestMissing {}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub kind: Kind,
    pub task: Task,
    pub layers: usize,
    pub f_in: usize,
    pub f_hid: usize,
    pub classes: usize,
    pub b_max: usize,
    pub residual: bool,
    /// (f_in, f_out) per layer.
    pub weight_shapes: Vec<(usize, usize)>,
    /// kernel feasibility estimates exported by the AOT step.
    pub vmem_bytes_est: usize,
    pub mxu_utilization_est: f64,
}

impl ArtifactMeta {
    /// Per-layer activation input dims (VR-GCN Hc shapes).
    pub fn layer_in_dims(&self) -> Vec<usize> {
        self.weight_shapes.iter().map(|&(fi, _)| fi).collect()
    }

    /// Total parameter element count (one weight set; Adam state is 2x).
    pub fn param_elements(&self) -> usize {
        self.weight_shapes.iter().map(|&(a, b)| a * b).sum()
    }

    /// Number of expected inputs in order (see model.py docstring).
    pub fn input_count(&self) -> usize {
        let l = self.layers;
        match self.kind {
            Kind::Train => 3 * l + 2 + 4,
            Kind::Forward => l + 2,
            Kind::Vrgcn => 3 * l + 2 + 1 + l + 3,
        }
    }

    /// Number of outputs in the result tuple.
    pub fn output_count(&self) -> usize {
        let l = self.layers;
        match self.kind {
            Kind::Train => 3 * l + 1,
            Kind::Forward => 1,
            Kind::Vrgcn => 3 * l + 1 + (l - 1),
        }
    }
}

#[derive(Debug)]
pub struct Registry {
    pub dir: PathBuf,
    by_name: BTreeMap<String, ArtifactMeta>,
}

impl Registry {
    pub fn load(dir: &Path) -> Result<Registry> {
        let man_path = dir.join("manifest.json");
        if !man_path.is_file() {
            return Err(anyhow::Error::new(ManifestMissing { dir: dir.to_path_buf() }));
        }
        let text = std::fs::read_to_string(&man_path).with_context(|| {
            format!(
                "reading {man_path:?} — run `make artifacts` first"
            )
        })?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut by_name = BTreeMap::new();
        for a in arts {
            let get_str = |k: &str| -> Result<&str> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("artifact missing str {k}"))
            };
            let get_n = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("artifact missing num {k}"))
            };
            let kind = match get_str("kind")? {
                "train" => Kind::Train,
                "forward" => Kind::Forward,
                "vrgcn" => Kind::Vrgcn,
                other => bail!("unknown kind {other}"),
            };
            let task = match get_str("task")? {
                "multiclass" => Task::Multiclass,
                "multilabel" => Task::Multilabel,
                other => bail!("unknown task {other}"),
            };
            let weight_shapes = a
                .get("weight_shapes")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("missing weight_shapes"))?
                .iter()
                .map(|p| {
                    let p = p.as_arr().ok_or_else(|| anyhow!("bad shape"))?;
                    Ok((
                        p[0].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
                        p[1].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            let meta = ArtifactMeta {
                name: get_str("name")?.to_string(),
                file: dir.join(get_str("file")?),
                kind,
                task,
                layers: get_n("layers")?,
                f_in: get_n("f_in")?,
                f_hid: get_n("f_hid")?,
                classes: get_n("classes")?,
                b_max: get_n("b_max")?,
                residual: a
                    .get("residual")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false),
                weight_shapes,
                vmem_bytes_est: get_n("vmem_bytes_est").unwrap_or(0),
                mxu_utilization_est: a
                    .get("mxu_utilization_est")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0),
            };
            by_name.insert(meta.name.clone(), meta);
        }
        Ok(Registry { dir: dir.to_path_buf(), by_name })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.by_name.get(name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not in manifest ({} known); \
                 re-run `make artifacts`?",
                self.by_name.len()
            )
        })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"name":"t_L2","file":"t_L2.hlo.txt","kind":"train",
                "task":"multiclass","layers":2,"f_in":8,"f_hid":16,"classes":4,
                "b_max":128,"residual":false,
                "weight_shapes":[[8,16],[16,4]],
                "vmem_bytes_est":1000,"mxu_utilization_est":0.9}]}"#,
        )
        .unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cgcn_reg_{}_{}", std::process::id(), tag));
        p
    }

    #[test]
    fn parses_manifest() {
        let dir = tmpdir("ok");
        write_manifest(&dir);
        let reg = Registry::load(&dir).unwrap();
        let m = reg.get("t_L2").unwrap();
        assert_eq!(m.layers, 2);
        assert_eq!(m.kind, Kind::Train);
        assert_eq!(m.weight_shapes, vec![(8, 16), (16, 4)]);
        assert_eq!(m.param_elements(), 8 * 16 + 16 * 4);
        // train: 3L weights/adam + step + lr + A,X,Y,mask
        assert_eq!(m.input_count(), 6 + 2 + 4);
        assert_eq!(m.output_count(), 7);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifact_errors() {
        let dir = tmpdir("miss");
        write_manifest(&dir);
        let reg = Registry::load(&dir).unwrap();
        assert!(reg.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = tmpdir("nodir2");
        std::fs::remove_dir_all(&dir).ok();
        assert!(Registry::load(&dir).is_err());
    }

    /// The "no manifest at all" case is a typed, downcastable error —
    /// the CLI keys its `--backend host` suggestion off it.
    #[test]
    fn missing_manifest_is_downcastable() {
        let dir = tmpdir("nodir3");
        std::fs::remove_dir_all(&dir).ok();
        let err = Registry::load(&dir).unwrap_err();
        let mm = err
            .downcast_ref::<ManifestMissing>()
            .expect("should be ManifestMissing");
        assert_eq!(mm.dir, dir);
        assert!(mm.to_string().contains("manifest.json"));

        // a *malformed* manifest is NOT ManifestMissing
        let dir2 = tmpdir("badjson");
        std::fs::create_dir_all(&dir2).unwrap();
        std::fs::write(dir2.join("manifest.json"), "{not json").unwrap();
        let err2 = Registry::load(&dir2).unwrap_err();
        assert!(err2.downcast_ref::<ManifestMissing>().is_none());
        std::fs::remove_dir_all(&dir2).ok();
    }
}
