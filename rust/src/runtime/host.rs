//! [`HostBackend`]: the artifact-free execution backend.  The full
//! training pipeline — forward, masked loss, backward, Adam — runs on
//! the host, built from the same tiled SpMM·GEMM kernels the exact
//! evaluator uses (`coordinator::inference`) plus the pooled backward
//! engine (`runtime::backward`), so `cluster-gcn train --backend host`
//! works with no `artifacts/` directory and no python step at all.
//!
//! Batches are consumed **sparse-natively**: every assembled
//! [`Batch`] carries a CSR [`crate::coordinator::batch::SparseBlock`]
//! view of its normalized adjacency block (bit-identical to the dense
//! tensor the PJRT path feeds its executables), so neither `train_step`
//! nor `forward` ever re-derives the block from the dense `b_max²`
//! tensor.  All per-step scratch — per-layer `P_l`/`Z_l` stores, the
//! `dz`/`mbuf`/`dh` buffers, the flat gradient arena, and the `Âᵀ`
//! transpose — lives in one reusable
//! [`crate::runtime::backward::BackwardWorkspace`]; steady-state
//! training allocates nothing on the backward path.
//!
//! Parity contract: [`HostBackend::forward`] over a full-graph batch
//! (all nodes in natural order) is **bit-identical** to
//! [`crate::coordinator::inference::full_forward_cached`] at every pool
//! width — the batch renormalization computes the same f32 values as
//! `normalize_sparse`, the carried CSR block reproduces the dense
//! entries bit for bit, and the layer loop mirrors the evaluator's
//! ping-pong exactly.  The property suite pins this.
//!
//! The backward pass is the standard GCN chain: with `P_l = Â·H_l`,
//! `Z_l = P_l·W_l`, `H_{l+1} = relu(Z_l) (+ H_l)`,
//!
//! ```text
//!   dW_l = P_l^T · dZ_l
//!   dH_l = Â^T · (dZ_l · W_l^T)  (+ dH_{l+1} through the residual)
//! ```
//!
//! executed on the pooled kernels (`gemm_at_b_pooled`, `AdjT` gather,
//! `gemm_a_bt_pooled`), with the Adam update batched across layers into
//! one pooled pass over the flat arena — β1 = 0.9, β2 = 0.999,
//! ε = 1e-8, bias-corrected, matching `python/compile/model.py`.  The
//! pre-engine scalar backward survives verbatim as
//! [`host_grads_scalar`]: the parity oracle for the pooled engine and
//! the baseline the backward benches measure speedup against.  Unit
//! tests check every analytic gradient (cluster and VR-GCN paths)
//! against central finite differences.
#![deny(missing_docs)]

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::coordinator::batch::Batch;
use crate::coordinator::inference::{propagate_raw_into, spmm_layer_raw_into};
use crate::coordinator::trainer::TrainState;
use crate::graph::Task;
use crate::runtime::backend::{Backend, ModelSpec, VrgcnBatch};
use crate::runtime::backward::{
    adam_update_pooled, dz_col_block_mask, gemm, gemm_a_bt, gemm_a_bt_pooled, gemm_at_b,
    gemm_at_b_masked_pooled, gemm_at_b_pooled, gemm_pooled, scatter_adj_t, AdjT,
    BackwardWorkspace,
};
use crate::runtime::exec::Tensor;
use crate::util::pool::{self, default_threads};
use crate::util::simd::axpy;

/// Pure-host execution backend over registered [`ModelSpec`]s.
///
/// Models are declared with [`Backend::register_model`] (the
/// [`crate::session::Session`] does this automatically); there is no
/// artifact directory, manifest, or compile step.
pub struct HostBackend {
    models: BTreeMap<String, ModelSpec>,
    threads: usize,
    ws: BackwardWorkspace,
}

impl Default for HostBackend {
    fn default() -> HostBackend {
        HostBackend::new()
    }
}

impl HostBackend {
    /// Backend over the default pool width.
    pub fn new() -> HostBackend {
        HostBackend::with_threads(default_threads())
    }

    /// Backend with an explicit kernel thread cap (results are
    /// bit-identical at every width; see `coordinator::inference` and
    /// `runtime::backward`).
    pub fn with_threads(threads: usize) -> HostBackend {
        HostBackend {
            models: BTreeMap::new(),
            threads: threads.max(1),
            ws: BackwardWorkspace::new(),
        }
    }

    /// Registered model ids, in sorted order.
    pub fn models(&self) -> impl Iterator<Item = &str> {
        self.models.keys().map(|s| s.as_str())
    }

    fn spec(&self, model: &str) -> Result<&ModelSpec> {
        self.models.get(model).ok_or_else(|| {
            anyhow!(
                "model '{model}' not registered with the host backend \
                 ({} known)",
                self.models.len()
            )
        })
    }

    /// Loss + per-layer weight gradients over `batch` on the pooled
    /// backward engine — the diagnostics entry behind the
    /// finite-difference and parity suites.  Training itself keeps
    /// gradients in the flat workspace arena and never materializes
    /// these per-layer `Vec`s.
    pub fn loss_and_grads(
        &mut self,
        model: &str,
        weights: &[Tensor],
        batch: &Batch,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let spec = self.spec(model)?.clone();
        let loss = host_grads_pooled(&spec, weights, batch, self.threads, &mut self.ws)?;
        let grads = self.ws.grad_layers().iter().map(|s| s.to_vec()).collect();
        Ok((loss, grads))
    }

    /// Loss, hidden activations, and per-layer weight gradients of one
    /// VR-GCN step on the sparse-native path **without** touching
    /// optimizer state — the diagnostics entry the sparse-vs-dense
    /// parity and finite-difference suites compare against
    /// [`vrgcn_grads_dense`].
    pub fn vrgcn_loss_and_grads(
        &mut self,
        model: &str,
        weights: &[Tensor],
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>, Vec<Vec<f32>>)> {
        let spec = self.spec(model)?.clone();
        let (loss, hiddens) = vrgcn_grads(&spec, weights, batch, self.threads, &mut self.ws)?;
        let grads = self.ws.grad_layers().iter().map(|s| s.to_vec()).collect();
        Ok((loss, hiddens, grads))
    }
}

/// Sparse view of one dense batch block (oracle-side only): CSR
/// structure + normalized values + per-node self-loop, shaped exactly
/// like the full-graph normalization.  The production path consumes the
/// assembler-built `SparseBlock` instead; this re-extraction survives
/// for [`host_grads_scalar`], which deliberately derives its block from
/// the dense tensor so it stays independent of the sparse-native path
/// it oracles.
struct BlockAdj {
    offsets: Vec<usize>,
    cols: Vec<u32>,
    vals: Vec<f32>,
    self_loop: Vec<f32>,
}

/// Re-extract the `n_real × n_real` prefix of the dense batch block
/// into CSR form.  Normalized entries are strictly positive, so exact
/// zeros are structural (absent edges) and can be dropped.
fn extract_block(a: &Tensor, n: usize) -> BlockAdj {
    let b = a.dims[0];
    debug_assert!(n <= b);
    let mut offsets = vec![0usize; n + 1];
    let mut cols: Vec<u32> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut self_loop = vec![0f32; n];
    for u in 0..n {
        let row = &a.data[u * b..u * b + n];
        for (v, &av) in row.iter().enumerate() {
            if v == u {
                self_loop[u] = av;
            } else if av != 0.0 {
                cols.push(v as u32);
                vals.push(av);
            }
        }
        offsets[u + 1] = cols.len();
    }
    BlockAdj { offsets, cols, vals, self_loop }
}

/// Sparse row extraction of the `n × n` prefix of a padded dense block
/// (row stride `b`), diagonal **inline** — the VR-GCN `A_in` layout.
/// Oracle-side only since the batch carries its CSR natively: the
/// production step never densifies, and this re-extraction survives for
/// [`vrgcn_grads_dense`], which deliberately derives its view from the
/// dense realization so it stays independent of the sparse-native path
/// it checks.
fn extract_dense_rows(
    a: &[f32],
    n: usize,
    b: usize,
    offsets: &mut Vec<usize>,
    cols: &mut Vec<u32>,
    vals: &mut Vec<f32>,
) {
    offsets.clear();
    offsets.resize(n + 1, 0);
    cols.clear();
    vals.clear();
    for u in 0..n {
        let row = &a[u * b..u * b + n];
        for (v, &av) in row.iter().enumerate() {
            if av != 0.0 {
                cols.push(v as u32);
                vals.push(av);
            }
        }
        offsets[u + 1] = cols.len();
    }
}

/// Masked mean loss (eq. (2)/(7), matching `model.masked_loss`) and its
/// gradient w.r.t. the logits, written into `dz[..n * classes]` (zeroed
/// first, so masked-out rows contribute nothing).  Rows `0..n`,
/// mask/label rows taken from the padded batch tensors.
#[allow(clippy::too_many_arguments)]
fn loss_and_dlogits_into(
    task: Task,
    logits: &[f32],
    y: &[f32],
    mask: &[f32],
    n: usize,
    classes: usize,
    dz: &mut [f32],
) -> f32 {
    let c = classes;
    let msum: f32 = mask[..n].iter().sum();
    let denom = msum.max(1.0);
    dz[..n * c].fill(0.0);
    let mut loss = 0f32;
    match task {
        Task::Multiclass => {
            for i in 0..n {
                let mi = mask[i];
                if mi == 0.0 {
                    continue;
                }
                let row = &logits[i * c..(i + 1) * c];
                let yrow = &y[i * c..(i + 1) * c];
                let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let mut se = 0f32;
                for &v in row {
                    se += (v - mx).exp();
                }
                let lse = se.ln();
                let sum_y: f32 = yrow.iter().sum();
                let mut per = 0f32;
                for j in 0..c {
                    per -= yrow[j] * (row[j] - mx - lse);
                    let p = (row[j] - mx).exp() / se;
                    dz[i * c + j] = mi / denom * (p * sum_y - yrow[j]);
                }
                loss += per * mi;
            }
        }
        Task::Multilabel => {
            let scale = 1.0 / c as f32;
            for i in 0..n {
                let mi = mask[i];
                if mi == 0.0 {
                    continue;
                }
                let row = &logits[i * c..(i + 1) * c];
                let yrow = &y[i * c..(i + 1) * c];
                let mut per = 0f32;
                for j in 0..c {
                    let zv = row[j];
                    let yv = yrow[j];
                    per += zv.max(0.0) - zv * yv + (-zv.abs()).exp().ln_1p();
                    let sig = 1.0 / (1.0 + (-zv).exp());
                    dz[i * c + j] = mi * scale / denom * (sig - yv);
                }
                loss += per * scale * mi;
            }
        }
    }
    loss / denom
}

/// Pooled forward + backward over the batch's carried sparse block:
/// loss returned, per-layer weight gradients left in the workspace's
/// flat arena (`ws.spans` indexes them).  Zero steady-state
/// allocation; every kernel is deterministic and width-independent.
fn host_grads_pooled(
    spec: &ModelSpec,
    weights: &[Tensor],
    batch: &Batch,
    threads: usize,
    ws: &mut BackwardWorkspace,
) -> Result<f32> {
    let n = batch.n_real;
    if n == 0 {
        return Err(anyhow!("empty batch (n_real = 0)"));
    }
    let blk = &batch.block;
    if blk.n() != n {
        return Err(anyhow!(
            "batch carries no sparse block for its {n} rows \
             (assemble it through BatchAssembler)"
        ));
    }
    let l = weights.len();
    ws.prepare(weights, n);

    // ---- forward + loss, overlapped with the Âᵀ transpose build -----
    // The backward needs `ws.adj_t` only when l > 1, and its serial
    // counting-sort build was the last single-thread seam in the step:
    // run it on `pipeline`'s producer thread while the pooled forward
    // dispatches from this thread.  The build output is a pure function
    // of the block (no shared float state with the forward), so the
    // overlap cannot change any bit of the step — pinned by
    // `overlapped_step_matches_serial_bitwise`.
    let loss = if l > 1 {
        let adj_t = std::mem::take(&mut ws.adj_t);
        let mut loss = None;
        let (spare, built) = pool::pipeline(
            2,
            AdjT::new(),
            adj_t,
            |i, buf: &mut AdjT| {
                // item 0 is a no-op spare so the build (item 1) runs
                // concurrently with consume(0) = the forward below.
                if i == 1 {
                    buf.build(&blk.offsets, &blk.cols, &blk.vals, &blk.self_loop);
                }
            },
            |i, _| {
                if i == 0 {
                    loss = Some(forward_and_loss(spec, weights, batch, threads, ws));
                }
                true
            },
        );
        drop(spare); // empty AdjT — no allocation to keep
        ws.adj_t = built;
        loss.expect("pipeline consumed item 0")
    } else {
        forward_and_loss(spec, weights, batch, threads, ws)
    };

    // ---- backward sweep on the pooled engine ------------------------
    backward_sweep(weights, n, spec.residual, threads, ws);
    Ok(loss)
}

/// The forward pass (storing `P_l` and `Z_l` for the backward) plus the
/// masked loss + `dL/dlogits` into the `dh` ping buffer.  Split out of
/// [`host_grads_pooled`] so it can run as the consumer half of the
/// transpose-build overlap; `ws.adj_t` is never touched here.
fn forward_and_loss(
    spec: &ModelSpec,
    weights: &[Tensor],
    batch: &Batch,
    threads: usize,
    ws: &mut BackwardWorkspace,
) -> f32 {
    let n = batch.n_real;
    let blk = &batch.block;
    let l = weights.len();
    ws.cur[..n * spec.f_in].copy_from_slice(&batch.x.data[..n * spec.f_in]);
    let mut f = spec.f_in;
    for (li, w) in weights.iter().enumerate() {
        debug_assert_eq!(w.dims[0], f, "weight in-dim mismatch at layer {li}");
        let g_dim = w.dims[1];
        let last = li == l - 1;
        propagate_raw_into(
            &blk.offsets,
            &blk.cols,
            &blk.vals,
            &blk.self_loop,
            &ws.cur[..n * f],
            f,
            threads,
            &mut ws.ps[li][..n * f],
        );
        gemm_pooled(
            &ws.ps[li][..n * f],
            n,
            f,
            &w.data,
            g_dim,
            threads,
            &mut ws.zs[li][..n * g_dim],
        );
        let residual_from = if spec.residual { Some(f) } else { None };
        activate_layer(ws, li, n, g_dim, last, residual_from);
        f = g_dim;
    }

    let logits = &ws.zs[l - 1];
    loss_and_dlogits_into(
        spec.task,
        &logits[..n * spec.classes],
        &batch.y.data,
        &batch.mask.data,
        n,
        spec.classes,
        &mut ws.dh,
    )
}

/// The layer activation shared by both forward paths: `nxt =
/// relu(Z_li)` (plain copy on the last layer), optional residual add
/// from the incoming hidden when the widths match
/// (`residual_from = Some(f_in_of_layer)`), then the `cur`/`nxt`
/// ping-pong swap — after the call `ws.cur` holds `H_{li+1}`.  One
/// definition, so the cluster and VR-GCN forwards cannot drift.
fn activate_layer(
    ws: &mut BackwardWorkspace,
    li: usize,
    n: usize,
    g_dim: usize,
    last: bool,
    residual_from: Option<usize>,
) {
    {
        let z = &ws.zs[li];
        let nxt = &mut ws.nxt;
        if last {
            nxt[..n * g_dim].copy_from_slice(&z[..n * g_dim]);
        } else {
            for i in 0..n * g_dim {
                nxt[i] = z[i].max(0.0);
            }
        }
        if let Some(f) = residual_from {
            if !last && g_dim == f {
                let cur = &ws.cur;
                for i in 0..n * f {
                    nxt[i] += cur[i];
                }
            }
        }
    }
    std::mem::swap(&mut ws.cur, &mut ws.nxt);
}

/// The shared backward sweep (cluster and VR-GCN paths): consumes
/// `ws.dh` (dL/dlogits), the forward's `ws.ps`/`ws.zs`, and `ws.adj_t`
/// (built by the caller when `l > 1`); leaves layer `li`'s `dW` at
/// `ws.spans[li]` in the flat arena.  On relu layers the `dW`
/// contraction is **sparse-aware**: `dz` column blocks the relu killed
/// across the whole batch are masked out of the kernel entirely
/// (bit-identical to the unmasked run — see
/// [`crate::runtime::backward::gemm_at_b_masked_pooled`]).
fn backward_sweep(
    weights: &[Tensor],
    n: usize,
    residual: bool,
    threads: usize,
    ws: &mut BackwardWorkspace,
) {
    let l = weights.len();
    for li in (0..l).rev() {
        let w = &weights[li];
        let (fi, go) = (w.dims[0], w.dims[1]);
        let last = li == l - 1;
        // dz = dh ⊙ σ'(z); the last layer has no activation.
        {
            let dz = &mut ws.dz;
            if last {
                dz[..n * go].copy_from_slice(&ws.dh[..n * go]);
            } else {
                let z = &ws.zs[li];
                let dh = &ws.dh;
                for i in 0..n * go {
                    dz[i] = if z[i] > 0.0 { dh[i] } else { 0.0 };
                }
            }
        }
        let (off, len) = ws.spans[li];
        let skipped = if last {
            0
        } else {
            dz_col_block_mask(&ws.dz[..n * go], n, go, &mut ws.col_mask).1
        };
        if skipped > 0 {
            gemm_at_b_masked_pooled(
                &ws.ps[li][..n * fi],
                &ws.dz[..n * go],
                n,
                fi,
                go,
                &ws.col_mask,
                threads,
                &mut ws.grads[off..off + len],
            );
        } else {
            gemm_at_b_pooled(
                &ws.ps[li][..n * fi],
                &ws.dz[..n * go],
                n,
                fi,
                go,
                threads,
                &mut ws.grads[off..off + len],
            );
        }
        if li > 0 {
            gemm_a_bt_pooled(
                &ws.dz[..n * go],
                &w.data,
                n,
                go,
                fi,
                threads,
                &mut ws.mbuf[..n * fi],
            );
            ws.adj_t.gather_into_pooled(&ws.mbuf[..n * fi], fi, threads, &mut ws.dh_new[..n * fi]);
            if residual && !last && go == fi {
                let dh = &ws.dh;
                let dh_new = &mut ws.dh_new;
                for i in 0..n * fi {
                    dh_new[i] += dh[i];
                }
            }
            std::mem::swap(&mut ws.dh, &mut ws.dh_new);
        }
    }
}

/// The **pre-engine** scalar backward, kept verbatim: derives its block
/// from the dense batch tensor via `extract_block` (so it stays
/// independent of the sparse-native path), runs the forward on the
/// pooled propagate + scalar GEMM it always used, and the backward on
/// the scalar `gemm_at_b`/`gemm_a_bt`/`scatter_adj_t` oracles.  Serves
/// as the parity oracle for the pooled engine in the property suite and
/// as the baseline the backward benches measure speedup against.
pub fn host_grads_scalar(
    spec: &ModelSpec,
    weights: &[Tensor],
    batch: &Batch,
    threads: usize,
) -> Result<(f32, Vec<Vec<f32>>)> {
    let n = batch.n_real;
    if n == 0 {
        return Err(anyhow!("empty batch (n_real = 0)"));
    }
    let l = weights.len();
    let blk = extract_block(&batch.a, n);
    let (ps, zs) =
        forward_store_scalar(&blk, weights, &batch.x.data, spec.f_in, spec.residual, threads);
    let logits = &zs[l - 1];
    let mut dlogits = vec![0f32; n * spec.classes];
    let loss = loss_and_dlogits_into(
        spec.task,
        logits,
        &batch.y.data,
        &batch.mask.data,
        n,
        spec.classes,
        &mut dlogits,
    );

    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); l];
    // dh = dL/dH_{li+1} while processing layer li (top-down).
    let mut dh = dlogits;
    for li in (0..l).rev() {
        let w = &weights[li];
        let (fi, go) = (w.dims[0], w.dims[1]);
        let last = li == l - 1;
        let dz: Vec<f32> = if last {
            dh.clone()
        } else {
            dh.iter()
                .zip(&zs[li])
                .map(|(&d, &zv)| if zv > 0.0 { d } else { 0.0 })
                .collect()
        };
        let mut gw = vec![0f32; fi * go];
        gemm_at_b(&ps[li], &dz, n, fi, go, &mut gw);
        if li > 0 {
            let mut mbuf = vec![0f32; n * fi];
            gemm_a_bt(&dz, &w.data, n, go, fi, &mut mbuf);
            let mut dh_new = vec![0f32; n * fi];
            scatter_adj_t(
                &blk.offsets,
                &blk.cols,
                &blk.vals,
                &blk.self_loop,
                &mbuf,
                fi,
                &mut dh_new,
            );
            if spec.residual && !last && go == fi {
                for (o, &d) in dh_new.iter_mut().zip(&dh) {
                    *o += d;
                }
            }
            dh = dh_new;
        }
        grads[li] = gw;
    }
    Ok((loss, grads))
}

/// Scalar-oracle forward over an extracted block, storing the per-layer
/// propagations `P_l` and pre-activations `Z_l`.  Returns `(ps, zs)`;
/// the logits are the last `zs` entry.
fn forward_store_scalar(
    blk: &BlockAdj,
    weights: &[Tensor],
    x: &[f32],
    f_in: usize,
    residual: bool,
    threads: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let n = blk.self_loop.len();
    let l = weights.len();
    let mut ps: Vec<Vec<f32>> = Vec::with_capacity(l);
    let mut zs: Vec<Vec<f32>> = Vec::with_capacity(l);
    let mut h: Vec<f32> = x[..n * f_in].to_vec();
    let mut f = f_in;
    for (li, w) in weights.iter().enumerate() {
        debug_assert_eq!(w.dims[0], f, "weight in-dim mismatch at layer {li}");
        let g_dim = w.dims[1];
        let last = li == l - 1;
        let mut p = vec![0f32; n * f];
        propagate_raw_into(
            &blk.offsets, &blk.cols, &blk.vals, &blk.self_loop, &h, f, threads, &mut p,
        );
        let mut z = vec![0f32; n * g_dim];
        gemm(&p, n, f, &w.data, g_dim, &mut z);
        let mut h_next: Vec<f32> = if last {
            z.clone()
        } else {
            z.iter().map(|&v| v.max(0.0)).collect()
        };
        if residual && !last && g_dim == f {
            for (hv, &prev) in h_next.iter_mut().zip(&h) {
                *hv += prev;
            }
        }
        ps.push(p);
        zs.push(z);
        h = h_next;
        f = g_dim;
    }
    (ps, zs)
}

/// Loss only — the finite-difference oracle for the gradient tests.
#[cfg(test)]
fn host_loss(spec: &ModelSpec, weights: &[Tensor], batch: &Batch, threads: usize) -> f32 {
    let n = batch.n_real;
    let blk = extract_block(&batch.a, n);
    let (_, zs) =
        forward_store_scalar(&blk, weights, &batch.x.data, spec.f_in, spec.residual, threads);
    let logits = zs.last().expect("at least one layer");
    let mut dz = vec![0f32; n * spec.classes];
    loss_and_dlogits_into(
        spec.task,
        logits,
        &batch.y.data,
        &batch.mask.data,
        n,
        spec.classes,
        &mut dz,
    )
}

/// Pooled VR-GCN forward + backward over an explicit CSR view of
/// `A_in` (diagonal inline): loss and the `L-1` hidden activations
/// returned, gradients left in the workspace arena.  Shared core of the
/// sparse-native production path ([`vrgcn_grads`], which passes the
/// batch's carried [`crate::runtime::VrgcnAdj`] buffers straight
/// through) and the retained dense parity oracle
/// ([`vrgcn_grads_dense`], which densifies and re-extracts first).
#[allow(clippy::too_many_arguments)]
fn vrgcn_grads_with(
    spec: &ModelSpec,
    weights: &[Tensor],
    batch: &VrgcnBatch,
    offs: &[usize],
    cls: &[u32],
    vls: &[f32],
    threads: usize,
    ws: &mut BackwardWorkspace,
) -> Result<(f32, Vec<Tensor>)> {
    let n = batch.n_real;
    if n == 0 {
        return Err(anyhow!("empty vrgcn batch (n_real = 0)"));
    }
    if offs.len() != n + 1 {
        return Err(anyhow!(
            "vrgcn batch carries a {}-row A_in for its {n} real rows",
            offs.len().saturating_sub(1)
        ));
    }
    let l = spec.layers;
    let b = batch.x.dims[0];
    let dims = spec.layer_in_dims();
    ws.prepare(weights, n);

    // ---- forward: P_l = A_in·H_l + Hc_l; Z_l = P_l·W_l --------------
    let mut hiddens: Vec<Tensor> = Vec::with_capacity(l.saturating_sub(1));
    ws.cur[..n * spec.f_in].copy_from_slice(&batch.x.data[..n * spec.f_in]);
    for li in 0..l {
        let f = dims[li];
        let w = &weights[li];
        let g_dim = w.dims[1];
        let last = li == l - 1;
        let hc = &batch.hcs[li].data;
        {
            let h = &ws.cur;
            let p = &mut ws.ps[li];
            let gather_row = |_ci: usize, rows: std::ops::Range<usize>, out_rows: &mut [f32]| {
                for (ri, i) in rows.clone().enumerate() {
                    let pr = &mut out_rows[ri * f..(ri + 1) * f];
                    pr.copy_from_slice(&hc[i * f..(i + 1) * f]);
                    let off = offs[i];
                    for (idx, &j) in cls[off..offs[i + 1]].iter().enumerate() {
                        let a = vls[off + idx];
                        let j = j as usize;
                        axpy(pr, &h[j * f..(j + 1) * f], a);
                    }
                }
            };
            pool::global().run_rows_with(n, threads.max(1), f, &mut p[..n * f], gather_row);
        }
        gemm_pooled(
            &ws.ps[li][..n * f],
            n,
            f,
            &w.data,
            g_dim,
            threads,
            &mut ws.zs[li][..n * g_dim],
        );
        activate_layer(ws, li, n, g_dim, last, None);
        if !last {
            // padded (b, f_hid) hidden for the history refresh — after
            // the activation swap, `ws.cur` holds H_{li+1}
            let mut hid = vec![0f32; b * g_dim];
            hid[..n * g_dim].copy_from_slice(&ws.cur[..n * g_dim]);
            hiddens.push(Tensor::new(vec![b, g_dim], hid));
        }
    }

    let loss = {
        let logits = &ws.zs[l - 1];
        loss_and_dlogits_into(
            spec.task,
            &logits[..n * spec.classes],
            &batch.y.data,
            &batch.mask.data,
            n,
            spec.classes,
            &mut ws.dh,
        )
    };

    // ---- backward on the shared sweep (A_inᵀ, diagonal inline) ------
    if l > 1 {
        ws.adj_t.build_inline(offs, cls, vls);
    }
    backward_sweep(weights, n, false, threads, ws);
    Ok((loss, hiddens))
}

/// The sparse-native VR-GCN step body: consumes the batch's carried
/// [`crate::runtime::VrgcnAdj`] directly — no dense `b_max²` block is
/// ever materialized on this path.
fn vrgcn_grads(
    spec: &ModelSpec,
    weights: &[Tensor],
    batch: &VrgcnBatch,
    threads: usize,
    ws: &mut BackwardWorkspace,
) -> Result<(f32, Vec<Tensor>)> {
    let adj = &batch.a_in;
    vrgcn_grads_with(spec, weights, batch, &adj.offsets, &adj.cols, &adj.vals, threads, ws)
}

/// The **dense parity oracle** for the sparse-native VR-GCN step: the
/// pre-PR-5 round trip, kept deliberately — realize the carried CSR as
/// the padded dense block ([`crate::runtime::VrgcnAdj::to_dense`]),
/// re-extract its rows into a fresh CSR (`extract_dense_rows`), and
/// run the same pooled core.  The extraction reproduces the carried
/// buffers entry for entry (ascending columns, non-zero values), so
/// loss, hidden activations, and gradients are **bit-identical** to the
/// sparse path — pinned by the unit and property suites.
pub fn vrgcn_grads_dense(
    spec: &ModelSpec,
    weights: &[Tensor],
    batch: &VrgcnBatch,
    threads: usize,
) -> Result<(f32, Vec<Tensor>, Vec<Vec<f32>>)> {
    let b = batch.x.dims[0];
    let dense = batch.a_in.to_dense(b);
    let mut offs = Vec::new();
    let mut cls = Vec::new();
    let mut vls = Vec::new();
    extract_dense_rows(&dense.data, batch.n_real, b, &mut offs, &mut cls, &mut vls);
    let mut ws = BackwardWorkspace::new();
    let (loss, hiddens) =
        vrgcn_grads_with(spec, weights, batch, &offs, &cls, &vls, threads, &mut ws)?;
    let grads = ws.grad_layers().iter().map(|s| s.to_vec()).collect();
    Ok((loss, hiddens, grads))
}

/// Loss only — the finite-difference oracle for the VR-GCN gradient
/// test: a straight scalar re-implementation over the **densified**
/// `A_in`, independent of the CSR walk and the pooled kernels.
#[cfg(test)]
fn vrgcn_loss(spec: &ModelSpec, weights: &[Tensor], batch: &VrgcnBatch) -> f32 {
    let n = batch.n_real;
    let l = spec.layers;
    let b = batch.x.dims[0];
    let a_dense = batch.a_in.to_dense(b);
    let dims = spec.layer_in_dims();
    let mut h: Vec<f32> = batch.x.data[..n * spec.f_in].to_vec();
    let mut logits: Vec<f32> = Vec::new();
    for li in 0..l {
        let f = dims[li];
        let w = &weights[li];
        let g_dim = w.dims[1];
        let last = li == l - 1;
        let hc = &batch.hcs[li].data;
        let mut p = vec![0f32; n * f];
        for i in 0..n {
            p[i * f..(i + 1) * f].copy_from_slice(&hc[i * f..(i + 1) * f]);
            let arow = &a_dense.data[i * b..i * b + n];
            for (j, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                for k in 0..f {
                    p[i * f + k] += a * h[j * f + k];
                }
            }
        }
        let mut z = vec![0f32; n * g_dim];
        gemm(&p, n, f, &w.data, g_dim, &mut z);
        h = if last {
            z.clone()
        } else {
            z.iter().map(|&v| v.max(0.0)).collect()
        };
        if last {
            logits = z;
        }
    }
    let mut dz = vec![0f32; n * spec.classes];
    loss_and_dlogits_into(
        spec.task,
        &logits,
        &batch.y.data,
        &batch.mask.data,
        n,
        spec.classes,
        &mut dz,
    )
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn model_spec(&mut self, model: &str) -> Result<ModelSpec> {
        Ok(self.spec(model)?.clone())
    }

    fn prepare(&mut self, model: &str) -> Result<()> {
        self.spec(model).map(|_| ())
    }

    fn register_model(&mut self, model: &str, spec: ModelSpec) -> bool {
        self.models.insert(model.to_string(), spec);
        true
    }

    fn train_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &Batch,
    ) -> Result<f32> {
        let spec = self.spec(model)?.clone();
        state.step += 1;
        let loss = host_grads_pooled(&spec, &state.weights, batch, self.threads, &mut self.ws)?;
        if !loss.is_finite() {
            return Err(anyhow!("non-finite loss at step {}", state.step));
        }
        adam_update_pooled(
            &mut state.weights,
            &mut state.m,
            &mut state.v,
            &self.ws.grads,
            &self.ws.spans,
            state.step as f32,
            lr,
            self.threads,
        );
        Ok(loss)
    }

    fn forward(&mut self, model: &str, weights: &[Tensor], batch: &Batch) -> Result<Tensor> {
        let spec = self.spec(model)?.clone();
        let b = batch.a.dims[0];
        let classes = spec.classes;
        let n = batch.n_real;
        let mut out = vec![0f32; b * classes];
        if n > 0 {
            let blk = &batch.block;
            if blk.n() != n {
                return Err(anyhow!(
                    "batch carries no sparse block for its {n} rows \
                     (assemble it through BatchAssembler)"
                ));
            }
            // Mirror `full_forward_cached` exactly: two max-width
            // ping-pong buffers, relu on every layer but the last —
            // this is what makes the full-graph batch bit-identical to
            // the exact evaluator.
            let max_w = weights
                .iter()
                .map(|w| w.dims[1])
                .chain([spec.f_in])
                .max()
                .ok_or_else(|| anyhow!("model has no layers"))?;
            let mut cur = vec![0f32; n * max_w];
            cur[..n * spec.f_in].copy_from_slice(&batch.x.data[..n * spec.f_in]);
            let mut nxt = vec![0f32; n * max_w];
            let mut f = spec.f_in;
            let last = weights.len() - 1;
            for (l, w) in weights.iter().enumerate() {
                let g_dim = w.dims[1];
                spmm_layer_raw_into(
                    &blk.offsets,
                    &blk.cols,
                    &blk.vals,
                    &blk.self_loop,
                    &cur[..n * f],
                    f,
                    w,
                    l != last,
                    self.threads,
                    &mut nxt[..n * g_dim],
                );
                if spec.residual && l != last && g_dim == f {
                    for i in 0..n * f {
                        nxt[i] += cur[i];
                    }
                }
                std::mem::swap(&mut cur, &mut nxt);
                f = g_dim;
            }
            if f != classes {
                return Err(anyhow!("final layer width {f} != classes {classes}"));
            }
            out[..n * classes].copy_from_slice(&cur[..n * classes]);
        }
        Ok(Tensor::new(vec![b, classes], out))
    }

    fn vrgcn_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        let spec = self.spec(model)?.clone();
        state.step += 1;
        let (loss, hiddens) =
            vrgcn_grads(&spec, &state.weights, batch, self.threads, &mut self.ws)?;
        if !loss.is_finite() {
            return Err(anyhow!("vrgcn non-finite loss at step {}", state.step));
        }
        adam_update_pooled(
            &mut state.weights,
            &mut state.m,
            &mut state.v,
            &self.ws.grads,
            &self.ws.spans,
            state.step as f32,
            lr,
            self.threads,
        );
        Ok((loss, hiddens))
    }

    /// The data-parallel primitive: the same pooled forward + backward
    /// as [`Backend::train_step`], but gradients are copied out into
    /// the caller's reusable per-layer buffers and **no** optimizer
    /// state is touched.  Every pooled kernel on this path accumulates
    /// in a chunk-layout-independent order, so the gradients (and the
    /// loss) are bit-identical at every thread width — which is what
    /// makes a one-replica [`super::ShardedBackend`] reproduce
    /// `train_step` exactly.
    fn grad_step(
        &mut self,
        model: &str,
        weights: &[Tensor],
        batch: &Batch,
        grads: &mut Vec<Vec<f32>>,
    ) -> Result<f32> {
        let spec = self.spec(model)?.clone();
        let loss = host_grads_pooled(&spec, weights, batch, self.threads, &mut self.ws)?;
        if !loss.is_finite() {
            return Err(anyhow!("non-finite loss in grad_step"));
        }
        let layers = self.ws.grad_layers();
        grads.resize(layers.len(), Vec::new());
        for (dst, src) in grads.iter_mut().zip(layers) {
            dst.clear();
            dst.extend_from_slice(src);
        }
        Ok(loss)
    }

    /// One bias-corrected Adam step over externally accumulated
    /// per-layer gradients.  Runs the same pooled element-wise Adam
    /// core as `train_step`'s arena pass (one pooled dispatch per
    /// layer), so a step through `grad_step` + `apply_grads` is
    /// bit-identical to the fused `train_step`.
    fn apply_grads(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        grads: &[Vec<f32>],
    ) -> Result<()> {
        self.spec(model)?;
        if grads.len() != state.weights.len() {
            return Err(anyhow!(
                "apply_grads: {} gradient layers for a {}-layer state",
                grads.len(),
                state.weights.len()
            ));
        }
        state.step += 1;
        let t = state.step as f32;
        for li in 0..state.weights.len() {
            let len = grads[li].len();
            if state.weights[li].data.len() != len {
                return Err(anyhow!(
                    "apply_grads: layer {li} gradient has {len} elements, \
                     weights have {}",
                    state.weights[li].data.len()
                ));
            }
            adam_update_pooled(
                &mut state.weights[li..li + 1],
                &mut state.m[li..li + 1],
                &mut state.v[li..li + 1],
                &grads[li],
                &[(0, len)],
                t,
                lr,
                self.threads,
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batch::BatchAssembler;
    use crate::coordinator::inference::full_forward;
    use crate::graph::{Csr, Dataset, Labels, Split};
    use crate::norm::NormConfig;
    use crate::util::Rng;

    fn tiny_ds(task: Task) -> Dataset {
        // ring of 6 nodes, f_in = 3, 2 classes
        let n = 6;
        let edges: Vec<(u32, u32)> =
            (0..n as u32).map(|v| (v, (v + 1) % n as u32)).collect();
        let mut rng = Rng::new(11);
        let features: Vec<f32> = (0..n * 3).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let labels = match task {
            Task::Multiclass => Labels::Multiclass(vec![0, 1, 0, 1, 0, 1]),
            Task::Multilabel => {
                let mut l = Labels::multilabel_new(n, 2);
                for v in 0..n {
                    l.set_label(v, v % 2);
                    if v % 3 == 0 {
                        l.set_label(v, 0);
                    }
                }
                l
            }
        };
        Dataset {
            name: "host_tiny".into(),
            task,
            graph: Csr::from_edges(n, &edges),
            f_in: 3,
            num_classes: 2,
            features,
            labels,
            split: vec![
                Split::Train,
                Split::Train,
                Split::Val,
                Split::Train,
                Split::Train,
                Split::Test,
            ],
        }
    }

    fn rand_weights(spec: &ModelSpec, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        spec.weight_shapes
            .iter()
            .map(|&(fi, fo)| {
                Tensor::new(
                    vec![fi, fo],
                    (0..fi * fo).map(|_| rng.f32() - 0.5).collect(),
                )
            })
            .collect()
    }

    fn full_batch(ds: &Dataset, b_max: usize, norm: NormConfig) -> Batch {
        let mut asm = BatchAssembler::new(ds.n(), b_max, norm);
        let nodes: Vec<u32> = (0..ds.n() as u32).collect();
        asm.assemble(ds, &nodes)
    }

    /// Central finite differences over every weight entry, checked
    /// against the **pooled** engine (the production path).
    fn check_grads(task: Task, residual: bool, tol: f32) {
        let ds = tiny_ds(task);
        // square layers so the residual variant is exercised for real
        let mut spec = ModelSpec::gcn(task, 3, 3, 3, 2, 8);
        if residual {
            spec = spec.with_residual();
        }
        let batch = full_batch(&ds, 8, NormConfig::PAPER_DEFAULT);
        let weights = rand_weights(&spec, 21);
        let mut ws = BackwardWorkspace::new();
        host_grads_pooled(&spec, &weights, &batch, 2, &mut ws).unwrap();
        let grads: Vec<Vec<f32>> = ws.grad_layers().iter().map(|s| s.to_vec()).collect();
        let eps = 2e-3f32;
        for li in 0..spec.layers {
            for e in 0..weights[li].data.len() {
                let mut wp = weights.clone();
                wp[li].data[e] += eps;
                let lp = host_loss(&spec, &wp, &batch, 2);
                let mut wm = weights.clone();
                wm[li].data[e] -= eps;
                let lm = host_loss(&spec, &wm, &batch, 2);
                let num = (lp - lm) / (2.0 * eps);
                let ana = grads[li][e];
                assert!(
                    (num - ana).abs() <= tol + 0.1 * num.abs().max(ana.abs()),
                    "layer {li} entry {e}: numeric {num} vs analytic {ana}"
                );
            }
        }
    }

    #[test]
    fn grads_match_finite_differences_multiclass() {
        check_grads(Task::Multiclass, false, 5e-3);
    }

    #[test]
    fn grads_match_finite_differences_multilabel() {
        check_grads(Task::Multilabel, false, 5e-3);
    }

    #[test]
    fn grads_match_finite_differences_residual() {
        check_grads(Task::Multiclass, true, 5e-3);
    }

    /// The pooled engine agrees with the retained scalar backward (the
    /// dense-derived oracle) at several pool widths — loss bitwise,
    /// gradients within the dot-reassociation tolerance.
    #[test]
    fn pooled_grads_match_scalar_oracle() {
        for task in [Task::Multiclass, Task::Multilabel] {
            let ds = tiny_ds(task);
            let spec = ModelSpec::gcn(task, 3, 3, 5, 2, 8);
            let batch = full_batch(&ds, 8, NormConfig::PAPER_DEFAULT);
            let weights = rand_weights(&spec, 9);
            let (loss_s, grads_s) = host_grads_scalar(&spec, &weights, &batch, 2).unwrap();
            for threads in [1usize, 2, 8] {
                let mut ws = BackwardWorkspace::new();
                let loss_p =
                    host_grads_pooled(&spec, &weights, &batch, threads, &mut ws).unwrap();
                assert_eq!(loss_p.to_bits(), loss_s.to_bits(), "loss t={threads}");
                for (li, gs) in grads_s.iter().enumerate() {
                    let gp = ws.grad_layers()[li].to_vec();
                    for (e, (a, b)) in gp.iter().zip(gs).enumerate() {
                        assert!(
                            (a - b).abs() <= 1e-5 + 1e-4 * b.abs(),
                            "layer {li} entry {e} t={threads}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    /// The transpose-build/forward overlap inside [`host_grads_pooled`]
    /// must not change a single bit of the step: same loss and same
    /// gradients as running the identical pieces strictly serially
    /// (forward+loss, then the Âᵀ build, then the backward sweep).
    #[test]
    fn overlapped_step_matches_serial_bitwise() {
        for residual in [false, true] {
            let ds = tiny_ds(Task::Multiclass);
            let mut spec = ModelSpec::gcn(Task::Multiclass, 3, 3, 3, 2, 8);
            if residual {
                spec = spec.with_residual();
            }
            let batch = full_batch(&ds, 8, NormConfig::PAPER_DEFAULT);
            let weights = rand_weights(&spec, 33);

            let mut ws = BackwardWorkspace::new();
            let loss =
                host_grads_pooled(&spec, &weights, &batch, 2, &mut ws).unwrap();
            let grads: Vec<Vec<f32>> =
                ws.grad_layers().iter().map(|s| s.to_vec()).collect();

            let mut ws2 = BackwardWorkspace::new();
            ws2.prepare(&weights, batch.n_real);
            let loss2 = forward_and_loss(&spec, &weights, &batch, 2, &mut ws2);
            let blk = &batch.block;
            ws2.adj_t.build(&blk.offsets, &blk.cols, &blk.vals, &blk.self_loop);
            backward_sweep(&weights, batch.n_real, spec.residual, 2, &mut ws2);
            let grads2: Vec<Vec<f32>> =
                ws2.grad_layers().iter().map(|s| s.to_vec()).collect();

            assert_eq!(loss.to_bits(), loss2.to_bits(), "residual={residual}");
            for (li, (a, b)) in grads.iter().zip(&grads2).enumerate() {
                for (e, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "layer {li} entry {e} residual={residual}"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_matches_exact_evaluator_bitwise() {
        let ds = tiny_ds(Task::Multiclass);
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 5, 2, 8);
        let weights = rand_weights(&spec, 3);
        let batch = full_batch(&ds, 8, NormConfig::PAPER_DEFAULT);
        let expect = full_forward(&ds, &weights, NormConfig::PAPER_DEFAULT, false);
        for threads in [1usize, 2, 7] {
            let mut hb = HostBackend::with_threads(threads);
            hb.register_model("m", spec.clone());
            let got = hb.forward("m", &weights, &batch).unwrap();
            assert_eq!(got.dims, vec![8, 2]);
            assert_eq!(
                &got.data[..ds.n() * 2],
                &expect[..],
                "threads = {threads}"
            );
            // padding rows are zero
            assert!(got.data[ds.n() * 2..].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn train_step_learns_on_tiny_graph() {
        let ds = tiny_ds(Task::Multiclass);
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 8, 2, 8);
        let mut hb = HostBackend::new();
        hb.register_model("m", spec.clone());
        let mut state = TrainState::init(&spec, 7);
        let batch = full_batch(&ds, 8, NormConfig::PAPER_DEFAULT);
        let first = hb.train_step("m", &mut state, 0.05, &batch).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = hb.train_step("m", &mut state, 0.05, &batch).unwrap();
        }
        assert!(last < first * 0.5, "loss did not drop: {first} -> {last}");
        assert_eq!(state.step, 31);
    }

    /// The zero-allocation contract: after the first step sized every
    /// workspace buffer, further steps reuse them in place.
    #[test]
    fn train_steps_reuse_workspace_allocations() {
        let ds = tiny_ds(Task::Multiclass);
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 8, 2, 8);
        let mut hb = HostBackend::new();
        hb.register_model("m", spec.clone());
        let mut state = TrainState::init(&spec, 7);
        let batch = full_batch(&ds, 8, NormConfig::PAPER_DEFAULT);
        hb.train_step("m", &mut state, 0.05, &batch).unwrap();
        let ptrs = (
            hb.ws.grads.as_ptr(),
            hb.ws.dz.as_ptr(),
            hb.ws.mbuf.as_ptr(),
            hb.ws.ps[0].as_ptr(),
            hb.ws.zs[1].as_ptr(),
        );
        for _ in 0..3 {
            hb.train_step("m", &mut state, 0.05, &batch).unwrap();
        }
        assert_eq!(ptrs.0, hb.ws.grads.as_ptr());
        assert_eq!(ptrs.1, hb.ws.dz.as_ptr());
        assert_eq!(ptrs.2, hb.ws.mbuf.as_ptr());
        assert_eq!(ptrs.3, hb.ws.ps[0].as_ptr());
        assert_eq!(ptrs.4, hb.ws.zs[1].as_ptr());
    }

    /// Build a VR-GCN batch over the whole tiny graph; `hc_dims` are
    /// the per-layer `Hc` widths (the spec's `layer_in_dims`).
    fn tiny_vrgcn_batch(ds: &Dataset, b: usize, seed: u64, hc_dims: &[usize]) -> VrgcnBatch {
        use crate::runtime::VrgcnAdj;

        let n = ds.n();
        // row-normalized entries as A_in (CSR, diagonal inline), plus
        // non-zero Hc rows so the stop-gradient path is exercised
        let mut a_in = VrgcnAdj::new();
        a_in.offsets.push(0);
        for v in 0..n {
            let deg = ds.graph.degree(v) as f32 + 1.0;
            let mut row: Vec<u32> = ds.graph.neighbors(v).to_vec();
            row.push(v as u32);
            row.sort_unstable();
            row.dedup();
            for c in row {
                a_in.cols.push(c);
                a_in.vals.push(1.0 / deg);
            }
            a_in.offsets.push(a_in.cols.len());
        }
        let mut rng = Rng::new(seed);
        let mut hcs = Vec::new();
        for &fd in hc_dims {
            let mut hc = Tensor::zeros(vec![b, fd]);
            for x in hc.data[..n * fd].iter_mut() {
                *x = (rng.f32() - 0.5) * 0.3;
            }
            hcs.push(hc);
        }
        let mut x = Tensor::zeros(vec![b, 3]);
        x.data[..n * 3].copy_from_slice(&ds.features);
        let mut y = Tensor::zeros(vec![b, 2]);
        let mut mask = Tensor::zeros(vec![b]);
        for v in 0..n {
            ds.labels.write_row(v, 2, &mut y.data[v * 2..(v + 1) * 2]);
            mask.data[v] = 1.0;
        }
        VrgcnBatch { a_in, hcs, x, y, mask, n_real: n }
    }

    #[test]
    fn vrgcn_step_runs_and_returns_hiddens() {
        let ds = tiny_ds(Task::Multiclass);
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 4, 2, 8);
        let mut hb = HostBackend::new();
        hb.register_model("m", spec.clone());
        let mut state = TrainState::init(&spec, 5);
        let b = 8;
        let vb = tiny_vrgcn_batch(&ds, b, 99, &spec.layer_in_dims());
        let (first, hiddens) = hb.vrgcn_step("m", &mut state, 0.05, &vb).unwrap();
        assert!(first.is_finite());
        assert_eq!(hiddens.len(), 1);
        assert_eq!(hiddens[0].dims, vec![b, 4]);
        let mut last = first;
        for _ in 0..25 {
            last = hb.vrgcn_step("m", &mut state, 0.05, &vb).unwrap().0;
        }
        assert!(last < first, "vrgcn loss did not drop: {first} -> {last}");
    }

    /// Central finite differences over the VR-GCN step's weights,
    /// against a scalar dense-`A_in` loss oracle — covers the shared
    /// backward sweep with the inline-diagonal transpose.
    #[test]
    fn vrgcn_grads_match_finite_differences() {
        let ds = tiny_ds(Task::Multiclass);
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 4, 2, 8);
        let weights = rand_weights(&spec, 17);
        let vb = tiny_vrgcn_batch(&ds, 8, 23, &spec.layer_in_dims());
        let mut ws = BackwardWorkspace::new();
        vrgcn_grads(&spec, &weights, &vb, 2, &mut ws).unwrap();
        let grads: Vec<Vec<f32>> = ws.grad_layers().iter().map(|s| s.to_vec()).collect();
        let eps = 2e-3f32;
        let tol = 5e-3f32;
        for li in 0..spec.layers {
            for e in 0..weights[li].data.len() {
                let mut wp = weights.clone();
                wp[li].data[e] += eps;
                let lp = vrgcn_loss(&spec, &wp, &vb);
                let mut wm = weights.clone();
                wm[li].data[e] -= eps;
                let lm = vrgcn_loss(&spec, &wm, &vb);
                let num = (lp - lm) / (2.0 * eps);
                let ana = grads[li][e];
                assert!(
                    (num - ana).abs() <= tol + 0.1 * num.abs().max(ana.abs()),
                    "layer {li} entry {e}: numeric {num} vs analytic {ana}"
                );
            }
        }
    }

    /// The sparse-native VR-GCN step vs the retained dense oracle
    /// (densify → re-extract → same core): loss, hidden activations,
    /// and gradients **bitwise** equal at several pool widths — the
    /// acceptance contract of the sparse-native path.
    #[test]
    fn vrgcn_sparse_step_matches_dense_oracle_bitwise() {
        for task in [Task::Multiclass, Task::Multilabel] {
            let ds = tiny_ds(task);
            let spec = ModelSpec::gcn(task, 3, 3, 4, 2, 8);
            let weights = rand_weights(&spec, 31);
            let vb = tiny_vrgcn_batch(&ds, 8, 57, &spec.layer_in_dims());
            for threads in [1usize, 2, 8] {
                let mut hb = HostBackend::with_threads(threads);
                hb.register_model("m", spec.clone());
                let (loss_s, hid_s, grads_s) =
                    hb.vrgcn_loss_and_grads("m", &weights, &vb).unwrap();
                let (loss_d, hid_d, grads_d) =
                    vrgcn_grads_dense(&spec, &weights, &vb, threads).unwrap();
                assert_eq!(loss_s.to_bits(), loss_d.to_bits(), "loss t={threads}");
                assert_eq!(hid_s.len(), hid_d.len());
                for (li, (a, b)) in hid_s.iter().zip(&hid_d).enumerate() {
                    assert_eq!(a.dims, b.dims, "hidden {li} dims t={threads}");
                    for (e, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                        assert_eq!(x.to_bits(), y.to_bits(), "hidden {li} e={e} t={threads}");
                    }
                }
                for (li, (ga, gb)) in grads_s.iter().zip(&grads_d).enumerate() {
                    for (e, (x, y)) in ga.iter().zip(gb).enumerate() {
                        assert_eq!(x.to_bits(), y.to_bits(), "grad {li} e={e} t={threads}");
                    }
                }
            }
        }
    }

    #[test]
    fn unknown_model_errors() {
        let mut hb = HostBackend::new();
        assert!(hb.model_spec("nope").is_err());
        assert!(hb.prepare("nope").is_err());
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 4, 2, 8);
        assert!(hb.register_model("yes", spec));
        assert!(hb.prepare("yes").is_ok());
        assert_eq!(hb.models().collect::<Vec<_>>(), vec!["yes"]);
    }
}
