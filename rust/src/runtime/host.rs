//! [`HostBackend`]: the artifact-free execution backend.  The full
//! training pipeline — forward, masked loss, backward, Adam — runs on
//! the host, built from the same tiled SpMM·GEMM kernels the exact
//! evaluator uses (`coordinator::inference`), so `cluster-gcn train
//! --backend host` works with no `artifacts/` directory and no python
//! step at all.
//!
//! Parity contract: [`HostBackend::forward`] over a full-graph batch
//! (all nodes in natural order) is **bit-identical** to
//! [`crate::coordinator::inference::full_forward_cached`] at every pool
//! width — the batch renormalization computes the same f32 values as
//! `normalize_sparse`, the block is re-extracted into CSR form, and the
//! layer loop mirrors the evaluator's ping-pong exactly.  The property
//! suite pins this.
//!
//! The backward pass is the standard GCN chain: with `P_l = Â·H_l`,
//! `Z_l = P_l·W_l`, `H_{l+1} = relu(Z_l) (+ H_l)`,
//!
//! ```text
//!   dW_l = P_l^T · dZ_l
//!   dH_l = Â^T · (dZ_l · W_l^T)  (+ dH_{l+1} through the residual)
//! ```
//!
//! and the Adam step matches `python/compile/model.py` (β1 = 0.9,
//! β2 = 0.999, ε = 1e-8, bias-corrected).  Unit tests check every
//! analytic gradient against central finite differences.
#![deny(missing_docs)]

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::coordinator::batch::Batch;
use crate::coordinator::inference::{propagate_into, spmm_layer_into};
use crate::coordinator::trainer::TrainState;
use crate::graph::{Csr, Task};
use crate::runtime::backend::{Backend, ModelSpec, VrgcnBatch};
use crate::runtime::exec::Tensor;
use crate::util::pool::default_threads;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Pure-host execution backend over registered [`ModelSpec`]s.
///
/// Models are declared with [`Backend::register_model`] (the
/// [`crate::session::Session`] does this automatically); there is no
/// artifact directory, manifest, or compile step.
pub struct HostBackend {
    models: BTreeMap<String, ModelSpec>,
    threads: usize,
}

impl Default for HostBackend {
    fn default() -> HostBackend {
        HostBackend::new()
    }
}

impl HostBackend {
    /// Backend over the default pool width.
    pub fn new() -> HostBackend {
        HostBackend::with_threads(default_threads())
    }

    /// Backend with an explicit kernel thread cap (results are
    /// bit-identical at every width; see `coordinator::inference`).
    pub fn with_threads(threads: usize) -> HostBackend {
        HostBackend { models: BTreeMap::new(), threads: threads.max(1) }
    }

    /// Registered model ids, in sorted order.
    pub fn models(&self) -> impl Iterator<Item = &str> {
        self.models.keys().map(|s| s.as_str())
    }

    fn spec(&self, model: &str) -> Result<&ModelSpec> {
        self.models.get(model).ok_or_else(|| {
            anyhow!(
                "model '{model}' not registered with the host backend \
                 ({} known)",
                self.models.len()
            )
        })
    }
}

/// Sparse view of one dense batch block: CSR structure + normalized
/// values + per-node self-loop, shaped exactly like the full-graph
/// normalization so the tiled kernels apply unchanged.
struct BlockAdj {
    csr: Csr,
    vals: Vec<f32>,
    self_loop: Vec<f32>,
}

/// Re-extract the `n_real × n_real` prefix of the dense batch block
/// into CSR form.  Normalized entries are strictly positive, so exact
/// zeros are structural (absent edges) and can be dropped.
fn extract_block(a: &Tensor, n: usize) -> BlockAdj {
    let b = a.dims[0];
    debug_assert!(n <= b);
    let mut offsets = vec![0usize; n + 1];
    let mut cols: Vec<u32> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut self_loop = vec![0f32; n];
    for u in 0..n {
        let row = &a.data[u * b..u * b + n];
        for (v, &av) in row.iter().enumerate() {
            if v == u {
                self_loop[u] = av;
            } else if av != 0.0 {
                cols.push(v as u32);
                vals.push(av);
            }
        }
        offsets[u + 1] = cols.len();
    }
    let nnz = cols.len();
    let csr = Csr { offsets, cols, weights: vec![1; nnz], node_weights: vec![1; n] };
    BlockAdj { csr, vals, self_loop }
}

/// `z[n,g] = p[n,f] · w[f,g]` (dense, zero-skipping on `p`).
fn gemm(p: &[f32], n: usize, f: usize, w: &[f32], g: usize, z: &mut [f32]) {
    debug_assert_eq!(p.len(), n * f);
    debug_assert_eq!(w.len(), f * g);
    debug_assert_eq!(z.len(), n * g);
    z.fill(0.0);
    for i in 0..n {
        let pr = &p[i * f..(i + 1) * f];
        let zr = &mut z[i * g..(i + 1) * g];
        for (k, &pv) in pr.iter().enumerate() {
            if pv == 0.0 {
                continue;
            }
            let wr = &w[k * g..(k + 1) * g];
            for (zv, &wv) in zr.iter_mut().zip(wr) {
                *zv += pv * wv;
            }
        }
    }
}

/// `gw[f,g] += p[n,f]^T · dz[n,g]` (caller zeroes `gw`).
fn gemm_at_b(p: &[f32], dz: &[f32], n: usize, f: usize, g: usize, gw: &mut [f32]) {
    debug_assert_eq!(gw.len(), f * g);
    for i in 0..n {
        let pr = &p[i * f..(i + 1) * f];
        let dr = &dz[i * g..(i + 1) * g];
        for (k, &pv) in pr.iter().enumerate() {
            if pv == 0.0 {
                continue;
            }
            let gr = &mut gw[k * g..(k + 1) * g];
            for (gv, &dv) in gr.iter_mut().zip(dr) {
                *gv += pv * dv;
            }
        }
    }
}

/// `m[n,f] = dz[n,g] · w[f,g]^T`.
fn gemm_a_bt(dz: &[f32], w: &[f32], n: usize, g: usize, f: usize, m: &mut [f32]) {
    debug_assert_eq!(m.len(), n * f);
    for i in 0..n {
        let dr = &dz[i * g..(i + 1) * g];
        let mr = &mut m[i * f..(i + 1) * f];
        for (k, mv) in mr.iter_mut().enumerate() {
            let wr = &w[k * g..(k + 1) * g];
            let mut acc = 0f32;
            for (&dv, &wv) in dr.iter().zip(wr) {
                acc += dv * wv;
            }
            *mv = acc;
        }
    }
}

/// `out[n,f] += Â^T · m[n,f]` over the sparse block (caller zeroes
/// `out`): scatter each stored entry `Â[u,v]` into row `v`, plus the
/// diagonal self-loops.
fn scatter_adj_t(blk: &BlockAdj, m: &[f32], f: usize, out: &mut [f32]) {
    let n = blk.csr.n();
    debug_assert_eq!(m.len(), n * f);
    debug_assert_eq!(out.len(), n * f);
    for u in 0..n {
        let sl = blk.self_loop[u];
        for j in 0..f {
            out[u * f + j] += sl * m[u * f + j];
        }
        let off = blk.csr.offsets[u];
        for (idx, &v) in blk.csr.neighbors(u).iter().enumerate() {
            let a = blk.vals[off + idx];
            let v = v as usize;
            for j in 0..f {
                out[v * f + j] += a * m[u * f + j];
            }
        }
    }
}

/// Masked mean loss (eq. (2)/(7), matching `model.masked_loss`) and its
/// gradient w.r.t. the logits.  Rows `0..n`, mask/label rows taken from
/// the padded batch tensors.
fn loss_and_dlogits(
    task: Task,
    logits: &[f32],
    y: &[f32],
    mask: &[f32],
    n: usize,
    classes: usize,
) -> (f32, Vec<f32>) {
    let c = classes;
    let msum: f32 = mask[..n].iter().sum();
    let denom = msum.max(1.0);
    let mut dz = vec![0f32; n * c];
    let mut loss = 0f32;
    match task {
        Task::Multiclass => {
            for i in 0..n {
                let mi = mask[i];
                if mi == 0.0 {
                    continue;
                }
                let row = &logits[i * c..(i + 1) * c];
                let yrow = &y[i * c..(i + 1) * c];
                let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let mut se = 0f32;
                for &v in row {
                    se += (v - mx).exp();
                }
                let lse = se.ln();
                let sum_y: f32 = yrow.iter().sum();
                let mut per = 0f32;
                for j in 0..c {
                    per -= yrow[j] * (row[j] - mx - lse);
                    let p = (row[j] - mx).exp() / se;
                    dz[i * c + j] = mi / denom * (p * sum_y - yrow[j]);
                }
                loss += per * mi;
            }
        }
        Task::Multilabel => {
            let scale = 1.0 / c as f32;
            for i in 0..n {
                let mi = mask[i];
                if mi == 0.0 {
                    continue;
                }
                let row = &logits[i * c..(i + 1) * c];
                let yrow = &y[i * c..(i + 1) * c];
                let mut per = 0f32;
                for j in 0..c {
                    let zv = row[j];
                    let yv = yrow[j];
                    per += zv.max(0.0) - zv * yv + (-zv.abs()).exp().ln_1p();
                    let sig = 1.0 / (1.0 + (-zv).exp());
                    dz[i * c + j] = mi * scale / denom * (sig - yv);
                }
                loss += per * scale * mi;
            }
        }
    }
    (loss / denom, dz)
}

/// One bias-corrected Adam update over a flat parameter group.
fn adam_update(w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], t: f32, lr: f32) {
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    for i in 0..w.len() {
        let gi = g[i];
        m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * gi;
        v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * gi * gi;
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        w[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

/// Forward over the sparse block, storing the per-layer propagations
/// `P_l` and pre-activations `Z_l` the backward pass needs.  Returns
/// `(ps, zs)`; the logits are the last `zs` entry.
fn forward_store(
    blk: &BlockAdj,
    weights: &[Tensor],
    x: &[f32],
    f_in: usize,
    residual: bool,
    threads: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let n = blk.csr.n();
    let l = weights.len();
    let mut ps: Vec<Vec<f32>> = Vec::with_capacity(l);
    let mut zs: Vec<Vec<f32>> = Vec::with_capacity(l);
    let mut h: Vec<f32> = x[..n * f_in].to_vec();
    let mut f = f_in;
    for (li, w) in weights.iter().enumerate() {
        debug_assert_eq!(w.dims[0], f, "weight in-dim mismatch at layer {li}");
        let g_dim = w.dims[1];
        let last = li == l - 1;
        let mut p = vec![0f32; n * f];
        propagate_into(&blk.csr, &blk.vals, &blk.self_loop, &h, f, threads, &mut p);
        let mut z = vec![0f32; n * g_dim];
        gemm(&p, n, f, &w.data, g_dim, &mut z);
        let mut h_next: Vec<f32> = if last {
            z.clone()
        } else {
            z.iter().map(|&v| v.max(0.0)).collect()
        };
        if residual && !last && g_dim == f {
            for (hv, &prev) in h_next.iter_mut().zip(&h) {
                *hv += prev;
            }
        }
        ps.push(p);
        zs.push(z);
        h = h_next;
        f = g_dim;
    }
    (ps, zs)
}

/// Loss only — the finite-difference oracle for the gradient tests.
#[cfg(test)]
fn host_loss(spec: &ModelSpec, weights: &[Tensor], batch: &Batch, threads: usize) -> f32 {
    let n = batch.n_real;
    let blk = extract_block(&batch.a, n);
    let (_, zs) = forward_store(&blk, weights, &batch.x.data, spec.f_in, spec.residual, threads);
    let logits = zs.last().expect("at least one layer");
    loss_and_dlogits(spec.task, logits, &batch.y.data, &batch.mask.data, n, spec.classes).0
}

/// Full forward + backward: loss and per-layer weight gradients.
fn host_grads(
    spec: &ModelSpec,
    weights: &[Tensor],
    batch: &Batch,
    threads: usize,
) -> Result<(f32, Vec<Vec<f32>>)> {
    let n = batch.n_real;
    if n == 0 {
        return Err(anyhow!("empty batch (n_real = 0)"));
    }
    let l = weights.len();
    let blk = extract_block(&batch.a, n);
    let (ps, zs) = forward_store(&blk, weights, &batch.x.data, spec.f_in, spec.residual, threads);
    let logits = &zs[l - 1];
    let (loss, dlogits) =
        loss_and_dlogits(spec.task, logits, &batch.y.data, &batch.mask.data, n, spec.classes);

    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); l];
    // dh = dL/dH_{li+1} while processing layer li (top-down).
    let mut dh = dlogits;
    for li in (0..l).rev() {
        let w = &weights[li];
        let (fi, go) = (w.dims[0], w.dims[1]);
        let last = li == l - 1;
        // dz = dh ⊙ σ'(z); the last layer has no activation.
        let dz: Vec<f32> = if last {
            dh.clone()
        } else {
            dh.iter()
                .zip(&zs[li])
                .map(|(&d, &zv)| if zv > 0.0 { d } else { 0.0 })
                .collect()
        };
        let mut gw = vec![0f32; fi * go];
        gemm_at_b(&ps[li], &dz, n, fi, go, &mut gw);
        if li > 0 {
            let mut mbuf = vec![0f32; n * fi];
            gemm_a_bt(&dz, &w.data, n, go, fi, &mut mbuf);
            let mut dh_new = vec![0f32; n * fi];
            scatter_adj_t(&blk, &mbuf, fi, &mut dh_new);
            if spec.residual && !last && go == fi {
                for (o, &d) in dh_new.iter_mut().zip(&dh) {
                    *o += d;
                }
            }
            dh = dh_new;
        }
        grads[li] = gw;
    }
    Ok((loss, grads))
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn model_spec(&mut self, model: &str) -> Result<ModelSpec> {
        Ok(self.spec(model)?.clone())
    }

    fn prepare(&mut self, model: &str) -> Result<()> {
        self.spec(model).map(|_| ())
    }

    fn register_model(&mut self, model: &str, spec: ModelSpec) -> bool {
        self.models.insert(model.to_string(), spec);
        true
    }

    fn train_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &Batch,
    ) -> Result<f32> {
        let spec = self.spec(model)?.clone();
        state.step += 1;
        let (loss, grads) = host_grads(&spec, &state.weights, batch, self.threads)?;
        if !loss.is_finite() {
            return Err(anyhow!("non-finite loss at step {}", state.step));
        }
        let t = state.step as f32;
        for li in 0..state.weights.len() {
            adam_update(
                &mut state.weights[li].data,
                &grads[li],
                &mut state.m[li].data,
                &mut state.v[li].data,
                t,
                lr,
            );
        }
        Ok(loss)
    }

    fn forward(&mut self, model: &str, weights: &[Tensor], batch: &Batch) -> Result<Tensor> {
        let spec = self.spec(model)?.clone();
        let b = batch.a.dims[0];
        let classes = spec.classes;
        let n = batch.n_real;
        let mut out = vec![0f32; b * classes];
        if n > 0 {
            let blk = extract_block(&batch.a, n);
            // Mirror `full_forward_cached` exactly: two max-width
            // ping-pong buffers, relu on every layer but the last —
            // this is what makes the full-graph batch bit-identical to
            // the exact evaluator.
            let max_w = weights
                .iter()
                .map(|w| w.dims[1])
                .chain([spec.f_in])
                .max()
                .ok_or_else(|| anyhow!("model has no layers"))?;
            let mut cur = vec![0f32; n * max_w];
            cur[..n * spec.f_in].copy_from_slice(&batch.x.data[..n * spec.f_in]);
            let mut nxt = vec![0f32; n * max_w];
            let mut f = spec.f_in;
            let last = weights.len() - 1;
            for (l, w) in weights.iter().enumerate() {
                let g_dim = w.dims[1];
                spmm_layer_into(
                    &blk.csr,
                    &blk.vals,
                    &blk.self_loop,
                    &cur[..n * f],
                    f,
                    w,
                    l != last,
                    self.threads,
                    &mut nxt[..n * g_dim],
                );
                if spec.residual && l != last && g_dim == f {
                    for i in 0..n * f {
                        nxt[i] += cur[i];
                    }
                }
                std::mem::swap(&mut cur, &mut nxt);
                f = g_dim;
            }
            if f != classes {
                return Err(anyhow!("final layer width {f} != classes {classes}"));
            }
            out[..n * classes].copy_from_slice(&cur[..n * classes]);
        }
        Ok(Tensor::new(vec![b, classes], out))
    }

    fn vrgcn_step(
        &mut self,
        model: &str,
        state: &mut TrainState,
        lr: f32,
        batch: &VrgcnBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        let spec = self.spec(model)?.clone();
        state.step += 1;
        let n = batch.n_real;
        if n == 0 {
            return Err(anyhow!("empty vrgcn batch (n_real = 0)"));
        }
        let l = spec.layers;
        let b = batch.a_in.dims[0];
        let dims = spec.layer_in_dims();

        // ---- forward: P_l = A_in·H_l + Hc_l; Z_l = P_l·W_l ------------
        let mut ps: Vec<Vec<f32>> = Vec::with_capacity(l);
        let mut zs: Vec<Vec<f32>> = Vec::with_capacity(l);
        let mut hiddens: Vec<Tensor> = Vec::with_capacity(l.saturating_sub(1));
        let mut h: Vec<f32> = batch.x.data[..n * spec.f_in].to_vec();
        for li in 0..l {
            let f = dims[li];
            let w = &state.weights[li];
            let g_dim = w.dims[1];
            let last = li == l - 1;
            let hc = &batch.hcs[li].data;
            let mut p = vec![0f32; n * f];
            for i in 0..n {
                p[i * f..(i + 1) * f].copy_from_slice(&hc[i * f..(i + 1) * f]);
                let arow = &batch.a_in.data[i * b..i * b + n];
                for (j, &a) in arow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let hr = &h[j * f..(j + 1) * f];
                    for k in 0..f {
                        p[i * f + k] += a * hr[k];
                    }
                }
            }
            let mut z = vec![0f32; n * g_dim];
            gemm(&p, n, f, &w.data, g_dim, &mut z);
            let h_next: Vec<f32> = if last {
                z.clone()
            } else {
                z.iter().map(|&v| v.max(0.0)).collect()
            };
            if !last {
                // padded (b, f_hid) hidden for the history refresh
                let mut hid = vec![0f32; b * g_dim];
                hid[..n * g_dim].copy_from_slice(&h_next);
                hiddens.push(Tensor::new(vec![b, g_dim], hid));
            }
            ps.push(p);
            zs.push(z);
            h = h_next;
        }

        let logits = &zs[l - 1];
        let (loss, dlogits) = loss_and_dlogits(
            spec.task,
            logits,
            &batch.y.data,
            &batch.mask.data,
            n,
            spec.classes,
        );
        if !loss.is_finite() {
            return Err(anyhow!("vrgcn non-finite loss at step {}", state.step));
        }

        // ---- backward (Hc is stop-gradient, exactly like the AOT model)
        let mut grads: Vec<Vec<f32>> = vec![Vec::new(); l];
        let mut dh = dlogits;
        for li in (0..l).rev() {
            let w = &state.weights[li];
            let (fi, go) = (w.dims[0], w.dims[1]);
            let last = li == l - 1;
            let dz: Vec<f32> = if last {
                dh.clone()
            } else {
                dh.iter()
                    .zip(&zs[li])
                    .map(|(&d, &zv)| if zv > 0.0 { d } else { 0.0 })
                    .collect()
            };
            let mut gw = vec![0f32; fi * go];
            gemm_at_b(&ps[li], &dz, n, fi, go, &mut gw);
            if li > 0 {
                let mut mbuf = vec![0f32; n * fi];
                gemm_a_bt(&dz, &w.data, n, go, fi, &mut mbuf);
                // dh[j] += A_in[i,j] · mbuf[i]  (dense transpose scatter)
                let mut dh_new = vec![0f32; n * fi];
                for i in 0..n {
                    let arow = &batch.a_in.data[i * b..i * b + n];
                    let mr = &mbuf[i * fi..(i + 1) * fi];
                    for (j, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        for k in 0..fi {
                            dh_new[j * fi + k] += a * mr[k];
                        }
                    }
                }
                dh = dh_new;
            }
            grads[li] = gw;
        }

        let t = state.step as f32;
        for li in 0..l {
            adam_update(
                &mut state.weights[li].data,
                &grads[li],
                &mut state.m[li].data,
                &mut state.v[li].data,
                t,
                lr,
            );
        }
        Ok((loss, hiddens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batch::BatchAssembler;
    use crate::coordinator::inference::full_forward;
    use crate::graph::{Dataset, Labels, Split};
    use crate::norm::NormConfig;
    use crate::util::Rng;

    fn tiny_ds(task: Task) -> Dataset {
        // ring of 6 nodes, f_in = 3, 2 classes
        let n = 6;
        let edges: Vec<(u32, u32)> =
            (0..n as u32).map(|v| (v, (v + 1) % n as u32)).collect();
        let mut rng = Rng::new(11);
        let features: Vec<f32> = (0..n * 3).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let labels = match task {
            Task::Multiclass => Labels::Multiclass(vec![0, 1, 0, 1, 0, 1]),
            Task::Multilabel => {
                let mut l = Labels::multilabel_new(n, 2);
                for v in 0..n {
                    l.set_label(v, v % 2);
                    if v % 3 == 0 {
                        l.set_label(v, 0);
                    }
                }
                l
            }
        };
        Dataset {
            name: "host_tiny".into(),
            task,
            graph: Csr::from_edges(n, &edges),
            f_in: 3,
            num_classes: 2,
            features,
            labels,
            split: vec![
                Split::Train,
                Split::Train,
                Split::Val,
                Split::Train,
                Split::Train,
                Split::Test,
            ],
        }
    }

    fn rand_weights(spec: &ModelSpec, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        spec.weight_shapes
            .iter()
            .map(|&(fi, fo)| {
                Tensor::new(
                    vec![fi, fo],
                    (0..fi * fo).map(|_| rng.f32() - 0.5).collect(),
                )
            })
            .collect()
    }

    fn full_batch(ds: &Dataset, b_max: usize, norm: NormConfig) -> Batch {
        let mut asm = BatchAssembler::new(ds.n(), b_max, norm);
        let nodes: Vec<u32> = (0..ds.n() as u32).collect();
        asm.assemble(ds, &nodes)
    }

    /// Central finite differences over every weight entry.
    fn check_grads(task: Task, residual: bool, tol: f32) {
        let ds = tiny_ds(task);
        // square layers so the residual variant is exercised for real
        let mut spec = ModelSpec::gcn(task, 3, 3, 3, 2, 8);
        if residual {
            spec = spec.with_residual();
        }
        let batch = full_batch(&ds, 8, NormConfig::PAPER_DEFAULT);
        let weights = rand_weights(&spec, 21);
        let (_, grads) = host_grads(&spec, &weights, &batch, 2).unwrap();
        let eps = 2e-3f32;
        for li in 0..spec.layers {
            for e in 0..weights[li].data.len() {
                let mut wp = weights.clone();
                wp[li].data[e] += eps;
                let lp = host_loss(&spec, &wp, &batch, 2);
                let mut wm = weights.clone();
                wm[li].data[e] -= eps;
                let lm = host_loss(&spec, &wm, &batch, 2);
                let num = (lp - lm) / (2.0 * eps);
                let ana = grads[li][e];
                assert!(
                    (num - ana).abs() <= tol + 0.1 * num.abs().max(ana.abs()),
                    "layer {li} entry {e}: numeric {num} vs analytic {ana}"
                );
            }
        }
    }

    #[test]
    fn grads_match_finite_differences_multiclass() {
        check_grads(Task::Multiclass, false, 5e-3);
    }

    #[test]
    fn grads_match_finite_differences_multilabel() {
        check_grads(Task::Multilabel, false, 5e-3);
    }

    #[test]
    fn grads_match_finite_differences_residual() {
        check_grads(Task::Multiclass, true, 5e-3);
    }

    #[test]
    fn adam_single_step_known_values() {
        let mut w = vec![1.0f32];
        let g = vec![0.5f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        adam_update(&mut w, &g, &mut m, &mut v, 1.0, 0.1);
        // m = 0.05, v = 0.00025; bias-corrected mhat = 0.5, vhat = 0.25
        assert!((m[0] - 0.05).abs() < 1e-7);
        assert!((v[0] - 0.00025).abs() < 1e-9);
        // w -= 0.1 * 0.5 / (0.5 + eps) ≈ 1 - 0.1
        assert!((w[0] - 0.9).abs() < 1e-5, "w = {}", w[0]);
    }

    #[test]
    fn forward_matches_exact_evaluator_bitwise() {
        let ds = tiny_ds(Task::Multiclass);
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 5, 2, 8);
        let weights = rand_weights(&spec, 3);
        let batch = full_batch(&ds, 8, NormConfig::PAPER_DEFAULT);
        let expect = full_forward(&ds, &weights, NormConfig::PAPER_DEFAULT, false);
        for threads in [1usize, 2, 7] {
            let mut hb = HostBackend::with_threads(threads);
            hb.register_model("m", spec.clone());
            let got = hb.forward("m", &weights, &batch).unwrap();
            assert_eq!(got.dims, vec![8, 2]);
            assert_eq!(
                &got.data[..ds.n() * 2],
                &expect[..],
                "threads = {threads}"
            );
            // padding rows are zero
            assert!(got.data[ds.n() * 2..].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn train_step_learns_on_tiny_graph() {
        let ds = tiny_ds(Task::Multiclass);
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 8, 2, 8);
        let mut hb = HostBackend::new();
        hb.register_model("m", spec.clone());
        let mut state = TrainState::init(&spec, 7);
        let batch = full_batch(&ds, 8, NormConfig::PAPER_DEFAULT);
        let first = hb.train_step("m", &mut state, 0.05, &batch).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = hb.train_step("m", &mut state, 0.05, &batch).unwrap();
        }
        assert!(last < first * 0.5, "loss did not drop: {first} -> {last}");
        assert_eq!(state.step, 31);
    }

    #[test]
    fn vrgcn_step_runs_and_returns_hiddens() {
        let ds = tiny_ds(Task::Multiclass);
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 4, 2, 8);
        let mut hb = HostBackend::new();
        hb.register_model("m", spec.clone());
        let mut state = TrainState::init(&spec, 5);
        let n = ds.n();
        let b = 8;
        // dense block with plain row-normalized entries as A_in, zero Hc
        let mut a_in = Tensor::zeros(vec![b, b]);
        for v in 0..n {
            let deg = ds.graph.degree(v) as f32 + 1.0;
            a_in.data[v * b + v] = 1.0 / deg;
            for &u in ds.graph.neighbors(v) {
                a_in.data[v * b + u as usize] = 1.0 / deg;
            }
        }
        let mut x = Tensor::zeros(vec![b, 3]);
        x.data[..n * 3].copy_from_slice(&ds.features);
        let mut y = Tensor::zeros(vec![b, 2]);
        let mut mask = Tensor::zeros(vec![b]);
        for v in 0..n {
            ds.labels.write_row(v, 2, &mut y.data[v * 2..(v + 1) * 2]);
            mask.data[v] = 1.0;
        }
        let vb = VrgcnBatch {
            a_in,
            hcs: vec![Tensor::zeros(vec![b, 3]), Tensor::zeros(vec![b, 4])],
            x,
            y,
            mask,
            n_real: n,
        };
        let (first, hiddens) = hb.vrgcn_step("m", &mut state, 0.05, &vb).unwrap();
        assert!(first.is_finite());
        assert_eq!(hiddens.len(), 1);
        assert_eq!(hiddens[0].dims, vec![b, 4]);
        let mut last = first;
        for _ in 0..25 {
            last = hb.vrgcn_step("m", &mut state, 0.05, &vb).unwrap().0;
        }
        assert!(last < first, "vrgcn loss did not drop: {first} -> {last}");
    }

    #[test]
    fn unknown_model_errors() {
        let mut hb = HostBackend::new();
        assert!(hb.model_spec("nope").is_err());
        assert!(hb.prepare("nope").is_err());
        let spec = ModelSpec::gcn(Task::Multiclass, 2, 3, 4, 2, 8);
        assert!(hb.register_model("yes", spec));
        assert!(hb.prepare("yes").is_ok());
        assert_eq!(hb.models().collect::<Vec<_>>(), vec!["yes"]);
    }
}
