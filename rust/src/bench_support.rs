//! Shared plumbing for the paper-reproduction benches (`benches/`):
//! engine/dataset setup, method runners keyed the way the experiment
//! index in DESIGN.md §5 names them, table formatting, and JSON result
//! dumps under `bench_results/`.
//!
//! Benches read their effort from env vars so `cargo bench` stays
//! tractable on CPU while EXPERIMENTS.md records longer runs:
//!   CGCN_EPOCHS   — epochs per training run (default per-bench)
//!   CGCN_SEED     — experiment seed (default 42)

use std::path::Path;

use anyhow::Result;

use crate::baselines::{train_graphsage, train_vrgcn, SageParams, VrgcnParams};
use crate::coordinator::{train, ClusterSampler, TrainResult};
use crate::session::TrainConfig;
use crate::datagen::{build_cached, preset, Preset};
use crate::graph::Dataset;
use crate::partition::{
    parts_to_clusters, MultilevelPartitioner, Partitioner, RandomPartitioner,
};
use crate::runtime::Engine;
use crate::util::{Json, Rng};

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn env_seed() -> u64 {
    std::env::var("CGCN_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(42)
}

pub fn engine() -> Result<Engine> {
    Engine::new(Path::new("artifacts"))
}

pub fn dataset(name: &str) -> Result<Dataset> {
    let p = preset(name).expect("unknown preset");
    Ok(build_cached(p, env_seed(), Path::new("data"))?)
}

pub fn preset_of(ds: &Dataset) -> &'static Preset {
    preset(&ds.name).expect("dataset built from preset")
}

/// Cluster partition -> sampler with the preset's defaults (or
/// overridden p/q).
pub fn cluster_sampler(
    ds: &Dataset,
    parts: usize,
    q: usize,
    seed: u64,
) -> ClusterSampler {
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let part = MultilevelPartitioner::default().partition(&ds.graph, parts, &mut rng);
    ClusterSampler::new(parts_to_clusters(&part, parts), q)
}

pub fn random_sampler(ds: &Dataset, parts: usize, q: usize, seed: u64) -> ClusterSampler {
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let part = RandomPartitioner.partition(&ds.graph, parts, &mut rng);
    ClusterSampler::new(parts_to_clusters(&part, parts), q)
}

/// One named training run (rows of Fig. 6 / Tables 8-9).
pub fn run_method(
    engine: &mut Engine,
    ds: &Dataset,
    method: &str,
    layers: usize,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    let p = preset_of(ds);
    let short = ds.name.trim_end_matches("_like");
    match method {
        "cluster" => {
            let sampler =
                cluster_sampler(ds, p.default_partitions, p.default_q, cfg.seed);
            train(engine, ds, &sampler, &format!("{short}_L{layers}"), cfg)
        }
        "graphsage" => {
            let params = SageParams::for_depth(layers, 256);
            train_graphsage(engine, ds, &format!("{short}_sage_L{layers}"), &params, cfg)
        }
        "vrgcn" => {
            let params = VrgcnParams::default();
            train_vrgcn(engine, ds, &format!("{short}_vrgcn_L{layers}"), &params, cfg)
        }
        other => anyhow::bail!("unknown method {other}"),
    }
}

/// Append a result row to `bench_results/<bench>.json` (one JSON object
/// per line; the file is a JSONL log so repeated runs accumulate).
pub fn dump_row(bench: &str, row: Json) {
    let dir = Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{bench}.jsonl"));
    let mut line = row.to_string();
    line.push('\n');
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = f.write_all(line.as_bytes());
    }
}

/// Simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("--")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

pub fn fmt_mb(bytes: usize) -> String {
    format!("{:.1}", bytes as f64 / 1e6)
}

pub fn fmt_s(secs: f64) -> String {
    format!("{secs:.2}")
}

pub fn fmt_f1(f1: f64) -> String {
    format!("{:.4}", f1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_parsing_defaults() {
        assert_eq!(env_usize("CGCN_DOES_NOT_EXIST_XYZ", 7), 7);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // must not panic
    }
}
