//! Contiguous f32-lane inner loops shared by the forward GEMM tile and
//! the backward kernels (`runtime::backward`).
//!
//! There are no std::simd / intrinsics in the offline toolchain, so the
//! kernels lean on autovectorization instead: the two primitives here
//! expose the innermost loops in fixed-width `[f32; 8]` chunk form, the
//! shape LLVM reliably turns into packed vector code.
//!
//! Numeric contracts:
//!
//! - [`axpy`] computes every output element independently
//!   (`y[i] += a * x[i]`), so chunking does not change any result bit —
//!   kernels built on it stay bit-identical to their scalar oracles.
//! - [`dot`] accumulates into 8 independent lanes and reduces them in a
//!   fixed order, so it is deterministic at every call site, but it
//!   *reassociates* the sum relative to a strictly sequential scalar
//!   accumulation — parity tests against scalar oracles use a small
//!   tolerance instead of bit equality.

/// `y[i] += a * x[i]` over the common prefix, in `[f32; 8]` chunks.
///
/// `x` and `y` must be the same length (debug-asserted); each element is
/// updated independently, so the result is bit-identical to the naive
/// scalar loop.
#[inline]
pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (yy, xx) in (&mut yc).zip(&mut xc) {
        for l in 0..8 {
            yy[l] += a * xx[l];
        }
    }
    for (yv, &xv) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yv += a * xv;
    }
}

/// Dot product with 8 parallel lane accumulators and a fixed-order
/// horizontal reduction.  Deterministic, but reassociated relative to a
/// sequential scalar sum (see module docs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (aa, bb) in (&mut ac).zip(&mut bc) {
        for l in 0..8 {
            lanes[l] += aa[l] * bb[l];
        }
    }
    let mut tail = 0f32;
    for (&av, &bv) in ac.remainder().iter().zip(bc.remainder()) {
        tail += av * bv;
    }
    let even = (lanes[0] + lanes[2]) + (lanes[4] + lanes[6]);
    let odd = (lanes[1] + lanes[3]) + (lanes[5] + lanes[7]);
    (even + odd) + tail
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_matches_scalar_bitwise() {
        for n in [0usize, 1, 7, 8, 9, 16, 33] {
            let x: Vec<f32> = (0..n).map(|i| (i as f32 - 3.5) * 0.37).collect();
            let mut y: Vec<f32> = (0..n).map(|i| (i as f32) * 0.11 - 1.0).collect();
            let mut expect = y.clone();
            let a = 0.73f32;
            for (e, &xv) in expect.iter_mut().zip(&x) {
                *e += a * xv;
            }
            axpy(&mut y, &x, a);
            for (got, want) in y.iter().zip(&expect) {
                assert_eq!(got.to_bits(), want.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn dot_close_to_scalar() {
        for n in [0usize, 1, 5, 8, 13, 64, 100] {
            let a: Vec<f32> = (0..n).map(|i| ((i * 7 % 11) as f32 - 5.0) * 0.1).collect();
            let b: Vec<f32> = (0..n).map(|i| ((i * 3 % 13) as f32 - 6.0) * 0.2).collect();
            let scalar: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!(
                (got - scalar).abs() <= 1e-5 * scalar.abs().max(1.0),
                "n={n}: {got} vs {scalar}"
            );
        }
    }

    #[test]
    fn dot_deterministic() {
        let a: Vec<f32> = (0..97).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..97).map(|i| (i as f32).cos()).collect();
        assert_eq!(dot(&a, &b).to_bits(), dot(&a, &b).to_bits());
    }
}
