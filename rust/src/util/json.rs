//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Covers the subset the project needs: the AOT `manifest.json`, bench
//! result dumps, and config files.  Full escape handling for strings we
//! produce/consume; numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while self
                        .peek()
                        .map_or(false, |c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"artifacts":[{"b_max":512,"name":"cora_L2","residual":false}]}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(512.0).to_string(), "512");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
