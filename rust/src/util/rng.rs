//! Deterministic PRNG: SplitMix64 seeding + Xoshiro256** stream.
//!
//! crates.io `rand` is unavailable offline (DESIGN.md §7), and we want
//! bit-reproducible experiments anyway: every run in EXPERIMENTS.md is
//! keyed by an explicit u64 seed.

/// SplitMix64: used to expand a user seed into stream state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — fast, high-quality, 2^256 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-run splits).
    pub fn split(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; datagen is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.usize_below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..50 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(5);
        for _ in 0..20 {
            let s = r.sample_distinct(50, 10);
            assert_eq!(s.len(), 10);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(s.iter().all(|&x| x < 50));
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(123);
        let mut a = root.split(1);
        let mut b = root.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
