//! Shared substrates: deterministic PRNG, minimal JSON, stats/benching,
//! a tiny thread pool, the runtime-dispatched SIMD kernels, and the
//! seeded failpoint framework chaos tests replay bit-exactly
//! (tokio/rand/serde/criterion are unavailable in the offline build —
//! DESIGN.md §7).

pub mod failpoint;
pub mod json;
pub mod memstat;
pub mod pool;
pub mod rng;
pub mod simd;
pub mod stats;

pub use failpoint::InjectedFault;
pub use json::Json;
pub use pool::{pipeline, WorkerPool};
pub use rng::Rng;
pub use stats::{bench, entropy, Summary, Timer};
