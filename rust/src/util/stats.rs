//! Timing + summary statistics used by the bench harness (criterion is
//! unavailable offline; see DESIGN.md §7).

use std::time::{Duration, Instant};

/// Summary of a sample of measurements.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| {
            let idx = ((n as f64 - 1.0) * p).round() as usize;
            sorted[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(0.50),
            p95: pct(0.95),
            max: sorted[n - 1],
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} std={:.3} min={:.3} p50={:.3} p95={:.3} max={:.3}",
            self.n, self.mean, self.std, self.min, self.p50, self.p95, self.max
        )
    }
}

/// Measure `f` for `iters` iterations after `warmup` discarded runs;
/// returns per-iteration seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Shannon entropy (nats→bits conversion left to caller; the paper's
/// Fig. 2 uses label-distribution entropy per batch).
pub fn entropy(counts: &[usize]) -> f64 {
    let total: usize = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            h -= p * p.ln();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn entropy_uniform_max() {
        let h_uniform = entropy(&[10, 10, 10, 10]);
        let h_skewed = entropy(&[37, 1, 1, 1]);
        let h_point = entropy(&[40, 0, 0, 0]);
        assert!(h_uniform > h_skewed);
        assert!(h_skewed > h_point);
        assert!((h_uniform - (4.0f64).ln()).abs() < 1e-12);
        assert_eq!(h_point, 0.0);
    }

    #[test]
    fn entropy_empty() {
        assert_eq!(entropy(&[]), 0.0);
        assert_eq!(entropy(&[0, 0]), 0.0);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0usize;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }
}
