//! Scoped fork-join helpers over std::thread (no tokio offline).
//!
//! The coordinator uses this for batch-assembly prefetch and the bench
//! harness for parallel workload generation.  `std::thread::scope` keeps
//! lifetimes simple — no 'static bounds on closures.

/// Run `f(chunk_index, item_range)` over `n` items split into at most
/// `threads` contiguous chunks; returns per-chunk results in order.
pub fn parallel_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(threads);
    let ranges: Vec<_> = (0..threads)
        .map(|i| (i * chunk).min(n)..((i + 1) * chunk).min(n))
        .filter(|r| !r.is_empty())
        .collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let f = &f;
                let r = r.clone();
                s.spawn(move || f(i, r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Available parallelism with a sane floor.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_items() {
        let results = parallel_chunks(100, 7, |_, r| r.len());
        assert_eq!(results.iter().sum::<usize>(), 100);
    }

    #[test]
    fn single_item() {
        let results = parallel_chunks(1, 8, |i, r| (i, r.start, r.end));
        assert_eq!(results, vec![(0, 0, 1)]);
    }

    #[test]
    fn empty() {
        let results = parallel_chunks(0, 4, |_, _| 1);
        assert!(results.is_empty());
    }

    #[test]
    fn ordered_results() {
        let results = parallel_chunks(64, 4, |i, _| i);
        assert_eq!(results, vec![0, 1, 2, 3]);
    }
}
