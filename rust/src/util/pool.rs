//! Host thread pool: a **persistent** worker pool for the hot paths
//! (exact inference SpMM·GEMM, bench workload generation) plus a
//! double-buffered producer/consumer [`pipeline`] the trainer uses to
//! overlap batch assembly with PJRT execution.
//!
//! The original implementation spawned a fresh `std::thread::scope` per
//! call; on the L3 hot loop that is ~20-60 µs of thread create/join per
//! dispatch.  The pool keeps workers parked on a condvar and hands them
//! chunk ranges of a single active job, so a dispatch is one mutex
//! round-trip per chunk.  The spawn-per-call version survives as
//! [`scoped_chunks`] — it is the comparison baseline for the dispatch
//! probe in `examples/perf_probe.rs` and an independent oracle for the
//! pool property tests.
//!
//! Chunk layout is a pure function of `(n, n_chunks)` — never of worker
//! count or scheduling — so results written into disjoint output ranges
//! are deterministic and identical at every pool width.
//!
//! Constraint: dispatches must not nest — a chunk closure must not call
//! back into `run_chunks*` on the same pool (the pool runs one job at a
//! time, so the inner dispatch would wait on the outer job forever).
//! Concurrent dispatch from *different* threads is fine: jobs serialize,
//! and an idle submitter may even help drain the other's chunks.

use std::ops::Range;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Type-erased job shared with the workers.
///
/// The raw closure pointer is only dereferenced between job
/// installation and the final chunk completion; `run_chunks_with` does
/// not return (and therefore the closure's stack frame stays alive)
/// until `pending` hits zero, so workers never touch a dangling
/// pointer.
struct Job {
    f: *const (dyn Fn(usize, Range<usize>) + Sync),
    id: u64,
    n: usize,
    chunk: usize,
    n_chunks: usize,
    /// next chunk index to claim.
    next: usize,
    /// chunks not yet completed.
    pending: usize,
    /// a chunk closure panicked (re-raised on the submitting thread).
    panicked: bool,
}

// Safety: the pointee is `Sync` (concurrent calls are the point) and
// the completion protocol above bounds its lifetime.
unsafe impl Send for Job {}

struct State {
    job: Option<Job>,
    next_id: u64,
    /// ids of completed jobs that had a panicking chunk; each is
    /// drained by its own submitter, which re-raises.
    panicked: Vec<u64>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// signalled when a job is installed (workers wait here).
    work: Condvar,
    /// signalled when a job completes (submitters wait here).
    done: Condvar,
}

/// The single source of truth for the chunk decomposition — every
/// execution path (worker, helping submitter, serial fallback) derives
/// its ranges from this, so they can never diverge.
#[inline]
fn chunk_range(i: usize, chunk: usize, n: usize) -> Range<usize> {
    (i * chunk).min(n)..((i + 1) * chunk).min(n)
}

/// (closure, chunk index, item range) of a claimed chunk.
type Claimed = (*const (dyn Fn(usize, Range<usize>) + Sync), usize, Range<usize>);

fn claim(job: &mut Job) -> Option<Claimed> {
    if job.next < job.n_chunks {
        let i = job.next;
        job.next += 1;
        Some((job.f, i, chunk_range(i, job.chunk, job.n)))
    } else {
        None
    }
}

/// Execute a claimed chunk outside the lock, then report it complete.
/// A panicking closure is caught so the job still finishes (keeping
/// the erased closure pointer valid for the other chunks and the pool
/// functional); the panic is flagged on the job and re-raised by the
/// submitting thread after completion.
fn run_claimed(shared: &Shared, claimed: Claimed) -> std::sync::MutexGuard<'_, State> {
    let (f, i, range) = claimed;
    let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // Safety: see `Job` — the completion protocol keeps the closure
        // alive until every chunk (including this one) reports in below.
        unsafe { (*f)(i, range) };
    }))
    .is_err();
    let mut guard = shared.state.lock().unwrap();
    let j = guard.job.as_mut().expect("job cleared with chunks in flight");
    if panicked {
        j.panicked = true;
    }
    j.pending -= 1;
    if j.pending == 0 {
        let done = guard.job.take().expect("job vanished");
        if done.panicked {
            guard.panicked.push(done.id);
        }
        shared.done.notify_all();
    }
    guard
}

fn worker_loop(shared: &Shared) {
    let mut guard = shared.state.lock().unwrap();
    loop {
        if guard.shutdown {
            return;
        }
        match guard.job.as_mut().and_then(claim) {
            Some(claimed) => {
                drop(guard);
                guard = run_claimed(shared, claimed);
            }
            None => {
                guard = shared.work.wait(guard).unwrap();
            }
        }
    }
}

/// Persistent fork-join pool.  Workers are spawned once and parked
/// between jobs; the submitting thread participates in every job, so a
/// pool of width `t` runs `t`-wide with `t - 1` spawned threads.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                next_id: 0,
                panicked: Vec::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("cgcn-pool-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles, threads }
    }

    /// Parallel width (spawned workers + the submitting thread).
    pub fn width(&self) -> usize {
        self.threads
    }

    /// Run `f(chunk_index, item_range)` over `n` items split into
    /// pool-width chunks.  Blocks until every chunk has completed.
    pub fn run_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, Range<usize>) + Sync,
    {
        self.run_chunks_with(n, self.threads, f);
    }

    /// Like [`WorkerPool::run_chunks`] but with an explicit chunk count
    /// (chunk layout is `(n, n_chunks)`-determined, so callers that need
    /// a fixed decomposition — e.g. `parallel_chunks` — stay
    /// deterministic regardless of pool width).
    pub fn run_chunks_with<F>(&self, n: usize, n_chunks: usize, f: F)
    where
        F: Fn(usize, Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        // chaos-only latency fault; one untaken branch when disabled
        super::failpoint::maybe_delay("pool.run", 1);
        let n_chunks = n_chunks.max(1).min(n);
        if n_chunks == 1 || self.threads == 1 {
            // serial fast path still honours the requested decomposition
            let chunk = n.div_ceil(n_chunks);
            for i in 0..n.div_ceil(chunk) {
                f(i, chunk_range(i, chunk, n));
            }
            return;
        }
        let chunk = n.div_ceil(n_chunks);
        let n_chunks = n.div_ceil(chunk);

        let obj: &(dyn Fn(usize, Range<usize>) + Sync) = &f;
        // Safety: lifetime erasure only; this function does not return
        // until every chunk has run, so the pointer never dangles.
        let ptr: *const (dyn Fn(usize, Range<usize>) + Sync) =
            unsafe { std::mem::transmute(obj) };

        let my_id;
        {
            let mut guard = self.shared.state.lock().unwrap();
            while guard.job.is_some() {
                guard = self.shared.done.wait(guard).unwrap();
            }
            my_id = guard.next_id;
            guard.next_id = guard.next_id.wrapping_add(1);
            guard.job = Some(Job {
                f: ptr,
                id: my_id,
                n,
                chunk,
                n_chunks,
                next: 0,
                pending: n_chunks,
                panicked: false,
            });
        }
        self.shared.work.notify_all();

        // The submitting thread works too (it may also help a
        // concurrent submitter's job to completion, which is equally
        // bounded by that submitter's blocking wait).
        let mut guard = self.shared.state.lock().unwrap();
        loop {
            match guard.job.as_mut().and_then(claim) {
                Some(claimed) => {
                    drop(guard);
                    guard = run_claimed(&self.shared, claimed);
                }
                None => break,
            }
        }
        while matches!(&guard.job, Some(j) if j.id == my_id) {
            guard = self.shared.done.wait(guard).unwrap();
        }
        // re-raise a chunk panic (ours, not a helped job's) now that
        // the protocol is complete and the closure is out of use
        if let Some(pos) = guard.panicked.iter().position(|&id| id == my_id) {
            guard.panicked.swap_remove(pos);
            drop(guard);
            panic!("WorkerPool: a chunk closure panicked during this dispatch");
        }
    }

    /// Row-sliced variant writing into a caller-provided buffer: `out`
    /// is viewed as `rows` rows of `stride` elements; each chunk gets
    /// `f(chunk_index, row_range, &mut out[rows of that range])`.  The
    /// per-chunk slices are disjoint, so no copies or concatenation.
    pub fn run_rows<T, F>(&self, rows: usize, stride: usize, out: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, Range<usize>, &mut [T]) + Sync,
    {
        self.run_rows_with(rows, self.threads, stride, out, f);
    }

    /// [`WorkerPool::run_rows`] with an explicit chunk count.
    pub fn run_rows_with<T, F>(
        &self,
        rows: usize,
        n_chunks: usize,
        stride: usize,
        out: &mut [T],
        f: F,
    ) where
        T: Send,
        F: Fn(usize, Range<usize>, &mut [T]) + Sync,
    {
        assert_eq!(out.len(), rows * stride, "run_rows: out/rows/stride mismatch");
        let base = SendPtr(out.as_mut_ptr());
        self.run_chunks_with(rows, n_chunks, |i, r| {
            // Safety: chunk ranges are disjoint, so the row slices are
            // non-overlapping; `out` outlives the (blocking) dispatch.
            let slice = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(r.start * stride), r.len() * stride)
            };
            f(i, r, slice);
        });
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // &mut self proves no run_chunks is in flight (they borrow &self),
        // so workers are idle and exit at the next wakeup.
        {
            let mut g = self.shared.state.lock().unwrap();
            g.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

struct SendPtr<T>(*mut T);
// Safety: used only to smuggle a base pointer into Sync closures that
// write disjoint ranges (see `run_rows_with`).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// The process-wide pool (width = available parallelism), created on
/// first use and kept for the process lifetime.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| {
        // Resolve the SIMD dispatch table alongside pool startup so the
        // one-time feature detection + env read never lands inside a
        // timed kernel (kernels would otherwise resolve it lazily).
        crate::util::simd::init();
        WorkerPool::new(default_threads())
    })
}

/// Run `f(chunk_index, item_range)` over `n` items split into at most
/// `threads` contiguous chunks; returns per-chunk results in order.
/// Same API/decomposition as the original spawn-per-call helper, now
/// executed on the persistent global pool.
pub fn parallel_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Range<usize>) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let chunk = n.div_ceil(threads);
    let n_chunks = n.div_ceil(chunk);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n_chunks);
    out.resize_with(n_chunks, || None);
    {
        let slots = SendPtr(out.as_mut_ptr());
        global().run_chunks_with(n, n_chunks, |i, r| {
            // Safety: chunk i writes slot i exactly once; slots disjoint.
            unsafe { *slots.0.add(i) = Some(f(i, r)) };
        });
    }
    out.into_iter()
        .map(|o| o.expect("pool skipped a chunk"))
        .collect()
}

/// Spawn-per-call fork-join over `std::thread::scope` — the pre-pool
/// implementation, kept as the dispatch-overhead baseline
/// (`examples/perf_probe.rs`) and as an independent oracle in the pool
/// property tests.
pub fn scoped_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Range<usize>) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(threads);
    let ranges: Vec<_> = (0..threads)
        .map(|i| (i * chunk).min(n)..((i + 1) * chunk).min(n))
        .filter(|r| !r.is_empty())
        .collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let f = &f;
                let r = r.clone();
                s.spawn(move || f(i, r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Available parallelism with a sane floor.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Double-buffered producer/consumer pipeline over exactly two reusable
/// buffers: `produce(i, &mut T)` runs on a helper thread one item ahead
/// of `consume(i, &mut T)` on the calling thread (items are consumed in
/// production order).  Used by the trainer to assemble batch `i + 1`
/// while PJRT executes batch `i`.  `consume` returning `false` stops
/// the pipeline early.  Returns the two buffers for reuse by the next
/// epoch — no per-item allocation.
pub fn pipeline<T, P, C>(n: usize, a: T, b: T, mut produce: P, mut consume: C) -> (T, T)
where
    T: Send,
    P: FnMut(usize, &mut T) + Send,
    C: FnMut(usize, &mut T) -> bool,
{
    if n == 0 {
        return (a, b);
    }
    use std::sync::mpsc::channel;
    std::thread::scope(|s| {
        let (free_tx, free_rx) = channel::<T>();
        let (ready_tx, ready_rx) = channel::<T>();
        free_tx.send(a).expect("fresh channel");
        free_tx.send(b).expect("fresh channel");
        let producer = s.spawn(move || {
            for i in 0..n {
                let Ok(mut buf) = free_rx.recv() else {
                    return free_rx; // consumer stopped early
                };
                produce(i, &mut buf);
                if ready_tx.send(buf).is_err() {
                    return free_rx;
                }
            }
            free_rx
        });
        let mut recovered: Vec<T> = Vec::with_capacity(2);
        for i in 0..n {
            let Ok(mut buf) = ready_rx.recv() else { break };
            if consume(i, &mut buf) {
                let _ = free_tx.send(buf);
            } else {
                recovered.push(buf);
                break;
            }
        }
        drop(free_tx);
        let free_rx = producer.join().expect("pipeline producer panicked");
        while let Ok(buf) = free_rx.try_recv() {
            recovered.push(buf);
        }
        while let Ok(buf) = ready_rx.try_recv() {
            recovered.push(buf);
        }
        let b_out = recovered.pop().expect("pipeline lost a buffer");
        let a_out = recovered.pop().expect("pipeline lost a buffer");
        (a_out, b_out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

    #[test]
    fn covers_all_items() {
        let results = parallel_chunks(100, 7, |_, r| r.len());
        assert_eq!(results.iter().sum::<usize>(), 100);
    }

    #[test]
    fn single_item() {
        let results = parallel_chunks(1, 8, |i, r| (i, r.start, r.end));
        assert_eq!(results, vec![(0, 0, 1)]);
    }

    #[test]
    fn empty() {
        let results = parallel_chunks(0, 4, |_, _| 1);
        assert!(results.is_empty());
    }

    #[test]
    fn ordered_results() {
        let results = parallel_chunks(64, 4, |i, _| i);
        assert_eq!(results, vec![0, 1, 2, 3]);
    }

    #[test]
    fn matches_spawn_per_call_decomposition() {
        for n in [0usize, 1, 5, 64, 100, 1000] {
            for threads in [1usize, 2, 3, 7, 16] {
                let pooled = parallel_chunks(n, threads, |i, r| (i, r.start, r.end));
                let spawned = scoped_chunks(n, threads, |i, r| (i, r.start, r.end));
                assert_eq!(pooled, spawned, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn pool_covers_each_item_exactly_once() {
        let pool = WorkerPool::new(4);
        let n = 1000;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        pool.run_chunks_with(n, 13, |_, r| {
            for j in r {
                hits[j].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn pool_reusable_across_many_jobs() {
        let pool = WorkerPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run_chunks(64, |_, r| {
                total.fetch_add(r.len(), Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200 * 64);
    }

    #[test]
    fn run_rows_writes_disjoint_slices() {
        let pool = WorkerPool::new(4);
        let rows = 37;
        let stride = 5;
        let mut out = vec![u32::MAX; rows * stride];
        pool.run_rows_with(rows, 6, stride, &mut out, |_, range, slice| {
            assert_eq!(slice.len(), range.len() * stride);
            for (k, v) in slice.iter_mut().enumerate() {
                *v = (range.start * stride + k) as u32;
            }
        });
        let expect: Vec<u32> = (0..(rows * stride) as u32).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn concurrent_submitters_serialize() {
        let pool = WorkerPool::new(4);
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..50 {
                    pool.run_chunks(100, |_, r| {
                        a.fetch_add(r.len(), Ordering::Relaxed);
                    });
                }
            });
            s.spawn(|| {
                for _ in 0..50 {
                    pool.run_chunks(100, |_, r| {
                        b.fetch_add(r.len(), Ordering::Relaxed);
                    });
                }
            });
        });
        assert_eq!(a.load(Ordering::Relaxed), 5000);
        assert_eq!(b.load(Ordering::Relaxed), 5000);
    }

    #[test]
    fn panicking_chunk_fails_dispatch_but_pool_survives() {
        let pool = WorkerPool::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_chunks_with(8, 8, |i, _| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "submitter must re-raise the chunk panic");
        // the pool is not wedged: later dispatches complete normally
        let total = AtomicUsize::new(0);
        pool.run_chunks(100, |_, r| {
            total.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn global_pool_is_persistent() {
        let p1 = global() as *const WorkerPool;
        global().run_chunks(10, |_, _| {});
        let p2 = global() as *const WorkerPool;
        assert_eq!(p1, p2);
    }

    #[test]
    fn pipeline_consumes_in_order_and_returns_buffers() {
        let (a, b) = pipeline(
            7,
            Vec::<usize>::new(),
            Vec::<usize>::new(),
            |i, buf| {
                buf.clear();
                buf.push(i);
            },
            |i, buf| {
                assert_eq!(buf, &vec![i]);
                true
            },
        );
        // both buffers came back with their capacity intact
        assert!(a.capacity() >= 1 && b.capacity() >= 1);
    }

    #[test]
    fn pipeline_early_stop_recovers_both_buffers() {
        let mut seen = 0usize;
        let (a, b) = pipeline(
            100,
            vec![0u8; 8],
            vec![0u8; 8],
            |i, buf| buf[0] = i as u8,
            |_, _| {
                seen += 1;
                seen < 3
            },
        );
        assert_eq!(seen, 3);
        assert_eq!(a.len(), 8);
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn pipeline_zero_items() {
        let (a, b) = pipeline(0, 1u32, 2u32, |_, _| {}, |_, _| true);
        assert_eq!((a, b), (1, 2));
    }
}
