//! Process memory readings from `/proc/self/status`.
//!
//! The out-of-core storage work (ISSUE 9 / ROADMAP item 1) is judged on
//! peak resident set size: the paper's Table 8 headline is Amazon2M in
//! 2.2 GB while every competing method OOMs.  Every `BENCH_*.json`
//! writer records `peak_rss_bytes` via this module so the memory
//! trajectory is tracked from this PR onward.
//!
//! Linux-only by nature (procfs); on other platforms the readers return
//! `None` and the bench writers record 0 rather than failing — the
//! numbers are a measurement, not a correctness gate.

/// A point-in-time memory reading.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemStat {
    /// `VmRSS`: current resident set size, bytes.
    pub rss_bytes: u64,
    /// `VmHWM`: peak resident set size ("high water mark"), bytes.
    pub peak_rss_bytes: u64,
}

/// Read `VmRSS` / `VmHWM` from `/proc/self/status`.
///
/// Returns `None` when procfs is unavailable (non-Linux) or the fields
/// are missing/unparseable.
pub fn read() -> Option<MemStat> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let rss = parse_kb_line(&status, "VmRSS:")?;
    let peak = parse_kb_line(&status, "VmHWM:")?;
    Some(MemStat { rss_bytes: rss, peak_rss_bytes: peak })
}

/// Peak RSS in bytes, or 0 when unavailable.  The convenience form the
/// bench writers use: a missing procfs degrades to a recorded zero.
pub fn peak_rss_bytes() -> u64 {
    read().map(|m| m.peak_rss_bytes).unwrap_or(0)
}

/// Parse a `/proc/self/status` line of the form `Key:   12345 kB`
/// into bytes.
fn parse_kb_line(status: &str, key: &str) -> Option<u64> {
    let line = status.lines().find(|l| l.starts_with(key))?;
    let rest = line[key.len()..].trim();
    let num = rest.split_whitespace().next()?;
    let kb: u64 = num.parse().ok()?;
    // the kernel reports these fields in kB
    Some(kb * 1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_kb_lines() {
        let s = "Name:\tcargo\nVmHWM:\t  204800 kB\nVmRSS:\t   10240 kB\n";
        assert_eq!(parse_kb_line(s, "VmRSS:"), Some(10240 * 1024));
        assert_eq!(parse_kb_line(s, "VmHWM:"), Some(204800 * 1024));
        assert_eq!(parse_kb_line(s, "VmSwap:"), None);
    }

    #[test]
    fn malformed_lines_are_none() {
        assert_eq!(parse_kb_line("VmRSS: lots kB\n", "VmRSS:"), None);
        assert_eq!(parse_kb_line("", "VmRSS:"), None);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn live_reading_is_sane() {
        let m = read().expect("procfs reading on linux");
        // a running test binary is at least 1 MB resident and the high
        // water mark can never be below the current RSS
        assert!(m.rss_bytes > 1 << 20);
        assert!(m.peak_rss_bytes >= m.rss_bytes);
        assert!(peak_rss_bytes() >= m.rss_bytes);
    }
}
