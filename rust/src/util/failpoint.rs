//! Deterministic fault injection: named failpoint sites compiled into
//! the checkpoint/batch/pool/sharded/serve paths, activated by a parsed
//! spec (programmatic or `CGCN_FAILPOINTS`/`CGCN_FAIL_SEED` env vars)
//! and seeded so a chaos run replays **bit-exactly** — same seed, same
//! hit sequence ⇒ same injected faults.
//!
//! ## Cost when disabled
//!
//! Every site check is a single relaxed atomic load plus an untaken
//! branch — no allocation, no lock, no RNG draw — so the steady-state
//! zero-allocation pins on the training hot path hold with the sites
//! compiled in.  The registry lock is only touched while a spec is
//! installed.
//!
//! ## Spec grammar
//!
//! `site=prob[:max[:skip]]`, semicolon- or comma-separated:
//!
//! - `prob` — probability each *eligible* hit fires (1 = always);
//! - `max` — total fires allowed (0 = unlimited, the default);
//! - `skip` — hits to pass through before the site becomes eligible.
//!
//! `ckpt.torn=1:1` fires exactly once on the first checkpoint write;
//! `driver.loss=1:1:12` corrupts the reported loss of the 13th step.
//! Each site draws from its own [`Rng`] stream seeded by
//! `(seed, fnv(site))`, so sites are independent and adding one does
//! not shift another's sequence.
//!
//! ## Site map
//!
//! | site              | effect at the call site                         |
//! |-------------------|-------------------------------------------------|
//! | `ckpt.write`      | typed IO error before the tmp write starts      |
//! | `ckpt.torn`       | tmp file cut mid-write (crash mid-save)         |
//! | `driver.step`     | typed error from the training step              |
//! | `driver.loss`     | reported step loss becomes NaN (weights intact) |
//! | `batch.assemble`  | assembly stalls (latency fault)                 |
//! | `pool.run`        | worker-pool dispatch stalls (latency fault)     |
//! | `shard.exchange`  | typed error in the sharded gradient exchange    |
//! | `serve.flush`     | flush fails with `ServeError::Injected`         |
//! | `serve.flush.delay` | flush stalls (drives queue pressure)          |
//! | `dist.send.drop`  | distributed step request dropped before the write (chief reconnects + retries) |
//! | `dist.send.torn`  | distributed step request cut mid-frame (worker CRC-fails and redials) |
//! | `dist.recv.delay` | distributed gradient response stalls (latency fault) |
//!
//! Faults are *simulations at the recovery seam*: `driver.loss`
//! corrupts only the reported loss (never the weights), so a guarded
//! rollback's post-recovery trajectory can be compared bitwise against
//! the fault-free run.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use super::rng::Rng;

/// A fault fired by an active failpoint — the typed error injected
/// sites propagate instead of panicking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InjectedFault {
    /// Name of the site that fired.
    pub site: &'static str,
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected fault at failpoint `{}`", self.site)
    }
}

impl std::error::Error for InjectedFault {}

/// Per-site counters, for chaos-test assertions and reports.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SiteReport {
    /// Site name as configured.
    pub site: String,
    /// Times the site was evaluated while active.
    pub hits: u64,
    /// Times it actually fired.
    pub fires: u64,
}

struct Site {
    name: String,
    prob: f64,
    max_fires: u64,
    skip: u64,
    hits: u64,
    fires: u64,
    rng: Rng,
}

/// `true` iff a spec is installed; the one word every disabled-path
/// check reads.
static ENABLED: AtomicBool = AtomicBool::new(false);

static REGISTRY: Mutex<Vec<Site>> = Mutex::new(Vec::new());

fn fnv(s: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    h
}

fn lock_registry() -> std::sync::MutexGuard<'static, Vec<Site>> {
    // a panic while holding this lock leaves only counters half-updated
    REGISTRY.lock().unwrap_or_else(|p| p.into_inner())
}

/// Install a failpoint plan; replaces any active plan.  See the module
/// docs for the grammar.  An empty spec deactivates everything (same as
/// [`clear`]).
pub fn install(spec: &str, seed: u64) -> Result<(), String> {
    let mut sites = Vec::new();
    for part in spec.split([';', ',']).map(str::trim).filter(|p| !p.is_empty()) {
        let (name, rest) = part
            .split_once('=')
            .ok_or_else(|| format!("failpoint `{part}` is missing `=prob`"))?;
        let name = name.trim();
        if name.is_empty() {
            return Err(format!("failpoint `{part}` has an empty site name"));
        }
        let mut fields = rest.split(':');
        let prob: f64 = fields
            .next()
            .unwrap_or("")
            .trim()
            .parse()
            .map_err(|_| format!("failpoint `{name}`: bad probability in `{rest}`"))?;
        if !(0.0..=1.0).contains(&prob) {
            return Err(format!("failpoint `{name}`: probability {prob} not in [0, 1]"));
        }
        let max_fires: u64 = match fields.next() {
            None => 0,
            Some(v) => v
                .trim()
                .parse()
                .map_err(|_| format!("failpoint `{name}`: bad max-fires in `{rest}`"))?,
        };
        let skip: u64 = match fields.next() {
            None => 0,
            Some(v) => v
                .trim()
                .parse()
                .map_err(|_| format!("failpoint `{name}`: bad skip count in `{rest}`"))?,
        };
        if fields.next().is_some() {
            return Err(format!("failpoint `{name}`: too many `:` fields in `{rest}`"));
        }
        sites.push(Site {
            name: name.to_string(),
            prob,
            max_fires,
            skip,
            hits: 0,
            fires: 0,
            rng: Rng::new(seed ^ fnv(name)),
        });
    }
    let active = !sites.is_empty();
    *lock_registry() = sites;
    ENABLED.store(active, Ordering::Release);
    Ok(())
}

/// Install from `CGCN_FAILPOINTS` (+ optional `CGCN_FAIL_SEED`, default
/// 0); returns whether a plan was activated.  Unset env ⇒ no-op.
pub fn install_from_env() -> Result<bool, String> {
    let spec = match std::env::var("CGCN_FAILPOINTS") {
        Ok(s) if !s.trim().is_empty() => s,
        _ => return Ok(false),
    };
    let seed = match std::env::var("CGCN_FAIL_SEED") {
        Ok(s) => s
            .trim()
            .parse()
            .map_err(|_| format!("CGCN_FAIL_SEED `{s}` is not a u64"))?,
        Err(_) => 0,
    };
    install(&spec, seed)?;
    Ok(active())
}

/// Deactivate every site and drop the plan.
pub fn clear() {
    ENABLED.store(false, Ordering::Release);
    lock_registry().clear();
}

/// Whether any failpoint plan is active.
pub fn active() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// Should `site` fire this hit?  The disabled path is one relaxed
/// atomic load and an untaken branch — safe on zero-allocation pins.
#[inline]
pub fn should_fail(site: &str) -> bool {
    if !ENABLED.load(Ordering::Relaxed) {
        return false;
    }
    should_fail_slow(site)
}

#[cold]
fn should_fail_slow(site: &str) -> bool {
    let mut reg = lock_registry();
    let s = match reg.iter_mut().find(|s| s.name == site) {
        Some(s) => s,
        None => return false,
    };
    s.hits += 1;
    if s.hits <= s.skip {
        return false;
    }
    if s.max_fires > 0 && s.fires >= s.max_fires {
        return false;
    }
    // always draw, so firing history stays a pure function of the
    // eligible-hit index regardless of prior outcomes
    let fire = s.rng.f64() < s.prob;
    if fire {
        s.fires += 1;
    }
    fire
}

/// `Err(InjectedFault)` when `site` fires — the one-liner error-path
/// sites use (`failpoint::check("ckpt.write")?`).
#[inline]
pub fn check(site: &'static str) -> Result<(), InjectedFault> {
    if should_fail(site) {
        Err(InjectedFault { site })
    } else {
        Ok(())
    }
}

/// Stall for `ms` when `site` fires — the latency-fault injector for
/// infallible paths (batch assembly, pool dispatch, serve flushes).
#[inline]
pub fn maybe_delay(site: &str, ms: u64) {
    if should_fail(site) {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// Counter snapshot for every configured site (configured order).
pub fn report() -> Vec<SiteReport> {
    lock_registry()
        .iter()
        .map(|s| SiteReport { site: s.name.clone(), hits: s.hits, fires: s.fires })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Registry state is process-global; serialize the tests that
    /// install plans.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_is_inert() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        clear();
        assert!(!active());
        for _ in 0..1000 {
            assert!(!should_fail("anything"));
        }
        assert!(check("anything").is_ok());
        assert!(report().is_empty());
    }

    #[test]
    fn spec_parses_and_fires_deterministically() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let run = |seed: u64| -> Vec<bool> {
            install("a.b=0.5; c=1:2:3", seed).unwrap();
            let fired: Vec<bool> = (0..64).map(|_| should_fail("a.b")).collect();
            clear();
            fired
        };
        let (x, y) = (run(7), run(7));
        assert_eq!(x, y, "same seed must replay the same fault sequence");
        assert!(x.iter().any(|&f| f) && x.iter().any(|&f| !f), "p=0.5 mixes outcomes");
        let z = run(8);
        assert_ne!(x, z, "different seeds should diverge");
    }

    #[test]
    fn skip_and_max_fires_bound_the_site() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        install("s=1:2:3", 0).unwrap();
        let fired: Vec<bool> = (0..10).map(|_| should_fail("s")).collect();
        assert_eq!(
            fired,
            vec![false, false, false, true, true, false, false, false, false, false],
            "skip 3 hits, then fire exactly twice"
        );
        let rep = report();
        assert_eq!(rep.len(), 1);
        assert_eq!((rep[0].hits, rep[0].fires), (10, 2));
        // unknown sites never fire even while a plan is active
        assert!(!should_fail("unknown"));
        clear();
    }

    #[test]
    fn check_returns_the_typed_fault() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        install("typed=1:1", 0).unwrap();
        let e = check("typed").unwrap_err();
        assert_eq!(e.site, "typed");
        assert!(e.to_string().contains("typed"));
        assert!(check("typed").is_ok(), "max-fires exhausted");
        clear();
    }

    #[test]
    fn bad_specs_are_rejected() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        clear();
        for bad in ["nameonly", "x=", "x=2.0", "x=0.5:a", "x=0.5:1:b", "x=1:1:1:1", "=1"] {
            assert!(install(bad, 0).is_err(), "spec {bad:?} should be rejected");
        }
        assert!(!active(), "a rejected spec must not activate anything");
    }
}
