//! One-time runtime backend selection: a function-pointer [`Table`] per
//! backend, detected candidates, and the process-wide active table
//! cached in a [`OnceLock`].
//!
//! Selection rules (documented in PERF.md "SIMD backends & dispatch"):
//!
//! - `CGCN_SIMD=<name>` forces a backend by name; an unknown or
//!   unsupported name falls back to `portable` (never a panic — a
//!   trace recorded on an AVX-512 box must still replay on a laptop).
//! - With no override, the **last bit-stable candidate** wins: portable
//!   → avx2 on an AVX2 x86 host → neon on aarch64.  The default never
//!   auto-selects FMA: the golden-trace suite asserts bitwise equality
//!   across backends, and fused multiply-adds change result bits.
//!   `CGCN_SIMD=fma` is an explicit opt-in with tolerance-only
//!   contracts.
//! - The table is resolved once per process (first use or
//!   [`super::init`]) and cannot change afterwards; per-backend A/B
//!   within one process goes through [`super::BackendHandle`] instead
//!   of the env override.

use std::sync::OnceLock;

use super::portable;

/// Function-pointer table for one backend.  All entries share the
/// portable kernels' signatures and bounds contracts; `bit_stable`
/// records whether every kernel is bit-identical to portable (false
/// only for fused/reordered paths like FMA).
pub struct Table {
    /// Backend name as accepted by `CGCN_SIMD` and reported by
    /// [`super::active_backend`].
    pub name: &'static str,
    /// Whether every kernel in this table is bit-identical to the
    /// portable oracle (FMA fuses rounding, so it is not).
    pub bit_stable: bool,
    /// `y[i] += a * x[i]` (equal lengths, caller-checked).
    pub axpy: fn(&mut [f32], &[f32], f32),
    /// 8-lane dot product (equal lengths, caller-checked).
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// Accumulating GEMM tile; see [`portable::gemm_tile`] for the
    /// layout parameters.
    pub gemm_tile: fn(&mut [f32], usize, &[f32], usize, usize, &[f32], usize, usize, usize, usize),
}

/// The always-available fallback and parity oracle.
pub static PORTABLE: Table = Table {
    name: "portable",
    bit_stable: true,
    axpy: portable::axpy,
    dot: portable::dot,
    gemm_tile: portable::gemm_tile,
};

/// All backends usable on this host, detection-ordered: `portable`
/// first, then specialized tables from least to most aggressive.  The
/// default pick is the last **bit-stable** entry.
pub fn candidates() -> Vec<&'static Table> {
    #[allow(unused_mut)]
    let mut tables: Vec<&'static Table> = vec![&PORTABLE];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            tables.push(&super::x86::AVX2);
            if std::arch::is_x86_feature_detected!("fma") {
                tables.push(&super::x86::FMA);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is a mandatory feature of aarch64 — no detection needed.
        tables.push(&super::neon::NEON);
    }
    tables
}

/// Resolve the table for an optional forced name: exact match among
/// detected candidates, else the default (last bit-stable candidate).
fn select(force: Option<&str>) -> &'static Table {
    let tables = candidates();
    if let Some(name) = force {
        if let Some(t) = tables.iter().find(|t| t.name == name) {
            return t;
        }
        return &PORTABLE;
    }
    tables
        .iter()
        .rev()
        .find(|t| t.bit_stable)
        .copied()
        .unwrap_or(&PORTABLE)
}

/// The process-wide active table; `CGCN_SIMD` is read exactly once, on
/// the first call (normally pool startup via [`super::init`]).
pub fn active() -> &'static Table {
    static ACTIVE: OnceLock<&'static Table> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let force = std::env::var("CGCN_SIMD").ok();
        select(force.as_deref())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn portable_is_always_a_candidate() {
        let names: Vec<&str> = candidates().iter().map(|t| t.name).collect();
        assert_eq!(names[0], "portable");
    }

    #[test]
    fn forced_unknown_name_falls_back_to_portable() {
        assert_eq!(select(Some("avx512-unicorn")).name, "portable");
        assert_eq!(select(Some("portable")).name, "portable");
    }

    #[test]
    fn default_selection_is_bit_stable() {
        assert!(select(None).bit_stable, "default must never pick FMA");
    }

    #[test]
    fn active_is_stable_across_calls() {
        assert!(std::ptr::eq(active(), active()));
    }
}
