//! Explicit AVX2 / AVX2+FMA kernels (`core::arch::x86_64`).
//!
//! Two tables, duplicated rather than macro-generated so the numeric
//! contract of each is visible in the source:
//!
//! - [`AVX2`] — separate multiply + add (`_mm256_mul_ps` then
//!   `_mm256_add_ps`), every element rounded exactly like the portable
//!   loops, so `axpy`/`gemm_tile` are **bit-identical** to portable and
//!   `dot` reproduces portable's 8-lane accumulate + fixed-order
//!   reduction bit-for-bit.  This is the auto-selected default on AVX2
//!   hosts (`bit_stable: true`).
//! - [`FMA`] — `_mm256_fmadd_ps` fuses the multiply-add with a single
//!   rounding, so results differ from portable in the last ulps.
//!   Tolerance-only contract; never auto-selected (`CGCN_SIMD=fma`
//!   opt-in).
//!
//! Every kernel is an `unsafe fn` under `#[target_feature]` with a safe
//! wrapper.  Soundness: the wrappers are reachable only through
//! [`super::dispatch`] tables that `candidates()` includes *after*
//! `is_x86_feature_detected!` passes, so the target features are
//! guaranteed present whenever the wrapped code runs.

#![cfg(target_arch = "x86_64")]

use super::dispatch::Table;

/// AVX2 without fused multiply-add: bit-identical to portable.
pub static AVX2: Table = Table {
    name: "avx2",
    bit_stable: true,
    axpy: axpy_avx2_safe,
    dot: dot_avx2_safe,
    gemm_tile: gemm_tile_avx2_safe,
};

/// AVX2 with fused multiply-add: fastest, tolerance-only contract.
pub static FMA: Table = Table {
    name: "fma",
    bit_stable: false,
    axpy: axpy_fma_safe,
    dot: dot_fma_safe,
    gemm_tile: gemm_tile_fma_safe,
};

// ---- safe wrappers (see module docs for the soundness argument) ----

fn axpy_avx2_safe(y: &mut [f32], x: &[f32], a: f32) {
    // SAFETY: only dispatched after is_x86_feature_detected!("avx2").
    unsafe { axpy_avx2(y, x, a) }
}

fn dot_avx2_safe(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: only dispatched after is_x86_feature_detected!("avx2").
    unsafe { dot_avx2(a, b) }
}

#[allow(clippy::too_many_arguments)]
fn gemm_tile_avx2_safe(
    out: &mut [f32],
    ldo: usize,
    p: &[f32],
    ldp: usize,
    pks: usize,
    w: &[f32],
    ldw: usize,
    rows: usize,
    kn: usize,
    cols: usize,
) {
    // SAFETY: only dispatched after is_x86_feature_detected!("avx2");
    // slice bounds are asserted by the public wrapper in `super`.
    unsafe { gemm_tile_avx2(out, ldo, p, ldp, pks, w, ldw, rows, kn, cols) }
}

fn axpy_fma_safe(y: &mut [f32], x: &[f32], a: f32) {
    // SAFETY: only dispatched after is_x86_feature_detected!("fma").
    unsafe { axpy_fma(y, x, a) }
}

fn dot_fma_safe(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: only dispatched after is_x86_feature_detected!("fma").
    unsafe { dot_fma(a, b) }
}

#[allow(clippy::too_many_arguments)]
fn gemm_tile_fma_safe(
    out: &mut [f32],
    ldo: usize,
    p: &[f32],
    ldp: usize,
    pks: usize,
    w: &[f32],
    ldw: usize,
    rows: usize,
    kn: usize,
    cols: usize,
) {
    // SAFETY: only dispatched after is_x86_feature_detected!("fma");
    // slice bounds are asserted by the public wrapper in `super`.
    unsafe { gemm_tile_fma(out, ldo, p, ldp, pks, w, ldw, rows, kn, cols) }
}

// ---- AVX2 (non-fused) kernels -------------------------------------

/// # Safety
/// Requires AVX2. `y.len() == x.len()` (debug-asserted).
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(y: &mut [f32], x: &[f32], a: f32) {
    unsafe {
        use core::arch::x86_64::*;
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let yp = y.as_mut_ptr();
        let xp = x.as_ptr();
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let yv = _mm256_loadu_ps(yp.add(i));
            let xv = _mm256_loadu_ps(xp.add(i));
            // mul then add, matching portable's `y += a * x` rounding.
            _mm256_storeu_ps(yp.add(i), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
            i += 8;
        }
        while i < n {
            *yp.add(i) += a * *xp.add(i);
            i += 1;
        }
    }
}

/// # Safety
/// Requires AVX2. `a.len() == b.len()` (debug-asserted).
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    unsafe {
        use core::arch::x86_64::*;
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        // One YMM accumulator = portable's 8 independent lanes, updated
        // vertically in the same order.
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let av = _mm256_loadu_ps(ap.add(i));
            let bv = _mm256_loadu_ps(bp.add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
            i += 8;
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut tail = 0f32;
        while i < n {
            tail += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        // Portable's exact reduction order.
        let even = (lanes[0] + lanes[2]) + (lanes[4] + lanes[6]);
        let odd = (lanes[1] + lanes[3]) + (lanes[5] + lanes[7]);
        (even + odd) + tail
    }
}

/// Register-blocked 8×8 accumulating GEMM tile (see
/// [`super::portable::gemm_tile`] for the layout parameters): 8 row
/// accumulators live in YMM registers across the whole k loop, one
/// `w`-row load per k shared by 8 broadcasts of `p`.
///
/// Per output element the accumulation is ascending-k mul+add with the
/// same `p == 0.0` skip as portable — bit-identical.
///
/// # Safety
/// Requires AVX2.  Slice bounds per the public wrapper's asserts.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_tile_avx2(
    out: &mut [f32],
    ldo: usize,
    p: &[f32],
    ldp: usize,
    pks: usize,
    w: &[f32],
    ldw: usize,
    rows: usize,
    kn: usize,
    cols: usize,
) {
    unsafe {
        use core::arch::x86_64::*;
        let op = out.as_mut_ptr();
        let pp = p.as_ptr();
        let wp = w.as_ptr();
        let mut c = 0;
        while c + 8 <= cols {
            let mut r = 0;
            while r + 8 <= rows {
                let mut acc = [_mm256_setzero_ps(); 8];
                for rr in 0..8 {
                    acc[rr] = _mm256_loadu_ps(op.add((r + rr) * ldo + c));
                }
                for k in 0..kn {
                    let wv = _mm256_loadu_ps(wp.add(k * ldw + c));
                    for rr in 0..8 {
                        let pv = *pp.add((r + rr) * ldp + k * pks);
                        if pv != 0.0 {
                            acc[rr] = _mm256_add_ps(acc[rr], _mm256_mul_ps(_mm256_set1_ps(pv), wv));
                        }
                    }
                }
                for rr in 0..8 {
                    _mm256_storeu_ps(op.add((r + rr) * ldo + c), acc[rr]);
                }
                r += 8;
            }
            while r < rows {
                let mut acc = _mm256_loadu_ps(op.add(r * ldo + c));
                for k in 0..kn {
                    let pv = *pp.add(r * ldp + k * pks);
                    if pv != 0.0 {
                        let wv = _mm256_loadu_ps(wp.add(k * ldw + c));
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(pv), wv));
                    }
                }
                _mm256_storeu_ps(op.add(r * ldo + c), acc);
                r += 1;
            }
            c += 8;
        }
        if c < cols {
            for r in 0..rows {
                for k in 0..kn {
                    let pv = *pp.add(r * ldp + k * pks);
                    if pv == 0.0 {
                        continue;
                    }
                    for j in c..cols {
                        *op.add(r * ldo + j) += pv * *wp.add(k * ldw + j);
                    }
                }
            }
        }
    }
}

// ---- AVX2+FMA kernels ---------------------------------------------

/// # Safety
/// Requires AVX2 and FMA. `y.len() == x.len()` (debug-asserted).
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_fma(y: &mut [f32], x: &[f32], a: f32) {
    unsafe {
        use core::arch::x86_64::*;
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let yp = y.as_mut_ptr();
        let xp = x.as_ptr();
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let yv = _mm256_loadu_ps(yp.add(i));
            let xv = _mm256_loadu_ps(xp.add(i));
            _mm256_storeu_ps(yp.add(i), _mm256_fmadd_ps(av, xv, yv));
            i += 8;
        }
        while i < n {
            *yp.add(i) = a.mul_add(*xp.add(i), *yp.add(i));
            i += 1;
        }
    }
}

/// # Safety
/// Requires AVX2 and FMA. `a.len() == b.len()` (debug-asserted).
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
    unsafe {
        use core::arch::x86_64::*;
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let av = _mm256_loadu_ps(ap.add(i));
            let bv = _mm256_loadu_ps(bp.add(i));
            acc = _mm256_fmadd_ps(av, bv, acc);
            i += 8;
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut tail = 0f32;
        while i < n {
            tail = (*ap.add(i)).mul_add(*bp.add(i), tail);
            i += 1;
        }
        let even = (lanes[0] + lanes[2]) + (lanes[4] + lanes[6]);
        let odd = (lanes[1] + lanes[3]) + (lanes[5] + lanes[7]);
        (even + odd) + tail
    }
}

/// FMA variant of [`gemm_tile_avx2`]: same blocking, fused
/// multiply-adds (tolerance-only contract).
///
/// # Safety
/// Requires AVX2 and FMA.  Slice bounds per the public wrapper's
/// asserts.
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_tile_fma(
    out: &mut [f32],
    ldo: usize,
    p: &[f32],
    ldp: usize,
    pks: usize,
    w: &[f32],
    ldw: usize,
    rows: usize,
    kn: usize,
    cols: usize,
) {
    unsafe {
        use core::arch::x86_64::*;
        let op = out.as_mut_ptr();
        let pp = p.as_ptr();
        let wp = w.as_ptr();
        let mut c = 0;
        while c + 8 <= cols {
            let mut r = 0;
            while r + 8 <= rows {
                let mut acc = [_mm256_setzero_ps(); 8];
                for rr in 0..8 {
                    acc[rr] = _mm256_loadu_ps(op.add((r + rr) * ldo + c));
                }
                for k in 0..kn {
                    let wv = _mm256_loadu_ps(wp.add(k * ldw + c));
                    for rr in 0..8 {
                        let pv = *pp.add((r + rr) * ldp + k * pks);
                        if pv != 0.0 {
                            acc[rr] = _mm256_fmadd_ps(_mm256_set1_ps(pv), wv, acc[rr]);
                        }
                    }
                }
                for rr in 0..8 {
                    _mm256_storeu_ps(op.add((r + rr) * ldo + c), acc[rr]);
                }
                r += 8;
            }
            while r < rows {
                let mut acc = _mm256_loadu_ps(op.add(r * ldo + c));
                for k in 0..kn {
                    let pv = *pp.add(r * ldp + k * pks);
                    if pv != 0.0 {
                        let wv = _mm256_loadu_ps(wp.add(k * ldw + c));
                        acc = _mm256_fmadd_ps(_mm256_set1_ps(pv), wv, acc);
                    }
                }
                _mm256_storeu_ps(op.add(r * ldo + c), acc);
                r += 1;
            }
            c += 8;
        }
        if c < cols {
            for r in 0..rows {
                for k in 0..kn {
                    let pv = *pp.add(r * ldp + k * pks);
                    if pv == 0.0 {
                        continue;
                    }
                    for j in c..cols {
                        *op.add(r * ldo + j) = pv.mul_add(*wp.add(k * ldw + j), *op.add(r * ldo + j));
                    }
                }
            }
        }
    }
}
