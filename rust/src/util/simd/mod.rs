//! Runtime-dispatched f32 inner-loop kernels shared by the forward
//! GEMM tile (`coordinator::inference`) and the backward kernels
//! (`runtime::backward`).
//!
//! Three primitives — [`axpy`], [`dot`], and the register-blocked
//! accumulating [`gemm_tile`] — each backed by per-architecture
//! implementations selected **once per process**:
//!
//! | backend    | arch      | selected when                  | bit-identical to portable |
//! |------------|-----------|--------------------------------|---------------------------|
//! | `portable` | any       | fallback / `CGCN_SIMD=portable`| (is the oracle)           |
//! | `avx2`     | x86_64    | AVX2 detected (default)        | yes                       |
//! | `fma`      | x86_64    | `CGCN_SIMD=fma` only           | no (fused rounding)       |
//! | `neon`     | aarch64   | always (mandatory feature)     | yes                       |
//!
//! The default pick is the most aggressive **bit-stable** backend, so
//! golden traces recorded under any default configuration replay
//! bitwise everywhere; `CGCN_SIMD=fma` opts into fused multiply-adds
//! with tolerance-only contracts.  The `CGCN_SIMD` env var is read
//! exactly once (first kernel call, or [`init`] from pool startup) —
//! per-backend A/B inside one process goes through [`BackendHandle`]
//! instead (see `tests/simd_parity.rs` and `examples/perf_probe.rs`).
//!
//! Numeric contracts (pinned by the parity suite):
//!
//! - [`axpy`] and [`gemm_tile`] compute each output element with
//!   ascending-index mul-then-add accumulation, so every bit-stable
//!   backend is bit-identical to the scalar oracles.
//! - [`dot`] accumulates 8 independent lanes reduced in a fixed order:
//!   deterministic at every call site and bit-identical across
//!   bit-stable backends, but *reassociated* relative to a sequential
//!   scalar sum — scalar-oracle parity uses a small tolerance.
#![deny(missing_docs)]

mod dispatch;
#[cfg(target_arch = "aarch64")]
mod neon;
pub mod portable;
#[cfg(target_arch = "x86_64")]
mod x86;

use dispatch::Table;

/// `y[i] += a * x[i]` over the common prefix via the active backend.
///
/// `x` and `y` must be the same length (debug-asserted); each element
/// is updated independently, so the result is bit-identical to the
/// naive scalar loop on every bit-stable backend.
#[inline]
pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    (dispatch::active().axpy)(y, x, a)
}

/// Dot product via the active backend: 8 lane accumulators reduced in
/// a fixed order.  Deterministic, but reassociated relative to a
/// sequential scalar sum (see module docs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    (dispatch::active().dot)(a, b)
}

/// Accumulating GEMM tile via the active backend:
/// `out[r][c] += Σ_k p(r, k) · w[k][c]` for `r < rows`, `c < cols`,
/// `k < kn`, where `out` has row stride `ldo`, `w` has row stride
/// `ldw`, and `p` is read as `p[r * ldp + k * pks]` — the k-stride
/// `pks` lets the same kernel compute `P·W` (`ldp = f`, `pks = 1`) and
/// `Pᵀ·W` (`ldp = 1`, `pks = f`) without materializing a transpose.
///
/// Accumulation per output element is ascending-k with a `p == 0.0`
/// skip (which also preserves signed zeros in `out`), matching the
/// scalar tile loops this replaced — bit-identical on every bit-stable
/// backend.
///
/// Panics if any slice is too short for the requested shape.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gemm_tile(
    out: &mut [f32],
    ldo: usize,
    p: &[f32],
    ldp: usize,
    pks: usize,
    w: &[f32],
    ldw: usize,
    rows: usize,
    kn: usize,
    cols: usize,
) {
    if rows == 0 || kn == 0 || cols == 0 {
        return;
    }
    assert_gemm_bounds(out, ldo, p, ldp, pks, w, ldw, rows, kn, cols);
    (dispatch::active().gemm_tile)(out, ldo, p, ldp, pks, w, ldw, rows, kn, cols)
}

#[allow(clippy::too_many_arguments)]
fn assert_gemm_bounds(
    out: &[f32],
    ldo: usize,
    p: &[f32],
    ldp: usize,
    pks: usize,
    w: &[f32],
    ldw: usize,
    rows: usize,
    kn: usize,
    cols: usize,
) {
    // Highest index touched in each operand (rows/kn/cols > 0 here).
    assert!(
        out.len() >= (rows - 1) * ldo + cols,
        "gemm_tile: out too short ({} < {})",
        out.len(),
        (rows - 1) * ldo + cols
    );
    assert!(
        p.len() >= (rows - 1) * ldp + (kn - 1) * pks + 1,
        "gemm_tile: p too short ({} < {})",
        p.len(),
        (rows - 1) * ldp + (kn - 1) * pks + 1
    );
    assert!(
        w.len() >= (kn - 1) * ldw + cols,
        "gemm_tile: w too short ({} < {})",
        w.len(),
        (kn - 1) * ldw + cols
    );
}

/// Name of the backend the process dispatches to (`portable`, `avx2`,
/// `fma`, or `neon`).  Resolves the dispatch table if not yet resolved.
pub fn active_backend() -> &'static str {
    dispatch::active().name
}

/// Force dispatch-table resolution now (reads `CGCN_SIMD` once).
/// Called from `util::pool::global()` startup so the selection cost and
/// the env read never land inside a timed kernel.
pub fn init() {
    let _ = dispatch::active();
}

/// A handle on one detected backend, for in-process A/B comparison
/// (parity suites, per-backend benchmarks) — the global dispatch table
/// resolves once per process and cannot be switched afterwards, so
/// comparing backends goes through handles instead of `CGCN_SIMD`.
#[derive(Clone, Copy)]
pub struct BackendHandle(&'static Table);

impl BackendHandle {
    /// Backend name (`portable`, `avx2`, `fma`, `neon`).
    pub fn name(self) -> &'static str {
        self.0.name
    }

    /// Whether every kernel is bit-identical to the portable oracle.
    pub fn bit_stable(self) -> bool {
        self.0.bit_stable
    }

    /// This backend's [`axpy`].
    pub fn axpy(self, y: &mut [f32], x: &[f32], a: f32) {
        debug_assert_eq!(y.len(), x.len());
        (self.0.axpy)(y, x, a)
    }

    /// This backend's [`dot`].
    pub fn dot(self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        (self.0.dot)(a, b)
    }

    /// This backend's [`gemm_tile`] (same bounds panics).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_tile(
        self,
        out: &mut [f32],
        ldo: usize,
        p: &[f32],
        ldp: usize,
        pks: usize,
        w: &[f32],
        ldw: usize,
        rows: usize,
        kn: usize,
        cols: usize,
    ) {
        if rows == 0 || kn == 0 || cols == 0 {
            return;
        }
        assert_gemm_bounds(out, ldo, p, ldp, pks, w, ldw, rows, kn, cols);
        (self.0.gemm_tile)(out, ldo, p, ldp, pks, w, ldw, rows, kn, cols)
    }
}

/// Handles on every backend usable on this host, detection-ordered
/// (`portable` always first).
pub fn available_backends() -> Vec<BackendHandle> {
    dispatch::candidates().into_iter().map(BackendHandle).collect()
}

/// Handle on one detected backend by name, if usable on this host.
pub fn backend(name: &str) -> Option<BackendHandle> {
    dispatch::candidates()
        .into_iter()
        .find(|t| t.name == name)
        .map(BackendHandle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_matches_scalar_bitwise() {
        for n in [0usize, 1, 7, 8, 9, 16, 33] {
            let x: Vec<f32> = (0..n).map(|i| (i as f32 - 3.5) * 0.37).collect();
            let mut y: Vec<f32> = (0..n).map(|i| (i as f32) * 0.11 - 1.0).collect();
            let mut expect = y.clone();
            let a = 0.73f32;
            for (e, &xv) in expect.iter_mut().zip(&x) {
                *e += a * xv;
            }
            axpy(&mut y, &x, a);
            for (got, want) in y.iter().zip(&expect) {
                assert_eq!(got.to_bits(), want.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn dot_close_to_scalar() {
        for n in [0usize, 1, 5, 8, 13, 64, 100] {
            let a: Vec<f32> = (0..n).map(|i| ((i * 7 % 11) as f32 - 5.0) * 0.1).collect();
            let b: Vec<f32> = (0..n).map(|i| ((i * 3 % 13) as f32 - 6.0) * 0.2).collect();
            let scalar: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!(
                (got - scalar).abs() <= 1e-5 * scalar.abs().max(1.0),
                "n={n}: {got} vs {scalar}"
            );
        }
    }

    #[test]
    fn dot_deterministic() {
        let a: Vec<f32> = (0..97).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..97).map(|i| (i as f32).cos()).collect();
        assert_eq!(dot(&a, &b).to_bits(), dot(&a, &b).to_bits());
    }

    /// The dispatched gemm_tile must match the naive ascending-k scalar
    /// loop bitwise — the active backend is always bit-stable unless
    /// the test runner forced `CGCN_SIMD=fma`, in which case skip.
    #[test]
    fn gemm_tile_matches_naive_bitwise() {
        if active_backend() == "fma" {
            return;
        }
        // shapes straddling the 8×8 blocking in every dimension
        for &(rows, kn, cols) in
            &[(1usize, 1usize, 1usize), (8, 8, 8), (9, 5, 17), (16, 3, 8), (7, 9, 23), (20, 16, 40)]
        {
            let ldo = cols + 3;
            let ldp = kn + 1;
            let ldw = cols + 2;
            let p: Vec<f32> = (0..rows * ldp)
                .map(|i| if i % 5 == 0 { 0.0 } else { (i as f32).sin() })
                .collect();
            let w: Vec<f32> = (0..kn * ldw).map(|i| (i as f32 * 0.31).cos()).collect();
            let base: Vec<f32> = (0..rows * ldo).map(|i| (i as f32) * 0.01 - 0.6).collect();
            let mut got = base.clone();
            gemm_tile(&mut got, ldo, &p, ldp, 1, &w, ldw, rows, kn, cols);
            let mut want = base.clone();
            for r in 0..rows {
                for k in 0..kn {
                    let pv = p[r * ldp + k];
                    if pv == 0.0 {
                        continue;
                    }
                    for c in 0..cols {
                        want[r * ldo + c] += pv * w[k * ldw + c];
                    }
                }
            }
            for (i, (g, e)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), e.to_bits(), "({rows},{kn},{cols}) idx {i}");
            }
        }
    }

    /// The pks stride computes Pᵀ·W bitwise-equal to materializing the
    /// transpose and using pks = 1.
    #[test]
    fn gemm_tile_k_stride_matches_transposed() {
        let (n, f, g) = (13usize, 9usize, 17usize);
        // p is n×f row-major; compute out = pᵀ·w  (f×g) two ways.
        let p: Vec<f32> = (0..n * f)
            .map(|i| if i % 4 == 0 { 0.0 } else { (i as f32 * 0.7).sin() })
            .collect();
        let w: Vec<f32> = (0..n * g).map(|i| (i as f32 * 0.13).cos()).collect();
        let mut strided = vec![0.1f32; f * g];
        let direct_base = strided.clone();
        // rows = f, contraction over the n dimension: p[r + k*f]
        gemm_tile(&mut strided, g, &p, 1, f, &w, g, f, n, g);
        let mut pt = vec![0f32; f * n];
        for i in 0..n {
            for j in 0..f {
                pt[j * n + i] = p[i * f + j];
            }
        }
        let mut direct = direct_base;
        gemm_tile(&mut direct, g, &pt, n, 1, &w, g, f, n, g);
        for (a, b) in strided.iter().zip(&direct) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn gemm_tile_zero_dims_are_noops() {
        let mut out = [1.0f32, 2.0];
        gemm_tile(&mut out, 2, &[], 0, 1, &[], 0, 0, 0, 0);
        gemm_tile(&mut out, 2, &[1.0], 1, 1, &[1.0, 1.0], 2, 1, 1, 0);
        assert_eq!(out, [1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "out too short")]
    fn gemm_tile_bounds_checked() {
        let mut out = [0f32; 3];
        gemm_tile(&mut out, 2, &[1.0, 1.0], 1, 1, &[1.0, 1.0], 2, 2, 1, 2);
    }

    #[test]
    fn handles_cover_portable_and_active() {
        let names: Vec<&str> = available_backends().iter().map(|h| h.name()).collect();
        assert!(names.contains(&"portable"));
        assert!(
            names.contains(&active_backend()),
            "active {} not in {names:?}",
            active_backend()
        );
        let h = backend("portable").unwrap();
        assert!(h.bit_stable());
        assert!(backend("no-such-backend").is_none());
    }
}
