//! NEON kernels for aarch64 (`core::arch::aarch64`).
//!
//! Compiles on aarch64 but is **not exercised by CI** (the CI hosts are
//! x86_64); the parity suite will cover it the first time the tests run
//! on an ARM box.  NEON is a mandatory aarch64 feature, so
//! `candidates()` includes this table unconditionally there.
//!
//! Numeric contract: bit-identical to portable.  Every multiply-add is
//! an explicit `vmulq_f32` + `vaddq_f32` pair — deliberately *not*
//! `vfmaq_f32`/`vmlaq_f32`, which may fuse — and `dot` emulates
//! portable's 8-lane accumulator structure with two 4-lane registers
//! advanced 8 elements per iteration, reduced in portable's exact
//! order.

#![cfg(target_arch = "aarch64")]

use super::dispatch::Table;

/// NEON: bit-identical to portable (non-fused multiply-adds).
pub static NEON: Table = Table {
    name: "neon",
    bit_stable: true,
    axpy: axpy_neon_safe,
    dot: dot_neon_safe,
    gemm_tile: gemm_tile_neon_safe,
};

fn axpy_neon_safe(y: &mut [f32], x: &[f32], a: f32) {
    // SAFETY: NEON is a mandatory aarch64 feature.
    unsafe { axpy_neon(y, x, a) }
}

fn dot_neon_safe(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: NEON is a mandatory aarch64 feature.
    unsafe { dot_neon(a, b) }
}

#[allow(clippy::too_many_arguments)]
fn gemm_tile_neon_safe(
    out: &mut [f32],
    ldo: usize,
    p: &[f32],
    ldp: usize,
    pks: usize,
    w: &[f32],
    ldw: usize,
    rows: usize,
    kn: usize,
    cols: usize,
) {
    // SAFETY: NEON is a mandatory aarch64 feature; slice bounds are
    // asserted by the public wrapper in `super`.
    unsafe { gemm_tile_neon(out, ldo, p, ldp, pks, w, ldw, rows, kn, cols) }
}

/// # Safety
/// Requires NEON (always present on aarch64). `y.len() == x.len()`.
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(y: &mut [f32], x: &[f32], a: f32) {
    unsafe {
        use core::arch::aarch64::*;
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let yp = y.as_mut_ptr();
        let xp = x.as_ptr();
        let av = vdupq_n_f32(a);
        let mut i = 0;
        while i + 4 <= n {
            let yv = vld1q_f32(yp.add(i));
            let xv = vld1q_f32(xp.add(i));
            // explicit mul + add (never vfmaq): portable rounding.
            vst1q_f32(yp.add(i), vaddq_f32(yv, vmulq_f32(av, xv)));
            i += 4;
        }
        while i < n {
            *yp.add(i) += a * *xp.add(i);
            i += 1;
        }
    }
}

/// # Safety
/// Requires NEON (always present on aarch64). `a.len() == b.len()`.
#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    unsafe {
        use core::arch::aarch64::*;
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        // Two 4-lane accumulators advanced 8 elements per iteration =
        // portable's 8 independent lanes (acc0 holds lanes 0..4, acc1
        // lanes 4..8), updated in the same vertical order.
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 8 <= n {
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i))));
            acc1 = vaddq_f32(
                acc1,
                vmulq_f32(vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4))),
            );
            i += 8;
        }
        let mut lanes = [0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        let mut tail = 0f32;
        while i < n {
            tail += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        // Portable's exact reduction order.
        let even = (lanes[0] + lanes[2]) + (lanes[4] + lanes[6]);
        let odd = (lanes[1] + lanes[3]) + (lanes[5] + lanes[7]);
        (even + odd) + tail
    }
}

/// Row/k loop over [`axpy_neon`] — ascending-k accumulation with the
/// portable zero-skip, so bit-identical to portable.
///
/// # Safety
/// Requires NEON (always present on aarch64).  Slice bounds per the
/// public wrapper's asserts.
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_tile_neon(
    out: &mut [f32],
    ldo: usize,
    p: &[f32],
    ldp: usize,
    pks: usize,
    w: &[f32],
    ldw: usize,
    rows: usize,
    kn: usize,
    cols: usize,
) {
    unsafe {
        for r in 0..rows {
            let or = &mut out[r * ldo..r * ldo + cols];
            for k in 0..kn {
                let pv = p[r * ldp + k * pks];
                if pv == 0.0 {
                    continue;
                }
                axpy_neon(or, &w[k * ldw..k * ldw + cols], pv);
            }
        }
    }
}
