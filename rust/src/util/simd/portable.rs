//! Portable chunked-lane kernels: the dispatch fallback on every
//! architecture and the **parity oracle** every specialized backend is
//! tested against (`tests/simd_parity.rs`).
//!
//! These are the original autovectorization-shaped loops: fixed-width
//! `[f32; 8]` chunks, the form LLVM reliably turns into packed vector
//! code even without explicit intrinsics.  They define the numeric
//! contracts of the whole module:
//!
//! - [`axpy`] computes every output element independently
//!   (`y[i] += a * x[i]`), so chunking does not change any result bit —
//!   kernels built on it stay bit-identical to their scalar oracles.
//! - [`dot`] accumulates into 8 independent lanes and reduces them in a
//!   fixed order, so it is deterministic at every call site, but it
//!   *reassociates* the sum relative to a strictly sequential scalar
//!   accumulation — parity tests against scalar oracles use a small
//!   tolerance instead of bit equality.
//! - [`gemm_tile`] accumulates each output element over `k` in
//!   ascending order with a zero-skip, exactly like the scalar tile
//!   loops it replaced, so it is bit-identical to them.

/// `y[i] += a * x[i]` over the common prefix, in `[f32; 8]` chunks.
///
/// `x` and `y` must be the same length (debug-asserted); each element is
/// updated independently, so the result is bit-identical to the naive
/// scalar loop.
#[inline]
pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (yy, xx) in (&mut yc).zip(&mut xc) {
        for l in 0..8 {
            yy[l] += a * xx[l];
        }
    }
    for (yv, &xv) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yv += a * xv;
    }
}

/// Dot product with 8 parallel lane accumulators and a fixed-order
/// horizontal reduction.  Deterministic, but reassociated relative to a
/// sequential scalar sum (see module docs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (aa, bb) in (&mut ac).zip(&mut bc) {
        for l in 0..8 {
            lanes[l] += aa[l] * bb[l];
        }
    }
    let mut tail = 0f32;
    for (&av, &bv) in ac.remainder().iter().zip(bc.remainder()) {
        tail += av * bv;
    }
    let even = (lanes[0] + lanes[2]) + (lanes[4] + lanes[6]);
    let odd = (lanes[1] + lanes[3]) + (lanes[5] + lanes[7]);
    (even + odd) + tail
}

/// Accumulating GEMM tile: `out[r][c] += Σ_k p(r, k) · w[k][c]`.
///
/// `out` has row stride `ldo`, `w` has row stride `ldw`, and `p` is
/// accessed as `p[r * ldp + k * pks]` — the extra k-stride `pks` lets
/// one kernel serve both `P·W` (`pks = 1`, `ldp = f`) and `Pᵀ·W`
/// (`pks = f`, `ldp = 1`) without materializing a transpose.
///
/// Per output element the accumulation runs over `k` ascending with a
/// `p == 0.0` skip, matching the scalar tile loops this replaced, so
/// the result is bit-identical to them (the skip also preserves signed
/// zeros: `-0.0 + 0.0` would flush the sign).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gemm_tile(
    out: &mut [f32],
    ldo: usize,
    p: &[f32],
    ldp: usize,
    pks: usize,
    w: &[f32],
    ldw: usize,
    rows: usize,
    kn: usize,
    cols: usize,
) {
    for r in 0..rows {
        let or = &mut out[r * ldo..r * ldo + cols];
        for k in 0..kn {
            let pv = p[r * ldp + k * pks];
            if pv == 0.0 {
                continue;
            }
            axpy(or, &w[k * ldw..k * ldw + cols], pv);
        }
    }
}
