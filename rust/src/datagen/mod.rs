//! Synthetic dataset generation (stand-ins for the paper's datasets —
//! DESIGN.md §4): SBM topology + community-correlated labels/features.

pub mod features;
pub mod presets;
pub mod sbm;
pub mod stream;

pub use presets::{build, build_cached, preset, Preset, PRESETS};
pub use sbm::{generate, SbmGraph, SbmSpec};
pub use stream::{build_cached_store, build_store};
