//! Label/community-correlated feature + label models layered on the SBM
//! topology (DESIGN.md §4): features are Gaussian mixtures around
//! class + community centroids, so a GCN can actually learn — and
//! deeper propagation genuinely helps (neighbors share community, hence
//! centroid), mirroring why depth pays off on PPI in the paper.

use crate::graph::{Labels, Task};
use crate::util::Rng;

pub struct LabelModel {
    pub task: Task,
    pub classes: usize,
    /// multiclass: probability a node keeps its community's class;
    /// multilabel: per-class flip noise.
    pub noise: f64,
    /// multilabel only: how many classes a community switches "on".
    pub active_per_community: usize,
}

/// Assign labels given community structure.
pub fn gen_labels(
    model: &LabelModel,
    community: &[u32],
    communities: usize,
    rng: &mut Rng,
) -> Labels {
    let n = community.len();
    match model.task {
        Task::Multiclass => {
            // each community leans to one dominant class
            let dominant: Vec<u32> = (0..communities)
                .map(|_| rng.below(model.classes as u64) as u32)
                .collect();
            let mut labels = vec![0u32; n];
            for v in 0..n {
                labels[v] = if rng.f64() < model.noise {
                    rng.below(model.classes as u64) as u32
                } else {
                    dominant[community[v] as usize]
                };
            }
            Labels::Multiclass(labels)
        }
        Task::Multilabel => {
            // each community activates a subset of classes
            let mut active: Vec<Vec<bool>> = Vec::with_capacity(communities);
            for _ in 0..communities {
                let mut on = vec![false; model.classes];
                let k = model.active_per_community.min(model.classes);
                for idx in rng.sample_distinct(model.classes, k) {
                    on[idx] = true;
                }
                active.push(on);
            }
            let mut labels = Labels::multilabel_new(n, model.classes);
            for v in 0..n {
                let on = &active[community[v] as usize];
                for (c, &is_on) in on.iter().enumerate() {
                    let p = if is_on { 0.85 } else { 0.03 };
                    let p = if rng.f64() < model.noise { 1.0 - p } else { p };
                    if rng.f64() < p {
                        labels.set_label(v, c);
                    }
                }
            }
            labels
        }
    }
}

/// The centroid mixture behind [`gen_features`], split out so the
/// streaming generator (`datagen::stream`) can produce raw feature rows
/// one chunk at a time with the exact same RNG draws: centroids are
/// sampled up front, then each row consumes its per-node draws in node
/// order.
pub struct FeatureModel {
    class_c: Vec<Vec<f32>>,
    comm_c: Vec<Vec<f32>>,
    classes: usize,
    f_in: usize,
    noise: f64,
}

impl FeatureModel {
    /// Sample class + community centroids (consumes the centroid draws
    /// of [`gen_features`], in the same order).
    pub fn new(
        classes: usize,
        communities: usize,
        f_in: usize,
        noise: f64,
        rng: &mut Rng,
    ) -> FeatureModel {
        let centroid = |rng: &mut Rng| -> Vec<f32> {
            (0..f_in).map(|_| rng.normal() as f32 * 0.8).collect()
        };
        let class_c: Vec<Vec<f32>> = (0..classes).map(|_| centroid(rng)).collect();
        let comm_c: Vec<Vec<f32>> = (0..communities).map(|_| centroid(rng)).collect();
        FeatureModel { class_c, comm_c, classes, f_in, noise }
    }

    /// Fill `row` (length `f_in`) with node `v`'s *raw* (unstandardized)
    /// features. Rows must be generated in node order for RNG parity
    /// with [`gen_features`].
    pub fn raw_row(
        &self,
        v: usize,
        labels: &Labels,
        community: &[u32],
        rng: &mut Rng,
        row: &mut [f32],
    ) {
        debug_assert_eq!(row.len(), self.f_in);
        row.iter_mut().for_each(|x| *x = 0.0);
        let f_in = self.f_in;
        let noise = self.noise;
        let cc = &self.comm_c[community[v] as usize];
        match labels {
            Labels::Multiclass(l) => {
                let lc = &self.class_c[l[v] as usize];
                for j in 0..f_in {
                    row[j] = lc[j] + 0.5 * cc[j] + noise as f32 * rng.normal() as f32;
                }
            }
            Labels::Multilabel { .. } => {
                // average of active class centroids
                let mut cnt = 0f32;
                for c in 0..self.classes {
                    if labels.has_label(v, c) {
                        for j in 0..f_in {
                            row[j] += self.class_c[c][j];
                        }
                        cnt += 1.0;
                    }
                }
                let inv = if cnt > 0.0 { 1.0 / cnt } else { 0.0 };
                for j in 0..f_in {
                    row[j] = row[j] * inv + 0.5 * cc[j]
                        + noise as f32 * rng.normal() as f32;
                }
            }
        }
    }
}

/// Features: class-centroid + community-centroid + white noise,
/// row-major [n, f_in].
pub fn gen_features(
    labels: &Labels,
    community: &[u32],
    communities: usize,
    classes: usize,
    f_in: usize,
    noise: f64,
    rng: &mut Rng,
) -> Vec<f32> {
    let n = community.len();
    let model = FeatureModel::new(classes, communities, f_in, noise, rng);
    let mut x = vec![0f32; n * f_in];
    for v in 0..n {
        model.raw_row(v, labels, community, rng, &mut x[v * f_in..(v + 1) * f_in]);
    }
    // feature normalization (paper §6.2 "feature normalization is also
    // conducted"): per-feature standardization.
    for j in 0..f_in {
        let mut mean = 0f64;
        for v in 0..n {
            mean += x[v * f_in + j] as f64;
        }
        mean /= n as f64;
        let mut var = 0f64;
        for v in 0..n {
            let d = x[v * f_in + j] as f64 - mean;
            var += d * d;
        }
        let std = (var / n as f64).sqrt().max(1e-6);
        for v in 0..n {
            x[v * f_in + j] = ((x[v * f_in + j] as f64 - mean) / std) as f32;
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiclass_labels_in_range() {
        let mut rng = Rng::new(1);
        let community: Vec<u32> = (0..500).map(|i| (i % 10) as u32).collect();
        let m = LabelModel {
            task: Task::Multiclass,
            classes: 7,
            noise: 0.1,
            active_per_community: 0,
        };
        let labels = gen_labels(&m, &community, 10, &mut rng);
        if let Labels::Multiclass(v) = &labels {
            assert!(v.iter().all(|&c| c < 7));
            // same community should be mostly one class
            let c0: Vec<u32> = (0..500).filter(|i| i % 10 == 0).map(|i| v[i]).collect();
            let mut h = [0usize; 7];
            for &c in &c0 {
                h[c as usize] += 1;
            }
            assert!(*h.iter().max().unwrap() as f64 > 0.6 * c0.len() as f64);
        } else {
            panic!("wrong labels kind");
        }
    }

    #[test]
    fn multilabel_density() {
        let mut rng = Rng::new(2);
        let community: Vec<u32> = (0..400).map(|i| (i % 4) as u32).collect();
        let m = LabelModel {
            task: Task::Multilabel,
            classes: 50,
            noise: 0.02,
            active_per_community: 15,
        };
        let labels = gen_labels(&m, &community, 4, &mut rng);
        let mut on = 0usize;
        for v in 0..400 {
            for c in 0..50 {
                if labels.has_label(v, c) {
                    on += 1;
                }
            }
        }
        let per_node = on as f64 / 400.0;
        // ~ 15*0.85 + 35*0.03 ≈ 13.8
        assert!(per_node > 9.0 && per_node < 19.0, "per_node={per_node}");
    }

    #[test]
    fn features_standardized() {
        let mut rng = Rng::new(3);
        let community: Vec<u32> = (0..300).map(|i| (i % 3) as u32).collect();
        let labels = Labels::Multiclass((0..300).map(|i| (i % 5) as u32).collect());
        let x = gen_features(&labels, &community, 3, 5, 16, 0.5, &mut rng);
        assert_eq!(x.len(), 300 * 16);
        for j in 0..16 {
            let mean: f64 = (0..300).map(|v| x[v * 16 + j] as f64).sum::<f64>() / 300.0;
            assert!(mean.abs() < 1e-3, "feature {j} mean {mean}");
        }
    }

    #[test]
    fn features_separate_classes() {
        // nodes of the same class should be closer in feature space
        let mut rng = Rng::new(4);
        let community = vec![0u32; 200];
        let labels = Labels::Multiclass(
            (0..200).map(|i| if i < 100 { 0 } else { 1 }).collect(),
        );
        let x = gen_features(&labels, &community, 1, 2, 8, 0.3, &mut rng);
        let centroid = |lo: usize, hi: usize| -> Vec<f64> {
            let mut c = vec![0f64; 8];
            for v in lo..hi {
                for j in 0..8 {
                    c[j] += x[v * 8 + j] as f64;
                }
            }
            c.iter().map(|s| s / (hi - lo) as f64).collect()
        };
        let c0 = centroid(0, 100);
        let c1 = centroid(100, 200);
        let dist: f64 = c0
            .iter()
            .zip(&c1)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(dist > 0.5, "class centroids not separated: {dist}");
    }
}
