//! Named dataset presets — scaled synthetic stand-ins for the paper's
//! datasets (Table 3 / Table 12; substitutions documented in DESIGN.md
//! §4).  Feature dims are padded to multiples the kernels tile well
//! (e.g. PPI's 50 -> 64).  Shapes must stay in sync with
//! `python/compile/manifest.py`.

use crate::graph::{Dataset, Split, Task};
use crate::util::Rng;

use super::features::{gen_features, gen_labels, LabelModel};
use super::sbm::{generate, SbmSpec};

#[derive(Clone, Debug)]
pub struct Preset {
    pub name: &'static str,
    pub task: Task,
    pub n: usize,
    pub communities: usize,
    pub avg_deg: f64,
    pub intra_frac: f64,
    pub classes: usize,
    pub f_in: usize,
    pub label_noise: f64,
    pub feat_noise: f64,
    pub active_per_community: usize,
    /// (train, val) fractions; test = remainder.
    pub split: (f64, f64),
    /// default #partitions (paper Table 4) and clusters per batch.
    pub default_partitions: usize,
    pub default_q: usize,
    /// padded batch size — must match the AOT manifest's b_max.
    pub b_max: usize,
    pub f_hid: usize,
}

pub const PRESETS: &[Preset] = &[
    // Table 2 datasets -----------------------------------------------------
    Preset {
        name: "cora_like",
        task: Task::Multiclass,
        n: 2708,
        communities: 28,
        avg_deg: 4.9,
        intra_frac: 0.83,
        classes: 7,
        f_in: 128,
        label_noise: 0.12,
        feat_noise: 1.0,
        active_per_community: 0,
        split: (0.60, 0.20),
        default_partitions: 10,
        default_q: 1,
        b_max: 512,
        f_hid: 128,
    },
    Preset {
        name: "pubmed_like",
        task: Task::Multiclass,
        n: 19_717,
        communities: 60,
        avg_deg: 5.5,
        intra_frac: 0.82,
        classes: 3,
        f_in: 128,
        label_noise: 0.15,
        feat_noise: 1.2,
        active_per_community: 0,
        split: (0.60, 0.20),
        default_partitions: 10,
        default_q: 1,
        b_max: 2560,
        f_hid: 128,
    },
    // PPI: 56,944 nodes scaled 1/4; multilabel 121 classes ----------------
    Preset {
        name: "ppi_like",
        task: Task::Multilabel,
        n: 14_236,
        communities: 110,
        avg_deg: 28.8,
        intra_frac: 0.88,
        classes: 121,
        f_in: 64,
        label_noise: 0.03,
        feat_noise: 0.9,
        active_per_community: 30,
        split: (0.79, 0.11),
        default_partitions: 50,
        default_q: 1,
        b_max: 512,
        f_hid: 512,
    },
    // Reddit: 232,965 nodes scaled ~1/6.5; degree scaled 99.6 -> 50 -------
    Preset {
        name: "reddit_like",
        task: Task::Multiclass,
        n: 36_000,
        communities: 450,
        avg_deg: 50.0,
        intra_frac: 0.87,
        classes: 41,
        f_in: 128,
        label_noise: 0.08,
        feat_noise: 1.0,
        active_per_community: 0,
        split: (0.66, 0.10),
        default_partitions: 1500,
        default_q: 20,
        b_max: 768,
        f_hid: 128,
    },
    // Amazon: 334,863 nodes scaled ~1/8; paper has no features (identity);
    // we substitute low-dim random-projection features (DESIGN.md §4).
    Preset {
        name: "amazon_like",
        task: Task::Multilabel,
        n: 40_000,
        communities: 320,
        avg_deg: 5.5,
        intra_frac: 0.85,
        classes: 58,
        f_in: 64,
        label_noise: 0.04,
        feat_noise: 1.1,
        active_per_community: 12,
        split: (0.27, 0.05),
        default_partitions: 200,
        default_q: 1,
        b_max: 384,
        f_hid: 128,
    },
    // Amazon2M: 2,449,029 nodes scaled ~1/15; degree 50.5 -> 25 -----------
    Preset {
        name: "amazon2m_like",
        task: Task::Multiclass,
        n: 160_000,
        communities: 1400,
        avg_deg: 25.0,
        intra_frac: 0.86,
        classes: 47,
        f_in: 100,
        label_noise: 0.10,
        feat_noise: 1.1,
        active_per_community: 0,
        split: (0.70, 0.05),
        default_partitions: 1200,
        default_q: 10,
        b_max: 1792,
        f_hid: 400,
    },
    // Amazon2M at full paper scale: 2M nodes, ~61M sampled edges
    // (Table 8). Only generatable via `datagen::stream::build_store`
    // (the in-RAM `build` would need ~2.5 GB for the edge list + CSR +
    // feature matrix alone); many small partitions keep the dense
    // batch block b_max² tiny, matching the paper's Amazon2M setting
    // (10,000 partitions).
    Preset {
        name: "amazon2m_full",
        task: Task::Multiclass,
        n: 2_000_000,
        communities: 16_000,
        avg_deg: 61.0,
        intra_frac: 0.86,
        classes: 47,
        f_in: 100,
        label_noise: 0.10,
        feat_noise: 1.1,
        active_per_community: 0,
        split: (0.70, 0.05),
        default_partitions: 10_000,
        default_q: 2,
        b_max: 1024,
        f_hid: 400,
    },
];

pub fn preset(name: &str) -> Option<&'static Preset> {
    PRESETS.iter().find(|p| p.name == name)
}

/// Generate the dataset for a preset (deterministic in `seed`).
pub fn build(p: &Preset, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xC1A5_7E2C_6C4E_5EED);
    let sbm = generate(
        &SbmSpec {
            n: p.n,
            communities: p.communities,
            avg_deg: p.avg_deg,
            intra_frac: p.intra_frac,
            size_skew: 1.5,
        },
        &mut rng,
    );
    let labels = gen_labels(
        &LabelModel {
            task: p.task,
            classes: p.classes,
            noise: p.label_noise,
            active_per_community: p.active_per_community,
        },
        &sbm.community,
        p.communities,
        &mut rng,
    );
    let features = gen_features(
        &labels,
        &sbm.community,
        p.communities,
        p.classes,
        p.f_in,
        p.feat_noise,
        &mut rng,
    );
    let split = (0..p.n)
        .map(|_| {
            let r = rng.f64();
            if r < p.split.0 {
                Split::Train
            } else if r < p.split.0 + p.split.1 {
                Split::Val
            } else {
                Split::Test
            }
        })
        .collect();
    let ds = Dataset {
        name: p.name.to_string(),
        task: p.task,
        graph: sbm.graph,
        f_in: p.f_in,
        num_classes: p.classes,
        features,
        labels,
        split,
    };
    debug_assert!(ds.validate().is_ok());
    ds
}

/// Build or load from the on-disk cache under `dir`.
pub fn build_cached(p: &Preset, seed: u64, dir: &std::path::Path) -> std::io::Result<Dataset> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{}_s{}.bin", p.name, seed));
    if path.exists() {
        if let Ok(ds) = crate::graph::io::load(&path) {
            return Ok(ds);
        }
    }
    let ds = build(p, seed);
    crate::graph::io::save(&ds, &path)?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cora_like_builds_and_validates() {
        let p = preset("cora_like").unwrap();
        let ds = build(p, 42);
        ds.validate().unwrap();
        assert_eq!(ds.n(), 2708);
        assert_eq!(ds.num_classes, 7);
        let (tr, va, te) = ds.split_counts();
        assert!(tr > va && tr > te && va > 0 && te > 0);
    }

    #[test]
    fn ppi_like_multilabel() {
        let p = preset("ppi_like").unwrap();
        let ds = build(p, 42);
        ds.validate().unwrap();
        assert_eq!(ds.task, Task::Multilabel);
        // mean labels per node should be ~ active * 0.85
        let h = ds.label_histogram(&(0..200u32).collect::<Vec<_>>());
        let per_node: f64 = h.iter().sum::<usize>() as f64 / 200.0;
        assert!(per_node > 5.0, "labels too sparse: {per_node}");
    }

    #[test]
    fn all_presets_resolve() {
        for p in PRESETS {
            assert!(preset(p.name).is_some());
            assert!(p.b_max % 128 == 0, "{} b_max not tile aligned", p.name);
        }
    }

    #[test]
    fn deterministic() {
        let p = preset("cora_like").unwrap();
        let a = build(p, 7);
        let b = build(p, 7);
        assert_eq!(a.graph.cols, b.graph.cols);
        assert_eq!(a.features, b.features);
    }
}
