//! Streaming preset generation straight into the on-disk `CGCNGS01`
//! store — the path that makes the million-node presets feasible.
//!
//! [`presets::build`] materializes the edge list, the CSR, and the full
//! feature matrix (~2.5 GB for `amazon2m_full`); [`build_store`] emits
//! the same dataset with only O(chunk) residency:
//!
//! * **Edges** are bucketed by row chunk into ≤256 temp files as they
//!   are sampled (each undirected pair lands in both endpoints'
//!   buckets), then each bucket is sorted + deduplicated per row and
//!   appended to the store — replicating `Csr::from_edges` semantics
//!   (self loops dropped, per-row sorted dedup) one bucket at a time.
//! * **Features** are written raw chunk-by-chunk, then standardized in
//!   place with three chunked passes over the store file (mean, var,
//!   rewrite) via [`StoreWriter::for_each_feature_chunk_mut`]. The per-
//!   column f64 accumulations visit rows in the same ascending order as
//!   the in-RAM path, so the results are bit-identical.
//!
//! The RNG stream is consumed in exactly [`presets::build`]'s order
//! (layout → edges → labels → centroids → feature rows → splits), so
//! `build_store(p, seed)` produces a file **byte-identical** to
//! `write_store(&build(p, seed))` — pinned by tests. Per-node arrays
//! (community map, labels, splits: a few bytes/node) stay resident; the
//! adjacency and feature matrix never do.

use std::fs::{self, File};
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::graph::store::{chunk_ranges, DEFAULT_CHUNK_ROWS};
use crate::graph::{DiskDataset, Labels, Split, StoreError, StoreMeta, StoreWriter};
use crate::util::Rng;

use super::features::{gen_labels, FeatureModel, LabelModel};
use super::presets::Preset;
use super::sbm::{emit_edges, layout, SbmSpec};

/// Cap on edge-bucket temp files (and thus file descriptors).
const MAX_BUCKETS: usize = 256;

/// Directed edge records bucketed by source-row range, spilled to temp
/// files next to the output store.
struct EdgeBuckets {
    dir: PathBuf,
    writers: Vec<BufWriter<File>>,
    rows_per_bucket: usize,
    err: Option<io::Error>,
}

impl EdgeBuckets {
    fn create(dir: PathBuf, n: usize) -> io::Result<EdgeBuckets> {
        if dir.exists() {
            fs::remove_dir_all(&dir)?;
        }
        fs::create_dir_all(&dir)?;
        let rows_per_bucket = n.div_ceil(MAX_BUCKETS).max(1);
        let buckets = n.div_ceil(rows_per_bucket);
        let mut writers = Vec::with_capacity(buckets);
        for b in 0..buckets {
            let f = File::create(dir.join(format!("edges_{b:03}.bin")))?;
            writers.push(BufWriter::new(f));
        }
        Ok(EdgeBuckets { dir, writers, rows_per_bucket, err: None })
    }

    fn buckets(&self) -> usize {
        self.writers.len()
    }

    fn row_range(&self, b: usize, n: usize) -> std::ops::Range<usize> {
        let lo = b * self.rows_per_bucket;
        lo..(lo + self.rows_per_bucket).min(n)
    }

    fn push_record(&mut self, row: u32, partner: u32) {
        if self.err.is_some() {
            return;
        }
        let b = row as usize / self.rows_per_bucket;
        let mut rec = [0u8; 8];
        rec[..4].copy_from_slice(&row.to_le_bytes());
        rec[4..].copy_from_slice(&partner.to_le_bytes());
        if let Err(e) = self.writers[b].write_all(&rec) {
            self.err = Some(e);
        }
    }

    /// Record an undirected pair under both endpoints (self loops are
    /// dropped here, matching `Csr::from_edges`).
    fn push_pair(&mut self, u: u32, v: u32) {
        if u == v {
            return;
        }
        self.push_record(u, v);
        self.push_record(v, u);
    }

    /// Flush writers and surface any deferred write error.
    fn seal(&mut self) -> io::Result<()> {
        if let Some(e) = self.err.take() {
            return Err(e);
        }
        for w in &mut self.writers {
            w.flush()?;
        }
        Ok(())
    }

    /// Load one bucket's records (the only edge state ever resident:
    /// ~2·nnz/buckets entries).
    fn read_bucket(&self, b: usize, out: &mut Vec<(u32, u32)>) -> io::Result<()> {
        let bytes = fs::read(self.dir.join(format!("edges_{b:03}.bin")))?;
        if bytes.len() % 8 != 0 {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "torn edge record"));
        }
        out.clear();
        out.reserve(bytes.len() / 8);
        for c in bytes.chunks_exact(8) {
            out.push((
                u32::from_le_bytes([c[0], c[1], c[2], c[3]]),
                u32::from_le_bytes([c[4], c[5], c[6], c[7]]),
            ));
        }
        Ok(())
    }

    fn cleanup(self) {
        let _ = fs::remove_dir_all(&self.dir);
    }
}

/// Generate preset `p` directly into an on-disk store at `out` without
/// ever materializing the adjacency or feature matrix. Byte-identical
/// to `write_store(&build(p, seed), out)`.
pub fn build_store(
    p: &Preset,
    seed: u64,
    out: &Path,
    chunk_rows: usize,
) -> Result<(), StoreError> {
    let chunk_rows = if chunk_rows == 0 { DEFAULT_CHUNK_ROWS } else { chunk_rows };
    let mut rng = Rng::new(seed ^ 0xC1A5_7E2C_6C4E_5EED);
    let spec = SbmSpec {
        n: p.n,
        communities: p.communities,
        avg_deg: p.avg_deg,
        intra_frac: p.intra_frac,
        size_skew: 1.5,
    };

    // --- layout + edge sampling → buckets (build()'s draw order) -------
    let (community, members) = layout(&spec, &mut rng);
    let file_name = out
        .file_name()
        .map(|f| f.to_string_lossy().into_owned())
        .unwrap_or_else(|| "store".into());
    let tmp = out.with_file_name(format!("{file_name}.edges-tmp"));
    let mut buckets = EdgeBuckets::create(tmp, p.n)?;
    emit_edges(&spec, &members, &mut rng, |u, v| buckets.push_pair(u, v));
    buckets.seal()?;
    drop(members);

    // --- labels (resident: a few bytes per node) ------------------------
    let labels = gen_labels(
        &LabelModel {
            task: p.task,
            classes: p.classes,
            noise: p.label_noise,
            active_per_community: p.active_per_community,
        },
        &community,
        p.communities,
        &mut rng,
    );

    // --- adjacency rows: per-bucket sort + per-row dedup ----------------
    let meta = StoreMeta {
        name: p.name.to_string(),
        task: p.task,
        n: p.n,
        f_in: p.f_in,
        num_classes: p.classes,
    };
    let mut w = StoreWriter::create(out, meta)?;
    let mut recs: Vec<(u32, u32)> = Vec::new();
    let mut row_buf: Vec<u32> = Vec::new();
    for b in 0..buckets.buckets() {
        buckets.read_bucket(b, &mut recs)?;
        recs.sort_unstable();
        let mut i = 0;
        for v in buckets.row_range(b, p.n) {
            row_buf.clear();
            while i < recs.len() && recs[i].0 as usize == v {
                let partner = recs[i].1;
                if row_buf.last() != Some(&partner) {
                    row_buf.push(partner);
                }
                i += 1;
            }
            w.push_neighbor_row(&row_buf)?;
        }
        debug_assert_eq!(i, recs.len(), "edge record outside bucket row range");
    }
    buckets.cleanup();

    // --- raw feature rows, chunk at a time ------------------------------
    let fm = FeatureModel::new(p.classes, p.communities, p.f_in, p.feat_noise, &mut rng);
    let mut chunk = Vec::new();
    for r in chunk_ranges(p.n, chunk_rows) {
        chunk.resize((r.end - r.start) * p.f_in, 0.0f32);
        for v in r.clone() {
            let lo = (v - r.start) * p.f_in;
            fm.raw_row(v, &labels, &community, &mut rng, &mut chunk[lo..lo + p.f_in]);
        }
        w.push_feature_rows(&chunk)?;
    }

    // --- 3-pass chunked standardization (bit-equal to gen_features'
    //     per-column two-pass: each column's f64 accumulator sees rows
    //     in the same ascending order) --------------------------------
    let n = p.n as f64;
    let f_in = p.f_in;
    let mut mean = vec![0f64; f_in];
    w.for_each_feature_chunk_mut(chunk_rows, |_, vals| {
        for row in vals.chunks_exact(f_in) {
            for (m, &x) in mean.iter_mut().zip(row) {
                *m += x as f64;
            }
        }
    })?;
    mean.iter_mut().for_each(|m| *m /= n);
    let mut var = vec![0f64; f_in];
    w.for_each_feature_chunk_mut(chunk_rows, |_, vals| {
        for row in vals.chunks_exact(f_in) {
            for j in 0..f_in {
                let d = row[j] as f64 - mean[j];
                var[j] += d * d;
            }
        }
    })?;
    let std: Vec<f64> = var.iter().map(|v| (v / n).sqrt().max(1e-6)).collect();
    w.for_each_feature_chunk_mut(chunk_rows, |_, vals| {
        for row in vals.chunks_exact_mut(f_in) {
            for j in 0..f_in {
                row[j] = ((row[j] as f64 - mean[j]) / std[j]) as f32;
            }
        }
    })?;

    // --- labels + splits (build()'s draw order) -------------------------
    match &labels {
        Labels::Multiclass(y) => {
            for &c in y {
                w.push_class(c)?;
            }
        }
        Labels::Multilabel { bits, words_per_node } => {
            for v in 0..p.n {
                w.push_label_words(&bits[v * words_per_node..(v + 1) * words_per_node])?;
            }
        }
    }
    for _ in 0..p.n {
        let r = rng.f64();
        w.push_split(if r < p.split.0 {
            Split::Train
        } else if r < p.split.0 + p.split.1 {
            Split::Val
        } else {
            Split::Test
        })?;
    }
    w.finish()
}

/// Build or reuse the cached on-disk store `{name}_s{seed}.store` under
/// `dir`; the streamed twin of [`presets::build_cached`].
pub fn build_cached_store(
    p: &Preset,
    seed: u64,
    dir: &Path,
    chunk_rows: usize,
) -> Result<DiskDataset, StoreError> {
    fs::create_dir_all(dir).map_err(StoreError::Io)?;
    let path = dir.join(format!("{}_s{}.store", p.name, seed));
    if path.exists() {
        if let Ok(ds) = DiskDataset::open(&path) {
            return Ok(ds);
        }
    }
    build_store(p, seed, &path, chunk_rows)?;
    DiskDataset::open(&path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::presets::build;
    use crate::graph::{write_store, Task};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("cgcn_stream_{}_{}", tag, std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn tiny(task: Task) -> Preset {
        Preset {
            name: "stream_tiny",
            task,
            n: 600,
            communities: 8,
            avg_deg: 6.0,
            intra_frac: 0.85,
            classes: if task == Task::Multilabel { 70 } else { 5 },
            f_in: 9,
            label_noise: 0.1,
            feat_noise: 1.0,
            active_per_community: 12,
            split: (0.6, 0.2),
            default_partitions: 4,
            default_q: 1,
            b_max: 256,
            f_hid: 16,
        }
    }

    #[test]
    fn byte_identical_to_in_ram_build() {
        for task in [Task::Multiclass, Task::Multilabel] {
            let p = tiny(task);
            let dir = tmpdir(match task {
                Task::Multiclass => "mc",
                Task::Multilabel => "ml",
            });
            let ram_path = dir.join("ram.store");
            let stream_path = dir.join("stream.store");
            write_store(&build(&p, 11), &ram_path).unwrap();
            build_store(&p, 11, &stream_path, 37).unwrap();
            let a = fs::read(&ram_path).unwrap();
            let b = fs::read(&stream_path).unwrap();
            assert_eq!(a, b, "stream/{:?} bytes differ from in-RAM build", task);
            let _ = fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn chunk_size_does_not_change_bytes() {
        let p = tiny(Task::Multiclass);
        let dir = tmpdir("chunks");
        let mut files = Vec::new();
        for (i, chunk_rows) in [1usize, 101, 0].into_iter().enumerate() {
            let path = dir.join(format!("c{i}.store"));
            build_store(&p, 3, &path, chunk_rows).unwrap();
            files.push(fs::read(&path).unwrap());
        }
        for f in &files[1..] {
            assert_eq!(f, &files[0]);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn cached_store_roundtrips_and_verifies() {
        let p = tiny(Task::Multiclass);
        let dir = tmpdir("cache");
        let ds = build_cached_store(&p, 5, &dir, 64).unwrap();
        assert_eq!(ds.n(), 600);
        ds.verify_data().unwrap();
        // second call hits the cache (no rebuild: mtime untouched)
        let again = build_cached_store(&p, 5, &dir, 64).unwrap();
        assert_eq!(again.n(), 600);
        let _ = fs::remove_dir_all(&dir);
    }
}
